"""Methods — flip-rate measurement.

The paper's flip rate = N p-bits updated per local clock (all N flip
attempts per sweep), measured with on-chip counters.  Here: measured
sweeps/s x N x R for every registry engine at equal problem size, with the
lattice path measured both through the fused multi-phase kernel (one launch
per ``sync_every`` sweeps — the production dispatch) and through the seed's
per-phase reference dispatch (one launch per color phase).

Writes the usual reports/bench/flip_rate.json detail plus BENCH_flip_rate.json
at the repo root recording the fused-vs-per-phase speedup against the seed
lattice path.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.engines import make_engine
from repro.core.graph import ea3d
from repro.core.coloring import lattice3d_coloring
from repro.core.partition import slab_partition
from repro.core.annealing import constant_schedule

from .common import save_detail, row

ROOT_BENCH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_flip_rate.json")
SYNC = 8          # the seed benchmark's boundary-exchange period


def _rate(handle, sweeps: int, sync, reps: int = 9) -> float:
    """Best-of-N sweeps/s: on a contended host every disturbance only slows
    a rep down, so the max over reps is the least-biased throughput
    estimate (medians swing ~2x under this container's scheduler)."""
    sch = constant_schedule(3.0, 8 * sweeps)
    warm = handle.init_state(seed=0)
    handle.run_recorded(warm, sch, [sweeps], sync_every=sync)  # compile
    vals = []
    for _ in range(reps):
        st = handle.init_state(seed=0)
        t0 = time.perf_counter()
        handle.run_recorded(st, sch, [sweeps], sync_every=sync)
        vals.append(sweeps / (time.perf_counter() - t0))
    return float(np.max(vals))


def run(quick: bool = True, engine: str = None, replicas: int = 1):
    L = 8 if quick else 16
    sweeps = 1024 if quick else 8192
    R = max(int(replicas), 1)
    g = ea3d(L, seed=0)
    col = lattice3d_coloring(L)

    # lazy handle thunks: only the paths that survive the --engine filter
    # are ever constructed (lattice builds are seconds at --full size)
    thunks = {
        "monolithic": lambda: make_engine("gibbs", g, coloring=col,
                                          rng="lfsr", replicas=R),
        "dsim_stacked": lambda: make_engine("dsim", g, coloring=col,
                                            rng="lfsr", K=4,
                                            labels=slab_partition(L, 4),
                                            replicas=R),
        "lattice_kernel": lambda: make_engine("lattice", L=L, seed=0,
                                              impl="ref", fused=True,
                                              replicas=R),
        "lattice_per_phase": lambda: make_engine("lattice", L=L, seed=0,
                                                 impl="ref", fused=False,
                                                 replicas=R),
    }
    if engine == "dsim_dist":
        # single-device shard_map path (K=1): measures the distributed
        # backend's per-chunk overhead without needing a forced device count
        thunks = {"dsim_dist_k1": lambda: make_engine(
            "dsim_dist", g, coloring=col, K=1,
            labels=np.zeros(g.n, np.int32), rng="lfsr", replicas=R)}
    elif engine is not None:
        keep = {"gibbs": ["monolithic"], "dsim": ["dsim_stacked"],
                "lattice": ["lattice_kernel", "lattice_per_phase"]}
        names = keep.get(engine, [engine])
        thunks = {k: v for k, v in thunks.items() if k in names}
        if not thunks:
            raise ValueError(f"no flip-rate path for engine {engine!r}")
    handles = {k: mk() for k, mk in thunks.items()}

    n = g.n
    out, sync_used, rep_of = {}, {}, {}
    for name, h in handles.items():
        sync = SYNC if "lattice" in name or "dsim" in name else 1
        sync_used[name] = sync
        rep_of[name] = R
        out[name] = _rate(h, sweeps, sync)

    # the replica-parallel production path: one fused call drives R_BATCH
    # independent chains of the SAME instance (the paper's many-anneals-per-
    # machine operating point); the seed had neither fusion nor replicas
    if engine in (None, "lattice"):
        R_BATCH = max(R, 8)
        hb = make_engine("lattice", L=L, seed=0, impl="ref", fused=True,
                         replicas=R_BATCH)
        name = f"lattice_fused_R{R_BATCH}"
        sync_used[name] = SYNC
        rep_of[name] = R_BATCH
        out[name] = _rate(hb, sweeps, SYNC)

    flips = {k: v * n * rep_of[k] for k, v in out.items()}
    detail = {"L": L, "N": n, "replicas": rep_of, "sync_every": sync_used,
              "sweeps_per_s": out, "flips_per_s": flips}
    if "lattice_kernel" in flips and "lattice_per_phase" in flips:
        detail["fused_speedup_vs_per_phase"] = (
            flips["lattice_kernel"] / flips["lattice_per_phase"])
    save_detail("flip_rate", detail)

    # the seed-comparison record is only meaningful for the canonical R=1
    # run (its baseline key is the seed's single-chain dispatch)
    if R == 1 and "lattice_kernel" in flips and "lattice_per_phase" in flips:
        batch_keys = [k for k in flips if k.startswith("lattice_fused_R")]
        best_batch = max((flips[k] for k in batch_keys),
                         default=flips["lattice_kernel"])
        bench = {
            "mode": "quick" if quick else "full",
            "problem": {"L": L, "N": n, "sync_every": SYNC},
            "seed_lattice_flips_per_s": None,
            "seed_note": ("the seed's lattice flip-rate path cannot run on "
                          "this jax install (jax.shard_map / "
                          "jax.make_mesh(axis_types=...) unsupported — the "
                          "benchmark and engine both crash); "
                          "'lattice_per_phase_R1' below runs the seed's "
                          "exact per-phase single-chain dispatch through "
                          "the restored engine and stands in as the seed "
                          "baseline at equal problem size"),
            "lattice_per_phase_R1_flips_per_s": flips["lattice_per_phase"],
            "lattice_fused_R1_flips_per_s": flips["lattice_kernel"],
            "lattice_path_flips_per_s": {k: flips[k] for k in flips
                                         if k.startswith("lattice")},
            # two separately-labeled speedups: kernel fusion alone at equal
            # R=1, and the full new operating point (fusion + replica
            # batch); the latter is aggregate chain-flips, not a per-chain
            # kernel speedup
            "speedup_fused_R1_vs_seed_dispatch":
                flips["lattice_kernel"] / flips["lattice_per_phase"],
            "speedup_fused_replica_batch_vs_seed_dispatch":
                best_batch / flips["lattice_per_phase"],
            "all_paths_flips_per_s": flips,
        }
        with open(ROOT_BENCH, "w") as f:
            json.dump(bench, f, indent=1, default=float)

    return [row("flip_rate", 1e6 / max(out.get("monolithic",
                                               next(iter(out.values()))),
                                       1e-9),
                " ".join(f"{k}={v:.3e}f/s" for k, v in flips.items()))]
