"""Methods — flip-rate measurement.

The paper's flip rate = N p-bits updated per local clock (all N flip
attempts per sweep), measured with on-chip counters.  Here: measured
sweeps/s x N x R for every registry engine at equal problem size, with the
lattice path measured through the fused multi-phase kernel (f32 and the
fixed-point int8 pipeline) and through the seed's per-phase reference
dispatch (one launch per color phase).

Every timing is reported as best-of-N *plus* the per-run spread
(min/median/max AND the trimmed median over the reps) — this container's
scheduler swings ~2x run to run, so a bare best-of number is unreadable
without the spread — and the JSON carries a host fingerprint for cross-run
comparability.  Engine-level reps are INTERLEAVED across paths (rep i of
every path runs before rep i+1 of any), so host drift hits all paths
equally and path-vs-path ratios are apples to apples; the rep count is
recorded per path.

Writes the usual reports/bench/flip_rate.json detail plus BENCH_flip_rate.json
at the repo root recording the fused-vs-per-phase and int8-vs-f32 speedups
against the seed lattice path (schema checked in CI by
tools/check_bench_schema.py).
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.engines import make_engine
from repro.core.graph import ea3d
from repro.core.coloring import lattice3d_coloring
from repro.core.partition import slab_partition
from repro.core.annealing import constant_schedule

from .common import eta_probe, host_fingerprint, row, save_detail

ROOT_BENCH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_flip_rate.json")
SYNC = 8          # the seed benchmark's boundary-exchange period




def _rates_interleaved(handles: dict, sweeps: int, sync_of: dict,
                       reps: int = 9) -> dict:
    """Throughput of every path with spread, reps interleaved across paths.

    On a contended host every disturbance only slows a rep down, so the max
    over reps ("best") is the least-biased throughput estimate — but the
    min/median/max spread is what says whether a comparison is signal or
    scheduler noise, and interleaving (rep i of every path before rep i+1
    of any) is what makes the path-vs-path ratios robust to drift: a
    CPU-frequency or cgroup swing lands on all paths, not one.
    """
    sch = constant_schedule(3.0, 8 * sweeps)
    for name, h in handles.items():               # compile outside the reps
        st = h.init_state(seed=0)
        h.run_recorded(st, sch, [sweeps], sync_every=sync_of[name])
    vals = {name: [] for name in handles}
    for _ in range(reps):
        for name, h in handles.items():
            st = h.init_state(seed=0)
            t0 = time.perf_counter()
            h.run_recorded(st, sch, [sweeps], sync_every=sync_of[name])
            vals[name].append(sweeps / (time.perf_counter() - t0))
    return {name: _stats(v) for name, v in vals.items()}


def _stats(vals) -> dict:
    """best-of-N plus spread; ``trimmed_median`` drops the one fastest and
    one slowest rep before the median — a robust center the best-of number
    is read against (reps also recorded, per the schema)."""
    vals = sorted(float(v) for v in vals)
    trimmed = vals[1:-1] if len(vals) >= 3 else vals
    return {"best": float(np.max(vals)), "min": float(np.min(vals)),
            "median": float(np.median(vals)),
            "trimmed_median": float(np.median(trimmed)),
            "max": float(np.max(vals)), "reps": int(len(vals))}


def _kernel_head_to_head(L: int, reps: int = 15) -> dict:
    """Kernel-layer flips/s of the fused sweep op, f32 vs int8, at equal
    (L, R=1, sync_every=S halos held fixed).

    Reps interleave the two precisions so host drift hits both equally —
    the end-to-end engine numbers fold both pipelines into one fused
    XLA program whose shared traffic (neighbor concats, xorshift, masked
    writes) hides the update-rule cost; this is the measurement of the
    update rule itself.
    """
    import jax
    import jax.numpy as jnp
    from repro.core.lattice import build_ea3d_lattice
    from repro.core.pbit import quantize_couplings, field_bound, threshold_lut
    from repro.kernels.ref import pbit_brick_sweep_ref, pbit_brick_sweep_int_ref

    p = build_ea3d_lattice(L)
    rng = np.random.default_rng(0)
    m = jnp.asarray(rng.choice([-1, 1], size=p.dims).astype(np.int8))
    s = jnp.asarray(rng.integers(1, 2 ** 32, size=p.dims, dtype=np.uint32))
    halos = tuple(jnp.zeros((L, L), jnp.int8) for _ in range(6))
    betas = jnp.full((SYNC,), 3.0, jnp.float32)
    h_q, w6_q, scale = quantize_couplings(p.h, p.w6)
    lut = jnp.asarray(threshold_lut([3.0], scale, field_bound(h_q, w6_q)))
    rows = jnp.zeros((SYNC,), jnp.int32)
    fns = {
        "f32": jax.jit(lambda m, s: pbit_brick_sweep_ref(
            m, s, betas, p.masks, p.h, p.w6, halos, None)),
        "int8": jax.jit(lambda m, s: pbit_brick_sweep_int_ref(
            m, s, rows, p.masks, h_q, w6_q, halos, lut)),
    }
    calls = max(1, (1 << 21) // (L ** 3 * SYNC))   # ~2M flips per rep
    for fn in fns.values():
        jax.block_until_ready(fn(m, s))
    times = {k: [] for k in fns}
    for _ in range(reps):
        for k, fn in fns.items():                  # interleaved
            t0 = time.perf_counter()
            for _ in range(calls):
                o = fn(m, s)
            jax.block_until_ready(o[0])
            times[k].append(L ** 3 * SYNC * calls
                            / (time.perf_counter() - t0))
    out = {"L": L, "sweeps_per_call": SYNC, "calls_per_rep": calls,
           "f32_flips_per_s": _stats(times["f32"]),
           "int8_flips_per_s": _stats(times["int8"])}
    out["speedup_int8_vs_f32"] = (out["int8_flips_per_s"]["best"]
                                  / out["f32_flips_per_s"]["best"])
    return out


def _bitplane_word_scaling_bench(L: int, reps: int = 9) -> dict:
    """Per-lane cost of the word sweep across stacked word planes, at the
    kernel layer (W in {1, 2, 4} interleaved, halos fixed) — the gate that
    stacking planes does not tax the lanes.

    Kernel-layer like ``_kernel_head_to_head`` and for the same reason:
    the end-to-end engine numbers fold per-chunk dispatch and this host's
    scheduler swings into every path, drowning the W-scaling signal; the
    word loop itself is what the multi-word fabric adds, so it is what
    gets measured.  (The engine-level aggregates at R=32/64/128 ride the
    interleaved rep loop and land in ``all_paths_flips_per_s``.)
    """
    import jax
    import jax.numpy as jnp
    from repro.core.lattice import build_ea3d_lattice
    from repro.core.packing import pack_lanes
    from repro.core.pbit import (bitplane_planes, field_bound,
                                 quantize_couplings, threshold_lut)
    from repro.kernels.ref import pbit_bitplane_sweep_ref

    p = build_ea3d_lattice(L)
    rng = np.random.default_rng(0)
    h_q, w6_q, scale = quantize_couplings(p.h, p.w6)
    signs6, nz6, base, _ = bitplane_planes(h_q, w6_q)
    lut = jnp.asarray(threshold_lut([3.0], scale, field_bound(h_q, w6_q)))
    rows = jnp.zeros((SYNC,), jnp.int32)
    masks = np.asarray(p.masks)
    widths, fns, inputs = (1, 2, 4), {}, {}
    for W in widths:
        R = 32 * W
        mw = pack_lanes(jnp.asarray(
            rng.choice([-1, 1], size=(R,) + p.dims).astype(np.int8)))
        s = jnp.asarray(rng.integers(1, 2 ** 32, size=(R,) + p.dims,
                                     dtype=np.uint32))
        # every lane live (R is a word multiple): full masks on all planes
        masks_w = jnp.asarray(
            np.where(masks[:, None] != 0, np.uint32(0xFFFFFFFF),
                     np.uint32(0))[:, [0] * W])
        halos_w = tuple(jnp.zeros((W, L, L), jnp.uint32) for _ in range(6))
        fns[W] = jax.jit(lambda mw, s, mk=masks_w, hl=halos_w:
                         pbit_bitplane_sweep_ref(mw, s, rows, mk, signs6,
                                                 nz6, base, hl, lut))
        inputs[W] = (mw, s)
        jax.block_until_ready(fns[W](mw, s)[0])   # compile outside reps
    calls = max(1, (1 << 19) // (L ** 3 * SYNC))
    rates = {W: [] for W in widths}                # AGGREGATE lane-flips/s
    for _ in range(reps):
        for W in widths:                           # interleaved
            mw, s = inputs[W]
            t0 = time.perf_counter()
            for _ in range(calls):
                o = fns[W](mw, s)
            jax.block_until_ready(o[0])
            rates[W].append(L ** 3 * SYNC * 32 * W * calls
                            / (time.perf_counter() - t0))
    spread = {W: _stats(v) for W, v in rates.items()}
    agg = {f"W{W}_R{32 * W}": spread[W]["best"] for W in widths}
    return {
        "L": L, "sweeps_per_call": SYNC, "calls_per_rep": calls,
        "layer": "kernel (jitted word sweep, halos fixed, interleaved)",
        "note": ("lane_efficiency is PER-LANE COST: aggregate lane-flips/s "
                 "at W planes over aggregate at one plane (on this serial "
                 "host total throughput is the conserved quantity, so the "
                 "wall-clock rate of any single lane divides by W by "
                 "construction; ~1.0 means stacking planes taxes no lane)"),
        "aggregate_lane_flips_per_s": agg,
        "aggregate_lane_flips_per_s_spread":
            {f"W{W}_R{32 * W}": spread[W] for W in widths},
        "per_lane_flips_per_s":
            {f"W{W}_R{32 * W}": agg[f"W{W}_R{32 * W}"] / (32 * W)
             for W in widths},
        "lane_efficiency_vs_one_word": {
            f"W{W}_R{32 * W}": agg[f"W{W}_R{32 * W}"] / agg["W1_R32"]
            for W in widths if W > 1},
    }


def _dist_word_boundary_bench(L: int, sweeps: int, reps: int = 5) -> dict:
    """Mesh-engine word path: dsim_dist bitplane vs *unpacked* int8 at the
    same R=32 width on a one-device mesh (measures the engine path without
    a forced device count; the boundary payload accounting is exact and
    host-independent).  The bitplane all-gather ships native uint32 words —
    4 B per boundary site for all 32 chains, zero pack/unpack on the
    collective path — vs 32 B/site for unpacked int8 planes."""
    from repro.compat import make_mesh, auto_axes
    g = ea3d(L, seed=0)
    col = lattice3d_coloring(L)
    labels = np.zeros(g.n, np.int32)
    mesh = make_mesh((1,), ("data",), axis_types=auto_axes(1))
    mk = lambda prec, **kw: make_engine(
        "dsim_dist", g, coloring=col, K=1, labels=labels, mesh=mesh,
        rng="lfsr", precision=prec, replicas=32, **kw)
    handles = {"dsim_dist_int8_R32": mk("int8", bitpack=False),
               "dsim_dist_bitplane_R32": mk("bitplane")}
    sync_of = {k: SYNC for k in handles}
    spread = _rates_interleaved(handles, sweeps, sync_of, reps=reps)
    flips = {k: v["best"] * g.n * 32 for k, v in spread.items()}
    payloads = {k: h.eng.boundary_payload() for k, h in handles.items()}
    return {
        "L": L, "N": g.n, "replicas": 32, "sync_every": SYNC,
        "sweeps_per_s_spread": spread,
        "lane_flips_per_s": flips,
        "speedup_bitplane_vs_int8_unpacked":
            flips["dsim_dist_bitplane_R32"] / flips["dsim_dist_int8_R32"],
        # the wire format the tentpole gates: bytes one device publishes
        # per boundary site covering ALL 32 chains
        "boundary_bytes_per_site_bitplane_R32":
            payloads["dsim_dist_bitplane_R32"]["bytes_per_site_all_chains"],
        "boundary_bytes_per_site_int8_unpacked_R32":
            payloads["dsim_dist_int8_R32"]["bytes_per_site_all_chains"],
        "boundary_shrink":
            payloads["dsim_dist_int8_R32"]["bytes_per_site_all_chains"]
            / payloads["dsim_dist_bitplane_R32"]["bytes_per_site_all_chains"],
        "payload_dtype": payloads["dsim_dist_bitplane_R32"]["dtype"],
        "pack_compute_bitplane":
            payloads["dsim_dist_bitplane_R32"]["pack_compute"],
    }


def _apt_packed_bench(reps: int = 5, sweeps: int = 24) -> dict:
    """Lane-packed APT+ICM vs the unpacked fixed-point ladder it is
    bit-identical to: a (chains=4) x (temperatures=8) grid = all 32 word
    lanes.  Also times the replica-exchange swap move in isolation — the
    packed move is one lane permutation (bit gather/scatter) per offset
    pass applied to every word, vs the unpacked (P, T, N) where-chain."""
    import jax
    from repro.core.apt_icm import APTICM

    g = ea3d(4, seed=0)
    col = lattice3d_coloring(4)
    betas = np.linspace(0.5, 3.0, 8)
    un = APTICM(g, col, betas, chains=4, rng="lfsr")
    pk = APTICM(g, col, betas, chains=4, rng="lfsr", packed=True)
    engines = {"apt_icm_unpacked": un, "apt_icm_packed": pk}
    for eng in engines.values():                  # compile outside the reps
        eng.run(eng.init_state(seed=0), 2, icm_every=2, record_every=2)
    vals = {k: [] for k in engines}
    for _ in range(reps):
        for k, eng in engines.items():
            st = eng.init_state(seed=0)
            t0 = time.perf_counter()
            eng.run(st, sweeps, icm_every=8, record_every=sweeps)
            vals[k].append(sweeps / (time.perf_counter() - t0))
    # the swap move alone, jitted, per call (best over reps)
    su, sp = un.init_state(seed=0), pk.init_state(seed=0)
    f_un = jax.jit(lambda m, E, k, s: un._exchange(m, E, k, s))
    f_pk = jax.jit(lambda w, E, k, s: pk._exchange_packed(w, E, k, s))
    jax.block_until_ready(f_un(su.m, su.E, su.key, su.swaps))
    jax.block_until_ready(f_pk(sp.m, sp.E, sp.key, sp.swaps))
    swap = {}
    for name, fn, st in (("unpacked_s", f_un, su), ("packed_s", f_pk, sp)):
        ts = []
        for _ in range(max(reps, 3)):
            t0 = time.perf_counter()
            for _ in range(16):
                o = fn(st.m, st.E, st.key, st.swaps)
            jax.block_until_ready(o[0])
            ts.append((time.perf_counter() - t0) / 16)
        swap[name] = float(np.min(ts))
    return {
        "N": g.n, "chains": 4, "temperatures": 8, "lanes": 32,
        "sweeps": sweeps,
        "packed_sweeps_per_s": _stats(vals["apt_icm_packed"]),
        "unpacked_sweeps_per_s": _stats(vals["apt_icm_unpacked"]),
        "speedup_packed_vs_unpacked":
            max(vals["apt_icm_packed"]) / max(vals["apt_icm_unpacked"]),
        "swap_move_cost": swap,
    }


_DEGRADED_SCRIPT = r"""
import json, sys
import numpy as np
from repro.compat import make_mesh, auto_axes
from repro.core import commcost
from repro.core.annealing import ea_schedule
from repro.core.coloring import lattice3d_coloring
from repro.core.graph import ea3d
from repro.core.partition import slab_partition
from repro.engines import make_engine
from repro.obs import EtaMeter
from repro.serve.faults import FaultPlan, FaultRule

L, SYNC, SWEEPS = %(L)d, %(SYNC)d, %(SWEEPS)d
g = ea3d(L, seed=0)
col = lattice3d_coloring(L)
labels = slab_partition(L, 2)
mesh = make_mesh((2,), ("data",), axis_types=auto_axes(2))
h = make_engine("dsim_dist", g, coloring=col, K=2, labels=labels,
                mesh=mesh, rng="lfsr", precision="int8", replicas=1,
                degrade="stale_hold:%(SWEEPS)d")
sch = ea_schedule(SWEEPS)
total = int(sch.total_sweeps)
n_ex = max(total // SYNC, 1)
pts = sorted(set(range(SYNC, total + 1, SYNC)))
b = commcost.boundary_matrix(np.asarray(g.idx), np.asarray(g.w), labels, 2)
cc = commcost.comm_cost(b, commcost.RingTopology(k=2, pins_per_link=1))
meter = EtaMeter(n_color=len(h.eng.p.color_slots), c_max=cc.c_max,
                 sync_every=SYNC)
h.eng.set_exchange_faults(np.zeros(n_ex, np.int32))
h.run_recorded(h.init_state(seed=0), sch, pts,
               sync_every=SYNC)                 # compile outside timing
meter.measure_exchange(
    lambda st=h.init_state(seed=0): h.eng.boundary_exchange_fn()(st))
arms = {}
eta_clean = None
for frac in (0.0, 0.1, 0.3):
    if frac > 0:
        plan = FaultPlan([FaultRule(site="exchange_drop", rate=frac)],
                         seed=12345)
        codes = plan.exchange_codes(n_ex)
    else:
        codes = np.zeros(n_ex, np.int32)   # same traced shape: one trace
    h.eng.set_exchange_faults(codes)
    cur = h.start_recorded(h.init_state(seed=0), sch, pts, sync_every=SYNC)
    if frac == 0.0:
        meter.attach(cur)
    while not cur.done:
        cur.advance(1)
    rec = cur.record()
    rep = h.eng.health.report()
    if frac == 0.0:
        eta_clean = float(meter.eta)
    E = np.asarray(rec.energies)[:, 0]
    arms["%%.1f" %% frac] = {
        "drop_fraction": frac,
        "completed": bool(cur.done),
        "detections": int(rep["detections"]),
        "stale_exchanges": int(rep["stale_exchanges"]),
        "exchanges_total": int(rep["exchanges_total"]),
        "max_staleness_seen": int(rep["max_staleness_seen"]),
        "delivered_fraction": float(rep["delivered_fraction"]),
        # effective eta uses the ONE clean measured eta so the arm-vs-arm
        # comparison isolates the fault process from host timing noise
        "effective_eta": eta_clean * float(rep["delivered_fraction"]),
        "energy_first": float(E[0]),
        "energy_final": float(E[-1]),
        "residual_energy_drop": float(E[0] - E[-1]),
    }
out = {
    "engine": "dsim_dist", "K": 2, "L": L, "N": int(g.n),
    "precision": "int8", "policy": "stale_hold:%(SWEEPS)d",
    "sync_every": SYNC, "exchanges_per_run": n_ex,
    "measured_eta_clean": eta_clean,
    "eta_threshold": float(meter.eta_threshold),
    "arms": arms,
}
print("DEGJSON" + json.dumps(out, default=float))
"""


def _degraded_mesh_bench(sweeps: int) -> dict:
    """Degraded arm of the flip-rate record: a REAL 2-device dsim_dist
    mesh (forced host platform device count, hence the subprocess) under
    ``stale_hold`` with 0/10/30% of boundary exchanges dropped at the
    engine fault site — residual-energy decay per arm plus the
    staleness-vs-eta accounting (effective_eta = clean measured eta x
    delivered fraction).  Gated by tools/check_bench_schema.py: all arms
    complete, effective_eta finite and monotone non-increasing in the
    drop fraction, detections > 0 whenever exchanges were dropped."""
    import subprocess
    import sys

    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=2").strip()
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    script = _DEGRADED_SCRIPT % {
        "L": 6, "SYNC": SYNC, "SWEEPS": max(min(sweeps // 4, 256), 64)}
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=900)
    if proc.returncode != 0:
        raise RuntimeError(
            f"degraded-mesh bench subprocess failed:\n{proc.stderr[-4000:]}")
    for line in proc.stdout.splitlines():
        if line.startswith("DEGJSON"):
            return json.loads(line[len("DEGJSON"):])
    raise RuntimeError("degraded-mesh bench subprocess printed no record")


def _telemetry_bench(L: int, sweeps: int, flips: dict,
                     reps: int = 9) -> dict:
    """The benchmark's own observability record: the measured-η probe, a
    per-chunk latency histogram from the cursor ``chunk_timer`` hook, and
    the cost of that hook itself — the SAME fused lattice path annealed
    with the timer attached vs detached, reps interleaved so host drift
    hits both arms equally.  The timer brackets every chunk with a
    ``block_until_ready`` pair, so this is the full price of enabling
    chunk telemetry; the gate is < 2% on the trimmed medians."""
    from repro.obs import MetricsRegistry

    eta = eta_probe(L=min(L, 5), sweeps=max(sweeps // 8, 64),
                    sync_every=SYNC)

    reg = MetricsRegistry()
    hist = reg.histogram("bench_chunk_seconds",
                         "recorded-chunk wall time, fused lattice path")
    g_rate = reg.gauge("bench_flips_per_s",
                       "best-of-reps flips/s per engine path")
    for path, v in flips.items():
        g_rate.labels(path=path).set(v)

    h = make_engine("lattice", L=L, seed=0, impl="ref", fused=True,
                    replicas=1)
    # sparse record points (2 per run) over a long anneal: the timer
    # serializes host dispatch against device work at every chunk
    # boundary, so its cost is a fixed ~0.1 ms per chunk — measured at
    # recorded-run granularity (tens of ms per chunk) it amortizes below
    # the 2% gate, while dense record points would charge the pipeline
    # stall to the hook; the long runs also lift each rep well above the
    # host's per-call timing jitter
    total = 8 * sweeps
    sch = constant_schedule(3.0, total)
    step = max(total // 2, 1)
    pts = list(range(step, total + 1, step))

    def _run(timed: bool) -> float:
        cur = h.start_recorded(h.init_state(seed=0), sch, pts,
                               sync_every=SYNC)
        if timed:
            cur.chunk_timer = lambda sw, s: hist.observe(s)
        t0 = time.perf_counter()
        while not cur.done:
            cur.advance(1)
        cur.record()                      # settle device work
        return time.perf_counter() - t0

    _run(True), _run(False)               # compile/warm both arms
    on, off = [], []
    for _ in range(reps):                 # interleaved
        on.append(total / _run(True))
        off.append(total / _run(False))
    s_on, s_off = _stats(on), _stats(off)
    overhead = s_off["trimmed_median"] / s_on["trimmed_median"] - 1.0
    return {
        "eta": eta,
        "overhead": {
            "path": "lattice_kernel (fused, R=1, chunked cursor)",
            "chunks_per_run": len(pts), "sweeps_per_run": total,
            "sweeps_per_s_timer_on": s_on,
            "sweeps_per_s_timer_off": s_off,
            "overhead_fraction": overhead,
            "note": ("trimmed-median slowdown of the chunk_timer hook "
                     "(block_until_ready pair + histogram observe per "
                     "chunk) over the untimed cursor; interleaved reps. "
                     "The bracket serializes host dispatch against "
                     "device work once per chunk (~0.1 ms), amortized "
                     "over recorded-run-sized chunks; values within "
                     "this host's noise band (|x| of a few %) mean "
                     "'below measurement noise', and a negative sign "
                     "is scheduler drift, not a speedup"),
        },
        "metrics": reg.snapshot(),
    }


def run(quick: bool = True, engine: str = None, replicas: int = 1):
    L = 8 if quick else 16
    sweeps = 1024 if quick else 8192
    R = max(int(replicas), 1)
    g = ea3d(L, seed=0)
    col = lattice3d_coloring(L)

    # lazy handle thunks: only the paths that survive the --engine filter
    # are ever constructed (lattice builds are seconds at --full size)
    thunks = {
        "monolithic": lambda: make_engine("gibbs", g, coloring=col,
                                          rng="lfsr", replicas=R),
        "dsim_stacked": lambda: make_engine("dsim", g, coloring=col,
                                            rng="lfsr", K=4,
                                            labels=slab_partition(L, 4),
                                            replicas=R),
        "lattice_kernel": lambda: make_engine("lattice", L=L, seed=0,
                                              impl="ref", fused=True,
                                              replicas=R),
        "lattice_per_phase": lambda: make_engine("lattice", L=L, seed=0,
                                                 impl="ref", fused=False,
                                                 replicas=R),
        # the tentpole path: fixed-point pipeline through the fused kernel
        "lattice_fused_int8": lambda: make_engine("lattice", L=L, seed=0,
                                                  impl="ref", fused=True,
                                                  precision="int8",
                                                  replicas=R),
    }
    if engine == "dsim_dist":
        # single-device shard_map path (K=1): measures the distributed
        # backend's per-chunk overhead without needing a forced device count
        thunks = {"dsim_dist_k1": lambda: make_engine(
            "dsim_dist", g, coloring=col, K=1,
            labels=np.zeros(g.n, np.int32), rng="lfsr", replicas=R)}
    elif engine is not None:
        keep = {"gibbs": ["monolithic"], "dsim": ["dsim_stacked"],
                "lattice": ["lattice_kernel", "lattice_per_phase",
                            "lattice_fused_int8"]}
        names = keep.get(engine, [engine])
        thunks = {k: v for k, v in thunks.items() if k in names}
        if not thunks:
            raise ValueError(f"no flip-rate path for engine {engine!r}")
    handles = {k: mk() for k, mk in thunks.items()}

    n = g.n
    sync_used, rep_of = {}, {}
    for name in handles:
        sync_used[name] = SYNC if "lattice" in name or "dsim" in name else 1
        rep_of[name] = R

    # the replica-parallel production paths: one fused call drives R_BATCH
    # independent chains of the SAME instance (the paper's many-anneals-
    # per-machine operating point; the seed had neither fusion nor
    # replicas), and the bit-plane paths pack 32 lanes into every uint32
    # word — one, two, and four stacked word planes (the multi-word fabric
    # this benchmark gates: per-lane rate must hold as W grows)
    R_BATCH = max(R, 8)
    R_LANES = 32
    if engine in (None, "lattice"):
        for name, prec, rr in [
                (f"lattice_fused_R{R_BATCH}", "f32", R_BATCH),
                (f"lattice_fused_int8_R{R_BATCH}", "int8", R_BATCH),
                (f"lattice_fused_int8_R{R_LANES}", "int8", R_LANES),
                (f"lattice_bitplane_R{R_LANES}", "bitplane", R_LANES),
                (f"lattice_bitplane_R{2 * R_LANES}", "bitplane",
                 2 * R_LANES),
                (f"lattice_bitplane_R{4 * R_LANES}", "bitplane",
                 4 * R_LANES)]:
            handles[name] = make_engine("lattice", L=L, seed=0, impl="ref",
                                        precision=prec, replicas=rr)
            sync_used[name] = SYNC
            rep_of[name] = rr

    # ALL engine-level paths timed in one interleaved rep loop
    spread = _rates_interleaved(handles, sweeps, sync_used)
    out = {k: v["best"] for k, v in spread.items()}

    # kernel-layer head-to-head of the update rule (interleaved reps)
    k2k = None
    if engine in (None, "lattice"):
        k2k = _kernel_head_to_head(16 if quick else 32)

    # the word-lane mesh-engine path and the lane-packed tempering ladder
    # (cheap at quick size; part of the gated record, so they run whenever
    # the record below will be written)
    dist_word = apt_packed = word_scaling = degraded = None
    if R == 1 and engine in (None, "lattice"):
        dist_word = _dist_word_boundary_bench(L, max(sweeps // 4, 256))
        apt_packed = _apt_packed_bench()
        word_scaling = _bitplane_word_scaling_bench(L)
        degraded = _degraded_mesh_bench(sweeps)

    flips = {k: v * n * rep_of[k] for k, v in out.items()}

    # the telemetry record (measured η + chunk-latency histogram + the
    # <2% chunk-timer overhead gate) rides with the gated BENCH record
    telemetry = None
    if R == 1 and engine in (None, "lattice"):
        telemetry = _telemetry_bench(L, sweeps, flips)

    detail = {"L": L, "N": n, "replicas": rep_of, "sync_every": sync_used,
              "host": host_fingerprint(),
              "sweeps_per_s": out, "sweeps_per_s_spread": spread,
              "flips_per_s": flips}
    if "lattice_kernel" in flips and "lattice_per_phase" in flips:
        detail["fused_speedup_vs_per_phase"] = (
            flips["lattice_kernel"] / flips["lattice_per_phase"])
    if k2k is not None:
        detail["kernel_int8_vs_f32"] = k2k
    if dist_word is not None:
        detail["dsim_dist_bitplane"] = dist_word
    if apt_packed is not None:
        detail["apt_icm_packed"] = apt_packed
    if word_scaling is not None:
        detail["bitplane_word_scaling"] = word_scaling
    if degraded is not None:
        detail["degraded_mesh"] = degraded
    if telemetry is not None:
        detail["telemetry"] = telemetry
    save_detail("flip_rate", detail)

    # the seed-comparison record is only meaningful for the canonical R=1
    # run (its baseline key is the seed's single-chain dispatch)
    if R == 1 and "lattice_kernel" in flips and "lattice_per_phase" in flips:
        batch_keys = [k for k in flips if k.startswith("lattice_fused_R")]
        best_batch = max((flips[k] for k in batch_keys),
                         default=flips["lattice_kernel"])
        bp_key = f"lattice_bitplane_R{R_LANES}"
        bp64_key = f"lattice_bitplane_R{2 * R_LANES}"
        bp128_key = f"lattice_bitplane_R{4 * R_LANES}"
        i8_key = f"lattice_fused_int8_R{R_BATCH}"
        bench = {
            "mode": "quick" if quick else "full",
            "problem": {"L": L, "N": n, "sync_every": SYNC},
            "host": host_fingerprint(),
            "seed_lattice_flips_per_s": None,
            "seed_note": ("the seed's lattice flip-rate path cannot run on "
                          "this jax install (jax.shard_map / "
                          "jax.make_mesh(axis_types=...) unsupported — the "
                          "benchmark and engine both crash); "
                          "'lattice_per_phase_R1' below runs the seed's "
                          "exact per-phase single-chain dispatch through "
                          "the restored engine and stands in as the seed "
                          "baseline at equal problem size"),
            "lattice_per_phase_R1_flips_per_s": flips["lattice_per_phase"],
            "lattice_fused_R1_flips_per_s": flips["lattice_kernel"],
            "lattice_fused_int8_R1_flips_per_s": flips["lattice_fused_int8"],
            "lattice_path_flips_per_s": {k: flips[k] for k in flips
                                         if k.startswith("lattice")},
            # separately-labeled speedups: kernel fusion alone at equal
            # R=1, the fixed-point update rule over the f32 rule inside the
            # fused kernel at equal (L, R, sync_every) — measured at the
            # kernel layer with interleaved reps, because end-to-end both
            # pipelines compile into one fused XLA program whose shared
            # traffic masks the update rule on this host (the engine-level
            # ratio is recorded alongside) — and the full new operating
            # point (fusion + replica batch — aggregate chain-flips, not a
            # per-chain kernel speedup)
            "speedup_fused_R1_vs_seed_dispatch":
                flips["lattice_kernel"] / flips["lattice_per_phase"],
            "speedup_int8_vs_f32_fused_R1": k2k["speedup_int8_vs_f32"],
            "speedup_int8_vs_f32_fused_R1_note": (
                "kernel-layer measurement (fused sweep op, halos fixed, "
                "interleaved reps; see kernel_int8_vs_f32); "
                "engine_speedup_int8_vs_f32_R1 is the end-to-end ratio, "
                "fusion- and noise-dominated on this host"),
            "engine_speedup_int8_vs_f32_R1":
                flips["lattice_fused_int8"] / flips["lattice_kernel"],
            "kernel_int8_vs_f32": k2k,
            "speedup_fused_replica_batch_vs_seed_dispatch":
                best_batch / flips["lattice_per_phase"],
            # the multi-spin-coded operating point: 32 replica lanes per
            # uint32 word, one word sweep per call.  Aggregate lane-flips
            # vs the int8 R=8 replica batch (both interleaved in the same
            # rep loop on this host), plus the per-lane rates — a packed
            # lane must cost no more than an unpacked int8 replica at the
            # SAME batch width (R=32), which is the apples-to-apples lane
            # comparison; the R=8 batch is int8's small-batch sweet spot
            # on this 2-core container (per-replica rate FALLS with R for
            # the unpacked paths, while the word path holds at 32)
            f"{bp_key}_flips_per_s": flips[bp_key],
            f"{bp64_key}_flips_per_s": flips[bp64_key],
            f"{bp128_key}_flips_per_s": flips[bp128_key],
            # the multi-word fabric: stacking word planes multiplies the
            # lane count (W=2 -> 64 lanes, W=4 -> 128) with one word loop
            # around the same one-word kernel; lane_efficiency is the
            # per-lane rate at W words over the per-lane rate at one word
            # (the gate: stacking planes must not tax the lanes), measured
            # at the kernel layer with interleaved reps
            "bitplane_word_scaling": word_scaling,
            "speedup_bitplane_vs_int8_R8": flips[bp_key] / flips[i8_key],
            "speedup_bitplane_vs_int8_R8_note": (
                "AGGREGATE lane-flips ratio of one 32-lane word call vs "
                "the R=8 int8 batch (4x the chains per call) — NOT a "
                "per-lane ratio; per_lane_flips_per_s records the "
                "per-chain rates, where int8's small R=8 batch is its "
                "per-replica sweet spot on this host and the matched-"
                "width lane-cost gate is "
                "speedup_bitplane_vs_int8_R32_per_lane"),
            "speedup_bitplane_vs_int8_R32_per_lane":
                flips[bp_key] / flips[f"lattice_fused_int8_R{R_LANES}"],
            "per_lane_flips_per_s": {
                bp_key: flips[bp_key] / R_LANES,
                i8_key: flips[i8_key] / R_BATCH,
                f"lattice_fused_int8_R{R_LANES}":
                    flips[f"lattice_fused_int8_R{R_LANES}"] / R_LANES,
            },
            # the wire format: a face plane ships 4 B/site for ALL 32
            # lanes (uint32 words, the paper's 1 bit per boundary p-bit)
            # vs 1 B/site/replica unpacked int8 planes — 8x smaller at
            # R=32, with zero pack/unpack compute
            "bitplane_halo_payload": {
                "bytes_per_face_site_int8_R32": 32,
                "bytes_per_face_site_bitplane_R32": 4,
                "shrink": 8.0,
            },
            # the same word wire format on the mesh engine: the boundary
            # all-gather ships native uint32 words (4 B/site for all 32
            # chains, zero pack/unpack in the collective chunk) — plus the
            # lane-packed APT+ICM ladder, whose swap moves are lane
            # permutations (cost recorded per move)
            "dsim_dist_bitplane": dist_word,
            "apt_icm_packed": apt_packed,
            # degraded-mode arm: the 2-device mesh under stale_hold with
            # 0/10/30% dropped exchanges — every arm must complete, with
            # effective_eta monotone non-increasing in the drop fraction
            "degraded_mesh": degraded,
            # measured η / f_comm / f_pbit from the EtaMeter probe, the
            # chunk-latency histogram, and the chunk-timer overhead gate
            "telemetry": telemetry,
            "all_paths_flips_per_s": flips,
            # min/median/max + trimmed median sweeps/s over the interleaved
            # reps of each path: a speedup whose intervals overlap is
            # scheduler noise, not signal
            "sweeps_per_s_spread": spread,
        }
        with open(ROOT_BENCH, "w") as f:
            json.dump(bench, f, indent=1, default=float)

    return [row("flip_rate", 1e6 / max(out.get("monolithic",
                                               next(iter(out.values()))),
                                       1e-9),
                " ".join(f"{k}={v:.3e}f/s" for k, v in flips.items()))]
