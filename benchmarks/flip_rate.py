"""Methods — flip-rate measurement.

The paper's flip rate = N p-bits updated per local clock (all N flip
attempts per sweep), measured with on-chip counters.  Here: measured
sweeps/s x N for the monolithic engine, the partitioned engine, and the
structured-lattice engine with the Pallas-oracle kernel."""

from __future__ import annotations

import time

import numpy as np
import jax

from repro.core.graph import ea3d
from repro.core.coloring import lattice3d_coloring
from repro.core.partition import slab_partition
from repro.core.gibbs import GibbsEngine
from repro.core.dsim import build_partitioned, DSIMEngine
from repro.core.lattice import build_ea3d_lattice
from repro.core.lattice_dsim import LatticeDSIM
from repro.core.annealing import constant_schedule

from .common import save_detail, row


def _rate(run_fn, sweeps):
    run_fn(max(sweeps // 8, 1))          # compile + warm
    t0 = time.perf_counter()
    run_fn(sweeps)
    return sweeps / (time.perf_counter() - t0)


def run(quick: bool = True):
    L = 8 if quick else 16
    sweeps = 2048 if quick else 8192
    g = ea3d(L, seed=0)
    col = lattice3d_coloring(L)
    sch = constant_schedule(3.0, 8 * sweeps)
    out = {}

    eng = GibbsEngine(g, col, rng="lfsr")

    def run_mono(n):
        st = eng.init_state(seed=0)
        eng.run_recorded(st, sch, [n])
    out["monolithic"] = _rate(run_mono, sweeps)

    prob = build_partitioned(g, col, slab_partition(L, 4), 4)
    deng = DSIMEngine(prob, rng="lfsr")

    def run_dsim(n):
        st = deng.init_state(seed=0)
        deng.run_recorded(st, sch, [n], sync_every=8)
    out["dsim_stacked"] = _rate(run_dsim, sweeps)

    lat = build_ea3d_lattice(L, seed=0)
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    leng = LatticeDSIM(lat, mesh, dim_axes=("data", None, None), impl="ref")

    def run_lat(n):
        st = leng.init_state(seed=0)
        leng.run_recorded(st, sch, [n], sync_every=8)
    out["lattice_kernel"] = _rate(run_lat, sweeps)

    n = g.n
    detail = {"L": L, "N": n, "sweeps_per_s": out,
              "flips_per_s": {k: v * n for k, v in out.items()}}
    save_detail("flip_rate", detail)
    return [row("flip_rate", 1e6 / max(out["monolithic"], 1e-9),
                " ".join(f"{k}={v * n:.3e}f/s" for k, v in out.items()))]
