"""Shared benchmark scaffolding.

Every benchmark module exposes ``run(quick=True) -> list[dict]`` with rows
{"name", "us_per_call", "derived"} (plus free-form detail), and writes its
detail JSON under reports/bench/.  ``benchmarks.run`` prints the paper-table
CSV.  Scale note: CPU container => reduced lattice sizes and sweep budgets;
the *claims* (collapse, exponents, tradeoffs) are what is reproduced.
"""

from __future__ import annotations

import json
import os
import platform
import time

import numpy as np

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "reports", "bench")


def host_fingerprint() -> dict:
    """Host identity stamped into BENCH_* records so cross-run comparisons
    are grounded (this container's scheduler swings ~2x run to run)."""
    import jax
    return {
        "platform": platform.platform(),
        "machine": platform.machine(),
        "python": platform.python_version(),
        "jax": jax.__version__,
        "jax_backend": jax.default_backend(),
        "cpu_count": os.cpu_count(),
    }

# reduced-scale experiment defaults (quick mode)
QUICK = dict(L=8, K=4, budget=2048, instances=3, runs=3, seed0=50)
FULL = dict(L=12, K=6, budget=20000, instances=5, runs=5, seed0=50)


def save_detail(name: str, payload: dict):
    os.makedirs(REPORT_DIR, exist_ok=True)
    with open(os.path.join(REPORT_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1, default=float)


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6


def row(name: str, us: float, derived: str) -> dict:
    return {"name": name, "us_per_call": us, "derived": derived}
