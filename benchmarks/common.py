"""Shared benchmark scaffolding.

Every benchmark module exposes ``run(quick=True) -> list[dict]`` with rows
{"name", "us_per_call", "derived"} (plus free-form detail), and writes its
detail JSON under reports/bench/.  ``benchmarks.run`` prints the paper-table
CSV.  Scale note: CPU container => reduced lattice sizes and sweep budgets;
the *claims* (collapse, exponents, tradeoffs) are what is reproduced.
"""

from __future__ import annotations

import json
import os
import platform
import time

import numpy as np

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "reports", "bench")


def host_fingerprint() -> dict:
    """Host identity stamped into BENCH_* records so cross-run comparisons
    are grounded (this container's scheduler swings ~2x run to run)."""
    import jax
    return {
        "platform": platform.platform(),
        "machine": platform.machine(),
        "python": platform.python_version(),
        "jax": jax.__version__,
        "jax_backend": jax.default_backend(),
        "cpu_count": os.cpu_count(),
    }

# reduced-scale experiment defaults (quick mode)
QUICK = dict(L=8, K=4, budget=2048, instances=3, runs=3, seed0=50)
FULL = dict(L=12, K=6, budget=20000, instances=5, runs=5, seed0=50)


def save_detail(name: str, payload: dict):
    os.makedirs(REPORT_DIR, exist_ok=True)
    with open(os.path.join(REPORT_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1, default=float)


def eta_probe(L: int = 5, sweeps: int = 64, sync_every: int = 8,
              replicas: int = 32, precision: str = "bitplane") -> dict:
    """Measured-η telemetry block shared by the BENCH writers.

    Runs a one-device ``dsim_dist`` engine with an :class:`repro.obs.
    EtaMeter` attached to the recorded cursor (per-chunk wall time) and
    to the engine's ``boundary_exchange_fn`` (exchange-only time), so the
    BENCH record carries *measured* η = f_comm/f_pbit, f_comm, and
    f_pbit.  The K=1 probe has no cut of its own (c_max would be 0 and
    the margin undefined), so the threshold is taken from the reference
    2-way slab cut of the same graph on a unit-pin ring — the margin the
    2-way-partitioned machine would have at the measured rates.
    """
    import numpy as np

    from repro.compat import auto_axes, make_mesh
    from repro.core import commcost
    from repro.core.annealing import constant_schedule
    from repro.core.coloring import lattice3d_coloring
    from repro.core.graph import ea3d
    from repro.core.partition import slab_partition
    from repro.engines import make_engine
    from repro.obs import EtaMeter

    g = ea3d(L, seed=0)
    col = lattice3d_coloring(L)
    mesh = make_mesh((1,), ("data",), axis_types=auto_axes(1))
    h = make_engine("dsim_dist", g, coloring=col, K=1,
                    labels=np.zeros(g.n, np.int32), mesh=mesh, rng="lfsr",
                    precision=precision, replicas=replicas)
    labels2 = slab_partition(L, 2)
    b = commcost.boundary_matrix(np.asarray(g.idx), np.asarray(g.w),
                                 labels2, 2)
    cc = commcost.comm_cost(b, commcost.RingTopology(k=2, pins_per_link=1))
    meter = EtaMeter(n_color=len(h.eng.p.color_slots), c_max=cc.c_max,
                     sync_every=sync_every)
    sch = constant_schedule(3.0, 8 * sweeps)
    pts = [sweeps // 2, sweeps]
    h.run_recorded(h.init_state(seed=0), sch, pts,
                   sync_every=sync_every)        # compile outside timing
    meter.measure_exchange(
        lambda st=h.init_state(seed=0): h.eng.boundary_exchange_fn()(st))
    cur = h.start_recorded(h.init_state(seed=0), sch, pts,
                           sync_every=sync_every)
    meter.attach(cur)
    while not cur.done:
        cur.advance(1)
    rep = meter.report()
    rep["probe"] = {"engine": "dsim_dist", "K": 1, "L": L, "N": g.n,
                    "precision": precision, "replicas": replicas,
                    "threshold_partition": "reference 2-way slab cut, "
                                           "unit-pin ring (K=1 probe has "
                                           "no cut of its own)",
                    "c_max_ref": float(cc.c_max)}
    return rep


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6


def row(name: str, us: float, derived: str) -> dict:
    return {"name": name, "us_per_call": us, "derived": derived}
