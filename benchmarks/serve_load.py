"""Serving-layer load benchmark: throughput and latency vs offered load.

A load generator drives :class:`repro.serve.SampleServer` (background
serving thread) with a mixed workload — two EA problems x two engines,
every job R=2 replicas — at several offered arrival rates, and measures
per-job completion latency (submit -> terminal, i.e. queueing included)
and aggregate throughput.  Each rate runs twice on identically warmed
pools: **packed** (replica-packing scheduler on) vs **baseline**
(pack=False — one job per engine call through the same machinery), which
isolates exactly what coalescing compatible requests onto the replica
axis buys.

Writes reports/bench/serve_load.json plus BENCH_serve_load.json at the
repo root (schema-gated in CI by tools/check_bench_schema.py): per-load
p50/p95/p99 latency, jobs/s, exact flips, engine calls vs jobs submitted
(engine_calls < jobs is the packing evidence), and the packed-vs-baseline
throughput ratio.

A **fault wave** then measures serving under injected failure: a seeded
:class:`FaultPlan` fails each chunk with probability 0 / 5 / 20%
(transient), checkpointing every sweeps/8, and the wave records goodput
(DONE jobs per second), p99 completion latency over the jobs that
finished, retry/bisect counts, and recovered-vs-restarted sweep totals —
the cost of chaos with recovery on, not just the happy path.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core.coloring import lattice3d_coloring
from repro.core.graph import ea3d
from repro.obs import Tracer
from repro.serve import FaultPlan, FaultRule, SampleServer

from .common import eta_probe, host_fingerprint, row, save_detail

ROOT_BENCH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_serve_load.json")

FAULT_RATES = (0.0, 0.05, 0.20)


def _make_server(pack: bool, max_r: int, sweeps: int,
                 **server_kw) -> SampleServer:
    srv = SampleServer(pool_capacity=32, max_queue_depth=4096,
                       max_replicas_per_call=max_r, pack=pack,
                       **server_kw)
    for name, L, seed in (("ea_a", 5, 11), ("ea_b", 6, 12)):
        g = ea3d(L, seed=seed)
        srv.register_problem(name, graph=g,
                             coloring=lattice3d_coloring(L), rng="lfsr")
    # pools start hot for every (problem, engine, pow2-bucket) the packer
    # can form, so the measured waves compare scheduling, not compile luck
    buckets = [2] if not pack else \
        [b for b in (2, 4, 8, 16, 32, 64) if b <= max_r]
    threads = []
    for prob, eng, sync in _MIX:
        for b in buckets:
            threads.append(srv.prewarm(prob, engine=eng, replicas=b,
                                       sweeps=sweeps, sync_every=sync))
    for t in threads:
        t.join()
    return srv


_MIX = [("ea_a", "gibbs", 1), ("ea_a", "dsim", 4),
        ("ea_b", "gibbs", 1), ("ea_b", "dsim", 4)]


def _wave(srv: SampleServer, n_jobs: int, sweeps: int, rate: float,
          seed0: int) -> dict:
    """Submit n_jobs at `rate` jobs/s (inf = burst), wait for all, and
    return latency percentiles + throughput + packing evidence."""
    calls0 = srv.stats()["engine_calls"]
    ids = []
    t0 = time.perf_counter()
    # per-phase spans on the server's own tracer: "run" is the paced
    # submission window (jobs complete concurrently inside it), "drain"
    # the tail from last submit to last result — a goodput regression is
    # attributable to one or the other (satellite: phase timing per wave)
    with srv.tracer.span("wave.run", jobs=n_jobs) as sp_run:
        for i in range(n_jobs):
            if np.isfinite(rate):
                target = t0 + i / rate
                delay = target - time.perf_counter()
                if delay > 0:
                    time.sleep(delay)
            prob, eng, sync = _MIX[i % len(_MIX)]
            ids.append(srv.submit(prob, engine=eng, sweeps=sweeps,
                                  replicas=2, seed=seed0 + i,
                                  sync_every=sync))
    with srv.tracer.span("wave.drain", jobs=n_jobs) as sp_drain:
        results = [srv.result(j, timeout=600.0) for j in ids]
    elapsed = time.perf_counter() - t0
    assert all(r["status"] == "done" for r in results)
    lat_ms = np.asarray([r["total_s"] for r in results]) * 1e3
    p50, p95, p99 = np.percentile(lat_ms, [50, 95, 99])
    return {
        "jobs": n_jobs,
        "throughput_jobs_per_s": n_jobs / elapsed,
        "p50_ms": float(p50), "p95_ms": float(p95), "p99_ms": float(p99),
        "engine_calls": srv.stats()["engine_calls"] - calls0,
        "flips_total": int(sum(r["flips"] for r in results)),
        "elapsed_s": elapsed,
        "phase_s": {"build": 0.0,          # pools prewarmed by _make_server
                    "run": sp_run.duration_s,
                    "drain": sp_drain.duration_s},
    }


def _fault_wave(fault_rate: float, n_jobs: int, sweeps: int, max_r: int,
                seed0: int) -> dict:
    """One burst wave against a fresh packed server whose chunks fail
    (transient) with ``fault_rate`` probability; recovery machinery on
    (checkpoint resume, bisect, retries).  Jobs that exhaust recovery may
    end FAILED — goodput counts only DONE jobs, and nothing here asserts
    all-done at nonzero rates."""
    plan = None if fault_rate <= 0 else FaultPlan(
        [FaultRule(site="chunk", kind="transient", rate=fault_rate,
                   times=None)], seed=17)
    # the build phase (server + prewarm compiles) happens before the
    # server's own tracer exists, so it gets a wave-local tracer; run and
    # drain land on the server tracer next to its pump.chunk spans
    tr = Tracer()
    with tr.span("wave.build", fault_rate=fault_rate) as sp_build:
        srv = _make_server(True, max_r, sweeps, fault_plan=plan,
                           checkpoint_every=max(sweeps // 8, 1),
                           max_bisect_calls=64)
        srv.start()
    ids = []
    t0 = time.perf_counter()
    with srv.tracer.span("wave.run", jobs=n_jobs,
                         fault_rate=fault_rate) as sp_run:
        for i in range(n_jobs):
            prob, eng, sync = _MIX[i % len(_MIX)]
            ids.append(srv.submit(prob, engine=eng, sweeps=sweeps,
                                  replicas=2, seed=seed0 + i,
                                  sync_every=sync, max_retries=8))
    results = []
    with srv.tracer.span("wave.drain", jobs=n_jobs) as sp_drain:
        for j in ids:
            try:
                results.append(srv.result(j, timeout=600.0))
            except TimeoutError:
                results.append(srv.poll(j))
    elapsed = time.perf_counter() - t0
    s = srv.stats()
    srv.stop()
    done = [r for r in results if r["status"] == "done"]
    lat_ms = sorted(r["total_s"] * 1e3 for r in done)
    p99 = float(np.percentile(lat_ms, 99)) if lat_ms else float("nan")
    return {
        "injected_fault_rate": fault_rate,
        "jobs": n_jobs,
        "done": len(done),
        "failed": s["failed"],
        "goodput_jobs_per_s": len(done) / elapsed,
        "p99_ms": p99,
        "retries": s["retries"],
        "quarantined_batches": s["quarantined_batches"],
        "bisect_requeues": s["bisect_requeues"],
        "faults_injected": s["faults_injected"],
        "checkpoints_written": s["checkpoints_written"],
        # recovered-vs-restarted: sweeps continued from a checkpoint vs
        # sweeps re-executed from scratch across every job's lifetime
        "recovered_sweeps": int(sum(r["resumed_sweeps"] for r in results)),
        "restarted_sweeps": int(sum(r["restarted_sweeps"]
                                    for r in results)),
        "elapsed_s": elapsed,
        "phase_s": {"build": sp_build.duration_s,
                    "run": sp_run.duration_s,
                    "drain": sp_drain.duration_s},
    }


def run(quick: bool = True):
    n_jobs = 16 if quick else 64
    sweeps = 256 if quick else 2048
    max_r = 16 if quick else 64
    rates = [8.0, float("inf")] if quick else [4.0, 16.0, float("inf")]

    reps = 3 if quick else 5
    servers = {}
    for mode, pack in (("packed", True), ("baseline", False)):
        srv = _make_server(pack, max_r, sweeps)
        srv.start()
        # one full-size untimed wave on top of the prewarmed pool (first
        # wave in a process carries residual warmup noise) before measuring
        _wave(srv, n_jobs, sweeps, float("inf"), seed0=900)
        servers[mode] = srv

    loads, rows = [], []
    for ri, rate in enumerate(rates):
        entry = {"offered_jobs_per_s": ("burst" if not np.isfinite(rate)
                                        else rate)}
        # best-of-N with modes interleaved, so host drift hits both equally
        # (this container's scheduler swings ~2x run to run); the per-rep
        # throughputs ride along as the spread
        waves = {m: [] for m in servers}
        for rep in range(reps):
            for mode, srv in servers.items():
                waves[mode].append(_wave(srv, n_jobs, sweeps, rate,
                                         seed0=1000 + 100 * ri + 10 * rep))
        for mode in servers:
            best = max(waves[mode],
                       key=lambda w: w["throughput_jobs_per_s"])
            best["throughput_reps"] = [w["throughput_jobs_per_s"]
                                       for w in waves[mode]]
            entry[mode] = best
        entry["speedup_packed_vs_baseline"] = (
            entry["packed"]["throughput_jobs_per_s"]
            / entry["baseline"]["throughput_jobs_per_s"])
        loads.append(entry)
        tag = entry["offered_jobs_per_s"]
        for mode in ("packed", "baseline"):
            e = entry[mode]
            rows.append(row(
                f"serve_load_{mode}@{tag}", e["p50_ms"] * 1e3,
                f"{e['throughput_jobs_per_s']:.2f} jobs/s, "
                f"p95 {e['p95_ms']:.0f} ms, "
                f"{e['engine_calls']} calls / {e['jobs']} jobs"))

    # telemetry snapshot of the packed server AFTER the measured waves:
    # queue-wait / pump-latency / goodput histograms are populated, and
    # the Prometheus text head documents the exposition in the record
    telemetry = {
        "metrics": servers["packed"].metrics_snapshot(),
        "prometheus_head":
            servers["packed"].render_metrics().splitlines()[:12],
    }

    for srv in servers.values():
        srv.stop()

    fault_waves = []
    for fi, fr in enumerate(FAULT_RATES):
        w = _fault_wave(fr, n_jobs, sweeps, max_r, seed0=5000 + 1000 * fi)
        fault_waves.append(w)
        rows.append(row(
            f"serve_load_faults@{fr:.0%}", w["p99_ms"] * 1e3,
            f"{w['goodput_jobs_per_s']:.2f} done-jobs/s "
            f"({w['done']}/{w['jobs']} done, {w['retries']} retries, "
            f"{w['recovered_sweeps']} sweeps resumed / "
            f"{w['restarted_sweeps']} restarted)"))

    # measured η rides with the serving record too: the serving tier runs
    # the same recorded-cursor machinery, and the schema gate requires a
    # finite measured η in every BENCH telemetry block
    telemetry["eta"] = eta_probe(L=4, sweeps=32)

    best = max(e["speedup_packed_vs_baseline"] for e in loads)
    burst = loads[-1]
    bench = {
        "bench": "serve_load",
        "mode": "quick" if quick else "full",
        "host": host_fingerprint(),
        "workload": {"jobs_per_wave": n_jobs, "sweeps": sweeps,
                     "replicas_per_job": 2,
                     "max_replicas_per_call": max_r,
                     "mix": [f"{p}/{e}" for p, e, _ in _MIX]},
        "loads": loads,
        "fault_waves": fault_waves,
        "telemetry": telemetry,
        "speedup_packed_vs_baseline_best": best,
        "packing_observed": bool(
            burst["packed"]["engine_calls"] < burst["packed"]["jobs"]),
    }
    save_detail("serve_load", bench)
    with open(ROOT_BENCH, "w") as f:
        json.dump(bench, f, indent=1, default=float)
    rows.append(row("serve_load_speedup_best", 0.1,
                    f"packed vs baseline x{best:.2f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
