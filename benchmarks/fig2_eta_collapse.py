"""Fig. 2 — a single timing ratio controls optimization quality.

Reduced-scale EA spin glasses on a K-partition chain; residual energy at a
fixed sweep budget versus the staleness control S (exchange every S sweeps;
eta ~ eta_threshold/S via Eq. 2).  The paper's claim: quality depends on the
ratio only, and saturates above a topology-dependent threshold."""

from __future__ import annotations

import numpy as np

from repro.core.graph import ea3d
from repro.core.coloring import lattice3d_coloring
from repro.core.partition import slab_partition
from repro.core.dsim import build_partitioned, DSIMEngine
from repro.core.commcost import (boundary_matrix, ChainTopology, comm_cost,
                                 eta_threshold)
from repro.core.annealing import ea_schedule
from repro.core.analysis import bootstrap_ci, eta_from_sync
from repro.problems.ea3d import GroundStore, establish_grounds, instance_set

from .common import QUICK, FULL, save_detail, row, timed

SYNCS = ["phase", 1, 4, 16, 64, 256, None]


def run(quick: bool = True):
    cfgv = QUICK if quick else FULL
    L, K = cfgv["L"], cfgv["K"]
    budget = cfgv["budget"]
    graphs = instance_set(L, cfgv["instances"], seed0=cfgv["seed0"])
    store = GroundStore("reports/bench/grounds.json")
    grounds = establish_grounds(graphs, store, sweeps=4 * budget, runs=1)
    col = lattice3d_coloring(L)
    sch = ea_schedule(budget)

    # comm-cost model for the eta axis (paper Eq. 2 evaluated on this map)
    g0 = graphs[0]
    labels = slab_partition(L, K)
    b = boundary_matrix(np.asarray(g0.idx), np.asarray(g0.w), labels, K)
    topo = ChainTopology(pins=[32] * (K - 1))
    cm = comm_cost(b, topo).c_max
    thr = eta_threshold(col.n_colors, cm)

    results = {}
    total_us = 0.0
    for sync in SYNCS:
        rhos = []
        for gi, (g, Eg) in enumerate(zip(graphs, grounds)):
            prob = build_partitioned(g, col, slab_partition(L, K), K)
            eng = DSIMEngine(prob, rng="lfsr")
            for r in range(cfgv["runs"]):
                st = eng.init_state(seed=1000 * gi + r)
                (st, (_, Es)), us = timed(
                    eng.run_recorded, st, sch, [budget], sync_every=sync)
                total_us += us
                rhos.append((float(Es[-1]) - Eg) / g.n)
        point, lo, hi = bootstrap_ci(np.asarray(rhos), seed=0)
        results[str(sync)] = {
            "eta": eta_from_sync(sync, col.n_colors, cm),
            "rho": point, "lo": lo, "hi": hi}

    save_detail("fig2_eta_collapse", {
        "L": L, "K": K, "budget": budget, "eta_threshold": thr,
        "c_max": cm, "n_colors": col.n_colors, "results": results})

    rho_exact = results["phase"]["rho"]
    rho_none = results["None"]["rho"]
    # trend check robust to CI-level noise between adjacent settings:
    # Spearman-style rank correlation between staleness order and rho
    rhos_in_order = [results[str(s)]["rho"] for s in SYNCS]
    ranks = np.argsort(np.argsort(rhos_in_order))
    n = len(SYNCS)
    rs = np.corrcoef(np.arange(n), ranks)[0, 1]
    return [row("fig2_eta_collapse", total_us / max(len(SYNCS), 1),
                f"rho_exact={rho_exact:.4f} rho_nocomm={rho_none:.4f} "
                f"rank_corr={rs:.2f} eta_thr={thr:.0f}")]
