"""Fig. S9 — disconnected-links control.

With boundary exchange off (sync=None), each partition's local-subgraph
energy trace must be independent of everything except its own dynamics —
stable across runs and matching an isolated anneal of the same subgraph.
This isolates staleness (not local-update corruption) as the origin of the
coupled-run slope loss."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.graph import ea3d
from repro.core.coloring import lattice3d_coloring
from repro.core.partition import slab_partition
from repro.core.dsim import build_partitioned, DSIMEngine
from repro.core.annealing import ea_schedule
from repro.core.pbit import S41

from .common import save_detail, row


def per_partition_energy(eng, st):
    """Local-subgraph energies (excluding ghost couplings entirely)."""
    p = eng.p
    mext = jnp.concatenate(
        [st.m.astype(jnp.float32),
         jnp.zeros_like(st.ghosts)], axis=1)       # ghosts zeroed out
    import jax
    nbr = jax.vmap(lambda row, ii: row[ii])(mext, p.local_idx)
    e = -0.5 * (st.m.astype(jnp.float32) *
                (p.local_w * nbr).sum(-1)) - p.local_h * st.m
    return np.asarray((e * p.valid).sum(axis=1))


def run(quick: bool = True):
    L, K = (8, 4) if quick else (12, 6)
    budget = 1024 if quick else 8192
    g = ea3d(L, seed=0)
    col = lattice3d_coloring(L)
    prob = build_partitioned(g, col, slab_partition(L, K), K)
    sch = ea_schedule(budget)

    finals = []
    for s in range(4):
        eng = DSIMEngine(prob, rng="lfsr", fmt=S41)
        st = eng.init_state(seed=s)
        st, _ = eng.run_recorded(st, sch, [budget], sync_every=None)
        finals.append(per_partition_energy(eng, st))
    finals = np.asarray(finals)                     # (runs, K)
    spread = finals.std(axis=0) / np.abs(finals.mean(axis=0))
    save_detail("figS9_disconnected", {
        "per_partition_mean": finals.mean(axis=0).tolist(),
        "per_partition_relstd": spread.tolist()})
    return [row("figS9_disconnected", 1e6,
                f"local E stable: max rel-std {spread.max():.3f} over "
                f"{K} partitions x 4 runs")]
