"""Fig. 3 — stale boundaries reduce the power-law exponent identically in
hardware (DSIM) and theory (CMFT).

Fits kappa_f from residual-energy traces across staleness settings for both
engines with identical partitioning, instances and schedule; the exponent
saturates toward the exact limit under frequent exchange and degrades under
infrequent exchange, with the CMFT S axis mapping monotonically onto eta."""

from __future__ import annotations

import numpy as np

from repro.core.graph import ea3d
from repro.core.coloring import lattice3d_coloring
from repro.core.partition import slab_partition
from repro.core.dsim import build_partitioned, DSIMEngine
from repro.core.gibbs import GibbsEngine
from repro.core.annealing import ea_schedule
from repro.core.analysis import bootstrap_kappa
from repro.problems.ea3d import GroundStore, establish_grounds, instance_set

from .common import QUICK, FULL, save_detail, row, timed

SYNCS = ["phase", 4, 32, 128, None]


def traces(engine_fn, graphs, grounds, pts, sch, runs, sync):
    rhos = []
    for gi, (g, Eg) in enumerate(zip(graphs, grounds)):
        eng = engine_fn(g)
        for r in range(runs):
            st = eng.init_state(seed=777 * gi + r)
            st, (ts, Es) = eng.run_recorded(st, sch, pts, sync_every=sync)
            rhos.append((np.asarray(Es) - Eg) / g.n)
    return np.asarray(ts), np.asarray(rhos)


def run(quick: bool = True):
    cfgv = QUICK if quick else FULL
    L, K, budget = cfgv["L"], cfgv["K"], 2 * cfgv["budget"]
    graphs = instance_set(L, cfgv["instances"], seed0=cfgv["seed0"])
    store = GroundStore("reports/bench/grounds.json")
    grounds = establish_grounds(graphs, store, sweeps=4 * budget, runs=1)
    col = lattice3d_coloring(L)
    sch = ea_schedule(budget)
    pts = sorted(set(np.geomspace(4, budget, 16).astype(int)))
    win = (8, budget)

    out = {"dsim": {}, "cmft": {}}
    t_us = 0.0
    labels = slab_partition(L, K)
    for mode in ("dsim", "cmft"):
        for sync in SYNCS:
            if mode == "cmft" and sync in ("phase", None):
                continue

            def mk(g):
                prob = build_partitioned(g, col, labels, K)
                return DSIMEngine(prob, rng="lfsr", mode=mode)
            (ts, rhos), us = timed(traces, mk, graphs, grounds, pts, sch,
                                   cfgv["runs"], sync)
            t_us += us
            k, lo, hi = bootstrap_kappa(ts, rhos, window=win, n_boot=200)
            out[mode][str(sync)] = {"kappa": k, "lo": lo, "hi": hi}

    # monolithic reference exponent (the paper's GPU baseline role)
    def mono(g):
        return GibbsEngine(g, col)
    rhos = []
    for gi, (g, Eg) in enumerate(zip(graphs, grounds)):
        eng = mono(g)
        for r in range(cfgv["runs"]):
            st = eng.init_state(seed=777 * gi + r)
            st, Es = eng.run_recorded(st, sch, pts)
            rhos.append((np.asarray(Es) - Eg) / g.n)
    k_mono, lo_m, hi_m = bootstrap_kappa(np.asarray(pts), np.asarray(rhos),
                                         window=win, n_boot=200)
    out["monolithic"] = {"kappa": k_mono, "lo": lo_m, "hi": hi_m}

    save_detail("fig3_kappa_vs_eta", {"L": L, "K": K, "budget": budget,
                                      "syncs": [str(s) for s in SYNCS],
                                      "results": out})
    k_exact = out["dsim"]["phase"]["kappa"]
    k_stale = out["dsim"]["128"]["kappa"]
    return [row("fig3_kappa_vs_eta", t_us / 8,
                f"kappa_mono={k_mono:.3f} kappa_phase={k_exact:.3f} "
                f"kappa_S128={k_stale:.3f} "
                f"cmft_S4={out['cmft']['4']['kappa']:.3f} "
                f"cmft_S128={out['cmft']['128']['kappa']:.3f}")]
