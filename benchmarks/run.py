# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
import argparse
import inspect
import sys
import traceback


MODULES = [
    "fig2_eta_collapse",
    "fig3_kappa_vs_eta",
    "fig45_time_to_target",
    "flip_rate",
    "serve_load",
    "tableS2_maxcut",
    "figS15_sat",
    "figS3_commcost",
    "figS5_partition",
    "figS9_disconnected",
    "figS13_planted",
    "roofline_table",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None)
    ap.add_argument("--full", action="store_true",
                    help="larger lattices / budgets (hours on CPU)")
    ap.add_argument("--engine", default=None,
                    choices=["gibbs", "dsim", "dsim_dist", "lattice"],
                    help="restrict engine-aware benchmarks to one registry "
                         "backend")
    ap.add_argument("--replicas", type=int, default=1,
                    help="replica batch (R independent chains per call) for "
                         "engine-aware benchmarks")
    args = ap.parse_args()

    mods = args.only if args.only else MODULES
    print("name,us_per_call,derived")
    failures = 0
    for name in mods:
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            kw = {"quick": not args.full}
            # engine/replicas forwarded to every benchmark whose run()
            # accepts them (the registry-migrated ones)
            params = inspect.signature(mod.run).parameters
            if "engine" in params and args.engine is not None:
                kw["engine"] = args.engine
            if "replicas" in params:
                kw["replicas"] = args.replicas
            for r in mod.run(**kw):
                print(f"{r['name']},{r['us_per_call']:.1f},\"{r['derived']}\"")
            sys.stdout.flush()
        except Exception as e:
            failures += 1
            print(f"{name},nan,\"FAILED: {type(e).__name__}: {e}\"")
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(f"{failures} benchmarks failed")


if __name__ == '__main__':
    main()
