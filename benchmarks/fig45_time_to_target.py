"""Figs. 4/5 — time-to-target: conservative (exact) vs overclocked (stale).

The stale mode produces more sweeps per second (here: the measured
wall-clock speedup of exchanging every S sweeps instead of every phase),
each consuming staler boundaries; easy targets favor throughput, hard
targets favor exactness, with a crossover in between — the paper's central
throughput/accuracy tradeoff, with flips/s measured on this machine."""

from __future__ import annotations

import time

import numpy as np

from repro.core.coloring import lattice3d_coloring
from repro.core.partition import slab_partition
from repro.core.dsim import build_partitioned, DSIMEngine
from repro.core.annealing import ea_schedule
from repro.core.analysis import time_to_target
from repro.problems.ea3d import GroundStore, establish_grounds, instance_set

from .common import QUICK, FULL, save_detail, row


def measured_rate(eng, sch, sweeps, sync):
    st = eng.init_state(seed=0)
    # warmup/compile
    eng.run_recorded(st, sch, [sweeps // 4], sync_every=sync)
    st = eng.init_state(seed=1)
    t0 = time.perf_counter()
    eng.run_recorded(st, sch, [sweeps], sync_every=sync)
    dt = time.perf_counter() - t0
    return sweeps / dt


def run(quick: bool = True):
    cfgv = QUICK if quick else FULL
    L, K, budget = cfgv["L"], cfgv["K"], 2 * cfgv["budget"]
    graphs = instance_set(L, cfgv["instances"], seed0=cfgv["seed0"])
    store = GroundStore("reports/bench/grounds.json")
    grounds = establish_grounds(graphs, store, sweeps=4 * budget, runs=1)
    col = lattice3d_coloring(L)
    sch = ea_schedule(budget)
    pts = sorted(set(np.geomspace(4, budget, 16).astype(int)))
    labels = slab_partition(L, K)

    modes = {"conservative": "phase", "overclocked": 64}
    data, rates = {}, {}
    for name, sync in modes.items():
        rhos = []
        for gi, (g, Eg) in enumerate(zip(graphs, grounds)):
            prob = build_partitioned(g, col, labels, K)
            eng = DSIMEngine(prob, rng="lfsr")
            for r in range(cfgv["runs"]):
                st = eng.init_state(seed=11 * gi + r)
                st, (ts, Es) = eng.run_recorded(st, sch, pts, sync_every=sync)
                rhos.append((np.asarray(Es) - Eg) / graphs[gi].n)
        data[name] = (np.asarray(ts), np.mean(rhos, axis=0))
        prob = build_partitioned(graphs[0], col, labels, K)
        eng = DSIMEngine(prob, rng="lfsr")
        rates[name] = measured_rate(eng, sch, min(1024, budget), sync)

    flips_per_sweep = graphs[0].n
    detail = {"rates_sweeps_per_s": rates,
              "flips_per_s": {k: v * flips_per_sweep for k, v in rates.items()},
              "traces": {k: {"t": v[0].tolist(), "rho": v[1].tolist()}
                         for k, v in data.items()}}

    # time-to-target on the wall clock implied by measured rates
    targets = {}
    rhos_all = np.concatenate([v[1] for v in data.values()])
    for frac, tag in ((0.5, "easy"), (0.12, "hard")):
        tgt = float(np.nanmin(rhos_all)) + frac * float(np.nanmax(rhos_all))
        tt = {}
        for name in modes:
            t, rho = data[name]
            tt[name] = time_to_target(t / rates[name], rho, tgt)
        targets[tag] = {"target_rho": tgt, **tt}
    detail["targets"] = targets
    save_detail("fig45_time_to_target", detail)

    e = targets["easy"]
    h = targets["hard"]
    sp_easy = e["conservative"] / e["overclocked"] if e["overclocked"] else 0
    sp_hard = h["conservative"] / h["overclocked"] \
        if np.isfinite(h["overclocked"]) and h["overclocked"] else float("nan")
    return [row("fig45_time_to_target", 1e6,
                f"flips/s cons={detail['flips_per_s']['conservative']:.2e} "
                f"over={detail['flips_per_s']['overclocked']:.2e} "
                f"speedup_easy={sp_easy:.2f}x speedup_hard={sp_hard:.2f}x")]
