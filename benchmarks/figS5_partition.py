"""Fig. S5 — cut-edge distance distribution: distance-blind vs Potts.

The Potts objective concentrates cut edges at hop distance 1 (paper: 73.1%
vs 47.4% for METIS) and Fig. S6: solution quality is unchanged."""

from __future__ import annotations

import numpy as np

from repro.core.graph import ea3d
from repro.core.coloring import lattice3d_coloring
from repro.core.partition import greedy_partition
from repro.core.potts_partition import potts_partition
from repro.core.commcost import cut_distance_histogram
from repro.core.dsim import build_partitioned, DSIMEngine
from repro.core.annealing import ea_schedule

from .common import save_detail, row


def run(quick: bool = True):
    L, K = (10, 4) if quick else (16, 6)
    budget = 1024 if quick else 8192
    g = ea3d(L, seed=0)
    idx, w = np.asarray(g.idx), np.asarray(g.w)
    col = lattice3d_coloring(L)
    sch = ea_schedule(budget)

    out = {}
    for name, labels in (
            ("metis_like", greedy_partition(idx, w, K, seed=0)),
            ("potts", potts_partition(idx, w, K, seed=0))):
        hist = cut_distance_histogram(idx, w, labels, K=K)
        # Fig. S6: solution quality unchanged under the Potts objective
        energies = []
        for s in range(3):
            prob = build_partitioned(g, col, labels, K)
            eng = DSIMEngine(prob, rng="lfsr")
            st = eng.init_state(seed=s)
            st, (_, Es) = eng.run_recorded(st, sch, [budget], sync_every=4)
            energies.append(float(Es[-1]))
        out[name] = {"d1_frac": float(hist[0]), "hist": hist.tolist(),
                     "mean_E": float(np.mean(energies))}
    save_detail("figS5_partition", out)
    dE = abs(out["potts"]["mean_E"] - out["metis_like"]["mean_E"])
    rel = dE / abs(out["metis_like"]["mean_E"])
    return [row("figS5_partition_distance", 1e6,
                f"d1: potts={out['potts']['d1_frac']:.2f} vs "
                f"metis={out['metis_like']['d1_frac']:.2f}; "
                f"quality_delta={100 * rel:.1f}%")]
