"""Fig. S3 — permutation sensitivity of the communication cost on a chain.

For a K-cluster partition of an EA lattice, the physical slot ordering
changes C_tot by a large factor for distance-blind partitions, while the
Potts partition's canonical order is already (near-)optimal."""

from __future__ import annotations

import itertools

import numpy as np

from repro.core.graph import ea3d
from repro.core.partition import greedy_partition
from repro.core.potts_partition import potts_partition
from repro.core.commcost import (boundary_matrix, ChainTopology, comm_cost,
                                 best_chain_permutation)

from .common import save_detail, row


def run(quick: bool = True):
    L, K = (10, 4) if quick else (16, 6)
    g = ea3d(L, seed=0)
    idx, w = np.asarray(g.idx), np.asarray(g.w)
    topo = ChainTopology(pins=[32] * (K - 1))

    out = {}
    for name, labels in (
            ("metis_like", greedy_partition(idx, w, K, seed=0)),
            ("potts", potts_partition(idx, w, K, seed=0))):
        b = boundary_matrix(idx, w, labels, K)
        costs = []
        for perm in itertools.permutations(range(K)):
            if perm[0] > perm[-1]:
                continue
            costs.append(comm_cost(b, topo, np.asarray(perm)).c_tot)
        canonical = comm_cost(b, topo).c_tot
        best, best_c = best_chain_permutation(b, topo)
        out[name] = {"canonical": canonical, "best": best_c,
                     "worst": max(costs), "spread": max(costs) / max(min(costs), 1e-9),
                     "canonical_is_best": canonical <= best_c * 1.02}
    save_detail("figS3_commcost", out)
    return [row("figS3_commcost_permutations", 1e6,
                f"metis spread={out['metis_like']['spread']:.2f}x "
                f"potts canonical_best={out['potts']['canonical_is_best']}")]
