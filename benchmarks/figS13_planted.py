"""Fig. S13 — planted instances with known ground states.

Frustrated-loop planting on irregular (random-regular) and lattice hosts;
the distributed sampler must reach the planted ground energy (the paper's
Pegasus/Zephyr capability demonstration, topology-agnostic)."""

from __future__ import annotations

import numpy as np

from repro.core.graph import ea3d, random_regular
from repro.core.coloring import greedy_coloring
from repro.core.partition import greedy_partition
from repro.core.dsim import build_partitioned, DSIMEngine
from repro.core.annealing import Schedule
from repro.problems.planting import plant_frustrated_loops

from .common import save_detail, row


def run(quick: bool = True):
    budget = 2000 if quick else 20000
    hosts = {
        "lattice_6": ea3d(6, seed=1),
        "random_reg_500_d6": random_regular(500, 6, seed=2),
    }
    out = {}
    for name, host in hosts.items():
        inst = plant_frustrated_loops(host, n_loops=host.n // 4, seed=3)
        g = inst.graph
        col = greedy_coloring(np.asarray(g.idx), np.asarray(g.w))
        K = 4
        labels = greedy_partition(np.asarray(g.idx), np.asarray(g.w), K,
                                  seed=0)
        prob = build_partitioned(g, col, labels, K)
        eng = DSIMEngine(prob, rng="lfsr")
        sch = Schedule(np.arange(0.5, 8.01, 0.5), budget)
        reached = []
        for s in range(3):
            st = eng.init_state(seed=s)
            st, (_, Es) = eng.run_recorded(
                st, sch, sorted(set(np.geomspace(8, budget, 8).astype(int))),
                sync_every=4)
            best = float(np.asarray(Es).min())
            reached.append(best <= inst.ground_energy + 1e-3)
        out[name] = {"ground": inst.ground_energy,
                     "reached": int(sum(reached)), "runs": len(reached)}
    save_detail("figS13_planted", out)
    return [row("figS13_planted", 1e6,
                " ".join(f"{k}:{v['reached']}/{v['runs']}"
                         for k, v in out.items()))]
