"""Table S2 / Fig. S11 — Max-Cut with APT+ICM on a toroidal Gset-family
instance (the G81 file itself is not bundled offline; same topology and
weight distribution at reduced size).  Reports the best-cut distribution
across independent trials and the hex-encoded best configuration, exactly
the paper's verification protocol."""

from __future__ import annotations

import numpy as np

from repro.core.coloring import greedy_coloring
from repro.core.apt_icm import APTICM, adapt_ladder
from repro.core.gibbs import GibbsEngine
from repro.core.annealing import Schedule
from repro.problems.maxcut import (gset_like_toroidal, maxcut_to_ising,
                                   cut_of, spins_to_hex)

from .common import save_detail, row


def run(quick: bool = True):
    rows, cols_ = (8, 12) if quick else (20, 40)
    sweeps = 600 if quick else 4000
    trials = 5 if quick else 10
    g = gset_like_toroidal(rows, cols_, seed=81)
    gi = maxcut_to_ising(g)
    col = greedy_coloring(np.asarray(gi.idx), np.asarray(gi.w))
    betas = adapt_ladder(gi, col, 1.0, 6.0, 6 if quick else 10,
                         pilot_sweeps=60)

    cuts = []
    for t in range(trials):
        apt = APTICM(gi, col, betas, chains=2)
        st = apt.init_state(seed=t)
        st, _ = apt.run(st, sweeps, icm_every=10, record_every=sweeps)
        m, E = apt.best_config(st)
        cuts.append(cut_of(g, m))
    best = max(cuts)
    best_m, _ = apt.best_config(st)

    # plain annealing baseline on the same budget
    eng = GibbsEngine(gi, col)
    s0 = eng.init_state(seed=0)
    s0, (Etr, _) = eng.run_dense(
        s0, Schedule(np.arange(0.5, 5.01, 0.5), sweeps).beta_array())
    anneal_cut = cut_of(g, np.asarray(s0.m))

    save_detail("tableS2_maxcut", {
        "grid": [rows, cols_], "n": g.n, "trials": trials,
        "cuts": cuts, "best": best, "anneal_cut": anneal_cut,
        "p_best": float(np.mean(np.asarray(cuts) == best)),
        "best_hex": spins_to_hex(best_m)})
    return [row("tableS2_maxcut", 1e6,
                f"best_cut={best:.0f} p(best)={np.mean(np.asarray(cuts)==best):.2f} "
                f"anneal={anneal_cut:.0f} n={g.n}")]
