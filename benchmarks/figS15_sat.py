"""Fig. S15 — invertible-logic 3SAT near the phase transition.

Random 3SAT at alpha ~ 4.26 encoded with OR-gate invertible logic +
copy-gate sparsification; satisfied clauses for the partitioned DSIM
against the monolithic engine (the paper's FPGA-vs-GPU comparison), both
with the paper's s{4}{3} fixed-point format."""

from __future__ import annotations

import numpy as np

from repro.core.coloring import greedy_coloring
from repro.core.partition import greedy_partition
from repro.core.dsim import build_partitioned, DSIMEngine
from repro.core.gibbs import GibbsEngine
from repro.core.annealing import sat_schedule
from repro.core.pbit import S43
from repro.problems.sat import (random_3sat, encode_3sat, decode_assignment,
                                count_satisfied)

from .common import save_detail, row


def run(quick: bool = True):
    n_vars = 60 if quick else 400
    m_cl = int(round(n_vars * 4.26))
    sweeps = 3000 if quick else 20000
    clauses = random_3sat(n_vars, m_cl, seed=426)
    enc = encode_3sat(clauses, n_vars)
    g = enc.graph
    col = greedy_coloring(np.asarray(g.idx), np.asarray(g.w))
    sch = sat_schedule(sweeps)

    # monolithic reference (the paper's GPU role)
    eng = GibbsEngine(g, col, rng="philox", fmt=S43)
    st = eng.init_state(seed=0)
    st, (Etr, _) = eng.run_dense(st, sch.beta_array())
    best_mono = count_satisfied(clauses,
                                decode_assignment(enc, np.asarray(st.m)))

    # partitioned DSIM: 4 clusters, stale boundaries, LFSR
    K = 4
    labels = greedy_partition(np.asarray(g.idx), np.asarray(g.w), K, seed=0)
    prob = build_partitioned(g, col, labels, K)
    deng = DSIMEngine(prob, rng="lfsr", fmt=S43)
    ds = deng.init_state(seed=0)
    ds, _ = deng.run_recorded(ds, sch, [sweeps], sync_every=4)
    best_dsim = count_satisfied(
        clauses, decode_assignment(enc, np.asarray(deng.global_spins(ds))))

    save_detail("figS15_sat", {
        "n_vars": n_vars, "clauses": m_cl, "alpha": m_cl / n_vars,
        "p_bits": g.n, "n_colors": col.n_colors, "sweeps": sweeps,
        "monolithic_satisfied": int(best_mono),
        "dsim_satisfied": int(best_dsim)})
    return [row("figS15_sat", 1e6,
                f"p_bits={g.n} mono={best_mono}/{m_cl} dsim={best_dsim}/"
                f"{m_cl} ({100 * best_dsim / m_cl:.1f}%)")]
