"""Aggregate the dry-run reports into the §Roofline table."""

from __future__ import annotations

import glob
import json
import os

from .common import save_detail, row

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "reports", "dryrun")


def load_cells():
    cells = []
    for f in sorted(glob.glob(os.path.join(DRYRUN, "*.json"))):
        cells.append(json.load(open(f)))
    return cells


def run(quick: bool = True):
    cells = load_cells()
    if not cells:
        return [row("roofline_table", 0.0,
                    "no dry-run reports; run python -m repro.launch.dryrun --all")]
    single = [c for c in cells if c["mesh"] == "single_pod_16x16"]
    bott = {}
    for c in single:
        bott[c["roofline"]["bottleneck"]] = \
            bott.get(c["roofline"]["bottleneck"], 0) + 1
    save_detail("roofline_table", {"cells": len(cells),
                                   "single_pod": len(single),
                                   "bottlenecks": bott})
    return [row("roofline_table", 0.0,
                f"cells={len(cells)} single_pod={len(single)} "
                f"bottlenecks={bott}")]
