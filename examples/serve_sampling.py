"""Serving walkthrough: the async sampling server end to end.

The paper's machine is a *shared* accelerator — one million p-bits serving
spin-glass, Max-Cut, and SAT tenants concurrently.  This example drives the
software analogue, ``repro.serve.SampleServer``, through the full serving
story on two small EA instances:

  1. register problems, prewarm the engine pool (cold compiles off the
     serving path),
  2. submit a burst of concurrent jobs across two problems and two engines
     — compatible requests coalesce into batched replica-packed engine
     calls (watch ``engine_calls`` vs jobs submitted),
  3. stream a long-running anneal with ``poll`` (partial energy trace,
     best-so-far configuration, exact flips, mid-anneal),
  4. preempt it with a high-priority job, cancel a queued one,
  5. read the final payloads and the scheduler/pool counters,
  6. crash mid-anneal and recover: a checkpointing server is abandoned
     between chunks, a fresh server adopts its spool with ``recover()``,
     and the resumed results come out bitwise-identical to a run that
     was never interrupted.

  PYTHONPATH=src python examples/serve_sampling.py
"""

import shutil
import tempfile
import time

import numpy as np

from repro.core.coloring import lattice3d_coloring
from repro.core.graph import ea3d
from repro.serve import SampleServer


def main():
    srv = SampleServer(pool_capacity=8, max_replicas_per_call=16)

    # -- 1. problems + prewarm ------------------------------------------------
    for name, L, seed in (("glass_a", 6, 1), ("glass_b", 7, 2)):
        g = ea3d(L, seed=seed)
        fp = srv.register_problem(name, graph=g,
                                  coloring=lattice3d_coloring(L), rng="lfsr")
        print(f"registered {name}: N={g.n}, fingerprint {fp}")
    srv.prewarm("glass_a", engine="gibbs", replicas=8, sweeps=512,
                wait=True)  # compile lands before any request needs it
    srv.start()             # serve on a background thread

    # -- 2. a burst of concurrent tenants ------------------------------------
    jobs = []
    for k in range(4):      # 4 compatible requests -> ONE batched call
        jobs.append(srv.submit("glass_a", engine="gibbs", sweeps=512,
                               replicas=2, seed=k))
    for k in range(2):      # different problem+engine -> their own batch
        jobs.append(srv.submit("glass_b", engine="dsim", sweeps=512,
                               replicas=2, seed=k, sync_every=4))
    for jid in jobs:
        r = srv.result(jid, timeout=300)
        print(f"{jid}: {r['status']}  best E = {r['best_energy']:9.1f}  "
              f"{r['flips']:,} flips  packed with {r['packed_with']} "
              f"co-tenants  (pool {'hit' if r['pool_hit'] else 'miss'})")
    s = srv.stats()
    print(f"--> {s['submitted']} jobs served by {s['engine_calls']} engine "
          f"calls (replica packing); pool {s['pool']['hits']} hits / "
          f"{s['pool']['misses']} misses")

    # -- 3./4. streaming, priorities, cancel ----------------------------------
    long_id = srv.submit("glass_a", engine="gibbs", sweeps=8192, replicas=2,
                         seed=77)
    victim = srv.submit("glass_b", engine="dsim", sweeps=4096, seed=78,
                        sync_every=4)
    srv.cancel(victim)      # still queued -> cancelled immediately
    while True:
        p = srv.poll(long_id)
        if p["status"] != "queued" and (p["status"] != "running"
                                        or p["sweeps_done"] >= 1024):
            break
        time.sleep(0.01)
    print(f"streaming {long_id}: {p['sweeps_done']}/{p['total_sweeps']} "
          f"sweeps, {len(p['times'])} trace points, best so far "
          f"{p['best_energy']:.1f}, {p['flips']:,} exact flips")
    hi = srv.submit("glass_a", engine="gibbs", sweeps=512, replicas=2,
                    seed=79, priority=10)
    r = srv.result(hi, timeout=300)   # overtakes the long anneal
    print(f"high-priority {hi} finished ({r['status']}) while {long_id} at "
          f"{srv.poll(long_id)['sweeps_done']} sweeps; preemptions: "
          f"{srv.stats()['preemptions']}")
    r = srv.result(long_id, timeout=600)
    trace = r["energies"].min(axis=1)
    print(f"{long_id} done: E trace {np.round(trace[:4], 1)} ... "
          f"-> {trace[-1]:.1f}")
    print(f"cancelled {victim}: {srv.poll(victim)['status']}")

    srv.stop()
    print("\nfinal stats:", {k: v for k, v in srv.stats().items()
                             if not isinstance(v, dict)})

    # -- 6. crash, recover, resume -------------------------------------------
    crash_recover_demo()


def crash_recover_demo():
    """Kill a checkpointing server mid-anneal; a fresh one resumes it."""
    print("\n--- crash / recover / resume ---")
    g = ea3d(5, seed=3)
    col = lattice3d_coloring(5)

    def fresh(spool):
        s = SampleServer(pool_capacity=4, max_replicas_per_call=8,
                         spool_dir=spool, checkpoint_every=128)
        s.register_problem("glass_c", graph=g, coloring=col, rng="lfsr")
        return s

    # the ground truth: the same two jobs on a server nobody crashes
    ref_srv = fresh(None)
    ref = {}
    for k in range(2):
        jid = ref_srv.submit("glass_c", engine="gibbs", sweeps=1024,
                             replicas=2, seed=40 + k)
        ref[k] = ref_srv.result(jid, timeout=300)
    ref_srv.drain()

    spool = tempfile.mkdtemp(prefix="serve_spool_")
    try:
        # server A checkpoints every 128 sweeps... and "crashes" (we just
        # abandon it between pumps — a kill -9 lands in the same place,
        # see tests/test_faults.py for the real-subprocess version)
        a = fresh(spool)
        for k in range(2):
            a.submit("glass_c", engine="gibbs", sweeps=1024, replicas=2,
                     seed=40 + k)
        while a.stats()["checkpoints_written"] < 2:
            a.pump()
        sa = a.stats()
        print(f"server A crashed with {sa['checkpoints_written']} "
              f"checkpoints spooled ({sa['spool']['nbytes']:,} bytes), "
              f"0/{sa['submitted']} jobs finished")
        del a

        # server B: re-register the problem, adopt the spool, drain
        b = fresh(spool)
        readmitted = b.recover()
        print(f"server B re-admitted {len(readmitted)} in-flight jobs")
        b.drain()
        for k, jid in enumerate(readmitted):
            r = b.poll(jid)
            same = (r["best_energy"] == ref[k]["best_energy"]
                    and np.array_equal(r["energies"], ref[k]["energies"])
                    and r["flips"] == ref[k]["flips"])
            print(f"{jid}: {r['status']}, resumed {r['resumed_sweeps']} "
                  f"sweeps from checkpoint, bitwise == uninterrupted run: "
                  f"{same}")
            assert same
        print("spool after drain:", b.stats()["spool"])
    finally:
        shutil.rmtree(spool, ignore_errors=True)


if __name__ == "__main__":
    main()
