"""Max-Cut with adaptive parallel tempering + isoenergetic cluster moves.

The paper's G81 protocol (Sec. S9) at reduced size: a toroidal +-1 grid,
APT preprocessing for the temperature ladder, APT+ICM search, best-cut
distribution over trials, and the hex-encoded verification string.

  PYTHONPATH=src python examples/maxcut_gset.py [--rows 10 --cols 16]
"""

import argparse

import numpy as np

from repro.core.coloring import greedy_coloring
from repro.core.apt_icm import APTICM, adapt_ladder
from repro.problems.maxcut import (gset_like_toroidal, maxcut_to_ising,
                                   cut_of, spins_to_hex)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=10)
    ap.add_argument("--cols", type=int, default=16)
    ap.add_argument("--sweeps", type=int, default=1500)
    ap.add_argument("--trials", type=int, default=5)
    args = ap.parse_args()

    g = gset_like_toroidal(args.rows, args.cols, seed=81)
    gi = maxcut_to_ising(g)
    col = greedy_coloring(np.asarray(gi.idx), np.asarray(gi.w))
    print(f"toroidal grid {args.rows}x{args.cols} (n={g.n}), "
          f"{col.n_colors} colors")

    betas = adapt_ladder(gi, col, 1.0, 6.0, 8, pilot_sweeps=80)
    print("adaptive ladder:", np.round(betas, 2))

    cuts, best_m = [], None
    for t in range(args.trials):
        apt = APTICM(gi, col, betas, chains=2)
        st = apt.init_state(seed=t)
        st, _ = apt.run(st, args.sweeps, icm_every=10,
                        record_every=args.sweeps)
        m, E = apt.best_config(st)
        c = cut_of(g, m)
        cuts.append(c)
        if c == max(cuts):
            best_m = m
        print(f"trial {t}: cut = {c:.0f}  (E = {E:.0f}, "
              f"{int(st.swaps)} swaps, {int(st.icms)} cluster moves)")

    best = max(cuts)
    print(f"\nbest cut {best:.0f}; found in "
          f"{100 * np.mean(np.asarray(cuts) == best):.0f}% of trials")
    print("verification hex (paper S9 format):")
    print(spins_to_hex(best_m)[:120] + "...")


if __name__ == "__main__":
    main()
