"""Batched serving example: prefill + greedy decode over every cache kind.

Runs reduced configs of four cache families — standard KV (deepseek),
rolling SWA ring (danube), pure SSM state (mamba2), hybrid (jamba) — and
prints tokens/s for batched greedy generation.

  PYTHONPATH=src python examples/serve_lm.py [--max-new 16]
"""

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.lm import build_model
from repro.serve.serve_step import greedy_generate

ARCHS = ["deepseek-7b", "h2o-danube-1.8b", "mamba2-370m", "jamba-v0.1-52b"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    args = ap.parse_args()

    for name in ARCHS:
        cfg = get_config(name).reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        toks = jnp.zeros((args.batch, args.prompt_len), jnp.int32)
        batch = {"tokens": toks}
        # warmup (compile)
        greedy_generate(model, cfg, params, batch, max_new=2)
        t0 = time.perf_counter()
        out = greedy_generate(model, cfg, params, batch, max_new=args.max_new)
        dt = time.perf_counter() - t0
        rate = args.batch * args.max_new / dt
        print(f"{name:18s} generated {out.shape} in {dt:5.2f}s "
              f"({rate:7.1f} tok/s, reduced config)")


if __name__ == "__main__":
    main()
