"""Quickstart: sample a 3D Edwards-Anderson spin glass with the p-computer.

Builds a small EA instance, anneals it with the monolithic chromatic Gibbs
engine (the paper's GPU-baseline role), then runs the same instance on the
partitioned DSIM at several boundary-exchange frequencies and prints the
eta-staleness effect — the paper's core result, in one screen of code.

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core.graph import ea3d
from repro.core.coloring import lattice3d_coloring
from repro.core.partition import slab_partition
from repro.core.gibbs import GibbsEngine
from repro.core.dsim import build_partitioned, DSIMEngine
from repro.core.commcost import (boundary_matrix, ChainTopology, comm_cost,
                                 eta_threshold)
from repro.core.annealing import ea_schedule
from repro.core.analysis import eta_from_sync


def main():
    L, K, budget = 10, 4, 2048
    print(f"EA spin glass L={L} (N={L**3}), {K}-FPGA-style chain, "
          f"{budget} sweeps\n")
    g = ea3d(L, seed=0)
    col = lattice3d_coloring(L)
    print(f"coloring: {col.n_colors} colors (paper: 2 for even L, 3 odd)")

    # monolithic reference
    eng = GibbsEngine(g, col, rng="philox")
    st = eng.init_state(seed=0)
    st, (Etr, flips) = eng.run_dense(st, ea_schedule(budget).beta_array())
    print(f"monolithic  : E = {float(Etr[-1]):9.1f}   "
          f"({np.asarray(flips).sum():,} flips)")

    # the design rule (Eq. 2) for this partition on a chain
    labels = slab_partition(L, K)
    b = boundary_matrix(np.asarray(g.idx), np.asarray(g.w), labels, K)
    cm = comm_cost(b, ChainTopology(pins=[32] * (K - 1))).c_max
    thr = eta_threshold(col.n_colors, cm)
    print(f"\ncomm-cost model: C_max = {cm:.1f}, "
          f"eta threshold = 2*N_color*C_max = {thr:.0f}\n")

    prob = build_partitioned(g, col, labels, K)
    for sync in ["phase", 1, 16, 128, None]:
        eng = DSIMEngine(prob, rng="lfsr")
        st = eng.init_state(seed=0)
        st, (_, Es) = eng.run_recorded(st, ea_schedule(budget), [budget],
                                       sync_every=sync)
        eta = eta_from_sync(sync, col.n_colors, cm)
        tag = {"phase": "exact (per-phase exchange)",
               None: "disconnected links"}.get(sync, f"exchange every {sync}")
        print(f"DSIM S={str(sync):>5} : E = {float(Es[-1]):9.1f}   "
              f"eta ~ {eta:8.1f}   [{tag}]")

    print("\nStale boundaries trade solution quality for throughput —")
    print("the single ratio eta governs it (benchmarks/fig2, fig3).")


if __name__ == "__main__":
    main()
