"""Quickstart: sample a 3D Edwards-Anderson spin glass with the p-computer.

Builds a small EA instance, then drives it through the unified engine layer
(`repro.engines.make_engine`): the monolithic chromatic Gibbs engine (the
paper's GPU-baseline role), the partitioned DSIM at several boundary-
exchange frequencies (the eta-staleness effect — the paper's core result),
and the fused-kernel lattice engine running a batch of independent replica
anneals — one screen of code, every backend behind one API.

For the *serving* story — async job queue, replica-packing scheduler,
engine pool, streaming results — see examples/serve_sampling.py.

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.engines import make_engine
from repro.core.graph import ea3d
from repro.core.coloring import lattice3d_coloring
from repro.core.partition import slab_partition
from repro.core.commcost import (boundary_matrix, ChainTopology, comm_cost,
                                 eta_threshold)
from repro.core.annealing import ea_schedule
from repro.core.analysis import eta_from_sync


def main():
    L, K, budget = 10, 4, 2048
    print(f"EA spin glass L={L} (N={L**3}), {K}-FPGA-style chain, "
          f"{budget} sweeps\n")
    g = ea3d(L, seed=0)
    col = lattice3d_coloring(L)
    print(f"coloring: {col.n_colors} colors (paper: 2 for even L, 3 odd)")

    # monolithic reference through the registry
    eng = make_engine("gibbs", g, coloring=col, rng="philox")
    st = eng.init_state(seed=0)
    st, rec = eng.run_recorded(st, ea_schedule(budget), [budget])
    print(f"monolithic  : E = {float(rec.energies[-1, 0]):9.1f}   "
          f"({rec.flips:,} flips)")

    # the design rule (Eq. 2) for this partition on a chain
    labels = slab_partition(L, K)
    b = boundary_matrix(np.asarray(g.idx), np.asarray(g.w), labels, K)
    cm = comm_cost(b, ChainTopology(pins=[32] * (K - 1))).c_max
    thr = eta_threshold(col.n_colors, cm)
    print(f"\ncomm-cost model: C_max = {cm:.1f}, "
          f"eta threshold = 2*N_color*C_max = {thr:.0f}\n")

    from repro.core.dsim import build_partitioned
    prob = build_partitioned(g, col, labels, K)   # once, shared by all syncs
    for sync in ["phase", 1, 16, 128, None]:
        eng = make_engine("dsim", prob, rng="lfsr")
        st = eng.init_state(seed=0)
        st, rec = eng.run_recorded(st, ea_schedule(budget), [budget],
                                   sync_every=sync)
        eta = eta_from_sync(sync, col.n_colors, cm)
        tag = {"phase": "exact (per-phase exchange)",
               None: "disconnected links"}.get(sync, f"exchange every {sync}")
        print(f"DSIM S={str(sync):>5} : E = {float(rec.energies[-1, 0]):9.1f}   "
              f"eta ~ {eta:8.1f}   [{tag}]")

    # the production path: fused multi-phase kernel, R independent replicas
    R = 4
    eng = make_engine("lattice", L=L, seed=0, replicas=R)
    st = eng.init_state(seed=0)
    st, rec = eng.run_recorded(st, ea_schedule(budget), [budget],
                               sync_every=8)
    Es = np.asarray(rec.energies[-1])
    print(f"\nlattice x{R} replicas (fused kernel): "
          f"best E = {Es.min():9.1f}, per-replica {np.round(Es, 1)}")

    # the same path through the hardware's fixed-point pipeline: int8
    # on-chip couplings, integer fields, LUT-threshold accepts — zero
    # floating point in the inner loop (DESIGN.md "Fixed-point pipeline")
    eng = make_engine("lattice", L=L, seed=0, replicas=R, precision="int8")
    st = eng.init_state(seed=0)
    st, rec = eng.run_recorded(st, ea_schedule(budget), [budget],
                               sync_every=8)
    Es = np.asarray(rec.energies[-1])
    print(f"lattice x{R} replicas (int8 pipeline, {eng.kernel_path}): "
          f"best E = {Es.min():9.1f}, per-replica {np.round(Es, 1)}")

    # ... and the bit-plane form of the same pipeline: independent
    # replicas packed into the bit lanes of stacked uint32 word planes
    # (32 per word, up to 8 words) — multi-spin coding, the paper's
    # one-bit-per-spin claim in software (DESIGN.md "Bit-plane replica
    # pipeline")
    eng = make_engine("lattice", L=L, seed=0, replicas=32,
                      precision="bitplane")
    st = eng.init_state(seed=0)
    st, rec = eng.run_recorded(st, ea_schedule(budget), [budget],
                               sync_every=8)
    Es = np.asarray(rec.energies[-1])
    print(f"lattice x32 lanes (bit-plane words, {eng.kernel_path}): "
          f"best E = {Es.min():9.1f} ({rec.flips:,} lane-flips)")

    # lane-packed APT+ICM: the (chains x temperatures) tempering grid of
    # the G81 workload rides the word lanes — the paper's full T=64
    # ladder at 2 chains is 128 lanes across 4 stacked word planes.
    # Replica-exchange swap moves are lane permutations (a bit
    # gather/scatter across the word stack, cross-word moves included),
    # ICM disagreement is a per-pair (word, bit) extraction; bit-identical
    # to the unpacked fixed-point ladder at matched seeds
    # (DESIGN.md "The word wire format across engines")
    from repro.core.apt_icm import APTICM
    gs = ea3d(6, seed=0)
    cols = lattice3d_coloring(6)
    betas = np.geomspace(0.3, 3.0, 64)         # 2 chains x 64 temps = 128 lanes
    apt = APTICM(gs, cols, betas, chains=2, rng="lfsr", packed=True)
    stp, (_, best) = apt.run(apt.init_state(seed=0), 60, icm_every=10,
                             record_every=20)
    _, e_best = apt.best_config(stp)
    print(f"\nAPT+ICM packed (L=6, {apt.L} lanes / {apt.words} words): "
          f"best E = {e_best:9.1f}, "
          f"{int(stp.swaps)} swaps (lane permutations), "
          f"{int(stp.icms)} cluster moves")

    print("\nStale boundaries trade solution quality for throughput —")
    print("the single ratio eta governs it (benchmarks/fig2, fig3).")


if __name__ == "__main__":
    main()
