"""3SAT via invertible-logic Ising encoding (paper Sec. S12).

Generates a random 3SAT instance near the satisfiability transition,
encodes it with OR-gate invertible logic + copy-gate sparsification, runs
simulated annealing with the paper's s{4}{3} fixed point on the partitioned
DSIM, and decodes with majority vote over variable copies.

  PYTHONPATH=src python examples/sat3_invertible.py [--vars 80]
"""

import argparse

import numpy as np

from repro.core.coloring import greedy_coloring
from repro.core.partition import greedy_partition
from repro.core.dsim import build_partitioned, DSIMEngine
from repro.core.annealing import sat_schedule
from repro.core.pbit import S43
from repro.problems.sat import (random_3sat, encode_3sat, decode_assignment,
                                count_satisfied)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--vars", type=int, default=80)
    ap.add_argument("--alpha", type=float, default=4.26)
    ap.add_argument("--sweeps", type=int, default=4000)
    ap.add_argument("--partitions", type=int, default=4)
    args = ap.parse_args()

    m_cl = int(round(args.vars * args.alpha))
    clauses = random_3sat(args.vars, m_cl, seed=426)
    enc = encode_3sat(clauses, args.vars)
    g = enc.graph
    col = greedy_coloring(np.asarray(g.idx), np.asarray(g.w))
    print(f"3SAT n={args.vars} m={m_cl} (alpha={args.alpha}) -> "
          f"{g.n} p-bits after copy-gate sparsification, "
          f"{col.n_colors} colors")

    K = args.partitions
    labels = greedy_partition(np.asarray(g.idx), np.asarray(g.w), K, seed=0)
    prob = build_partitioned(g, col, labels, K)
    eng = DSIMEngine(prob, rng="lfsr", fmt=S43)
    pts = sorted(set(np.geomspace(64, args.sweeps, 6).astype(int)))
    best = 0
    for p in pts:
        # fresh run to each point so every trace gets the correct
        # annealing-schedule prefix (geometric points: ~2x total work)
        st = eng.init_state(seed=0)
        st, _ = eng.run_recorded(st, sat_schedule(p), [p], sync_every=4)
        assign = decode_assignment(enc, np.asarray(eng.global_spins(st)))
        ns = count_satisfied(clauses, assign)
        best = max(best, ns)
        print(f"  sweeps {p:6d}: satisfied {ns}/{m_cl} "
              f"({100 * ns / m_cl:.2f}%)")
    print(f"\nbest: {best}/{m_cl} = {100 * best / m_cl:.2f}% "
          f"(paper at 250k p-bits: 99.74%)")


if __name__ == "__main__":
    main()
