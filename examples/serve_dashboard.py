"""Live serving dashboard: goodput, latency percentiles, and measured η.

The observability walkthrough (``repro.obs``): a background thread keeps
submitting mixed gibbs/dsim jobs to a :class:`repro.serve.SampleServer`
while the foreground loop prints, once a second, what the machine says
about itself —

  * goodput (completed jobs and the per-engine flips/s gauges),
  * queue depth and queue-wait / pump-chunk p50/p99 from the registry's
    fixed-bucket histograms (no samples stored, percentiles interpolated),
  * retry / bisect / breaker counters (the fault machinery's telemetry),
  * measured η = f_comm/f_pbit from an :class:`repro.obs.EtaMeter` probe
    against the commcost threshold — the paper's Eq. 2 ratio as a live
    number instead of a prediction.

Ends with the degraded-mode stanza — a mesh job run under
``degrade_policy="stale_hold:8"`` with an injected boundary-exchange
drop, printing the job's ``degrade`` provenance (detections, held
exchanges, delivered fraction) and the integrity counters — and then
the Prometheus text exposition head, the same surface a scrape endpoint
would serve.

  PYTHONPATH=src python examples/serve_dashboard.py
"""

import os
import sys
import threading
import time

# the measured-η probe lives with the benchmarks (repo root, not src/)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.core.coloring import lattice3d_coloring
from repro.core.graph import ea3d
from repro.serve import SampleServer

TICKS = 8          # dashboard refreshes
JOBS_PER_TICK = 4


def _hist_line(snap: dict, family: str) -> str:
    """One-line p50/p99 summary over every labeled series of a family."""
    out = []
    for s in snap.get(family, {}).get("series", []):
        if not s.get("count"):
            continue
        eng = s["labels"].get("engine", "all")
        out.append(f"{eng} p50={s['p50'] * 1e3:.1f}ms "
                   f"p99={s['p99'] * 1e3:.1f}ms (n={s['count']})")
    return "; ".join(out) or "no samples yet"


def main():
    srv = SampleServer(pool_capacity=8, max_replicas_per_call=16)
    g = ea3d(5, seed=4)
    srv.register_problem("glass", graph=g,
                         coloring=lattice3d_coloring(5), rng="lfsr")
    srv.prewarm("glass", engine="gibbs", replicas=4, sweeps=256, wait=True)
    srv.start()

    # measured η rides alongside: a one-device dsim_dist probe with the
    # EtaMeter attached (per-chunk wall time + exchange-only collective),
    # margin vs the commcost threshold of a reference 2-way slab cut
    from benchmarks.common import eta_probe
    eta = eta_probe(L=4, sweeps=32)

    stop = threading.Event()

    def offer():
        seed = 0
        while not stop.is_set():
            for _ in range(JOBS_PER_TICK):
                eng, sync = (("gibbs", 1) if seed % 2 else ("dsim", 4))
                srv.submit("glass", engine=eng, sweeps=128, replicas=2,
                           seed=seed, sync_every=sync)
                seed += 1
            time.sleep(0.3)

    t = threading.Thread(target=offer, daemon=True)
    t.start()

    done0, t0 = srv.completed, time.perf_counter()
    for tick in range(TICKS):
        time.sleep(1.0)
        s = srv.stats()
        snap = srv.metrics_snapshot()
        goodput = (s["completed"] - done0) / (time.perf_counter() - t0)
        flips = {f"{e['labels']['engine']}": e["value"]
                 for e in snap.get("engine_flips_per_s", {}).get(
                     "series", [])}
        print(f"[tick {tick}] goodput {goodput:6.2f} done-jobs/s | "
              f"queue {s['queue_depth']:3d} | "
              f"retries {s['retries']} bisects {s['bisect_requeues']} "
              f"open-circuits {s['pool']['open_circuits']}")
        print(f"   queue-wait: {_hist_line(snap, 'serve_queue_wait_seconds')}")
        print(f"   pump-chunk: {_hist_line(snap, 'serve_pump_chunk_seconds')}")
        print(f"   flips/s: " + (", ".join(
            f"{k}={v:.3g}" for k, v in flips.items()) or "warming"))
        print(f"   measured η {eta['measured_eta']:.1f} "
              f"(f_comm {eta['f_comm_hz']:.3g} Hz, "
              f"f_pbit {eta['f_pbit_hz']:.3g} Hz) vs threshold "
              f"{eta['eta_threshold']:.0f} -> margin {eta['margin']:.3f}")

    stop.set()
    t.join()
    srv.drain()
    srv.stop()

    # -- degraded mode: a mesh job surviving a dropped boundary exchange --
    # A fresh K=1 dsim_dist server with a deterministic fault plan that
    # drops the last of the job's 8 exchanges; stale_hold keeps annealing
    # on the held ghost region and the result carries the quarantine mark.
    print("\n-- degraded-mode mesh (stale_hold vs a dropped exchange) --")
    import numpy as np
    from repro.compat import auto_axes, make_mesh
    from repro.serve.faults import FaultPlan, FaultRule

    plan = FaultPlan([FaultRule(site="exchange_drop", index=7)], seed=4)
    dsrv = SampleServer(warm_compile=False, fault_plan=plan)
    dsrv.register_problem("glass1", graph=g,
                          coloring=lattice3d_coloring(5), K=1,
                          labels=np.zeros(g.n, np.int32),
                          mesh=make_mesh((1,), ("data",),
                                         axis_types=auto_axes(1)),
                          rng="lfsr")
    jid = dsrv.submit("glass1", engine="dsim_dist", precision="int8",
                      sweeps=32, sync_every=4, seed=3,
                      degrade_policy="stale_hold:8")
    out = dsrv.drain().result(jid)
    deg = out["degrade"]
    ds = dsrv.stats()
    print(f"   job {out['status']} under {deg['policy']}: "
          f"{deg['detections']} detection(s), "
          f"{deg['stale_exchanges']}/{deg['exchanges_total']} held, "
          f"delivered {deg['delivered_fraction']:.3f}, "
          f"suspect={deg['suspect']}")
    print(f"   counters: integrity-failures "
          f"{ds['exchange_integrity_failures']}, "
          f"stale {ds['stale_exchanges']}, resyncs {ds['mesh_resyncs']}")
    dsrv.stop()

    print("\n-- Prometheus exposition (head) --")
    print("\n".join(srv.render_metrics().splitlines()[:20]))


if __name__ == "__main__":
    main()
