"""Eta sweep with the CMFT twin — predict hardware behaviour in software.

Runs the partitioned DSIM and the parallel cluster-mean-field model on the
same instance/partition/schedule across staleness settings, fits kappa_f
for both, and prints the paired table (the paper's Fig. 3 protocol: CMFT as
a design-screening tool, Supplementary S3.2).

  PYTHONPATH=src python examples/eta_sweep.py
"""

import numpy as np

from repro.core.graph import ea3d
from repro.core.coloring import lattice3d_coloring
from repro.core.partition import slab_partition
from repro.core.dsim import build_partitioned, DSIMEngine
from repro.core.annealing import ea_schedule
from repro.core.analysis import fit_kappa
from repro.core.gibbs import GibbsEngine


def trace(eng_fn, g, Eg, sch, pts, sync, runs=3):
    """Mean residual-energy trace; returns (record times, rho).

    The DSIM engines quantize record points to multiples of S (collapsing
    duplicates), so the fit must use the times they actually recorded at —
    returned in the RunRecord.  The monolithic engine records at ``pts``
    verbatim and returns the energy trace directly."""
    rhos, times = [], np.asarray(pts)
    for r in range(runs):
        eng = eng_fn()
        st = eng.init_state(seed=r)
        st, out = eng.run_recorded(st, sch, pts, sync_every=sync) \
            if sync != "mono" else eng.run_recorded(st, sch, pts)
        if hasattr(out, "energies"):
            times, Es = np.asarray(out.times), out.energies
        else:
            Es = out
        rhos.append((np.asarray(Es) - Eg) / g.n)
    return times, np.mean(rhos, axis=0)


def main():
    L, K, budget = 8, 4, 4096
    g = ea3d(L, seed=42)
    col = lattice3d_coloring(L)
    prob = build_partitioned(g, col, slab_partition(L, K), K)
    sch = ea_schedule(budget)
    pts = sorted(set(np.geomspace(4, budget, 14).astype(int)))

    # putative ground (longer run, paper protocol)
    ref = GibbsEngine(g, col)
    st = ref.init_state(seed=0)
    st, (Etr, _) = ref.run_dense(st, ea_schedule(4 * budget).beta_array())
    Eg = float(np.asarray(Etr).min())
    print(f"L={L} K={K}, putative ground {Eg:.0f}\n")
    print(f"{'S':>6s} {'kappa_DSIM':>11s} {'kappa_CMFT':>11s}")

    ts, rho = trace(lambda: GibbsEngine(g, col), g, Eg, sch, pts, "mono")
    k_mono = fit_kappa(ts, rho, window=(8, budget)).kappa
    print(f"{'mono':>6s} {k_mono:11.3f} {'—':>11s}")

    for S in (1, 8, 64, 256):
        ks = {}
        for mode in ("dsim", "cmft"):
            ts, rho = trace(lambda: DSIMEngine(prob, rng="lfsr", mode=mode),
                            g, Eg, sch, pts, S)
            ks[mode] = fit_kappa(ts, rho, window=(8, budget)).kappa
        print(f"{S:6d} {ks['dsim']:11.3f} {ks['cmft']:11.3f}")

    print("\nBoth columns degrade together as S grows (eta shrinks):")
    print("staleness is a property of partitioned stochastic dynamics, so")
    print("CMFT predicts the hardware exponent before any hardware exists.")


if __name__ == "__main__":
    main()
