"""End-to-end LM training driver example.

Thin wrapper over ``repro.launch.train`` — trains an assigned-pool arch on
the synthetic Markov stream with checkpoint/restart.  On this CPU container
the default is a reduced config for a quick demonstrable loss curve; on
real hardware drop --reduced and raise the sizes (the same driver lowers
the full configs; see the dry-run for their sharding).

  PYTHONPATH=src python examples/train_lm.py
  PYTHONPATH=src python examples/train_lm.py --arch jamba-v0.1-52b --reduced \
      --steps 60 --sync-every 4 --mesh data=2,model=1   # eta-local-SGD
"""

import sys

from repro.launch.train import main

if __name__ == "__main__":
    if len(sys.argv) == 1:
        sys.argv += ["--arch", "mamba2-370m", "--reduced", "--steps", "120",
                     "--batch", "8", "--seq", "64", "--ckpt", "/tmp/repro_ck",
                     "--ckpt-every", "60"]
    main()
