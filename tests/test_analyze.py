"""Contract-auditor self-tests: every rule must catch its seeded violation
(with the right rule id and location), and the real repo must gate green.
"""

import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import AbstractMesh, NamedSharding, PartitionSpec as P

from repro.analyze.findings import Finding, Waivers, render_report
from repro.analyze.ir_rules import ChunkAudit, audit_chunk
from repro.analyze.lint import lint_file
from repro.compat import shard_map

U32 = (np.dtype(np.uint32),)


def _audit(traced, precision="int8", predicted=None, payload_dtypes=U32,
           payload_bytes=(), counters=None, working_set=None):
    return ChunkAudit(
        engine="test", precision=precision, variant="seeded",
        closed=traced.jaxpr, predicted=predicted or {},
        payload_dtypes=payload_dtypes, payload_bytes=payload_bytes,
        counters=counters or {}, working_set=working_set)


def _rules_fired(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------- IR layer


def test_ir_a_catches_float_arith_in_int8_body():
    tr = jax.jit(lambda x: (x.astype(jnp.float32) * 2.0).astype(jnp.int8)) \
        .trace(jax.ShapeDtypeStruct((8,), jnp.int8))
    found = audit_chunk(_audit(tr, precision="int8"))
    assert any(f.rule == "IR-A" and f.loc == "ir:test/int8/seeded"
               for f in found)
    # the same body is legal on the f32 path
    assert "IR-A" not in _rules_fired(audit_chunk(_audit(tr, "f32")))


def test_ir_b_catches_8bit_wire_in_bitplane_chunk():
    mesh = AbstractMesh((("data", 2),))

    def body(x):
        return jax.lax.all_gather(x, "data", tiled=True)

    f = jax.jit(shard_map(body, mesh=mesh, in_specs=P("data"),
                          out_specs=P()))
    sds = jax.ShapeDtypeStruct((4, 8), jnp.int8,
                               sharding=NamedSharding(mesh, P("data")))
    found = audit_chunk(_audit(f.trace(sds), precision="bitplane",
                               predicted={"all_gather": 1}))
    msgs = [f.msg for f in found if f.rule == "IR-B"]
    assert any("on the wire" in m for m in msgs), found


def test_ir_b_catches_payload_byte_mismatch():
    mesh = AbstractMesh((("data", 2),))

    def body(x):
        return jax.lax.all_gather(x, "data", tiled=True)

    f = jax.jit(shard_map(body, mesh=mesh, in_specs=P("data"),
                          out_specs=P()))
    sds = jax.ShapeDtypeStruct((4, 8), jnp.uint32,
                               sharding=NamedSharding(mesh, P("data")))
    found = audit_chunk(_audit(
        f.trace(sds), precision="bitplane", predicted={"all_gather": 1},
        payload_bytes=(4,)))   # wire is 2*8*4 = 64 B/device, declared 4
    assert any(f.rule == "IR-B" and "declared boundary payload" in f.msg
               for f in found)


def test_ir_c_catches_collective_count_mismatch():
    mesh = AbstractMesh((("data", 2),))

    def body(x):
        return jax.lax.psum(x, "data")

    f = jax.jit(shard_map(body, mesh=mesh, in_specs=P("data"),
                          out_specs=P()))
    sds = jax.ShapeDtypeStruct((4,), jnp.int32,
                               sharding=NamedSharding(mesh, P("data")))
    found = audit_chunk(_audit(f.trace(sds), precision="f32",
                               predicted={"psum": 3}))
    assert any(f.rule == "IR-C" and "psum" in f.msg for f in found)
    # correct prediction: silent
    ok = audit_chunk(_audit(f.trace(sds), precision="f32",
                            predicted={"psum": 1}))
    assert "IR-C" not in _rules_fired(ok)


def test_ir_c_scales_counts_by_scan_length():
    mesh = AbstractMesh((("data", 2),))

    def body(x):
        def step(c, _):
            return jax.lax.psum(c, "data"), None
        out, _ = jax.lax.scan(step, x, None, length=5)
        return out

    f = jax.jit(shard_map(body, mesh=mesh, in_specs=P("data"),
                          out_specs=P()))
    sds = jax.ShapeDtypeStruct((4,), jnp.float32,
                               sharding=NamedSharding(mesh, P("data")))
    ok = audit_chunk(_audit(f.trace(sds), precision="f32",
                            predicted={"psum": 5}))
    assert "IR-C" not in _rules_fired(ok)


def test_ir_d_catches_host_callback():
    def fn(x):
        return jax.pure_callback(
            lambda a: np.asarray(a), jax.ShapeDtypeStruct((4,), np.float32),
            x)

    tr = jax.jit(fn).trace(jax.ShapeDtypeStruct((4,), jnp.float32))
    found = audit_chunk(_audit(tr, precision="f32"))
    assert any(f.rule == "IR-D" and "callback" in f.msg for f in found)


def test_ir_e_catches_i32_counter_and_accepts_modular_publish():
    from repro.core.pbit import flips_publish

    bad = jax.jit(lambda fl, d: fl + d).trace(
        jax.ShapeDtypeStruct((), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.int32))
    found = audit_chunk(_audit(bad, "f32", counters={"flips": 0}))
    assert any(f.rule == "IR-E" and "`add`" in f.msg for f in found)

    good = jax.jit(flips_publish).trace(
        jax.ShapeDtypeStruct((), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.uint32))
    ok = audit_chunk(_audit(good, "f32", counters={"flips": 0}))
    assert "IR-E" not in _rules_fired(ok)


def test_ir_e_checks_seq_dtype():
    tr = jax.jit(lambda s: s + 1).trace(jax.ShapeDtypeStruct((), jnp.int32))
    found = audit_chunk(_audit(tr, "f32", counters={"seq": 0}))
    assert any(f.rule == "IR-E" and "seq" in f.msg for f in found)


def test_ir_f_catches_working_set_drift():
    mesh = AbstractMesh((("data", 2),))

    def body(x):
        return x + jnp.float32(1)

    f = jax.jit(shard_map(body, mesh=mesh, in_specs=P("data"),
                          out_specs=P("data")))
    sds = jax.ShapeDtypeStruct((64,), jnp.float32,
                               sharding=NamedSharding(mesh, P("data")))
    tr = f.trace(sds)
    found = audit_chunk(_audit(tr, "f32",
                               working_set=(10_000_000, (4, 4, 4))))
    assert any(f.rule == "IR-F" for f in found)
    ok = audit_chunk(_audit(tr, "f32", working_set=(512, (4, 4, 4))))
    assert "IR-F" not in _rules_fired(ok)


# --------------------------------------------------------------- AST layer


def _lint(tmp_path, src):
    p = tmp_path / "seeded.py"
    p.write_text(textwrap.dedent(src))
    return lint_file(p, "seeded.py")


def test_al_random_catches_np_random_in_jitted_fn(tmp_path):
    found = _lint(tmp_path, """\
        import numpy as np
        import jax

        @jax.jit
        def f(x):
            return x + np.random.rand()
    """)
    assert any(f.rule == "AL-RANDOM" and f.loc == "seeded.py:6"
               for f in found)


def test_al_random_catches_time_in_scanned_fn(tmp_path):
    found = _lint(tmp_path, """\
        import time
        import jax

        def run(xs):
            def step(c, x):
                return c + time.time(), x
            return jax.lax.scan(step, 0.0, xs)
    """)
    assert any(f.rule == "AL-RANDOM" and "time.time" in f.msg
               for f in found)


def test_al_random_ignores_host_side_randomness(tmp_path):
    found = _lint(tmp_path, """\
        import numpy as np

        def seed_spawner():
            return np.random.randint(0, 2**31)
    """)
    assert not found


def test_al_key_catches_array_in_cache_key(tmp_path):
    found = _lint(tmp_path, """\
        import numpy as np
        _pool_cache = {}

        def put(labels, n):
            k = np.asarray(labels)
            _pool_cache[(k, n)] = 1
    """)
    assert any(f.rule == "AL-KEY" and f.loc == "seeded.py:6" for f in found)


def test_al_key_accepts_digested_keys(tmp_path):
    found = _lint(tmp_path, """\
        import hashlib
        import numpy as np
        _pool_cache = {}

        def put(labels, n):
            k = hashlib.sha1(np.asarray(labels).tobytes()).hexdigest()
            _pool_cache[(k, n)] = 1
    """)
    assert not found


def test_al_lock_catches_unlocked_counter(tmp_path):
    src = """\
        import threading

        class S:
            def __init__(self):
                self._lock = threading.Lock()
                self.n = 0   # guarded_by: _lock

            def bump(self):
                self.n += 1

            def read_ok(self):
                with self._lock:
                    return self.n

            def held_ok(self):  # lock_held: _lock
                return self.n
    """
    found = _lint(tmp_path, src)
    assert [f.loc for f in found if f.rule == "AL-LOCK"] == ["seeded.py:9"]


def test_al_lock_honors_condition_alias(tmp_path):
    found = _lint(tmp_path, """\
        import threading

        class S:
            def __init__(self):
                self._lock = threading.Lock()
                self._cv = threading.Condition(self._lock)  # lock_alias: _lock
                self.jobs = []   # guarded_by: _lock

            def wait_ok(self):
                with self._cv:
                    return len(self.jobs)
    """)
    assert not [f for f in found if f.rule == "AL-LOCK"]


def test_al_except_catches_silent_swallow_around_exchange(tmp_path):
    found = _lint(tmp_path, """\
        def pump(eng, m):
            try:
                ghosts = eng.exchange_block(m)
            except Exception:
                pass
            return m
    """)
    assert any(f.rule == "AL-EXCEPT" and f.loc == "seeded.py:4"
               for f in found)


def test_al_except_accepts_handled_exchange(tmp_path):
    found = _lint(tmp_path, """\
        def pump(eng, m, health):
            try:
                ghosts = eng.exchange_block(m)
            except Exception as e:
                health.record(e)
                raise
            return m
    """)
    assert not [f for f in found if f.rule == "AL-EXCEPT"]


# ---------------------------------------------------------------- deadcode


def test_al_dead_flags_unreachable_module(tmp_path):
    from repro.analyze import deadcode
    (tmp_path / "src" / "repro").mkdir(parents=True)
    (tmp_path / "tests").mkdir()
    (tmp_path / "src" / "repro" / "__init__.py").write_text("")
    (tmp_path / "src" / "repro" / "used.py").write_text("X = 1\n")
    (tmp_path / "src" / "repro" / "dead.py").write_text("Y = 2\n")
    (tmp_path / "tests" / "test_used.py").write_text(
        "from repro.used import X\n")
    found = deadcode.run(tmp_path)
    assert [f.loc for f in found] == ["src/repro/dead.py"]


def test_al_dead_sees_imports_inside_runpy_strings(tmp_path):
    from repro.analyze import deadcode
    (tmp_path / "src" / "repro").mkdir(parents=True)
    (tmp_path / "tests").mkdir()
    (tmp_path / "src" / "repro" / "__init__.py").write_text("")
    (tmp_path / "src" / "repro" / "sub.py").write_text("Z = 3\n")
    (tmp_path / "tests" / "test_sub.py").write_text(
        'SNIPPET = """\nfrom repro.sub import Z\n"""\n')
    assert deadcode.run(tmp_path) == []


# ----------------------------------------------------------------- waivers


def test_waivers_match_and_unused(tmp_path):
    wf = tmp_path / "waivers.txt"
    wf.write_text(
        "AL-DEAD  src/repro/x.py   # CLI entry point\n"
        "IR-C     ir:lattice/*     # never matched\n")
    w = Waivers.load(wf)
    hit = Finding("AL-DEAD", "src/repro/x.py", "dead")
    miss = Finding("AL-DEAD", "src/repro/y.py", "dead")
    assert w.match(hit) == "CLI entry point"
    assert w.match(miss) is None
    assert [e[0] for e in w.unused()] == ["IR-C"]


def test_waivers_strip_line_numbers(tmp_path):
    wf = tmp_path / "waivers.txt"
    wf.write_text("AL-LOCK  src/repro/serve/x.py  # reviewed\n")
    w = Waivers.load(wf)
    assert w.match(Finding("AL-LOCK", "src/repro/serve/x.py:123", "m"))


def test_waivers_reject_rationale_free_lines(tmp_path):
    wf = tmp_path / "waivers.txt"
    wf.write_text("AL-DEAD src/repro/x.py\n")
    with pytest.raises(ValueError):
        Waivers.load(wf)


def test_render_report_exit_code(tmp_path):
    w = Waivers([], path=None)
    text, code = render_report({"lint": []}, w)
    assert code == 0 and "CLEAN" in text
    text, code = render_report(
        {"lint": [Finding("AL-KEY", "a.py:1", "bad key")]}, w)
    assert code == 1 and "FAIL" in text and "AL-KEY" in text


# --------------------------------------------------- repo-level acceptance


@pytest.fixture(scope="module")
def repo_audits():
    from repro.analyze.configs import build_audits
    return build_audits()


def test_ir_enumeration_covers_every_engine_precision(repo_audits):
    from repro.engines.base import ENGINE_PRECISIONS
    audits, failures = repo_audits
    assert failures == [], failures
    covered = {(a.engine, a.precision) for a in audits}
    wanted = {(e, p) for e, ps in ENGINE_PRECISIONS.items() for p in ps}
    assert wanted <= covered
    # both mesh engines' degraded exchanges are audited too
    variants = {(a.engine, a.variant) for a in audits}
    for eng in ("dsim_dist", "lattice"):
        assert (eng, "degrade") in variants
        assert (eng, "degrade+codes") in variants


def test_repo_gates_green(repo_audits):
    """The committed tree must pass its own auditor (CI's analyze step)."""
    from repro.analyze.ir_rules import audit_chunk as audit
    from repro.analyze.runner import (DEFAULT_WAIVER_FILE, repo_root,
                                      run_deadcode, run_lint)
    audits, _ = repo_audits
    findings = [f for a in audits for f in audit(a)]
    root = repo_root()
    findings += run_lint(root) + run_deadcode(root)
    waivers = Waivers.load(root / DEFAULT_WAIVER_FILE)
    unwaived = [f for f in findings if waivers.match(f) is None]
    assert unwaived == [], "\n".join(f.render() for f in unwaived)
