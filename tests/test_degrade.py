"""Degraded-mode mesh: boundary integrity, stale-hold failover, resync.

In-process tests cover the policy vocabulary, the wire checksum, the
failure-classification and fault-code plumbing, the checkpoint-spool hash
verification, the EtaMeter staleness accounting, the exchange-closure
cache invalidation, and the serve-layer wiring on a K=1 mesh.  The REAL
multi-device acceptance tests (poisoned exchanges on a 2-device mesh,
zero-corrupt-ghost ingestion, bitwise resync) run in SUBPROCESSES with a
forced host device count, like tests/test_dist.py.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.degrade import (DegradePolicy, MeshHealthMonitor,
                                StateCorruption, health_init, wire_checksum)
from repro.serve.faults import FaultPlan, FaultRule, classify_error

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, devices: int = 2, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       timeout=timeout)
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


# -- policy vocabulary ---------------------------------------------------------

def test_degrade_policy_parse():
    assert DegradePolicy.parse(None) is None
    p = DegradePolicy.parse("stale_hold:4")
    assert p.mode == "stale_hold" and p.max_staleness == 4
    assert DegradePolicy.parse("stale_hold").mode == "stale_hold"
    assert DegradePolicy.parse("fail_fast").mode == "fail_fast"
    assert DegradePolicy.parse("freeze_boundary").mode == "freeze_boundary"
    assert DegradePolicy.parse(p) is p          # idempotent on instances
    with pytest.raises(ValueError):
        DegradePolicy.parse("best_effort")
    with pytest.raises(ValueError):
        DegradePolicy.parse("stale_hold:nope")
    with pytest.raises(ValueError):
        DegradePolicy(mode="gibberish")


def test_health_monitor_report_shape():
    mon = MeshHealthMonitor(DegradePolicy.parse("stale_hold:8"), 6,
                            kind="faces")
    rep = mon.report()
    for k in ("policy", "detections", "stale_exchanges", "exchanges_total",
              "max_staleness_seen", "delivered_fraction", "resyncs",
              "suspect", "sources", "staleness"):
        assert k in rep, k
    assert rep["detections"] == 0 and rep["delivered_fraction"] == 1.0
    assert not mon.suspect


# -- wire checksum -------------------------------------------------------------

def test_wire_checksum_detects_damage_and_reorder():
    a = np.arange(64, dtype=np.int8) - 32
    ck = int(wire_checksum(a))
    flipped = a.copy()
    flipped[17] ^= 2                       # one bit plane of one site
    assert int(wire_checksum(flipped)) != ck
    # position-weighted: a permutation of the same bytes must not collide
    perm = a.copy()
    perm[0], perm[1] = a[1], a[0]
    assert int(wire_checksum(perm)) != ck
    # dtype-specific paths agree with themselves deterministically
    w = np.arange(16, dtype=np.uint32)
    assert int(wire_checksum(w)) == int(wire_checksum(w.copy()))
    f = np.linspace(-1.0, 1.0, 16).astype(np.float32)
    fz = f.copy()
    fz[3] = np.nextafter(f[3], 2.0, dtype=np.float32)
    assert int(wire_checksum(f)) != int(wire_checksum(fz))


# -- failure classification ----------------------------------------------------

def _fake_xla_error(msg):
    cls = type("XlaRuntimeError", (RuntimeError,), {})
    return cls(msg)


def test_classify_error_jax_runtime():
    assert classify_error(_fake_xla_error(
        "RESOURCE_EXHAUSTED: out of memory allocating")) == "transient"
    assert classify_error(_fake_xla_error(
        "INTERNAL: cross-replica all-gather failed")) == "transient"
    assert classify_error(_fake_xla_error(
        "INVALID_ARGUMENT: shapes do not match")) == "permanent"
    # the duck-typed check wins over the generic tuples: a subclass of
    # ValueError named XlaRuntimeError still splits on the status code
    cls = type("XlaRuntimeError", (ValueError,), {})
    assert classify_error(cls("RESOURCE_EXHAUSTED: oom")) == "transient"


def test_classify_error_taxonomy_unchanged():
    assert classify_error(StateCorruption("mesh")) == "transient"
    assert classify_error(ValueError("bad")) == "permanent"
    assert classify_error(TimeoutError("slow")) == "transient"
    assert classify_error(RuntimeError("????")) == "transient"


# -- fault-code compilation ----------------------------------------------------

def test_exchange_codes_compile_and_replay():
    plan = FaultPlan([FaultRule(site="exchange_drop", rate=0.5)], seed=9)
    codes = plan.exchange_codes(64)
    assert codes is not None and codes.dtype == np.int32
    assert set(np.unique(codes)) <= {0, 1}
    assert 0 < int((codes == 1).sum()) < 64
    # deterministic: replay() and a second compile agree bitwise
    np.testing.assert_array_equal(codes, plan.replay().exchange_codes(64))
    np.testing.assert_array_equal(codes, plan.exchange_codes(64))


def test_exchange_codes_index_after_and_overlap():
    plan = FaultPlan([FaultRule(site="exchange_drop", index=3),
                      FaultRule(site="exchange_corrupt", index=3),
                      FaultRule(site="exchange_drop", after=8)], seed=0)
    codes = plan.exchange_codes(12)
    assert codes[3] == 2                  # corrupt wins the overlap
    assert (codes[8:] == 1).all() and (codes[:3] == 0).all()
    # no engine-site rules -> None (host-site rules don't leak in)
    assert FaultPlan([FaultRule(site="chunk")]).exchange_codes(8) is None


def test_engine_rejects_codes_without_policy():
    from repro.compat import auto_axes, make_mesh
    from repro.core.coloring import lattice3d_coloring
    from repro.core.dsim import build_partitioned
    from repro.core.dsim_dist import DistDSIMEngine
    from repro.core.graph import ea3d

    g = ea3d(4, seed=1)
    prob = build_partitioned(g, lattice3d_coloring(4),
                             np.zeros(g.n, np.int32), 1)
    mesh = make_mesh((1,), ("data",), axis_types=auto_axes(1))
    e = DistDSIMEngine(prob, mesh, rng="lfsr", precision="int8")
    with pytest.raises(ValueError, match="degrade"):
        e.set_exchange_faults(np.zeros(4, np.int32))


# -- checkpoint-spool content verification ------------------------------------

def test_spool_rejects_bit_flipped_checkpoint(tmp_path):
    from repro.serve.spool import CheckpointSpool

    spool = CheckpointSpool(str(tmp_path))
    digest = spool.put({"token": ("batch", "job-1"), "sweeps_done": 128})
    path = os.path.join(str(tmp_path), digest + ".ck")
    blob = bytearray(open(path, "rb").read())
    blob[len(blob) // 2] ^= 0x40                  # one flipped bit
    open(path, "wb").write(bytes(blob))
    with pytest.raises(FileNotFoundError, match="content-hash"):
        spool.load(digest)
    assert spool.corrupt_checkpoints == 1
    assert not os.path.exists(path)               # treated as missing
    assert spool.stats()["corrupt_checkpoints"] == 1
    # records() scan skips (and clears) corruption instead of raising
    d2 = spool.put({"token": ("batch", "job-2"), "sweeps_done": 64})
    p2 = os.path.join(str(tmp_path), d2 + ".ck")
    open(p2, "ab").write(b"\x00tail")             # appended garbage
    assert spool.records() == []
    assert spool.corrupt_checkpoints == 2


def test_spool_truncated_checkpoint(tmp_path):
    from repro.serve.spool import CheckpointSpool

    spool = CheckpointSpool(str(tmp_path))
    digest = spool.put({"token": ("batch", "job-1"), "sweeps_done": 7})
    path = os.path.join(str(tmp_path), digest + ".ck")
    blob = open(path, "rb").read()
    open(path, "wb").write(blob[:len(blob) // 2])
    with pytest.raises(FileNotFoundError):
        spool.load(digest)
    assert spool.corrupt_checkpoints == 1


# -- EtaMeter degraded accounting ---------------------------------------------

def test_eta_meter_effective_eta_accounting():
    from repro.obs import EtaMeter

    m = EtaMeter(n_color=1, c_max=0.045, sync_every=10)
    m.record_chunk(100, 1.0, exchanges=10)
    m.record_exchange(0.5, 10)            # t_ex = 0.05 s
    # t_pbit = (1.0 - 10 * 0.05) / 100 = 0.005 -> eta = 0.1
    assert m.eta == pytest.approx(0.1)
    assert m.effective_eta == pytest.approx(0.1)      # healthy: equal
    rep = m.report()
    assert rep["margin"] > 1.0 and rep["degraded_below_threshold"] is False
    m.note_stale(3, 10, max_staleness=2)
    assert m.stale_exchanges == 3
    assert m.max_staleness_seen == 2
    assert m.delivered_fraction == pytest.approx(0.7)
    assert m.effective_eta == pytest.approx(0.07)
    rep = m.report()
    # threshold 2 * 1 * 0.045 = 0.09: clean margin >= 1, effective below
    assert rep["effective_eta"] < rep["eta_threshold"] <= rep["measured_eta"]
    assert rep["degraded_below_threshold"] is True
    assert rep["stale_exchanges"] == 3
    assert rep["max_staleness_seen"] == 2


def test_health_carry_roundtrip():
    carry = health_init(6)
    assert len(carry) == 6
    assert carry[1].shape == (6,)
    mon = MeshHealthMonitor(DegradePolicy.parse("stale_hold:2"), 6,
                            kind="faces")
    # a carry whose max staleness exceeds the budget escalates
    bad = (np.uint32(4), np.full(6, 3, np.int32), np.int32(0),
           np.int32(3), np.int32(3), np.int32(3))
    with pytest.raises(StateCorruption, match="staleness"):
        mon.update(bad, exchanges=4)
    # fail_fast escalates on the first detection
    mon2 = MeshHealthMonitor(DegradePolicy.parse("fail_fast"), 6,
                             kind="faces")
    det = (np.uint32(1), np.zeros(6, np.int32), np.int32(0),
           np.int32(1), np.int32(0), np.int32(0))
    with pytest.raises(StateCorruption, match="fail_fast"):
        mon2.update(det, exchanges=1)


# -- exchange-closure cache invalidation --------------------------------------

def test_boundary_exchange_fn_cache_invalidated_on_restore():
    from repro.compat import auto_axes, make_mesh
    from repro.core.coloring import lattice3d_coloring
    from repro.core.graph import ea3d
    from repro.engines import make_engine

    g = ea3d(4, seed=2)
    mesh = make_mesh((1,), ("data",), axis_types=auto_axes(1))
    h = make_engine("dsim_dist", g, coloring=lattice3d_coloring(4), K=1,
                    labels=np.zeros(g.n, np.int32), mesh=mesh, rng="lfsr",
                    precision="int8", replicas=2)
    st = h.init_state(seed=5)
    fn1 = h.eng.boundary_exchange_fn()
    assert h.eng.boundary_exchange_fn() is fn1    # cached while valid
    snap = h.snapshot(st)
    st2 = h.restore(snap)                         # re-shards -> invalidate
    assert h.eng._exchange_only_fn is None
    fn2 = h.eng.boundary_exchange_fn()
    assert fn2 is not fn1
    # the rebuilt closure runs against the restored (re-sharded) state
    ghosts = fn2(st2)
    np.testing.assert_array_equal(np.asarray(ghosts),
                                  np.asarray(fn2(st2)))


def test_lattice_exchange_fn_cache_invalidated_on_restore():
    from repro.engines import make_engine

    h = make_engine("lattice", L=4, seed=3, impl="ref", precision="int8",
                    replicas=2)
    st = h.init_state(seed=5)
    fn1 = h.eng.boundary_exchange_fn()
    st2 = h.restore(h.snapshot(st))
    assert h.eng._exchange_only_fn is None
    fn2 = h.eng.boundary_exchange_fn()
    assert fn2 is not fn1
    halos = fn2(st2)
    assert len(halos) == 6


# -- serve-layer wiring (K=1 mesh; no forced device count needed) -------------

def _graph_server(**kw):
    from repro.compat import auto_axes, make_mesh
    from repro.core.coloring import lattice3d_coloring
    from repro.core.graph import ea3d
    from repro.serve.server import SampleServer

    g = ea3d(4, seed=11)
    srv = SampleServer(warm_compile=False, retry_backoff_s=0.0, **kw)
    srv.register_problem("ea4", graph=g,
                         coloring=lattice3d_coloring(4), K=1,
                         labels=np.zeros(g.n, np.int32),
                         mesh=make_mesh((1,), ("data",),
                                        axis_types=auto_axes(1)),
                         rng="lfsr")
    return srv


def test_submit_degrade_policy_validation():
    srv = _graph_server()
    with pytest.raises(ValueError, match="mesh engines"):
        srv.submit("ea4", engine="gibbs", degrade_policy="stale_hold")
    with pytest.raises(ValueError, match="integer sync_every"):
        srv.submit("ea4", engine="dsim_dist", degrade_policy="stale_hold",
                   sync_every="phase")
    with pytest.raises(ValueError, match="degrade"):
        srv.submit("ea4", engine="dsim_dist", degrade_policy="best_effort",
                   sync_every=4)


def test_serve_degrade_provenance_clean():
    srv = _graph_server()
    jid = srv.submit("ea4", engine="dsim_dist", precision="int8", sweeps=32,
                     sync_every=4, seed=3, degrade_policy="stale_hold:8")
    out = srv.drain().result(jid)
    assert out["status"] == "done"
    deg = out["degrade"]
    assert deg is not None
    assert deg["policy"] == "stale_hold:8"
    assert deg["detections"] == 0
    assert deg["delivered_fraction"] == 1.0
    assert not deg["suspect"]
    st = srv.stats()
    assert st["exchange_integrity_failures"] == 0
    assert st["stale_exchanges"] == 0
    # a policy-free job on the same problem carries no provenance (and
    # compiles under a DIFFERENT pool key — the clean executable)
    jid2 = srv.submit("ea4", engine="dsim_dist", precision="int8",
                      sweeps=32, sync_every=4, seed=3)
    out2 = srv.drain().result(jid2)
    assert out2["status"] == "done" and out2["degrade"] is None
    assert srv.stats()["pool"]["size"] == 2


def test_serve_degrade_provenance_with_injected_drops():
    # poison the LAST of the 8 exchanges (sweeps=32, sync_every=4), so
    # the quarantine mark is still up when the batch retires — staleness
    # is *consecutive*, so a mid-run drop heals by run end
    plan = FaultPlan([FaultRule(site="exchange_drop", index=7)], seed=4)
    srv = _graph_server(fault_plan=plan)
    jid = srv.submit("ea4", engine="dsim_dist", precision="int8", sweeps=32,
                     sync_every=4, seed=3, degrade_policy="stale_hold:8")
    out = srv.drain().result(jid)
    assert out["status"] == "done"
    deg = out["degrade"]
    assert deg["detections"] == 1
    assert deg["stale_exchanges"] == 1
    assert deg["max_staleness_seen"] == 1
    assert deg["suspect"]
    assert 0.0 < deg["delivered_fraction"] < 1.0
    st = srv.stats()
    assert st["exchange_integrity_failures"] == 1
    assert st["stale_exchanges"] == 1


def test_serve_fail_fast_fails_job():
    plan = FaultPlan([FaultRule(site="exchange_corrupt", index=1)], seed=4)
    srv = _graph_server(fault_plan=plan, max_retries=0)
    jid = srv.submit("ea4", engine="dsim_dist", precision="int8", sweeps=32,
                     sync_every=4, seed=3, degrade_policy="fail_fast")
    out = srv.drain().result(jid)
    assert out["status"] == "failed"
    assert "StateCorruption" in out["error"]
    assert srv.stats()["exchange_integrity_failures"] >= 1


# -- 2-device acceptance (subprocess, forced host device count) ---------------

def test_degrade_zero_fault_parity_2dev():
    """stale_hold with ZERO injected faults is bitwise the normal run —
    both mesh engines, int8 and bitplane, on a real 2-device mesh."""
    run_py("""
        import numpy as np
        import jax
        assert jax.device_count() == 2
        from repro.core.graph import ea3d
        from repro.core.coloring import lattice3d_coloring
        from repro.core.partition import slab_partition
        from repro.core.dsim import build_partitioned
        from repro.core.dsim_dist import DistDSIMEngine
        from repro.core.lattice import build_ea3d_lattice
        from repro.core.lattice_dsim import LatticeDSIM
        from repro.core.annealing import ea_schedule
        from repro.compat import make_mesh, auto_axes

        L = 4
        sch = ea_schedule(40)
        mesh = make_mesh((2,), ("data",), axis_types=auto_axes(2))
        g = ea3d(L, seed=7)
        dprob = build_partitioned(g, lattice3d_coloring(L),
                                  slab_partition(L, 2), 2)
        lprob = build_ea3d_lattice(L, seed=7)

        def dist(prec, degrade):
            e = DistDSIMEngine(dprob, mesh, rng="lfsr", precision=prec,
                               replicas=3, degrade=degrade)
            st = e.init_state(seed=3)
            st, (_, E) = e.run_recorded(st, sch, [40], sync_every=4)
            return e, np.asarray(e.global_spins(st)), np.asarray(E)

        def lat(prec, degrade):
            e = LatticeDSIM(lprob, mesh, dim_axes=("data", None, None),
                            impl="ref", replicas=3, precision=prec,
                            degrade=degrade)
            st = e.init_state(seed=3)
            st, (_, E) = e.run_recorded(st, sch, [40], sync_every=4)
            return e, np.asarray(e.global_spins(st)), np.asarray(E)

        for mk in (dist, lat):
            for prec in ("int8", "bitplane"):
                eb, mb, Eb = mk(prec, None)
                ed, md, Ed = mk(prec, "stale_hold:4")
                np.testing.assert_array_equal(mb, md)
                np.testing.assert_array_equal(Eb, Ed)
                rep = ed.health.report()
                assert rep["detections"] == 0, rep
                assert rep["stale_exchanges"] == 0, rep
                assert rep["delivered_fraction"] == 1.0, rep
                assert rep["exchanges_total"] == 10, rep
        print("zero-fault parity ok")
        """)


def test_dsim_dist_poisoned_exchange_2dev():
    """Acceptance: 2-device mesh, corrupted exchange at the engine site.
    stale_hold completes with ZERO corrupted ghosts ingested (the corrupt
    arm is bitwise the drop arm), resync() returns ghosts bitwise equal
    to the no-fault trajectory, fail_fast raises StateCorruption, and
    freeze_boundary holds every source after first detection."""
    run_py("""
        import numpy as np
        import jax
        assert jax.device_count() == 2
        from repro.core.graph import ea3d
        from repro.core.coloring import lattice3d_coloring
        from repro.core.partition import slab_partition
        from repro.core.dsim import build_partitioned
        from repro.core.dsim_dist import DistDSIMEngine
        from repro.core.degrade import StateCorruption
        from repro.core.annealing import ea_schedule
        from repro.compat import make_mesh, auto_axes

        L = 4
        g = ea3d(L, seed=7)
        prob = build_partitioned(g, lattice3d_coloring(L),
                                 slab_partition(L, 2), 2)
        mesh = make_mesh((2,), ("data",), axis_types=auto_axes(2))
        sch = ea_schedule(40)   # 40 sweeps, sync 4 -> 10 exchanges

        def run(prec, degrade=None, codes=None):
            e = DistDSIMEngine(prob, mesh, rng="lfsr", precision=prec,
                               replicas=3, degrade=degrade)
            st = e.init_state(seed=3)
            if codes is not None:
                e.set_exchange_faults(codes)
            st, (_, E) = e.run_recorded(st, sch, [40], sync_every=4)
            return e, st, np.asarray(E)

        for prec in ("int8", "bitplane"):
            eb, sb, Eb = run(prec)                  # clean reference
            codes = np.zeros(10, np.int32); codes[-1] = 2
            ed, sd, Ed = run(prec, "stale_hold:4", codes)
            rep = ed.health.report()
            assert rep["detections"] == 1, rep
            assert rep["stale_exchanges"] == 1, rep
            assert rep["max_staleness_seen"] == 1, rep
            assert rep["suspect"], rep
            # corruption hit after the last sweeps: m bitwise unaffected
            assert (np.asarray(ed.global_spins(sd)) ==
                    np.asarray(eb.global_spins(sb))).all()
            np.testing.assert_array_equal(Eb, Ed)
            # drop arm == corrupt arm bitwise: NOTHING was ingested
            codes_d = np.zeros(10, np.int32); codes_d[-1] = 1
            e2, s2, _ = run(prec, "stale_hold:4", codes_d)
            np.testing.assert_array_equal(np.asarray(sd.ghosts),
                                          np.asarray(s2.ghosts))
            # quarantine/resync: bitwise the no-fault ghost state
            sr = ed.resync(sd)
            np.testing.assert_array_equal(np.asarray(sr.ghosts),
                                          np.asarray(sb.ghosts))
            assert not ed.health.suspect
            assert ed.health.resyncs == 1
            # fail_fast raises at first detection
            try:
                run(prec, "fail_fast", codes)
                raise SystemExit("fail_fast did not raise")
            except StateCorruption:
                pass
            # freeze_boundary: holds ALL sources after first detection
            codes_f = np.zeros(10, np.int32); codes_f[4] = 2
            ef, sf, _ = run(prec, "freeze_boundary", codes_f)
            repf = ef.health.report()
            assert repf["detections"] == 1, repf
            assert repf["stale_exchanges"] == 6, repf
            print(prec, "dsim_dist acceptance ok")
        """)


def test_lattice_poisoned_exchange_2dev():
    """Same acceptance on the lattice engine's halo fabric: per-face
    integrity headers ride the same ppermute as the payload."""
    run_py("""
        import numpy as np
        import jax
        assert jax.device_count() == 2
        from repro.core.lattice import build_ea3d_lattice
        from repro.core.lattice_dsim import LatticeDSIM
        from repro.core.degrade import StateCorruption
        from repro.core.annealing import ea_schedule
        from repro.compat import make_mesh, auto_axes

        prob = build_ea3d_lattice(4, seed=7)
        mesh = make_mesh((2,), ("data",), axis_types=auto_axes(2))
        sch = ea_schedule(40)

        def run(prec, degrade=None, codes=None):
            e = LatticeDSIM(prob, mesh, dim_axes=("data", None, None),
                            impl="ref", replicas=3, precision=prec,
                            degrade=degrade)
            st = e.init_state(seed=3)
            if codes is not None:
                e.set_exchange_faults(codes)
            st, (_, E) = e.run_recorded(st, sch, [40], sync_every=4)
            return e, st, np.asarray(E)

        def halos_np(st):
            return [np.asarray(h) for h in st.halos]

        for prec in ("int8", "bitplane"):
            eb, sb, Eb = run(prec)
            codes = np.zeros(10, np.int32); codes[-1] = 2
            ed, sd, Ed = run(prec, "stale_hold:4", codes)
            rep = ed.health.report()
            assert rep["detections"] == 1, rep
            assert rep["stale_exchanges"] == 1, rep
            assert rep["suspect"], rep
            assert (np.asarray(ed.global_spins(sd)) ==
                    np.asarray(eb.global_spins(sb))).all()
            np.testing.assert_array_equal(Eb, Ed)
            # drop arm == corrupt arm bitwise (nothing ingested)
            codes_d = np.zeros(10, np.int32); codes_d[-1] = 1
            e2, s2, _ = run(prec, "stale_hold:4", codes_d)
            for a, b in zip(halos_np(sd), halos_np(s2)):
                np.testing.assert_array_equal(a, b)
            # resync -> bitwise the no-fault halos
            sr = ed.resync(sd)
            for a, b in zip(halos_np(sr), halos_np(sb)):
                np.testing.assert_array_equal(a, b)
            assert not ed.health.suspect and ed.health.resyncs == 1
            try:
                run(prec, "fail_fast", codes)
                raise SystemExit("fail_fast did not raise")
            except StateCorruption:
                pass
            codes_f = np.zeros(10, np.int32); codes_f[4] = 2
            ef, sf, _ = run(prec, "freeze_boundary", codes_f)
            repf = ef.health.report()
            assert repf["detections"] == 1, repf
            assert repf["stale_exchanges"] == 6, repf
            print(prec, "lattice acceptance ok")
        """)
