"""Analysis utilities: power-law fits, bootstrap, time-to-target."""

import numpy as np
import pytest

from repro.core.analysis import (fit_kappa, bootstrap_ci, bootstrap_kappa,
                                 time_to_target, eta_from_sync)


def test_fit_kappa_recovers_exponent():
    t = np.geomspace(1, 1e5, 60)
    for kappa in (0.1, 0.27, 0.5):
        rho = 2.0 * t ** -kappa
        f = fit_kappa(t, rho)
        assert abs(f.kappa - kappa) < 1e-6
        assert f.r2 > 0.999999


def test_fit_kappa_window_and_noise():
    rng = np.random.default_rng(0)
    t = np.geomspace(1, 1e5, 80)
    rho = 3.0 * t ** -0.27 * np.exp(rng.normal(0, 0.05, 80))
    f = fit_kappa(t, rho, window=(10, 1e5))
    assert abs(f.kappa - 0.27) < 0.03


def test_fit_kappa_handles_zeros():
    t = np.asarray([1, 10, 100, 1000])
    rho = np.asarray([1.0, 0.1, 0.0, 0.0])
    f = fit_kappa(t, rho)
    assert np.isfinite(f.kappa)


def test_bootstrap_ci_covers_mean():
    rng = np.random.default_rng(1)
    x = rng.normal(5.0, 1.0, size=200)
    point, lo, hi = bootstrap_ci(x, seed=0)
    assert lo < 5.0 < hi
    assert hi - lo < 0.6


def test_bootstrap_kappa():
    rng = np.random.default_rng(2)
    t = np.geomspace(1, 1e4, 40)
    runs = np.stack([2.0 * t ** -0.25 * np.exp(rng.normal(0, 0.05, 40))
                     for _ in range(20)])
    point, lo, hi = bootstrap_kappa(t, runs, seed=0)
    assert lo < 0.25 < hi
    assert abs(point - 0.25) < 0.02


def test_time_to_target_interpolation():
    t = np.geomspace(1, 1e6, 100)
    rho = 1.0 * t ** -0.5
    # rho = 0.01 at t = 1e4
    ttt = time_to_target(t, rho, 0.01)
    assert abs(np.log10(ttt) - 4) < 0.05
    assert time_to_target(t, rho, 1e-9) == float("inf")


def test_eta_from_sync_ordering():
    """More frequent exchange => larger eta; threshold at S=1."""
    thr = 2 * 3 * 50.8
    assert eta_from_sync(1, 3, 50.8) == pytest.approx(thr)
    assert eta_from_sync("phase", 3, 50.8) > eta_from_sync(1, 3, 50.8)
    assert eta_from_sync(10, 3, 50.8) < eta_from_sync(1, 3, 50.8)
    assert eta_from_sync(None, 3, 50.8) == 0.0
