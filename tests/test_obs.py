"""Observability subsystem: metrics registry under concurrency, span
tracing, the EtaMeter against commcost, the server's metrics surface,
and a 2-device dsim_dist measured-η run (subprocess, forced devices)."""

import json
import os
import re
import subprocess
import sys
import textwrap
import threading

import numpy as np
import pytest

from repro.core import commcost
from repro.core.coloring import lattice3d_coloring
from repro.core.graph import ea3d
from repro.obs import (DEFAULT_TIME_BUCKETS, EtaMeter, MetricsRegistry,
                       Tracer, exchanges_per_sweep)
from repro.serve import SampleServer

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, devices: int = 2, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       timeout=timeout)
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


# -- metrics registry ---------------------------------------------------------

# Prometheus text exposition: every sample line is name{labels} value
_SAMPLE_RE = re.compile(
    r'^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[a-zA-Z_]\w*="[^"]*"'
    r'(,[a-zA-Z_]\w*="[^"]*")*\})? \S+$')


def _assert_exposition_parses(text: str):
    assert text.endswith("\n")
    for line in text.splitlines():
        if not line or line.startswith("#"):
            continue
        assert _SAMPLE_RE.match(line), f"unparseable sample line: {line!r}"


def test_registry_concurrent_writers_exact_totals():
    """>= 8 writer threads hammer one counter family (labeled + no-label)
    and one histogram while a reader renders snapshots and text; no
    increment is lost and every exposition parses."""
    reg = MetricsRegistry()
    c = reg.counter("hits_total", "hammered counter")
    h = reg.histogram("lat_seconds", "hammered histogram")
    writers, per_writer = 8, 2000
    stop = threading.Event()
    reader_errors = []

    def write(i):
        child = c.labels(worker=str(i % 4))
        for k in range(per_writer):
            c.inc()
            child.inc(2.0)
            h.observe(1e-4 * (k % 50))

    def read():
        while not stop.is_set():
            try:
                snap = reg.snapshot()
                json.dumps(snap)                 # JSON-able mid-write
                _assert_exposition_parses(reg.render_text())
            except Exception as e:              # noqa: BLE001
                reader_errors.append(e)
                return

    rt = threading.Thread(target=read)
    rt.start()
    ts = [threading.Thread(target=write, args=(i,)) for i in range(writers)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    stop.set()
    rt.join()
    assert not reader_errors
    assert c.value == writers * per_writer       # no-label child exact
    total_labeled = sum(child.value for key, child in c.series()
                        if dict(key).get("worker") is not None)
    assert total_labeled == writers * per_writer * 2.0
    assert h.count == writers * per_writer
    # final exposition carries the exact totals
    text = reg.render_text()
    assert f"lat_seconds_count {writers * per_writer}" in text
    _assert_exposition_parses(text)


def test_registry_kinds_and_snapshot_shape():
    reg = MetricsRegistry()
    g = reg.gauge("depth", "queue depth")
    g.set(3)
    g.labels(engine="dsim").set(7)
    reg.counter("depth2")                        # distinct name ok
    with pytest.raises(ValueError):
        reg.counter("depth")                     # kind clash
    with pytest.raises(ValueError):
        reg.counter("c").inc(-1)                 # counters only go up
    h = reg.histogram("h", buckets=(1.0, 2.0))
    h.observe(0.5)
    h.observe(5.0)                               # lands in +Inf bucket
    snap = reg.snapshot()
    assert snap["depth"]["type"] == "gauge"
    assert {"labels": {}, "value": 3.0} in snap["depth"]["series"]
    hs = snap["h"]["series"][0]
    assert hs["count"] == 2 and hs["buckets"][-1] == ["+Inf", 2]
    # +Inf observations clamp percentiles to the last finite bound
    assert h.quantile(0.99) == 2.0
    assert np.isnan(reg.histogram("h2").quantile(0.5))


def test_histogram_percentiles_interpolate():
    reg = MetricsRegistry()
    h = reg.histogram("t", buckets=DEFAULT_TIME_BUCKETS)
    for v in np.linspace(1e-4, 9e-4, 200):
        h.observe(float(v))
    # true p50 = 5e-4; bucket interpolation stays within the owning
    # bucket (2.5e-4, 5e-4] .. (5e-4, 1e-3] span
    assert 2.5e-4 <= h.quantile(0.5) <= 1e-3
    assert h.quantile(0.99) <= 1e-3
    assert h.quantile(0.0) <= h.quantile(0.5) <= h.quantile(1.0)


# -- tracer -------------------------------------------------------------------

def test_tracer_spans_nest_and_export(tmp_path):
    clk = iter(np.arange(0.0, 100.0, 0.5))
    synced = []
    tr = Tracer(clock=lambda: float(next(clk)), capacity=8,
                block=synced.append)
    with tr.span("outer", job="j1") as outer:
        with tr.span("inner") as inner:
            inner.set(chunk=3)
            inner.sync({"state": 1})
    spans = tr.spans()
    assert [s["name"] for s in spans] == ["inner", "outer"]
    by = {s["name"]: s for s in spans}
    assert by["inner"]["parent_id"] == by["outer"]["span_id"]
    assert by["inner"]["attrs"] == {"chunk": 3}
    assert by["outer"]["attrs"] == {"job": "j1"}
    assert by["inner"]["duration_s"] == pytest.approx(0.5)  # one tick
    assert synced == [{"state": 1}]             # block ran before t1
    assert tr.durations("outer") == [pytest.approx(1.5)]
    p = tmp_path / "spans.jsonl"
    assert tr.export_jsonl(str(p)) == 2
    rows = [json.loads(line) for line in p.read_text().splitlines()]
    assert {r["name"] for r in rows} == {"inner", "outer"}
    # bounded ring: old spans evicted
    for i in range(20):
        with tr.span(f"s{i}"):
            pass
    assert len(tr.spans()) == 8


# -- EtaMeter vs commcost -----------------------------------------------------

def test_exchanges_per_sweep():
    assert exchanges_per_sweep("phase", 3) == 3.0
    assert exchanges_per_sweep(None, 3) == 1.0
    assert exchanges_per_sweep(4, 3) == 0.25
    with pytest.raises(ValueError):
        exchanges_per_sweep(0, 3)


def test_eta_meter_fake_clock_vs_commcost():
    """Hand-computable accounting: t_ex = 0.02 s, chunk of 8 sweeps in
    0.84 s at sync_every=4 -> 2 exchanges -> t_pbit = (0.84 - 0.04)/8 =
    0.1 s, η = 5.0, threshold = 2 * n_color * c_max = 16 (commcost),
    margin = 0.3125."""
    m = EtaMeter(n_color=2, c_max=4, sync_every=4)
    assert np.isnan(m.t_exchange_s) and np.isnan(m.eta)
    m.record_exchange(0.2, count=10)
    m.record_chunk(sweeps=8, seconds=0.84)
    assert m.t_exchange_s == pytest.approx(0.02)
    assert m.t_pbit_sweep_s == pytest.approx(0.1)
    assert m.f_comm_hz == pytest.approx(50.0)
    assert m.f_pbit_hz == pytest.approx(10.0)
    assert m.eta == pytest.approx(5.0)
    assert m.eta_threshold == commcost.eta_threshold(2, 4) == 16.0
    r = m.report()
    assert r["measured_eta"] == pytest.approx(5.0)
    assert r["margin"] == pytest.approx(5.0 / 16.0)
    assert r["behaves_unpartitioned"] is False
    assert r["chunks_recorded"] == 1 and r["sweeps_recorded"] == 8
    assert r["exchanges_attributed"] == pytest.approx(2.0)

    # a fast enough exchange clears the bound: margin >= 1
    fast = EtaMeter(n_color=2, c_max=4, sync_every=4)
    fast.record_exchange(0.2, count=10000)       # t_ex = 2e-5
    fast.record_chunk(sweeps=8, seconds=0.84)
    rf = fast.report()
    assert rf["margin"] >= 1.0 and rf["behaves_unpartitioned"] is True

    # the floor: a mismeasured (too large) t_ex can never produce a
    # negative p-bit time — floored at a tenth of the raw per-sweep time
    bad = EtaMeter(n_color=2, c_max=4, sync_every=1)
    bad.record_exchange(10.0, count=10)
    bad.record_chunk(sweeps=8, seconds=0.8)
    assert bad.t_pbit_sweep_s == pytest.approx(0.1 * 0.8 / 8)


def test_eta_meter_hooks_into_cursor():
    """attach() installs the meter on the recorded cursor's chunk_timer
    (the same hook surface fault injection uses) and accumulates every
    recorded chunk of a real anneal."""
    from repro.core.annealing import constant_schedule
    from repro.engines import make_engine

    h = make_engine("gibbs", ea3d(3, seed=0),
                    coloring=lattice3d_coloring(3), rng="lfsr")
    sch = constant_schedule(2.0, 64)
    cur = h.start_recorded(h.init_state(seed=0), sch, [8, 16], sync_every=1)
    m = EtaMeter(n_color=2, sync_every=1).attach(cur)
    assert cur.chunk_timer == m.on_chunk
    while not cur.done:
        cur.advance(1)
    r = m.report()
    assert r["chunks_recorded"] == 2 and r["sweeps_recorded"] == 16
    assert r["chunk_seconds"] > 0
    assert np.isfinite(r["f_pbit_hz"])           # no exchange side needed


def test_eta_meter_2device_dsim_dist():
    """The acceptance run: a 2-device dsim_dist engine (K=2 slab) reports
    measured η, f_comm, f_pbit, and the margin vs commcost.eta_threshold
    from the EtaMeter, all finite and self-consistent."""
    out = run_py("""
        import json
        import numpy as np
        from repro.compat import auto_axes, make_mesh
        from repro.core.annealing import constant_schedule
        from repro.core.coloring import lattice3d_coloring
        from repro.core.graph import ea3d
        from repro.core.partition import slab_partition
        from repro.engines import make_engine
        from repro.obs import dist_eta_meter

        L = 4
        g = ea3d(L, seed=7)
        h = make_engine("dsim_dist", g, coloring=lattice3d_coloring(L),
                        K=2, labels=slab_partition(L, 2),
                        mesh=make_mesh((2,), ("data",),
                                       axis_types=auto_axes(2)),
                        rng="lfsr", replicas=4)
        meter = dist_eta_meter(h.eng, sync_every=8)
        sch = constant_schedule(3.0, 8 * 64)
        h.run_recorded(h.init_state(seed=0), sch, [32, 64],
                       sync_every=8)                  # compile
        st = h.init_state(seed=0)
        meter.measure_exchange(
            lambda: h.eng.boundary_exchange_fn()(st), reps=16)
        cur = h.start_recorded(st, sch, [32, 64], sync_every=8)
        meter.attach(cur)
        while not cur.done:
            cur.advance(1)
        print(json.dumps(meter.report()))
    """)
    r = json.loads(out.strip().splitlines()[-1])
    for f in ("measured_eta", "eta_threshold", "margin", "f_comm_hz",
              "f_pbit_hz", "t_exchange_s", "t_pbit_sweep_s"):
        assert np.isfinite(r[f]) and r[f] > 0, (f, r)
    # threshold is the commcost bound for the ACTUAL K=2 slab partition
    g = ea3d(4, seed=7)
    from repro.core.partition import slab_partition
    b = commcost.boundary_matrix(np.asarray(g.idx), np.asarray(g.w),
                                 slab_partition(4, 2), 2)
    cc = commcost.comm_cost(b, commcost.RingTopology(k=2, pins_per_link=1))
    assert r["eta_threshold"] == pytest.approx(
        commcost.eta_threshold(r["n_color"], cc.c_max))
    assert r["margin"] == pytest.approx(
        r["measured_eta"] / r["eta_threshold"])
    assert r["measured_eta"] == pytest.approx(
        r["f_comm_hz"] / r["f_pbit_hz"], rel=1e-6)
    assert r["sweeps_recorded"] == 64 and r["chunks_recorded"] == 2
    assert r["behaves_unpartitioned"] == (r["margin"] >= 1.0)


# -- server surface -----------------------------------------------------------

@pytest.fixture(scope="module")
def served():
    """One tiny mixed workload; the metrics surface is inspected by
    several tests."""
    g = ea3d(4, seed=3)
    srv = SampleServer(max_replicas_per_call=8)
    srv.register_problem("p", graph=g, coloring=lattice3d_coloring(4),
                         rng="lfsr")
    ids = [srv.submit("p", engine="gibbs", sweeps=32, replicas=2, seed=s)
           for s in (0, 1)]
    ids.append(srv.submit("p", engine="dsim", sweeps=32, replicas=2,
                          seed=2, sync_every=4))
    srv.drain()
    results = [srv.result(j) for j in ids]
    return srv, results


def test_server_metrics_surface(served):
    """stats() is a registry view; the snapshot and Prometheus text cover
    queue wait, pump latency, goodput, retries/breaker, per-engine
    flips/s."""
    srv, results = served
    assert all(r["status"] == "done" for r in results)
    s = srv.stats()
    snap = srv.metrics_snapshot()
    # counters migrated onto the registry: stats() mirrors family values
    assert s["completed"] == 3
    assert snap["serve_jobs_completed_total"]["series"][0]["value"] == 3
    assert s["submitted"] == sum(
        e["value"] for e in snap["serve_jobs_submitted_total"]["series"])
    # latency/goodput histograms observed per engine
    for fam in ("serve_queue_wait_seconds", "serve_pump_chunk_seconds",
                "serve_job_total_seconds", "serve_job_flips_per_s"):
        engines = {e["labels"].get("engine") for e in snap[fam]["series"]}
        assert {"gibbs", "dsim"} <= engines, fam
        assert sum(e["count"] for e in snap[fam]["series"]) >= 2, fam
        assert all("p50" in e and "p99" in e for e in snap[fam]["series"])
    # per-engine flips/s gauge
    rates = {(e["labels"]["engine"], e["labels"]["precision"]): e["value"]
             for e in snap["engine_flips_per_s"]["series"]}
    assert all(v > 0 for v in rates.values()) and len(rates) >= 2
    # pool + scheduler instrumentation share the registry
    assert sum(e["value"] for e in snap["pool_misses_total"]["series"]) \
        == s["pool"]["misses"]
    assert sum(e["count"] for e in snap["pool_build_seconds"]["series"]) \
        == s["pool"]["misses"]
    assert sum(e["count"]
               for e in snap["sched_pack_width_replicas"]["series"]) \
        == s["scheduler"]["batches_formed"]
    assert s["scheduler"]["padding_replicas"] >= 0
    # Prometheus text: parseable, and the catalogue is present
    text = srv.render_metrics()
    _assert_exposition_parses(text)
    for name in ("serve_jobs_completed_total", "serve_queue_wait_seconds_bucket",
                 "serve_pump_chunk_seconds_count", "serve_job_flips_per_s_sum",
                 "engine_flips_per_s", "pool_hits_total",
                 "sched_pack_width_replicas_bucket", "serve_queue_depth",
                 "serve_retries_total", "pool_open_circuits"):
        assert name in text, name
    # pump.chunk spans recorded with engine attribution
    chunk_spans = srv.tracer.spans("pump.chunk")
    assert len(chunk_spans) >= 2
    assert all(sp["duration_s"] > 0 and "engine" in sp["attrs"]
               for sp in chunk_spans)


def test_server_stats_snapshot_is_isolated(served):
    """Satellite regression: mutating the returned stats() dict (top
    level and nested pool/scheduler/spool views) cannot corrupt server
    state."""
    srv, _ = served
    before = srv.stats()
    victim = srv.stats()
    victim["completed"] = 10 ** 9
    victim["pool"].clear()
    victim["scheduler"]["batches_formed"] = -1
    if isinstance(victim["spool"], dict):
        victim["spool"].clear()
    victim.clear()
    after = srv.stats()
    assert after == before
    assert after["pool"]["misses"] == before["pool"]["misses"]
    # the counters really live on the registry, not the mutated dict
    assert srv.completed == before["completed"]


def test_legacy_counter_attributes_still_read(served):
    srv, _ = served
    assert srv.completed == 3 and srv.failed == 0 and srv.retries == 0
    with pytest.raises(AttributeError):
        srv.not_a_counter
