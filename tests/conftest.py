import os
import sys

# Tests and benches run single-device (the dry-run sets its own 512-device
# flag in its own process); make sure nothing leaks in.
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
