"""Bit-plane replica engine: multi-spin-coded sweeps over the multi-word
lane fabric (32 lanes per uint32 word plane, W = ceil(R/32) stacked
planes).

Three layers of guarantees, mirroring tests/test_quantized.py:
  * bit-exact — the Pallas word kernel against its jnp oracle, and lane r
    of the word math against replica r of the int8 integer pipeline
    (multi-spin coding changes the layout, never the dynamics);
  * structural — lane pack/unpack identities, the carry-save ones count,
    registry/scheduler guards (clear errors, lane clamping), the VMEM
    working-set model;
  * statistical — every packed lane is an independent chain: per-lane
    EA3D energy trajectories match the int8 engine for all 32 lanes
    individually, lanes are prefix-stable in R, and a packed lane's
    trajectory depends only on its own seed.
"""

import warnings

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.annealing import ea_schedule, replica_beta_arrays
from repro.core.lattice import build_ea3d_lattice
from repro.core.lattice_dsim import (BitplaneLatticeState, LatticeDSIM,
                                     fused_brick_ceiling,
                                     fused_working_set_bytes)
from repro.core.packing import (LANE_WIDTH, MAX_LANE_WORDS, lane_words,
                                pack_lanes, unpack_lanes)
from repro.core.pbit import (bitplane_planes, field_bound, quantize_couplings,
                             threshold_lut)
from repro.compat import make_mesh, auto_axes
from repro.engines import make_engine
from repro.engines.base import check_precision, lanes_of
from repro.kernels.ops import pbit_bitplane_sweep_op
from repro.kernels.ref import (bitplane_ones_count_ref,
                               pbit_bitplane_sweep_ref,
                               pbit_brick_sweep_int_ref)

RNG = np.random.default_rng(23)


def make_bitplane_inputs(shape, R, n_betas=3, with_h=True):
    """Random +-J-style brick in both layouts: per-replica int8 arrays and
    the packed word forms, sharing one quantized problem."""
    Bx, By, Bz = shape
    m = RNG.choice([-1, 1], size=(R,) + shape).astype(np.int8)
    s = RNG.integers(1, 2 ** 32, size=(R,) + shape, dtype=np.uint32)
    h = (RNG.choice([-1.0, 0.0, 1.0], size=shape) if with_h
         else np.zeros(shape)).astype(np.float32)
    w6 = [RNG.choice([-1.0, 0.0, 1.0], size=shape).astype(np.float32)
          for _ in range(6)]
    h_q, w6_q, scale = quantize_couplings(h, w6)
    lut = jnp.asarray(threshold_lut(np.linspace(0.4, 4.0, n_betas), scale,
                                    field_bound(h_q, w6_q)))
    halos = [RNG.choice([-1, 1], (R,) + sh).astype(np.int8) for sh in
             [(By, Bz), (By, Bz), (Bx, Bz), (Bx, Bz), (Bx, By), (Bx, By)]]
    masks = np.zeros((2,) + shape, np.int8)
    masks[0][(np.indices(shape).sum(0) % 2) == 0] = 1
    masks[1] = 1 - masks[0]
    signs6, nz6, base, _ = bitplane_planes(h_q, w6_q)
    # per-word live-lane masks: full words all-ones, the tail word masks
    # its dead lanes (mirrors LatticeDSIM's lane_masks construction)
    W = lane_words(R)
    last = R - (W - 1) * LANE_WIDTH
    lane_masks = np.full((W,), 0xFFFFFFFF, np.uint64)
    if last < LANE_WIDTH:
        lane_masks[-1] = (1 << last) - 1
    lane_masks = lane_masks.astype(np.uint32)
    masks_w = jnp.asarray(
        np.where(masks[:, None] != 0,
                 lane_masks[None, :, None, None, None], 0).astype(np.uint32))
    mw = pack_lanes(jnp.asarray(m))
    halos_w = tuple(pack_lanes(jnp.asarray(hh)) for hh in halos)
    return dict(m=m, s=s, h_q=h_q, w6_q=w6_q, lut=lut, halos=halos,
                masks=jnp.asarray(masks), signs6=signs6, nz6=nz6, base=base,
                masks_w=masks_w, mw=mw, halos_w=halos_w)


# -- bit-exact: lanes == int8 replicas ----------------------------------------

@pytest.mark.parametrize("shape,R", [
    ((6, 4, 4), 1), ((6, 4, 4), 7), ((4, 4, 4), 32), ((5, 3, 4), 13),
    ((4, 3, 3), 40), ((4, 3, 3), 64),
])
def test_bitplane_oracle_matches_int8_per_lane(shape, R):
    """Lane r (word r//32, bit r%32) of the word oracle is bit-identical
    (spins, LFSR, flips) to replica r of the int8 reference — multi-spin
    coding is a layout, not a different sampler — including lane counts
    that straddle into a second word plane."""
    d = make_bitplane_inputs(shape, R)
    rows = jnp.asarray([0, 2, 1], jnp.int32)
    mw2, s2, fl2 = pbit_bitplane_sweep_ref(
        d["mw"], jnp.asarray(d["s"]), rows, d["masks_w"], d["signs6"],
        d["nz6"], d["base"], d["halos_w"], d["lut"])
    m_un = np.asarray(unpack_lanes(mw2, R))
    for r in range(R):
        mr, sr, fl = pbit_brick_sweep_int_ref(
            jnp.asarray(d["m"][r]), jnp.asarray(d["s"][r]), rows,
            d["masks"], d["h_q"], d["w6_q"],
            tuple(jnp.asarray(hh[r]) for hh in d["halos"]), d["lut"])
        assert (m_un[r] == np.asarray(mr)).all()
        assert (np.asarray(s2)[r] == np.asarray(sr)).all()
        assert int(fl2[r]) == int(fl)


@pytest.mark.parametrize("shape,R", [((6, 4, 4), 3), ((4, 4, 4), 8),
                                     ((4, 3, 3), 34)])
def test_bitplane_kernel_matches_oracle(shape, R):
    """The Pallas word kernel (interpreter) against the jnp oracle —
    identical integer op outcomes, including per-lane flip counts; the
    W=2 case exercises the word loop in the op dispatch."""
    d = make_bitplane_inputs(shape, R)
    rows = jnp.asarray([1, 0, 2, 2], jnp.int32)
    want = pbit_bitplane_sweep_ref(
        d["mw"], jnp.asarray(d["s"]), rows, d["masks_w"], d["signs6"],
        d["nz6"], d["base"], d["halos_w"], d["lut"])
    got = pbit_bitplane_sweep_op(
        d["mw"], jnp.asarray(d["s"]), rows, d["masks_w"], d["signs6"],
        d["nz6"], d["base"], d["halos_w"], d["lut"], impl="interpret")
    for a, b in zip(got, want):
        assert (np.asarray(a) == np.asarray(b)).all()


def test_bitplane_kernel_per_lane_rows():
    """A (S, R) per-lane staircase fan flows through both impls
    identically — each lane reads its own LUT row."""
    R = 5
    d = make_bitplane_inputs((4, 4, 4), R)
    rows = jnp.asarray(RNG.integers(0, 3, size=(3, R)), jnp.int32)
    want = pbit_bitplane_sweep_ref(
        d["mw"], jnp.asarray(d["s"]), rows, d["masks_w"], d["signs6"],
        d["nz6"], d["base"], d["halos_w"], d["lut"])
    got = pbit_bitplane_sweep_op(
        d["mw"], jnp.asarray(d["s"]), rows, d["masks_w"], d["signs6"],
        d["nz6"], d["base"], d["halos_w"], d["lut"], impl="interpret")
    for a, b in zip(got, want):
        assert (np.asarray(a) == np.asarray(b)).all()
    # and the fan actually differentiates lanes: identical lane states,
    # different rows -> different trajectories
    d2 = make_bitplane_inputs((4, 4, 4), 2)
    same = np.broadcast_to(d2["s"][:1], d2["s"].shape).copy()
    mw_same = pack_lanes(jnp.asarray(
        np.broadcast_to(d2["m"][:1], d2["m"].shape).copy()))
    fan = jnp.asarray([[0, 2]] * 6, jnp.int32)
    mw3, _, _ = pbit_bitplane_sweep_ref(
        mw_same, jnp.asarray(same), fan, d2["masks_w"], d2["signs6"],
        d2["nz6"], d2["base"], d2["halos_w"], d2["lut"])
    lanes = np.asarray(unpack_lanes(mw3, 2))
    assert (lanes[0] != lanes[1]).any()


def test_bitplane_ones_count_matches_popcount():
    """The carry-save adder tree's 3 bit-slices equal the per-lane sum of
    contribution bits, for every lane of every site."""
    R = LANE_WIDTH
    d = make_bitplane_inputs((4, 3, 3), R)
    # the CSA tree is a ONE-WORD primitive: feed it word plane 0
    b0, b1, b2 = bitplane_ones_count_ref(
        d["mw"][0], d["signs6"], d["nz6"],
        tuple(h[0] for h in d["halos_w"]))
    cnt = (np.asarray(unpack_lanes(b0[None], R)) > 0).astype(np.int64) \
        + 2 * (np.asarray(unpack_lanes(b1[None], R)) > 0) \
        + 4 * (np.asarray(unpack_lanes(b2[None], R)) > 0)
    # direct recount from the unpacked layout
    from repro.kernels.ref import _shifted_int
    want = np.zeros((R,) + (4, 3, 3), np.int64)
    for r in range(R):
        nbs = _shifted_int(jnp.asarray(d["m"][r]),
                           tuple(jnp.asarray(hh[r]) for hh in d["halos"]))
        for nb, w in zip(nbs, d["w6_q"]):
            wq = np.asarray(w, np.int64)
            want[r] += ((np.asarray(nb, np.int64) * wq > 0) & (wq != 0))
    np.testing.assert_array_equal(cnt, want)


# -- engine layer -------------------------------------------------------------

def test_engine_ref_vs_interpret_bitexact():
    outs = []
    for impl in ("ref", "interpret"):
        h = make_engine("lattice", L=4, seed=3, impl=impl, replicas=3,
                        precision="bitplane")
        st = h.init_state(seed=5)
        st, _ = h.run_recorded(st, ea_schedule(8), [8], sync_every=4)
        outs.append(st)
    assert (np.asarray(outs[0].m) == np.asarray(outs[1].m)).all()
    assert (np.asarray(outs[0].s) == np.asarray(outs[1].s)).all()


def test_bitplane_engine_matches_int8_all_32_lanes():
    """The acceptance gate: EA3D energy trajectories of the bit-plane
    engine equal the int8 engine's per replica, for all 32 lanes
    individually, at matched seeds and schedules — and every lane anneals
    (statistical sanity on top of the exact match)."""
    R, SW = LANE_WIDTH, 96
    rec_pts = [32, 64, 96]
    res = {}
    for prec in ("int8", "bitplane"):
        h = make_engine("lattice", L=6, seed=7, impl="ref", replicas=R,
                        precision=prec)
        st = h.init_state(seed=1)
        st, rec = h.run_recorded(st, ea_schedule(SW), rec_pts, sync_every=4)
        res[prec] = (np.asarray(rec.energies), rec.flips,
                     np.asarray(h.global_spins(st)))
    e_bp, fl_bp, spins_bp = res["bitplane"]
    e_i8, fl_i8, spins_i8 = res["int8"]
    assert e_bp.shape == (len(rec_pts), R)
    for r in range(R):
        np.testing.assert_allclose(e_bp[:, r], e_i8[:, r], rtol=0, atol=0)
        assert e_bp[-1, r] < 0                      # every lane annealed
    assert fl_bp == fl_i8
    np.testing.assert_array_equal(spins_bp, spins_i8)


def test_bitplane_engine_matches_int8_at_two_words():
    """The W=2 acceptance gate: at R=64 every lane of the stacked word
    planes is bit-identical to its int8 replica — spins, energies, and
    flip totals — so the word loop over planes changes nothing about the
    dynamics."""
    R, SW = 2 * LANE_WIDTH, 48
    res = {}
    for prec in ("int8", "bitplane"):
        h = make_engine("lattice", L=4, seed=7, impl="ref", replicas=R,
                        precision=prec)
        st = h.init_state(seed=1)
        st, rec = h.run_recorded(st, ea_schedule(SW), [24, 48],
                                 sync_every=4)
        res[prec] = (np.asarray(rec.energies), rec.flips,
                     np.asarray(h.global_spins(st)))
    e_bp, fl_bp, spins_bp = res["bitplane"]
    e_i8, fl_i8, spins_i8 = res["int8"]
    assert e_bp.shape == (2, R)
    np.testing.assert_array_equal(e_bp, e_i8)
    assert fl_bp == fl_i8
    np.testing.assert_array_equal(spins_bp, spins_i8)


def test_lane_prefix_stability():
    """Replica r of (seed, R) equals replica r of (seed, R') — growing the
    packed batch never reshuffles existing lanes (the spawn_seeds
    contract, preserved through the word layout) — in the bit index AND
    across word-plane boundaries (R=33 vs R=64)."""
    e = {}
    for R in (8, 32, 33, 64):
        h = make_engine("lattice", L=4, seed=0, impl="ref", replicas=R,
                        precision="bitplane")
        st = h.init_state(seed=9)
        st, rec = h.run_recorded(st, ea_schedule(16), [16], sync_every=4)
        e[R] = np.asarray(rec.energies[-1])
    np.testing.assert_array_equal(e[8], e[32][:8])
    np.testing.assert_array_equal(e[32], e[64][:32])
    np.testing.assert_array_equal(e[33], e[64][:33])


def test_packed_lane_depends_only_on_its_seed():
    """init_state_packed: a lane's trajectory is bitwise independent of
    its batch-mates (the replica-packing contract on the word layout)."""
    seeds = [11, 222, 3333]
    h3 = make_engine("lattice", L=4, seed=0, impl="ref", replicas=3,
                     precision="bitplane")
    st = h3.init_state_packed(seeds)
    st, rec3 = h3.run_recorded(st, ea_schedule(16), [16], sync_every=4)
    h1 = make_engine("lattice", L=4, seed=0, impl="ref", replicas=1,
                     precision="bitplane")
    s1 = h1.init_state_packed([seeds[1]])
    s1, rec1 = h1.run_recorded(s1, ea_schedule(16), [16], sync_every=4)
    assert float(rec3.energies[-1][1]) == float(rec1.energies[-1][0])


def test_per_replica_staircase_fan_rides_bitplane():
    R = 4
    sch = ea_schedule(48)
    bR = replica_beta_arrays(sch, R, spread=0.3)
    outs = {}
    for prec in ("int8", "bitplane"):
        h = make_engine("lattice", L=6, seed=7, impl="ref", replicas=R,
                        precision=prec)
        st = h.init_state(seed=0)
        st, rec = h.eng.run_recorded_full(st, sch, [48], sync_every=4,
                                          betas_R=bR)
        outs[prec] = np.asarray(rec.energies[-1])
    assert outs["bitplane"].shape == (R,)
    assert len(np.unique(outs["bitplane"])) > 1     # the fan differentiates
    np.testing.assert_array_equal(outs["bitplane"], outs["int8"])


def test_snapshot_restore_bitwise_resume():
    h = make_engine("lattice", L=4, seed=0, impl="ref", replicas=4,
                    precision="bitplane")
    st = h.init_state(seed=2)
    st, _ = h.run_recorded(st, ea_schedule(16), [8], sync_every=4)
    st2 = h.restore(h.snapshot(st))
    assert isinstance(st2, BitplaneLatticeState)
    a, ra = h.run_recorded(st, ea_schedule(16), [8], sync_every=4)
    b, rb = h.run_recorded(st2, ea_schedule(16), [8], sync_every=4)
    assert (np.asarray(a.m) == np.asarray(b.m)).all()
    np.testing.assert_array_equal(np.asarray(ra.energies),
                                  np.asarray(rb.energies))


def test_bitplane_multi_device_halo_exchange():
    """On an x-sharded 2-device mesh, lane r of the bit-plane engine stays
    bit-identical to replica r of the int8 engine: the word halo planes
    crossing the ppermute carry exactly what the int8 exchange carries
    (same boundary-staleness semantics, 8x smaller payload) — at R=5
    (one word) and R=40 (two stacked word planes crossing the wire),
    across exchange cadences.  (k=1 vs k=2 differ BY DESIGN —
    cross-device neighbors see sync_every-stale halos — so the gate is
    cross-precision at equal mesh, not cross-mesh.)"""
    import os
    import subprocess
    import sys
    import textwrap
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent("""
        import numpy as np
        from repro.core.lattice import build_ea3d_lattice
        from repro.core.lattice_dsim import LatticeDSIM
        from repro.core.packing import unpack_lanes
        from repro.core.annealing import ea_schedule
        from repro.compat import make_mesh, auto_axes
        prob = build_ea3d_lattice(6, seed=4)
        mesh = make_mesh((2,), ("x",), axis_types=auto_axes(1))
        for R, sync in ((5, 4), (40, 1), (40, 4)):
            outs = {}
            for prec in ("int8", "bitplane"):
                eng = LatticeDSIM(prob, mesh, dim_axes=("x", None, None),
                                  precision=prec, impl="ref", replicas=R)
                st = eng.init_state(seed=3)
                st, rec = eng.run_recorded(st, ea_schedule(24), [24],
                                           sync_every=sync)
                m = np.asarray(unpack_lanes(st.m, R)) \\
                    if prec == "bitplane" else np.asarray(st.m)
                outs[prec] = (m, np.asarray(st.s),
                              np.asarray(rec.energies[-1]))
            for a, b in zip(outs["bitplane"], outs["int8"]):
                assert (a == b).all(), (R, sync)
        print("DIST-BITWISE OK")
    """)], capture_output=True, text=True, env=env, timeout=420)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "DIST-BITWISE OK" in r.stdout


# -- working-set model --------------------------------------------------------

def test_bitplane_working_set_per_lane_beats_int8():
    """Per replica-lane, the word layout is the densest of the three
    pipelines — the whole point of multi-spin coding."""
    b = (32, 32, 32)
    for n_c in (2, 3):
        per_lane_bp = fused_working_set_bytes(b, n_c, "bitplane",
                                              lanes=32) / 32
        per_rep_i8 = fused_working_set_bytes(b, n_c, "int8", lut_width=13)
        assert per_lane_bp < per_rep_i8
    assert fused_brick_ceiling(3, "bitplane", lanes=32) >= 32


def test_bitplane_over_budget_warns_not_falls_back():
    prob = build_ea3d_lattice(6, seed=0)
    mesh = make_mesh((1,), ("data",), axis_types=auto_axes(1))
    with pytest.warns(RuntimeWarning, match="no per-phase fallback"):
        eng = LatticeDSIM(prob, mesh, dim_axes=("data", None, None),
                          precision="bitplane", impl="ref", replicas=32,
                          vmem_budget_bytes=1024)
    assert eng.kernel_path == "bitplane"    # still the word kernel
    st = eng.init_state(seed=0)
    st, rec = eng.run_recorded(st, ea_schedule(8), [8], sync_every=4)
    assert float(np.asarray(rec.energies[-1]).min()) < 0


# -- guards -------------------------------------------------------------------

def test_registry_guards():
    from repro.core.graph import ea3d
    from repro.core.coloring import lattice3d_coloring
    g = ea3d(4, seed=0)
    col = lattice3d_coloring(4)
    for eng_name in ("gibbs", "dsim"):
        with pytest.raises(ValueError, match="lattice/dsim_dist path"):
            make_engine(eng_name, g, coloring=col, K=2,
                        labels=np.zeros(g.n, np.int32),
                        precision="bitplane")
    cap = MAX_LANE_WORDS * LANE_WIDTH
    with pytest.raises(ValueError, match=rf"\[1, {cap}\]"):
        make_engine("lattice", L=4, precision="bitplane", replicas=cap + 1)
    # word-straddling replica counts are legal now (the multi-word fabric)
    h = make_engine("lattice", L=4, precision="bitplane", replicas=33,
                    impl="ref")
    assert h.eng.words == 2
    with pytest.raises(ValueError, match="kernel_bx"):
        make_engine("lattice", L=4, precision="bitplane", kernel_bx=2)
    assert lanes_of("bitplane") == LANE_WIDTH and lanes_of("int8") == 1
    check_precision("lattice", "bitplane")          # allowed


def test_non_sign_couplings_rejected():
    """Problems whose couplings don't quantize to +-1/0 have no sign plane
    — a clear init error pointing at int8, not a packing shape error."""
    import dataclasses
    base = build_ea3d_lattice(4, seed=0)
    wide = dataclasses.replace(
        base, h=jnp.asarray(RNG.normal(0, 1.0, base.dims), jnp.float32))
    mesh = make_mesh((1,), ("data",), axis_types=auto_axes(1))
    with pytest.raises(ValueError):
        LatticeDSIM(wide, mesh, dim_axes=("data", None, None),
                    precision="bitplane", impl="ref")


# -- serving layer ------------------------------------------------------------

def test_scheduler_clamps_bitplane_to_lane_multiples():
    from repro.serve.scheduler import ReplicaPackingScheduler
    from repro.serve.jobs import Job, JobSpec, schedule_fingerprint
    sch = ea_schedule(32)
    fp = schedule_fingerprint(sch)

    def job(seq, replicas, precision):
        spec = JobSpec(problem="p", engine="lattice", sweeps=32,
                       replicas=replicas, precision=precision)
        return Job(f"j{seq}", seq, spec, "lat:L=6:seed=0", sch, fp, 0.0)

    s = ReplicaPackingScheduler(max_replicas_per_call=64)
    # two bitplane jobs coalesce and execute at the full 32-lane word
    b = s.next_batch([job(0, 4, "bitplane"), job(1, 8, "bitplane")])
    assert len(b.jobs) == 2 and b.r_exec == 32
    # a word-straddling pack clamps to the next word multiple, not pow2
    b = s.next_batch([job(0, 20, "bitplane"), job(1, 20, "bitplane")])
    assert len(b.jobs) == 2 and b.r_exec == 64       # W=2, not one word
    # the budget still bounds the pack (cap 64 here -> at most two words)
    b = s.next_batch([job(0, 40, "bitplane"), job(1, 40, "bitplane")])
    assert len(b.jobs) == 1 and b.r_exec == 64
    assert s.replica_budget("bitplane") == 64
    wide = ReplicaPackingScheduler(max_replicas_per_call=1024)
    assert wide.replica_budget("bitplane") == 32 * MAX_LANE_WORDS
    # bitplane never packs with int8 (precision is in the pack key)
    b = s.next_batch([job(0, 4, "bitplane"), job(1, 4, "int8")])
    assert len(b.jobs) == 1
    # prewarm bucketing agrees with batch formation: word multiples,
    # R=33 and R=64 bucket to the SAME W=2 executable
    assert s.r_exec_for("lattice", 4, "bitplane") == 32
    assert s.r_exec_for("lattice", 33, "bitplane") == 64
    assert s.r_exec_for("lattice", 64, "bitplane") == 64
    assert wide.r_exec_for("lattice", 65, "bitplane") == 96   # not pow2 128
    assert s.r_exec_for("lattice", 4, "int8") == 4
    # a cap below the word width just runs unpadded
    tight = ReplicaPackingScheduler(max_replicas_per_call=16)
    b = tight.next_batch([job(0, 3, "bitplane")])
    assert b.r_exec == 4                             # pow2 pad only


def test_server_bitplane_jobs_pack_and_guard():
    from repro.core.graph import ea3d
    from repro.core.coloring import lattice3d_coloring
    from repro.core.partition import slab_partition
    from repro.serve.server import SampleServer
    srv = SampleServer(pack=True, warm_compile=False)
    srv.register_problem("lat6", L=6, seed=0, impl="ref")
    g = ea3d(4, seed=0)
    srv.register_problem("g4", graph=g, coloring=lattice3d_coloring(4), K=2,
                         labels=slab_partition(4, 2), rng="lfsr")
    # unsupported engine/precision pair: clear error at submit, not a
    # failed job (let alone a packing shape error)
    with pytest.raises(ValueError, match="lattice/dsim_dist path"):
        srv.submit("g4", engine="dsim", precision="bitplane", sweeps=16)
    # the admission cap is the scheduler budget: min(per-call cap 64,
    # MAX_LANE_WORDS words); word-straddling counts (e.g. 40) are legal now
    with pytest.raises(ValueError, match=r"\[1, 64\]"):
        srv.submit("lat6", engine="lattice", precision="bitplane",
                   replicas=100, sweeps=16)
    a = srv.submit("lat6", engine="lattice", precision="bitplane",
                   replicas=4, sweeps=32, sync_every=4, seed=1)
    b = srv.submit("lat6", engine="lattice", precision="bitplane",
                   replicas=8, sweeps=32, sync_every=4, seed=2)
    ra, rb = srv.result(a), srv.result(b)
    assert ra["status"] == "done" and rb["status"] == "done"
    assert ra["packed_with"] == 1 and rb["packed_with"] == 1
    assert ra["energies"].shape[1] == 4 and rb["energies"].shape[1] == 8
    assert ra["best_energy"] < 0 and rb["best_energy"] < 0
    assert ra["flips"] > 0 and rb["flips"] > 0
    # a solo bitplane job of the same spec reproduces its packed lanes
    solo = srv.submit("lat6", engine="lattice", precision="bitplane",
                      replicas=4, sweeps=32, sync_every=4, seed=1)
    rs = srv.result(solo)
    np.testing.assert_array_equal(rs["energies"], ra["energies"])


def test_server_pool_keys_bitplane_by_word_count():
    """R=33 and R=64 submissions both clamp to the W=2 (64-lane) executed
    width, so they share ONE pooled executable: the second is a pool hit,
    never a recompile.  ``prewarm_words=2`` builds that same bucket at
    register time."""
    from repro.serve.server import SampleServer
    srv = SampleServer(pack=True, warm_compile=False)
    srv.register_problem("lat4", L=4, seed=0, impl="ref")
    a = srv.submit("lat4", engine="lattice", precision="bitplane",
                   replicas=33, sweeps=16, sync_every=4, seed=1)
    ra = srv.result(a)
    assert ra["status"] == "done" and ra["cold_start"] is True
    assert ra["energies"].shape[1] == 33         # own lanes only
    b = srv.submit("lat4", engine="lattice", precision="bitplane",
                   replicas=64, sweeps=16, sync_every=4, seed=2)
    rb = srv.result(b)
    assert rb["status"] == "done"
    assert rb["cold_start"] is False             # same W=2 pool key
    assert rb["energies"].shape[1] == 64
    # register-time prewarm of the W=2 bucket serves the first tenant warm
    srv2 = SampleServer(pack=True, warm_compile=False)
    srv2.register_problem("lat4", L=4, seed=0, impl="ref",
                          prewarm_bitplane=True, prewarm_words=2)
    srv2.prewarm_threads[0].join(timeout=400)
    assert not srv2.prewarm_threads[0].is_alive()
    c = srv2.submit("lat4", engine="lattice", precision="bitplane",
                    replicas=40, sweeps=16, sync_every=4, seed=3)
    rc = srv2.result(c)
    assert rc["status"] == "done" and rc["cold_start"] is False
    with pytest.raises(ValueError, match="prewarm_words"):
        srv2.register_problem("bad", L=4, prewarm_words=0)
