"""Problem suite: EA grounds, Max-Cut, 3SAT encoding, planting, APT+ICM."""

import os
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.graph import ea3d
from repro.core.coloring import lattice3d_coloring, greedy_coloring
from repro.core.gibbs import GibbsEngine
from repro.core.annealing import ea_schedule, sat_schedule, Schedule
from repro.core.energy import energy
from repro.core.apt_icm import APTICM, adapt_ladder
from repro.problems.ea3d import instance_set, GroundStore, establish_grounds
from repro.problems.maxcut import (parse_gset, gset_like_toroidal,
                                   maxcut_to_ising, cut_of, spins_to_hex,
                                   hex_to_spins)
from repro.problems.sat import (random_3sat, encode_3sat, decode_assignment,
                                count_satisfied)
from repro.problems.planting import plant_frustrated_loops


def test_instance_set_protocol():
    graphs = instance_set(4, n_instances=3)
    assert len(graphs) == 3
    seeds = [g.meta["seed"] for g in graphs]
    assert len(set(seeds)) == 3


def test_ground_store(tmp_path):
    store = GroundStore(str(tmp_path / "g.json"))
    assert store.get(5, 1) is None
    assert store.update(5, 1, -100.0) == -100.0
    assert store.update(5, 1, -90.0) == -100.0   # min-merge
    assert store.update(5, 1, -120.0) == -120.0
    store2 = GroundStore(str(tmp_path / "g.json"))
    assert store2.get(5, 1) == -120.0


def test_establish_grounds(tmp_path):
    graphs = instance_set(4, n_instances=2)
    store = GroundStore(str(tmp_path / "g.json"))
    grounds = establish_grounds(graphs, store, sweeps=200, runs=1)
    assert len(grounds) == 2
    assert all(g < 0 for g in grounds)


def test_gset_parser():
    text = "3 2\n1 2 1\n2 3 -1\n"
    g = parse_gset(text)
    assert g.n == 3 and g.num_edges == 2
    m = jnp.asarray([1, -1, -1], jnp.int8)
    assert cut_of(g, m) == 1.0  # edge (1,2) cut w=+1; (2,3) uncut


def test_maxcut_mapping_consistency():
    g = gset_like_toroidal(6, 8, seed=0)
    gi = maxcut_to_ising(g)
    rng = np.random.default_rng(0)
    W = float(np.asarray(g.w).sum()) / 2
    for _ in range(4):
        m = jnp.asarray(rng.choice([-1, 1], g.n).astype(np.int8))
        # with J = -w:  E_ising = -sum J m m = +sum w m m, so
        # cut = (W_tot - sum w m m) / 2 = (W_tot - E_ising) / 2
        cut = cut_of(g, m)
        E = float(energy(gi, m))
        assert abs(cut - (W - E) / 2) < 1e-3


def test_hex_roundtrip():
    rng = np.random.default_rng(1)
    m = rng.choice([-1, 1], 101).astype(np.int8)
    assert (hex_to_spins(spins_to_hex(m), 101) == m).all()


def test_sat_encoding_ground_states():
    """Satisfying assignments of the formula must be ground states of the
    Ising encoding (gate Hamiltonian correctness)."""
    clauses = np.array([[1, 2, 3], [-1, 2, -3], [1, -2, 3]])
    enc = encode_3sat(clauses, 3, max_fanout=10)
    g = enc.graph

    def clause_energy(assign):
        # brute-force the auxiliary spins for given variable assignment
        best = np.inf
        n_aux = enc.n_aux
        for mask in range(2 ** n_aux):
            full = np.ones(g.n, dtype=np.int8)
            for v in range(3):
                full[enc.copies_of[v]] = assign[v]
            for a in range(n_aux):
                full[g.n - n_aux + a] = 1 if (mask >> a) & 1 else -1
            best = min(best, float(energy(g, jnp.asarray(full))))
        return best

    energies = {}
    for bits in range(8):
        assign = np.asarray([(bits >> i) & 1 for i in range(3)]) * 2 - 1
        nsat = count_satisfied(clauses, assign)
        energies.setdefault(nsat, []).append(clause_energy(assign))
    # all-satisfying assignments reach the global minimum
    emin = min(min(v) for v in energies.values())
    assert min(energies[3]) == emin
    assert min(energies[2]) > emin - 1e-6


def test_sat_pipeline_end_to_end():
    clauses = random_3sat(25, 100, seed=3)
    enc = encode_3sat(clauses, 25)
    col = greedy_coloring(np.asarray(enc.graph.idx), np.asarray(enc.graph.w))
    eng = GibbsEngine(enc.graph, col)
    st = eng.init_state(seed=0)
    st, _ = eng.run_dense(st, sat_schedule(2500).beta_array())
    assign = decode_assignment(enc, np.asarray(st.m))
    assert count_satisfied(clauses, assign) >= 95  # >= 95% on easy-ish alpha=4


def test_copy_chain_fanout():
    clauses = random_3sat(10, 80, seed=0)
    enc = encode_3sat(clauses, 10, max_fanout=4)
    # high-occupancy variables got split
    occ = np.zeros(10)
    for c in clauses:
        for lit in c:
            occ[abs(lit) - 1] += 1
    for v in range(10):
        assert len(enc.copies_of[v]) == max(1, int(np.ceil(occ[v] / 4)))


def test_planted_instance():
    host = ea3d(5, seed=2)
    inst = plant_frustrated_loops(host, n_loops=40, seed=1)
    E_check = float(energy(inst.graph, jnp.asarray(inst.ground_state)))
    assert abs(E_check - inst.ground_energy) < 1e-4
    # annealing reaches the planted ground energy (paper S11 protocol)
    col = greedy_coloring(np.asarray(inst.graph.idx), np.asarray(inst.graph.w))
    eng = GibbsEngine(inst.graph, col)
    st = eng.init_state(seed=0)
    st, (Etr, _) = eng.run_dense(
        st, Schedule(np.arange(0.5, 5.01, 0.5), 1500).beta_array())
    assert float(np.asarray(Etr).min()) <= inst.ground_energy + 1e-4


def test_apt_icm_invariants():
    g = ea3d(5, seed=1)
    col = lattice3d_coloring(5)
    betas = adapt_ladder(g, col, 0.3, 3.0, 5, pilot_sweeps=50)
    assert (np.diff(betas) > 0).all()
    apt = APTICM(g, col, betas, chains=2)
    st = apt.init_state(seed=0)
    st2, (ts, best) = apt.run(st, 40, icm_every=5, record_every=10)
    # incremental energies stay exact through swaps + ICM
    Edir = jax.vmap(jax.vmap(lambda mm: energy(g, mm)))(st2.m)
    assert float(jnp.abs(Edir - st2.E).max()) == 0.0
    assert int(st2.swaps) > 0
    # ICM preserves the pair-sum exactly
    m, E, key, icms = apt._icm(st2.m, st2.E, st2.key, st2.icms)
    before = np.asarray(st2.E)[0] + np.asarray(st2.E)[1]
    after = np.asarray(E)[0] + np.asarray(E)[1]
    np.testing.assert_allclose(before, after, atol=1e-3)


def _apt_spins(apt, m):
    """(P, T, N) int8 view of a raw state array in either mode."""
    if apt.packed:
        from repro.core.packing import unpack_lanes
        return np.asarray(unpack_lanes(m, apt.L)).reshape(apt.P, apt.T,
                                                          apt.n)
    return np.asarray(m)


def test_apt_packed_guards():
    g = ea3d(4, seed=0)
    col = lattice3d_coloring(4)
    betas = np.linspace(0.5, 3.0, 8)
    with pytest.raises(ValueError, match="rng='lfsr'"):
        APTICM(g, col, betas, chains=4, packed=True)
    with pytest.raises(ValueError, match="bit lanes"):
        # chains * temperatures = 288 > 8 words * 32 lanes
        APTICM(g, col, np.linspace(0.5, 3.0, 36), chains=8, rng="lfsr",
               packed=True)
    # word-straddling grids are legal now: 4 * 10 = 40 lanes -> W = 2
    assert APTICM(g, col, np.linspace(0.5, 3.0, 10), chains=4, rng="lfsr",
                  packed=True).words == 2
    with pytest.raises(ValueError, match="unknown rng"):
        APTICM(g, col, betas, chains=4, rng="pcg")


def test_apt_packed_bitwise_matches_unpacked_lfsr():
    """The lane-packed ladder (4 chains x 8 temperatures = 32 word lanes)
    is bit-identical to the unpacked fixed-point run at matched seeds:
    same spins, same energies, same best-energy trajectory, same swap and
    ICM counters — swap moves as lane permutations included."""
    g = ea3d(4, seed=1)
    col = lattice3d_coloring(4)
    betas = np.linspace(0.5, 3.0, 8)
    un = APTICM(g, col, betas, chains=4, rng="lfsr")
    pk = APTICM(g, col, betas, chains=4, rng="lfsr", packed=True)
    su, sp = un.init_state(seed=0), pk.init_state(seed=0)
    np.testing.assert_array_equal(np.asarray(un.spins(su)),
                                  np.asarray(pk.spins(sp)))
    su, (_, bu) = un.run(su, 12, icm_every=4, record_every=4)
    sp, (_, bp) = pk.run(sp, 12, icm_every=4, record_every=4)
    np.testing.assert_array_equal(bu, bp)
    np.testing.assert_array_equal(np.asarray(un.spins(su)),
                                  np.asarray(pk.spins(sp)))
    np.testing.assert_array_equal(np.asarray(su.E), np.asarray(sp.E))
    assert int(su.swaps) == int(sp.swaps) > 0
    assert int(su.icms) == int(sp.icms) > 0
    cu, eu = un.best_config(su)
    cp, ep = pk.best_config(sp)
    assert eu == ep
    np.testing.assert_array_equal(cu, cp)


def test_apt_packed_multiword_bitwise_matches_unpacked_lfsr():
    """The multi-word ladder (4 chains x 10 temperatures = 40 lanes across
    W=2 word planes) stays bit-identical to the unpacked fixed-point run —
    replica-exchange swaps are now cross-word lane permutations and the
    ICM pair (2p, 2p+1) can straddle a word boundary."""
    g = ea3d(4, seed=1)
    col = lattice3d_coloring(4)
    betas = np.linspace(0.4, 2.8, 10)
    un = APTICM(g, col, betas, chains=4, rng="lfsr")
    pk = APTICM(g, col, betas, chains=4, rng="lfsr", packed=True)
    assert pk.words == 2
    su, sp = un.init_state(seed=2), pk.init_state(seed=2)
    np.testing.assert_array_equal(np.asarray(un.spins(su)),
                                  np.asarray(pk.spins(sp)))
    su, (_, bu) = un.run(su, 12, icm_every=4, record_every=4)
    sp, (_, bp) = pk.run(sp, 12, icm_every=4, record_every=4)
    np.testing.assert_array_equal(bu, bp)
    np.testing.assert_array_equal(np.asarray(un.spins(su)),
                                  np.asarray(pk.spins(sp)))
    np.testing.assert_array_equal(np.asarray(su.E), np.asarray(sp.E))
    assert int(su.swaps) == int(sp.swaps) > 0
    assert int(su.icms) == int(sp.icms) > 0


def test_apt_packed_t64_ladder_end_to_end():
    """A G81-class T=64 ladder (2 chains -> 128 lanes, W=4) runs packed
    end to end — the configuration the 32-lane cap used to reject with a
    ValueError — and its incremental energies stay exact."""
    g = ea3d(4, seed=3)
    col = lattice3d_coloring(4)
    pk = APTICM(g, col, np.linspace(0.2, 3.0, 64), chains=2, rng="lfsr",
                packed=True)
    assert pk.words == 4
    st = pk.init_state(seed=1)
    st, (ts, best) = pk.run(st, 8, icm_every=4, record_every=4)
    assert int(st.swaps) > 0
    Edir = jax.vmap(jax.vmap(lambda mm: energy(g, mm)))(pk.spins(st))
    assert float(jnp.abs(Edir - st.E).max()) == 0.0


def test_apt_packed_incremental_energy_exact():
    """Packed-sweep incremental energies stay exact against direct
    recomputation from the unpacked lanes (XOR field + LUT accept feed the
    same per-flip delta as the integer reference)."""
    g = ea3d(4, seed=2)
    col = lattice3d_coloring(4)
    pk = APTICM(g, col, np.linspace(0.4, 2.5, 8), chains=4, rng="lfsr",
                packed=True)
    st = pk.init_state(seed=3)
    st, _ = pk.run(st, 10, icm_every=3, record_every=5)
    Edir = jax.vmap(jax.vmap(lambda mm: energy(g, mm)))(pk.spins(st))
    assert float(jnp.abs(Edir - st.E).max()) == 0.0


@pytest.mark.parametrize("f_max", [6, 70])
def test_apt_accept_rows_narrow_and_wide_agree_with_gather(f_max):
    """Both branches of _accept_rows (rank-count unroll for narrow rows,
    take_along_axis fallback for rows wider than LUT_SELECT_MAX_WIDTH —
    non-+-J couplings blow f_max up to int8 magnitudes) implement the same
    accept test ``u >= thr[field + f_max]``."""
    from repro.core.pbit import LUT_SELECT_MAX_WIDTH
    g = ea3d(4, seed=0)
    col = lattice3d_coloring(4)
    apt = APTICM(g, col, np.linspace(0.5, 2.0, 4), chains=2, rng="lfsr")
    rng = np.random.default_rng(7)
    lw = 2 * f_max + 1
    assert (lw <= LUT_SELECT_MAX_WIDTH) == (f_max == 6)
    # monotone nonincreasing rows, like threshold_lut guarantees
    rows = np.sort(rng.integers(0, 1 << 24, size=(4, lw)),
                   axis=-1)[:, ::-1].astype(np.uint32)
    thr = jnp.asarray(rows[None, :, None, :])            # (1, T, 1, lw)
    field = jnp.asarray(rng.integers(-f_max, f_max + 1, size=(3, 4, 10)),
                        jnp.int32)
    u = jnp.asarray(rng.integers(0, 1 << 24,
                                 size=(3, 4, 10)).astype(np.uint32))
    apt.f_max = f_max
    got = np.asarray(apt._accept_rows(thr, field, u))
    idx = np.clip(np.asarray(field) + f_max, 0, lw - 1)
    want = np.asarray(u) >= np.take_along_axis(
        np.broadcast_to(rows[None, :, None, :], (3, 4, 10, lw)),
        idx[..., None], axis=-1)[..., 0]
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("packed", [False, True])
def test_apt_icm_move_invariants(packed):
    """Satellite invariants of the Houdayer move, in both modes: the
    cluster flip (a) touches identical site sets in both chains of a pair,
    (b) stays confined to the pair's disagreement set, and (c) preserves
    E1+E2 per (pair, temperature) exactly up to f32 recomputation."""
    g = ea3d(4, seed=3)
    col = lattice3d_coloring(4)
    kw = dict(rng="lfsr", packed=True) if packed else {}
    apt = APTICM(g, col, np.linspace(0.5, 3.0, 8), chains=4, **kw)
    st = apt.init_state(seed=1)
    st, _ = apt.run(st, 6, icm_every=0, record_every=6)   # decorrelate
    m0 = _apt_spins(apt, st.m)
    E0 = np.asarray(st.E)
    if packed:
        m, E, _, icms = apt._icm_packed(st.m, st.E, st.key, st.icms)
    else:
        m, E, _, icms = apt._icm(st.m, st.E, st.key, st.icms)
    m1 = _apt_spins(apt, m)
    flipped = m0 != m1                                    # (P, T, N)
    disagree = m0[0::2] != m0[1::2]                       # (P/2, T, N)
    # same cluster flips in both chains of each pair
    np.testing.assert_array_equal(flipped[0::2], flipped[1::2])
    # cluster confined to the disagreement set
    assert not (flipped[0::2] & ~disagree).any()
    # pair-sum energies preserved (isoenergetic move)
    pair0 = E0[0::2] + E0[1::2]
    pair1 = np.asarray(E)[0::2] + np.asarray(E)[1::2]
    np.testing.assert_allclose(pair1, pair0, atol=1e-3)
    # the move counter advances by the pairs that had any disagreement
    assert int(icms) - int(st.icms) == int(disagree.any(axis=-1).sum())
    assert int(icms) > int(st.icms)


def test_apt_beats_plain_annealing_on_hard_instance():
    g = ea3d(5, seed=9)
    col = lattice3d_coloring(5)
    betas = np.linspace(0.5, 4.0, 6)
    apt = APTICM(g, col, betas, chains=2)
    st = apt.init_state(seed=0)
    st, (ts, best) = apt.run(st, 150, icm_every=10, record_every=50)
    _, E_apt = apt.best_config(st)
    eng = GibbsEngine(g, col)
    s2 = eng.init_state(seed=0)
    s2, (Etr, _) = eng.run_dense(s2, ea_schedule(150).beta_array())
    assert E_apt <= float(np.asarray(Etr).min()) + 4.0
