"""Problem suite: EA grounds, Max-Cut, 3SAT encoding, planting, APT+ICM."""

import os
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.graph import ea3d
from repro.core.coloring import lattice3d_coloring, greedy_coloring
from repro.core.gibbs import GibbsEngine
from repro.core.annealing import ea_schedule, sat_schedule, Schedule
from repro.core.energy import energy
from repro.core.apt_icm import APTICM, adapt_ladder
from repro.problems.ea3d import instance_set, GroundStore, establish_grounds
from repro.problems.maxcut import (parse_gset, gset_like_toroidal,
                                   maxcut_to_ising, cut_of, spins_to_hex,
                                   hex_to_spins)
from repro.problems.sat import (random_3sat, encode_3sat, decode_assignment,
                                count_satisfied)
from repro.problems.planting import plant_frustrated_loops


def test_instance_set_protocol():
    graphs = instance_set(4, n_instances=3)
    assert len(graphs) == 3
    seeds = [g.meta["seed"] for g in graphs]
    assert len(set(seeds)) == 3


def test_ground_store(tmp_path):
    store = GroundStore(str(tmp_path / "g.json"))
    assert store.get(5, 1) is None
    assert store.update(5, 1, -100.0) == -100.0
    assert store.update(5, 1, -90.0) == -100.0   # min-merge
    assert store.update(5, 1, -120.0) == -120.0
    store2 = GroundStore(str(tmp_path / "g.json"))
    assert store2.get(5, 1) == -120.0


def test_establish_grounds(tmp_path):
    graphs = instance_set(4, n_instances=2)
    store = GroundStore(str(tmp_path / "g.json"))
    grounds = establish_grounds(graphs, store, sweeps=200, runs=1)
    assert len(grounds) == 2
    assert all(g < 0 for g in grounds)


def test_gset_parser():
    text = "3 2\n1 2 1\n2 3 -1\n"
    g = parse_gset(text)
    assert g.n == 3 and g.num_edges == 2
    m = jnp.asarray([1, -1, -1], jnp.int8)
    assert cut_of(g, m) == 1.0  # edge (1,2) cut w=+1; (2,3) uncut


def test_maxcut_mapping_consistency():
    g = gset_like_toroidal(6, 8, seed=0)
    gi = maxcut_to_ising(g)
    rng = np.random.default_rng(0)
    W = float(np.asarray(g.w).sum()) / 2
    for _ in range(4):
        m = jnp.asarray(rng.choice([-1, 1], g.n).astype(np.int8))
        # with J = -w:  E_ising = -sum J m m = +sum w m m, so
        # cut = (W_tot - sum w m m) / 2 = (W_tot - E_ising) / 2
        cut = cut_of(g, m)
        E = float(energy(gi, m))
        assert abs(cut - (W - E) / 2) < 1e-3


def test_hex_roundtrip():
    rng = np.random.default_rng(1)
    m = rng.choice([-1, 1], 101).astype(np.int8)
    assert (hex_to_spins(spins_to_hex(m), 101) == m).all()


def test_sat_encoding_ground_states():
    """Satisfying assignments of the formula must be ground states of the
    Ising encoding (gate Hamiltonian correctness)."""
    clauses = np.array([[1, 2, 3], [-1, 2, -3], [1, -2, 3]])
    enc = encode_3sat(clauses, 3, max_fanout=10)
    g = enc.graph

    def clause_energy(assign):
        # brute-force the auxiliary spins for given variable assignment
        best = np.inf
        n_aux = enc.n_aux
        for mask in range(2 ** n_aux):
            full = np.ones(g.n, dtype=np.int8)
            for v in range(3):
                full[enc.copies_of[v]] = assign[v]
            for a in range(n_aux):
                full[g.n - n_aux + a] = 1 if (mask >> a) & 1 else -1
            best = min(best, float(energy(g, jnp.asarray(full))))
        return best

    energies = {}
    for bits in range(8):
        assign = np.asarray([(bits >> i) & 1 for i in range(3)]) * 2 - 1
        nsat = count_satisfied(clauses, assign)
        energies.setdefault(nsat, []).append(clause_energy(assign))
    # all-satisfying assignments reach the global minimum
    emin = min(min(v) for v in energies.values())
    assert min(energies[3]) == emin
    assert min(energies[2]) > emin - 1e-6


def test_sat_pipeline_end_to_end():
    clauses = random_3sat(25, 100, seed=3)
    enc = encode_3sat(clauses, 25)
    col = greedy_coloring(np.asarray(enc.graph.idx), np.asarray(enc.graph.w))
    eng = GibbsEngine(enc.graph, col)
    st = eng.init_state(seed=0)
    st, _ = eng.run_dense(st, sat_schedule(2500).beta_array())
    assign = decode_assignment(enc, np.asarray(st.m))
    assert count_satisfied(clauses, assign) >= 95  # >= 95% on easy-ish alpha=4


def test_copy_chain_fanout():
    clauses = random_3sat(10, 80, seed=0)
    enc = encode_3sat(clauses, 10, max_fanout=4)
    # high-occupancy variables got split
    occ = np.zeros(10)
    for c in clauses:
        for lit in c:
            occ[abs(lit) - 1] += 1
    for v in range(10):
        assert len(enc.copies_of[v]) == max(1, int(np.ceil(occ[v] / 4)))


def test_planted_instance():
    host = ea3d(5, seed=2)
    inst = plant_frustrated_loops(host, n_loops=40, seed=1)
    E_check = float(energy(inst.graph, jnp.asarray(inst.ground_state)))
    assert abs(E_check - inst.ground_energy) < 1e-4
    # annealing reaches the planted ground energy (paper S11 protocol)
    col = greedy_coloring(np.asarray(inst.graph.idx), np.asarray(inst.graph.w))
    eng = GibbsEngine(inst.graph, col)
    st = eng.init_state(seed=0)
    st, (Etr, _) = eng.run_dense(
        st, Schedule(np.arange(0.5, 5.01, 0.5), 1500).beta_array())
    assert float(np.asarray(Etr).min()) <= inst.ground_energy + 1e-4


def test_apt_icm_invariants():
    g = ea3d(5, seed=1)
    col = lattice3d_coloring(5)
    betas = adapt_ladder(g, col, 0.3, 3.0, 5, pilot_sweeps=50)
    assert (np.diff(betas) > 0).all()
    apt = APTICM(g, col, betas, chains=2)
    st = apt.init_state(seed=0)
    st2, (ts, best) = apt.run(st, 40, icm_every=5, record_every=10)
    # incremental energies stay exact through swaps + ICM
    Edir = jax.vmap(jax.vmap(lambda mm: energy(g, mm)))(st2.m)
    assert float(jnp.abs(Edir - st2.E).max()) == 0.0
    assert int(st2.swaps) > 0
    # ICM preserves the pair-sum exactly
    m, E, key, icms = apt._icm(st2.m, st2.E, st2.key, st2.icms)
    before = np.asarray(st2.E)[0] + np.asarray(st2.E)[1]
    after = np.asarray(E)[0] + np.asarray(E)[1]
    np.testing.assert_allclose(before, after, atol=1e-3)


def test_apt_beats_plain_annealing_on_hard_instance():
    g = ea3d(5, seed=9)
    col = lattice3d_coloring(5)
    betas = np.linspace(0.5, 4.0, 6)
    apt = APTICM(g, col, betas, chains=2)
    st = apt.init_state(seed=0)
    st, (ts, best) = apt.run(st, 150, icm_every=10, record_every=50)
    _, E_apt = apt.best_config(st)
    eng = GibbsEngine(g, col)
    s2 = eng.init_state(seed=0)
    s2, (Etr, _) = eng.run_dense(s2, ea_schedule(150).beta_array())
    assert E_apt <= float(np.asarray(Etr).min()) + 4.0
