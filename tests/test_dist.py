"""Multi-device distribution tests.

These run in SUBPROCESSES with forced host device counts so the main pytest
process keeps the default single device (per the harness contract)."""

import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, devices: int = 4, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       timeout=timeout)
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


def test_dist_dsim_bitwise_matches_stacked():
    out = run_py("""
        import numpy as np, jax
        from repro.core.graph import ea3d
        from repro.core.coloring import lattice3d_coloring
        from repro.core.partition import slab_partition
        from repro.core.dsim import build_partitioned, DSIMEngine
        from repro.core.dsim_dist import DistDSIMEngine
        from repro.core.annealing import ea_schedule
        g = ea3d(8, seed=7); col = lattice3d_coloring(8)
        prob = build_partitioned(g, col, slab_partition(8, 4), 4)
        from repro.compat import make_mesh, auto_axes
        mesh = make_mesh((4,), ("data",), axis_types=auto_axes(1))
        sch = ea_schedule(256)
        d = DistDSIMEngine(prob, mesh, rng="lfsr", bitpack=True)
        sd = d.init_state(seed=3)
        sd, (_, Ed) = d.run_recorded(sd, sch, [64, 256], sync_every=4)
        s = DSIMEngine(prob, rng="lfsr")
        ss = s.init_state(seed=3)
        ss, (_, Es) = s.run_recorded(ss, sch, [64, 256], sync_every=4)
        md = np.asarray(d.global_spins(sd)); ms = np.asarray(s.global_spins(ss))
        print("BITWISE", bool((md == ms).all()))
        print("E", float(Ed[-1]), float(Es[-1]))
    """)
    assert "BITWISE True" in out


def test_lattice_dsim_multiaxis_halo():
    out = run_py("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.lattice import build_ea3d_lattice
        from repro.core.lattice_dsim import LatticeDSIM
        from repro.core.graph import ea3d
        from repro.core.energy import energy
        from repro.core.annealing import ea_schedule
        from repro.compat import make_mesh, auto_axes
        mesh = make_mesh((2, 2, 2), ("x", "y", "z"), axis_types=auto_axes(3))
        prob = build_ea3d_lattice(8, seed=5)
        eng = LatticeDSIM(prob, mesh, dim_axes=("x", "y", "z"), impl="ref")
        st = eng.init_state(seed=0)
        g = ea3d(8, seed=5)
        m = jnp.asarray(np.asarray(st.m).reshape(-1))
        print("EQ", abs(float(eng.energy(st)) - float(energy(g, m))) < 1e-3)
        stf, (_, Es) = eng.run_recorded(st, ea_schedule(256), [256],
                                        sync_every=4)
        print("ANNEALED", float(Es[-1]) < float(eng.energy(st)) )
        print("E_final", float(Es[-1]))
    """, devices=8)
    assert "EQ True" in out and "ANNEALED True" in out


def test_local_sgd_and_compressed_allreduce():
    out = run_py("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.models.lm import build_model
        from repro.train.optimizer import AdamW
        from repro.train.train_step import TrainState, make_local_sgd_step
        from repro.train.compression import make_ef_allreduce
        from repro.train.data import MarkovLM
        cfg = get_config("h2o-danube-1.8b").reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        from repro.compat import make_mesh, auto_axes
        mesh = make_mesh((4,), ("data",), axis_types=auto_axes(1))
        opt = AdamW(lr=3e-3, warmup=5)
        outer, repl = make_local_sgd_step(model, opt, mesh, "data",
                                          sync_every=2)
        st = repl(TrainState(params=params, opt=opt.init(params)))
        data = MarkovLM(cfg.vocab, seed=2)
        losses = []
        for i in range(6):
            t = data.sample(4 * 2 * 4, 32).reshape(4, 2, 4, 32)
            bb = {"tokens": jnp.asarray(t), "targets": jnp.asarray(t),
                  "mask": jnp.ones_like(jnp.asarray(t))}
            st, m = outer(st, bb)
            losses.append(float(m["loss"]))
        print("LOCAL_SGD_DOWN", losses[-1] < losses[0])
        # params replicated identically after sync
        w = np.asarray(st.params["embed"])
        print("SYNCED", bool(np.allclose(w[0], w[1]) and np.allclose(w[0], w[3])))
        ef = make_ef_allreduce(mesh, "data")
        g = {"w": jnp.stack([jnp.full((256,), float(i)) for i in range(4)])}
        e = {"w": jnp.zeros((4, 256))}
        avg, e2 = ef(g, e)
        print("EF_MEAN", float(jnp.abs(avg["w"][0] - 1.5).max()) < 0.05)
    """)
    assert "LOCAL_SGD_DOWN True" in out
    assert "SYNCED True" in out
    assert "EF_MEAN True" in out


def test_sharded_train_step_matches_single_device():
    out = run_py("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.models.lm import build_model
        from repro.train.optimizer import AdamW
        from repro.train.train_step import TrainState, make_train_step
        from repro.sharding.rules import train_state_shardings, batch_shardings
        cfg = get_config("deepseek-moe-16b").reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        opt = AdamW(lr=1e-3, warmup=1)
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab)
        batch = {"tokens": toks, "targets": toks,
                 "mask": jnp.ones((8, 32), jnp.int32)}
        # single device
        st = TrainState(params=params, opt=opt.init(params))
        st1, m1 = jax.jit(make_train_step(model, opt))(st, batch)
        # 2x2 mesh sharded
        from repro.compat import make_mesh, auto_axes
        mesh = make_mesh((2, 2), ("data", "model"), axis_types=auto_axes(2))
        st = TrainState(params=params, opt=opt.init(params))
        sh = train_state_shardings(st, mesh, True, False)
        st = jax.tree.map(jax.device_put, st, sh)
        bsh = batch_shardings(batch, mesh)
        bb = jax.tree.map(jax.device_put, batch, bsh)
        from repro.compat import set_mesh
        with set_mesh(mesh):
            st2, m2 = jax.jit(make_train_step(model, opt))(st, bb)
        print("LOSS_EQ", abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3)
        d = max(float(jnp.abs(a - jnp.asarray(np.asarray(b))).max())
                for a, b in zip(jax.tree.leaves(st1.params),
                                jax.tree.leaves(st2.params)))
        print("PARAM_EQ", d < 5e-3, d)
    """)
    assert "LOSS_EQ True" in out
    assert "PARAM_EQ True" in out
