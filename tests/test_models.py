"""Model zoo: per-arch reduced smoke tests + decode==forward consistency."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import list_configs, get_config
from repro.models.lm import build_model, cross_entropy

LM_ARCHS = [n for n, c in list_configs().items() if c.family != "ising"]


def _batch_for(cfg, B=2, S=16):
    if cfg.encdec:
        return {"frames": jnp.ones((B, S, cfg.d_model), jnp.float32),
                "tokens": jnp.zeros((B, S), jnp.int32),
                "targets": jnp.zeros((B, S), jnp.int32),
                "mask": jnp.ones((B, S), jnp.int32)}
    if cfg.input_kind == "embeds3":
        return {"embeds": jnp.ones((B, S, cfg.d_model), jnp.float32),
                "positions3": jnp.zeros((3, B, S), jnp.int32),
                "targets": jnp.zeros((B, S), jnp.int32),
                "mask": jnp.ones((B, S), jnp.int32)}
    return {"tokens": jnp.zeros((B, S), jnp.int32),
            "targets": jnp.zeros((B, S), jnp.int32),
            "mask": jnp.ones((B, S), jnp.int32)}


@pytest.mark.parametrize("name", LM_ARCHS)
def test_smoke_forward_loss_grad(name):
    """One forward + train step on a reduced same-family config: correct
    shapes, finite loss, finite grads (the per-arch smoke requirement)."""
    cfg = get_config(name).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg)
    loss, grads = jax.value_and_grad(lambda p: model.loss(p, batch))(params)
    assert np.isfinite(float(loss))
    assert float(loss) < 2 * np.log(cfg.vocab) + 1
    gn = sum(float((g.astype(jnp.float32) ** 2).sum())
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0
    if not cfg.encdec:
        logits, _, _ = model.forward(params, batch.get("tokens"),
                                     embeds=batch.get("embeds"),
                                     positions3=batch.get("positions3"))
        assert logits.shape == (2, 16, cfg.vocab_padded)


@pytest.mark.parametrize("name", LM_ARCHS)
def test_decode_matches_forward(name):
    cfg = get_config(name).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B, S = 2, 24
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)
    if cfg.encdec:
        frames = jax.random.normal(jax.random.PRNGKey(3), (B, 16, cfg.d_model))
        enc = model.encode(params, frames)
        full, _ = model.decode(params, toks, enc)
        caches = model.init_cache(B, S + 8, dtype=jnp.float32)
        _, c2 = model.decode(params, toks[:, :-1], enc, caches=caches)
        last, _ = model.decode(params, toks[:, -1:], enc, caches=c2)
    elif cfg.input_kind == "embeds3":
        emb = jax.random.normal(jax.random.PRNGKey(4), (B, S, cfg.d_model)) * .1
        p3 = jnp.broadcast_to(jnp.arange(S)[None, None], (3, B, S)).astype(jnp.int32)
        full, _, _ = model.forward(params, embeds=emb, positions3=p3)
        caches = model.init_cache(B, S + 8, dtype=jnp.float32)
        _, c2, _ = model.forward(params, embeds=emb[:, :-1],
                                 positions3=p3[:, :, :-1], caches=caches)
        last, _, _ = model.forward(params, embeds=emb[:, -1:],
                                   positions3=p3[:, :, -1:], caches=c2)
    else:
        full, _, _ = model.forward(params, toks)
        caches = model.init_cache(B, S + 8, dtype=jnp.float32)
        _, c2, _ = model.forward(params, toks[:, :-1], caches=caches)
        last, _, _ = model.forward(params, toks[:, -1:], caches=c2)
    rel = float(jnp.abs(last[:, 0] - full[:, -1]).max()) / \
        float(jnp.abs(full[:, -1]).max())
    assert rel < 2e-2, rel


def test_rolling_swa_long_decode():
    """Ring cache smaller than the sequence still reproduces windowed
    attention exactly — the long_500k mechanism."""
    cfg = get_config("h2o-danube-1.8b").reduced()   # window 16
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    S = 48
    toks = jax.random.randint(jax.random.PRNGKey(5), (1, S), 0, cfg.vocab)
    full, _, _ = model.forward(params, toks)
    caches = model.init_cache(1, cfg.window, dtype=jnp.float32)
    _, c2, _ = model.forward(params, toks[:, :S - 4], caches=caches)
    for t in range(S - 4, S):
        last, c2, _ = model.forward(params, toks[:, t:t + 1], caches=c2)
    rel = float(jnp.abs(last[:, 0] - full[:, -1]).max()) / \
        float(jnp.abs(full[:, -1]).max())
    assert rel < 2e-2


def test_mamba2_chunk_invariance():
    """SSD output must not depend on the chunk size (algebraic identity)."""
    from repro.models.mamba2 import init_mamba2, mamba2_fwd
    key = jax.random.PRNGKey(0)
    p = init_mamba2(key, 32, 16, headdim=16)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 32))
    outs = []
    for chunk in (4, 8, 24):
        y, _ = mamba2_fwd(p, x, d_state=16, headdim=16, chunk=chunk)
        outs.append(np.asarray(y))
    np.testing.assert_allclose(outs[0], outs[1], rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(outs[0], outs[2], rtol=2e-4, atol=2e-4)


def test_moe_routes_and_balances():
    from repro.models.moe import init_moe, moe_fwd
    p = init_moe(jax.random.PRNGKey(0), 16, 32, n_experts=4)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 16))
    y, aux = moe_fwd(p, x, top_k=2, capacity_factor=8.0)
    assert y.shape == x.shape
    assert np.isfinite(float(aux))
    # zero routing logits => near-uniform probs => aux ~ 1 (balanced)
    p["router"] = jnp.zeros_like(p["router"])
    _, aux0 = moe_fwd(p, x, top_k=2, capacity_factor=8.0)
    assert abs(float(aux0) - 1.0) < 0.1


def test_moe_capacity_drops():
    from repro.models.moe import init_moe, moe_fwd
    p = init_moe(jax.random.PRNGKey(0), 16, 32, n_experts=4)
    # force all tokens to expert 0 => capacity drop at small factor
    p["router"] = jnp.zeros((16, 4)).at[:, 0].set(100.0)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 16))
    y_small, _ = moe_fwd(p, x, top_k=1, capacity_factor=0.25)
    y_big, _ = moe_fwd(p, x, top_k=1, capacity_factor=8.0)
    # dropped tokens contribute zero -> outputs differ
    assert float(jnp.abs(y_small - y_big).max()) > 1e-6


def test_cross_entropy_masking():
    logits = jnp.zeros((1, 4, 8))
    targets = jnp.zeros((1, 4), jnp.int32)
    full = cross_entropy(logits, targets, jnp.ones((1, 4)))
    assert abs(float(full) - np.log(8)) < 1e-5
    none = cross_entropy(logits, targets, jnp.zeros((1, 4)))
    assert float(none) == 0.0


def test_exact_config_dimensions():
    """Assigned-architecture configs carry the published numbers."""
    c = get_config("deepseek-67b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (95, 8192, 64, 8, 22016, 102400)
    c = get_config("grok-1-314b")
    assert (c.moe_experts, c.moe_top_k, c.vocab) == (8, 2, 131072)
    c = get_config("deepseek-moe-16b")
    assert (c.moe_experts, c.moe_top_k, c.moe_shared) == (64, 6, 2)
    c = get_config("jamba-v0.1-52b")
    assert len(c.group) == 8
    assert sum(1 for b in c.group if b.mixer == "attn") == 1
    assert sum(1 for b in c.group if b.ffn == "moe") == 4
    c = get_config("mamba2-370m")
    assert c.ssm_state == 128 and c.d_ff == 0
    c = get_config("qwen2-vl-7b")
    assert c.mrope_sections == (16, 24, 24)
    c = get_config("h2o-danube-1.8b")
    assert c.window == 4096
    c = get_config("seamless-m4t-medium")
    assert c.encdec and c.enc_layers == 12 and c.vocab == 256206
