"""Pallas kernels vs pure-jnp oracles: shape/dtype/format sweeps.

Spin and LFSR outputs must be bitwise equal (identical integer math);
energies allclose (f32 reduction order differs across tilings)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.ops import pbit_update_op, brick_energy_op
from repro.kernels.ref import pbit_brick_update_ref, brick_energy_ref
from repro.core.pbit import S41, S43, FixedPoint

RNG = np.random.default_rng(0)


def make_inputs(shape, hscale=0.1):
    Bx, By, Bz = shape
    m = jnp.asarray(RNG.choice([-1, 1], size=shape).astype(np.int8))
    s = jnp.asarray(RNG.integers(1, 2 ** 32, size=shape, dtype=np.uint32))
    h = jnp.asarray(RNG.normal(0, hscale, shape).astype(np.float32))
    w6 = tuple(jnp.asarray(RNG.choice([-1.0, 0.0, 1.0], size=shape)
                           .astype(np.float32)) for _ in range(6))
    halos = (jnp.asarray(RNG.choice([-1, 1], (By, Bz)).astype(np.int8)),
             jnp.asarray(RNG.choice([-1, 1], (By, Bz)).astype(np.int8)),
             jnp.asarray(RNG.choice([-1, 1], (Bx, Bz)).astype(np.int8)),
             jnp.asarray(RNG.choice([-1, 1], (Bx, Bz)).astype(np.int8)),
             jnp.asarray(RNG.choice([-1, 1], (Bx, By)).astype(np.int8)),
             jnp.asarray(RNG.choice([-1, 1], (Bx, By)).astype(np.int8)))
    par = jnp.asarray((RNG.random(shape) < 0.5).astype(np.int8))
    active = jnp.asarray(np.ones(shape, np.int8))
    return m, s, h, w6, halos, par, active


@pytest.mark.parametrize("shape,bx", [
    ((8, 4, 4), 2), ((8, 4, 4), 4), ((8, 4, 4), 8),
    ((16, 8, 8), 4), ((6, 3, 5), 3), ((12, 2, 2), 6),
])
@pytest.mark.parametrize("fmt", [None, S41, S43])
def test_pbit_kernel_matches_ref(shape, bx, fmt):
    m, s, h, w6, halos, par, active = make_inputs(shape)
    m1, s1 = pbit_update_op(m, s, 1.7, par, h, w6, halos, fmt=fmt, bx=bx,
                            impl="interpret")
    m2, s2 = pbit_brick_update_ref(m, s, 1.7, par, h, w6, halos, fmt=fmt)
    assert (np.asarray(m1) == np.asarray(m2)).all()
    assert (np.asarray(s1) == np.asarray(s2)).all()


@pytest.mark.parametrize("beta", [0.1, 1.0, 5.0])
def test_pbit_kernel_beta_sweep(beta):
    m, s, h, w6, halos, par, active = make_inputs((8, 4, 4))
    m1, s1 = pbit_update_op(m, s, beta, par, h, w6, halos, bx=4,
                            impl="interpret")
    m2, s2 = pbit_brick_update_ref(m, s, beta, par, h, w6, halos)
    assert (np.asarray(m1) == np.asarray(m2)).all()


def test_pbit_kernel_respects_mask():
    m, s, h, w6, halos, par, active = make_inputs((8, 4, 4))
    frozen = jnp.zeros_like(par)
    m1, _ = pbit_update_op(m, s, 2.0, frozen, h, w6, halos, impl="interpret")
    assert (np.asarray(m1) == np.asarray(m)).all()


@pytest.mark.parametrize("shape,bx", [((8, 4, 4), 2), ((16, 8, 8), 8),
                                      ((6, 3, 5), 2)])
def test_energy_kernel_matches_ref(shape, bx):
    m, s, h, w6, halos, par, active = make_inputs(shape)
    e1 = brick_energy_op(m, active, h, w6, halos, bx=bx, impl="interpret")
    e2 = brick_energy_ref(m, active, h, w6, halos)
    np.testing.assert_allclose(float(e1), float(e2), rtol=1e-5, atol=1e-3)


def test_energy_kernel_active_mask():
    m, s, h, w6, halos, par, active = make_inputs((8, 4, 4))
    none = jnp.zeros_like(active)
    e = brick_energy_op(m, none, h, w6, halos, impl="interpret")
    assert float(e) == 0.0


def test_kernel_under_jit_and_grad_free():
    # the kernel composes under jit (as used inside shard_map scans)
    m, s, h, w6, halos, par, active = make_inputs((8, 4, 4))

    @jax.jit
    def two_phases(m, s):
        m, s = pbit_update_op(m, s, 1.0, par, h, w6, halos, impl="interpret")
        m, s = pbit_update_op(m, s, 1.0, 1 - par, h, w6, halos,
                              impl="interpret")
        return m, s
    m1, s1 = two_phases(m, s)
    mr, sr = pbit_brick_update_ref(m, s, 1.0, par, h, w6, halos)
    mr, sr = pbit_brick_update_ref(mr, sr, 1.0, 1 - par, h, w6, halos)
    assert (np.asarray(m1) == np.asarray(mr)).all()
