"""Training substrate: optimizer (fp32+int8), checkpointing, data, serve."""

import os
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models.lm import build_model
from repro.train.optimizer import (AdamW, q8_encode, q8_decode,
                                   clip_by_global_norm)
from repro.train.train_step import TrainState, make_train_step, sync_budget
from repro.train.data import MarkovLM, prefetch
from repro.train import checkpoint as ckpt
from repro.serve.serve_step import greedy_generate, cache_len_for


def test_q8_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    for shape in [(130,), (4, 257), (3, 5, 128)]:
        x = jnp.asarray(rng.normal(0, 2.0, shape).astype(np.float32))
        q, s = q8_encode(x)
        y = q8_decode(q, s, shape)
        blockmax = np.abs(np.asarray(x)).max()
        assert float(jnp.abs(y - x).max()) <= blockmax / 127.0 + 1e-6


def test_clip_by_global_norm():
    g = {"a": jnp.ones((10,)) * 3.0}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert abs(float(gn) - 3.0 * np.sqrt(10)) < 1e-4
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-4


@pytest.mark.parametrize("int8", [False, True])
def test_adamw_converges_quadratic(int8):
    opt = AdamW(lr=0.1, warmup=1, weight_decay=0.0, int8_state=int8)
    params = {"w": jnp.asarray([5.0, -3.0, 2.0])}
    st = opt.init(params)
    for _ in range(150):
        grads = {"w": params["w"]}          # d/dw (w^2/2)
        params, st = opt.update(grads, st, params)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_train_loss_decreases_both_optimizers():
    cfg = get_config("deepseek-7b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    for int8 in (False, True):
        opt = AdamW(lr=3e-3, warmup=5, int8_state=int8)
        st = TrainState(params=params, opt=opt.init(params))
        step = jax.jit(make_train_step(model, opt))
        data = MarkovLM(cfg.vocab, seed=1)
        losses = []
        for i, b in zip(range(25), data.batches(8, 32)):
            st, m = step(st, {k: jnp.asarray(v) for k, v in b.items()})
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] - 0.2, (int8, losses[0], losses[-1])


def test_grad_accum_equivalence():
    """Pre-split microbatch accumulation == single-batch gradients."""
    cfg = get_config("h2o-danube-1.8b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=1e-3, warmup=1)
    data = MarkovLM(cfg.vocab, seed=2)
    toks = data.sample(8, 32)
    b1 = {"tokens": jnp.asarray(toks), "targets": jnp.asarray(toks),
          "mask": jnp.ones((8, 32), jnp.int32)}
    b2 = jax.tree.map(lambda x: x.reshape(2, 4, 32), b1)
    s1 = TrainState(params=params, opt=opt.init(params))
    s2 = TrainState(params=params, opt=opt.init(params))
    s1, m1 = jax.jit(make_train_step(model, opt, grad_accum=1))(s1, b1)
    s2, m2 = jax.jit(make_train_step(model, opt, grad_accum=2))(s2, b2)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    d = max(float(jnp.abs(a - b).max()) for a, b in
            zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)))
    assert d < 1e-5


def test_checkpoint_roundtrip_and_retention(tmp_path):
    cfg = get_config("mamba2-370m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamW()
    st = TrainState(params=params, opt=opt.init(params))
    for step in (1, 2, 3, 4):
        ckpt.save(str(tmp_path), step, st, keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 4
    dirs = sorted(os.listdir(tmp_path))
    assert len([d for d in dirs if d.startswith("step_")]) == 2  # retention
    st2 = ckpt.restore(str(tmp_path), st)
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(st2)):
        assert (np.asarray(a) == np.asarray(b)).all()


def test_checkpoint_shape_mismatch_raises(tmp_path):
    st = {"w": jnp.zeros((4,))}
    ckpt.save(str(tmp_path), 1, st)
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), {"w": jnp.zeros((5,))})


def test_markov_data_learnable_structure():
    data = MarkovLM(64, seed=0)
    toks = data.sample(4, 256, seed=1)
    assert toks.shape == (4, 256) and toks.max() < 64
    # order-1 structure: conditional entropy < unigram entropy
    uni = np.bincount(toks.ravel(), minlength=64) + 1e-9
    uni = uni / uni.sum()
    H_uni = -(uni * np.log(uni)).sum()
    pair = np.zeros((64, 64)) + 1e-9
    for row in toks:
        np.add.at(pair, (row[:-1], row[1:]), 1)
    cond = pair / pair.sum(axis=1, keepdims=True)
    H_cond = -(pair / pair.sum() * np.log(cond)).sum()
    assert H_cond < H_uni - 0.3


def test_prefetch_order():
    it = prefetch(iter(range(10)), depth=3)
    assert list(it) == list(range(10))


def test_sync_budget_design_rule():
    # tiny model, fast link: sync every step; huge model, slow link: rarely
    assert sync_budget(1e6, 0.1, 50e9) == 1
    assert sync_budget(2 * 314e9, 0.5, 50e9) > 10


def test_serve_greedy_generate_all_cache_kinds():
    for name in ("h2o-danube-1.8b", "mamba2-370m", "jamba-v0.1-52b",
                 "seamless-m4t-medium"):
        cfg = get_config(name).reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        if cfg.encdec:
            batch = {"frames": jnp.ones((2, 8, cfg.d_model), jnp.float32),
                     "tokens": jnp.zeros((2, 4), jnp.int32)}
        else:
            batch = {"tokens": jnp.zeros((2, 8), jnp.int32)}
        out = greedy_generate(model, cfg, params, batch, max_new=4)
        assert out.shape == (2, 4)
        assert (np.asarray(out) >= 0).all()
        assert (np.asarray(out) < cfg.vocab_padded).all()


def test_cache_len_for_swa():
    cfg = get_config("h2o-danube-1.8b")
    assert cache_len_for(cfg, 524288) == 4096      # rolling window
    cfg2 = get_config("deepseek-7b")
    assert cache_len_for(cfg2, 32768) == 32768
