"""DSIM partitioned engine: shadow weights, staleness, CMFT, comm-cost."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.graph import ea3d, random_regular
from repro.core.coloring import lattice3d_coloring, greedy_coloring
from repro.core.partition import (slab_partition, brick_partition,
                                  greedy_partition, refine_partition,
                                  cut_edges, partition_sizes)
from repro.core.potts_partition import potts_partition, potts_energy
from repro.core.commcost import (boundary_matrix, ChainTopology, RingTopology,
                                 comm_cost, eta_threshold,
                                 best_chain_permutation,
                                 cut_distance_histogram)
from repro.core.dsim import build_partitioned, DSIMEngine
from repro.core.energy import local_fields, energy
from repro.core.annealing import ea_schedule
from repro.core.packing import pack_pm1, unpack_pm1
from repro.core.gibbs import GibbsEngine

L, K = 8, 4


@pytest.fixture(scope="module")
def setup():
    g = ea3d(L, seed=7)
    col = lattice3d_coloring(L)
    labels = slab_partition(L, K)
    prob = build_partitioned(g, col, labels, K)
    return g, col, labels, prob


def test_shadow_weights_fields_exact(setup):
    """With fresh ghosts, partitioned local fields == monolithic fields:
    proves shadow-weight duplication and ghost indexing are exact."""
    g, col, labels, prob = setup
    eng = DSIMEngine(prob, rng="philox")
    st = eng.init_state(seed=0)
    f_part = eng.local_fields_check(st)
    f_mono = local_fields(g, eng.global_spins(st))
    assert float(jnp.abs(f_part - f_mono).max()) == 0.0


def test_energy_scatter(setup):
    g, col, labels, prob = setup
    eng = DSIMEngine(prob, rng="philox")
    st = eng.init_state(seed=1)
    assert abs(float(eng.energy(st)) -
               float(energy(g, eng.global_spins(st)))) < 1e-4


def test_phase_sync_matches_monolithic_stats(setup):
    """sync='phase' is the exact limit: final-energy stats must be
    statistically indistinguishable from the monolithic engine."""
    g, col, labels, prob = setup
    sch = ea_schedule(400)
    part_E, mono_E = [], []
    for s in range(4):
        eng = DSIMEngine(prob, rng="philox")
        st = eng.init_state(seed=s)
        st, (_, Es) = eng.run_recorded(st, sch, [400], sync_every="phase")
        part_E.append(float(Es[-1]))
        me = GibbsEngine(g, col)
        ms = me.init_state(seed=s)
        ms, (Etr, _) = me.run_dense(ms, sch.beta_array())
        mono_E.append(float(Etr[-1]))
    assert abs(np.mean(part_E) - np.mean(mono_E)) / abs(np.mean(mono_E)) < 0.05


def test_staleness_degrades_quality(setup):
    """The paper's central claim at fixed sweep budget: more staleness
    (larger S, i.e. smaller eta) => worse energies; no-comm worst."""
    g, col, labels, prob = setup
    sch = ea_schedule(512)
    means = {}
    for sync in ["phase", 16, None]:
        vals = []
        for s in range(4):
            eng = DSIMEngine(prob, rng="philox")
            st = eng.init_state(seed=s)
            st, (_, Es) = eng.run_recorded(st, sch, [512], sync_every=sync)
            vals.append(float(Es[-1]))
        means[sync] = np.mean(vals)
    assert means["phase"] <= means[16] + 2
    assert means[16] < means[None]


def test_cmft_runs_and_improves_with_frequency(setup):
    g, col, labels, prob = setup
    sch = ea_schedule(512)
    out = {}
    for S in (2, 64):
        vals = []
        for s in range(3):
            eng = DSIMEngine(prob, rng="philox", mode="cmft")
            st = eng.init_state(seed=s)
            st, (_, Es) = eng.run_recorded(st, sch, [512], sync_every=S)
            vals.append(float(Es[-1]))
        out[S] = np.mean(vals)
    assert out[2] <= out[64] + 2  # frequent exchange at least as good


def test_partitioners():
    g = ea3d(8, seed=1)
    idx, w = np.asarray(g.idx), np.asarray(g.w)
    lab = slab_partition(8, 4)
    assert (partition_sizes(lab, 4) == 128).all()
    bl = brick_partition((8, 8, 8), (2, 2, 2))
    assert (partition_sizes(bl, 8) == 64).all()
    gp = greedy_partition(idx, w, 4, seed=0)
    sizes = partition_sizes(gp, 4)
    assert sizes.min() > 0.5 * sizes.max()
    ref = refine_partition(idx, w, gp, 4)
    assert cut_edges(idx, w, ref) <= cut_edges(idx, w, gp)


def test_potts_partition_concentrates_distance():
    g = ea3d(10, seed=0)
    idx, w = np.asarray(g.idx), np.asarray(g.w)
    lab = potts_partition(idx, w, 4, seed=0)
    sizes = partition_sizes(lab, 4)
    assert sizes.min() > 0.7 * (g.n / 4)
    hist = cut_distance_histogram(idx, w, lab, K=4)
    assert hist[0] > 0.7  # paper Fig. S5: cut concentrated at d=1
    # potts energy of the result should beat a random labeling
    rnd = np.random.default_rng(0).integers(0, 4, g.n).astype(np.int32)
    assert potts_energy(idx, w, lab, 4) < potts_energy(idx, w, rnd, 4)


def test_commcost_reproduces_paper_S4_6():
    """b_46=660, d=2, P=min(26,54)=26, N_color=3 => eta_thr ~ 305."""
    cmax = 660 * 2 / 26
    assert abs(eta_threshold(3, cmax) - 304.6) < 1.0


def test_commcost_machinery():
    g = ea3d(8, seed=3)
    idx, w = np.asarray(g.idx), np.asarray(g.w)
    lab = slab_partition(8, 4)
    b = boundary_matrix(idx, w, lab, 4)
    # slabs: only adjacent partitions share boundaries
    assert b[0, 2] == 0 and b[0, 3] == 0
    assert b[0, 1] == 64  # one full 8x8 plane
    topo = ChainTopology(pins=[32, 16, 32])
    cc = comm_cost(b, topo)
    assert cc.c_max >= cc.c_tot / 3
    order, score = best_chain_permutation(b, topo)
    ident = comm_cost(b, topo, np.arange(4)).c_tot
    assert score <= ident + 1e-9
    ring = RingTopology(k=4, pins_per_link=32)
    assert ring.hop(0, 3) == 1  # wraps


def test_bit_packing_roundtrip():
    rng = np.random.default_rng(0)
    for n in (8, 24, 128):
        x = jnp.asarray(rng.choice([-1, 1], size=(3, n)).astype(np.int8))
        p = pack_pm1(x)
        assert p.shape == (3, n // 8) and p.dtype == jnp.uint8
        assert (unpack_pm1(p, n) == x).all()


def test_disconnected_control_keeps_local_quality(setup):
    """Paper S7: with links cut, each partition still anneals its local
    subgraph correctly (local energies drop), proving the slope loss in
    coupled runs comes from staleness, not local update errors."""
    g, col, labels, prob = setup
    eng = DSIMEngine(prob, rng="lfsr")
    st = eng.init_state(seed=0)
    E0 = float(eng.energy(st))
    st, (_, Es) = eng.run_recorded(st, ea_schedule(512), [512],
                                   sync_every=None)
    assert float(Es[-1]) < 0.5 * E0 if E0 < 0 else float(Es[-1]) < E0
