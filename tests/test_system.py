"""End-to-end system behaviour: the paper's claims at smoke scale, plus the
launch-layer pieces that run in-process (config registry, cell enumeration)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.graph import ea3d
from repro.core.coloring import lattice3d_coloring
from repro.core.partition import slab_partition
from repro.core.dsim import build_partitioned, DSIMEngine
from repro.core.gibbs import GibbsEngine
from repro.core.annealing import ea_schedule
from repro.core.analysis import fit_kappa, time_to_target
from repro.problems.ea3d import GroundStore
from repro.configs import list_configs, get_config
from repro.configs.base import SHAPES


def test_eta_monotonicity_smoke():
    """Fixed sweep budget: mean final energy degrades with staleness
    (Fig. 2 at smoke scale)."""
    L, K = 8, 4
    g = ea3d(L, seed=11)
    col = lattice3d_coloring(L)
    prob = build_partitioned(g, col, slab_partition(L, K), K)
    sch = ea_schedule(768)
    means = {}
    for sync in ["phase", 8, 64, None]:
        vals = []
        for s in range(5):
            eng = DSIMEngine(prob, rng="philox")
            st = eng.init_state(seed=100 + s)
            st, (_, Es) = eng.run_recorded(st, sch, [768], sync_every=sync)
            vals.append(float(Es[-1]))
        means[sync] = float(np.mean(vals))
    assert means["phase"] <= means[64] + 3
    assert means[8] <= means[None] + 3
    assert means["phase"] < means[None]


def test_power_law_decay_visible():
    """Residual energy decays ~ power law over the mid window (Fig. 3a)."""
    L = 8
    g = ea3d(L, seed=12)
    col = lattice3d_coloring(L)
    eng = GibbsEngine(g, col)
    # putative ground from a longer run (paper Methods protocol)
    stg = eng.init_state(seed=0)
    stg, (Etr, _) = eng.run_dense(stg, ea_schedule(4000).beta_array())
    Eg = float(np.asarray(Etr).min())
    pts = list(np.unique(np.geomspace(1, 1000, 24).astype(int)))
    runs = []
    for s in range(4):
        st = eng.init_state(seed=s + 1)
        st, Es = eng.run_recorded(st, ea_schedule(1000), pts)
        runs.append((np.asarray(Es) - Eg) / g.n)
    rho = np.mean(runs, axis=0)
    f = fit_kappa(np.asarray(pts), rho, window=(3, 1000))
    assert 0.05 < f.kappa < 1.2
    assert f.r2 > 0.7


def test_throughput_accuracy_tradeoff():
    """Stale mode with a throughput multiplier reaches easy targets first
    (the Fig. 4/5 time-to-target logic)."""
    L, K = 8, 4
    g = ea3d(L, seed=13)
    col = lattice3d_coloring(L)
    prob = build_partitioned(g, col, slab_partition(L, K), K)
    pts = sorted(set(np.geomspace(4, 512, 10).astype(int)))
    sch = ea_schedule(512)

    def trace(sync, speedup):
        rhos = []
        for s in range(4):
            eng = DSIMEngine(prob, rng="philox")
            st = eng.init_state(seed=s)
            st, (ts, Es) = eng.run_recorded(st, sch, pts, sync_every=sync)
            rhos.append(np.asarray(Es))
        return np.asarray(ts) / speedup, np.mean(rhos, axis=0)

    t_exact, E_exact = trace("phase", 1.0)
    t_fast, E_fast = trace(64, 8.0)
    Eg = min(E_exact.min(), E_fast.min()) - 1
    # target = where the exact trace sits mid-run: reachable by both, but
    # not before either mode's first record point (the stale mode records
    # only every S sweeps, so ultra-easy targets are unmeasurable for it)
    easy = float((E_exact[len(E_exact) // 2] - Eg) / g.n)
    tt_exact = time_to_target(t_exact, (E_exact - Eg) / g.n, easy)
    tt_fast = time_to_target(t_fast, (E_fast - Eg) / g.n, easy)
    assert np.isfinite(tt_fast)
    assert tt_fast < tt_exact


def test_all_cells_enumerate_correctly():
    cfgs = list_configs()
    lm = {n: c for n, c in cfgs.items() if c.family != "ising"}
    assert len(lm) == 10
    long_capable = sorted(n for n, c in lm.items() if c.long_context)
    assert long_capable == ["h2o-danube-1.8b", "jamba-v0.1-52b",
                            "mamba2-370m"]
    cells = sum(len(c.shapes()) for c in lm.values())
    assert cells == 10 * 3 + 3
    assert "ea3d-1m" in cfgs


def test_decode_cells_are_serve_shapes():
    for name in ("decode_32k", "long_500k"):
        assert SHAPES[name].kind == "decode"
    assert SHAPES["train_4k"].kind == "train"
    assert SHAPES["prefill_32k"].kind == "prefill"
