"""Property tests for core.packing: the 1-bit wire/site format and the
32-lane multi-spin word format.

Previously only exercised indirectly through dsim_dist's boundary
all-gather; these pin the round-trip contract directly — arbitrary (incl.
non-multiple-of-32 and non-multiple-of-8) lengths via pad_to_multiple,
empty inputs, and dtype stability.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.packing import (LANE_WIDTH, pack_lanes, pack_pm1,
                                pad_to_multiple, unpack_lanes, unpack_pm1)

RNG = np.random.default_rng(5)


# -- site packing (pack_pm1 / unpack_pm1) -------------------------------------

@pytest.mark.parametrize("n", [1, 5, 7, 8, 9, 24, 31, 33, 100, 257])
def test_pack_pm1_round_trip_any_length(n):
    """Non-multiple-of-8 (and of-32) lengths round-trip through the
    pad-pack-unpack pipeline the halo exchange uses."""
    x = RNG.choice([-1, 1], size=n).astype(np.int8)
    npad = pad_to_multiple(n, 8)
    padded = np.pad(x, (0, npad - n), constant_values=1)
    packed = pack_pm1(jnp.asarray(padded))
    assert packed.dtype == jnp.uint8
    assert packed.shape == (npad // 8,)
    out = unpack_pm1(packed, n)
    assert out.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(out), x)


def test_pack_pm1_empty():
    packed = pack_pm1(jnp.zeros((0,), jnp.int8))
    assert packed.shape == (0,) and packed.dtype == jnp.uint8
    out = unpack_pm1(packed, 0)
    assert out.shape == (0,) and out.dtype == jnp.int8


def test_pack_pm1_leading_dims_and_reject_ragged():
    x = jnp.asarray(RNG.choice([-1, 1], size=(3, 2, 16)).astype(np.int8))
    out = unpack_pm1(pack_pm1(x), 16)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))
    with pytest.raises(ValueError):
        pack_pm1(jnp.zeros((4, 13), jnp.int8))


def test_pack_pm1_dtype_stability():
    """int32 +-1 input still packs to uint8 and unpacks to int8 — the
    wire dtypes never follow the caller's."""
    x = jnp.asarray(RNG.choice([-1, 1], size=24).astype(np.int32))
    packed = pack_pm1(x)
    assert packed.dtype == jnp.uint8
    assert unpack_pm1(packed, 24).dtype == jnp.int8


# -- lane packing (pack_lanes / unpack_lanes) ---------------------------------

@pytest.mark.parametrize("R", [1, 2, 7, 31, 32])
def test_pack_lanes_round_trip(R):
    x = RNG.choice([-1, 1], size=(R, 4, 3, 5)).astype(np.int8)
    w = pack_lanes(jnp.asarray(x))
    assert w.dtype == jnp.uint32
    assert w.shape == (4, 3, 5)
    out = unpack_lanes(w, R)
    assert out.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(out), x)


def test_pack_lanes_empty_sites():
    w = pack_lanes(jnp.zeros((4, 0), jnp.int8))
    assert w.shape == (0,) and w.dtype == jnp.uint32
    out = unpack_lanes(w, 4)
    assert out.shape == (4, 0) and out.dtype == jnp.int8


def test_pack_lanes_unused_lanes_zero():
    """Lanes >= R pack to 0 bits — the word tail is inert, so growing the
    lane count later never reinterprets old words."""
    x = jnp.asarray(np.ones((3, 8), np.int8))
    w = np.asarray(pack_lanes(x))
    assert (w == 0b111).all()


def test_pack_lanes_rejects_too_many():
    with pytest.raises(ValueError):
        pack_lanes(jnp.ones((LANE_WIDTH + 1, 4), jnp.int8))
    with pytest.raises(ValueError):
        unpack_lanes(jnp.zeros((4,), jnp.uint32), LANE_WIDTH + 1)


def test_pack_lanes_lane_bit_identity():
    """Bit r of every word is exactly lane r's spin sign."""
    R = 9
    x = RNG.choice([-1, 1], size=(R, 17)).astype(np.int8)
    w = np.asarray(pack_lanes(jnp.asarray(x)))
    for r in range(R):
        np.testing.assert_array_equal((w >> r) & 1, (x[r] > 0))
