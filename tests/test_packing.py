"""Property tests for core.packing: the 1-bit wire/site format and the
32-lane multi-spin word format.

Previously only exercised indirectly through dsim_dist's boundary
all-gather; these pin the round-trip contract directly — arbitrary (incl.
non-multiple-of-32 and non-multiple-of-8) lengths via pad_to_multiple,
empty inputs, and dtype stability.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.packing import (LANE_WIDTH, lane_permute, lane_swap,
                                pack_lanes, pack_pm1, pad_to_multiple,
                                unpack_lanes, unpack_pm1)

RNG = np.random.default_rng(5)


# -- site packing (pack_pm1 / unpack_pm1) -------------------------------------

@pytest.mark.parametrize("n", [1, 5, 7, 8, 9, 24, 31, 33, 100, 257])
def test_pack_pm1_round_trip_any_length(n):
    """Non-multiple-of-8 (and of-32) lengths round-trip through the
    pad-pack-unpack pipeline the halo exchange uses."""
    x = RNG.choice([-1, 1], size=n).astype(np.int8)
    npad = pad_to_multiple(n, 8)
    padded = np.pad(x, (0, npad - n), constant_values=1)
    packed = pack_pm1(jnp.asarray(padded))
    assert packed.dtype == jnp.uint8
    assert packed.shape == (npad // 8,)
    out = unpack_pm1(packed, n)
    assert out.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(out), x)


def test_pack_pm1_empty():
    packed = pack_pm1(jnp.zeros((0,), jnp.int8))
    assert packed.shape == (0,) and packed.dtype == jnp.uint8
    out = unpack_pm1(packed, 0)
    assert out.shape == (0,) and out.dtype == jnp.int8


def test_pack_pm1_leading_dims_and_reject_ragged():
    x = jnp.asarray(RNG.choice([-1, 1], size=(3, 2, 16)).astype(np.int8))
    out = unpack_pm1(pack_pm1(x), 16)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))
    with pytest.raises(ValueError):
        pack_pm1(jnp.zeros((4, 13), jnp.int8))


def test_pack_pm1_dtype_stability():
    """int32 +-1 input still packs to uint8 and unpacks to int8 — the
    wire dtypes never follow the caller's."""
    x = jnp.asarray(RNG.choice([-1, 1], size=24).astype(np.int32))
    packed = pack_pm1(x)
    assert packed.dtype == jnp.uint8
    assert unpack_pm1(packed, 24).dtype == jnp.int8


# -- lane packing (pack_lanes / unpack_lanes) ---------------------------------

@pytest.mark.parametrize("R", [1, 2, 7, 31, 32])
def test_pack_lanes_round_trip(R):
    x = RNG.choice([-1, 1], size=(R, 4, 3, 5)).astype(np.int8)
    w = pack_lanes(jnp.asarray(x))
    assert w.dtype == jnp.uint32
    assert w.shape == (4, 3, 5)
    out = unpack_lanes(w, R)
    assert out.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(out), x)


def test_pack_lanes_empty_sites():
    w = pack_lanes(jnp.zeros((4, 0), jnp.int8))
    assert w.shape == (0,) and w.dtype == jnp.uint32
    out = unpack_lanes(w, 4)
    assert out.shape == (4, 0) and out.dtype == jnp.int8


def test_pack_lanes_unused_lanes_zero():
    """Lanes >= R pack to 0 bits — the word tail is inert, so growing the
    lane count later never reinterprets old words."""
    x = jnp.asarray(np.ones((3, 8), np.int8))
    w = np.asarray(pack_lanes(x))
    assert (w == 0b111).all()


def test_pack_lanes_rejects_too_many():
    with pytest.raises(ValueError):
        pack_lanes(jnp.ones((LANE_WIDTH + 1, 4), jnp.int8))
    with pytest.raises(ValueError):
        unpack_lanes(jnp.zeros((4,), jnp.uint32), LANE_WIDTH + 1)


def test_pack_lanes_lane_bit_identity():
    """Bit r of every word is exactly lane r's spin sign."""
    R = 9
    x = RNG.choice([-1, 1], size=(R, 17)).astype(np.int8)
    w = np.asarray(pack_lanes(jnp.asarray(x)))
    for r in range(R):
        np.testing.assert_array_equal((w >> r) & 1, (x[r] > 0))


# -- lane permutation (lane_permute / lane_swap) ------------------------------
# the replica-exchange swap move of the packed tempering ladder: one bit
# gather/scatter applied to every word

@pytest.mark.parametrize("L", [1, 2, 7, 31, 32])
def test_lane_permute_matches_unpacked_gather(L):
    """lane_permute on words == the same permutation on unpacked lanes."""
    x = RNG.choice([-1, 1], size=(L, 5, 3)).astype(np.int8)
    perm = RNG.permutation(L)
    w = pack_lanes(jnp.asarray(x))
    out = unpack_lanes(lane_permute(w, perm), L)
    np.testing.assert_array_equal(np.asarray(out), x[perm])


@pytest.mark.parametrize("L", [1, 6, 32])
def test_lane_permute_inverse_round_trip(L):
    """Applying a permutation then its inverse restores every word (on the
    live lanes; lanes >= L are cleared by convention)."""
    x = RNG.choice([-1, 1], size=(L, 11)).astype(np.int8)
    w = pack_lanes(jnp.asarray(x))
    perm = RNG.permutation(L)
    inv = np.argsort(perm)
    back = lane_permute(lane_permute(w, perm), inv)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(w))


def test_lane_permute_identity_clears_dead_lanes():
    """The identity permutation of L lanes zeroes bits >= L — the packed
    convention that keeps unused lanes inert."""
    w = jnp.full((4,), 0xFFFFFFFF, jnp.uint32)
    out = np.asarray(lane_permute(w, np.arange(5)))
    assert (out == 0b11111).all()


def test_lane_permute_rejects_bad_width():
    with pytest.raises(ValueError):
        lane_permute(jnp.zeros((3,), jnp.uint32), np.arange(LANE_WIDTH + 1))
    with pytest.raises(ValueError):
        lane_permute(jnp.zeros((3,), jnp.uint32), np.arange(0))


def test_lane_swap_is_transposition():
    """lane_swap(i, j) == lane_permute with the (i j) transposition on the
    live lanes, and is an involution (swap twice = identity)."""
    L = 16
    x = RNG.choice([-1, 1], size=(L, 9)).astype(np.int8)
    w = pack_lanes(jnp.asarray(x))
    i, j = 3, 12
    perm = np.arange(L)
    perm[[i, j]] = perm[[j, i]]
    np.testing.assert_array_equal(np.asarray(lane_swap(w, i, j)),
                                  np.asarray(lane_permute(w, perm)))
    np.testing.assert_array_equal(np.asarray(lane_swap(lane_swap(w, i, j),
                                                       i, j)),
                                  np.asarray(w))


def test_lane_swap_accept_gated():
    """A False accept is a no-op; a per-site accept vector swaps exactly
    the accepted sites (the Metropolis gate of a packed exchange pass)."""
    L = 8
    x = RNG.choice([-1, 1], size=(L, 10)).astype(np.int8)
    w = pack_lanes(jnp.asarray(x))
    np.testing.assert_array_equal(
        np.asarray(lane_swap(w, 1, 5, accept=jnp.bool_(False))),
        np.asarray(w))
    acc = jnp.asarray(RNG.random(10) < 0.5)
    out = unpack_lanes(lane_swap(w, 1, 5, accept=acc), L)
    want = x.copy()
    accn = np.asarray(acc)
    want[1, accn], want[5, accn] = x[5, accn], x[1, accn]
    np.testing.assert_array_equal(np.asarray(out), want)
