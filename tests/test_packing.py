"""Property tests for core.packing: the 1-bit wire/site format and the
multi-word lane fabric (W stacked uint32 word planes, 32 lanes each).

Previously only exercised indirectly through dsim_dist's boundary
all-gather; these pin the round-trip contract directly — arbitrary (incl.
non-multiple-of-32 and non-multiple-of-8) lengths via pad_to_multiple,
empty inputs, dtype stability, word-straddling lane counts, cross-word
permutations, and the dead-lane (last-word tail) masking convention.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.packing import (LANE_WIDTH, MAX_LANE_WORDS, lane_permute,
                                lane_swap, lane_words, pack_lanes, pack_pm1,
                                pad_to_multiple, unpack_lanes, unpack_pm1)

RNG = np.random.default_rng(5)


# -- site packing (pack_pm1 / unpack_pm1) -------------------------------------

@pytest.mark.parametrize("n", [1, 5, 7, 8, 9, 24, 31, 33, 100, 257])
def test_pack_pm1_round_trip_any_length(n):
    """Non-multiple-of-8 (and of-32) lengths round-trip through the
    pad-pack-unpack pipeline the halo exchange uses."""
    x = RNG.choice([-1, 1], size=n).astype(np.int8)
    npad = pad_to_multiple(n, 8)
    padded = np.pad(x, (0, npad - n), constant_values=1)
    packed = pack_pm1(jnp.asarray(padded))
    assert packed.dtype == jnp.uint8
    assert packed.shape == (npad // 8,)
    out = unpack_pm1(packed, n)
    assert out.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(out), x)


def test_pack_pm1_empty():
    packed = pack_pm1(jnp.zeros((0,), jnp.int8))
    assert packed.shape == (0,) and packed.dtype == jnp.uint8
    out = unpack_pm1(packed, 0)
    assert out.shape == (0,) and out.dtype == jnp.int8


def test_pack_pm1_leading_dims_and_reject_ragged():
    x = jnp.asarray(RNG.choice([-1, 1], size=(3, 2, 16)).astype(np.int8))
    out = unpack_pm1(pack_pm1(x), 16)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))
    with pytest.raises(ValueError):
        pack_pm1(jnp.zeros((4, 13), jnp.int8))


def test_pack_pm1_dtype_stability():
    """int32 +-1 input still packs to uint8 and unpacks to int8 — the
    wire dtypes never follow the caller's."""
    x = jnp.asarray(RNG.choice([-1, 1], size=24).astype(np.int32))
    packed = pack_pm1(x)
    assert packed.dtype == jnp.uint8
    assert unpack_pm1(packed, 24).dtype == jnp.int8


# -- lane packing (pack_lanes / unpack_lanes) ---------------------------------

@pytest.mark.parametrize("R", [1, 2, 7, 31, 32, 33, 64, 100])
def test_pack_lanes_round_trip(R):
    """Round trip at every word-boundary regime: sub-word, exactly one
    word, straddling into a second word, exactly two, and a ragged
    four-word count."""
    x = RNG.choice([-1, 1], size=(R, 4, 3, 5)).astype(np.int8)
    w = pack_lanes(jnp.asarray(x))
    assert w.dtype == jnp.uint32
    assert w.shape == (lane_words(R), 4, 3, 5)
    out = unpack_lanes(w, R)
    assert out.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(out), x)


def test_pack_lanes_empty_sites():
    w = pack_lanes(jnp.zeros((4, 0), jnp.int8))
    assert w.shape == (1, 0) and w.dtype == jnp.uint32
    out = unpack_lanes(w, 4)
    assert out.shape == (4, 0) and out.dtype == jnp.int8


def test_pack_lanes_unused_lanes_zero():
    """Lanes >= R pack to 0 bits — confined to the LAST word plane, so the
    word tail is inert and growing the lane count later never reinterprets
    old words."""
    x = jnp.asarray(np.ones((3, 8), np.int8))
    w = np.asarray(pack_lanes(x))
    assert w.shape == (1, 8) and (w == 0b111).all()
    x2 = jnp.asarray(np.ones((35, 8), np.int8))
    w2 = np.asarray(pack_lanes(x2))
    assert w2.shape == (2, 8)
    assert (w2[0] == 0xFFFFFFFF).all()      # full word: every lane live
    assert (w2[1] == 0b111).all()           # tail word: 3 live lanes only


def test_pack_lanes_rejects_too_many():
    cap = MAX_LANE_WORDS * LANE_WIDTH
    with pytest.raises(ValueError):
        pack_lanes(jnp.ones((cap + 1, 4), jnp.int8))
    with pytest.raises(ValueError):
        unpack_lanes(jnp.zeros((MAX_LANE_WORDS + 1, 4), jnp.uint32), cap + 1)


def test_unpack_lanes_rejects_word_count_mismatch():
    """The word axis is load-bearing: unpacking R lanes from the wrong
    number of word planes is a contract violation, not a silent
    truncation."""
    with pytest.raises(ValueError):
        unpack_lanes(jnp.zeros((1, 4), jnp.uint32), 33)
    with pytest.raises(ValueError):
        unpack_lanes(jnp.zeros((2, 4), jnp.uint32), 32)


def test_pack_lanes_lane_bit_identity():
    """Bit r%32 of word plane r//32 is exactly lane r's spin sign — at a
    word-straddling lane count."""
    R = 41
    x = RNG.choice([-1, 1], size=(R, 17)).astype(np.int8)
    w = np.asarray(pack_lanes(jnp.asarray(x)))
    for r in range(R):
        np.testing.assert_array_equal(
            (w[r // LANE_WIDTH] >> (r % LANE_WIDTH)) & 1, (x[r] > 0))


def test_pack_lanes_prefix_stability_across_word_counts():
    """The first R lanes pack identically whether or not more lanes (and
    more word planes) follow — the property that lets the scheduler pad a
    pack up to a word multiple without touching tenant chains."""
    x = RNG.choice([-1, 1], size=(100, 6)).astype(np.int8)
    w_all = np.asarray(pack_lanes(jnp.asarray(x)))
    for R in (31, 32, 33, 64):
        w_r = np.asarray(pack_lanes(jnp.asarray(x[:R])))
        W = lane_words(R)
        full = (R // LANE_WIDTH)        # word planes with every lane live
        np.testing.assert_array_equal(w_r[:full], w_all[:full])
        if full < W:                    # tail word: live-lane bits only
            tail_mask = np.uint32((1 << (R - full * LANE_WIDTH)) - 1)
            np.testing.assert_array_equal(w_r[full],
                                          w_all[full] & tail_mask)


# -- lane permutation (lane_permute / lane_swap) ------------------------------
# the replica-exchange swap move of the packed tempering ladder: one bit
# gather/scatter applied to every site's word planes, cross-word moves
# included

@pytest.mark.parametrize("L", [1, 2, 7, 31, 32, 33, 64, 100])
def test_lane_permute_matches_unpacked_gather(L):
    """lane_permute on word planes == the same permutation on unpacked
    lanes, including permutations that move lanes across word planes."""
    x = RNG.choice([-1, 1], size=(L, 5, 3)).astype(np.int8)
    perm = RNG.permutation(L)
    w = pack_lanes(jnp.asarray(x))
    out = unpack_lanes(lane_permute(w, perm), L)
    np.testing.assert_array_equal(np.asarray(out), x[perm])


@pytest.mark.parametrize("L", [1, 6, 32, 65])
def test_lane_permute_inverse_round_trip(L):
    """Applying a permutation then its inverse restores every word plane
    (on the live lanes; lanes >= L are cleared by convention)."""
    x = RNG.choice([-1, 1], size=(L, 11)).astype(np.int8)
    w = pack_lanes(jnp.asarray(x))
    perm = RNG.permutation(L)
    inv = np.argsort(perm)
    back = lane_permute(lane_permute(w, perm), inv)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(w))


def test_lane_permute_identity_clears_dead_lanes():
    """The identity permutation of L lanes zeroes bits >= L in the last
    word plane — the packed convention that keeps unused lanes inert."""
    w = jnp.full((1, 4), 0xFFFFFFFF, jnp.uint32)
    out = np.asarray(lane_permute(w, np.arange(5)))
    assert (out == 0b11111).all()
    # multi-word: dead lanes live only in the LAST plane's tail
    w2 = jnp.full((2, 4), 0xFFFFFFFF, jnp.uint32)
    out2 = np.asarray(lane_permute(w2, np.arange(40)))
    assert (out2[0] == 0xFFFFFFFF).all()
    assert (out2[1] == 0xFF).all()


def test_lane_permute_rejects_bad_width():
    cap = MAX_LANE_WORDS * LANE_WIDTH
    with pytest.raises(ValueError):
        lane_permute(jnp.zeros((1, 3), jnp.uint32), np.arange(cap + 1))
    with pytest.raises(ValueError):
        lane_permute(jnp.zeros((1, 3), jnp.uint32), np.arange(0))


@pytest.mark.parametrize("L,i,j", [(16, 3, 12), (40, 3, 36), (64, 0, 63)])
def test_lane_swap_is_transposition(L, i, j):
    """lane_swap(i, j) == lane_permute with the (i j) transposition on the
    live lanes, and is an involution (swap twice = identity) — including
    transpositions across word planes."""
    x = RNG.choice([-1, 1], size=(L, 9)).astype(np.int8)
    w = pack_lanes(jnp.asarray(x))
    perm = np.arange(L)
    perm[[i, j]] = perm[[j, i]]
    np.testing.assert_array_equal(np.asarray(lane_swap(w, i, j)),
                                  np.asarray(lane_permute(w, perm)))
    np.testing.assert_array_equal(np.asarray(lane_swap(lane_swap(w, i, j),
                                                       i, j)),
                                  np.asarray(w))


@pytest.mark.parametrize("L,i,j", [(8, 1, 5), (48, 1, 37)])
def test_lane_swap_accept_gated(L, i, j):
    """A False accept is a no-op; a per-site accept vector swaps exactly
    the accepted sites (the Metropolis gate of a packed exchange pass) —
    cross-word pairs included."""
    x = RNG.choice([-1, 1], size=(L, 10)).astype(np.int8)
    w = pack_lanes(jnp.asarray(x))
    np.testing.assert_array_equal(
        np.asarray(lane_swap(w, i, j, accept=jnp.bool_(False))),
        np.asarray(w))
    acc = jnp.asarray(RNG.random(10) < 0.5)
    out = unpack_lanes(lane_swap(w, i, j, accept=acc), L)
    want = x.copy()
    accn = np.asarray(acc)
    want[i, accn], want[j, accn] = x[j, accn], x[i, accn]
    np.testing.assert_array_equal(np.asarray(out), want)
