"""Sampling server subsystem: queue lifecycle, replica packing, engine
pool, streaming, preemption, cancellation, admission control."""

import threading

import numpy as np
import pytest

from repro.core.coloring import lattice3d_coloring
from repro.core.graph import ea3d
from repro.serve import EnginePool, QueueFull, SampleServer
from repro.serve.jobs import problem_fingerprint, schedule_fingerprint
from repro.core.annealing import constant_schedule, ea_schedule

L_A, L_B = 5, 6
SW = 64


@pytest.fixture(scope="module")
def problems():
    return {
        "pa": (ea3d(L_A, seed=1), lattice3d_coloring(L_A)),
        "pb": (ea3d(L_B, seed=2), lattice3d_coloring(L_B)),
    }


def _server(problems, **kw):
    srv = SampleServer(**kw)
    for name, (g, col) in problems.items():
        srv.register_problem(name, graph=g, coloring=col, rng="lfsr")
    srv.register_problem("lat", L=L_B, seed=3)
    return srv


def _check_payload(r, g_n, replicas):
    assert r["status"] == "done"
    e = r["energies"]
    assert e.ndim == 2 and e.shape[1] == replicas and len(e) >= 1
    assert np.isfinite(e).all()
    assert r["best_energy"] == pytest.approx(float(e.min()))
    assert r["best_spins"] is not None and r["best_spins"].shape == (g_n,)
    assert set(np.unique(r["best_spins"])) <= {-1, 1}
    assert r["flips"] > 0 and r["wall_s"] >= 0 and r["device_s"] > 0
    assert r["sweeps_done"] == r["total_sweeps"]


# -- the acceptance workload: concurrent mixed jobs, packing observable -------

def test_mixed_concurrent_workload_packs(problems):
    """>= 8 in-flight jobs across 2 problems and 2 engines: all complete,
    payloads validate, and compatible requests shared engine calls."""
    srv = _server(problems, max_replicas_per_call=16)
    ids = []
    for k in range(4):
        ids.append(srv.submit("pa", engine="gibbs", sweeps=SW, replicas=2,
                              seed=k))
    for k in range(2):
        ids.append(srv.submit("pb", engine="gibbs", sweeps=SW, replicas=2,
                              seed=k))
    for k in range(2):
        ids.append(srv.submit("pa", engine="dsim", sweeps=SW, replicas=2,
                              seed=k, sync_every=4))
    assert srv.stats()["queue_depth"] == 8          # all in flight
    srv.drain()
    for jid, name in zip(ids, ["pa"] * 4 + ["pb"] * 2 + ["pa"] * 2):
        _check_payload(srv.result(jid), problems[name][0].n, 2)
    s = srv.stats()
    assert s["completed"] == 8
    # the packing claim: batched engine calls < submitted jobs
    assert s["engine_calls"] == 3 < s["submitted"]
    assert s["scheduler"]["jobs_packed"] == 8


def test_packed_job_bitwise_equals_solo(problems):
    """A tenant's trajectory is independent of its batch-mates: the same
    job packed with strangers reproduces its solo run bitwise."""
    packed = _server(problems, max_replicas_per_call=16)
    ids = [packed.submit("pa", engine="gibbs", sweeps=SW, replicas=2,
                         seed=s) for s in (9, 10, 11)]
    packed.drain()
    assert packed.stats()["engine_calls"] == 1
    solo = _server(problems, pack=False)
    sid = solo.submit("pa", engine="gibbs", sweeps=SW, replicas=2, seed=9)
    solo.drain()
    rp, rs = packed.result(ids[0]), solo.result(sid)
    assert np.array_equal(rp["energies"], rs["energies"])
    assert np.array_equal(rp["best_spins"], rs["best_spins"])
    assert rp["flips"] == rs["flips"]


def test_packed_trace_isolated_from_batch_mates(problems):
    """A tenant only gets its own record points: packing with a mate that
    requested different points must not change the tenant's trace."""
    packed = _server(problems, max_replicas_per_call=16)
    a = packed.submit("pa", engine="gibbs", sweeps=SW, replicas=2, seed=9,
                      record_points=[SW // 2, SW])
    packed.submit("pa", engine="gibbs", sweeps=SW, replicas=2, seed=10,
                  record_points=[SW // 4])
    packed.drain()
    assert packed.stats()["engine_calls"] == 1
    solo = _server(problems, pack=False)
    s = solo.submit("pa", engine="gibbs", sweeps=SW, replicas=2, seed=9,
                    record_points=[SW // 2, SW])
    solo.drain()
    rp, rs = packed.result(a), solo.result(s)
    assert np.array_equal(rp["times"], rs["times"])
    assert np.array_equal(rp["energies"], rs["energies"])


def test_pow2_padding_respects_replica_cap(problems):
    """Padding never pushes the executed width past max_replicas_per_call
    (the cap is sized to the device, e.g. memory)."""
    srv = _server(problems, max_replicas_per_call=12)
    for s in range(6):
        srv.submit("pa", engine="gibbs", sweeps=SW, replicas=2, seed=s)
    assert srv.pump()                        # forms + starts the batch
    batches = srv._batches
    assert len(batches) == 1 and batches[0].r_exec == 12  # not padded to 16
    srv.drain()
    assert srv.stats()["completed"] == 6


def test_terminal_jobs_evicted_beyond_retention(problems):
    srv = _server(problems, retain_jobs=2)
    ids = [srv.submit("pa", engine="gibbs", sweeps=SW, seed=s)
           for s in range(3)]
    srv.drain()
    assert srv.result(ids[-1])["status"] == "done"
    with pytest.raises(KeyError):
        srv.poll(ids[0])                     # oldest terminal job evicted


def test_sync_every_validated_at_submit(problems):
    srv = _server(problems)
    with pytest.raises(ValueError, match="sync_every"):
        srv.submit("pa", engine="dsim", sweeps=SW, sync_every=0)
    with pytest.raises(ValueError, match="sync_every"):
        srv.submit("pa", engine="dsim", sweeps=4, sync_every=8)


def test_prewarm_wait_surfaces_build_errors(problems):
    srv = SampleServer()
    g, col = problems["pa"]
    srv.register_problem("bad", graph=g, coloring=col, rng="not-an-rng")
    with pytest.raises(ValueError):
        srv.prewarm("bad", engine="gibbs", replicas=2, sweeps=SW, wait=True)


def test_lattice_packs_through_server(problems):
    srv = _server(problems, max_replicas_per_call=8)
    ids = [srv.submit("lat", engine="lattice", sweeps=SW, replicas=2,
                      seed=s, sync_every=4) for s in range(3)]
    srv.drain()
    n = L_B ** 3
    for jid in ids:
        _check_payload(srv.result(jid), n, 2)
    assert srv.stats()["engine_calls"] == 1


# -- streaming / preemption / cancel ------------------------------------------

def test_streaming_partial_results(problems):
    srv = _server(problems, stream_chunks=8)
    jid = srv.submit("pa", engine="gibbs", sweeps=512, replicas=2, seed=0)
    srv.pump(); srv.pump()
    p = srv.poll(jid)
    assert p["status"] == "running"
    assert 0 < p["sweeps_done"] < 512
    assert len(p["times"]) >= 1 and p["times"][-1] <= p["sweeps_done"]
    assert p["energies"].shape == (len(p["times"]), 2)
    assert p["flips"] > 0                    # exact mid-anneal flip count
    assert p["best_spins"] is not None       # best-so-far configuration
    before = p["sweeps_done"]
    srv.drain()
    r = srv.result(jid)
    assert r["status"] == "done" and r["sweeps_done"] == 512
    assert r["flips"] > p["flips"] and before < r["sweeps_done"]


def test_priority_preempts_running_batch(problems):
    srv = _server(problems)
    lo = srv.submit("pa", engine="gibbs", sweeps=1024, replicas=1, seed=1)
    srv.pump()                               # lo is mid-anneal
    hi = srv.submit("pa", engine="gibbs", sweeps=SW, replicas=1, seed=2,
                    priority=5)
    while srv.poll(hi)["status"] != "done":
        assert srv.pump()
    assert srv.poll(lo)["status"] == "running"   # parked, not lost
    assert srv.stats()["preemptions"] >= 1
    srv.drain()
    assert srv.poll(lo)["status"] == "done"


def test_cancel_queued_and_running(problems):
    srv = _server(problems)
    q = srv.submit("pa", engine="gibbs", sweeps=SW)
    assert srv.cancel(q) and srv.poll(q)["status"] == "cancelled"
    assert not srv.cancel(q)                 # already terminal
    run = srv.submit("pa", engine="gibbs", sweeps=512, seed=3)
    mate = srv.submit("pa", engine="gibbs", sweeps=512, seed=4)
    srv.pump()
    assert srv.cancel(run)
    srv.drain()
    r = srv.result(run)
    assert r["status"] == "cancelled" and 0 < r["sweeps_done"] < 512
    _check_payload(srv.result(mate), ea3d(L_A, seed=1).n, 1)  # unharmed
    assert srv.stats()["cancelled"] == 2


# -- admission control / validation -------------------------------------------

def test_admission_control(problems):
    srv = _server(problems, max_queue_depth=2)
    srv.submit("pa", sweeps=SW)
    srv.submit("pa", sweeps=SW)
    with pytest.raises(QueueFull):
        srv.submit("pa", sweeps=SW)
    assert srv.stats()["rejected"] == 1
    srv.drain()                              # draining reopens admission
    srv.submit("pa", sweeps=SW)
    srv.drain()


def test_submit_validation(problems):
    srv = _server(problems, max_replicas_per_call=4)
    with pytest.raises(ValueError):
        srv.submit("nope", sweeps=SW)
    with pytest.raises(ValueError):
        srv.submit("pa", engine="lattice", sweeps=SW)     # graph problem
    with pytest.raises(ValueError):
        srv.submit("lat", engine="gibbs", sweeps=SW)      # lattice problem
    with pytest.raises(ValueError):
        srv.submit("pa", engine="gibbs", precision="int8", sweeps=SW)
    with pytest.raises(ValueError):
        srv.submit("pa", replicas=5, sweeps=SW)           # > max per call
    with pytest.raises(ValueError):
        srv.submit("pa", sweeps=SW, record_points=[SW + 1])
    with pytest.raises(KeyError):
        srv.poll("job-999999")


def test_gibbs_sync_every_keeps_all_points(problems):
    """Gibbs has no boundaries, so its cursor records at S=1 whatever
    sync_every says — the harvest filter must use the cursor's actual
    quantum or requested points silently vanish."""
    srv = _server(problems)
    jid = srv.submit("pa", engine="gibbs", sweeps=SW, sync_every=4,
                     record_points=[13, SW // 2, SW])
    srv.drain()
    r = srv.result(jid)
    assert {13, SW // 2, SW} <= set(r["times"].tolist())
    assert r["energies"].shape[0] == len(r["times"])


def test_dsim_points_quantized_to_exchange_period(problems):
    srv = _server(problems)
    jid = srv.submit("pa", engine="dsim", sweeps=SW, sync_every=4,
                     record_points=[14])
    srv.drain()
    times = set(srv.result(jid)["times"].tolist())
    assert 16 in times                       # 14 snapped to a boundary
    assert all(t % 4 == 0 for t in times)
    assert {8, 16, 24, 32, 40, 48, 56, 64} <= times   # stream points intact


def test_awkward_sync_period_near_schedule_end(problems):
    """sweeps not a multiple of sync_every: stream points that round past
    the schedule clamp to the last reachable boundary instead of failing
    the whole batch."""
    srv = _server(problems)
    jid = srv.submit("pa", engine="dsim", sweeps=SW, sync_every=7)
    srv.drain()
    r = srv.result(jid)
    assert r["status"] == "done"
    assert len(r["times"]) >= 1 and r["times"][-1] == (SW // 7) * 7


def test_result_timeout_honored_inline(problems):
    srv = _server(problems)           # no background thread
    jid = srv.submit("pa", sweeps=SW)
    with pytest.raises(TimeoutError):
        srv.result(jid, timeout=0.0)
    assert srv.result(jid)["status"] == "done"


def test_incompatible_schedules_do_not_pack(problems):
    """Same problem/engine but different staircases -> separate batches."""
    srv = _server(problems)
    a = srv.submit("pa", engine="gibbs", sweeps=SW,
                   schedule=ea_schedule(SW))
    b = srv.submit("pa", engine="gibbs", sweeps=SW,
                   schedule=constant_schedule(2.0, SW))
    srv.drain()
    assert srv.stats()["engine_calls"] == 2
    assert srv.result(a)["status"] == srv.result(b)["status"] == "done"


# -- engine pool ---------------------------------------------------------------

def test_pool_lru_hit_and_evict(problems):
    srv = _server(problems, pool_capacity=1)
    srv.submit("pa", engine="gibbs", sweeps=SW); srv.drain()
    srv.submit("pb", engine="gibbs", sweeps=SW); srv.drain()  # evicts pa
    srv.submit("pb", engine="gibbs", sweeps=SW); srv.drain()  # hit
    s = srv.stats()["pool"]
    assert s["size"] == 1 and s["evictions"] >= 1 and s["hits"] >= 1
    # hit/miss is reported on the job payload as cold_start
    jid = srv.submit("pb", engine="gibbs", sweeps=SW); srv.drain()
    assert srv.result(jid)["cold_start"] is False


def test_pool_single_flight_builds():
    pool = EnginePool(capacity=4)
    built = []

    def builder():
        built.append(1)
        return object()

    outs = []
    ts = [threading.Thread(
        target=lambda: outs.append(pool.get(("k",), builder)))
        for _ in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert len(built) == 1                   # concurrent gets build once
    assert len({id(h) for h, _ in outs}) == 1
    assert pool.stats()["hits"] == 3 and pool.stats()["misses"] == 1


def test_pool_waiter_on_inflight_build_not_a_hit():
    """A caller that waited on another thread's build gets was_hit=False:
    that handle is freshly built and possibly unwarmed."""
    import time as _time
    pool = EnginePool(capacity=4)
    gate = threading.Event()

    def slow_builder():
        gate.wait(10)
        return object()

    t1 = threading.Thread(target=lambda: pool.get(("k",), slow_builder))
    t1.start()
    _time.sleep(0.05)                        # t1 is mid-build
    out = {}
    t2 = threading.Thread(
        target=lambda: out.update(r=pool.get(("k",), slow_builder)))
    t2.start()
    _time.sleep(0.05)
    gate.set()
    t1.join()
    t2.join()
    assert out["r"][1] is False              # waited -> not a warm hit
    _, hit = pool.get(("k",), slow_builder)  # genuinely cached now
    assert hit is True


def test_prewarm_moves_compile_off_path(problems):
    srv = _server(problems)
    srv.prewarm("pa", engine="gibbs", replicas=2, sweeps=SW, wait=True)
    jid = srv.submit("pa", engine="gibbs", sweeps=SW, replicas=2)
    srv.drain()
    r = srv.result(jid)
    assert r["pool_hit"] is True and r["cold_start"] is False
    assert srv.stats()["pool"]["hits"] >= 1


# -- background serving thread -------------------------------------------------

def test_threaded_serving_concurrent_submitters(problems):
    """Submissions race in from several threads while the serving loop
    runs; everything completes and validates (the CI smoke contract)."""
    srv = _server(problems).start()
    ids, errs = [], []
    lock = threading.Lock()

    def client(k):
        try:
            eng = ("gibbs", "dsim")[k % 2]
            jid = srv.submit("pa", engine=eng, sweeps=SW, replicas=2,
                             seed=k, sync_every=4 if eng == "dsim" else 1)
            r = srv.result(jid, timeout=300)
            with lock:
                ids.append((jid, r))
        except Exception as e:               # noqa: BLE001
            with lock:
                errs.append(e)

    ts = [threading.Thread(target=client, args=(k,)) for k in range(8)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    srv.stop()
    assert not errs
    assert len(ids) == 8
    g_n = ea3d(L_A, seed=1).n
    for _, r in ids:
        _check_payload(r, g_n, 2)
    assert srv.stats()["completed"] == 8


def test_result_after_stop_falls_back_inline(problems):
    srv = _server(problems).start()
    srv.stop()
    jid = srv.submit("pa", sweeps=SW)
    assert srv.result(jid, timeout=120)["status"] == "done"


def test_result_survives_stop_mid_wait(problems):
    """A waiter must not hang when the serving thread is stopped under
    it — it takes over pumping instead."""
    srv = _server(problems).start()
    jid = srv.submit("pa", sweeps=256, replicas=1)
    out = {}
    t = threading.Thread(
        target=lambda: out.update(r=srv.result(jid, timeout=120)))
    t.start()
    srv.stop()
    t.join(timeout=120)
    assert not t.is_alive()
    assert out["r"]["status"] == "done"


# -- fingerprints --------------------------------------------------------------

def test_fingerprints_discriminate(problems):
    (ga, _), (gb, _) = problems["pa"], problems["pb"]
    assert problem_fingerprint(graph=ga) == problem_fingerprint(graph=ga)
    assert problem_fingerprint(graph=ga) != problem_fingerprint(graph=gb)
    assert problem_fingerprint(L=8, seed=0) != problem_fingerprint(L=8,
                                                                   seed=1)
    assert schedule_fingerprint(ea_schedule(SW)) == \
        schedule_fingerprint(ea_schedule(SW))
    assert schedule_fingerprint(ea_schedule(SW)) != \
        schedule_fingerprint(constant_schedule(1.0, SW))
