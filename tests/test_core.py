"""Core Ising library: graphs, coloring, energies, monolithic Gibbs."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.graph import (ea3d, toroidal_grid, random_regular, from_edges,
                              edges_from_ell)
from repro.core.coloring import (lattice3d_coloring, greedy_coloring,
                                 validate_coloring)
from repro.core.energy import energy, local_fields, residual_energy
from repro.core.gibbs import GibbsEngine, chunk_plan
from repro.core.annealing import ea_schedule, sat_schedule, Schedule
from repro.core.pbit import (FixedPoint, quantize, pbit_update, lfsr_init,
                             lfsr_next, lfsr_uniform, S41)


def test_ea3d_structure():
    L = 6
    g = ea3d(L, seed=0)
    assert g.n == L ** 3
    # interior degree 6; open x/y boundaries reduce it
    deg = (np.asarray(g.w) != 0).sum(axis=1)
    assert deg.max() == 6
    assert deg.min() == 4
    # periodic z: every site has both z neighbors
    assert g.num_edges == 3 * L ** 3 - 2 * L * L  # 2 open faces x,y

    ei, ej, ew = edges_from_ell(g)
    assert set(np.unique(ew)) <= {-1.0, 1.0}
    # rebuild and compare energies on a random config
    g2 = from_edges(g.n, ei, ej, ew)
    m = jnp.asarray(np.random.default_rng(0).choice([-1, 1], g.n), jnp.int8)
    assert float(energy(g, m)) == float(energy(g2, m))


def test_ea3d_deterministic_by_seed():
    a, b = ea3d(5, seed=3), ea3d(5, seed=3)
    c = ea3d(5, seed=4)
    assert (np.asarray(a.w) == np.asarray(b.w)).all()
    assert not (np.asarray(a.w) == np.asarray(c.w)).all()


@pytest.mark.parametrize("L,expected", [(4, 2), (6, 2), (5, 3), (7, 3)])
def test_lattice_coloring(L, expected):
    g = ea3d(L, seed=0)
    col = lattice3d_coloring(L)
    assert col.n_colors == expected
    assert validate_coloring(np.asarray(g.idx), np.asarray(g.w), col.colors)
    assert sum(len(grp) for grp in col.groups) == L ** 3


def test_greedy_coloring_valid():
    g = random_regular(120, 4, seed=1)
    col = greedy_coloring(np.asarray(g.idx), np.asarray(g.w))
    assert validate_coloring(np.asarray(g.idx), np.asarray(g.w), col.colors)
    assert col.n_colors <= 5  # greedy <= max_degree + 1


def test_energy_matches_brute_force():
    g = random_regular(10, 3, seed=0)
    ei, ej, ew = edges_from_ell(g)
    rng = np.random.default_rng(1)
    for _ in range(5):
        m = rng.choice([-1, 1], g.n).astype(np.int8)
        brute = -(ew * m[ei] * m[ej]).sum()
        assert abs(float(energy(g, jnp.asarray(m))) - brute) < 1e-5


def test_local_fields_definition():
    g = random_regular(12, 3, seed=2)
    m = jnp.ones((g.n,), jnp.int8)
    f = np.asarray(local_fields(g, m))
    # all spins up: field_i = sum_j J_ij
    expect = np.asarray(g.w).sum(axis=1)
    np.testing.assert_allclose(f, expect, atol=1e-6)


def test_fixed_point_quantize():
    fmt = FixedPoint(4, 1)
    x = jnp.asarray([0.24, 0.26, -20.0, 20.0, 3.3])
    q = np.asarray(quantize(x, fmt))
    assert q[0] == 0.0 and q[1] == 0.5
    assert q[2] == fmt.lo and q[3] == fmt.hi
    assert q[4] == 3.5
    # idempotent
    assert (np.asarray(quantize(jnp.asarray(q), fmt)) == q).all()


def test_pbit_update_limits():
    # beta -> inf: deterministic sign of field
    field = jnp.asarray([3.0, -3.0])
    r = jnp.asarray([0.3, -0.3])
    out = pbit_update(field, 100.0, r)
    assert list(np.asarray(out)) == [1, -1]
    # beta = 0: sign of r
    out = pbit_update(field, 0.0, r)
    assert list(np.asarray(out)) == [1, -1]


def test_lfsr_period_and_range():
    s = lfsr_init(64, seed=0)
    seen = set()
    x = s
    for _ in range(100):
        x = lfsr_next(x)
        u = np.asarray(lfsr_uniform(x))
        assert (u > -1).all() and (u < 1).all()
        seen.add(int(np.asarray(x)[0]))
    assert len(seen) == 100  # no short cycles
    assert (np.asarray(x) != 0).all()


def test_gibbs_energy_tracking_exact():
    g = ea3d(5, seed=2)
    eng = GibbsEngine(g, lattice3d_coloring(5), rng="philox", fmt=S41)
    st = eng.init_state(seed=0)
    st, _ = eng.run_dense(st, ea_schedule(128).beta_array())
    assert abs(float(st.E) - float(eng.direct_energy(st))) < 1e-3


def test_gibbs_anneals_to_low_energy():
    g = ea3d(6, seed=1)
    eng = GibbsEngine(g, lattice3d_coloring(6))
    st = eng.init_state(seed=0)
    E0 = float(st.E)
    st, (Etr, flips) = eng.run_dense(st, ea_schedule(400).beta_array())
    assert float(Etr[-1]) < 0.6 * E0 if E0 < 0 else float(Etr[-1]) < E0
    # a sweep updates every p-bit once: attempted-update count = N per sweep
    assert np.asarray(flips).max() <= g.n


def test_gibbs_lfsr_vs_philox_statistics():
    """Paper: LFSR and Philox give slightly different but comparable
    dynamics; final energies should agree within a few percent."""
    g = ea3d(6, seed=5)
    col = lattice3d_coloring(6)
    outs = {}
    for kind in ("philox", "lfsr"):
        vals = []
        for s in range(3):
            eng = GibbsEngine(g, col, rng=kind)
            st = eng.init_state(seed=s)
            st, (Etr, _) = eng.run_dense(st, ea_schedule(300).beta_array())
            vals.append(float(Etr[-1]))
        outs[kind] = np.mean(vals)
    assert abs(outs["philox"] - outs["lfsr"]) / abs(outs["philox"]) < 0.1


def test_chunk_plan():
    pts = [1, 2, 4, 8, 100]
    plan = chunk_plan(pts)
    acc, seen = 0, []
    for c in plan:
        assert c & (c - 1) == 0  # power of two
        acc += c
        seen.append(acc)
    for p in pts:
        assert p in seen


def test_schedules():
    s = ea_schedule(1000)
    arr = s.beta_array()
    assert arr[0] == 0.5 and arr[-1] == 5.0 and len(arr) == 1000
    assert float(s.beta_at(0)) == 0.5
    s2 = sat_schedule(77)
    assert s2.betas[-1] == 10.0
