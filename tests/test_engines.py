"""Unified engine layer: registry round-trip, replica axis, fused kernel,
shared recording driver, exact flip accounting."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.engines import (Engine, RunRecord, chunk_plan, make_engine,
                           run_recorded_driver, spawn_seeds)
from repro.engines.base import flips_chunk_cap
from repro.core.graph import ea3d
from repro.core.coloring import lattice3d_coloring
from repro.core.partition import slab_partition
from repro.core.gibbs import GibbsEngine
from repro.core.dsim import build_partitioned, DSIMEngine
from repro.core.annealing import (ea_schedule, constant_schedule,
                                  replica_beta_arrays)

L = 6
SW = 48


@pytest.fixture(scope="module")
def setup():
    g = ea3d(L, seed=7)
    col = lattice3d_coloring(L)
    labels = slab_partition(L, 2)
    return g, col, labels


def _mk(name, setup, replicas=1, **kw):
    g, col, labels = setup
    if name == "gibbs":
        return make_engine("gibbs", g, coloring=col, rng="lfsr",
                           replicas=replicas, **kw)
    if name == "dsim":
        return make_engine("dsim", g, coloring=col, K=2, labels=labels,
                           rng="lfsr", replicas=replicas, **kw)
    if name == "dsim_dist":
        # K=1 runs the full shard_map path on the single test device
        return make_engine("dsim_dist", g, coloring=col, K=1,
                           labels=np.zeros(g.n, np.int32), rng="lfsr",
                           replicas=replicas, **kw)
    return make_engine("lattice", L=L, seed=7, replicas=replicas, **kw)


# -- registry round-trip ------------------------------------------------------

@pytest.mark.parametrize("name", ["gibbs", "dsim", "dsim_dist", "lattice"])
def test_registry_round_trip(name, setup):
    g, col, labels = setup
    h = _mk(name, setup, replicas=2)
    assert isinstance(h, Engine)
    assert h.replicas == 2 and h.n_sites == g.n
    st = h.init_state(seed=0)
    st, rec = h.run_recorded(st, ea_schedule(SW), [SW // 2, SW],
                             sync_every=4)
    assert isinstance(rec, RunRecord)
    assert rec.energies.shape == (2, 2)            # (points, R)
    assert rec.flips > 0
    e = np.asarray(h.energy(st))
    assert e.shape == (2,)
    np.testing.assert_allclose(e, np.asarray(rec.energies[-1]), atol=1e-3)
    spins = np.asarray(h.global_spins(st))
    assert spins.shape == (2, g.n)
    assert set(np.unique(spins)) <= {-1, 1}
    # annealing actually anneals
    assert float(rec.energies[-1].min()) < 0


def test_unknown_engine_rejected(setup):
    with pytest.raises(ValueError):
        make_engine("does-not-exist")


# -- replica axis -------------------------------------------------------------

@pytest.mark.parametrize("name", ["gibbs", "dsim"])
def test_replica_r1_matches_legacy_bitwise(name, setup):
    """The registry at R=1 reproduces the legacy class exactly."""
    g, col, labels = setup
    h = _mk(name, setup, replicas=1)
    if name == "gibbs":
        legacy = GibbsEngine(g, col, rng="lfsr")
    else:
        legacy = DSIMEngine(build_partitioned(g, col, labels, 2), rng="lfsr")
    sh = h.init_state(seed=3)
    sl = legacy.init_state(seed=3)
    sh, rec = h.run_recorded(sh, ea_schedule(SW), [SW], sync_every=4)
    if name == "gibbs":
        sl, _ = legacy.run_recorded(sl, ea_schedule(SW), [SW])
        ml = np.asarray(sl.m)
    else:
        sl, _ = legacy.run_recorded(sl, ea_schedule(SW), [SW], sync_every=4)
        ml = np.asarray(legacy.global_spins(sl))
    mh = np.asarray(h.global_spins(sh))[0]
    assert (mh == ml).all()


def test_lattice_r1_matches_direct_engine(setup):
    from repro.core.lattice import build_ea3d_lattice
    from repro.core.lattice_dsim import LatticeDSIM
    from repro.compat import make_mesh, auto_axes
    h = _mk("lattice", setup, replicas=1)
    prob = build_ea3d_lattice(L, seed=7)
    mesh = make_mesh((1,), ("data",), axis_types=auto_axes(1))
    direct = LatticeDSIM(prob, mesh, dim_axes=("data", None, None))
    sh, sd = h.init_state(seed=3), direct.init_state(seed=3)
    sh, _ = h.run_recorded(sh, ea_schedule(SW), [SW], sync_every=4)
    sd, _ = direct.run_recorded(sd, ea_schedule(SW), [SW], sync_every=4)
    assert (np.asarray(sh.m) == np.asarray(sd.m)).all()
    assert (np.asarray(sh.s) == np.asarray(sd.s)).all()


@pytest.mark.parametrize("name", ["gibbs", "dsim", "dsim_dist", "lattice"])
def test_replicas_mutually_independent(name, setup):
    """R=4 chains diverge: pairwise-distinct spins and decorrelated signs."""
    h = _mk(name, setup, replicas=4)
    st = h.init_state(seed=0)
    st, rec = h.run_recorded(st, constant_schedule(0.8, SW), [SW],
                             sync_every=4)
    spins = np.asarray(h.global_spins(st)).astype(np.float64)
    n = spins.shape[1]
    for a in range(4):
        for b in range(a + 1, 4):
            assert (spins[a] != spins[b]).any()
            # at beta below the glass transition, independent chains show
            # only weak overlap: |q_ab| far from 1
            q = abs(float((spins[a] * spins[b]).mean()))
            assert q < 0.6, (a, b, q)


def test_replica_prefix_stability(setup):
    """Replica r of an R=2 batch equals replica r of an R=4 batch (seed
    spawning is prefix-stable), so growing the batch never reshuffles."""
    g, col, labels = setup
    h2 = _mk("gibbs", setup, replicas=2)
    h4 = _mk("gibbs", setup, replicas=4)
    s2, s4 = h2.init_state(seed=9), h4.init_state(seed=9)
    s2, _ = h2.run_recorded(s2, ea_schedule(SW), [SW])
    s4, _ = h4.run_recorded(s4, ea_schedule(SW), [SW])
    m2 = np.asarray(h2.global_spins(s2))
    m4 = np.asarray(h4.global_spins(s4))
    assert (m2 == m4[:2]).all()


def test_per_replica_beta_arrays(setup):
    g, col, labels = setup
    sch = ea_schedule(SW)
    bR = replica_beta_arrays(sch, 3, spread=0.2)
    assert bR.shape == (SW, 3)
    assert (bR[:, 0] < bR[:, 2]).all()
    eng = GibbsEngine(g, col, rng="lfsr")
    st = eng.init_state(seed=0, replicas=3)
    st, rec = eng.run_recorded_full(st, sch, [SW], betas_R=bR)
    assert rec.energies.shape == (1, 3)
    # identical spread=0 arrays reproduce the shared-schedule run bitwise
    st1 = eng.init_state(seed=0, replicas=3)
    st1, rec1 = eng.run_recorded_full(st1, sch, [SW])
    st2 = eng.init_state(seed=0, replicas=3)
    st2, rec2 = eng.run_recorded_full(st2, sch, [SW],
                                      betas_R=replica_beta_arrays(sch, 3))
    assert (np.asarray(st1.m) == np.asarray(st2.m)).all()


# -- fused multi-phase kernel -------------------------------------------------

@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_fused_sweep_matches_per_phase_bitwise(impl):
    """Acceptance: fused kernel == per-phase reference on an (8,8,8) brick,
    bitwise, through the full engine (halo exchange included)."""
    hf = make_engine("lattice", L=8, seed=5, replicas=2, fused=True,
                     impl=impl)
    hp = make_engine("lattice", L=8, seed=5, replicas=2, fused=False,
                     impl=impl)
    sf, sp = hf.init_state(seed=0), hp.init_state(seed=0)
    sf, rf = hf.run_recorded(sf, ea_schedule(16), [16], sync_every=4)
    sp, rp = hp.run_recorded(sp, ea_schedule(16), [16], sync_every=4)
    assert (np.asarray(sf.m) == np.asarray(sp.m)).all()
    assert (np.asarray(sf.s) == np.asarray(sp.s)).all()
    assert rf.flips == rp.flips > 0


def test_fused_kernel_op_level_bitwise():
    from repro.kernels.ops import pbit_update_op, pbit_sweep_op
    rng = np.random.default_rng(0)
    shape = (8, 8, 8)
    m = jnp.asarray(rng.choice([-1, 1], size=shape).astype(np.int8))
    s = jnp.asarray(rng.integers(1, 2 ** 32, size=shape, dtype=np.uint32))
    h = jnp.asarray(rng.normal(0, 0.1, shape).astype(np.float32))
    w6 = tuple(jnp.asarray(rng.choice([-1.0, 0.0, 1.0], size=shape)
                           .astype(np.float32)) for _ in range(6))
    halos = tuple(jnp.asarray(rng.choice([-1, 1], sh).astype(np.int8))
                  for sh in [(8, 8)] * 6)
    par = ((np.indices(shape).sum(axis=0)) % 2).astype(np.int8)
    masks = jnp.asarray(np.stack([par, 1 - par]))
    betas = jnp.asarray([1.0, 2.0, 3.0], jnp.float32)
    mm, ss, fl = m, s, 0
    for t in range(3):
        for c in range(2):
            m2, ss = pbit_update_op(mm, ss, betas[t], masks[c], h, w6, halos,
                                    impl="interpret")
            fl += int((np.asarray(m2) != np.asarray(mm)).sum())
            mm = m2
    mf, sf, flf = pbit_sweep_op(m, s, betas, masks, h, w6, halos,
                                impl="interpret")
    assert (np.asarray(mf) == np.asarray(mm)).all()
    assert (np.asarray(sf) == np.asarray(ss)).all()
    assert int(flf) == fl


# -- shared driver / exact flip accounting ------------------------------------

def test_chunk_plan_max_chunk():
    plan = chunk_plan([5, 9, 64], max_chunk=8)
    acc, seen = 0, []
    for c in plan:
        assert c & (c - 1) == 0 and c <= 8
        acc += c
        seen.append(acc)
    for p in (5, 9, 64):
        assert p in seen
    with pytest.raises(ValueError):
        chunk_plan([4], max_chunk=3)


def test_flip_total_exact_beyond_int32():
    """>2**31 flips accumulate exactly: the device counter is a wrapping
    int32 odometer, the driver's host-side total is an exact Python int."""
    FLIPS_PER_SWEEP = 1 << 24
    TOTAL = 512                                   # 512 * 2^24 = 2^33 flips
    cap = flips_chunk_cap(FLIPS_PER_SWEEP, 1)
    assert cap * FLIPS_PER_SWEEP < (1 << 31)      # per-chunk delta unambiguous

    class FakeState(dict):
        pass

    def chunk_fn(state, betas2d, iters, S):
        d = int(betas2d.shape[0]) * int(betas2d.shape[1]) * FLIPS_PER_SWEEP
        # int32 odometer semantics: wraps mod 2^32 (stored as uint32 here —
        # newer numpy refuses out-of-range int32 construction)
        wrapped = np.uint32((int(state["flips"]) + d) & 0xFFFFFFFF)
        return FakeState(flips=wrapped, E=state["E"])

    state = FakeState(flips=np.uint32(0), E=jnp.zeros(()))
    state, rec = run_recorded_driver(
        state=state, schedule=constant_schedule(1.0, TOTAL),
        record_points=[TOTAL], chunk_fn=chunk_fn,
        record_fn=lambda st: st["E"], sync_every=1,
        flips_of=lambda st: st["flips"],
        flips_per_sweep=FLIPS_PER_SWEEP)
    exact = TOTAL * FLIPS_PER_SWEEP
    assert exact > (1 << 31)
    assert rec.flips == exact                      # wrapped twice, still exact


def test_engine_flip_totals_consistent(setup):
    """Engine-reported exact totals equal the device odometer when small."""
    h = _mk("gibbs", setup, replicas=1)
    st = h.init_state(seed=0)
    st, rec = h.run_recorded(st, ea_schedule(SW), [SW])
    assert rec.flips == int(np.uint32(np.asarray(st.flips)))


def test_spawn_seeds_distinct_and_stable():
    a = spawn_seeds(0, 8)
    b = spawn_seeds(0, 4)
    assert a[:4] == b
    assert len(set(a)) == 8
    assert spawn_seeds(1, 4) != spawn_seeds(0, 4)


# -- serve path ---------------------------------------------------------------

def test_sample_service_round_trip(setup):
    from repro.serve.sample_service import SampleService
    g, col, labels = setup
    svc = SampleService(graph=g, coloring=col, rng="lfsr")
    out = svc.submit(engine="gibbs", sweeps=SW, replicas=3, seed=1)
    assert out["energies"].shape == (1, 3)
    assert out["best_spins"].shape == (g.n,)
    assert out["best_energy"] == float(out["energies"][-1].min())
    assert out["flips"] > 0 and out["wall_s"] > 0
    # second submit reuses the cached handle
    out2 = svc.submit(engine="gibbs", sweeps=SW, replicas=3, seed=1)
    assert out2["best_energy"] == out["best_energy"]
