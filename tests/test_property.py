"""Property-based tests (hypothesis) on the system's invariants."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

# hypothesis is a dev-only dependency (requirements-dev.txt); without it this
# module must skip cleanly instead of failing tier-1 collection
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st, HealthCheck

from repro.core.graph import from_edges, random_regular
from repro.core.coloring import greedy_coloring, validate_coloring
from repro.core.energy import energy
from repro.core.packing import pack_pm1, unpack_pm1
from repro.core.gibbs import chunk_plan
from repro.core.pbit import FixedPoint, quantize
from repro.train.optimizer import q8_encode, q8_decode
from repro.launch.roofline import _shape_bytes, _group_size

SET = dict(deadline=None, max_examples=20,
           suppress_health_check=[HealthCheck.too_slow])


@given(st.integers(2, 40), st.integers(0, 10 ** 6))
@settings(**SET)
def test_energy_gauge_invariance(n, seed):
    """E is invariant under J_ij -> J_ij s_i s_j, m -> m*s (gauge symmetry)."""
    rng = np.random.default_rng(seed)
    d = 3 if (n * 3) % 2 == 0 else 4
    try:
        g = random_regular(max(n, d + 1), d, seed=seed)
    except (RuntimeError, ValueError):
        return
    m = rng.choice([-1, 1], g.n).astype(np.int8)
    s = rng.choice([-1, 1], g.n).astype(np.int8)
    idx = np.asarray(g.idx)
    w = np.asarray(g.w) * s[:, None] * s[idx]
    g2 = from_edges(*_to_edges(idx, w, g.n))
    e1 = float(energy(g, jnp.asarray(m)))
    e2 = float(energy(g2, jnp.asarray((m * s).astype(np.int8))))
    assert abs(e1 - e2) < 1e-3


def _to_edges(idx, w, n):
    src = np.repeat(np.arange(n), idx.shape[1])
    dst = idx.ravel()
    wt = w.ravel()
    mask = (wt != 0) & (src < dst)
    return n, src[mask], dst[mask], wt[mask].astype(np.float32)


@given(st.integers(0, 10 ** 6))
@settings(**SET)
def test_energy_global_flip_invariance(seed):
    g = random_regular(20, 3, seed=seed % 100)
    m = np.random.default_rng(seed).choice([-1, 1], g.n).astype(np.int8)
    e1 = float(energy(g, jnp.asarray(m)))
    e2 = float(energy(g, jnp.asarray((-m).astype(np.int8))))
    assert abs(e1 - e2) < 1e-3   # h = 0: Z2 symmetry


@given(st.integers(1, 64), st.integers(1, 8), st.integers(0, 10 ** 6))
@settings(**SET)
def test_pack_unpack_roundtrip(words, rows, seed):
    n = words * 8
    x = np.random.default_rng(seed).choice([-1, 1], (rows, n)).astype(np.int8)
    assert (unpack_pm1(pack_pm1(jnp.asarray(x)), n) == x).all()


@given(st.lists(st.integers(1, 5000), min_size=1, max_size=12))
@settings(**SET)
def test_chunk_plan_hits_every_point(raw):
    pts = sorted(set(raw))
    plan = chunk_plan(pts)
    acc, hits = 0, set()
    for c in plan:
        assert c & (c - 1) == 0
        acc += c
        hits.add(acc)
    assert set(pts) <= hits
    assert acc == pts[-1]


@given(st.integers(1, 6), st.integers(0, 6),
       st.floats(-100, 100, allow_nan=False))
@settings(**SET)
def test_fixedpoint_properties(ib, fb, x):
    fmt = FixedPoint(ib, fb)
    q = float(quantize(jnp.asarray(x), fmt))
    assert fmt.lo <= q <= fmt.hi
    # idempotent & on-grid
    assert abs(float(quantize(jnp.asarray(q), fmt)) - q) < 1e-9
    assert abs(q / fmt.step - round(q / fmt.step)) < 1e-6
    # within half a step when in range
    if fmt.lo <= x <= fmt.hi:
        assert abs(q - x) <= fmt.step / 2 + 1e-9


@given(st.integers(1, 400), st.integers(0, 10 ** 6))
@settings(**SET)
def test_q8_error_bound(n, seed):
    x = np.random.default_rng(seed).normal(0, 3, n).astype(np.float32)
    q, s = q8_encode(jnp.asarray(x))
    y = np.asarray(q8_decode(q, s, (n,)))
    # blockwise absmax: per-block error <= blockmax/127 (+eps)
    pad = (-n) % 128
    xp = np.pad(x, (0, pad)).reshape(-1, 128)
    bm = np.abs(xp).max(axis=1)
    err = np.abs(np.pad(x, (0, pad)).reshape(-1, 128) -
                 np.pad(y, (0, pad)).reshape(-1, 128))
    assert (err <= bm[:, None] / 127.0 + 1e-5).all()


@given(st.integers(2, 30), st.integers(3, 6), st.integers(0, 10 ** 5))
@settings(**SET)
def test_greedy_coloring_always_valid(n, d, seed):
    if (n * d) % 2 != 0 or n <= d:
        return
    try:
        g = random_regular(n, d, seed=seed)
    except (RuntimeError, ValueError):
        return
    col = greedy_coloring(np.asarray(g.idx), np.asarray(g.w))
    assert validate_coloring(np.asarray(g.idx), np.asarray(g.w), col.colors)
    assert col.n_colors <= d + 1


def test_hlo_shape_parser():
    assert _shape_bytes("f32[4,8]{1,0}") == 128
    assert _shape_bytes("(bf16[2,2], u8[16])") == 24
    assert _shape_bytes("pred[7]") == 7
    assert _shape_bytes("s32[]") == 4  # scalar: product of no dims = 1 elem
    assert _group_size("replica_groups={{0,1,2,3}}") == 4
    assert _group_size("replica_groups=[2,8]<=[16]") == 8
    assert _group_size("source_target_pairs={{0,1}}") == 2
