"""Shared-driver edge cases: chunk planning, record-point quantization,
flip-cap bounds, and the resumable RecordedCursor surface."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.annealing import constant_schedule, ea_schedule
from repro.core.coloring import lattice3d_coloring
from repro.core.graph import ea3d
from repro.engines import make_engine
from repro.engines.base import (RecordedCursor, chunk_plan, flips_chunk_cap,
                                quantize_record_points, run_recorded_driver)


# -- chunk_plan ----------------------------------------------------------------

def test_chunk_plan_point_zero_is_empty():
    assert chunk_plan([0]) == []
    assert chunk_plan([0, 0]) == []


def test_chunk_plan_duplicate_points():
    plan = chunk_plan([4, 4, 8])
    assert sum(plan) == 8
    acc, seen = 0, set()
    for c in plan:
        acc += c
        seen.add(acc)
    assert {4, 8} <= seen


def test_chunk_plan_unsorted_rejected():
    with pytest.raises(ValueError, match="nondecreasing"):
        chunk_plan([8, 4])


def test_chunk_plan_non_pow2_max_chunk_rejected():
    for bad in (3, 6, 0, -4):
        with pytest.raises(ValueError, match="power of two"):
            chunk_plan([4], max_chunk=bad)


def test_chunk_plan_max_chunk_one():
    assert chunk_plan([5], max_chunk=1) == [1] * 5


def test_chunk_plan_covers_every_point():
    pts = [1, 7, 8, 21, 64]
    plan = chunk_plan(pts, max_chunk=16)
    acc, seen = 0, set()
    for c in plan:
        assert c & (c - 1) == 0 and 1 <= c <= 16
        acc += c
        seen.add(acc)
    assert set(pts) <= seen


# -- quantize_record_points ----------------------------------------------------

def test_quantize_point_zero_snaps_to_S():
    assert quantize_record_points([0], S=4) == [4]


def test_quantize_S_larger_than_first_point():
    # S > p: every point clamps up to at least one exchange period
    assert quantize_record_points([2, 16], S=8) == [8, 16]
    assert quantize_record_points([1, 2, 3], S=8) == [8]


def test_quantize_duplicates_and_unsorted():
    assert quantize_record_points([8, 4, 4, 8], S=4) == [4, 8]
    assert quantize_record_points([9, 6, 6], S=4) == [8]


def test_quantize_clamps_rounding_past_limit():
    # round-to-nearest can overshoot the schedule (1000 -> 1001 with S=7);
    # with limit= the point clamps to the last reachable boundary
    assert quantize_record_points([1000], 7) == [1001]
    assert quantize_record_points([1000], 7, limit=1000) == [994]
    assert quantize_record_points([20], 7, limit=20) == [14]
    assert quantize_record_points([16], 4, limit=16) == [16]  # no-op in range


def test_driver_survives_awkward_sync_near_schedule_end():
    _, rec = run_recorded_driver(
        state={}, schedule=constant_schedule(1.0, 20), record_points=[20],
        chunk_fn=_noop_chunk, record_fn=lambda st: jnp.zeros(()),
        sync_every=7)
    assert list(rec.times) == [14]           # last reachable boundary


# -- flips_chunk_cap -----------------------------------------------------------

def test_flips_chunk_cap_bounds_and_pow2():
    for fps, spi in [(1, 1), (125, 4), (1 << 20, 1), (7, 3)]:
        cap = flips_chunk_cap(fps, spi)
        assert cap >= 1 and cap & (cap - 1) == 0
        assert cap * fps * spi < (1 << 31)


def test_flips_chunk_cap_degenerate_inputs():
    assert flips_chunk_cap(0) == 1 << 30          # clamped to >= 1 flip
    assert flips_chunk_cap(1, 0) == 1 << 30
    assert flips_chunk_cap(1 << 40) == 1          # never below one iter


# -- driver guards -------------------------------------------------------------

def _noop_chunk(state, betas2d, iters, S):
    return state


def test_driver_empty_record_points_rejected():
    with pytest.raises(ValueError, match="non-empty"):
        run_recorded_driver(
            state={}, schedule=constant_schedule(1.0, 8), record_points=[],
            chunk_fn=_noop_chunk, record_fn=lambda st: jnp.zeros(()))
    with pytest.raises(ValueError, match="non-empty"):
        RecordedCursor(
            state={}, schedule=constant_schedule(1.0, 8), record_points=[],
            chunk_fn=_noop_chunk, record_fn=lambda st: jnp.zeros(()))


def test_driver_schedule_too_short_rejected():
    with pytest.raises(ValueError, match="shorter"):
        run_recorded_driver(
            state={}, schedule=constant_schedule(1.0, 8), record_points=[16],
            chunk_fn=_noop_chunk, record_fn=lambda st: jnp.zeros(()))


def test_driver_quantizes_S_above_first_point():
    seen = []

    def chunk(state, betas2d, iters, S):
        seen.append((iters, S))
        return state

    _, rec = run_recorded_driver(
        state={}, schedule=constant_schedule(1.0, 32), record_points=[2],
        chunk_fn=chunk, record_fn=lambda st: jnp.zeros(()), sync_every=8)
    assert list(rec.times) == [8]            # 2 snapped up to one period
    assert all(S == 8 for _, S in seen)


# -- the resumable cursor ------------------------------------------------------

L = 4
SW = 32


@pytest.fixture(scope="module")
def gibbs_handle():
    g = ea3d(L, seed=5)
    return g, make_engine("gibbs", g, coloring=lattice3d_coloring(L),
                          rng="lfsr", replicas=2)


def test_cursor_matches_one_shot_bitwise(gibbs_handle):
    g, h = gibbs_handle
    sch = ea_schedule(SW)
    pts = [SW // 4, SW // 2, SW]
    st0 = h.init_state(seed=3)
    st1, rec1 = h.run_recorded(st0, sch, pts)
    cur = h.start_recorded(h.init_state(seed=3), sch, pts)
    steps = 0
    while not cur.done:
        assert cur.advance(1) == 1
        steps += 1
    assert cur.advance(1) == 0               # done cursors are inert
    rec2 = cur.record()
    assert steps >= len(pts)
    assert np.array_equal(np.asarray(rec1.energies),
                          np.asarray(rec2.energies))
    assert np.array_equal(rec1.times, rec2.times)
    assert rec1.flips == rec2.flips
    assert np.array_equal(np.asarray(h.global_spins(st1)),
                          np.asarray(h.global_spins(cur.state)))


def test_cursor_partial_records_stream(gibbs_handle):
    g, h = gibbs_handle
    pts = [8, 16, 24, 32]
    cur = h.start_recorded(h.init_state(seed=1), ea_schedule(SW), pts)
    seen_pts, seen_flips = [0], [0]
    while not cur.done:
        cur.advance(1)
        rec = cur.record()
        assert len(rec.times) >= seen_pts[-1]
        assert rec.flips >= seen_flips[-1]   # exact and monotone mid-run
        if len(rec.times):
            assert rec.energies.shape == (len(rec.times), 2)
        seen_pts.append(len(rec.times))
        seen_flips.append(rec.flips)
    assert cur.sweeps_done == cur.total_sweeps == SW
    assert seen_pts[-1] == len(pts)
    per_rep = cur.flips_per_replica()
    assert per_rep.shape == (2,) and int(per_rep.sum()) == cur.flips > 0


def test_cursor_warm_does_not_advance(gibbs_handle):
    g, h = gibbs_handle
    sch = ea_schedule(SW)
    cur = h.start_recorded(h.init_state(seed=2), sch, [SW])
    cur.warm()
    assert cur.sweeps_done == 0 and not cur.done
    cur.advance(1000)
    ref = h.start_recorded(h.init_state(seed=2), sch, [SW])
    ref.advance(1000)
    assert np.array_equal(np.asarray(cur.record().energies),
                          np.asarray(ref.record().energies))
    assert cur.record().flips == ref.record().flips


def test_cursor_empty_partial_record(gibbs_handle):
    g, h = gibbs_handle
    cur = h.start_recorded(h.init_state(seed=0), ea_schedule(SW), [SW])
    rec = cur.record()                       # before any advance
    assert len(rec.times) == 0 and rec.flips == 0


# -- snapshot / restore --------------------------------------------------------

def test_snapshot_pickles_and_resumes_bitwise(gibbs_handle):
    import pickle
    from repro.core.snapshot import snapshot_nbytes
    g, h = gibbs_handle
    sch = ea_schedule(SW)
    st = h.init_state(seed=4)
    st, _ = h.run_recorded(st, sch, [SW])    # mid-trajectory state
    snap = h.snapshot(st)
    assert snapshot_nbytes(snap) > 0
    restored = h.restore(pickle.loads(pickle.dumps(snap)))
    a, ra = h.run_recorded(st, sch, [SW])
    b, rb = h.run_recorded(restored, sch, [SW])
    assert np.array_equal(np.asarray(ra.energies), np.asarray(rb.energies))
    assert ra.flips == rb.flips
    assert np.array_equal(np.asarray(h.global_spins(a)),
                          np.asarray(h.global_spins(b)))


def test_snapshot_restore_lattice_resharded():
    import pickle
    hl = make_engine("lattice", L=4, seed=2, replicas=2)
    sch = ea_schedule(16)
    st = hl.init_state(seed=0)
    st, _ = hl.run_recorded(st, sch, [16], sync_every=4)
    restored = hl.restore(pickle.loads(pickle.dumps(hl.snapshot(st))))
    a, _ = hl.run_recorded(st, sch, [16], sync_every=4)
    b, _ = hl.run_recorded(restored, sch, [16], sync_every=4)
    assert np.array_equal(np.asarray(a.m), np.asarray(b.m))
    assert np.array_equal(np.asarray(a.s), np.asarray(b.s))
