"""Word-lane mesh engine: dsim_dist precision="int8"/"bitplane".

In-process tests run on a K=1 mesh (one partition on the default single
device — the shard_map path without a forced device count); the
multi-device boundary-exchange tests run in SUBPROCESSES with a forced
host device count, like tests/test_dist.py.
"""

import dataclasses
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.graph import ea3d
from repro.core.coloring import lattice3d_coloring
from repro.core.partition import slab_partition
from repro.core.dsim import build_partitioned, DSIMEngine
from repro.core.dsim_dist import DistDSIMEngine
from repro.core.annealing import ea_schedule
from repro.compat import make_mesh, auto_axes
from repro.engines import make_engine

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, devices: int = 2, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       timeout=timeout)
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


def _k1(L=4, seed=7):
    g = ea3d(L, seed=seed)
    col = lattice3d_coloring(L)
    prob = build_partitioned(g, col, np.zeros(g.n, np.int32), 1)
    mesh = make_mesh((1,), ("data",), axis_types=auto_axes(1))
    return g, prob, mesh


# -- guards -------------------------------------------------------------------

def test_dist_precision_guards():
    g, prob, mesh = _k1()
    with pytest.raises(ValueError, match="rng='lfsr'"):
        DistDSIMEngine(prob, mesh, rng="philox", precision="int8")
    with pytest.raises(ValueError, match="rng='lfsr'"):
        DistDSIMEngine(prob, mesh, rng="lfsr", mode="cmft",
                       precision="bitplane")
    with pytest.raises(ValueError, match="256"):
        DistDSIMEngine(prob, mesh, rng="lfsr", precision="bitplane",
                       replicas=257)
    # word-straddling replica counts are legal (multi-word lane fabric)
    assert DistDSIMEngine(prob, mesh, rng="lfsr", precision="bitplane",
                          replicas=33).words == 2
    with pytest.raises(ValueError, match="unknown precision"):
        DistDSIMEngine(prob, mesh, precision="fp4")


def test_registry_dist_precisions():
    g, prob, mesh = _k1()
    h = make_engine("dsim_dist", prob, mesh=mesh, rng="lfsr",
                    precision="bitplane", replicas=4)
    assert h.precision == "bitplane"
    with pytest.raises(ValueError, match="bit lanes"):
        make_engine("dsim_dist", prob, mesh=mesh, rng="lfsr",
                    precision="bitplane", replicas=300)
    with pytest.raises(ValueError, match="not supported"):
        make_engine("gibbs", ea3d(4, seed=0), precision="bitplane")


# -- K=1 bit-identity chain: stacked int8 == dist int8 == bitplane lanes -----

def test_dist_int8_matches_stacked_int8():
    g, prob, mesh = _k1()
    sch = ea_schedule(96)
    R = 3
    s = DSIMEngine(prob, rng="lfsr", precision="int8")
    ss = s.init_state(seed=3, replicas=R)
    ss, (_, Es) = s.run_recorded(ss, sch, [32, 96], sync_every=4)
    d = DistDSIMEngine(prob, mesh, rng="lfsr", precision="int8", replicas=R)
    sd = d.init_state(seed=3)
    sd, (_, Ed) = d.run_recorded(sd, sch, [32, 96], sync_every=4)
    assert (np.asarray(s.global_spins(ss)) ==
            np.asarray(d.global_spins(sd))).all()
    np.testing.assert_array_equal(np.asarray(Es), np.asarray(Ed))


@pytest.mark.parametrize("R", [1, 5, 32, 40])
def test_dist_bitplane_lanes_match_int8_replicas(R):
    g, prob, mesh = _k1()
    sch = ea_schedule(64)
    outs = {}
    for prec in ("int8", "bitplane"):
        e = DistDSIMEngine(prob, mesh, rng="lfsr", precision=prec,
                           replicas=R)
        st = e.init_state(seed=11)
        st, rec = e.run_recorded_full(st, sch, [64], sync_every=4)
        outs[prec] = (np.asarray(e.global_spins(st)),
                      np.asarray(rec.energies), rec.flips)
    m8, E8, f8 = outs["int8"]
    mw, Ew, fw = outs["bitplane"]
    assert (m8 == mw).all()
    np.testing.assert_array_equal(E8, Ew)
    assert f8 == fw


def test_dist_bitplane_lane_prefix_stability():
    """Lane r depends on spawn_seeds(seed)[r] alone: growing the lane batch
    never reshuffles existing chains (what lets the serving scheduler pad
    every dist bit-plane job up to the one R=32 executable)."""
    g, prob, mesh = _k1()
    sch = ea_schedule(48)
    spins = {}
    for R in (4, 8):
        e = DistDSIMEngine(prob, mesh, rng="lfsr", precision="bitplane",
                           replicas=R)
        st = e.init_state(seed=5)
        st, _ = e.run_recorded(st, sch, [48], sync_every=4)
        spins[R] = np.asarray(e.global_spins(st))
    assert (spins[4] == spins[8][:4]).all()


# -- satellite: per-chunk flip accumulation survives int32 overflow ----------

def test_dist_flip_odometer_exact_across_int32_wrap():
    """Regression for the per-chunk accumulator: seeded just below 2^31,
    the counter crosses the int32 sign boundary inside one chunk, and the
    driver's exact host-side total must not care (uint32 modular
    accumulation + mod-2^32 odometer read)."""
    g, prob, mesh = _k1()
    sch = ea_schedule(64)
    R = 2
    e = DistDSIMEngine(prob, mesh, rng="lfsr", precision="int8", replicas=R)
    st0 = e.init_state(seed=1)
    _, ref = e.run_recorded_full(st0, sch, [64], sync_every=4)
    st = e.init_state(seed=1)
    near = np.full((R,), (1 << 31) - 7, np.int64).astype(np.int32)
    st = e.shard_state(dataclasses.replace(st, flips=jnp.asarray(near)))
    st, rec = e.run_recorded_full(st, sch, [64], sync_every=4)
    # same chains, same flips — the exact total ignores the counter origin
    assert rec.flips == ref.flips
    assert ref.flips > 0


# -- wire format --------------------------------------------------------------

def test_dist_boundary_payload_accounting():
    g, prob, mesh = _k1()
    bp = DistDSIMEngine(prob, mesh, rng="lfsr", precision="bitplane",
                        replicas=32).boundary_payload()
    assert bp["dtype"] == "uint32"
    assert bp["bytes_per_site_all_chains"] == 4.0
    assert bp["pack_compute"] == "none"
    # multi-word: 4 B/site per word plane, W planes on the wire
    bp2 = DistDSIMEngine(prob, mesh, rng="lfsr", precision="bitplane",
                         replicas=40).boundary_payload()
    assert bp2["word_planes"] == 2
    assert bp2["bytes_per_site_per_word"] == 4.0
    assert bp2["bytes_per_site_all_chains"] == 8.0
    assert bp2["bytes"] == 2 * bp["bytes"]
    i8 = DistDSIMEngine(prob, mesh, rng="lfsr", precision="int8",
                        replicas=32).boundary_payload()
    assert i8["bytes_per_site_all_chains"] == 32.0
    assert i8["bytes_per_site_all_chains"] / \
        bp["bytes_per_site_all_chains"] == 8.0
    f32 = DistDSIMEngine(prob, mesh, rng="lfsr",
                         replicas=32).boundary_payload()
    assert f32["dtype"] == "uint8-bitmap"
    assert "pack" in f32["pack_compute"]


def test_dist_bitplane_lowered_chunk_is_word_native():
    """The lowered collective chunk must contain no 8-bit tensors at all:
    spins, ghosts, and the all-gathered boundary payload are uint32 words
    end to end — there is nothing to pack or unpack."""
    g, prob, mesh = _k1()
    e = DistDSIMEngine(prob, mesh, rng="lfsr", precision="bitplane",
                       replicas=32)
    txt = e.lower_chunk(iters=2, S=2, sync=2).as_text()
    assert "all_gather" in txt
    # every all-gather in the chunk ships uint32 words
    ag = [ln for ln in txt.splitlines() if "all_gather" in ln]
    assert ag and all("ui32" in ln for ln in ag)
    assert "xi8" not in txt and "xui8" not in txt
    assert "tensor<i8>" not in txt and "tensor<ui8>" not in txt
    # the f32 path, by contrast, bit-packs into uint8 bitmaps (pack compute
    # on the collective path) — the compute the word format deletes
    f = DistDSIMEngine(prob, mesh, rng="lfsr", replicas=2)
    ftxt = f.lower_chunk(iters=2, S=2, sync=2).as_text()
    assert "xui8" in ftxt or "xi8" in ftxt


def test_dist_bitplane_snapshot_restore_roundtrip():
    from repro.core.snapshot import snapshot_state, restore_state
    g, prob, mesh = _k1()
    e = DistDSIMEngine(prob, mesh, rng="lfsr", precision="bitplane",
                       replicas=4)
    sch = ea_schedule(32)
    st, _ = e.run_recorded(e.init_state(seed=2), sch, [16], sync_every=4)
    snap = snapshot_state(st)
    st2 = e.shard_state(restore_state(snap))
    a, _ = e.run_recorded(st, sch, [16], sync_every=4)
    b, _ = e.run_recorded(st2, sch, [16], sync_every=4)
    assert (np.asarray(e.global_spins(a)) ==
            np.asarray(e.global_spins(b))).all()


# -- serving --------------------------------------------------------------------

def test_server_dist_bitplane_job_and_register_time_prewarm():
    """Graph-registered problems carry array kwargs (labels); the pool key
    must hash them by content (regression: every mesh-engine job used to
    die at the cache probe with 'unhashable type: numpy.ndarray').  With
    ``prewarm_bitplane=True`` the one R=32 word executable is built at
    register time, so the first bit-plane tenant is not a cold start, and
    its lanes are its own chains (prefix-stable padding to the word)."""
    from repro.serve.server import SampleServer
    g = ea3d(4, seed=0)
    srv = SampleServer(pack=True, warm_compile=False)
    srv.register_problem("g4", graph=g, coloring=lattice3d_coloring(4),
                         K=1, labels=np.zeros(g.n, np.int32), rng="lfsr",
                         prewarm_bitplane=True)
    assert len(srv.prewarm_threads) == 1
    srv.prewarm_threads[0].join(timeout=400)
    assert not srv.prewarm_threads[0].is_alive()
    j = srv.submit("g4", engine="dsim_dist", precision="bitplane",
                   replicas=8, sweeps=16, sync_every=4, seed=2)
    r = srv.result(j)
    assert r["status"] == "done"
    assert r["cold_start"] is False          # register-time prewarm hit
    assert r["energies"].shape[1] == 8       # own lanes only, pad dropped
    # the engine ran at the full word width (one executable for all packs)
    e = make_engine("dsim_dist", g, coloring=lattice3d_coloring(4), K=1,
                    labels=np.zeros(g.n, np.int32), rng="lfsr",
                    precision="bitplane", replicas=8)
    st = e.init_state(seed=2)
    st, rec = e.run_recorded(st, ea_schedule(16), [16], sync_every=4)
    np.testing.assert_array_equal(np.asarray(rec.energies[-1]),
                                  r["energies"][-1])
    # the f32 dist path serves through the same (now hashable) pool key
    j2 = srv.submit("g4", engine="dsim_dist", sweeps=16, sync_every=4,
                    seed=3)
    assert srv.result(j2)["status"] == "done"


# -- multi-device subprocess tests (forced host device count) ----------------

def test_2dev_word_boundaries_bit_equal_to_int8_across_sync():
    """Satellite: on a real 2-device mesh, the native-word boundary
    all-gather reproduces the unpacked int8 dist path bit-for-bit on all
    32 lanes, for every exchange cadence {1, 4, 'phase'}."""
    out = run_py("""
        import numpy as np
        from repro.core.graph import ea3d
        from repro.core.coloring import lattice3d_coloring
        from repro.core.partition import slab_partition
        from repro.core.dsim import build_partitioned
        from repro.core.dsim_dist import DistDSIMEngine
        from repro.core.annealing import ea_schedule
        from repro.compat import make_mesh, auto_axes
        L = 4
        g = ea3d(L, seed=7); col = lattice3d_coloring(L)
        prob = build_partitioned(g, col, slab_partition(L, 2), 2)
        mesh = make_mesh((2,), ("data",), axis_types=auto_axes(1))
        sch = ea_schedule(96)
        for sync in (1, 4, "phase"):
            outs = {}
            for prec in ("int8", "bitplane"):
                e = DistDSIMEngine(prob, mesh, rng="lfsr", precision=prec,
                                   replicas=32)
                st = e.init_state(seed=3)
                st, rec = e.run_recorded_full(st, sch, [32, 96],
                                              sync_every=sync)
                outs[prec] = (np.asarray(e.global_spins(st)),
                              np.asarray(rec.energies), rec.flips)
            m8, E8, f8 = outs["int8"]; mw, Ew, fw = outs["bitplane"]
            ok = bool((m8 == mw).all()) and bool((E8 == Ew).all()) \\
                and f8 == fw
            print(f"SYNC {sync} BITWISE {ok} flips {fw}")
    """)
    assert out.count("BITWISE True") == 3


def test_2dev_multiword_boundaries_bit_equal_to_int8_across_sync():
    """Tentpole gate: on a real 2-device mesh, the W=2 (R=40) native-word
    boundary all-gather — two stacked uint32 planes per boundary site on
    the wire — reproduces the unpacked int8 dist path bit-for-bit on all
    40 lanes, for exchange cadences {1, 'phase'}."""
    out = run_py("""
        import numpy as np
        from repro.core.graph import ea3d
        from repro.core.coloring import lattice3d_coloring
        from repro.core.partition import slab_partition
        from repro.core.dsim import build_partitioned
        from repro.core.dsim_dist import DistDSIMEngine
        from repro.core.annealing import ea_schedule
        from repro.compat import make_mesh, auto_axes
        L = 4
        g = ea3d(L, seed=7); col = lattice3d_coloring(L)
        prob = build_partitioned(g, col, slab_partition(L, 2), 2)
        mesh = make_mesh((2,), ("data",), axis_types=auto_axes(1))
        sch = ea_schedule(48)
        for sync in (1, "phase"):
            outs = {}
            for prec in ("int8", "bitplane"):
                e = DistDSIMEngine(prob, mesh, rng="lfsr", precision=prec,
                                   replicas=40)
                st = e.init_state(seed=3)
                st, rec = e.run_recorded_full(st, sch, [24, 48],
                                              sync_every=sync)
                outs[prec] = (np.asarray(e.global_spins(st)),
                              np.asarray(rec.energies), rec.flips)
            m8, E8, f8 = outs["int8"]; mw, Ew, fw = outs["bitplane"]
            ok = bool((m8 == mw).all()) and bool((E8 == Ew).all()) \\
                and f8 == fw
            print(f"SYNC {sync} BITWISE {ok} flips {fw}")
    """)
    assert out.count("BITWISE True") == 2


def test_2dev_cmft_phase_publishes_instantaneous_boundaries():
    """Satellite regression: cmft mode with sync_every='phase' used to
    publish macc/1 — all-zero ghost means right after every window reset.
    Per-phase refreshes must publish the instantaneous states (exactly the
    stacked engine's semantics), and no all-zero ghost payload may ever be
    exchanged after init."""
    out = run_py("""
        import numpy as np
        from repro.core.graph import ea3d
        from repro.core.coloring import lattice3d_coloring
        from repro.core.partition import slab_partition
        from repro.core.dsim import build_partitioned, DSIMEngine
        from repro.core.dsim_dist import DistDSIMEngine
        from repro.core.annealing import ea_schedule
        from repro.compat import make_mesh, auto_axes
        L = 4
        g = ea3d(L, seed=5); col = lattice3d_coloring(L)
        prob = build_partitioned(g, col, slab_partition(L, 2), 2)
        mesh = make_mesh((2,), ("data",), axis_types=auto_axes(1))
        sch = ea_schedule(64)
        d = DistDSIMEngine(prob, mesh, rng="lfsr", mode="cmft")
        sd = d.init_state(seed=3)
        sd, (_, Ed) = d.run_recorded(sd, sch, [64], sync_every="phase")
        s = DSIMEngine(prob, rng="lfsr", mode="cmft")
        ss = s.init_state(seed=3)
        ss, (_, Es) = s.run_recorded(ss, sch, [64], sync_every="phase")
        md = np.asarray(d.global_spins(sd)); ms = np.asarray(s.global_spins(ss))
        print("BITWISE", bool((md == ms).all()))
        gh = np.asarray(sd.ghosts)
        print("GHOSTS_PM1", bool((np.abs(gh) == 1.0).all()))
    """)
    assert "BITWISE True" in out
    assert "GHOSTS_PM1 True" in out
