"""Fixed-point p-bit pipeline: quantization, threshold LUTs, integer
kernels, and the precision="int8" engine path.

Three layers of guarantees:
  * bit-exact — the Pallas integer kernels against their jnp oracles
    (identical integer op sequences);
  * structural — LUT monotonicity (in beta down the staircase AND in the
    field along a row, the invariant the rank-count accept relies on),
    exact +-J quantization, row-index mapping;
  * statistical — precision="int8" and "f32" are different arithmetic, so
    trajectories diverge; their *ensembles* must not (EA3D residual-energy
    and flip-probability tolerance test).
"""

import warnings

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.pbit import (S41, LFSR_UNIFORM_BITS, quantize_couplings,
                             field_bound, threshold_lut, lut_accept)
from repro.core.annealing import (ArraySchedule, beta_table,
                                  beta_row_indices, ea_schedule,
                                  replica_beta_arrays)
from repro.core.lattice import build_ea3d_lattice
from repro.core.lattice_dsim import (LatticeDSIM, fused_brick_ceiling,
                                     fused_working_set_bytes)
from repro.compat import make_mesh, auto_axes
from repro.engines import make_engine
from repro.kernels.ops import pbit_update_int_op, pbit_sweep_int_op
from repro.kernels.ref import (pbit_brick_update_int_ref,
                               pbit_brick_sweep_int_ref)

RNG = np.random.default_rng(11)
HALF = 1 << (LFSR_UNIFORM_BITS - 1)


def make_int_inputs(shape, n_betas=3, hscale=0.1):
    Bx, By, Bz = shape
    m = jnp.asarray(RNG.choice([-1, 1], size=shape).astype(np.int8))
    s = jnp.asarray(RNG.integers(1, 2 ** 32, size=shape, dtype=np.uint32))
    h = RNG.normal(0, hscale, shape).astype(np.float32)
    w6 = [RNG.choice([-1.0, 0.0, 1.0], size=shape).astype(np.float32)
          for _ in range(6)]
    h_q, w6_q, scale = quantize_couplings(h, w6)
    lut = jnp.asarray(threshold_lut(np.linspace(0.4, 4.0, n_betas), scale,
                                    field_bound(h_q, w6_q)))
    halos = tuple(jnp.asarray(RNG.choice([-1, 1], sh).astype(np.int8))
                  for sh in [(By, Bz), (By, Bz), (Bx, Bz), (Bx, Bz),
                             (Bx, By), (Bx, By)])
    par = jnp.asarray((RNG.random(shape) < 0.5).astype(np.int8))
    return m, s, h_q, w6_q, halos, par, lut


# -- quantization -------------------------------------------------------------

def test_quantize_pm_j_exact():
    """+-J couplings quantize exactly, GCD-reduced to +-1 (scale folds it)."""
    p = build_ea3d_lattice(6, seed=0)
    h_q, w6_q, scale = quantize_couplings(p.h, p.w6)
    assert scale == 1.0
    for w, wq in zip(p.w6, w6_q):
        assert set(np.unique(np.asarray(wq))) <= {-1, 0, 1}
        np.testing.assert_array_equal(np.asarray(wq) * scale, np.asarray(w))
    assert field_bound(h_q, w6_q) == 6


def test_quantize_generic_error_bound():
    shape = (4, 4, 4)
    h = RNG.normal(0, 0.3, shape).astype(np.float32)
    w6 = [RNG.normal(0, 1.0, shape).astype(np.float32) for _ in range(6)]
    h_q, w6_q, scale = quantize_couplings(h, w6)
    for orig, q in zip([h] + w6, [h_q] + list(w6_q)):
        q = np.asarray(q, np.float64)
        assert np.abs(q).max() <= 127
        assert np.abs(q * scale - orig).max() <= scale / 2 + 1e-12


# -- threshold LUT structure --------------------------------------------------

def test_lut_monotone_in_beta():
    """Down the staircase (beta rising): thresholds fall for positive
    fields, rise for negative fields, and the zero-field column is the
    exact coin flip 2^23."""
    betas = np.arange(0.5, 5.01, 0.5)
    f_max = 6
    lut = threshold_lut(betas, 1.0, f_max).astype(np.int64)
    center = f_max
    assert (lut[:, center] == HALF).all()
    pos = lut[:, center + 1:]
    neg = lut[:, :center]
    assert (np.diff(pos, axis=0) <= 0).all()
    assert (np.diff(neg, axis=0) >= 0).all()
    assert lut.min() >= 0 and lut.max() <= (1 << LFSR_UNIFORM_BITS)


def test_lut_monotone_in_field_rowwise():
    """Each row nonincreasing in the field — the rank-count invariant."""
    lut = threshold_lut(np.arange(0.5, 5.01, 0.5), 0.03, 50,
                        fmt=S41).astype(np.int64)
    assert (np.diff(lut, axis=1) <= 0).all()


def test_lut_rejects_negative_beta():
    with pytest.raises(ValueError):
        threshold_lut([-0.5, 1.0], 1.0, 4)


@pytest.mark.parametrize("width", [13, 201])
def test_lut_accept_equals_direct_lookup(width):
    """Rank-count accept (narrow) and gather fallback (wide) both equal the
    definition u >= thr[field + f_off]."""
    f_max = (width - 1) // 2
    thr = jnp.asarray(threshold_lut([1.3], 1.0 / max(f_max, 1), f_max)[0])
    field = jnp.asarray(RNG.integers(-f_max, f_max + 1, size=(9, 7)),
                        jnp.int32)
    u = jnp.asarray(RNG.integers(0, 1 << LFSR_UNIFORM_BITS, size=(9, 7),
                                 dtype=np.uint32))
    got = np.asarray(lut_accept(thr, field, f_max, u))
    want = np.asarray(u) >= np.asarray(thr)[np.asarray(field) + f_max]
    np.testing.assert_array_equal(got, want)


# -- staircase -> row indices -------------------------------------------------

def test_beta_row_indices_round_trip():
    sch = ea_schedule(100)
    arr = sch.beta_array()
    table = beta_table(arr)
    rows = beta_row_indices(arr, table)
    np.testing.assert_array_equal(table[rows], arr)
    # per-replica fans map elementwise, any shape
    bR = replica_beta_arrays(sch, 4, spread=0.25)
    tR = beta_table(bR)
    rR = beta_row_indices(bR, tR)
    assert rR.shape == bR.shape and rR.dtype == np.int32
    np.testing.assert_array_equal(tR[rR], bR)


def test_beta_row_indices_unknown_beta_rejected():
    with pytest.raises(ValueError):
        beta_row_indices(np.array([0.5, 0.7]), np.array([0.5, 1.0]))


def test_array_schedule_preserves_dtype_and_shape():
    rows = np.arange(12, dtype=np.int32).reshape(6, 2)
    sched = ArraySchedule(rows)
    assert sched.total_sweeps == 6
    assert sched.beta_array().dtype == np.int32


# -- integer kernels vs jnp oracles (bit-exact) -------------------------------

@pytest.mark.parametrize("shape,bx", [
    ((8, 4, 4), 2), ((8, 4, 4), 8), ((16, 8, 8), 4), ((6, 3, 5), 3),
])
def test_int_update_kernel_matches_ref(shape, bx):
    m, s, h_q, w6_q, halos, par, lut = make_int_inputs(shape)
    m1, s1 = pbit_update_int_op(m, s, 1, par, h_q, w6_q, halos, lut, bx=bx,
                                impl="interpret")
    m2, s2 = pbit_brick_update_int_ref(m, s, 1, par, h_q, w6_q, halos, lut)
    assert (np.asarray(m1) == np.asarray(m2)).all()
    assert (np.asarray(s1) == np.asarray(s2)).all()


def test_int_sweep_kernel_matches_ref_and_per_phase():
    shape = (8, 4, 4)
    m, s, h_q, w6_q, halos, par, lut = make_int_inputs(shape)
    masks = np.zeros((2,) + shape, np.int8)
    masks[0][(np.indices(shape).sum(0) % 2) == 0] = 1
    masks[1] = 1 - masks[0]
    masks = jnp.asarray(masks)
    rows = jnp.asarray([0, 2, 1, 2], jnp.int32)
    got = pbit_sweep_int_op(m, s, rows, masks, h_q, w6_q, halos, lut,
                            impl="interpret")
    want = pbit_brick_sweep_int_ref(m, s, rows, masks, h_q, w6_q, halos, lut)
    for a, b in zip(got, want):
        assert (np.asarray(a) == np.asarray(b)).all()
    # the fused launch == chained per-phase launches (both Pallas)
    mc, sc = m, s
    fl = 0
    for t in range(rows.shape[0]):
        for c in range(2):
            m2, sc = pbit_update_int_op(mc, sc, rows[t], masks[c], h_q,
                                        w6_q, halos, lut, impl="interpret")
            fl += int((np.asarray(m2) != np.asarray(mc)).sum())
            mc = m2
    assert (np.asarray(got[0]) == np.asarray(mc)).all()
    assert (np.asarray(got[1]) == np.asarray(sc)).all()
    assert int(got[2]) == fl


def test_int_engine_ref_vs_interpret_bitexact():
    """The whole int8 engine path agrees bit-for-bit between the jnp
    oracle impl and the Pallas interpreter impl."""
    prob = build_ea3d_lattice(4, seed=3)
    mesh = make_mesh((1,), ("data",), axis_types=auto_axes(1))
    outs = []
    for impl in ("ref", "interpret"):
        eng = LatticeDSIM(prob, mesh, dim_axes=("data", None, None),
                          precision="int8", impl=impl)
        st = eng.init_state(seed=5)
        st, _ = eng.run_recorded(st, ea_schedule(8), [8], sync_every=4)
        outs.append(st)
    assert (np.asarray(outs[0].m) == np.asarray(outs[1].m)).all()
    assert (np.asarray(outs[0].s) == np.asarray(outs[1].s)).all()


# -- statistical equivalence int8 vs f32 --------------------------------------

def test_int8_statistically_matches_f32_ea3d():
    """Same EA3D instance, same schedule, R independent replicas per
    precision: mean final (annealed) energy and aggregate flip probability
    must agree within ensemble tolerance.  (On +-J the quantization is
    exact, so the only difference is tanh-rounding in the accept rule —
    trajectories diverge chaotically but the ensembles must not.)"""
    R, SW = 6, 240
    res = {}
    for prec in ("f32", "int8"):
        h = make_engine("lattice", L=6, seed=7, impl="ref", replicas=R,
                        precision=prec)
        st = h.init_state(seed=1)
        st, rec = h.run_recorded(st, ea_schedule(SW), [SW], sync_every=4)
        res[prec] = (float(np.asarray(rec.energies[-1]).mean()), rec.flips)
    e_f32, fl_f32 = res["f32"]
    e_i8, fl_i8 = res["int8"]
    assert e_f32 < 0 and e_i8 < 0
    assert abs(e_i8 - e_f32) / abs(e_f32) < 0.05
    assert abs(fl_i8 - fl_f32) / fl_f32 < 0.10


def test_int8_flip_probability_matches_f32_at_fixed_beta():
    """Per-site flip probability over many sweeps at constant beta."""
    from repro.core.annealing import constant_schedule
    R, SW, L = 4, 200, 6
    prob = {}
    for prec in ("f32", "int8"):
        h = make_engine("lattice", L=L, seed=3, impl="ref", replicas=R,
                        precision=prec)
        st = h.init_state(seed=2)
        st, rec = h.run_recorded(st, constant_schedule(1.0, SW), [SW],
                                 sync_every=4)
        prob[prec] = rec.flips / (L ** 3 * R * SW)
    assert 0.02 < prob["f32"] < 0.95
    assert abs(prob["int8"] - prob["f32"]) < 0.02


def test_dsim_int8_statistically_matches_f32():
    from repro.core.graph import ea3d
    from repro.core.coloring import lattice3d_coloring
    from repro.core.partition import slab_partition
    g = ea3d(6, seed=7)
    col = lattice3d_coloring(6)
    labels = slab_partition(6, 2)
    means = {}
    for prec in ("f32", "int8"):
        h = make_engine("dsim", g, coloring=col, K=2, labels=labels,
                        rng="lfsr", precision=prec, replicas=4)
        st = h.init_state(seed=0)
        st, rec = h.run_recorded(st, ea_schedule(200), [200], sync_every=4)
        means[prec] = float(np.asarray(rec.energies[-1]).mean())
    assert means["int8"] < 0
    assert abs(means["int8"] - means["f32"]) / abs(means["f32"]) < 0.05


# -- per-replica staircases on the integer path -------------------------------

def test_per_replica_staircase_rides_int8_path():
    R = 3
    sch = ea_schedule(48)
    bR = replica_beta_arrays(sch, R, spread=0.3)
    outs = {}
    for prec in ("f32", "int8"):
        h = make_engine("lattice", L=6, seed=7, impl="ref", replicas=R,
                        precision=prec)
        st = h.init_state(seed=0)
        st, rec = h.eng.run_recorded_full(st, sch, [48], sync_every=4,
                                          betas_R=bR)
        outs[prec] = np.asarray(rec.energies[-1])
    assert outs["int8"].shape == (R,)
    # the annealing-rate fan actually differentiates the replicas
    assert len(np.unique(outs["int8"])) > 1
    # and the fanned ensembles agree across precisions
    assert abs(outs["int8"].mean() - outs["f32"].mean()) \
        / abs(outs["f32"].mean()) < 0.05


# -- VMEM working-set decision ------------------------------------------------

def test_fused_fallback_warns_and_is_exposed():
    prob = build_ea3d_lattice(6, seed=0)
    mesh = make_mesh((1,), ("data",), axis_types=auto_axes(1))
    with pytest.warns(RuntimeWarning, match="falling back"):
        eng = LatticeDSIM(prob, mesh, dim_axes=("data", None, None),
                          impl="ref", vmem_budget_bytes=1024)
    assert eng.kernel_path == "per_phase"
    assert eng.fallback_reason == "vmem"
    assert eng.fused_requested and not eng.fused
    # the fallback engine still runs (per-phase dispatch)
    st = eng.init_state(seed=0)
    st, rec = eng.run_recorded(st, ea_schedule(8), [8], sync_every=4)
    assert float(np.asarray(rec.energies[-1])) < 0


def test_fused_decision_default_budget_and_handle_exposure():
    with warnings.catch_warnings():
        warnings.simplefilter("error")           # no warning expected
        h = make_engine("lattice", L=6, seed=0, impl="ref")
    assert h.kernel_path == "fused"
    assert h.precision == "f32"
    h2 = make_engine("lattice", L=6, seed=0, impl="ref", precision="int8",
                     vmem_budget_bytes=1 << 14)  # 16 KiB: 6^3 int8 fits
    assert h2.kernel_path == "fused" and h2.precision == "int8"


def test_int8_raises_fused_brick_ceiling():
    """The point of the exercise: the quantized working set is smaller, so
    the same VMEM budget admits a strictly larger fused brick."""
    for n_c in (2, 3):
        assert fused_brick_ceiling(n_c, "int8") > fused_brick_ceiling(n_c,
                                                                      "f32")
    assert fused_brick_ceiling(2, "int8") >= 90      # the ~96^3 claim
    b = (32, 32, 32)
    assert fused_working_set_bytes(b, 3, "int8", lut_width=13) < \
        fused_working_set_bytes(b, 3, "f32")


# -- registry guards ----------------------------------------------------------

def test_wide_lut_rejected_on_pallas_impl():
    """Non-GCD-reducible couplings widen the LUT past the rank-count cap;
    the pallas target must refuse at init, not fail at first lowering."""
    import dataclasses
    base = build_ea3d_lattice(4, seed=0)
    wide = dataclasses.replace(
        base, h=jnp.asarray(RNG.normal(0, 1.0, base.dims), jnp.float32))
    mesh = make_mesh((1,), ("data",), axis_types=auto_axes(1))
    with pytest.raises(ValueError, match="rank-count"):
        LatticeDSIM(wide, mesh, dim_axes=("data", None, None),
                    precision="int8", impl="pallas")
    # the jnp paths keep working (gather fallback)
    eng = LatticeDSIM(wide, mesh, dim_axes=("data", None, None),
                      precision="int8", impl="ref")
    st = eng.init_state(seed=0)
    st, rec = eng.run_recorded(st, ea_schedule(8), [8], sync_every=4)
    assert np.isfinite(float(np.asarray(rec.energies[-1])))


def test_registry_precision_guards():
    from repro.core.graph import ea3d
    from repro.core.coloring import lattice3d_coloring
    g = ea3d(4, seed=0)
    col = lattice3d_coloring(4)
    with pytest.raises(ValueError):
        make_engine("gibbs", g, coloring=col, precision="int8")
    with pytest.raises(ValueError):
        make_engine("lattice", L=4, precision="fp4")
    with pytest.raises(ValueError):
        make_engine("dsim", g, coloring=col, K=2,
                    labels=np.zeros(g.n, np.int32), rng="philox",
                    precision="int8")
