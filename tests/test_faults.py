"""Fault tolerance: deterministic fault injection, poison-batch
bisection, retry/backoff, checkpoint resume, crash recovery, deadlines,
watchdog, and the engine-pool circuit breaker.

Every recovery path here is driven by a :class:`FaultPlan` — chosen chunk
indices, build steps, or boundary exchanges fail on command, so the tests
assert exact outcomes (which job failed, how many retries, bitwise-equal
traces) instead of sleeping and hoping."""

import os
import pickle
import subprocess
import sys
import tempfile

import numpy as np
import pytest

from repro.core.coloring import lattice3d_coloring
from repro.core.graph import ea3d
from repro.engines import make_engine
from repro.serve import (CheckpointSpool, CircuitOpen, EnginePool,
                         FaultPlan, FaultRule, PermanentFault, SampleServer,
                         StateCorruption, TransientFault, classify_error,
                         compute_backoff)

L = 5
SW = 64


@pytest.fixture(scope="module")
def problem():
    return ea3d(L, seed=1), lattice3d_coloring(L)


def _server(problem, **kw):
    g, col = problem
    srv = SampleServer(**kw)
    srv.register_problem("pa", graph=g, coloring=col, rng="lfsr")
    return srv


def _reference(problem, seeds):
    """No-fault runs at the given seeds: the bitwise ground truth."""
    srv = _server(problem)
    ids = [srv.submit("pa", engine="gibbs", sweeps=SW, replicas=2, seed=s)
           for s in seeds]
    srv.drain()
    return [srv.result(i) for i in ids]


def _assert_bitwise(r0, r):
    assert np.array_equal(r0["energies"], r["energies"])
    assert r0["flips"] == r["flips"]
    assert np.array_equal(r0["best_spins"], r["best_spins"])
    assert r0["best_energy"] == r["best_energy"]


# -- the harness itself --------------------------------------------------------

def test_fault_rule_validates():
    with pytest.raises(ValueError):
        FaultRule(site="nope")
    with pytest.raises(ValueError):
        FaultRule(site="chunk", action="explode")
    with pytest.raises(ValueError):
        FaultRule(site="chunk", kind="sideways")


def test_fault_plan_matching_and_budget():
    plan = FaultPlan([
        FaultRule(site="chunk", index=3, job="j1", times=2),
        FaultRule(site="build", key="gibbs", times=1),
    ])
    assert plan.fire("chunk", index=2, jobs=("j1",)) is None   # wrong index
    assert plan.fire("chunk", index=3, jobs=("j2",)) is None   # wrong job
    assert plan.fire("build", key=("pa", "dsim")) is None      # wrong key
    assert plan.fire("chunk", index=3, jobs=("j1",)) is not None
    assert plan.fire("build", key=("pa", "gibbs", 8)) is not None
    assert plan.fire("build", key=("pa", "gibbs", 8)) is None  # budget spent
    assert plan.fire("chunk", index=3, jobs=("j1", "j3")) is not None
    assert plan.fire("chunk", index=3, jobs=("j1",)) is None   # budget spent
    assert plan.fired == 3
    assert [e[0] for e in plan.events] == ["chunk", "build", "chunk"]


def test_fault_plan_after_and_apply_kinds():
    plan = FaultPlan([FaultRule(site="exchange", after=5, kind="permanent")])
    assert plan.fire("exchange", index=4) is None
    with pytest.raises(PermanentFault):
        plan.apply("exchange", index=7)
    plan2 = FaultPlan([FaultRule(site="chunk")])
    with pytest.raises(TransientFault):
        plan2.apply("chunk", index=0)


def test_fault_plan_rate_is_seeded_and_replayable():
    rules = [FaultRule(site="chunk", rate=0.3, times=None)]
    draws = []
    for plan in (FaultPlan(rules, seed=42), FaultPlan(rules, seed=42)):
        draws.append([plan.fire("chunk", index=i) is not None
                      for i in range(64)])
    assert draws[0] == draws[1]                 # same seed, same decisions
    assert 0 < sum(draws[0]) < 64               # actually probabilistic
    replay = FaultPlan(rules, seed=42).replay()
    assert [replay.fire("chunk", index=i) is not None
            for i in range(64)] == draws[0]


def test_classify_error_split():
    assert classify_error(TransientFault("x")) == "transient"
    assert classify_error(StateCorruption("x")) == "transient"
    assert classify_error(TimeoutError()) == "transient"
    assert classify_error(CircuitOpen("x")) == "transient"
    assert classify_error(ConnectionError()) == "transient"
    assert classify_error(PermanentFault("x")) == "permanent"
    assert classify_error(ValueError("x")) == "permanent"
    assert classify_error(TypeError("x")) == "permanent"
    assert classify_error(RuntimeError("x")) == "transient"   # unknown


def test_compute_backoff():
    assert compute_backoff(0, base=0.0) == 0.0          # disabled
    assert compute_backoff(5, base=0.0, jitter=1.0) == 0.0
    seq = [compute_backoff(k, base=0.1, cap=1.0, jitter=0.0)
           for k in range(6)]
    assert seq == [pytest.approx(v)
                   for v in (0.1, 0.2, 0.4, 0.8, 1.0, 1.0)]   # capped
    a = compute_backoff(2, base=0.1, jitter=0.5, seed=7)
    assert a == compute_backoff(2, base=0.1, jitter=0.5, seed=7)
    assert a != compute_backoff(2, base=0.1, jitter=0.5, seed=8)
    assert 0.4 <= a <= 0.6 * (1 + 1e-9)


# -- checkpoint spool ----------------------------------------------------------

def test_spool_put_load_supersede(tmp_path):
    sp = CheckpointSpool(str(tmp_path))
    d1 = sp.put({"token": ("t",), "sweeps_done": 8})
    assert sp.load(d1) == {"token": ("t",), "sweeps_done": 8}
    assert d1 == sp.put({"token": ("t",), "sweeps_done": 8})  # idempotent
    assert len(sp) == 1
    d2 = sp.put({"token": ("t",), "sweeps_done": 16}, replaces=d1)
    assert len(sp) == 1 and d2 != d1
    assert [d for d, _ in sp.records()] == [d2]


def test_spool_cap_evicts_oldest(tmp_path):
    blob = os.urandom(2048)
    sp = CheckpointSpool(str(tmp_path), max_bytes=5000)
    digests = []
    for i in range(4):
        digests.append(sp.put({"i": i, "blob": blob}))
        os.utime(sp._path(digests[-1]), (i, i))   # deterministic age order
    assert sp.evictions > 0 and sp.nbytes() <= 5000 + 3000
    kept = {d for d, _ in sp.records()}
    assert digests[-1] in kept                    # newest never evicted
    assert digests[0] not in kept


def test_spool_skips_unreadable(tmp_path):
    sp = CheckpointSpool(str(tmp_path))
    d = sp.put({"ok": True})
    with open(os.path.join(str(tmp_path), "garbage.ck"), "wb") as f:
        f.write(b"\x80\x05not a pickle")
    (tmp_path / "litter.tmp").write_bytes(b"x")
    assert [dd for dd, _ in sp.records()] == [d]


# -- cursor checkpoint/restore (engine layer) ---------------------------------

def test_cursor_checkpoint_restore_bitwise(problem):
    g, col = problem
    from repro.core.annealing import ea_schedule
    sched, pts = ea_schedule(SW), [16, 32, 48, SW]
    h = make_engine("gibbs", g, coloring=col, replicas=2, rng="lfsr")
    cur = h.start_recorded(h.init_state(seed=3), sched, pts)
    while cur.sweeps_done < SW // 2:
        cur.advance(1)
    ck = pickle.loads(pickle.dumps(cur.checkpoint()))   # survives pickling
    while not cur.done:
        cur.advance(1)
    ref = cur.record()

    h2 = make_engine("gibbs", g, coloring=col, replicas=2, rng="lfsr")
    cur2 = h2.start_recorded(h2.init_state(seed=999), sched, pts)
    cur2.restore_checkpoint(ck)
    assert cur2.sweeps_done == ck["pos"]
    while not cur2.done:
        cur2.advance(1)
    got = cur2.record()
    assert np.array_equal(ref.times, got.times)
    assert np.array_equal(np.asarray(ref.energies), np.asarray(got.energies))
    assert ref.flips == got.flips
    # mismatched plan refuses to resume
    h3 = make_engine("gibbs", g, coloring=col, replicas=2, rng="lfsr")
    cur3 = h3.start_recorded(h3.init_state(seed=0), ea_schedule(SW * 2),
                             [SW * 2])
    with pytest.raises(ValueError):
        cur3.restore_checkpoint(ck)


# -- engine-pool circuit breaker ----------------------------------------------

def test_breaker_opens_fast_fails_and_half_opens():
    clk = [0.0]
    pool = EnginePool(4, breaker_threshold=2, breaker_cooldown_s=10.0,
                      clock=lambda: clk[0])
    calls = [0]

    def bad():
        calls[0] += 1
        raise RuntimeError("compile died")

    for _ in range(2):
        with pytest.raises(RuntimeError):
            pool.get(("k",), bad)
    assert calls[0] == 2
    with pytest.raises(CircuitOpen) as ei:
        pool.get(("k",), bad)
    assert calls[0] == 2                 # fast-fail: builder not called
    assert "compile died" in str(ei.value)
    s = pool.stats()
    assert s["failed_builds"] == 2 and s["fast_fails"] == 1
    assert s["open_circuits"] == 1 and "compile died" in s["last_error"]
    assert pool.breaker_state(("k",))["fails"] == 2
    clk[0] = 11.0                        # cooldown elapsed: half-open probe
    handle, hit = pool.get(("k",), lambda: "fresh")
    assert handle == "fresh" and not hit
    assert pool.breaker_state(("k",)) is None   # success closed it
    assert pool.stats()["open_circuits"] == 0


def test_prewarm_async_failure_surfaced_in_stats(problem):
    pool = EnginePool(4)

    def bad():
        raise RuntimeError("prewarm build exploded")

    t = pool.prewarm_async(("pk",), bad)
    t.join()
    assert t.error is not None
    s = pool.stats()
    assert s["failed_builds"] == 1
    assert "prewarm build exploded" in s["last_error"]
    # end-to-end: an injected build fault in SampleServer.prewarm shows in
    # SampleServer.stats() even when nobody joins the thread
    plan = FaultPlan([FaultRule(site="build", kind="permanent", times=2)])
    srv = _server(problem, fault_plan=plan)
    th = srv.prewarm("pa", engine="gibbs", replicas=2, sweeps=SW)
    th.join()
    ps = srv.stats()["pool"]
    assert ps["failed_builds"] >= 1 and "injected" in ps["last_error"]
    with pytest.raises(PermanentFault):
        srv.prewarm("pa", engine="gibbs", replicas=2, sweeps=SW, wait=True)


# -- poison-batch isolation ----------------------------------------------------

def test_poison_batch_bisect_isolates_culprit(problem):
    """The acceptance scenario: 8 packed jobs, one poisoned — exactly the
    poison job fails; the 7 innocents finish DONE, bitwise-equal to the
    no-fault run."""
    refs = _reference(problem, range(8))
    plan = FaultPlan([FaultRule(site="chunk", job="job-000003",
                                kind="permanent", times=None)])
    srv = _server(problem, max_replicas_per_call=16, fault_plan=plan)
    ids = [srv.submit("pa", engine="gibbs", sweeps=SW, replicas=2, seed=s)
           for s in range(8)]
    srv.drain()
    assert [srv.poll(i)["status"] for i in ids] == \
        ["done"] * 3 + ["failed"] + ["done"] * 4
    assert "PermanentFault" in srv.poll(ids[3])["error"]
    for k, (jid, r0) in enumerate(zip(ids, refs)):
        if k != 3:
            _assert_bitwise(r0, srv.result(jid))
    s = srv.stats()
    assert s["completed"] == 7 and s["failed"] == 1
    assert s["quarantined_batches"] >= 1 and s["bisect_requeues"] >= 2
    assert s["bisect_calls_left"] >= 0
    assert s["queue_depth"] == 0 and s["inflight_batches"] == 0


def test_bisect_isolated_transient_culprit_retries(problem):
    """Bisection narrows to the culprit; if its fault was transient with
    budget left, the culprit itself retries solo and completes too."""
    refs = _reference(problem, range(4))
    plan = FaultPlan([FaultRule(site="chunk", job="job-000002",
                                kind="transient", times=3)])
    srv = _server(problem, max_replicas_per_call=16, fault_plan=plan,
                  max_retries=3)
    ids = [srv.submit("pa", engine="gibbs", sweeps=SW, replicas=2, seed=s)
           for s in range(4)]
    srv.drain()
    assert all(srv.poll(i)["status"] == "done" for i in ids)
    for jid, r0 in zip(ids, refs):
        _assert_bitwise(r0, srv.result(jid))
    assert srv.poll(ids[2])["retries"] >= 1


def test_fail_batch_accounting_when_bisect_disabled(problem):
    """_fail_batch direct coverage: with no bisect budget a poisoned
    packed batch fails every tenant — per-job error strings, correct
    stats, clean queue/_batches bookkeeping, and the server still serves
    afterwards."""
    plan = FaultPlan([FaultRule(site="chunk", kind="permanent")])
    srv = _server(problem, max_replicas_per_call=16, fault_plan=plan,
                  max_bisect_calls=0, max_retries=0)
    ids = [srv.submit("pa", engine="gibbs", sweeps=SW, replicas=2, seed=s)
           for s in range(3)]
    srv.drain()
    for jid in ids:
        p = srv.poll(jid)
        assert p["status"] == "failed"
        assert p["error"] == ("PermanentFault: injected permanent fault "
                              "at chunk[0]")
    s = srv.stats()
    assert s["failed"] == 3 and s["completed"] == 0
    assert s["queue_depth"] == 0 and s["inflight_batches"] == 0
    assert len(srv._batches) == 0 and len(srv._queue) == 0
    # the server is not wedged: later work completes normally
    jid = srv.submit("pa", engine="gibbs", sweeps=SW, replicas=2, seed=9)
    srv.drain()
    assert srv.result(jid)["status"] == "done"
    assert srv.stats()["failed"] == 3 and srv.stats()["completed"] == 1


# -- retry policy --------------------------------------------------------------

def test_transient_retry_resumes_from_checkpoint(problem):
    [r0] = _reference(problem, [7])
    plan = FaultPlan([FaultRule(site="chunk", index=3)])
    srv = _server(problem, fault_plan=plan, checkpoint_every=SW // 8)
    jid = srv.submit("pa", engine="gibbs", sweeps=SW, replicas=2, seed=7)
    srv.drain()
    r = srv.result(jid)
    assert r["status"] == "done" and r["retries"] == 1
    assert r["resumed_sweeps"] > 0 and r["restarted_sweeps"] == 0
    _assert_bitwise(r0, r)
    s = srv.stats()
    assert s["retries"] == 1 and s["checkpoints_resumed"] == 1
    assert s["checkpoints_written"] >= 1 and s["faults_injected"] == 1


def test_transient_retry_without_checkpoint_restarts(problem):
    [r0] = _reference(problem, [7])
    plan = FaultPlan([FaultRule(site="chunk", index=3)])
    srv = _server(problem, fault_plan=plan)      # checkpointing off
    jid = srv.submit("pa", engine="gibbs", sweeps=SW, replicas=2, seed=7)
    srv.drain()
    r = srv.result(jid)
    assert r["status"] == "done" and r["retries"] == 1
    assert r["restarted_sweeps"] > 0 and r["resumed_sweeps"] == 0
    _assert_bitwise(r0, r)


def test_permanent_fault_never_retries(problem):
    plan = FaultPlan([FaultRule(site="chunk", kind="permanent")])
    srv = _server(problem, fault_plan=plan, max_retries=5)
    jid = srv.submit("pa", engine="gibbs", sweeps=SW, replicas=2, seed=7)
    srv.drain()
    p = srv.poll(jid)
    assert p["status"] == "failed" and p["retries"] == 0
    assert "PermanentFault" in p["error"]
    assert srv.stats()["retries"] == 0


def test_retry_budget_exhausts(problem):
    plan = FaultPlan([FaultRule(site="chunk", times=None)])  # always fails
    srv = _server(problem, fault_plan=plan)
    jid = srv.submit("pa", engine="gibbs", sweeps=SW, replicas=2, seed=7,
                     max_retries=2)
    srv.drain()
    p = srv.poll(jid)
    assert p["status"] == "failed" and p["retries"] == 2
    assert "TransientFault" in p["error"]
    assert srv.stats()["retries"] == 2


def test_backoff_gates_retry_and_pump_stays_live(problem):
    plan = FaultPlan([FaultRule(site="chunk", index=1)])
    srv = _server(problem, fault_plan=plan, retry_backoff_s=0.03,
                  retry_jitter=0.0)
    jid = srv.submit("pa", engine="gibbs", sweeps=SW, replicas=2, seed=7)
    # drive manually: after the injected failure the job is queued but
    # gated; pump() must keep returning True (runnable work exists) until
    # the gate opens, never False (which would end drain() early)
    while srv.poll(jid)["retries"] == 0:
        assert srv.pump()
    job = srv._jobs[jid]
    assert job.next_eligible_at > 0.0
    srv.drain()
    assert srv.result(jid)["status"] == "done"


def test_injected_build_fault_trips_pool_breaker(problem):
    plan = FaultPlan([FaultRule(site="build", times=None)])
    srv = _server(problem, fault_plan=plan, max_retries=1,
                  breaker_threshold=2, breaker_cooldown_s=3600.0)
    jid = srv.submit("pa", engine="gibbs", sweeps=SW, replicas=2, seed=7)
    srv.drain()
    p = srv.poll(jid)
    assert p["status"] == "failed" and p["retries"] == 1
    s = srv.stats()["pool"]
    assert s["failed_builds"] == 2 and "injected" in s["last_error"]
    # the circuit is now open: the next submit fast-fails without a build
    j2 = srv.submit("pa", engine="gibbs", sweeps=SW, replicas=2, seed=8,
                    max_retries=0)
    srv.drain()
    assert "CircuitOpen" in srv.poll(j2)["error"]
    assert srv.stats()["pool"]["fast_fails"] >= 1


# -- deadlines and watchdog ----------------------------------------------------

def test_running_deadline_fails_job_spares_packmates(problem):
    refs = _reference(problem, [0, 1])
    srv = _server(problem, max_replicas_per_call=16)
    a = srv.submit("pa", engine="gibbs", sweeps=SW, replicas=2, seed=0)
    b = srv.submit("pa", engine="gibbs", sweeps=SW, replicas=2, seed=1,
                   deadline_s=0.0)
    srv.pump()                    # starts the packed batch, first chunk
    srv.drain()
    pb = srv.poll(b)
    assert pb["status"] == "failed" and "DeadlineExceeded" in pb["error"]
    assert pb["sweeps_done"] < SW
    ra = srv.result(a)            # packmate unharmed, still bitwise-clean
    assert ra["status"] == "done"
    _assert_bitwise(refs[0], ra)
    assert srv.stats()["deadline_failures"] == 1


def test_queued_deadline_expires_before_running(problem):
    srv = _server(problem)
    jid = srv.submit("pa", engine="gibbs", sweeps=SW, replicas=2, seed=0,
                     deadline_s=0.0)
    # the expiry happens inside the scheduling step; with nothing left to
    # run afterwards, that same pump reports no runnable work
    assert srv.pump() is False
    p = srv.poll(jid)
    assert p["status"] == "failed" and "DeadlineExceeded" in p["error"]
    assert p["sweeps_done"] == 0
    assert srv.stats()["deadline_failures"] == 1
    with pytest.raises(ValueError):
        srv.submit("pa", sweeps=SW, deadline_s=-1.0)


def test_watchdog_marks_stuck_chunk_suspect(problem):
    plan = FaultPlan([FaultRule(site="chunk", action="hang", index=2,
                                hang_s=0.05)])
    srv = _server(problem, fault_plan=plan, chunk_timeout_s=0.02)
    jid = srv.submit("pa", engine="gibbs", sweeps=SW, replicas=2, seed=7)
    srv.drain()
    assert srv.result(jid)["status"] == "done"   # slow, not failed
    s = srv.stats()
    assert s["stuck_chunks"] >= 1
    assert s["pool"]["suspect_keys"] == 1
    key, reason = next(iter(srv.pool.suspects().items()))
    assert "chunk_timeout_s" in reason
    assert srv.pool.clear_suspect(key)
    assert srv.stats()["pool"]["suspect_keys"] == 0


# -- corruption ----------------------------------------------------------------

def test_corruption_detected_and_repaired_from_checkpoint(problem):
    [r0] = _reference(problem, [7])
    plan = FaultPlan([FaultRule(site="chunk", action="corrupt", index=4)])
    srv = _server(problem, fault_plan=plan, checkpoint_every=SW // 8)
    jid = srv.submit("pa", engine="gibbs", sweeps=SW, replicas=2, seed=7)
    srv.drain()
    r = srv.result(jid)
    assert r["status"] == "done" and r["retries"] == 1
    _assert_bitwise(r0, r)
    s = srv.stats()
    assert s["corrupted_chunks"] == 1 and s["checkpoints_resumed"] == 1


# -- crash recovery ------------------------------------------------------------

_CHILD = """
import os, sys
sys.path.insert(0, {src!r})
from repro.core.coloring import lattice3d_coloring
from repro.core.graph import ea3d
from repro.serve import SampleServer
g, col = ea3d({L}, seed=1), lattice3d_coloring({L})
srv = SampleServer(spool_dir={spool!r}, checkpoint_every={ck})
srv.register_problem("pa", graph=g, coloring=col, rng="lfsr")
for s in (7, 8):
    print(srv.submit("pa", engine="gibbs", sweeps={SW}, replicas=2, seed=s),
          flush=True)
while srv.stats()["checkpoints_written"] < 3:
    srv.pump()
os.kill(os.getpid(), 9)      # no atexit, no cleanup: a real crash
"""


def test_kill9_recover_resumes_bitwise(problem, tmp_path):
    """The acceptance scenario: kill -9 a serving process mid-anneal;
    recover() re-admits every in-flight job from its last checkpoint and
    the finished results are bitwise-identical to an uninterrupted run."""
    spool = str(tmp_path / "spool")
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    child = _CHILD.format(src=os.path.abspath(src), L=L, SW=SW,
                          spool=spool, ck=SW // 8)
    p = subprocess.run([sys.executable, "-c", child], capture_output=True,
                       text=True, timeout=600)
    assert p.returncode == -9, p.stderr
    ids = p.stdout.split()
    assert len(ids) == 2
    assert len(CheckpointSpool(spool)) >= 1      # durable checkpoints exist

    refs = _reference(problem, [7, 8])
    srv = _server(problem, spool_dir=spool, checkpoint_every=SW // 8)
    got = srv.recover()
    assert sorted(got) == sorted(ids)
    for jid in got:                       # partial progress was recovered
        assert srv.poll(jid)["sweeps_done"] > 0
    srv.drain()
    for jid, r0 in zip(ids, refs):
        r = srv.result(jid)
        assert r["status"] == "done" and r["resumed_sweeps"] > 0
        _assert_bitwise(r0, r)
    s = srv.stats()
    assert s["recovered_jobs"] == 2 and s["checkpoints_resumed"] >= 1
    assert len(CheckpointSpool(spool)) == 0      # done jobs left no litter
    assert srv.recover() == []                   # idempotent


def test_recover_refuses_unregistered_or_mismatched_problem(problem,
                                                            tmp_path):
    spool = str(tmp_path / "spool")
    srv = _server(problem, spool_dir=spool, checkpoint_every=SW // 8)
    jid = srv.submit("pa", engine="gibbs", sweeps=SW, replicas=2, seed=7)
    while srv.stats()["checkpoints_written"] < 1:
        srv.pump()
    del jid

    fresh = SampleServer(spool_dir=spool)
    with pytest.raises(RuntimeError, match="not registered"):
        fresh.recover()
    g2, col2 = ea3d(L, seed=99), lattice3d_coloring(L)   # different instance
    fresh.register_problem("pa", graph=g2, coloring=col2, rng="lfsr")
    with pytest.raises(RuntimeError, match="fingerprint"):
        fresh.recover()


# -- result(timeout=) ----------------------------------------------------------

def test_result_timeout_default_leaves_job_running(problem):
    srv = _server(problem)
    jid = srv.submit("pa", engine="gibbs", sweeps=SW, replicas=2, seed=7)
    with pytest.raises(TimeoutError):
        srv.result(jid, timeout=0.0)
    assert srv.poll(jid)["status"] == "queued"   # untouched by default
    srv.drain()
    assert srv.result(jid)["status"] == "done"


def test_result_cancel_on_timeout_cancels(problem):
    srv = _server(problem)
    jid = srv.submit("pa", engine="gibbs", sweeps=SW, replicas=2, seed=7)
    with pytest.raises(TimeoutError):
        srv.result(jid, timeout=0.0, cancel_on_timeout=True)
    srv.drain()
    assert srv.poll(jid)["status"] == "cancelled"
    assert srv.stats()["cancelled"] == 1
