"""repro — Distributed Sparse Ising Machine (DSIM) framework in JAX.

Reproduction + extension of "Programmable Probabilistic Computer with
1,000,000 p-bits": partitioned Gibbs sampling where devices exchange
nothing but 1-bit boundary p-bit states, the eta = f_comm/f_p-bit staleness
rule, the CMFT software twin, and the full multi-pod LM substrate required
by the assigned architecture pool.  See DESIGN.md / EXPERIMENTS.md.
"""

__version__ = "1.0.0"
