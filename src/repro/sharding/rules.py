"""Sharding rules: logical tensor roles -> mesh PartitionSpecs.

Axes: 'model' = tensor parallel, 'data' (+ 'pod' when present) = batch /
FSDP.  Rules, per tensor role (leading n_groups scan dim gets None):

  embed (V, D)            V->model (vocab padded to 128), D->data if fsdp
  lm_head (D, V)          V->model, D->data if fsdp
  attn wq/wk/wv (D, HDh)  dim1->model, dim0->data if fsdp (column parallel)
  attn wo (HDh, D)        dim0->model, dim1->data if fsdp (row parallel)
  mlp wi/wg (D, F)        F->model, D fsdp;  mlp wo (F, D) F->model, D fsdp
  moe wi/wg (E, D, F)     F->model, D fsdp;  moe wo (E, F, D) same
  moe router              replicated
  mamba in/out proj       fsdp over D only (model axis idle in SSM blocks —
                          head-parallel Mamba is a recorded §Perf candidate)
  norms / scalar vectors  replicated

Activations: batch dims -> ('pod','data'); KV caches: batch->data,
kv-length->model (flash-decoding-style split-KV — what makes 32k/500k decode
fit and parallelize); Mamba states: batch->data.

Every dim is sharded only if divisible by the axis size (else replicated on
that axis), so one rule set serves all 10 archs and any mesh.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.layers import KVCache
from repro.models.mamba2 import Mamba2Cache

__all__ = ["batch_axes", "params_shardings", "batch_shardings",
           "cache_shardings", "opt_shardings", "train_state_shardings",
           "spec_to_sharding"]


def batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _div(dim: int, mesh: Mesh, axis) -> bool:
    if axis is None:
        return True
    sizes = np.prod([mesh.shape[a] for a in
                     ((axis,) if isinstance(axis, str) else axis)])
    return dim % int(sizes) == 0


def _maybe(dim, mesh, axis):
    return axis if _div(dim, mesh, axis) else None


def _param_spec(pathstr: str, shape: Tuple[int, ...], mesh: Mesh,
                fsdp: bool, n_lead: int) -> P:
    """n_lead = number of stacked scan dims to leave unsharded."""
    lead = (None,) * n_lead
    core = shape[n_lead:]
    dax = "data" if fsdp else None

    def sp(*axes_for_core):
        fixed = tuple(_maybe(core[i], mesh, a)
                      for i, a in enumerate(axes_for_core))
        return P(*(lead + fixed))

    if "embed" in pathstr:
        return sp("model", dax)
    if "lm_head" in pathstr:
        return sp(dax, "model")
    if any(k in pathstr for k in ("'wq'", "'wk'", "'wv'")):
        return sp(dax, "model")
    if "'wo'" in pathstr and "attn" in pathstr:
        return sp("model", dax)
    if "moe" in pathstr and "shared" not in pathstr:
        if "router" in pathstr:
            return P(*(lead + (None,) * len(core)))
        if any(k in pathstr for k in ("'wi'", "'wg'")):
            return sp(None, dax, "model")     # (E, D, F)
        if "'wo'" in pathstr:
            return sp(None, "model", dax)     # (E, F, D)
    if any(k in pathstr for k in ("'wi'", "'wg'")):   # dense mlp (D, F)
        return sp(dax, "model")
    if "'wo'" in pathstr:                              # dense mlp (F, D)
        return sp("model", dax)
    if "mamba" in pathstr and any(k in pathstr for k in
                                  ("in_proj", "out_proj")):
        return sp(dax, None)
    # norms, conv, A_log, dt_bias, router etc: replicate
    return P(*(lead + (None,) * len(core)))


def _n_lead_for(pathstr: str) -> int:
    return 1 if ("groups" in pathstr) else 0


def params_shardings(params: Any, mesh: Mesh, fsdp: bool):
    def one(path, leaf):
        ps = jax.tree_util.keystr(path)
        spec = _param_spec(ps, leaf.shape, mesh, fsdp, _n_lead_for(ps))
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(one, params)


def batch_shardings(batch_tree: Any, mesh: Mesh):
    baxes = batch_axes(mesh)

    def one(path, leaf):
        ps = jax.tree_util.keystr(path)
        if "positions3" in ps:
            spec = P(None, _maybe(leaf.shape[1], mesh, baxes))
        else:
            spec = P(_maybe(leaf.shape[0], mesh, baxes))
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(one, batch_tree)


def cache_shardings(caches: Any, mesh: Mesh):
    """KV k/v (B, Hkv, S, Dh): B->data, S->model (split-KV decode).
    Mamba conv (B, w, ch) / ssm (B, h, p, n): B->data.
    Stacked group caches carry a leading n_groups dim."""

    def one(path, leaf):
        ps = jax.tree_util.keystr(path)
        n_lead = 1 if ".groups" in ps or "groups'" in ps else 0
        lead = (None,) * n_lead
        shape = leaf.shape[n_lead:]
        if ".pos" in ps or leaf.ndim == n_lead:
            return NamedSharding(mesh, P(*lead) if lead else P())
        b_ax = _maybe(shape[0], mesh, "data")
        if ".k" in ps or ".v" in ps:
            s_ax = _maybe(shape[2], mesh, "model")
            return NamedSharding(mesh, P(*(lead + (b_ax, None, s_ax, None))))
        rest = (None,) * (len(shape) - 1)
        return NamedSharding(mesh, P(*(lead + (b_ax,) + rest)))

    return jax.tree_util.tree_map_with_path(one, caches)


def _norm_spec(sh: NamedSharding, ndim: int):
    spec = tuple(sh.spec) + (None,) * (ndim - len(sh.spec))
    return spec[:ndim]


def opt_shardings(opt_state: Any, param_sharding_tree: Any, mesh: Mesh,
                  int8: bool):
    """m/v shard like their parameter; int8 codes (..., L/128, 128) and
    scales (..., L/128) inherit the param spec — the last param-dim spec
    entry lands on the block-count dim (divisibility permitting)."""
    import dataclasses as _dc
    repl = NamedSharding(mesh, P())
    if not int8:
        return _dc.replace(
            opt_state, step=repl,
            m=param_sharding_tree, v=param_sharding_tree,
            m_scale=None, v_scale=None)

    def codes(sh, leaf):
        spec = _norm_spec(sh, leaf.ndim - 1)
        spec = tuple(a if _div(leaf.shape[i], mesh, a) else None
                     for i, a in enumerate(spec))
        return NamedSharding(mesh, P(*(spec + (None,))))

    def scales(sh, leaf):
        spec = _norm_spec(sh, leaf.ndim)
        spec = tuple(a if _div(leaf.shape[i], mesh, a) else None
                     for i, a in enumerate(spec))
        return NamedSharding(mesh, P(*spec))

    return _dc.replace(
        opt_state, step=repl,
        m=jax.tree.map(codes, param_sharding_tree, opt_state.m),
        v=jax.tree.map(codes, param_sharding_tree, opt_state.v),
        m_scale=jax.tree.map(scales, param_sharding_tree, opt_state.m_scale),
        v_scale=jax.tree.map(scales, param_sharding_tree, opt_state.v_scale))


def train_state_shardings(state, mesh: Mesh, fsdp: bool, int8: bool):
    import dataclasses as _dc
    pss = params_shardings(state.params, mesh, fsdp)
    oss = opt_shardings(state.opt, pss, mesh, int8)
    return _dc.replace(state, params=pss, opt=oss)


def spec_to_sharding(mesh, tree_of_specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_of_specs,
                        is_leaf=lambda x: isinstance(x, P))
