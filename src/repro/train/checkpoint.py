"""Fault-tolerant checkpointing with elastic resharding.

- Atomic: write to a temp dir, fsync, rename; a crash mid-save never
  corrupts the latest checkpoint.
- Elastic: arrays are saved in GLOBAL layout (gathered host-side), restore
  re-shards onto whatever mesh the restarted job brings up — a 512-chip run
  can resume on 256 chips and vice versa (node-failure recovery path).
- Async: ``save(..., blocking=False)`` snapshots to host then writes on a
  background thread, overlapping I/O with the next training steps.
- Retention: keeps the newest ``keep`` checkpoints.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "wait_pending"]

_pending: list = []


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = {}
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        items[key] = leaf
    return items, treedef


def save(path: str, step: int, tree: Any, meta: Optional[dict] = None,
         keep: int = 3, blocking: bool = True):
    """Save a pytree of arrays under path/step_<N>/ atomically."""
    items, _ = _flatten(tree)
    host = {k: np.asarray(v) for k, v in items.items()}   # gather to host

    def write():
        final = os.path.join(path, f"step_{step:010d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **host)
        manifest = {"step": int(step), "keys": sorted(host.keys()),
                    "meta": meta or {}}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        _gc(path, keep)

    if blocking:
        write()
    else:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        _pending.append(t)


def wait_pending():
    while _pending:
        _pending.pop().join()


def _gc(path: str, keep: int):
    steps = sorted(d for d in os.listdir(path)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(path, d), ignore_errors=True)


def latest_step(path: str) -> Optional[int]:
    if not os.path.isdir(path):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(path)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(path: str, template: Any, step: Optional[int] = None,
            shardings: Any = None) -> Any:
    """Restore into the structure of ``template``; if ``shardings`` is a
    pytree (or prefix) of NamedShardings, arrays are placed onto the new
    mesh (elastic restart)."""
    step = latest_step(path) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {path}")
    d = os.path.join(path, f"step_{step:010d}")
    data = np.load(os.path.join(d, "arrays.npz"))
    items, treedef = _flatten(template)
    leaves = []
    for key, tmpl in items.items():
        if key not in data:
            raise KeyError(f"checkpoint missing {key}")
        arr = data[key]
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {tmpl.shape}")
        leaves.append(arr.astype(tmpl.dtype))
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree
