"""Int8 error-feedback gradient all-reduce (beyond-paper distributed trick).

Mirrors the paper's thesis — stochastic iterative optimization tolerates
low-precision/stale communication — on the training side: gradients are
blockwise-int8 quantized before crossing links, and the quantization error
is fed back into the next step (EF-SGD), which preserves convergence.

Wire format per tensor: int8 codes + one f32 scale per 128 block = ~26% of
f32 traffic.  The collective is an all-gather of the quantized shards
followed by a local dequant-sum (overflow-safe; bytes counted in §Roofline).
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from .optimizer import q8_encode, q8_decode

__all__ = ["ef_init", "ef_compressed_psum", "make_ef_allreduce"]


def ef_init(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def _compressed_mean_block(x, axis_name):
    """Inside shard_map: per-device x -> mean over axis via int8 wire."""
    q, s = q8_encode(x)
    qg = jax.lax.all_gather(q, axis_name)            # (K, ..., blocks, 128)
    sg = jax.lax.all_gather(s, axis_name)            # (K, ..., blocks)
    dec = qg.astype(jnp.float32) * sg[..., None]
    mean_blocks = dec.mean(axis=0)                   # (..., blocks, 128)
    *lead, L = x.shape
    return mean_blocks.reshape(*lead, -1)[..., :L]


def ef_compressed_psum(grads, err, axis_name):
    """(grads, err) -> (averaged grads, new err); call inside shard_map."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, s = q8_encode(g32)
        local_dec = q8_decode(q, s, g32.shape)
        new_e = g32 - local_dec                       # error feedback
        avg = _compressed_mean_block(g32, axis_name)  # wire = int8 + scales
        return avg, new_e
    outer = jax.tree.structure(grads)
    inner = jax.tree.structure((0, 1))
    out = jax.tree.map(one, grads, err)
    return jax.tree.transpose(outer, inner, out)


def make_ef_allreduce(mesh, axis_name: str = "data"):
    """jit-able compressed data-parallel gradient mean over ``axis_name``.

    Takes replica-sharded (leading axis) grads + error state; returns the
    averaged grads (replicated content, still leading-axis laid out) and the
    per-replica error state.
    """
    from jax.sharding import PartitionSpec as P
    rspec = P(axis_name)

    def block(grads, err):
        g1 = jax.tree.map(lambda x: x[0], grads)     # squeeze replica dim
        e1 = jax.tree.map(lambda x: x[0], err)
        avg, new_e = ef_compressed_psum(g1, e1, axis_name)
        return (jax.tree.map(lambda x: x[None], avg),
                jax.tree.map(lambda x: x[None], new_e))

    from repro.compat import shard_map as _shard_map
    return jax.jit(_shard_map(
        block, mesh=mesh, in_specs=(rspec, rspec),
        out_specs=(rspec, rspec), check_vma=False))
