"""Train-step builders: standard SPMD step, microbatched accumulation, and
the eta-style periodic-sync local-SGD step (the paper's staleness rule as a
training feature — see DESIGN.md §4)."""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from .optimizer import AdamW, OptState, clip_by_global_norm

__all__ = ["TrainState", "make_train_step", "make_local_sgd_step",
           "sync_budget"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: OptState


def make_train_step(model, opt: AdamW, grad_accum: int = 1,
                    clip: float = 1.0):
    """Standard SPMD data-parallel step (gradient all-reduce every step is
    inserted by the partitioner from the batch/param shardings).

    grad_accum > 1: the batch must arrive PRE-SPLIT with a leading
    (grad_accum,) dim and the batch sharding on dim 1 — splitting inside jit
    loses the data sharding through the reshape (observed: 256->(4,64)
    resharded the microbatch only 4-ways)."""

    def step(state: TrainState, batch):
        def loss_fn(p, b):
            return model.loss(p, b, train=True)

        if grad_accum == 1:
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
        else:
            def acc_body(carry, mb):
                gsum, lsum = carry
                l, g = jax.value_and_grad(loss_fn)(state.params, mb)
                return (jax.tree.map(jnp.add, gsum, g), lsum + l), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (grads, loss), _ = jax.lax.scan(
                acc_body, (zeros, jnp.zeros((), jnp.float32)), batch)
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            loss = loss / grad_accum

        grads, gnorm = clip_by_global_norm(grads, clip)
        params, opt2 = opt.update(grads, state.opt, state.params)
        metrics = {"loss": loss, "grad_norm": gnorm, "step": opt2.step}
        return TrainState(params=params, opt=opt2), metrics

    return step


# ---------------------------------------------------------------------------
# eta-style local SGD (paper technique -> training)
# ---------------------------------------------------------------------------


def make_local_sgd_step(model, opt: AdamW, mesh, replica_axis: str = "data",
                        sync_every: int = 1, clip: float = 1.0):
    """Replicas (one per device along ``replica_axis``) take ``sync_every``
    local optimizer steps between parameter-averaging rounds — the direct
    analog of S local sweeps between boundary exchanges in the DSIM, with
    the same throughput/staleness trade governed by one ratio.

    State arrays carry a leading replica dimension sharded over the axis.
    Returns (outer_step, replicate_fn) where outer_step does sync_every local
    steps + one averaging round, and batch has leading dims
    (replicas, sync_every, local_batch, ...).
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    R = mesh.shape[replica_axis]
    rspec = P(replica_axis)

    def local_steps(state: TrainState, batches):
        # strip the leading replica dim the sharding leaves on the block
        state = jax.tree.map(lambda x: x[0], state)
        batches = jax.tree.map(lambda x: x[0], batches)

        def loss_fn(p, b):
            return model.loss(p, b, train=True)

        def one(st, b):
            loss, grads = jax.value_and_grad(loss_fn)(st.params, b)
            grads, gn = clip_by_global_norm(grads, clip)
            params, opt2 = opt.update(grads, st.opt, st.params)
            return TrainState(params, opt2), loss

        st, losses = jax.lax.scan(one, state, batches)
        # parameter averaging = the boundary exchange
        avg = jax.tree.map(
            lambda x: jax.lax.pmean(x, replica_axis), st.params)
        out = TrainState(avg, st.opt)
        return (jax.tree.map(lambda x: x[None], out),
                jax.lax.pmean(losses.mean(), replica_axis))

    from repro.compat import shard_map as _shard_map
    smapped = _shard_map(
        local_steps, mesh=mesh,
        in_specs=(rspec, rspec), out_specs=(rspec, P()),
        check_vma=False)

    @jax.jit
    def outer_step(state, batches):
        st, loss = smapped(state, batches)
        return st, {"loss": loss}

    def replicate(state: TrainState) -> TrainState:
        dup = jax.tree.map(
            lambda x: jax.device_put(
                jnp.broadcast_to(x[None], (R,) + x.shape),
                NamedSharding(mesh, P(replica_axis))), state)
        return dup

    return outer_step, replicate


def sync_budget(param_bytes: float, step_time_s: float, link_bw_Bps: float,
                overlap: float = 0.0) -> int:
    """Minimum sync period S so averaging traffic fits the link budget —
    the Eq.-2 design rule transcribed to training:

      paper:    f_p-bit <= f_comm / (2 N_color C_max)
      here:     step rate <= link_bw / (2 * param_bytes * (1-overlap)) * S

    i.e. S >= 2 * param_bytes * (1-overlap) / (link_bw * step_time).
    """
    s = 2.0 * param_bytes * (1.0 - overlap) / (link_bw_Bps * step_time_s)
    return max(1, int(jnp.ceil(s)))
