"""Synthetic-but-learnable data pipeline with host prefetch.

Tokens are drawn from a fixed random order-1 Markov chain, so a capable
model's loss drops well below the unigram entropy — gives the end-to-end
training example a real learning signal without external data.  A background
thread keeps a prefetch queue full (straggler mitigation at the input layer:
the trainer never blocks on data generation).
"""

from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import numpy as np

__all__ = ["MarkovLM", "prefetch"]


class MarkovLM:
    def __init__(self, vocab: int, seed: int = 0, concentration: float = 0.3):
        rng = np.random.default_rng(seed)
        # sparse-ish transition matrix with a few likely successors per token
        probs = rng.dirichlet(np.full(vocab, concentration), size=vocab)
        self.cum = np.cumsum(probs, axis=1)
        self.vocab = vocab
        self.rng = rng

    def sample(self, batch: int, seq: int, seed: Optional[int] = None
               ) -> np.ndarray:
        rng = self.rng if seed is None else np.random.default_rng(seed)
        out = np.empty((batch, seq), dtype=np.int32)
        cur = rng.integers(0, self.vocab, size=batch)
        out[:, 0] = cur
        for t in range(1, seq):
            u = rng.random(batch)
            cur = (self.cum[cur] < u[:, None]).sum(axis=1)
            np.clip(cur, 0, self.vocab - 1, out=cur)
            out[:, t] = cur
        return out

    def batches(self, batch: int, seq: int) -> Iterator[dict]:
        while True:
            toks = self.sample(batch, seq)
            yield {"tokens": toks, "targets": toks,
                   "mask": np.ones_like(toks)}


def prefetch(it: Iterator, depth: int = 2) -> Iterator:
    q: "queue.Queue" = queue.Queue(maxsize=depth)
    stop = object()

    def worker():
        try:
            for item in it:
                q.put(item)
        finally:
            q.put(stop)

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    while True:
        item = q.get()
        if item is stop:
            return
        yield item
