"""AdamW with fp32 or int8 block-quantized moments (no optax dependency).

The int8 variant stores m and v as int8 with per-128-block absmax scales
(8-bit-Adam style) — 2.5 bytes/param of optimizer state instead of 8, which
is what lets grok-1-314b's train_4k cell fit 16 GB/chip on a single pod.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["AdamW", "OptState", "q8_encode", "q8_decode",
           "clip_by_global_norm"]

_BLOCK = 128


def _pad_len(n: int) -> int:
    return ((n + _BLOCK - 1) // _BLOCK) * _BLOCK


def q8_encode(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(..., L) f32 -> codes (..., ceil(L/128), 128) int8 + scales
    (..., ceil(L/128)) f32.  Blocking only the last dim preserves the
    leading structure, so quantized optimizer state shards with the same
    PartitionSpec as its parameter (see sharding.rules.opt_shardings)."""
    *lead, L = x.shape
    pad = _pad_len(L) - L
    if pad:
        x = jnp.pad(x, [(0, 0)] * len(lead) + [(0, pad)])
    blocks = x.reshape(*lead, -1, _BLOCK)
    scale = jnp.abs(blocks).max(axis=-1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale[..., 0]


def q8_decode(q: jnp.ndarray, scale: jnp.ndarray, shape) -> jnp.ndarray:
    dec = q.astype(jnp.float32) * scale[..., None]
    *lead, L = shape
    return dec.reshape(*lead, -1)[..., :L].reshape(shape)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class OptState:
    step: jnp.ndarray
    m: Any
    v: Any
    m_scale: Any   # None (fp32 mode) or per-block scales
    v_scale: Any


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum((g.astype(jnp.float32) ** 2).sum() for g in leaves))
    factor = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * factor), grads), gn


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    int8_state: bool = False
    warmup: int = 100

    def init(self, params) -> OptState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        if not self.int8_state:
            return OptState(step=jnp.zeros((), jnp.int32),
                            m=jax.tree.map(zeros, params),
                            v=jax.tree.map(zeros, params),
                            m_scale=None, v_scale=None)
        enc = lambda p: q8_encode(jnp.zeros(p.shape, jnp.float32))
        mq = jax.tree.map(lambda p: enc(p)[0], params)
        ms = jax.tree.map(lambda p: enc(p)[1], params)
        return OptState(step=jnp.zeros((), jnp.int32),
                        m=mq, v=jax.tree.map(lambda p: q8_encode(
                            jnp.zeros(p.shape, jnp.float32))[0], params),
                        m_scale=ms,
                        v_scale=jax.tree.map(lambda p: q8_encode(
                            jnp.zeros(p.shape, jnp.float32))[1], params))

    def _schedule(self, step):
        warm = jnp.minimum(step.astype(jnp.float32) / max(self.warmup, 1), 1.0)
        return self.lr * warm

    def update(self, grads, state: OptState, params):
        step = state.step + 1
        lr = self._schedule(step)
        t = step.astype(jnp.float32)
        bc1 = 1.0 - self.b1 ** t
        bc2 = 1.0 - self.b2 ** t

        def upd(g, p, m, v, msc, vsc):
            g = g.astype(jnp.float32)
            if self.int8_state:
                m = q8_decode(m, msc, g.shape)
                # v is stored in sqrt domain (better resolution near 0 —
                # linear int8 lets v flush to 0 in blocks m doesn't, which
                # explodes m/sqrt(v))
                v = q8_decode(v, vsc, g.shape) ** 2
            m = self.b1 * m + (1 - self.b1) * g
            v = self.b2 * v + (1 - self.b2) * g * g
            mh = m / bc1
            vh = v / bc2
            dp = mh / (jnp.sqrt(vh) + self.eps)
            if self.int8_state:
                # guard residual quantization-flush outliers
                dp = jnp.clip(dp, -10.0, 10.0)
            dp = dp + self.weight_decay * p.astype(jnp.float32)
            newp = (p.astype(jnp.float32) - lr * dp).astype(p.dtype)
            if self.int8_state:
                mq, ms = q8_encode(m)
                vq, vs = q8_encode(jnp.sqrt(v))
                return newp, mq, vq, ms, vs
            return newp, m, v

        outer = jax.tree.structure(grads)
        if self.int8_state:
            inner = jax.tree.structure(tuple(range(5)))
            out = jax.tree.map(upd, grads, params, state.m, state.v,
                               state.m_scale, state.v_scale)
            newp, m, v, ms, vs = jax.tree.transpose(outer, inner, out)
            return newp, OptState(step=step, m=m, v=v, m_scale=ms, v_scale=vs)
        inner = jax.tree.structure(tuple(range(3)))
        out = jax.tree.map(
            lambda g, p, m, v: upd(g, p, m, v, None, None),
            grads, params, state.m, state.v)
        newp, m, v = jax.tree.transpose(outer, inner, out)
        return newp, OptState(step=step, m=m, v=v, m_scale=None, v_scale=None)
