"""qwen2-vl-7b [vlm]: 28L d=3584 28H GQA(kv=4) d_ff=18944 vocab=152064,
M-RoPE (t/h/w sections 16/24/24 of the 64 rotary pairs) [arXiv:2409.12191].
The vision frontend is a STUB: input_specs() provides precomputed patch/token
embeddings plus (3, B, S) M-RoPE position ids."""
from repro.models.blocks import BlockSpec
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2-vl-7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, d_ff=18944,
    vocab=152064, group=(BlockSpec("attn", "dense"),),
    mrope_sections=(16, 24, 24), input_kind="embeds3",
    rope_theta=1000000.0, fsdp=True,
    notes="M-RoPE backbone; dynamic-resolution frontend stubbed; long_500k skipped",
))
