"""grok-1-314b [moe]: 64L d=6144 48H GQA(kv=8) expert d_ff=32768
vocab=131072, MoE 8 experts top-2 [hf:xai-org/grok-1]."""
from repro.models.blocks import BlockSpec
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=32768,
    vocab=131072, group=(BlockSpec("attn", "moe"),),
    moe_experts=8, moe_top_k=2, moe_d_ff=32768,
    fsdp=True, opt_8bit=True,
    notes="int8 optimizer state to fit 314B on 256 chips; long_500k skipped",
))
