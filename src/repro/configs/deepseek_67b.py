"""deepseek-67b [dense]: 95L d=8192 64H GQA(kv=8) d_ff=22016 vocab=102400
[arXiv:2401.02954]."""
from repro.models.blocks import BlockSpec
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="deepseek-67b", family="dense",
    n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=22016,
    vocab=102400, group=(BlockSpec("attn", "dense"),),
    fsdp=True,
    notes="full attention => long_500k skipped",
))
