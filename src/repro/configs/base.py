"""Architecture configs: the assigned pool + the paper's own workload.

Every LM arch declares its exact published dimensions and a ``reduced()``
variant (same family, tiny widths) for CPU smoke tests.  Shape cells
(train_4k / prefill_32k / decode_32k / long_500k) are defined here too.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax.numpy as jnp

from repro.models.blocks import BlockSpec

__all__ = ["ArchConfig", "register", "get_config", "list_configs", "SHAPES",
           "ShapeCell"]


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str               # 'train' | 'prefill' | 'decode'


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                         # dense|moe|ssm|hybrid|audio|vlm|ising
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    group: Tuple[BlockSpec, ...]        # repeating block pattern
    prelude: Tuple[BlockSpec, ...] = () # unscanned leading blocks
    d_head: Optional[int] = None
    window: Optional[int] = None        # sliding-window attention
    use_rolling_swa: bool = True
    rope_theta: float = 10000.0
    mrope_sections: Optional[Tuple[int, int, int]] = None
    ssm_state: int = 128
    ssm_headdim: int = 64
    ssm_chunk: int = 128
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_shared: int = 0
    moe_d_ff: int = 0
    moe_d_ff_shared: Optional[int] = None
    moe_capacity: float = 1.25
    encdec: bool = False
    enc_layers: int = 0
    input_kind: str = "tokens"          # 'tokens' | 'frames' (stub frontend)
    long_context: bool = False          # can run long_500k
    dtype: str = "bfloat16"
    fsdp: bool = False                  # shard params over data axis too
    opt_8bit: bool = False              # int8 optimizer state
    remat: bool = True
    notes: str = ""

    def __post_init__(self):
        if self.d_head is None and self.n_heads:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)
        n_pattern = len(self.prelude) + 0
        body = self.n_layers - len(self.prelude)
        if self.group and body % len(self.group) != 0:
            raise ValueError(f"{self.name}: {body} layers not divisible by "
                             f"group of {len(self.group)}")

    @property
    def n_groups(self) -> int:
        if not self.group:
            return 0
        return (self.n_layers - len(self.prelude)) // len(self.group)

    @property
    def compute_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    @property
    def vocab_padded(self) -> int:
        """Embed/head tables padded to 128 so the vocab dim shards over any
        mesh factor (Megatron-style vocab padding; targets never index pads)."""
        return ((self.vocab + 127) // 128) * 128

    def shapes(self):
        names = ["train_4k", "prefill_32k", "decode_32k"]
        if self.long_context:
            names.append("long_500k")
        return [SHAPES[s] for s in names]

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        small_group = self.group
        prelude = self.prelude
        n_layers = len(prelude) + 2 * len(self.group)
        return dataclasses.replace(
            self, n_layers=n_layers, d_model=64,
            n_heads=4, n_kv_heads=min(4, max(1, self.n_kv_heads)),
            d_head=16, d_ff=128, vocab=256,
            window=min(self.window, 16) if self.window else None,
            mrope_sections=(2, 3, 3) if self.mrope_sections else None,
            ssm_state=16, ssm_headdim=16, ssm_chunk=16,
            moe_experts=min(self.moe_experts, 4) if self.moe_experts else 0,
            moe_top_k=min(self.moe_top_k, 2) if self.moe_top_k else 0,
            moe_d_ff=32 if self.moe_experts else 0,
            moe_d_ff_shared=64 if self.moe_shared else None,
            moe_capacity=8.0,   # no drops at smoke-test token counts
            enc_layers=2 if self.encdec else 0,
            dtype="float32", fsdp=False, opt_8bit=False)


_REGISTRY: Dict[str, ArchConfig] = {}
_LOWER_HOOKS: Dict[str, object] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs():
    return dict(_REGISTRY)
