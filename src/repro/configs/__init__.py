from .base import ArchConfig, register, get_config, list_configs  # noqa: F401
from . import (mamba2_370m, granite_20b, h2o_danube_1_8b, deepseek_7b,   # noqa: F401
               deepseek_67b, grok1_314b, deepseek_moe_16b, jamba_v01_52b,
               seamless_m4t_medium, qwen2_vl_7b, ea3d_1m)
