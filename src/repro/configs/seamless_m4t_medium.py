"""seamless-m4t-medium [audio]: enc-dec, 12L+12L d=1024 16H (kv=16)
d_ff=4096 vocab=256206 [arXiv:2308.11596].  The audio frontend is a STUB:
input_specs() provides precomputed (B, S, d_model) frame embeddings."""
from repro.models.blocks import BlockSpec
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="seamless-m4t-medium", family="audio",
    n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
    vocab=256206, group=(BlockSpec("attn", "dense"),),
    encdec=True, enc_layers=12, input_kind="frames",
    notes="enc-dec; decode shapes use the decoder; long_500k skipped",
))
