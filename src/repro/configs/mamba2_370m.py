"""mamba2-370m [ssm]: 48L d_model=1024 attn-free, vocab 50280, d_state=128.
SSD (state-space duality) [arXiv:2405.21060]."""
from repro.models.blocks import BlockSpec
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=0,
    vocab=50280, group=(BlockSpec("mamba", None),),
    ssm_state=128, ssm_headdim=64, ssm_chunk=128,
    long_context=True,
    notes="attention-free; d_inner=2*d_model, 32 SSD heads of headdim 64",
))
