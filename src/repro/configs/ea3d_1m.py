"""ea3d-1m [ising]: the paper's own workload — 10^6 p-bit 3D EA spin glass
(L=100, x/y padded to 112 for the mesh), brick-partitioned over the whole
pod; lowers the fused-Pallas lattice DSIM sampling chunk instead of an LM
step.  N_color=2 (even L), s{4}{1} fixed point, LFSR RNG."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="ea3d-1m", family="ising",
    n_layers=0, d_model=0, n_heads=0, n_kv_heads=0, d_ff=0, vocab=0,
    group=(),
    notes="paper production config: 100^3 EA, 1-bit halo exchange, eta=1/S",
))
