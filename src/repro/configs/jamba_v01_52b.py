"""jamba-v0.1-52b [hybrid]: 32L d=4096 32H GQA(kv=8) d_ff=14336 vocab=65536,
Mamba:attention 7:1 interleave (attn at index 4 of each 8-layer group), MoE
16 experts top-2 every other layer [arXiv:2403.19887].

Adaptation note (DESIGN.md): Jamba v0.1 uses Mamba-1 (d_state 16); this
framework's SSM block is Mamba-2/SSD with the same d_state — recorded as a
hardware-codesign substitution (SSD is the MXU-friendly dual form).
"""
from repro.models.blocks import BlockSpec
from .base import ArchConfig, register

_M_D = BlockSpec("mamba", "dense")
_M_E = BlockSpec("mamba", "moe")
_A_D = BlockSpec("attn", "dense")

CONFIG = register(ArchConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab=65536,
    group=(_M_D, _M_E, _M_D, _M_E, _A_D, _M_E, _M_D, _M_E),
    moe_experts=16, moe_top_k=2, moe_d_ff=14336,
    ssm_state=16, ssm_headdim=64, ssm_chunk=128,
    long_context=True, fsdp=True,
    notes="4 attention layers total; long_500k runs (B=1 KV fits)",
))
