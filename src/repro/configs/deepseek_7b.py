"""deepseek-7b [dense]: 30L d=4096 32H MHA(kv=32) d_ff=11008 vocab=102400
[arXiv:2401.02954]."""
from repro.models.blocks import BlockSpec
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="deepseek-7b", family="dense",
    n_layers=30, d_model=4096, n_heads=32, n_kv_heads=32, d_ff=11008,
    vocab=102400, group=(BlockSpec("attn", "dense"),),
    fsdp=True,
    notes="full attention => long_500k skipped",
))
