"""h2o-danube-1.8b [dense]: 24L d=2560 32H GQA(kv=8) d_ff=6912 vocab=32000,
sliding-window attention (llama+mistral mix) [arXiv:2401.16818]."""
from repro.models.blocks import BlockSpec
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="h2o-danube-1.8b", family="dense",
    n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8, d_ff=6912,
    vocab=32000, group=(BlockSpec("swa", "dense"),),
    window=4096, use_rolling_swa=True, long_context=True,
    notes="SWA rolling cache bounds memory => long_500k runs",
))
