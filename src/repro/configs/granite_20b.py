"""granite-20b [dense]: 52L d=6144 48H MQA(kv=1) d_ff=24576 vocab=49152.
Llama-arch code model [arXiv:2405.04324]."""
from repro.models.blocks import BlockSpec
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="granite-20b", family="dense",
    n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1, d_ff=24576,
    vocab=49152, group=(BlockSpec("attn", "dense"),),
    fsdp=True,
    notes="MQA; full attention => long_500k skipped",
))
