"""deepseek-moe-16b [moe]: 28L d=2048 16H (kv=16) vocab=102400; layer 0 is
dense (d_ff=10944), layers 1..27 fine-grained MoE: 64 routed top-6 + 2 shared
experts of d_ff=1408 [arXiv:2401.06066]."""
from repro.models.blocks import BlockSpec
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=10944,
    vocab=102400,
    prelude=(BlockSpec("attn", "dense"),),
    group=(BlockSpec("attn", "moe"),),
    moe_experts=64, moe_top_k=6, moe_shared=2, moe_d_ff=1408,
    moe_d_ff_shared=2816,
    fsdp=True,
    notes="fine-grained + shared experts; long_500k skipped",
))
