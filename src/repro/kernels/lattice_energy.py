"""Blocked Ising-energy reduction Pallas kernel for lattice bricks.

E_brick = -1/2 sum_i m_i (field_i - h_i) - sum_i h_i m_i, with the field
assembled from the same shifted-plane neighbor reads as the update kernel.
Shadow (cross-device) couplings are halved correctly because both sides hold
a copy: summing -1/2 m_i J_ij m_j over both devices yields each cut edge
exactly once after the global psum.

Grid steps accumulate into a single (1, 1) output block — the standard
Pallas reduction idiom (output index map constant, init at step 0).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["brick_energy"]


def _kernel(active_ref, h_ref, wxm_ref, wxp_ref, wym_ref, wyp_ref,
            wzm_ref, wzp_ref, m_l_ref, m_c_ref, m_r_ref,
            xlo_ref, xhi_ref, ylo_ref, yhi_ref, zlo_ref, zhi_ref,
            out_ref, *, nblocks: int):
    i = pl.program_id(0)
    f32 = jnp.float32
    mc = m_c_ref[...].astype(f32)
    left = jnp.where(i == 0, xlo_ref[...].astype(f32)[None],
                     m_l_ref[...][-1:].astype(f32))
    right = jnp.where(i == nblocks - 1, xhi_ref[...].astype(f32)[None],
                      m_r_ref[...][:1].astype(f32))
    xm = jnp.concatenate([left, mc[:-1]], axis=0)
    xp = jnp.concatenate([mc[1:], right], axis=0)
    ym = jnp.concatenate([ylo_ref[...].astype(f32)[:, None, :], mc[:, :-1]], axis=1)
    yp = jnp.concatenate([mc[:, 1:], yhi_ref[...].astype(f32)[:, None, :]], axis=1)
    zm = jnp.concatenate([zlo_ref[...].astype(f32)[:, :, None], mc[:, :, :-1]], axis=2)
    zp = jnp.concatenate([mc[:, :, 1:], zhi_ref[...].astype(f32)[:, :, None]], axis=2)

    pair = (wxm_ref[...] * xm + wxp_ref[...] * xp
            + wym_ref[...] * ym + wyp_ref[...] * yp
            + wzm_ref[...] * zm + wzp_ref[...] * zp)
    act = active_ref[...].astype(f32)
    e = (-0.5 * (mc * pair) - h_ref[...] * mc) * act

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[0, 0] += e.sum()


@functools.partial(jax.jit, static_argnames=("bx", "interpret"))
def brick_energy(m, active, h, w6, halos, bx: Optional[int] = None,
                 interpret: bool = True):
    """Brick-local Ising energy (psum across bricks gives the global E)."""
    Bx, By, Bz = m.shape
    bx = Bx if bx is None else bx
    if Bx % bx != 0:
        raise ValueError(f"Bx={Bx} not divisible by tile bx={bx}")
    nb = Bx // bx
    wxm, wxp, wym, wyp, wzm, wzp = w6
    xlo, xhi, ylo, yhi, zlo, zhi = halos

    blk = (bx, By, Bz)
    cur = pl.BlockSpec(blk, lambda i: (i, 0, 0))
    prv = pl.BlockSpec(blk, lambda i: (jnp.maximum(i - 1, 0), 0, 0))
    nxt = pl.BlockSpec(blk, lambda i: (jnp.minimum(i + 1, nb - 1), 0, 0))
    full2 = lambda a, b: pl.BlockSpec((a, b), lambda i: (0, 0))
    xtile = lambda b2: pl.BlockSpec((bx, b2), lambda i: (i, 0))

    out = pl.pallas_call(
        functools.partial(_kernel, nblocks=nb),
        grid=(nb,),
        in_specs=[
            cur, cur, cur, cur, cur, cur, cur, cur,
            prv, cur, nxt,
            full2(By, Bz), full2(By, Bz),
            xtile(Bz), xtile(Bz),
            xtile(By), xtile(By),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )(active, h, wxm, wxp, wym, wyp, wzm, wzp, m, m, m,
      xlo, xhi, ylo, yhi, zlo, zhi)
    return out[0, 0]
