"""Pallas TPU kernels for the paper's compute hot-spot.

pbit_lattice   — fused color-group p-bit update (shifted-plane neighbor
                 reads, in-kernel xorshift32 LFSR, fixed-point tanh
                 threshold, masked flip) with BlockSpec x-slab tiling.
lattice_energy — blocked Ising-energy reduction over a brick.
ops            — jit'd dispatch (pallas on TPU / interpret for validation /
                 jnp ref on CPU); ref — pure-jnp oracles.

Validated in interpret mode against the oracles across shape/format sweeps
(bitwise-equal spins and LFSR states; allclose energies).
"""
