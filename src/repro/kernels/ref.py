"""Pure-jnp oracles for the Pallas kernels (no pallas imports).

Same math, whole-array formulation.  The kernel tests assert exact agreement
(identical op sequences -> bitwise-equal int8/uint32 outputs, allclose f32).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.pbit import FixedPoint, lut_accept

__all__ = ["pbit_brick_update_ref", "pbit_brick_sweep_ref",
           "pbit_brick_update_int_ref", "pbit_brick_sweep_int_ref",
           "brick_energy_ref", "neighbor_sums_ref", "int_field_ref"]


def _shifted(m, halos):
    """Assemble the 6 neighbor arrays of a brick from halo planes."""
    f32 = jnp.float32
    xlo, xhi, ylo, yhi, zlo, zhi = [a.astype(f32) for a in halos]
    mc = m.astype(f32)
    xm = jnp.concatenate([xlo[None], mc[:-1]], axis=0)
    xp = jnp.concatenate([mc[1:], xhi[None]], axis=0)
    ym = jnp.concatenate([ylo[:, None, :], mc[:, :-1]], axis=1)
    yp = jnp.concatenate([mc[:, 1:], yhi[:, None, :]], axis=1)
    zm = jnp.concatenate([zlo[:, :, None], mc[:, :, :-1]], axis=2)
    zp = jnp.concatenate([mc[:, :, 1:], zhi[:, :, None]], axis=2)
    return xm, xp, ym, yp, zm, zp


def neighbor_sums_ref(m, h, w6, halos):
    wxm, wxp, wym, wyp, wzm, wzp = w6
    xm, xp, ym, yp, zm, zp = _shifted(m, halos)
    return (h + wxm * xm + wxp * xp + wym * ym + wyp * yp
            + wzm * zm + wzp * zp)


def pbit_brick_update_ref(m, s, beta, parity_mask, h, w6, halos,
                          fmt: Optional[FixedPoint] = None):
    field = neighbor_sums_ref(m, h, w6, halos)
    s = s ^ (s << jnp.uint32(13))
    s = s ^ (s >> jnp.uint32(17))
    s = s ^ (s << jnp.uint32(5))
    r = (s >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(2.0 / 16777216.0) \
        - jnp.float32(1.0)
    act = jnp.asarray(beta, jnp.float32) * field
    if fmt is not None:
        act = jnp.clip(jnp.round(act / fmt.step) * fmt.step, fmt.lo, fmt.hi)
    upd = jnp.where(jnp.tanh(act) + r >= 0, 1, -1).astype(jnp.int8)
    m_new = jnp.where(parity_mask != 0, upd, m)
    return m_new, s


def pbit_brick_sweep_ref(m, s, betas, masks, h, w6, halos,
                         fmt: Optional[FixedPoint] = None):
    """Oracle for the fused multi-phase kernel: ``len(betas)`` full sweeps
    (every color phase, in order) against halos held fixed.

    Composes :func:`pbit_brick_update_ref` phase by phase, so it is bitwise
    identical to the per-phase dispatch it replaces.  Returns
    (m_new, s_new, flips) with flips the int32 count of accepted changes.
    """
    betas = jnp.asarray(betas, jnp.float32).reshape(-1)
    flips = jnp.zeros((), jnp.int32)
    for t in range(betas.shape[0]):
        for c in range(masks.shape[0]):
            m2, s = pbit_brick_update_ref(m, s, betas[t], masks[c], h, w6,
                                          halos, fmt)
            flips = flips + (m2 != m).sum().astype(jnp.int32)
            m = m2
    return m, s, flips


# ---------------------------------------------------------------------------
# fixed-point pipeline oracles (zero floating-point ops in the update)
# ---------------------------------------------------------------------------

def _shifted_int(m, halos):
    """Neighbor assembly kept in int8 — the big shifted intermediates stay
    1 B/site (the accumulate below widens in registers)."""
    xlo, xhi, ylo, yhi, zlo, zhi = halos
    xm = jnp.concatenate([xlo[None], m[:-1]], axis=0)
    xp = jnp.concatenate([m[1:], xhi[None]], axis=0)
    ym = jnp.concatenate([ylo[:, None, :], m[:, :-1]], axis=1)
    yp = jnp.concatenate([m[:, 1:], yhi[:, None, :]], axis=1)
    zm = jnp.concatenate([zlo[:, :, None], m[:, :, :-1]], axis=2)
    zp = jnp.concatenate([m[:, :, 1:], zhi[:, :, None]], axis=2)
    return xm, xp, ym, yp, zm, zp


def int_field_ref(m, h_q, w6_q, halos):
    """Integer local field  h_q + sum_d w_q[d] * m_d  in int32.

    Products and sums accumulate in int32; the int8 operands widen inside
    the fused elementwise chain, so no int32 neighbor array is ever
    materialized."""
    i32 = jnp.int32
    wxm, wxp, wym, wyp, wzm, wzp = w6_q
    xm, xp, ym, yp, zm, zp = _shifted_int(m, halos)
    return (h_q.astype(i32)
            + wxm.astype(i32) * xm.astype(i32)
            + wxp.astype(i32) * xp.astype(i32)
            + wym.astype(i32) * ym.astype(i32)
            + wyp.astype(i32) * yp.astype(i32)
            + wzm.astype(i32) * zm.astype(i32)
            + wzp.astype(i32) * zp.astype(i32))


def pbit_brick_update_int_ref(m, s, row, parity_mask, h_q, w6_q, halos, lut):
    """One color-phase update on the integer path.

    ``row`` selects the beta row of ``lut`` ((n_rows, 2*f_max+1) uint32,
    :func:`repro.core.pbit.threshold_lut`); the accept test is a single
    unsigned compare of the raw 24-bit LFSR draw against the tabulated
    threshold — no floating point anywhere.
    """
    f_off = (lut.shape[1] - 1) // 2
    field = int_field_ref(m, h_q, w6_q, halos)
    s = s ^ (s << jnp.uint32(13))
    s = s ^ (s >> jnp.uint32(17))
    s = s ^ (s << jnp.uint32(5))
    u = s >> jnp.uint32(8)
    thr = jax.lax.dynamic_index_in_dim(lut, jnp.asarray(row, jnp.int32),
                                       axis=0, keepdims=False)
    upd = jnp.where(lut_accept(thr, field, f_off, u), 1, -1).astype(jnp.int8)
    m_new = jnp.where(parity_mask != 0, upd, m)
    return m_new, s


def pbit_brick_sweep_int_ref(m, s, rows, masks, h_q, w6_q, halos, lut):
    """Oracle for the fused integer sweep kernel: ``len(rows)`` full color
    cycles against halos held fixed, one LUT row index per sweep.  Returns
    (m_new, s_new, flips)."""
    rows = jnp.asarray(rows, jnp.int32).reshape(-1)
    flips = jnp.zeros((), jnp.int32)
    for t in range(rows.shape[0]):
        for c in range(masks.shape[0]):
            m2, s = pbit_brick_update_int_ref(m, s, rows[t], masks[c], h_q,
                                              w6_q, halos, lut)
            flips = flips + (m2 != m).sum().astype(jnp.int32)
            m = m2
    return m, s, flips


def brick_energy_ref(m, active, h, w6, halos):
    field = neighbor_sums_ref(m, h, w6, halos)
    mc = m.astype(jnp.float32)
    e = (-0.5 * mc * (field - h) - h * mc) * active.astype(jnp.float32)
    return e.sum()
