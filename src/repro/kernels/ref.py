"""Pure-jnp oracles for the Pallas kernels (no pallas imports).

Same math, whole-array formulation.  The kernel tests assert exact agreement
(identical op sequences -> bitwise-equal int8/uint32 outputs, allclose f32).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.pbit import FixedPoint, lut_accept

__all__ = ["pbit_brick_update_ref", "pbit_brick_sweep_ref",
           "pbit_brick_update_int_ref", "pbit_brick_sweep_int_ref",
           "pbit_bitplane_sweep_ref", "bitplane_ones_count_ref",
           "bitplane_count_planes_ref", "bitplane_gather_count_ref",
           "brick_energy_ref", "neighbor_sums_ref", "int_field_ref"]


def _shifted(m, halos):
    """Assemble the 6 neighbor arrays of a brick from halo planes."""
    f32 = jnp.float32
    xlo, xhi, ylo, yhi, zlo, zhi = [a.astype(f32) for a in halos]
    mc = m.astype(f32)
    xm = jnp.concatenate([xlo[None], mc[:-1]], axis=0)
    xp = jnp.concatenate([mc[1:], xhi[None]], axis=0)
    ym = jnp.concatenate([ylo[:, None, :], mc[:, :-1]], axis=1)
    yp = jnp.concatenate([mc[:, 1:], yhi[:, None, :]], axis=1)
    zm = jnp.concatenate([zlo[:, :, None], mc[:, :, :-1]], axis=2)
    zp = jnp.concatenate([mc[:, :, 1:], zhi[:, :, None]], axis=2)
    return xm, xp, ym, yp, zm, zp


def neighbor_sums_ref(m, h, w6, halos):
    wxm, wxp, wym, wyp, wzm, wzp = w6
    xm, xp, ym, yp, zm, zp = _shifted(m, halos)
    return (h + wxm * xm + wxp * xp + wym * ym + wyp * yp
            + wzm * zm + wzp * zp)


def pbit_brick_update_ref(m, s, beta, parity_mask, h, w6, halos,
                          fmt: Optional[FixedPoint] = None):
    field = neighbor_sums_ref(m, h, w6, halos)
    s = s ^ (s << jnp.uint32(13))
    s = s ^ (s >> jnp.uint32(17))
    s = s ^ (s << jnp.uint32(5))
    r = (s >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(2.0 / 16777216.0) \
        - jnp.float32(1.0)
    act = jnp.asarray(beta, jnp.float32) * field
    if fmt is not None:
        act = jnp.clip(jnp.round(act / fmt.step) * fmt.step, fmt.lo, fmt.hi)
    upd = jnp.where(jnp.tanh(act) + r >= 0, 1, -1).astype(jnp.int8)
    m_new = jnp.where(parity_mask != 0, upd, m)
    return m_new, s


def pbit_brick_sweep_ref(m, s, betas, masks, h, w6, halos,
                         fmt: Optional[FixedPoint] = None):
    """Oracle for the fused multi-phase kernel: ``len(betas)`` full sweeps
    (every color phase, in order) against halos held fixed.

    Composes :func:`pbit_brick_update_ref` phase by phase, so it is bitwise
    identical to the per-phase dispatch it replaces.  Returns
    (m_new, s_new, flips) with flips the int32 count of accepted changes.
    """
    betas = jnp.asarray(betas, jnp.float32).reshape(-1)
    flips = jnp.zeros((), jnp.int32)
    for t in range(betas.shape[0]):
        for c in range(masks.shape[0]):
            m2, s = pbit_brick_update_ref(m, s, betas[t], masks[c], h, w6,
                                          halos, fmt)
            flips = flips + (m2 != m).sum().astype(jnp.int32)
            m = m2
    return m, s, flips


# ---------------------------------------------------------------------------
# fixed-point pipeline oracles (zero floating-point ops in the update)
# ---------------------------------------------------------------------------

def _shifted_int(m, halos):
    """Neighbor assembly kept in int8 — the big shifted intermediates stay
    1 B/site (the accumulate below widens in registers)."""
    xlo, xhi, ylo, yhi, zlo, zhi = halos
    xm = jnp.concatenate([xlo[None], m[:-1]], axis=0)
    xp = jnp.concatenate([m[1:], xhi[None]], axis=0)
    ym = jnp.concatenate([ylo[:, None, :], m[:, :-1]], axis=1)
    yp = jnp.concatenate([m[:, 1:], yhi[:, None, :]], axis=1)
    zm = jnp.concatenate([zlo[:, :, None], m[:, :, :-1]], axis=2)
    zp = jnp.concatenate([m[:, :, 1:], zhi[:, :, None]], axis=2)
    return xm, xp, ym, yp, zm, zp


def int_field_ref(m, h_q, w6_q, halos):
    """Integer local field  h_q + sum_d w_q[d] * m_d  in int32.

    Products and sums accumulate in int32; the int8 operands widen inside
    the fused elementwise chain, so no int32 neighbor array is ever
    materialized."""
    i32 = jnp.int32
    wxm, wxp, wym, wyp, wzm, wzp = w6_q
    xm, xp, ym, yp, zm, zp = _shifted_int(m, halos)
    return (h_q.astype(i32)
            + wxm.astype(i32) * xm.astype(i32)
            + wxp.astype(i32) * xp.astype(i32)
            + wym.astype(i32) * ym.astype(i32)
            + wyp.astype(i32) * yp.astype(i32)
            + wzm.astype(i32) * zm.astype(i32)
            + wzp.astype(i32) * zp.astype(i32))


def pbit_brick_update_int_ref(m, s, row, parity_mask, h_q, w6_q, halos, lut):
    """One color-phase update on the integer path.

    ``row`` selects the beta row of ``lut`` ((n_rows, 2*f_max+1) uint32,
    :func:`repro.core.pbit.threshold_lut`); the accept test is a single
    unsigned compare of the raw 24-bit LFSR draw against the tabulated
    threshold — no floating point anywhere.
    """
    f_off = (lut.shape[1] - 1) // 2
    field = int_field_ref(m, h_q, w6_q, halos)
    s = s ^ (s << jnp.uint32(13))
    s = s ^ (s >> jnp.uint32(17))
    s = s ^ (s << jnp.uint32(5))
    u = s >> jnp.uint32(8)
    thr = jax.lax.dynamic_index_in_dim(lut, jnp.asarray(row, jnp.int32),
                                       axis=0, keepdims=False)
    upd = jnp.where(lut_accept(thr, field, f_off, u), 1, -1).astype(jnp.int8)
    m_new = jnp.where(parity_mask != 0, upd, m)
    return m_new, s


def pbit_brick_sweep_int_ref(m, s, rows, masks, h_q, w6_q, halos, lut):
    """Oracle for the fused integer sweep kernel: ``len(rows)`` full color
    cycles against halos held fixed, one LUT row index per sweep.  Returns
    (m_new, s_new, flips)."""
    rows = jnp.asarray(rows, jnp.int32).reshape(-1)
    flips = jnp.zeros((), jnp.int32)
    for t in range(rows.shape[0]):
        for c in range(masks.shape[0]):
            m2, s = pbit_brick_update_int_ref(m, s, rows[t], masks[c], h_q,
                                              w6_q, halos, lut)
            flips = flips + (m2 != m).sum().astype(jnp.int32)
            m = m2
    return m, s, flips


# ---------------------------------------------------------------------------
# bit-plane (multi-spin-coded) oracle: 32 replicas per uint32 word
# ---------------------------------------------------------------------------
#
# Spins live as bit-planes — bit r of word [x, y, z] is replica r's spin
# (1 = +1) — so one word op advances 32 independent chains at once.  The
# +-J field needs only the *count* of +1 neighbor contributions: each
# nonzero coupling contributes +1 exactly when (m_bit XOR sign_bit) is 1,
# and the six contribution planes are summed with a bit-sliced carry-save
# adder tree (c in [0, 6] fits 3 bit-slices; with the sign/carry structure
# of the +-J field, 4 slices bound the 13-value field).  Only the RNG and
# the threshold compare are per-lane: packed chains draw from their own
# LFSR columns (no shared randomness — lanes must stay statistically
# independent), and lane r of a bit-plane run is bit-identical to replica r
# of the int8 engine at matched seeds/schedules.

def _shifted_words(mw, halos_w):
    """Six neighbor word-planes of a brick of packed words."""
    xlo, xhi, ylo, yhi, zlo, zhi = halos_w
    xm = jnp.concatenate([xlo[None], mw[:-1]], axis=0)
    xp = jnp.concatenate([mw[1:], xhi[None]], axis=0)
    ym = jnp.concatenate([ylo[:, None, :], mw[:, :-1]], axis=1)
    yp = jnp.concatenate([mw[:, 1:], yhi[:, None, :]], axis=1)
    zm = jnp.concatenate([zlo[:, :, None], mw[:, :, :-1]], axis=2)
    zp = jnp.concatenate([mw[:, :, 1:], zhi[:, :, None]], axis=2)
    return xm, xp, ym, yp, zm, zp


def _full_add(a, b, c):
    """Bit-sliced full adder: per-lane a + b + c as (sum, carry) planes."""
    s = a ^ b
    return s ^ c, (a & b) | (c & s)


def bitplane_count_planes_ref(planes):
    """Per-lane count of set bits across an arbitrary list of word planes.

    The general-degree form of the carry-save adder tree: each plane is a
    1-bit contribution per lane, and the running count lives as bit-slice
    planes — ripple-adding plane k costs ``len(slices)`` AND/XOR pairs, and
    a new slice is appended only when the count can actually reach the next
    power of two, so the result has exactly ``ceil(log2(D+1))`` slices for
    D planes.  Lane r's count is ``sum_i 2**i * bit_r(slices[i])``.  This is
    the field accumulator of the gather-graph bit-plane paths (the mesh
    engine's D-neighbor update, the lane-packed tempering ladder), where the
    neighbor degree is not the lattice's fixed six.
    """
    slices = []
    for n, plane in enumerate(planes, start=1):
        carry = plane
        for i, s in enumerate(slices):
            slices[i] = s ^ carry
            carry = s & carry
        if (1 << len(slices)) <= n:
            slices.append(carry)
    return slices


def bitplane_ones_count_ref(mw, signs6, nz6, halos_w):
    """Per-lane count of +1 neighbor contributions, as 3 bit-slice planes.

    Returns (b0, b1, b2) uint32 planes: lane r's count is
    ``b0[r] + 2*b1[r] + 4*b2[r]`` (in [0, 6] — six neighbors).  This is the
    carry-save adder tree: two 3:2 full adders over the six contribution
    planes, then a bit-sliced combine of the two (sum, carry) pairs.
    """
    nbs = _shifted_words(mw, halos_w)
    t = [(nb ^ sg) & nz for nb, sg, nz in zip(nbs, signs6, nz6)]
    s1, c1 = _full_add(t[0], t[1], t[2])
    s2, c2 = _full_add(t[3], t[4], t[5])
    b0 = s1 ^ s2
    k = s1 & s2
    b1, b2 = _full_add(c1, c2, k)[0], (c1 & c2) | (k & (c1 ^ c2))
    return b0, b1, b2


def bitplane_gather_count_ref(mext_w, idx_c, signs_c, nz_c):
    """Per-lane +1-contribution count for a gather-graph (ELL) site set.

    ``mext_w`` is the (..., n_local + n_ghost) packed word pool — any
    leading axes (the stacked word planes of a W-word run) broadcast
    straight through, since the CSA tree is elementwise in the word —
    ``idx_c`` (nc, D) int32 neighbor slots, ``signs_c``/``nz_c`` (nc, D)
    uint32 sign / nonzero planes (:func:`repro.core.pbit.bitplane_planes`
    per direction).  Returns the bit-slice planes of
    :func:`bitplane_count_planes_ref` (shape (..., nc) each) — the
    D-neighbor analogue of the lattice tree above, shared by the word-lane
    mesh engine and the lane-packed tempering ladder.
    """
    nbr = jnp.take(mext_w, idx_c, axis=-1)           # (..., nc, D) words
    planes = [(nbr[..., d] ^ signs_c[:, d]) & nz_c[:, d]
              for d in range(int(idx_c.shape[1]))]
    return bitplane_count_planes_ref(planes)


def pbit_bitplane_sweep_ref(mw, s, rows, masks_w, signs6, nz6, base,
                            halos_w, lut):
    """Oracle for the multi-spin-coded sweep kernel, any word count W.

    Args:
      mw: (W, Bx, By, Bz) uint32 stacked spin word planes — bit b of
        plane w is replica lane ``w*32 + b``.
      s: (R, Bx, By, Bz) uint32 per-lane LFSR states, R <= W*32.
      rows: (S,) or (S, R) int32 LUT row indices — one per sweep, shared
        or per lane (the per-replica staircase fan).
      masks_w: (n_colors, W, Bx, By, Bz) uint32 color masks — each plane
        carries its own lane mask, so dead lanes (only ever in the LAST
        word) never update.
      signs6 / nz6 / base: :func:`repro.core.pbit.bitplane_planes`
        (word-independent: the couplings are shared by every lane).
      halos_w: 6 packed halo planes, each with a leading W axis (held
        fixed across the S sweeps).
      lut: (n_rows, 2*f_max+1) uint32 thresholds; rows must be narrow
        enough for the rank-count accept (monotone rows).

    Returns (mw_new, s_new, flips) with flips the (R,) int32 per-lane
    accepted-change counts.  Word planes are independent replica sets —
    no cross-word term exists in the update — so the oracle runs the
    single-word body once per plane and concatenates; lane (w, b) is
    bit-identical to replica ``w*32 + b`` of
    :func:`pbit_brick_sweep_int_ref` on the unpacked problem, and
    prefix-stable in both b and w.
    """
    W = int(mw.shape[0])
    R = int(s.shape[0])
    rows = jnp.asarray(rows, jnp.int32)
    outs = []
    for w in range(W):
        r0, r1 = w * 32, min(w * 32 + 32, R)
        rw = rows[:, r0:r1] if rows.ndim == 2 else rows
        outs.append(_bitplane_sweep_word_ref(
            mw[w], s[r0:r1], rw, masks_w[:, w], signs6, nz6, base,
            tuple(h[w] for h in halos_w), lut))
    return (jnp.stack([o[0] for o in outs]),
            jnp.concatenate([o[1] for o in outs]),
            jnp.concatenate([o[2] for o in outs]))


def _bitplane_sweep_word_ref(mw, s, rows, masks_w, signs6, nz6, base,
                             halos_w, lut):
    """One-word-plane sweep body: mw (Bx, By, Bz), s (R <= 32, ...)."""
    R = int(s.shape[0])
    n_colors = int(masks_w.shape[0])
    lw = int(lut.shape[1])
    rows = jnp.asarray(rows, jnp.int32)
    per_lane_rows = rows.ndim == 2
    # Per-lane work runs LANE-LAST: the 32 uint32 lanes of a site are
    # contiguous innermost, so every per-lane op (xorshift, compare, bit
    # extract) vectorizes across the lanes of one site — measured ~2x the
    # lane-leading layout on CPU.  The (R, ...) state layout is restored
    # on exit.
    s = jnp.moveaxis(s, 0, -1)                     # (Bx, By, Bz, R)
    lanes = jnp.arange(R, dtype=jnp.uint32)        # innermost broadcast
    one = jnp.uint32(1)
    i32 = jnp.int32
    # per-lane accept:  u >= thr[idx],  idx = base + 2c  (in range by the
    # field bound, so lut_accept's clip is a no-op) — in rank-count form
    # 2c + count >= lw - base  (monotone rows)
    rhs = (lw - base.astype(i32))[..., None]
    flips = jnp.zeros((R,), i32)
    for t in range(rows.shape[0]):
        if per_lane_rows:
            thr = lut[rows[t]]                     # (R, lw) per-lane rows
        else:
            # shared staircase entry: hoist the 7 reachable per-site
            # thresholds T_v = thr[base + 2v] once per sweep (c <= 6), so
            # each phase needs one where-chain select + ONE compare per
            # lane instead of the lw-wide rank count — the hot path the
            # engine benchmark runs
            thr_row = lut[rows[t]]
            Ts = [jnp.take(thr_row, jnp.clip(base + 2 * v, 0, lw - 1))
                  [..., None] for v in range(7)]
        for c in range(n_colors):
            b0, b1, b2 = bitplane_ones_count_ref(mw, signs6, nz6, halos_w)
            # free-running per-lane LFSR columns (no shared randomness)
            s = s ^ (s << jnp.uint32(13))
            s = s ^ (s >> jnp.uint32(17))
            s = s ^ (s << jnp.uint32(5))
            u = s >> jnp.uint32(8)
            cnt = (((b0[..., None] >> lanes) & one)
                   + (((b1[..., None] >> lanes) & one) << one)
                   + (((b2[..., None] >> lanes) & one) << jnp.uint32(2)))
            if per_lane_rows:
                count = jnp.zeros(u.shape, i32)
                for k in range(lw):
                    count = count + (u >= thr[:, k]).astype(i32)
                accept = 2 * cnt.astype(i32) + count >= rhs
            else:
                tsel = Ts[6]
                for v in range(5, -1, -1):
                    tsel = jnp.where(cnt == jnp.uint32(v), Ts[v], tsel)
                accept = u >= tsel
            upd = (accept.astype(jnp.uint32) << lanes).sum(axis=-1) \
                .astype(jnp.uint32)
            new = (mw & ~masks_w[c]) | (upd & masks_w[c])
            diff = mw ^ new
            flips = flips + ((diff[..., None] >> lanes) & one).astype(i32) \
                .sum(axis=(0, 1, 2))
            mw = new
    return mw, jnp.moveaxis(s, -1, 0), flips


def brick_energy_ref(m, active, h, w6, halos):
    field = neighbor_sums_ref(m, h, w6, halos)
    mc = m.astype(jnp.float32)
    e = (-0.5 * mc * (field - h) - h * mc) * active.astype(jnp.float32)
    return e.sum()
