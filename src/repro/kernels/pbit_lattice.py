"""Fused p-bit color-update Pallas kernel for 3D lattice bricks.

This is the compute hot-spot of the paper's machine: for every site of one
color group, gather the six neighbor spins, accumulate the local field from
on-chip weights, draw an LFSR random number, threshold a (quantized) tanh,
and write the new spin — all in one pass, exactly what one FPGA clock does
for a color group.

TPU adaptation (DESIGN.md): the FPGA's hardwired neighbor fabric becomes
shifted-plane reads of a VMEM-resident brick; the per-p-bit LFSR column
becomes a vectorized xorshift32 lane; s{4}{1} fixed point becomes a
round+clip on the activation.  The brick's x extent is tiled by BlockSpec
(grid over x-slabs); neighbor access across tile boundaries uses the
standard shifted-index-map halo pattern (the same input bound three times at
block indices i-1, i, i+1), and physical brick boundaries use explicit halo
planes produced by the inter-device ppermute exchange.

All operands of one grid step fit in VMEM: for a (bx, By, Bz) tile the
working set is 7 f32 weight/bias tiles + 3 int8 spin tiles + 1 u32 LFSR tile
+ 6 halo planes ~= (32 + 4) * bx*By*Bz bytes; the default bx keeps this
under 4 MiB.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.pbit import FixedPoint

__all__ = ["pbit_brick_update"]


def _kernel(parity_ref, beta_ref,
            h_ref, wxm_ref, wxp_ref, wym_ref, wyp_ref, wzm_ref, wzp_ref,
            m_l_ref, m_c_ref, m_r_ref,
            xlo_ref, xhi_ref, ylo_ref, yhi_ref, zlo_ref, zhi_ref,
            s_ref,
            m_out_ref, s_out_ref,
            *, fmt: Optional[FixedPoint], nblocks: int):
    i = pl.program_id(0)
    f32 = jnp.float32
    mc_raw = m_c_ref[...]
    mc = mc_raw.astype(f32)

    # x-direction neighbors: interior from the shifted blocks, edges from halos
    left_plane = jnp.where(i == 0, xlo_ref[...].astype(f32)[None],
                           m_l_ref[...][-1:].astype(f32))
    right_plane = jnp.where(i == nblocks - 1, xhi_ref[...].astype(f32)[None],
                            m_r_ref[...][:1].astype(f32))
    xm = jnp.concatenate([left_plane, mc[:-1]], axis=0)
    xp = jnp.concatenate([mc[1:], right_plane], axis=0)
    # y / z neighbors: in-tile shifts with per-tile halo planes
    ym = jnp.concatenate([ylo_ref[...].astype(f32)[:, None, :], mc[:, :-1]], axis=1)
    yp = jnp.concatenate([mc[:, 1:], yhi_ref[...].astype(f32)[:, None, :]], axis=1)
    zm = jnp.concatenate([zlo_ref[...].astype(f32)[:, :, None], mc[:, :, :-1]], axis=2)
    zp = jnp.concatenate([mc[:, :, 1:], zhi_ref[...].astype(f32)[:, :, None]], axis=2)

    field = (h_ref[...]
             + wxm_ref[...] * xm + wxp_ref[...] * xp
             + wym_ref[...] * ym + wyp_ref[...] * yp
             + wzm_ref[...] * zm + wzp_ref[...] * zp)

    # free-running per-site LFSR (every site advances every phase, like the
    # hardware's always-on LFSR columns)
    s = s_ref[...]
    s = s ^ (s << jnp.uint32(13))
    s = s ^ (s >> jnp.uint32(17))
    s = s ^ (s << jnp.uint32(5))
    r = (s >> jnp.uint32(8)).astype(f32) * f32(2.0 / 16777216.0) - f32(1.0)

    act = beta_ref[0, 0] * field
    if fmt is not None:
        act = jnp.clip(jnp.round(act / fmt.step) * fmt.step, fmt.lo, fmt.hi)
    upd = jnp.where(jnp.tanh(act) + r >= 0, 1, -1).astype(jnp.int8)
    mask = parity_ref[...] != 0
    m_out_ref[...] = jnp.where(mask, upd, mc_raw)
    s_out_ref[...] = s


@functools.partial(jax.jit, static_argnames=("fmt", "bx", "interpret"))
def pbit_brick_update(m, s, beta, parity_mask, h, w6, halos,
                      fmt: Optional[FixedPoint] = None,
                      bx: Optional[int] = None,
                      interpret: bool = True):
    """One fused color-phase update of a lattice brick.

    Args:
      m: (Bx, By, Bz) int8 spins.
      s: (Bx, By, Bz) uint32 LFSR states.
      beta: scalar f32 inverse temperature.
      parity_mask: (Bx, By, Bz) int8 — 1 where this color updates (also folds
        the active-site mask for padded lattices).
      h: (Bx, By, Bz) f32 biases.
      w6: tuple (wxm, wxp, wym, wyp, wzm, wzp), each (Bx, By, Bz) f32 —
        coupling to the -x/+x/-y/+y/-z/+z neighbor (0 on open boundaries);
        cross-device couplings appear on both sides (shadow weights).
      halos: tuple (xlo (By,Bz), xhi (By,Bz), ylo (Bx,Bz), yhi (Bx,Bz),
        zlo (Bx,By), zhi (Bx,By)) int8 neighbor boundary planes.
      fmt: optional fixed-point format for the activation (s{4}{1} etc).
      bx: x tile size (defaults to whole brick).
      interpret: run the Pallas interpreter (CPU validation); False on TPU.

    Returns: (m_new, s_new).
    """
    Bx, By, Bz = m.shape
    bx = Bx if bx is None else bx
    if Bx % bx != 0:
        raise ValueError(f"Bx={Bx} not divisible by tile bx={bx}")
    nb = Bx // bx
    wxm, wxp, wym, wyp, wzm, wzp = w6
    xlo, xhi, ylo, yhi, zlo, zhi = halos
    beta_arr = jnp.asarray(beta, jnp.float32).reshape(1, 1)

    blk = (bx, By, Bz)
    cur = pl.BlockSpec(blk, lambda i: (i, 0, 0))
    prv = pl.BlockSpec(blk, lambda i: (jnp.maximum(i - 1, 0), 0, 0))
    nxt = pl.BlockSpec(blk, lambda i: (jnp.minimum(i + 1, nb - 1), 0, 0))
    full2 = lambda a, b: pl.BlockSpec((a, b), lambda i: (0, 0))
    xtile = lambda b2: pl.BlockSpec((bx, b2), lambda i: (i, 0))

    return pl.pallas_call(
        functools.partial(_kernel, fmt=fmt, nblocks=nb),
        grid=(nb,),
        in_specs=[
            cur,                      # parity_mask
            full2(1, 1),              # beta
            cur, cur, cur, cur, cur, cur, cur,   # h + 6 weights
            prv, cur, nxt,            # m at i-1, i, i+1
            full2(By, Bz), full2(By, Bz),        # xlo, xhi
            xtile(Bz), xtile(Bz),     # ylo, yhi
            xtile(By), xtile(By),     # zlo, zhi
            cur,                      # lfsr state
        ],
        out_specs=[cur, cur],
        out_shape=[
            jax.ShapeDtypeStruct((Bx, By, Bz), jnp.int8),
            jax.ShapeDtypeStruct((Bx, By, Bz), jnp.uint32),
        ],
        interpret=interpret,
    )(parity_mask, beta_arr, h, wxm, wxp, wym, wyp, wzm, wzp,
      m, m, m, xlo, xhi, ylo, yhi, zlo, zhi, s)
