"""Fused p-bit color-update Pallas kernel for 3D lattice bricks.

This is the compute hot-spot of the paper's machine: for every site of one
color group, gather the six neighbor spins, accumulate the local field from
on-chip weights, draw an LFSR random number, threshold a (quantized) tanh,
and write the new spin — all in one pass, exactly what one FPGA clock does
for a color group.

TPU adaptation (DESIGN.md): the FPGA's hardwired neighbor fabric becomes
shifted-plane reads of a VMEM-resident brick; the per-p-bit LFSR column
becomes a vectorized xorshift32 lane; s{4}{1} fixed point becomes a
round+clip on the activation.  The ``*_int`` kernel variants go all the way
to the hardware arithmetic: int8 on-chip couplings, int32 field
accumulation, and the tanh + float compare replaced by one unsigned compare
of the raw LFSR draw against a precomputed threshold LUT (DESIGN.md
"Fixed-point pipeline and threshold LUTs") — zero floating-point ops in the
inner loop.  The brick's x extent is tiled by BlockSpec
(grid over x-slabs); neighbor access across tile boundaries uses the
standard shifted-index-map halo pattern (the same input bound three times at
block indices i-1, i, i+1), and physical brick boundaries use explicit halo
planes produced by the inter-device ppermute exchange.

All operands of one grid step fit in VMEM: for a (bx, By, Bz) tile the
working set is 7 f32 weight/bias tiles + 3 int8 spin tiles + 1 u32 LFSR tile
+ 6 halo planes ~= (32 + 4) * bx*By*Bz bytes; the default bx keeps this
under 4 MiB.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.pbit import FixedPoint, lut_accept

__all__ = ["pbit_brick_update", "pbit_brick_sweep",
           "pbit_brick_update_int", "pbit_brick_sweep_int"]


def _kernel(parity_ref, beta_ref,
            h_ref, wxm_ref, wxp_ref, wym_ref, wyp_ref, wzm_ref, wzp_ref,
            m_l_ref, m_c_ref, m_r_ref,
            xlo_ref, xhi_ref, ylo_ref, yhi_ref, zlo_ref, zhi_ref,
            s_ref,
            m_out_ref, s_out_ref,
            *, fmt: Optional[FixedPoint], nblocks: int):
    i = pl.program_id(0)
    f32 = jnp.float32
    mc_raw = m_c_ref[...]
    mc = mc_raw.astype(f32)

    # x-direction neighbors: interior from the shifted blocks, edges from halos
    left_plane = jnp.where(i == 0, xlo_ref[...].astype(f32)[None],
                           m_l_ref[...][-1:].astype(f32))
    right_plane = jnp.where(i == nblocks - 1, xhi_ref[...].astype(f32)[None],
                            m_r_ref[...][:1].astype(f32))
    xm = jnp.concatenate([left_plane, mc[:-1]], axis=0)
    xp = jnp.concatenate([mc[1:], right_plane], axis=0)
    # y / z neighbors: in-tile shifts with per-tile halo planes
    ym = jnp.concatenate([ylo_ref[...].astype(f32)[:, None, :], mc[:, :-1]], axis=1)
    yp = jnp.concatenate([mc[:, 1:], yhi_ref[...].astype(f32)[:, None, :]], axis=1)
    zm = jnp.concatenate([zlo_ref[...].astype(f32)[:, :, None], mc[:, :, :-1]], axis=2)
    zp = jnp.concatenate([mc[:, :, 1:], zhi_ref[...].astype(f32)[:, :, None]], axis=2)

    field = (h_ref[...]
             + wxm_ref[...] * xm + wxp_ref[...] * xp
             + wym_ref[...] * ym + wyp_ref[...] * yp
             + wzm_ref[...] * zm + wzp_ref[...] * zp)

    # free-running per-site LFSR (every site advances every phase, like the
    # hardware's always-on LFSR columns)
    s = s_ref[...]
    s = s ^ (s << jnp.uint32(13))
    s = s ^ (s >> jnp.uint32(17))
    s = s ^ (s << jnp.uint32(5))
    r = (s >> jnp.uint32(8)).astype(f32) * f32(2.0 / 16777216.0) - f32(1.0)

    act = beta_ref[0, 0] * field
    if fmt is not None:
        act = jnp.clip(jnp.round(act / fmt.step) * fmt.step, fmt.lo, fmt.hi)
    upd = jnp.where(jnp.tanh(act) + r >= 0, 1, -1).astype(jnp.int8)
    mask = parity_ref[...] != 0
    m_out_ref[...] = jnp.where(mask, upd, mc_raw)
    s_out_ref[...] = s


# ---------------------------------------------------------------------------
# fused multi-phase sweep kernel
# ---------------------------------------------------------------------------
#
# One pallas_call runs the ENTIRE color cycle — and up to ``sweeps_per_call``
# sweeps between halo exchanges — against halos held fixed: the analogue of
# the FPGA retiring one color group per clock with no host round-trips.  The
# whole brick is a single block (no x tiling): later phases must read the
# spins earlier phases just wrote, which grid steps cannot do.  The LFSR
# column is read from VMEM once, advanced in registers through every phase,
# and written back once.
#
# VMEM working set for a (Bx, By, Bz) brick:
#   7 f32 weight/bias arrays            28 * B bytes
#   n_colors int8 parity masks     n_c * 1 * B
#   in/out spins (int8) + LFSR (u32)    10 * B
#   6 int8 halo planes                  ~6 * B^(2/3)
# ~= (38 + n_colors) * Bx*By*Bz bytes — a 32^3 brick with 3 colors is
# ~1.3 MiB, comfortably inside a 16 MiB VMEM budget; 48^3 (~4.5 MiB) still
# fits.  Larger bricks must fall back to the per-phase kernel, which tiles x.


def _sweep_kernel(betas_ref, masks_ref,
                  h_ref, wxm_ref, wxp_ref, wym_ref, wyp_ref, wzm_ref, wzp_ref,
                  m_ref,
                  xlo_ref, xhi_ref, ylo_ref, yhi_ref, zlo_ref, zhi_ref,
                  s_ref,
                  m_out_ref, s_out_ref, flips_ref,
                  *, fmt: Optional[FixedPoint], n_colors: int, n_sweeps: int):
    f32 = jnp.float32
    m = m_ref[...]
    s = s_ref[...]
    h = h_ref[...]
    wxm, wxp = wxm_ref[...], wxp_ref[...]
    wym, wyp = wym_ref[...], wyp_ref[...]
    wzm, wzp = wzm_ref[...], wzp_ref[...]
    xlo = xlo_ref[...].astype(f32)[None]
    xhi = xhi_ref[...].astype(f32)[None]
    ylo = ylo_ref[...].astype(f32)[:, None, :]
    yhi = yhi_ref[...].astype(f32)[:, None, :]
    zlo = zlo_ref[...].astype(f32)[:, :, None]
    zhi = zhi_ref[...].astype(f32)[:, :, None]
    flips = jnp.zeros((), jnp.int32)

    for t in range(n_sweeps):                     # static unroll: S is small
        beta = betas_ref[t, 0]   # (S, 1) layout, like the per-phase kernel's
                                 # (1, 1) scalar convention (2-D lowers
                                 # cleanly through Mosaic; 1-D scalars don't)
        for c in range(n_colors):
            mc = m.astype(f32)
            xm = jnp.concatenate([xlo, mc[:-1]], axis=0)
            xp = jnp.concatenate([mc[1:], xhi], axis=0)
            ym = jnp.concatenate([ylo, mc[:, :-1]], axis=1)
            yp = jnp.concatenate([mc[:, 1:], yhi], axis=1)
            zm = jnp.concatenate([zlo, mc[:, :, :-1]], axis=2)
            zp = jnp.concatenate([mc[:, :, 1:], zhi], axis=2)
            field = (h + wxm * xm + wxp * xp + wym * ym + wyp * yp
                     + wzm * zm + wzp * zp)
            s = s ^ (s << jnp.uint32(13))
            s = s ^ (s >> jnp.uint32(17))
            s = s ^ (s << jnp.uint32(5))
            r = (s >> jnp.uint32(8)).astype(f32) * f32(2.0 / 16777216.0) \
                - f32(1.0)
            act = beta * field
            if fmt is not None:
                act = jnp.clip(jnp.round(act / fmt.step) * fmt.step,
                               fmt.lo, fmt.hi)
            upd = jnp.where(jnp.tanh(act) + r >= 0, 1, -1).astype(jnp.int8)
            new = jnp.where(masks_ref[c] != 0, upd, m)
            flips = flips + (new != m).sum().astype(jnp.int32)
            m = new

    m_out_ref[...] = m
    s_out_ref[...] = s
    flips_ref[0, 0] = flips


# ---------------------------------------------------------------------------
# fixed-point fused sweep kernel (precision="int8")
# ---------------------------------------------------------------------------
#
# Identical dataflow to ``_sweep_kernel`` with every float op removed: the
# couplings arrive as int8, the field accumulates in int32, and the tanh +
# float-compare collapses to one unsigned compare of the raw 24-bit LFSR
# draw against a per-(beta, field) threshold read from a small uint32 LUT
# (``repro.core.pbit.threshold_lut``) held in VMEM.  Annealing enters as
# one LUT *row index* per sweep.  VMEM working set drops from
# (38 + n_c) B/site to (17 + n_c) B/site — see lattice_dsim's working-set
# model for the resulting brick ceiling.


def _sweep_kernel_int(rows_ref, lut_ref, masks_ref,
                      h_ref, wxm_ref, wxp_ref, wym_ref, wyp_ref, wzm_ref,
                      wzp_ref, m_ref,
                      xlo_ref, xhi_ref, ylo_ref, yhi_ref, zlo_ref, zhi_ref,
                      s_ref,
                      m_out_ref, s_out_ref, flips_ref,
                      *, n_colors: int, n_sweeps: int, f_off: int):
    i32 = jnp.int32
    m = m_ref[...]
    s = s_ref[...]
    lut = lut_ref[...]
    h = h_ref[...].astype(i32)
    wxm, wxp = wxm_ref[...].astype(i32), wxp_ref[...].astype(i32)
    wym, wyp = wym_ref[...].astype(i32), wyp_ref[...].astype(i32)
    wzm, wzp = wzm_ref[...].astype(i32), wzp_ref[...].astype(i32)
    # halo planes stay int8 — neighbor concats below keep the 1 B/site
    # layout and widen in registers inside the field accumulate
    xlo = xlo_ref[...][None]
    xhi = xhi_ref[...][None]
    ylo = ylo_ref[...][:, None, :]
    yhi = yhi_ref[...][:, None, :]
    zlo = zlo_ref[...][:, :, None]
    zhi = zhi_ref[...][:, :, None]
    flips = jnp.zeros((), jnp.int32)

    for t in range(n_sweeps):                     # static unroll: S is small
        thr = jax.lax.dynamic_index_in_dim(lut, rows_ref[t, 0], axis=0,
                                           keepdims=False)
        for c in range(n_colors):
            xm = jnp.concatenate([xlo, m[:-1]], axis=0).astype(i32)
            xp = jnp.concatenate([m[1:], xhi], axis=0).astype(i32)
            ym = jnp.concatenate([ylo, m[:, :-1]], axis=1).astype(i32)
            yp = jnp.concatenate([m[:, 1:], yhi], axis=1).astype(i32)
            zm = jnp.concatenate([zlo, m[:, :, :-1]], axis=2).astype(i32)
            zp = jnp.concatenate([m[:, :, 1:], zhi], axis=2).astype(i32)
            field = (h + wxm * xm + wxp * xp + wym * ym + wyp * yp
                     + wzm * zm + wzp * zp)
            s = s ^ (s << jnp.uint32(13))
            s = s ^ (s >> jnp.uint32(17))
            s = s ^ (s << jnp.uint32(5))
            u = s >> jnp.uint32(8)
            upd = jnp.where(lut_accept(thr, field, f_off, u),
                            1, -1).astype(jnp.int8)
            new = jnp.where(masks_ref[c] != 0, upd, m)
            flips = flips + (new != m).sum().astype(jnp.int32)
            m = new

    m_out_ref[...] = m
    s_out_ref[...] = s
    flips_ref[0, 0] = flips


@functools.partial(jax.jit, static_argnames=("interpret",))
def pbit_brick_sweep_int(m, s, rows, masks, h_q, w6_q, halos, lut,
                         interpret: bool = True):
    """``len(rows)`` fused fixed-point sweeps of one brick.

    Args match :func:`pbit_brick_sweep` except:
      rows: (S,) int32 — LUT row index (= beta staircase entry) per sweep.
      h_q / w6_q: int8 quantized biases and couplings
        (:func:`repro.core.pbit.quantize_couplings`).
      lut: (n_rows, 2*f_max+1) uint32 acceptance thresholds
        (:func:`repro.core.pbit.threshold_lut`).

    Returns (m_new, s_new, flips).  Bit-exact against
    :func:`repro.kernels.ref.pbit_brick_sweep_int_ref`.
    """
    Bx, By, Bz = m.shape
    n_colors, S = int(masks.shape[0]), int(rows.shape[0])
    n_rows, lw = lut.shape
    wxm, wxp, wym, wyp, wzm, wzp = w6_q
    xlo, xhi, ylo, yhi, zlo, zhi = halos
    rows = jnp.asarray(rows, jnp.int32).reshape(S, 1)

    whole = pl.BlockSpec((Bx, By, Bz), lambda: (0, 0, 0))
    full = lambda *sh: pl.BlockSpec(sh, lambda: (0,) * len(sh))

    m_new, s_new, flips = pl.pallas_call(
        functools.partial(_sweep_kernel_int, n_colors=n_colors, n_sweeps=S,
                          f_off=(lw - 1) // 2),
        grid=(),
        in_specs=[
            full(S, 1),                           # LUT row per sweep
            full(n_rows, lw),                     # threshold LUT
            full(n_colors, Bx, By, Bz),           # masks
            whole, whole, whole, whole, whole, whole, whole,  # h_q + 6 w_q
            whole,                                # m
            full(By, Bz), full(By, Bz),           # xlo, xhi
            full(Bx, Bz), full(Bx, Bz),           # ylo, yhi
            full(Bx, By), full(Bx, By),           # zlo, zhi
            whole,                                # lfsr state
        ],
        out_specs=[whole, whole, full(1, 1)],
        out_shape=[
            jax.ShapeDtypeStruct((Bx, By, Bz), jnp.int8),
            jax.ShapeDtypeStruct((Bx, By, Bz), jnp.uint32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        interpret=interpret,
    )(rows, lut, masks, h_q, wxm, wxp, wym, wyp, wzm, wzp,
      m, xlo, xhi, ylo, yhi, zlo, zhi, s)
    return m_new, s_new, flips[0, 0]


@functools.partial(jax.jit, static_argnames=("fmt", "interpret"))
def pbit_brick_sweep(m, s, betas, masks, h, w6, halos,
                     fmt: Optional[FixedPoint] = None,
                     interpret: bool = True):
    """``len(betas)`` fused full sweeps (all color phases) of one brick.

    Args match :func:`pbit_brick_update` except:
      betas: (S,) f32 — one inverse temperature per sweep; the whole batch
        runs between two halo exchanges, so halos stay fixed throughout.
      masks: (n_colors, Bx, By, Bz) int8 color parity masks, updated in
        index order each sweep.

    Returns (m_new, s_new, flips) — flips is the int32 number of accepted
    spin changes over all S * n_colors phases, counted in-kernel.

    Bitwise-identical to S * n_colors chained :func:`pbit_brick_update`
    calls (the per-phase reference path, kept for exactly that comparison).
    """
    Bx, By, Bz = m.shape
    n_colors, S = int(masks.shape[0]), int(betas.shape[0])
    wxm, wxp, wym, wyp, wzm, wzp = w6
    xlo, xhi, ylo, yhi, zlo, zhi = halos
    betas = jnp.asarray(betas, jnp.float32).reshape(S, 1)

    whole = pl.BlockSpec((Bx, By, Bz), lambda: (0, 0, 0))
    full = lambda *sh: pl.BlockSpec(sh, lambda: (0,) * len(sh))

    m_new, s_new, flips = pl.pallas_call(
        functools.partial(_sweep_kernel, fmt=fmt, n_colors=n_colors,
                          n_sweeps=S),
        grid=(),
        in_specs=[
            full(S, 1),                           # betas
            full(n_colors, Bx, By, Bz),           # masks
            whole, whole, whole, whole, whole, whole, whole,  # h + 6 weights
            whole,                                # m
            full(By, Bz), full(By, Bz),           # xlo, xhi
            full(Bx, Bz), full(Bx, Bz),           # ylo, yhi
            full(Bx, By), full(Bx, By),           # zlo, zhi
            whole,                                # lfsr state
        ],
        out_specs=[whole, whole, full(1, 1)],
        out_shape=[
            jax.ShapeDtypeStruct((Bx, By, Bz), jnp.int8),
            jax.ShapeDtypeStruct((Bx, By, Bz), jnp.uint32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        interpret=interpret,
    )(betas, masks, h, wxm, wxp, wym, wyp, wzm, wzp,
      m, xlo, xhi, ylo, yhi, zlo, zhi, s)
    return m_new, s_new, flips[0, 0]


@functools.partial(jax.jit, static_argnames=("fmt", "bx", "interpret"))
def pbit_brick_update(m, s, beta, parity_mask, h, w6, halos,
                      fmt: Optional[FixedPoint] = None,
                      bx: Optional[int] = None,
                      interpret: bool = True):
    """One fused color-phase update of a lattice brick.

    Args:
      m: (Bx, By, Bz) int8 spins.
      s: (Bx, By, Bz) uint32 LFSR states.
      beta: scalar f32 inverse temperature.
      parity_mask: (Bx, By, Bz) int8 — 1 where this color updates (also folds
        the active-site mask for padded lattices).
      h: (Bx, By, Bz) f32 biases.
      w6: tuple (wxm, wxp, wym, wyp, wzm, wzp), each (Bx, By, Bz) f32 —
        coupling to the -x/+x/-y/+y/-z/+z neighbor (0 on open boundaries);
        cross-device couplings appear on both sides (shadow weights).
      halos: tuple (xlo (By,Bz), xhi (By,Bz), ylo (Bx,Bz), yhi (Bx,Bz),
        zlo (Bx,By), zhi (Bx,By)) int8 neighbor boundary planes.
      fmt: optional fixed-point format for the activation (s{4}{1} etc).
      bx: x tile size (defaults to whole brick).
      interpret: run the Pallas interpreter (CPU validation); False on TPU.

    Returns: (m_new, s_new).
    """
    Bx, By, Bz = m.shape
    bx = Bx if bx is None else bx
    if Bx % bx != 0:
        raise ValueError(f"Bx={Bx} not divisible by tile bx={bx}")
    nb = Bx // bx
    wxm, wxp, wym, wyp, wzm, wzp = w6
    xlo, xhi, ylo, yhi, zlo, zhi = halos
    beta_arr = jnp.asarray(beta, jnp.float32).reshape(1, 1)

    blk = (bx, By, Bz)
    cur = pl.BlockSpec(blk, lambda i: (i, 0, 0))
    prv = pl.BlockSpec(blk, lambda i: (jnp.maximum(i - 1, 0), 0, 0))
    nxt = pl.BlockSpec(blk, lambda i: (jnp.minimum(i + 1, nb - 1), 0, 0))
    full2 = lambda a, b: pl.BlockSpec((a, b), lambda i: (0, 0))
    xtile = lambda b2: pl.BlockSpec((bx, b2), lambda i: (i, 0))

    return pl.pallas_call(
        functools.partial(_kernel, fmt=fmt, nblocks=nb),
        grid=(nb,),
        in_specs=[
            cur,                      # parity_mask
            full2(1, 1),              # beta
            cur, cur, cur, cur, cur, cur, cur,   # h + 6 weights
            prv, cur, nxt,            # m at i-1, i, i+1
            full2(By, Bz), full2(By, Bz),        # xlo, xhi
            xtile(Bz), xtile(Bz),     # ylo, yhi
            xtile(By), xtile(By),     # zlo, zhi
            cur,                      # lfsr state
        ],
        out_specs=[cur, cur],
        out_shape=[
            jax.ShapeDtypeStruct((Bx, By, Bz), jnp.int8),
            jax.ShapeDtypeStruct((Bx, By, Bz), jnp.uint32),
        ],
        interpret=interpret,
    )(parity_mask, beta_arr, h, wxm, wxp, wym, wyp, wzm, wzp,
      m, m, m, xlo, xhi, ylo, yhi, zlo, zhi, s)


def _kernel_int(parity_ref, row_ref, lut_ref,
                h_ref, wxm_ref, wxp_ref, wym_ref, wyp_ref, wzm_ref, wzp_ref,
                m_l_ref, m_c_ref, m_r_ref,
                xlo_ref, xhi_ref, ylo_ref, yhi_ref, zlo_ref, zhi_ref,
                s_ref,
                m_out_ref, s_out_ref,
                *, nblocks: int, f_off: int):
    i = pl.program_id(0)
    i32 = jnp.int32
    mc_raw = m_c_ref[...]

    # x-direction neighbors: interior from the shifted blocks, edges from
    # halos — assembled in int8 (1 B/site), widened in the accumulate
    left_plane = jnp.where(i == 0, xlo_ref[...][None], m_l_ref[...][-1:])
    right_plane = jnp.where(i == nblocks - 1, xhi_ref[...][None],
                            m_r_ref[...][:1])
    xm = jnp.concatenate([left_plane, mc_raw[:-1]], axis=0).astype(i32)
    xp = jnp.concatenate([mc_raw[1:], right_plane], axis=0).astype(i32)
    ym = jnp.concatenate([ylo_ref[...][:, None, :], mc_raw[:, :-1]],
                         axis=1).astype(i32)
    yp = jnp.concatenate([mc_raw[:, 1:], yhi_ref[...][:, None, :]],
                         axis=1).astype(i32)
    zm = jnp.concatenate([zlo_ref[...][:, :, None], mc_raw[:, :, :-1]],
                         axis=2).astype(i32)
    zp = jnp.concatenate([mc_raw[:, :, 1:], zhi_ref[...][:, :, None]],
                         axis=2).astype(i32)

    field = (h_ref[...].astype(i32)
             + wxm_ref[...].astype(i32) * xm + wxp_ref[...].astype(i32) * xp
             + wym_ref[...].astype(i32) * ym + wyp_ref[...].astype(i32) * yp
             + wzm_ref[...].astype(i32) * zm + wzp_ref[...].astype(i32) * zp)

    s = s_ref[...]
    s = s ^ (s << jnp.uint32(13))
    s = s ^ (s >> jnp.uint32(17))
    s = s ^ (s << jnp.uint32(5))
    u = s >> jnp.uint32(8)

    thr = jax.lax.dynamic_index_in_dim(lut_ref[...], row_ref[0, 0], axis=0,
                                       keepdims=False)
    upd = jnp.where(lut_accept(thr, field, f_off, u), 1, -1).astype(jnp.int8)
    mask = parity_ref[...] != 0
    m_out_ref[...] = jnp.where(mask, upd, mc_raw)
    s_out_ref[...] = s


@functools.partial(jax.jit, static_argnames=("bx", "interpret"))
def pbit_brick_update_int(m, s, row, parity_mask, h_q, w6_q, halos, lut,
                          bx: Optional[int] = None,
                          interpret: bool = True):
    """One fixed-point color-phase update of a lattice brick (x-tiled).

    Args match :func:`pbit_brick_update` except ``row`` (scalar int32 LUT
    row index replacing beta), int8 ``h_q``/``w6_q``, and the uint32
    threshold ``lut``.  Bit-exact against
    :func:`repro.kernels.ref.pbit_brick_update_int_ref`.
    """
    Bx, By, Bz = m.shape
    bx = Bx if bx is None else bx
    if Bx % bx != 0:
        raise ValueError(f"Bx={Bx} not divisible by tile bx={bx}")
    nb = Bx // bx
    n_rows, lw = lut.shape
    wxm, wxp, wym, wyp, wzm, wzp = w6_q
    xlo, xhi, ylo, yhi, zlo, zhi = halos
    row_arr = jnp.asarray(row, jnp.int32).reshape(1, 1)

    blk = (bx, By, Bz)
    cur = pl.BlockSpec(blk, lambda i: (i, 0, 0))
    prv = pl.BlockSpec(blk, lambda i: (jnp.maximum(i - 1, 0), 0, 0))
    nxt = pl.BlockSpec(blk, lambda i: (jnp.minimum(i + 1, nb - 1), 0, 0))
    full2 = lambda a, b: pl.BlockSpec((a, b), lambda i: (0, 0))
    xtile = lambda b2: pl.BlockSpec((bx, b2), lambda i: (i, 0))

    return pl.pallas_call(
        functools.partial(_kernel_int, nblocks=nb, f_off=(lw - 1) // 2),
        grid=(nb,),
        in_specs=[
            cur,                      # parity_mask
            full2(1, 1),              # LUT row index
            full2(n_rows, lw),        # threshold LUT
            cur, cur, cur, cur, cur, cur, cur,   # h_q + 6 quantized weights
            prv, cur, nxt,            # m at i-1, i, i+1
            full2(By, Bz), full2(By, Bz),        # xlo, xhi
            xtile(Bz), xtile(Bz),     # ylo, yhi
            xtile(By), xtile(By),     # zlo, zhi
            cur,                      # lfsr state
        ],
        out_specs=[cur, cur],
        out_shape=[
            jax.ShapeDtypeStruct((Bx, By, Bz), jnp.int8),
            jax.ShapeDtypeStruct((Bx, By, Bz), jnp.uint32),
        ],
        interpret=interpret,
    )(parity_mask, row_arr, lut, h_q, wxm, wxp, wym, wyp, wzm, wzp,
      m, m, m, xlo, xhi, ylo, yhi, zlo, zhi, s)
