"""Multi-spin-coded Pallas sweep kernel: 32 replica lanes per uint32 word.

The paper's machine keeps every spin as literally one bit; this kernel does
the same in software — spins arrive as bit-planes (bit r of a word is
replica lane r's spin), so the neighbor gather, sign application, and field
count advance all 32 lanes with word-wide bitwise ops:

  * the six neighbor word-planes are the usual shifted-plane reads of the
    VMEM-resident brick (word halo planes at the faces);
  * the +-J coupling collapses to one XOR with a per-site *sign plane*
    (all-ones words where w < 0) and an AND with the nonzero mask;
  * the +1-contribution count c (the only lane-varying part of the field)
    is a bit-sliced carry-save adder tree — two 3:2 full adders plus a
    combine, 3 bit-slices for c in [0, 6]; 4 slices bound the 13-value
    +-J field once the lane-independent ``base = h_q - nnz + f_max`` plane
    folds in the rest.

Only the RNG and the threshold accept are per lane (an unrolled lane loop):
each lane owns its LFSR column — packed chains share NO randomness — and
acceptance is PR 2's rank-count compare against the threshold-LUT row of
that lane's staircase entry.  Everything is integer; lane r is bit-exact
against replica r of the int8 pipeline.

This kernel is the ONE-WORD primitive of the multi-word lane fabric:
replica counts past 32 stack extra word planes, and the word loop lives in
``kernels.ops.pbit_bitplane_sweep_op`` — word planes are independent
replica sets, so each plane is its own launch at the same traced shapes,
and one compiled executable serves every replica count in a word bucket.

VMEM working set for a (Bx, By, Bz) brick of R lanes:
  in/out spin words (u32)                 8 B/site
  in/out LFSR columns (u32, R lanes)      8R B/site
  6 sign + 6 nonzero planes (u32)         48 B/site
  base (i32) + n_c color masks (u32)      (4 + 4 n_c) B/site
~= (60 + 4 n_c + 8 R) B/site — ~328 B/site at R=32, n_c=3, i.e. ~10.3
B/site/replica-lane (vs the int8 path's 17 + n_c) and ONE launch where the
int8 path needs R.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["pbit_bitplane_sweep"]


def _bitplane_kernel(rows_ref, lut_ref, masks_ref,
                     sxm_ref, sxp_ref, sym_ref, syp_ref, szm_ref, szp_ref,
                     nxm_ref, nxp_ref, nym_ref, nyp_ref, nzm_ref, nzp_ref,
                     base_ref, m_ref,
                     xlo_ref, xhi_ref, ylo_ref, yhi_ref, zlo_ref, zhi_ref,
                     s_ref,
                     m_out_ref, s_out_ref, flips_ref,
                     *, n_colors: int, n_sweeps: int, n_lanes: int,
                     lut_width: int):
    i32 = jnp.int32
    u32 = jnp.uint32
    one = u32(1)
    mw = m_ref[...]
    base = base_ref[...]
    signs = (sxm_ref[...], sxp_ref[...], sym_ref[...],
             syp_ref[...], szm_ref[...], szp_ref[...])
    nzs = (nxm_ref[...], nxp_ref[...], nym_ref[...],
           nyp_ref[...], nzm_ref[...], nzp_ref[...])
    xlo = xlo_ref[...][None]
    xhi = xhi_ref[...][None]
    ylo = ylo_ref[...][:, None, :]
    yhi = yhi_ref[...][:, None, :]
    zlo = zlo_ref[...][:, :, None]
    zhi = zhi_ref[...][:, :, None]
    lut = lut_ref[...]
    # per-lane LFSR columns carried in registers across every phase
    lfsr = [s_ref[r] for r in range(n_lanes)]
    flips = [jnp.zeros((), i32) for _ in range(n_lanes)]

    for t in range(n_sweeps):                     # static unroll: S is small
        for c in range(n_colors):
            xm = jnp.concatenate([xlo, mw[:-1]], axis=0)
            xp = jnp.concatenate([mw[1:], xhi], axis=0)
            ym = jnp.concatenate([ylo, mw[:, :-1]], axis=1)
            yp = jnp.concatenate([mw[:, 1:], yhi], axis=1)
            zm = jnp.concatenate([zlo, mw[:, :, :-1]], axis=2)
            zp = jnp.concatenate([mw[:, :, 1:], zhi], axis=2)
            tb = [(nb ^ sg) & nz for nb, sg, nz in
                  zip((xm, xp, ym, yp, zm, zp), signs, nzs)]
            # carry-save adder tree: c = b0 + 2 b1 + 4 b2, all 32 lanes
            s1 = tb[0] ^ tb[1] ^ tb[2]
            c1 = (tb[0] & tb[1]) | (tb[2] & (tb[0] ^ tb[1]))
            s2 = tb[3] ^ tb[4] ^ tb[5]
            c2 = (tb[3] & tb[4]) | (tb[5] & (tb[3] ^ tb[4]))
            b0 = s1 ^ s2
            k = s1 & s2
            b1 = c1 ^ c2 ^ k
            b2 = (c1 & c2) | (k & (c1 ^ c2))

            upd = jnp.zeros(mw.shape, u32)
            for r in range(n_lanes):              # per-lane RNG + accept
                s = lfsr[r]
                s = s ^ (s << u32(13))
                s = s ^ (s >> u32(17))
                s = s ^ (s << u32(5))
                lfsr[r] = s
                u = s >> u32(8)
                thr = jax.lax.dynamic_index_in_dim(
                    lut, rows_ref[t, r], axis=0, keepdims=False)
                ur = u32(r)
                cnt = (((b0 >> ur) & one).astype(i32)
                       + 2 * ((b1 >> ur) & one).astype(i32)
                       + 4 * ((b2 >> ur) & one).astype(i32))
                idx = jnp.clip(base + 2 * cnt, 0, lut_width - 1)
                count = jnp.zeros(u.shape, i32)
                for q in range(lut_width):        # rank-count accept
                    count = count + (u >= thr[q]).astype(i32)
                accept = idx + count >= lut_width
                upd = upd | (accept.astype(u32) << ur)

            new = (mw & ~masks_ref[c]) | (upd & masks_ref[c])
            diff = mw ^ new
            for r in range(n_lanes):
                flips[r] = flips[r] + ((diff >> u32(r)) & one) \
                    .astype(i32).sum()
            mw = new

    m_out_ref[...] = mw
    for r in range(n_lanes):
        s_out_ref[r] = lfsr[r]
        flips_ref[r, 0] = flips[r]


@functools.partial(jax.jit, static_argnames=("interpret",))
def pbit_bitplane_sweep(mw, s, rows, masks_w, signs6, nz6, base, halos_w,
                        lut, interpret: bool = True):
    """``rows.shape[0]`` fused multi-spin-coded sweeps of one brick.

    Args match :func:`repro.kernels.ref.pbit_bitplane_sweep_ref` (rows must
    already be (S, R)).  Returns (mw_new, s_new, flips) with flips (R,)
    int32 per-lane counts.  Bit-exact against the oracle.
    """
    Bx, By, Bz = mw.shape
    R = int(s.shape[0])
    S = int(rows.shape[0])
    n_colors = int(masks_w.shape[0])
    n_rows, lw = lut.shape
    sxm, sxp, sym, syp, szm, szp = signs6
    nxm, nxp, nym, nyp, nzm, nzp = nz6
    xlo, xhi, ylo, yhi, zlo, zhi = halos_w
    rows = jnp.asarray(rows, jnp.int32).reshape(S, R)

    whole = pl.BlockSpec((Bx, By, Bz), lambda: (0, 0, 0))
    full = lambda *sh: pl.BlockSpec(sh, lambda: (0,) * len(sh))

    m_new, s_new, flips = pl.pallas_call(
        functools.partial(_bitplane_kernel, n_colors=n_colors, n_sweeps=S,
                          n_lanes=R, lut_width=lw),
        grid=(),
        in_specs=[
            full(S, R),                           # LUT row per (sweep, lane)
            full(n_rows, lw),                     # threshold LUT
            full(n_colors, Bx, By, Bz),           # lane-masked color masks
            whole, whole, whole, whole, whole, whole,   # 6 sign planes
            whole, whole, whole, whole, whole, whole,   # 6 nonzero planes
            whole,                                # base (i32)
            whole,                                # spin words
            full(By, Bz), full(By, Bz),           # xlo, xhi
            full(Bx, Bz), full(Bx, Bz),           # ylo, yhi
            full(Bx, By), full(Bx, By),           # zlo, zhi
            full(R, Bx, By, Bz),                  # LFSR columns
        ],
        out_specs=[whole, full(R, Bx, By, Bz), full(R, 1)],
        out_shape=[
            jax.ShapeDtypeStruct((Bx, By, Bz), jnp.uint32),
            jax.ShapeDtypeStruct((R, Bx, By, Bz), jnp.uint32),
            jax.ShapeDtypeStruct((R, 1), jnp.int32),
        ],
        interpret=interpret,
    )(rows, lut, masks_w, sxm, sxp, sym, syp, szm, szp,
      nxm, nxp, nym, nyp, nzm, nzp, base, mw,
      xlo, xhi, ylo, yhi, zlo, zhi, s)
    return m_new, s_new, flips[:, 0]
