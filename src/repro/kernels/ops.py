"""Jit'd dispatch wrappers for the Pallas kernels.

``impl`` selection:
  'pallas'    — real Pallas lowering (TPU target).
  'interpret' — Pallas interpreter (CPU correctness validation).
  'ref'       — the pure-jnp oracle (fast CPU path; numerically identical).
  'auto'      — 'pallas' on TPU backends, 'ref' elsewhere.
"""

from __future__ import annotations

from typing import Optional

import jax

from repro.core.pbit import FixedPoint
from . import pbit_bitplane, pbit_lattice, lattice_energy, ref as _ref

__all__ = ["pbit_update_op", "pbit_sweep_op", "pbit_update_int_op",
           "pbit_sweep_int_op", "pbit_bitplane_sweep_op",
           "bitplane_gather_count_op", "brick_energy_op",
           "default_impl"]


def default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _resolve(impl: str) -> str:
    return default_impl() if impl == "auto" else impl


def pbit_update_op(m, s, beta, parity_mask, h, w6, halos,
                   fmt: Optional[FixedPoint] = None,
                   bx: Optional[int] = None, impl: str = "auto"):
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.pbit_brick_update_ref(m, s, beta, parity_mask, h, w6, halos, fmt)
    return pbit_lattice.pbit_brick_update(
        m, s, beta, parity_mask, h, w6, halos, fmt=fmt, bx=bx,
        interpret=(impl == "interpret"))


def pbit_sweep_op(m, s, betas, masks, h, w6, halos,
                  fmt: Optional[FixedPoint] = None, impl: str = "auto"):
    """Fused multi-phase sweep: len(betas) full color cycles in one kernel
    launch (halos fixed).  Returns (m, s, flips:int32)."""
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.pbit_brick_sweep_ref(m, s, betas, masks, h, w6, halos,
                                         fmt)
    return pbit_lattice.pbit_brick_sweep(
        m, s, betas, masks, h, w6, halos, fmt=fmt,
        interpret=(impl == "interpret"))


def pbit_update_int_op(m, s, row, parity_mask, h_q, w6_q, halos, lut,
                       bx: Optional[int] = None, impl: str = "auto"):
    """Fixed-point color-phase update: int8 couplings, int32 fields, LUT
    thresholds (``row`` is the LUT row index replacing beta)."""
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.pbit_brick_update_int_ref(m, s, row, parity_mask, h_q,
                                              w6_q, halos, lut)
    return pbit_lattice.pbit_brick_update_int(
        m, s, row, parity_mask, h_q, w6_q, halos, lut, bx=bx,
        interpret=(impl == "interpret"))


def pbit_sweep_int_op(m, s, rows, masks, h_q, w6_q, halos, lut,
                      impl: str = "auto"):
    """Fused fixed-point multi-phase sweep: len(rows) full color cycles in
    one launch, annealing as LUT row indices.  Returns (m, s, flips)."""
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.pbit_brick_sweep_int_ref(m, s, rows, masks, h_q, w6_q,
                                             halos, lut)
    return pbit_lattice.pbit_brick_sweep_int(
        m, s, rows, masks, h_q, w6_q, halos, lut,
        interpret=(impl == "interpret"))


def pbit_bitplane_sweep_op(mw, s, rows, masks_w, signs6, nz6, base, halos_w,
                           lut, impl: str = "auto"):
    """Multi-spin-coded fused sweep over W stacked word planes: 32 replica
    lanes per uint32 word, lane l = word l//32 bit l%32, one launch per
    word plane per ``sync_every`` sweeps.

    ``mw`` is (W, Bx, By, Bz); ``masks_w`` (n_colors, W, ...); each halo
    carries a leading W axis; ``rows`` is (S,) shared or (S, R) per-lane
    LUT row indices.  Word planes are independent replica sets, so the op
    loops the Pallas kernel over the word axis — the kernel itself stays a
    one-word primitive, and because every full word traces at the same
    (Bx, By, Bz, 32) shapes, ONE compiled executable serves any replica
    count in the same word bucket.  Returns (mw, s, flips:(R,) int32).
    """
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.pbit_bitplane_sweep_ref(mw, s, rows, masks_w, signs6,
                                            nz6, base, halos_w, lut)
    import jax.numpy as jnp
    W = int(mw.shape[0])
    R = int(s.shape[0])
    rows = jnp.asarray(rows, jnp.int32)
    mws, ss, fls = [], [], []
    for w in range(W):
        r0, r1 = w * 32, min(w * 32 + 32, R)
        rw = rows[:, r0:r1] if rows.ndim == 2 else \
            jnp.broadcast_to(rows[:, None], (int(rows.shape[0]), r1 - r0))
        out = pbit_bitplane.pbit_bitplane_sweep(
            mw[w], s[r0:r1], rw, masks_w[:, w], signs6, nz6, base,
            tuple(h[w] for h in halos_w), lut,
            interpret=(impl == "interpret"))
        mws.append(out[0])
        ss.append(out[1])
        fls.append(out[2])
    return (jnp.stack(mws), jnp.concatenate(ss), jnp.concatenate(fls))


def bitplane_gather_count_op(mext_w, idx_c, signs_c, nz_c, impl: str = "auto"):
    """Per-lane +1-contribution bit-slice planes for a gather-graph (ELL)
    site set — the D-neighbor word-field accumulator shared by the mesh
    engine's bitplane path and the lane-packed APT ladder.  Runs inside
    shard_map / jit bodies, so only the jnp formulation exists today; a
    Mosaic lowering of the gather+CSA chain would slot in here."""
    del impl    # ref-only: the gather path has no Pallas lowering yet
    return _ref.bitplane_gather_count_ref(mext_w, idx_c, signs_c, nz_c)


def brick_energy_op(m, active, h, w6, halos, bx: Optional[int] = None,
                    impl: str = "auto"):
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.brick_energy_ref(m, active, h, w6, halos)
    return lattice_energy.brick_energy(
        m, active, h, w6, halos, bx=bx, interpret=(impl == "interpret"))
