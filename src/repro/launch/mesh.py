"""Production mesh construction.

Single pod = 16x16 = 256 chips (TPU v5e pod), axes ('data', 'model').
Multi-pod = 2 pods = (2, 16, 16) = 512 chips, axes ('pod', 'data', 'model').

A FUNCTION, not a module constant: importing this module never touches jax
device state (required so smoke tests see 1 device).
"""

from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh

__all__ = ["make_production_mesh", "make_mesh_shape"]


def make_mesh_shape(multi_pod: bool = False):
    if multi_pod:
        return (2, 16, 16), ("pod", "data", "model")
    return (16, 16), ("data", "model")


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape, axes = make_mesh_shape(multi_pod)
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devs)} — the "
            f"dry-run entrypoint must set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            f"any jax import")
    from repro.compat import make_mesh, auto_axes
    return make_mesh(shape, axes, axis_types=auto_axes(len(axes)),
                     devices=devs[:n])
