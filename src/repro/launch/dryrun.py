import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile the paper's 1M-p-bit sampling chunk
on placeholder devices, prove memory fits, and extract the roofline terms
(FLOPs / bytes / collective schedule).

MUST be run as its own process (the XLA_FLAGS line above has to execute
before any jax import — which is why it is the first statement of this
module and why nothing here is imported by conftest or the benchmarks).

Usage:
  python -m repro.launch.dryrun --all
  python -m repro.launch.dryrun --arch ising-1m --multi-pod
"""

import argparse
import json
import time
import traceback

import numpy as np

from repro.configs import get_config, list_configs
from repro.configs.base import ShapeCell
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import roofline

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "reports", "dryrun")


def lower_ising_cell(mesh, multi_pod: bool, L: int = 100,
                     iters: int = 2, S: int = 4):
    """The paper's 1M-p-bit production workload on the production mesh."""
    from repro.core.lattice import build_ea3d_lattice
    from repro.core.lattice_dsim import LatticeDSIM
    if multi_pod:
        dim_axes = ("data", "model", "pod")      # z (periodic) -> pod (2 | 100)
    else:
        dim_axes = ("data", "model", None)
    pad = (112, 112)                              # x,y padded to 16*7
    prob = build_ea3d_lattice(L, seed=0, pad_xy=pad)
    eng = LatticeDSIM(prob, mesh, dim_axes=dim_axes, impl="ref")
    lowered = eng.lower_chunk(iters=iters, S=S)
    extras = {"p_bits": L ** 3, "padded_sites": int(np.prod(prob.dims)),
              "n_colors": prob.n_colors, "sync_every": S}
    return lowered, extras


def run_cell(arch: str, multi_pod: bool, report_dir: str = REPORT_DIR) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    cfg = get_config(arch)
    if cfg.family != "ising":
        raise ValueError(f"{arch!r} is not an ising config; the dry-run "
                         "covers the p-bit production workload")
    cell = ShapeCell("sample_chunk", 0, 0, "sample")
    lowered, extras = lower_ising_cell(mesh, multi_pod)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    memd = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes"):
        memd[f] = getattr(mem, f, None)
    rep = roofline(compiled, chips, model_flops=None)
    rec = {
        "arch": arch, "shape": cell.name,
        "mesh": "multi_pod_2x16x16" if multi_pod else "single_pod_16x16",
        "chips": chips, "ok": True,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": memd, "extras": extras,
        "roofline": rep.as_dict(), "model_flops_global": None,
    }
    os.makedirs(report_dir, exist_ok=True)
    fn = f"{arch}__{cell.name}__{rec['mesh']}.json"
    with open(os.path.join(report_dir, fn), "w") as f:
        json.dump(rec, f, indent=1, default=str)
    return rec


def all_cells():
    for arch, cfg in list_configs().items():
        if cfg.family == "ising":
            yield arch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--report-dir", default=REPORT_DIR)
    args = ap.parse_args()

    cells = list(all_cells()) if args.all else [args.arch]
    meshes = [False, True] if (args.all or args.both_meshes) \
        else [args.multi_pod]
    failures = 0
    for arch in cells:
        for mp in meshes:
            mesh_tag = "multi_pod_2x16x16" if mp else "single_pod_16x16"
            tag = f"{arch:22s} sample_chunk   {'2x16x16' if mp else '16x16  '}"
            if args.skip_existing and os.path.exists(os.path.join(
                    args.report_dir,
                    f"{arch}__sample_chunk__{mesh_tag}.json")):
                print(f"SKIP {tag}")
                continue
            try:
                rec = run_cell(arch, mp, args.report_dir)
                r = rec["roofline"]
                print(f"OK   {tag} compile={rec['compile_s']:7.1f}s "
                      f"flops={r['flops']:.3e} wire={r['wire_bytes']:.3e} "
                      f"bottleneck={r['bottleneck']}")
            except Exception as e:
                failures += 1
                print(f"FAIL {tag} {type(e).__name__}: {e}")
                traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
