import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell on placeholder devices, prove memory fits, and extract the
roofline terms (FLOPs / bytes / collective schedule).

MUST be run as its own process (the XLA_FLAGS line above has to execute
before any jax import — which is why it is the first statement of this
module and why nothing here is imported by conftest or the benchmarks).

Usage:
  python -m repro.launch.dryrun --all
  python -m repro.launch.dryrun --arch deepseek-67b --shape train_4k --multi-pod
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, list_configs
from repro.configs.base import SHAPES, ShapeCell
from repro.models.lm import build_model
from repro.train.optimizer import AdamW
from repro.train.train_step import TrainState, make_train_step
from repro.serve.serve_step import (make_prefill_step, make_decode_step,
                                    cache_len_for)
from repro.sharding.rules import (params_shardings, batch_shardings,
                                  cache_shardings, train_state_shardings,
                                  batch_axes)
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import roofline, HW

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "reports", "dryrun")


# ---------------------------------------------------------------------------
# input specs
# ---------------------------------------------------------------------------


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def input_specs(cfg, cell: ShapeCell, mesh) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = cell.global_batch, cell.seq_len
    bax = batch_axes(mesh)
    bspec = P(bax if B % int(np.prod([mesh.shape[a] for a in bax])) == 0
              else None)
    tok = lambda s: _sds((B, s), jnp.int32, mesh, bspec)
    if cell.kind == "train":
        if cfg.encdec:
            half = S // 2
            return {"frames": _sds((B, half, cfg.d_model), jnp.bfloat16,
                                   mesh, bspec),
                    "tokens": tok(half), "targets": tok(half),
                    "mask": tok(half)}
        if cfg.input_kind == "embeds3":
            return {"embeds": _sds((B, S, cfg.d_model), jnp.bfloat16, mesh,
                                   bspec),
                    "positions3": _sds((3, B, S), jnp.int32, mesh,
                                       P(None, bspec[0] if bspec else None)),
                    "targets": tok(S), "mask": tok(S)}
        return {"tokens": tok(S), "targets": tok(S), "mask": tok(S)}
    if cell.kind == "prefill":
        if cfg.encdec:
            half = S // 2
            return {"frames": _sds((B, half, cfg.d_model), jnp.bfloat16,
                                   mesh, bspec),
                    "tokens": tok(half)}
        if cfg.input_kind == "embeds3":
            return {"embeds": _sds((B, S, cfg.d_model), jnp.bfloat16, mesh,
                                   bspec),
                    "positions3": _sds((3, B, S), jnp.int32, mesh,
                                       P(None, bspec[0] if bspec else None))}
        return {"tokens": tok(S)}
    # decode: one new token against a cache of seq_len
    return {"tokens": tok(1)}


def _count_params(params, cfg):
    """(total, active, non_embed_active) parameter counts."""
    tot = act = 0
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    for path, leaf in flat:
        ps = jax.tree_util.keystr(path)
        n = int(np.prod(leaf.shape))
        tot += n
        if "embed" in ps:
            continue
        if "moe" in ps and any(k in ps for k in ("'wi'", "'wg'", "'wo'")):
            act += n * cfg.moe_top_k / max(cfg.moe_experts, 1)
        else:
            act += n
    return tot, act


def _shard_bytes(tree_of_sds):
    """Per-device bytes of a sharded SDS tree (leaf bytes / shard count)."""
    total = 0.0
    for leaf in jax.tree.leaves(tree_of_sds):
        n = int(np.prod(leaf.shape)) * leaf.dtype.itemsize
        sh = leaf.sharding
        nshards = sh.num_devices // len(sh.device_set) if False else None
        # number of distinct shards = product of mesh axes used in the spec
        used = [a for axes in sh.spec if axes is not None
                for a in ((axes,) if isinstance(axes, str) else axes)]
        k = int(np.prod([sh.mesh.shape[a] for a in used])) if used else 1
        total += n / k
    return total


# ---------------------------------------------------------------------------
# cell lowering
# ---------------------------------------------------------------------------


def lower_lm_cell(cfg, cell: ShapeCell, mesh):
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params_sds = jax.eval_shape(model.init, key)
    pshard = params_shardings(params_sds, mesh, cfg.fsdp)
    params_sds = jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        params_sds, pshard)
    batch = input_specs(cfg, cell, mesh)
    extras = {"param_bytes_per_dev": _shard_bytes(params_sds)}

    if cell.kind == "train":
        opt = AdamW(int8_state=cfg.opt_8bit)
        opt_sds = jax.eval_shape(opt.init, params_sds)
        state_sds = TrainState(params=params_sds, opt=opt_sds)
        sshard = train_state_shardings(state_sds, mesh, cfg.fsdp,
                                       cfg.opt_8bit)
        state_sds = jax.tree.map(
            lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
            state_sds, sshard)
        extras["state_bytes_per_dev"] = _shard_bytes(state_sds)
        # microbatch so per-device live activations stay bounded:
        # ~4k tokens per device per microbatch (B splits must divide);
        # chosen so the layer-scan residuals (n_layers x ubatch x d_model
        # bf16) of the deepest arch fit HBM — see EXPERIMENTS.md §Perf H4
        dshards = int(np.prod([mesh.shape[a] for a in batch_axes(mesh)]))
        tokens_per_dev = cell.global_batch * cell.seq_len // dshards
        ga = max(1, tokens_per_dev // 4096)
        while cell.global_batch % (ga * dshards) != 0 and ga > 1:
            ga //= 2
        extras["grad_accum"] = ga
        if ga > 1:
            # pre-split microbatches: (ga, B/ga, ...) with batch dim 1
            def presplit(l):
                spec = l.sharding.spec
                shape = (ga, l.shape[0] // ga) + l.shape[1:]
                if "positions3" in str(spec):
                    pass
                return jax.ShapeDtypeStruct(
                    shape, l.dtype,
                    sharding=NamedSharding(mesh, P(None, *spec)))
            batch = {k: (presplit(v) if k != "positions3" else
                         jax.ShapeDtypeStruct(
                             (ga, 3, v.shape[1] // ga) + v.shape[2:], v.dtype,
                             sharding=NamedSharding(mesh, P(None, *v.sharding.spec))))
                     for k, v in batch.items()}
        step = make_train_step(model, opt, grad_accum=ga)
        lowered = jax.jit(step, donate_argnums=0).lower(state_sds, batch)
        return lowered, extras

    # serving cells
    B = cell.global_batch
    s_cache = cache_len_for(cfg, cell.seq_len)
    cache_sds = jax.eval_shape(
        lambda: model.init_cache(B, s_cache, dtype=jnp.bfloat16))
    cshard = cache_shardings(cache_sds, mesh)
    cache_sds = jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        cache_sds, cshard)
    extras["cache_bytes_per_dev"] = _shard_bytes(cache_sds)

    if cell.kind == "prefill":
        prefill = make_prefill_step(model, cfg)
        lowered = jax.jit(prefill, donate_argnums=2).lower(
            params_sds, batch, cache_sds)
        return lowered, extras

    # decode: enc-dec needs the encoder output as a standing input
    decode = make_decode_step(model, cfg)
    bax = batch_axes(mesh)
    bspec = P(bax if B % int(np.prod([mesh.shape[a] for a in bax])) == 0
              else None)
    kwargs = {}
    if cfg.encdec:
        enc = _sds((B, cell.seq_len // 2, cfg.d_model), jnp.bfloat16, mesh,
                   bspec)
        lowered = jax.jit(decode, donate_argnums=2).lower(
            params_sds, batch["tokens"], cache_sds, enc)
    elif cfg.input_kind == "embeds3":
        p3 = _sds((3, B, 1), jnp.int32, mesh,
                  P(None, bspec[0] if bspec else None))
        lowered = jax.jit(decode, donate_argnums=2).lower(
            params_sds, batch["tokens"], cache_sds, None, p3)
    else:
        lowered = jax.jit(decode, donate_argnums=2).lower(
            params_sds, batch["tokens"], cache_sds)
    return lowered, extras


def lower_ising_cell(mesh, multi_pod: bool, L: int = 100,
                     iters: int = 2, S: int = 4):
    """The paper's 1M-p-bit production workload on the production mesh."""
    from repro.core.lattice import build_ea3d_lattice
    from repro.core.lattice_dsim import LatticeDSIM
    if multi_pod:
        dim_axes = ("data", "model", "pod")      # z (periodic) -> pod (2 | 100)
    else:
        dim_axes = ("data", "model", None)
    pad = (112, 112)                              # x,y padded to 16*7
    prob = build_ea3d_lattice(L, seed=0, pad_xy=pad)
    eng = LatticeDSIM(prob, mesh, dim_axes=dim_axes, impl="ref")
    lowered = eng.lower_chunk(iters=iters, S=S)
    extras = {"p_bits": L ** 3, "padded_sites": int(np.prod(prob.dims)),
              "n_colors": prob.n_colors, "sync_every": S}
    return lowered, extras


def model_flops_estimate(cfg, cell: ShapeCell) -> Optional[float]:
    if cfg.family == "ising":
        return None
    model = build_model(cfg)
    params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    tot, act = _count_params(params_sds, cfg)
    if cell.kind == "train":
        tokens = cell.global_batch * (cell.seq_len // 2 if cfg.encdec
                                      else cell.seq_len)
        return 6.0 * act * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * (cell.seq_len // 2 if cfg.encdec
                                      else cell.seq_len)
        return 2.0 * act * tokens
    return 2.0 * act * cell.global_batch     # decode: one token per seq


# ---------------------------------------------------------------------------
# runner
# ---------------------------------------------------------------------------


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             report_dir: str = REPORT_DIR) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    cfg = get_config(arch)
    if cfg.family == "ising":
        cell = ShapeCell("sample_chunk", 0, 0, "sample")
        lowered, extras = lower_ising_cell(mesh, multi_pod)
        mf = None
    else:
        cell = SHAPES[shape_name]
        # ambient mesh scope so in-model shard_hint() constraints resolve
        from repro.compat import set_mesh
        with set_mesh(mesh):
            lowered, extras = lower_lm_cell(cfg, cell, mesh)
        mf = model_flops_estimate(cfg, cell)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    memd = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes"):
        memd[f] = getattr(mem, f, None)
    # global model flops -> per-chip for the roofline terms
    rep = roofline(compiled, chips,
                   model_flops=(mf / chips if mf else None))
    rec = {
        "arch": arch, "shape": cell.name,
        "mesh": "multi_pod_2x16x16" if multi_pod else "single_pod_16x16",
        "chips": chips, "ok": True,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": memd, "extras": extras,
        "roofline": rep.as_dict(), "model_flops_global": mf,
    }
    os.makedirs(report_dir, exist_ok=True)
    fn = f"{arch}__{cell.name}__{rec['mesh']}.json"
    with open(os.path.join(report_dir, fn), "w") as f:
        json.dump(rec, f, indent=1, default=str)
    return rec


def all_cells():
    for arch, cfg in list_configs().items():
        if cfg.family == "ising":
            yield arch, "sample_chunk"
            continue
        for cell in cfg.shapes():
            yield arch, cell.name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--subproc", action="store_true",
                    help="one fresh process per cell (bounds compile-cache "
                         "memory across the 68-cell matrix)")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--report-dir", default=REPORT_DIR)
    args = ap.parse_args()

    cells = list(all_cells()) if args.all else [(args.arch, args.shape)]
    meshes = [False, True] if (args.all or args.both_meshes) \
        else [args.multi_pod]
    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            mesh_tag = "multi_pod_2x16x16" if mp else "single_pod_16x16"
            tag = f"{arch:22s} {shape:14s} {'2x16x16' if mp else '16x16  '}"
            if args.skip_existing and os.path.exists(os.path.join(
                    args.report_dir, f"{arch}__{shape}__{mesh_tag}.json")):
                print(f"SKIP {tag}")
                continue
            if args.subproc:
                import subprocess, sys
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape,
                       "--report-dir", args.report_dir]
                if mp:
                    cmd.append("--multi-pod")
                r = subprocess.run(cmd, capture_output=True, text=True)
                out = (r.stdout or "").strip().splitlines()
                print(out[-1] if out else f"FAIL {tag} (no output)")
                if r.returncode != 0:
                    failures += 1
                    print((r.stderr or "")[-2000:])
                continue
            try:
                rec = run_cell(arch, shape, mp, args.report_dir)
                r = rec["roofline"]
                print(f"OK   {tag} compile={rec['compile_s']:7.1f}s "
                      f"flops={r['flops']:.3e} wire={r['wire_bytes']:.3e} "
                      f"bottleneck={r['bottleneck']}")
            except Exception as e:
                failures += 1
                print(f"FAIL {tag} {type(e).__name__}: {e}")
                traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
