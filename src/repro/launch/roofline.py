"""Roofline-term derivation from a compiled dry-run artifact.

  compute term    = HLO_FLOPs / (chips * 197e12 FLOP/s bf16)
  memory term     = HLO_bytes / (chips * 819e9 B/s HBM)
  collective term = wire_bytes_per_chip / 50e9 B/s per ICI link

FLOPs/bytes come from ``compiled.cost_analysis()`` (whole-program, i.e.
already the global totals).  Collective bytes are NOT in cost_analysis:
we parse ``compiled.as_text()``, summing the shapes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute, multiplied
by the trip count of every enclosing while loop (jax `scan`s compile to
whiles; the trip count is recovered from the loop-condition region's
comparison constant).  Ring-transfer accounting per chip:

  all-gather      result_bytes * (K-1)/K        (receives everyone's shard)
  reduce-scatter  operand_bytes * (K-1)/K
  all-reduce      2 * result_bytes * (K-1)/K    (RS + AG)
  all-to-all      result_bytes * (K-1)/K
  collective-permute  result_bytes
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["HW", "collective_bytes", "roofline", "RooflineReport",
           "parse_collectives"]

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12     # bf16 / chip
    hbm_bw: float = 819e9          # B/s / chip
    ici_bw: float = 50e9           # B/s / link
    hbm_bytes: float = 16e9        # v5e capacity


_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(text: str) -> int:
    """Sum bytes of every dtype[shape] group in an HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo.splitlines():
        m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)[\s(].*\{\s*$", line)
        if m and not line.startswith(" "):
            cur = m.group(1)
            comps[cur] = []
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)
    return comps


def _entry_name(hlo: str) -> Optional[str]:
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo, re.M)
    return m.group(1) if m else None


def _trip_count(cond_lines: List[str]) -> int:
    """Heuristic: max s32/u32 constant in the while-condition region."""
    best = 1
    for line in cond_lines:
        for m in re.finditer(r"[su]32\[\]\s+constant\((\d+)\)", line):
            best = max(best, int(m.group(1)))
    return best


def parse_collectives(hlo: str) -> List[dict]:
    """Per-collective records with while-loop multiplicity applied."""
    comps = _split_computations(hlo)
    entry = _entry_name(hlo)

    # while ops: body/condition computation references
    whiles: Dict[str, List[Tuple[str, str]]] = {k: [] for k in comps}
    for name, lines in comps.items():
        for line in lines:
            m = re.search(r"while\(.*?\)"
                          r".*condition=%?([\w\.\-]+).*body=%?([\w\.\-]+)",
                          line)
            if m:
                whiles[name].append((m.group(1), m.group(2)))

    # multiplicity via DFS from entry
    mult: Dict[str, int] = {}

    def visit(name: str, m: int):
        mult[name] = mult.get(name, 0) + m
        for cond, body in whiles.get(name, ()):
            trips = _trip_count(comps.get(cond, []))
            visit(body, m * trips)

    if entry:
        visit(entry, 1)

    out = []
    for name, lines in comps.items():
        m = mult.get(name, 0)
        if m == 0:
            continue
        for line in lines:
            for kind in _COLL_KINDS:
                if re.search(rf"\s{kind}(?:-start)?\(", line):
                    # result type = everything between '=' and the op name
                    rhs = line.split("=", 1)
                    if len(rhs) != 2:
                        continue
                    lhs_type = rhs[1].split(f"{kind}")[0]
                    size = _shape_bytes(lhs_type)
                    k = _group_size(line)
                    out.append({"kind": kind, "bytes": size, "group": k,
                                "mult": m, "comp": name})
                    break
    return out


def _group_size(line: str) -> int:
    # explicit format: replica_groups={{0,1,2,3},{...}}
    g = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if g:
        return len(g.group(1).split(","))
    # iota format: replica_groups=[G,S]<=[...]
    g = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if g:
        return int(g.group(2))
    # collective-permute has source_target_pairs instead
    if "source_target_pairs" in line:
        return 2
    return 1


# ---------------------------------------------------------------------------
# text-based per-program cost model
#
# XLA's HloCostAnalysis (compiled.cost_analysis()) visits every computation
# ONCE — it does not multiply while-loop bodies by their trip count, so a
# scanned L-layer model reports ~1/L of its true FLOPs.  We therefore walk
# the HLO text ourselves: symbol table per computation (name -> shape),
# dot/convolution FLOPs, naive operand+result HBM bytes per op (the same
# convention HloCostAnalysis uses), multiplied by loop multiplicity.
# ---------------------------------------------------------------------------

_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_OPND_RE = re.compile(r"%([\w\.\-]+)")
_SKIP_OPS = ("parameter(", "constant(", "get-tuple-element(", "tuple(",
             "bitcast(", "after-all(", "custom-call(")
# ops whose operand/result traffic actually hits HBM on TPU; standalone
# elementwise ops in the CPU-lowered HLO would be fused into neighbors by
# XLA:TPU, so counting them would systematically inflate the memory term
_MEM_OPS = frozenset({
    "dot", "convolution", "fusion", "copy", "transpose",
    "gather", "scatter", "dynamic-update-slice", "dynamic-slice", "slice",
    "reduce", "reduce-window", "sort", "select-and-scatter", "concatenate",
    "pad", "all-gather", "all-reduce", "reduce-scatter",
    "all-to-all", "collective-permute", "rng", "rng-bit-generator",
})


def _op_name(rest: str) -> str:
    # rest looks like: "f32[2,3]{1,0} add(%a, %b), meta..."
    m = re.search(r"\}?\s([a-z][\w\-]*)\(", rest)
    return m.group(1) if m else ""


def _parse_dims(rest: str):
    m = _SHAPE_RE.search(rest)
    if not m:
        return None, None
    dt, dims = m.group(1), m.group(2)
    shape = [int(d) for d in dims.split(",") if d]
    return dt, shape


def _region_cost(lines: List[str]):
    """(flops, bytes) of one computation body (single visit)."""
    sym: Dict[str, int] = {}        # name -> result bytes
    shp: Dict[str, list] = {}       # name -> result dims (first array only)
    flops = 0.0
    byts = 0.0
    for line in lines:
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        sym[name] = _shape_bytes(rest.split("(")[0] if "(" in rest else rest)
        dt, dims = _parse_dims(rest)
        shp[name] = dims or []
        if any(rest.lstrip().startswith(s) or f" {s}" in rest
               for s in _SKIP_OPS):
            continue
        op = _op_name(rest)
        if not op:
            continue
        body = rest.split("(", 1)[1] if "(" in rest else ""
        body = body.split("), ")[0]
        operands = _OPND_RE.findall(body)
        if op in _MEM_OPS:
            # traffic: result + operands (HloCostAnalysis convention),
            # restricted to ops that hit HBM on TPU (see _MEM_OPS)
            byts += sym[name] + sum(sym.get(o, 0) for o in operands)
        if op == "dot":
            res = shp.get(name) or []
            cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rest)
            lhs = shp.get(operands[0]) if operands else None
            csize = 1
            if cdims and lhs:
                for d in cdims.group(1).split(","):
                    if d:
                        csize *= lhs[int(d)]
            n = 1
            for d in res:
                n *= d
            flops += 2.0 * n * csize
        elif op == "convolution":
            # approx: 2 * out_elems * (in_ch/feature_group * prod(kernel))
            res = shp.get(name) or []
            n = 1
            for d in res:
                n *= d
            ker = shp.get(operands[1]) if len(operands) > 1 else None
            k = 1
            if ker:
                for d in ker[:-1]:
                    k *= d
            flops += 2.0 * n * k
    return flops, byts


def program_cost(hlo: str) -> Tuple[float, float]:
    """(flops, hbm_bytes) with while-loop multiplicity applied.

    FLOPs follow while bodies, fusions/calls, and conditional branches;
    bytes follow only while bodies and conditionals (a fusion's interior
    traffic stays in VMEM — the parent's fusion-op line already counts its
    boundary bytes)."""
    comps = _split_computations(hlo)
    entry = _entry_name(hlo)
    whiles: Dict[str, List[Tuple[str, str]]] = {k: [] for k in comps}
    calls: Dict[str, List[str]] = {k: [] for k in comps}
    branches: Dict[str, List[str]] = {k: [] for k in comps}
    for name, lines in comps.items():
        for line in lines:
            m = re.search(r"while\(.*?\).*condition=%?([\w\.\-]+)"
                          r".*body=%?([\w\.\-]+)", line)
            if m:
                whiles[name].append((m.group(1), m.group(2)))
                continue
            for cm in re.finditer(r"(?:calls|to_apply)=%?([\w\.\-]+)", line):
                calls[name].append(cm.group(1))
            bm = re.search(r"branch_computations=\{([^}]*)\}", line)
            if bm:
                branches[name].extend(
                    x.strip().lstrip("%") for x in bm.group(1).split(","))
            for key in ("true_computation", "false_computation"):
                km = re.search(rf"{key}=%?([\w\.\-]+)", line)
                if km:
                    branches[name].append(km.group(1))

    mult_f: Dict[str, int] = {}
    mult_b: Dict[str, int] = {}

    def visit(name: str, m: int, for_flops: bool):
        tab = mult_f if for_flops else mult_b
        tab[name] = tab.get(name, 0) + m
        for cond, body in whiles.get(name, ()):
            visit(body, m * _trip_count(comps.get(cond, [])), for_flops)
        for callee in branches.get(name, ()):
            visit(callee, m, for_flops)
        if for_flops:
            for callee in calls.get(name, ()):
                visit(callee, m, for_flops)

    if entry:
        visit(entry, 1, True)
        visit(entry, 1, False)
    flops = byts = 0.0
    for name, lines in comps.items():
        f, b = _region_cost(lines)
        flops += mult_f.get(name, 0) * f
        byts += mult_b.get(name, 0) * b
    return flops, byts


def collective_bytes(hlo: str) -> Tuple[float, dict]:
    """Wire bytes per chip (ring accounting) + per-kind breakdown."""
    per_kind: Dict[str, float] = {}
    total = 0.0
    for rec in parse_collectives(hlo):
        k = max(rec["group"], 1)
        ring = (k - 1) / k if k > 1 else 0.0
        if rec["kind"] == "all-reduce":
            b = 2.0 * rec["bytes"] * ring
        elif rec["kind"] == "collective-permute":
            b = float(rec["bytes"])
        else:
            b = rec["bytes"] * ring
        b *= rec["mult"]
        per_kind[rec["kind"]] = per_kind.get(rec["kind"], 0.0) + b
        total += b
    return total, per_kind


@dataclasses.dataclass
class RooflineReport:
    flops: float
    bytes_accessed: float
    wire_bytes: float
    per_kind: dict
    chips: int
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: Optional[float] = None

    @property
    def useful_ratio(self) -> Optional[float]:
        if self.model_flops is None or self.flops == 0:
            return None
        return self.model_flops / self.flops

    def as_dict(self) -> dict:
        return {**dataclasses.asdict(self),
                "useful_ratio": self.useful_ratio}


def roofline(compiled, chips: int, hw: HW = HW(),
             model_flops: Optional[float] = None,
             hlo_text: Optional[str] = None) -> RooflineReport:
    hlo = hlo_text if hlo_text is not None else compiled.as_text()
    # per-device program costs with loop multiplicity (XLA's own
    # cost_analysis() visits each computation once and so undercounts
    # scanned models by ~n_layers; see program_cost docstring)
    flops, byts = program_cost(hlo)
    wire, per_kind = collective_bytes(hlo)
    t_c = flops / hw.peak_flops
    t_m = byts / hw.hbm_bw
    t_x = wire / hw.ici_bw
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    return RooflineReport(
        flops=flops, bytes_accessed=byts, wire_bytes=wire, per_kind=per_kind,
        chips=chips, t_compute=t_c, t_memory=t_m, t_collective=t_x,
        bottleneck=max(terms, key=terms.get), model_flops=model_flops)
