"""End-to-end training driver.

Runs any registered arch (full or --reduced) with the real substrate:
sharded params/optimizer, microbatching, checkpoint/restart (resumes from
the newest checkpoint automatically — the node-failure recovery path),
periodic-sync local SGD (--sync-every, the paper's eta rule as a training
feature), and the synthetic-but-learnable Markov data pipeline.

  PYTHONPATH=src python -m repro.launch.train --arch mamba2-370m --reduced \
      --steps 100 --batch 8 --seq 128 --ckpt /tmp/ck
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import time

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.models.lm import build_model
from repro.train.optimizer import AdamW
from repro.train.train_step import (TrainState, make_train_step,
                                    make_local_sgd_step, sync_budget)
from repro.train.data import MarkovLM, prefetch
from repro.train import checkpoint as ckpt
from repro.sharding.rules import (params_shardings, train_state_shardings,
                                  batch_shardings)


def make_mesh_from_arg(spec: str):
    """--mesh 'data=4,model=2' (or 'single'/'multi' for production)."""
    if spec in ("single", "multi"):
        from repro.launch.mesh import make_production_mesh
        return make_production_mesh(multi_pod=(spec == "multi"))
    axes, sizes = [], []
    for part in spec.split(","):
        if not part:
            continue
        k, v = part.split("=")
        axes.append(k)
        sizes.append(int(v))
    n = int(np.prod(sizes))
    devs = jax.devices()[:n]
    from repro.compat import make_mesh, auto_axes
    return make_mesh(tuple(sizes), tuple(axes),
                     axis_types=auto_axes(len(axes)), devices=devs)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--sync-every", type=int, default=0,
                    help=">0: eta-style local SGD with this sync period")
    ap.add_argument("--mesh", default="data=1,model=1")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.encdec:
        raise SystemExit("use the seq2seq example for enc-dec archs")
    model = build_model(cfg)
    mesh = make_mesh_from_arg(args.mesh)
    print(f"arch={cfg.name} reduced={args.reduced} mesh={dict(mesh.shape)}")

    opt = AdamW(lr=args.lr, warmup=min(100, args.steps // 10 + 1),
                int8_state=cfg.opt_8bit)
    params = model.init(jax.random.PRNGKey(args.seed))
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"params: {n_params/1e6:.2f}M")
    state = TrainState(params=params, opt=opt.init(params))

    # shard the state onto the mesh
    sshard = train_state_shardings(state, mesh, cfg.fsdp, cfg.opt_8bit)
    state = jax.tree.map(jax.device_put, state, sshard)

    start = 0
    if args.ckpt and ckpt.latest_step(args.ckpt) is not None:
        start = ckpt.latest_step(args.ckpt)
        state = ckpt.restore(args.ckpt, state, shardings=sshard)
        print(f"restored checkpoint at step {start} (elastic reshard ok)")

    data = MarkovLM(cfg.vocab, seed=args.seed + 1)

    if args.sync_every > 0:
        outer, replicate = make_local_sgd_step(model, opt, mesh, "data",
                                               sync_every=args.sync_every)
        state = replicate(jax.tree.map(np.asarray, state))
        R = mesh.shape["data"]
        print(f"local SGD: R={R} replicas, sync every {args.sync_every}")

        def batches():
            while True:
                t = data.sample(R * args.sync_every * args.batch, args.seq)
                t = t.reshape(R, args.sync_every, args.batch, args.seq)
                yield {"tokens": t, "targets": t, "mask": np.ones_like(t)}

        step_fn = lambda st, b: outer(st, jax.tree.map(jnp.asarray, b))
    else:
        step = jax.jit(make_train_step(model, opt, grad_accum=args.grad_accum),
                       donate_argnums=0)

        def batches():
            while True:
                t = data.sample(args.batch * max(args.grad_accum, 1), args.seq)
                if args.grad_accum > 1:
                    t = t.reshape(args.grad_accum, args.batch, args.seq)
                yield {"tokens": t, "targets": t, "mask": np.ones_like(t)}

        bshard = None

        def step_fn(st, b):
            from repro.compat import set_mesh
            bb = jax.tree.map(jnp.asarray, b)
            with set_mesh(mesh):
                return step(st, bb)

    t0 = time.time()
    losses = []
    it = prefetch(batches(), depth=2)
    for i in range(start + 1, args.steps + 1):
        state, metrics = step_fn(state, next(it))
        losses.append(float(metrics["loss"]))
        if i % args.log_every == 0 or i == args.steps:
            dt = time.time() - t0
            tok_s = args.log_every * args.batch * args.seq * \
                max(args.grad_accum, 1) / max(dt, 1e-9)
            print(f"step {i:6d} loss {losses[-1]:.4f} "
                  f"({tok_s:,.0f} tok/s)")
            t0 = time.time()
        if args.ckpt and (i % args.ckpt_every == 0 or i == args.steps):
            ckpt.save(args.ckpt, i, state, meta={"arch": cfg.name},
                      blocking=False)
    ckpt.wait_pending()
    print(f"final loss {np.mean(losses[-10:]):.4f} "
          f"(start {np.mean(losses[:10]):.4f})")


if __name__ == "__main__":
    main()
