"""Deterministic fault injection and the serving failure taxonomy.

The paper's machine is a network of 28 FPGAs: any node can stall, drop a
boundary exchange, or hand back garbage, and the million-p-bit sampler
must keep producing valid Gibbs statistics around it.  The serving stack
therefore carries real recovery machinery (retry/backoff, poison-batch
bisection, checkpoint resume, deadlines, a circuit breaker) — and none of
it is trustworthy unless it can be *driven* deterministically.  This
module is that driver:

- **Failure taxonomy** — :class:`TransientFault` / :class:`PermanentFault`
  (injected), :class:`StateCorruption` (re-exported from
  ``core.degrade``: the server's integrity guard or a mesh engine's
  boundary-integrity layer tripped), and :func:`classify_error`, the one
  place that decides transient-vs-permanent for retry policy.
- **:class:`FaultPlan`** — a seeded, replayable list of
  :class:`FaultRule`\\ s that raise, hang, or corrupt at chosen sites:
  ``"build"`` (engine-pool compiles), ``"chunk"`` (between-chunk pump
  steps, matchable by chunk index and job id), and ``"exchange"`` (the
  cursor's per-chunk boundary hook inside ``RecordedCursor.advance``).
  Wired through ``SampleServer(fault_plan=...)``; every recovery path in
  tests is exercised by a plan, never by sleeps-and-hope chaos.
- **Engine-boundary sites** — ``"exchange_corrupt"`` / ``"exchange_drop"``
  rules damage the *wire itself*, inside the jitted chunk, not the pump:
  :meth:`FaultPlan.exchange_codes` compiles them into a per-exchange code
  array the mesh engines consume via ``set_exchange_faults`` — the
  degraded-mode integrity layer (``core.degrade``) must detect every one.
- **:func:`compute_backoff`** — pure, seeded exponential backoff with
  jitter, so retry pacing is unit-testable arithmetic.

Determinism contract: rules fire on exact matches (site / index / job /
key); probabilistic rules (``rate < 1``) draw from the plan's own seeded
generator in call order, so two identical runs of the same plan make
identical decisions, and :meth:`FaultPlan.replay` hands back a fresh
plan with the same seed and un-spent rule budgets.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["InjectedFault", "TransientFault", "PermanentFault",
           "StateCorruption", "DeadlineExceeded", "FaultRule", "FaultPlan",
           "classify_error", "compute_backoff", "corrupt_pytree"]


class InjectedFault(RuntimeError):
    """Base class for faults raised by a :class:`FaultPlan`."""


class TransientFault(InjectedFault):
    """Injected fault the retry policy should treat as retryable."""


class PermanentFault(InjectedFault):
    """Injected fault that must fail the job (no retry)."""


# StateCorruption moved to core.degrade (the mesh integrity layer raises it
# inside the engines); re-exported here so serve-layer callers and the
# transient classification below keep one exception identity.
from repro.core.degrade import StateCorruption  # noqa: E402


class DeadlineExceeded(RuntimeError):
    """A job blew its ``deadline_s`` budget (enforced between chunks)."""


# -- transient / permanent classification -------------------------------------

# Exceptions whose cause plausibly goes away on retry: injected transients,
# corrupted state (a checkpoint restore repairs it), infra-ish errors, and
# the pool's fast-fail while a build circuit is cooling down.
_TRANSIENT = (TransientFault, StateCorruption, TimeoutError,
              ConnectionError, InterruptedError)
# Exceptions that are deterministic properties of the request or the code:
# retrying re-raises them identically.
_PERMANENT = (PermanentFault, ValueError, TypeError, KeyError,
              NotImplementedError, AssertionError, AttributeError)


def _is_xla_runtime_error(err: BaseException) -> bool:
    """Duck-typed check for jaxlib's XlaRuntimeError (its import path has
    moved across jaxlib versions; the class *name* is the stable part)."""
    return any(c.__name__ == "XlaRuntimeError" for c in type(err).__mro__)


def classify_error(err: BaseException) -> str:
    """``"transient"`` or ``"permanent"`` — the retry-policy split.

    JAX runtime errors split on their embedded status code:
    ``INVALID_ARGUMENT`` is a deterministic property of the request
    (permanent); ``RESOURCE_EXHAUSTED`` (device OOM under co-tenancy) and
    every other runtime status are worth a bounded retry (transient).

    Unknown exception types classify transient: on a serving tier a
    bounded retry of an unrecognized failure is cheaper than wrongly
    failing a tenant, and ``max_retries`` bounds the waste.  (The pool's
    ``CircuitOpen`` classifies transient via its ``TimeoutError`` base.)
    """
    if _is_xla_runtime_error(err):
        msg = str(err)
        if "INVALID_ARGUMENT" in msg:
            return "permanent"
        return "transient"     # RESOURCE_EXHAUSTED, INTERNAL, ... — retry
    if isinstance(err, _PERMANENT):
        return "permanent"
    if isinstance(err, _TRANSIENT):
        return "transient"
    return "transient"


def compute_backoff(retries: int, *, base: float = 0.05, cap: float = 5.0,
                    jitter: float = 0.5, seed: int = 0) -> float:
    """Deterministic exponential backoff with seeded jitter.

    Retry k (0-based) waits ``min(cap, base * 2**k) * (1 + jitter * u)``
    with ``u = U[0, 1)`` drawn from a generator seeded by (seed, k) — the
    same (job, attempt) always gets the same delay, but distinct jobs
    decorrelate (no thundering-herd resubmission).  ``base = 0`` disables
    waiting entirely (immediate retry), which tests use for determinism.
    """
    if base <= 0.0:
        return 0.0
    delay = min(float(cap), float(base) * (2.0 ** max(int(retries), 0)))
    if jitter > 0.0:
        u = np.random.default_rng((int(seed) & 0x7FFFFFFF,
                                   max(int(retries), 0))).random()
        delay *= 1.0 + float(jitter) * u
    return delay


def corrupt_pytree(state):
    """Deterministically corrupt every array leaf of a state pytree.

    Float leaves become NaN (the server's integrity guard catches those as
    non-finite energies); integer/bool leaves are bit-scrambled.  Used by
    ``action="corrupt"`` rules to emulate a node handing back garbage."""
    import jax
    import jax.numpy as jnp

    def _corrupt(x):
        if not isinstance(x, (jax.Array, np.ndarray, np.generic)):
            return x
        a = jnp.asarray(x)
        if jnp.issubdtype(a.dtype, jnp.floating):
            return jnp.full_like(a, jnp.nan)
        if a.dtype == jnp.bool_:
            return ~a
        return a ^ jnp.asarray(0x55555555 & np.iinfo(
            np.dtype(a.dtype.name)).max, a.dtype)

    return jax.tree.map(_corrupt, state)


@dataclasses.dataclass(frozen=True)
class FaultRule:
    """One injection rule; all given coordinates must match for it to fire.

    site:   "build" | "chunk" | "exchange" — host-side injection — or the
            engine-boundary sites "exchange_corrupt" | "exchange_drop",
            which damage the wire *inside* the jitted chunk (compiled into
            a code array by :meth:`FaultPlan.exchange_codes`; ``index``
            selects an exact exchange seq, ``rate`` a Bernoulli fraction).
    action: "raise" (default) | "hang" (sleep ``hang_s`` inside the timed
            chunk window — the watchdog's prey) | "corrupt" (scramble the
            cursor state via :func:`corrupt_pytree`).
    kind:   "transient" | "permanent" — which exception a raise throws.
    index:  fire only at this exact chunk/attempt index (None = any).
    after:  fire only at index >= after (None = any).
    job:    fire only when this job id (or seed) is in the batch.
    key:    fire only when ``repr(pool key)`` contains this substring.
    rate:   firing probability when matched (seeded; 1.0 = always).
    times:  total firing budget (None = unlimited; ignored by the
            engine-boundary sites, whose whole schedule is precompiled).
    """

    site: str
    action: str = "raise"
    kind: str = "transient"
    index: Optional[int] = None
    after: Optional[int] = None
    job: Any = None
    key: Any = None
    rate: float = 1.0
    times: Optional[int] = 1
    hang_s: float = 0.05

    ENGINE_SITES = ("exchange_corrupt", "exchange_drop")

    def __post_init__(self):
        if self.site not in ("build", "chunk", "exchange") + \
                self.ENGINE_SITES:
            raise ValueError(f"unknown fault site {self.site!r}")
        if self.action not in ("raise", "hang", "corrupt"):
            raise ValueError(f"unknown fault action {self.action!r}")
        if self.kind not in ("transient", "permanent"):
            raise ValueError(f"unknown fault kind {self.kind!r}")


class FaultPlan:
    """A seeded, replayable sequence of fault injections.

    ``fire`` finds the first matching rule with budget left (consuming one
    firing and, for ``rate < 1`` rules, one draw from the seeded
    generator); ``apply`` additionally *performs* the action.  The plan
    records every firing in :attr:`events` for test assertions, and is
    thread-safe (prewarm threads and the pump share it).
    """

    def __init__(self, rules: Sequence[FaultRule], seed: int = 0):
        self.rules: List[FaultRule] = list(rules)
        self.seed = int(seed)
        self._rng = np.random.default_rng(self.seed)
        self._remaining = [r.times for r in self.rules]
        self.events: List[Tuple] = []
        self._lock = threading.Lock()

    def replay(self) -> "FaultPlan":
        """A fresh plan with the same rules, seed, and full budgets —
        re-running an identical workload makes identical decisions."""
        return FaultPlan(self.rules, seed=self.seed)

    def fire(self, site: str, *, index: Optional[int] = None,
             jobs: Sequence[Any] = (), key: Any = None
             ) -> Optional[FaultRule]:
        """The first matching rule (its budget consumed), or None."""
        with self._lock:
            jobs = tuple(jobs)
            for ri, r in enumerate(self.rules):
                if r.site != site:
                    continue
                if r.index is not None and index != r.index:
                    continue
                if r.after is not None and (index is None
                                            or index < r.after):
                    continue
                if r.job is not None and r.job not in jobs:
                    continue
                if r.key is not None and (key is None
                                          or str(r.key) not in repr(key)):
                    continue
                if self._remaining[ri] is not None \
                        and self._remaining[ri] <= 0:
                    continue
                if r.rate < 1.0 and self._rng.random() >= r.rate:
                    continue
                if self._remaining[ri] is not None:
                    self._remaining[ri] -= 1
                self.events.append((site, index, r.action, r.kind))
                return r
        return None

    def apply(self, site: str, cursor=None, *, index: Optional[int] = None,
              jobs: Sequence[Any] = (), key: Any = None
              ) -> Optional[FaultRule]:
        """Fire and perform: raise / hang / corrupt.  Returns the rule
        that fired (for "hang"/"corrupt") or None."""
        r = self.fire(site, index=index, jobs=jobs, key=key)
        if r is None:
            return None
        if r.action == "hang":
            time.sleep(r.hang_s)
            return r
        if r.action == "corrupt":
            if cursor is not None:
                cursor.state = corrupt_pytree(cursor.state)
            return r
        exc = TransientFault if r.kind == "transient" else PermanentFault
        raise exc(f"injected {r.kind} fault at {site}"
                  f"[{'any' if index is None else index}]")

    def exchange_codes(self, total: int) -> Optional[np.ndarray]:
        """Compile the engine-boundary rules into a per-exchange code array.

        Returns ``codes`` (total,) int32 with 0 = deliver, 1 = drop,
        2 = corrupt — indexed by the engine's traced exchange sequence
        number and consumed via ``engine.set_exchange_faults`` — or None
        when the plan has no ``exchange_corrupt``/``exchange_drop`` rules.

        Deterministic by construction: rate-based rules draw a Bernoulli
        mask from a generator seeded by (plan seed, site) — independent of
        host call order and identical on :meth:`replay` — and exact-index
        rules pin single exchanges.  ``times`` budgets don't apply: the
        whole schedule is compiled up front, not fired one event at a
        time.  Corrupt wins where rules overlap (damage beats absence).
        """
        total = int(total)
        codes = np.zeros(total, np.int32)
        hit = False
        for code, site in ((1, "exchange_drop"), (2, "exchange_corrupt")):
            for r in self.rules:
                if r.site != site:
                    continue
                hit = True
                if r.index is not None:
                    if 0 <= int(r.index) < total:
                        codes[int(r.index)] = code
                    continue
                lo = int(r.after) if r.after is not None else 0
                if r.rate >= 1.0:
                    codes[lo:] = code
                else:
                    rng = np.random.default_rng((self.seed & 0x7FFFFFFF,
                                                 code, lo))
                    mask = rng.random(total) < float(r.rate)
                    mask[:lo] = False
                    codes[mask] = code
        return codes if hit else None

    @property
    def fired(self) -> int:
        # under the plan's lock: a reader (server stats) must not see a
        # torn view while a pump thread is appending events
        with self._lock:
            return len(self.events)
