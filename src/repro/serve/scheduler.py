"""Replica-packing scheduler.

The machine's unit of parallelism is the replica axis R: every engine runs
R independent chains per batched call at marginal cost far below R separate
calls (one dispatch, one compiled runner, vectorized sweeps).  The
scheduler exploits that for multi-tenancy — compatible concurrent requests
(equal :func:`repro.serve.jobs.pack_key`: problem, engine, precision,
exchange period, beta staircase) coalesce into ONE batched call, each job
owning a contiguous replica slice, so eight R=2 requests for a hot problem
cost one R=16 anneal instead of eight dispatch+record loops.

Packed batch sizes are padded up to a power of two by default: the pad
replicas are throwaway chains (their results are sliced off), but the pool
then serves *any* pack composition summing into the same bucket from one
compiled handle — a 3+2 pack and a 4+1 pack both run the R=8 executable.

Priorities order batch formation (strict: a batch is led by the
highest-priority queued job, filled only with compatible jobs); FIFO
within a priority level.  `dsim_dist` runs one tenant per batched call
(its handle exposes no per-replica seed lists), so it is never packed
(batches of one).

Bit-plane jobs (``precision="bitplane"``) batch in *lane* units: the
engine packs replicas into the bit lanes of W = ceil(R/32) stacked uint32
word planes, so a batch totals up to ``MAX_LANE_WORDS * 32`` chains and
the executed width clamps up to a *word multiple* (instead of a power of
two — an R=33 pack runs the W=2 64-lane executable, an R=65 pack the W=3
96-lane one, not R=128's pow2).  Every pack composition landing in the
same word bucket reuses ONE compiled executable — the engine loops a
one-word kernel over the word axis — and pad lanes are throwaway chains
exactly like pow2 pad replicas.  The precision is already part of
:func:`repro.serve.jobs.pack_key`, so bit-plane jobs never coalesce with
int8/f32 jobs.  The word clamp also applies to ``dsim_dist`` bit-plane
jobs (one tenant per batch, but the executed width still pads to a full
word): the mesh engine's int8/bitplane lanes are *prefix-stable* — lane r
depends on spawn_seeds(seed)[r] alone — so pad lanes never perturb the
tenant's chains.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any, List, Optional, Sequence, Tuple

from repro.engines.base import MAX_LANE_WORDS, lanes_of

from .jobs import Job

__all__ = ["Batch", "ReplicaPackingScheduler", "PACKABLE_ENGINES",
           "ceil_pow2"]

# engines whose init_state takes per-replica seeds (see registry handles'
# ``supports_packing``); dsim_dist runs one tenant per call
PACKABLE_ENGINES = frozenset({"gibbs", "dsim", "lattice"})


def ceil_pow2(n: int) -> int:
    return 1 << (int(n) - 1).bit_length()


@dataclasses.dataclass
class Batch:
    """One batched engine call serving len(jobs) tenants.

    ``slices[i]`` is job i's [start, stop) replica range inside the packed
    state; ``r_exec`` (>= sum of job replicas) is the executed batch width
    after power-of-two padding.  The server attaches the live handle /
    cursor when the batch starts.
    """

    jobs: List[Job]
    key: tuple
    r_exec: int
    slices: List[Tuple[int, int]]
    seq: int                          # min job seq (FIFO tie-break)
    priority: int                     # max job priority

    # runtime (attached by the server)
    handle: Any = None
    cursor: Any = None
    pool_hit: Optional[bool] = None
    started_at: Optional[float] = None
    warm_s: float = 0.0
    device_s: float = 0.0
    points_seen: int = 0
    own_points: Any = None            # job id -> the points THAT job gets
    # fault tolerance (attached by the server)
    pool_key: Any = None              # engine-pool key (watchdog/breaker)
    chunks_done: int = 0              # chunk index for fault-site matching
    resume_ck: Any = None             # checkpoint record to restore at start
    ck: Any = None                    # latest checkpoint record (in-memory)
    ck_digest: Optional[str] = None   # its spool address (if spooled)
    ck_token: Any = None              # checkpoint lineage id
    last_ck_sweep: int = 0            # sweeps_done at the last checkpoint
    degrade_harvested: bool = False   # health report copied to tenants once

    @property
    def started(self) -> bool:
        return self.cursor is not None

    def relayout(self, pad_pow2: bool, cap: Optional[int] = None,
                 lanes: int = 1):
        """Compute slices / executed width / rank over the batch's jobs
        (called once at formation; batches never shrink — cancelled
        tenants keep their slice and are simply not harvested).  Padding
        never pushes the executed width past ``cap`` — near the cap the
        batch just runs unpadded.  ``lanes > 1`` (the bit-plane word
        width) clamps the executed width up to a lane multiple *instead
        of* a power of two — the word bucket W = r_exec/32 keys the
        compiled executable, so R=33 runs the W=2 (64-lane) binary and
        R=65 runs W=3 (96 lanes) rather than pow2's 128.  Under a
        sub-word cap the pow2 pad is the fallback."""
        self.slices, pos = [], 0
        for j in self.jobs:
            self.slices.append((pos, pos + j.spec.replicas))
            pos += j.spec.replicas
        self.r_exec = pos
        if lanes > 1:
            lane_r = ((pos + lanes - 1) // lanes) * lanes
            if cap is None or lane_r <= cap:
                self.r_exec = lane_r
            elif pad_pow2 and ceil_pow2(pos) <= cap:
                self.r_exec = ceil_pow2(pos)
        elif pad_pow2 and (cap is None or ceil_pow2(pos) <= cap):
            self.r_exec = ceil_pow2(pos)
        self.seq = min(j.seq for j in self.jobs)
        self.priority = max(j.spec.priority for j in self.jobs)


class ReplicaPackingScheduler:
    """Forms batches from the queued-job set; see the module docstring."""

    def __init__(self, max_replicas_per_call: int = 64, pack: bool = True,
                 pad_pow2: bool = True, metrics=None):
        if max_replicas_per_call < 1:
            raise ValueError("max_replicas_per_call must be >= 1")
        self.max_replicas_per_call = int(max_replicas_per_call)
        self.pack = bool(pack)
        self.pad_pow2 = bool(pad_pow2)
        # counters (monotone; read via stats()) — the server's pump and
        # stats threads hit these concurrently, so they get their own lock
        self._lock = threading.Lock()
        self.batches_formed = 0       # guarded_by: _lock
        self.jobs_batched = 0         # guarded_by: _lock
        self.jobs_packed = 0          # guarded_by: _lock
        self.padding_replicas = 0     # guarded_by: _lock
        # optional obs.MetricsRegistry: executed pack widths and the
        # padding waste (throwaway replicas) per formed batch
        self._h_width = self._m_padding = None
        if metrics is not None:
            self._h_width = metrics.histogram(
                "sched_pack_width_replicas", "executed batch width r_exec",
                buckets=(1, 2, 4, 8, 16, 32, 64, 128, 256))
            self._m_padding = metrics.counter(
                "sched_padding_replicas_total",
                "throwaway pad replicas executed (r_exec - packed)")

    def replica_budget(self, precision: str) -> int:
        """Per-batch (and per-job admission) chain cap: the per-call cap,
        additionally clamped to the lane fabric's capacity for bit-plane
        jobs (the engine cannot stack more than ``MAX_LANE_WORDS`` uint32
        word planes).  The server's ``submit`` validates against this same
        number, so admission never accepts a job the scheduler can't
        batch."""
        lanes = lanes_of(precision)
        if lanes > 1:
            return min(self.max_replicas_per_call, MAX_LANE_WORDS * lanes)
        return self.max_replicas_per_call

    def r_exec_for(self, engine: str, replicas: int,
                   precision: str = "f32") -> int:
        """Executed batch width for a pack totalling ``replicas`` chains —
        the pool-key bucketing ``prewarm`` must agree with.  Clamped like
        :meth:`Batch.relayout`: never padded past the per-call cap; lane
        (word-multiple) clamping replaces the pow2 pad for bit-plane
        jobs, with pow2 as the sub-word-cap fallback."""
        r = int(replicas)
        lanes = lanes_of(precision)
        if lanes > 1:
            lane_r = ((r + lanes - 1) // lanes) * lanes
            if lane_r <= self.max_replicas_per_call:
                return lane_r
            if self.pad_pow2 and engine in PACKABLE_ENGINES \
                    and ceil_pow2(r) <= self.max_replicas_per_call:
                return ceil_pow2(r)
            return r
        if self.pad_pow2 and engine in PACKABLE_ENGINES \
                and ceil_pow2(r) <= self.max_replicas_per_call:
            r = ceil_pow2(r)
        return r

    def next_batch(self, queued: Sequence[Job]) -> Optional[Batch]:
        """The single next batch to run, or None.

        Led by the highest-priority (then oldest) queued job; greedily
        filled with pack-compatible queued jobs in the same order while the
        replica budget holds.  Exactly the jobs it absorbs should be
        removed from the queue by the caller.
        """
        order = sorted(queued, key=lambda j: (-j.spec.priority, j.seq))
        if not order:
            return None
        lead = order[0]
        group = [lead]
        total = lead.spec.replicas
        budget = self.replica_budget(lead.spec.precision)
        if self.pack and lead.spec.engine in PACKABLE_ENGINES:
            for j in order[1:]:
                if j.pack_key != lead.pack_key:
                    continue
                # quarantine/bisect pinning: a re-run cohort (same
                # pack_group token) only packs with itself, so poison
                # isolation controls exactly which jobs share a call
                if j.pack_group != lead.pack_group:
                    continue
                if total + j.spec.replicas > budget:
                    continue
                group.append(j)
                total += j.spec.replicas
        b = Batch(jobs=group, key=lead.pack_key, r_exec=0, slices=[],
                  seq=0, priority=0)
        # non-packable engines derive all replica streams from one seed, so
        # pad replicas would perturb the tenant's chains — never pad them
        b.relayout(self.pad_pow2 and lead.spec.engine in PACKABLE_ENGINES,
                   cap=self.max_replicas_per_call,
                   lanes=lanes_of(lead.spec.precision))
        pad = b.r_exec - total
        with self._lock:
            self.batches_formed += 1
            self.jobs_batched += len(group)
            if len(group) > 1:
                self.jobs_packed += len(group)
            self.padding_replicas += pad
        if self._h_width is not None:
            self._h_width.observe(b.r_exec)
            self._m_padding.inc(pad)
        return b

    def stats(self) -> dict:
        with self._lock:
            return {"max_replicas_per_call": self.max_replicas_per_call,
                    "pack": self.pack, "pad_pow2": self.pad_pow2,
                    "batches_formed": self.batches_formed,
                    "jobs_batched": self.jobs_batched,
                    "jobs_packed": self.jobs_packed,
                    "padding_replicas": self.padding_replicas}
