"""Async sampling server: job queue, packing scheduler, engine pool,
streaming results.

``SampleServer`` turns the engine layer into a multi-tenant service:

- **submit / poll / result / cancel** — anneal requests become jobs with
  priorities and admission control (a bounded queue rejects overload with
  :class:`QueueFull` instead of buffering unboundedly).
- **replica packing** — compatible concurrent jobs (same problem, engine,
  precision, exchange period, beta staircase) coalesce into one batched
  engine call along the replica axis R; each tenant owns a replica slice,
  and because packed replicas are seeded per-job, a job's trajectory is
  bitwise independent of its batch-mates.
- **engine pool** — compiled handles live in an LRU keyed by problem
  fingerprint (+ engine/precision/packed width), so hot problems never
  recompile; ``prewarm`` moves cold compiles off the serving path entirely.
- **streaming** — jobs advance through the bounded chunks of the shared
  recording driver (``RecordedCursor``); ``poll`` returns the partial
  energy trace, best-so-far spins, and *exact* per-job flip counts
  mid-anneal, and the server can preempt a long batch between chunks when
  higher-priority work arrives.

Fault tolerance (see serve/faults.py for the taxonomy and DESIGN.md for
the state machine): a batched call that throws is **quarantined and
bisected** — innocent tenants re-run and complete, only the culprit
fails; transient failures retry with exponential backoff + jitter under
a per-job ``max_retries``; jobs past ``checkpoint_every`` sweeps snapshot
their cursor into a spool directory between chunks, so retries resume
from the checkpoint instead of sweep 0 and :meth:`SampleServer.recover`
re-admits in-flight jobs after a process crash (bitwise-identical
continuation); ``deadline_s`` is enforced between chunks; a watchdog
marks the engine-pool key of a stalled chunk suspect; and the pool's
circuit breaker stops a key that keeps failing to compile from stalling
the serving loop.  All of it is drivable deterministically through
``SampleServer(fault_plan=...)``.

Driving: ``pump()`` runs one chunk of the best batch (deterministic,
test-friendly); ``start()`` runs the same loop on a background thread.

  srv = SampleServer()
  srv.register_problem("ea8", graph=g, coloring=col)
  jid = srv.submit("ea8", engine="dsim", sweeps=2048, replicas=4)
  srv.poll(jid)["sweeps_done"]      # streams while annealing
  srv.result(jid)["best_energy"]
"""

from __future__ import annotations

import copy
import hashlib
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.core.annealing import ea_schedule
from repro.core.degrade import DegradePolicy
from repro.engines import make_engine
from repro.engines.base import (LANE_WIDTH, MAX_LANE_WORDS, check_precision,
                                lanes_of, quantize_record_points, spawn_seeds)
from repro.obs import MetricsRegistry, Tracer

from .faults import (FaultPlan, StateCorruption, classify_error,
                     compute_backoff)
from .jobs import Job, JobSpec, JobStatus, problem_fingerprint, \
    schedule_fingerprint
from .pool import EnginePool
from .scheduler import Batch, ReplicaPackingScheduler
from .spool import CheckpointSpool

__all__ = ["SampleServer", "QueueFull"]

_FILLER_SEED = 1_000_003      # namespace for pad-replica seed spawning


def _hashable_kw(kw: Dict[str, Any]) -> tuple:
    """Engine kwargs as a hashable pool-key component.  Graph-registered
    problems carry arrays (``labels`` partitions, meshes) in their
    ``engine_kw``; a raw ``tuple(sorted(kw.items()))`` made the pool key
    unhashable, so every mesh-engine job died at the cache probe.  Arrays
    key by content digest (same partition -> same executable, regardless
    of array identity); anything else non-primitive keys by ``repr``."""
    items = []
    for k, v in sorted(kw.items()):
        if isinstance(v, np.ndarray) or hasattr(v, "__array__"):
            a = np.asarray(v)
            v = ("ndarray", a.dtype.str, a.shape,
                 hashlib.sha1(a.tobytes()).hexdigest())
        elif not isinstance(v, (int, float, str, bool, bytes, frozenset,
                                tuple, type(None))):
            v = ("repr", repr(v))
        items.append((k, v))
    return tuple(items)


class QueueFull(RuntimeError):
    """Admission control: the bounded job queue rejected a submission."""


class _Problem:
    def __init__(self, name, graph, coloring, L, seed, engine_kw):
        self.name = name
        self.graph = graph
        self.coloring = coloring
        self.L = L
        self.seed = seed
        self.engine_kw = dict(engine_kw)
        self.fingerprint = problem_fingerprint(graph=graph, L=L, seed=seed)


class SampleServer:
    """Multi-tenant annealing server over the unified engine layer."""

    # lifecycle/fault counters live on the metrics registry (one counter
    # family each); attribute reads (`srv.failed`) resolve through
    # __getattr__ so the pre-telemetry surface is unchanged
    _COUNTERS = {
        "submitted": ("serve_jobs_submitted_total", "jobs admitted"),
        "completed": ("serve_jobs_completed_total", "jobs finished DONE"),
        "failed": ("serve_jobs_failed_total", "jobs finished FAILED"),
        "cancelled": ("serve_jobs_cancelled_total",
                      "jobs finished CANCELLED"),
        "rejected": ("serve_jobs_rejected_total",
                     "submissions bounced by admission control"),
        "engine_calls": ("serve_engine_calls_total",
                         "batched anneal launches (cursors built)"),
        "preemptions": ("serve_preemptions_total",
                        "batches parked by higher-priority work"),
        "retries": ("serve_retries_total",
                    "transient-failure retries granted"),
        "quarantined_batches": ("serve_quarantined_batches_total",
                                "multi-job batches sent to bisection"),
        "bisect_requeues": ("serve_bisect_requeues_total",
                            "jobs re-queued by quarantine splits"),
        "deadline_failures": ("serve_deadline_failures_total",
                              "jobs failed by wall-budget expiry"),
        "stuck_chunks": ("serve_stuck_chunks_total", "watchdog firings"),
        "corrupted_chunks": ("serve_corrupted_chunks_total",
                             "integrity-guard firings"),
        "checkpoints_written": ("serve_checkpoints_written_total",
                                "cursor snapshots spooled"),
        "checkpoints_resumed": ("serve_checkpoints_resumed_total",
                                "batches restored from a checkpoint"),
        "recovered_jobs": ("serve_recovered_jobs_total",
                           "jobs re-admitted by recover()"),
        "exchange_integrity_failures": (
            "serve_exchange_integrity_failures_total",
            "corrupted/out-of-order boundary exchanges detected (and "
            "NOT ingested) by the mesh engines' integrity layer"),
        "stale_exchanges": ("serve_stale_exchanges_total",
                            "boundary exchanges held at last-known-good "
                            "ghosts under a degrade policy"),
        "mesh_resyncs": ("serve_mesh_resyncs_total",
                         "quarantined meshes resynced to ground truth"),
    }

    def __init__(self, *, pool_capacity: int = 8, max_queue_depth: int = 128,
                 max_replicas_per_call: int = 64, pack: bool = True,
                 pad_pow2: bool = True, stream_chunks: int = 8,
                 warm_compile: bool = True, retain_jobs: int = 4096,
                 fault_plan: Optional[FaultPlan] = None,
                 spool_dir: Optional[str] = None,
                 spool_max_bytes: int = 256 * 1024 * 1024,
                 checkpoint_every: Optional[int] = None,
                 max_retries: int = 2, max_bisect_calls: int = 16,
                 retry_backoff_s: float = 0.0,
                 retry_backoff_cap_s: float = 5.0,
                 retry_jitter: float = 0.5,
                 chunk_timeout_s: Optional[float] = None,
                 breaker_threshold: int = 3,
                 breaker_cooldown_s: float = 30.0,
                 metrics: Optional[MetricsRegistry] = None,
                 tracer: Optional[Tracer] = None):
        """Fault-tolerance knobs (the rest as before):

        ``fault_plan`` — a :class:`repro.serve.faults.FaultPlan` injected
        at engine-pool builds, between-chunk pump steps, and the cursor's
        per-chunk boundary hook (deterministic chaos for tests/benches).
        ``spool_dir`` — enable chunk-granular checkpointing into this
        directory (content-addressed, size-capped by ``spool_max_bytes``);
        ``checkpoint_every`` is the default sweep interval between
        snapshots (per-job ``JobSpec.checkpoint_every`` overrides; either
        must be set for checkpoints to be taken).  ``max_retries`` bounds
        per-job transient-failure retries (spec override), paced by
        ``retry_backoff_s`` * 2**k with ``retry_jitter`` (0.0 = retry
        immediately — deterministic tests).  ``max_bisect_calls`` bounds
        the extra engine calls poison-batch isolation may spend re-running
        quarantined jobs.  ``chunk_timeout_s`` arms the stuck-chunk
        watchdog (the batch's pool key is marked suspect).  The breaker
        knobs pass through to :class:`EnginePool`.

        ``metrics`` / ``tracer`` — the server's telemetry fabric
        (``repro.obs``); fresh instances are created when omitted, so
        :meth:`metrics_snapshot` / :meth:`render_metrics` always work.
        """
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else Tracer()
        self.pool = EnginePool(pool_capacity,
                               breaker_threshold=breaker_threshold,
                               breaker_cooldown_s=breaker_cooldown_s,
                               metrics=self.metrics)
        self.scheduler = ReplicaPackingScheduler(
            max_replicas_per_call=max_replicas_per_call, pack=pack,
            pad_pow2=pad_pow2, metrics=self.metrics)
        self.max_queue_depth = int(max_queue_depth)
        self.stream_chunks = max(int(stream_chunks), 1)
        self.warm_compile = bool(warm_compile)
        # terminal results are retained for the most recent `retain_jobs`
        # jobs (bounded memory on a long-lived server); older ids 404
        self.retain_jobs = max(int(retain_jobs), 1)
        self._terminal_order: deque = deque()

        self.fault_plan = fault_plan
        self.spool = None if spool_dir is None else \
            CheckpointSpool(spool_dir, max_bytes=spool_max_bytes)
        self.checkpoint_every = None if checkpoint_every is None \
            else max(int(checkpoint_every), 1)
        self.max_retries = max(int(max_retries), 0)
        self.max_bisect_calls = max(int(max_bisect_calls), 0)
        self.retry_backoff_s = float(retry_backoff_s)
        self.retry_backoff_cap_s = float(retry_backoff_cap_s)
        self.retry_jitter = float(retry_jitter)
        self.chunk_timeout_s = None if chunk_timeout_s is None \
            else float(chunk_timeout_s)

        self._lock = threading.RLock()
        self._cv = threading.Condition(self._lock)  # lock_alias: _lock
        self._pump_lock = threading.Lock()
        self._problems: Dict[str, _Problem] = {}    # guarded_by: _lock
        self._jobs: Dict[str, Job] = {}             # guarded_by: _lock
        self._queue: List[Job] = []                 # guarded_by: _lock
        self._batches: List[Batch] = []             # guarded_by: _lock
        self._current: Optional[Batch] = None       # guarded_by: _lock
        self._next_seq = 0                          # guarded_by: _lock
        self._group_seq = 0                         # guarded_by: _lock
        self._bisect_left = self.max_bisect_calls   # guarded_by: _lock
        self._thread: Optional[threading.Thread] = None
        self._stop = False
        # register-time bit-plane prewarm threads (join to block on warmth)
        self.prewarm_threads: List[threading.Thread] = []
        # lifecycle + fault-tolerance counters: registry families keyed
        # by their legacy attribute names (stats() and `srv.<name>` read
        # through them)
        self._counter_fams = {
            attr: self.metrics.counter(name, help)
            for attr, (name, help) in self._COUNTERS.items()}
        # latency/goodput distributions and instantaneous gauges
        self._h_queue_wait = self.metrics.histogram(
            "serve_queue_wait_seconds", "submit -> first batch start")
        self._h_pump = self.metrics.histogram(
            "serve_pump_chunk_seconds", "one cursor chunk in the pump")
        self._h_job_total = self.metrics.histogram(
            "serve_job_total_seconds", "submit -> DONE wall time")
        self._h_goodput = self.metrics.histogram(
            "serve_job_flips_per_s", "per-DONE-job device flip rate",
            buckets=tuple(10.0 ** e for e in range(3, 13)))
        self._g_queue = self.metrics.gauge(
            "serve_queue_depth", "jobs waiting for a batch")
        self._g_inflight = self.metrics.gauge(
            "serve_inflight_batches", "batches started and unfinished")
        self._g_flips = self.metrics.gauge(
            "engine_flips_per_s", "last observed per-engine-path flip rate")

    def _count(self, attr: str, n: int = 1) -> None:
        """Bump a lifecycle counter (a registry family; see _COUNTERS)."""
        self._counter_fams[attr].inc(n)

    def __getattr__(self, name: str):
        # legacy counter attributes (srv.failed, srv.retries, ...) read
        # the registry; only consulted when normal lookup misses
        fams = self.__dict__.get("_counter_fams")
        if fams is not None and name in fams:
            return int(fams[name].value)
        raise AttributeError(
            f"{type(self).__name__!r} object has no attribute {name!r}")

    # -- problems --------------------------------------------------------------

    def register_problem(self, name: str, *, graph=None, coloring=None,
                         L: Optional[int] = None, seed: int = 0,
                         prewarm_bitplane: bool = False,
                         prewarm_words: int = 1,
                         **engine_kw) -> str:
        """Register a problem instance under ``name``; returns its content
        fingerprint (the packing/pool identity).

        ``prewarm_bitplane=True`` builds + warm-compiles the bit-plane
        executable of ``prewarm_words`` stacked word planes (the
        W = prewarm_words, R = 32*W bucket) on a daemon thread at register
        time: the scheduler clamps executed widths up to a word multiple,
        so every bit-plane pack composition totalling at most ``32 *
        prewarm_words`` chains buckets to that single key and sees zero
        cold starts (e.g. ``prewarm_words=2`` pre-compiles the W=2
        executable that R=33 and R=64 submissions share).
        Lattice-registered problems prewarm the lattice engine;
        graph-registered problems the mesh engine (which must be buildable
        on this host's device count — pass K/labels in ``engine_kw`` as
        needed).  The prewarm thread is appended to
        :attr:`prewarm_threads` (join it to block on warmth).
        """
        if (graph is None) == (L is None):
            raise ValueError("register exactly one of graph= or L=")
        words = int(prewarm_words)
        if not 1 <= words <= MAX_LANE_WORDS:
            raise ValueError(f"prewarm_words must be in "
                             f"[1, {MAX_LANE_WORDS}], got {prewarm_words}")
        p = _Problem(name, graph, coloring, L, seed, engine_kw)
        with self._lock:
            self._problems[name] = p
        if prewarm_bitplane:
            engine = "lattice" if L is not None else "dsim_dist"
            self.prewarm_threads.append(
                self.prewarm(name, engine=engine,
                             replicas=LANE_WIDTH * words,
                             precision="bitplane"))
        return p.fingerprint

    # -- submission ------------------------------------------------------------

    def submit(self, problem: str, *, engine: str = "gibbs",
               sweeps: int = 1024, replicas: int = 1, seed: int = 0,
               precision: str = "f32", sync_every=1,
               record_points: Optional[Sequence[int]] = None,
               priority: int = 0, schedule=None,
               max_retries: Optional[int] = None,
               deadline_s: Optional[float] = None,
               checkpoint_every: Optional[int] = None,
               degrade_policy: Optional[str] = None) -> str:
        """Admit one annealing job; returns its job id (non-blocking).

        ``max_retries`` / ``deadline_s`` / ``checkpoint_every`` override
        the server-level fault-tolerance defaults for this job alone
        (deadline is wall time from submission, enforced between chunks).

        ``degrade_policy`` arms the mesh engines' boundary-integrity
        layer: ``"fail_fast"`` | ``"stale_hold[:N]"`` |
        ``"freeze_boundary"`` (see :class:`repro.core.degrade
        .DegradePolicy`).  Mesh engines (dsim_dist / lattice) only, and
        the job's ``sync_every`` must be an integer (one checked
        exchange per S sweeps).  The health monitor's end-of-run report
        lands in the job's ``degrade`` result field.
        """
        if deadline_s is not None and deadline_s < 0:
            raise ValueError(f"deadline_s must be >= 0, got {deadline_s}")
        if max_retries is not None and max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {max_retries}")
        if checkpoint_every is not None and checkpoint_every < 1:
            raise ValueError(
                f"checkpoint_every must be >= 1, got {checkpoint_every}")
        with self._lock:
            if problem not in self._problems:
                raise ValueError(f"unknown problem {problem!r}")
            prob = self._problems[problem]
        if engine == "lattice" and prob.L is None:
            raise ValueError("lattice engine needs an L=-registered problem")
        if engine != "lattice" and prob.graph is None:
            raise ValueError(f"{engine!r} engine needs a graph-registered "
                             "problem")
        # same guard the registry applies, surfaced at admission so an
        # unsupported (engine, precision) pair is a clear submit error,
        # not a failed job (let alone a downstream shape error)
        check_precision(engine, precision)
        if degrade_policy is not None:
            DegradePolicy.parse(degrade_policy)   # vocabulary check
            if engine not in ("dsim_dist", "lattice"):
                raise ValueError(
                    "degrade_policy applies to the mesh engines "
                    f"(dsim_dist, lattice), not {engine!r}")
            if sync_every in ("phase", None):
                raise ValueError(
                    "degrade_policy needs an integer sync_every (one "
                    f"checked exchange per S sweeps), got {sync_every!r}")
        r_cap = self.scheduler.replica_budget(precision)
        if replicas < 1 or replicas > r_cap:
            raise ValueError(
                f"replicas must be in [1, {r_cap}]"
                + (" (bit-plane jobs pack into the 32 lanes of each of up "
                   f"to {MAX_LANE_WORDS} stacked uint32 word planes, "
                   "bounded by the per-call budget)"
                   if lanes_of(precision) > 1 else ""))
        if sync_every not in ("phase", None) and int(sync_every) < 1:
            raise ValueError(f"sync_every must be >= 1, 'phase', or None; "
                             f"got {sync_every!r}")
        sched = schedule if schedule is not None else ea_schedule(int(sweeps))
        sweeps = int(sched.total_sweeps)
        if sync_every not in ("phase", None) and int(sync_every) > sweeps:
            raise ValueError(
                f"sync_every={sync_every} exceeds the {sweeps}-sweep "
                "schedule (no record point is reachable)")
        if record_points is not None:
            record_points = tuple(int(p) for p in record_points)
            if any(p > sweeps for p in record_points):
                raise ValueError("record point beyond the schedule")
        spec = JobSpec(problem=problem, engine=engine, sweeps=sweeps,
                       replicas=int(replicas), seed=int(seed),
                       precision=precision, sync_every=sync_every,
                       record_points=record_points, priority=int(priority),
                       schedule=schedule, max_retries=max_retries,
                       deadline_s=deadline_s,
                       checkpoint_every=checkpoint_every,
                       degrade_policy=degrade_policy)
        with self._lock:
            if len(self._queue) >= self.max_queue_depth:
                self._count("rejected")
                raise QueueFull(
                    f"queue depth {len(self._queue)} at limit "
                    f"{self.max_queue_depth}")
            seq = self._next_seq
            self._next_seq += 1
            job = Job(f"job-{seq:06d}", seq, spec, prob.fingerprint, sched,
                      schedule_fingerprint(sched), time.perf_counter())
            self._jobs[job.id] = job
            self._queue.append(job)
            self._count("submitted")
            self._cv.notify_all()
        return job.id

    # -- queries ---------------------------------------------------------------

    def _job(self, job_id: str) -> Job:  # lock_held: _lock
        try:
            return self._jobs[job_id]
        except KeyError:
            raise KeyError(f"unknown job {job_id!r}") from None

    def poll(self, job_id: str) -> dict:
        """Snapshot of a job (streams partial results while RUNNING)."""
        with self._lock:
            return self._job(job_id).poll_snapshot()

    def result(self, job_id: str, timeout: Optional[float] = None,
               cancel_on_timeout: bool = False) -> dict:
        """Final payload; drives the server inline when no background
        thread is running, else blocks.  ``timeout`` bounds the wait
        either way (inline pumping checks the deadline between chunks).
        If the serving thread is stopped mid-wait, the caller takes over
        pumping instead of hanging.

        On timeout a :class:`TimeoutError` is raised.  By default the job
        itself is untouched — it stays QUEUED/RUNNING and keeps consuming
        device time, and a later ``result`` call can still collect it.
        ``cancel_on_timeout=True`` additionally cancels the job before
        raising (queued jobs stop immediately, running jobs at the next
        chunk boundary), so an abandoned wait does not strand work."""
        deadline = None if timeout is None else time.perf_counter() + timeout

        def _timed_out():
            if cancel_on_timeout:
                self.cancel(job_id)
            return TimeoutError(f"{job_id} not finished in {timeout}s")

        with self._lock:
            job = self._job(job_id)
            threaded = self._thread is not None
        if threaded:
            with self._cv:
                ok = self._cv.wait_for(
                    lambda: job.status.terminal or self._thread is None,
                    timeout=timeout)
            if not ok:
                raise _timed_out()
        while not job.status.terminal:
            if deadline is not None and time.perf_counter() > deadline:
                raise _timed_out()
            if not self.pump():
                with self._lock:     # a concurrent pumper may have just
                    if job.status.terminal:      # finished it
                        break
                raise RuntimeError(
                    f"{job_id} is {job.status.value} but the server has "
                    "no runnable work")
        with self._lock:
            return job.result_payload()

    def cancel(self, job_id: str) -> bool:
        """Cancel a job; queued jobs stop immediately, running jobs at the
        next chunk boundary (partial results are kept).  False if the job
        already reached a terminal state."""
        with self._lock:
            job = self._job(job_id)
            if job.status.terminal:
                return False
            job.cancel_requested = True
            if job.status is JobStatus.QUEUED and job in self._queue:
                self._queue.remove(job)
                self._finalize(job, JobStatus.CANCELLED)
            return True

    # -- the serving loop ------------------------------------------------------

    def pump(self) -> bool:
        """One scheduling step: pick the best batch (forming it from the
        queue if the queue outranks every started batch) and advance it by
        one bounded chunk.  Returns False when there is nothing to run.

        When every queued job is parked behind a retry-backoff gate, the
        step waits briefly (bounded, outside all locks) and returns True —
        work still exists, it just isn't eligible yet, so ``drain`` keeps
        driving instead of bailing out early."""
        with self._pump_lock:
            with self._lock:
                batch = self._choose_batch()
                if batch is None and self._queue:
                    # all queued jobs are backing off: wait out (a slice
                    # of) the soonest gate, then report runnable work
                    wait = min(j.next_eligible_at for j in self._queue) \
                        - time.perf_counter()
                    backoff_wait = min(max(wait, 0.0), 0.02)
                else:
                    backoff_wait = None
            if backoff_wait is not None:
                if backoff_wait > 0:
                    time.sleep(backoff_wait)
                return True
            if batch is None:
                return False
            try:
                if not batch.started:
                    self._start_batch(batch)
                self._advance_batch(batch)
            except Exception as e:        # noqa: BLE001 — isolate tenants
                self._handle_batch_failure(batch, e)
            return True

    def drain(self):
        """Run until every admitted job is terminal."""
        while self.pump():
            pass
        return self

    def start(self):
        """Serve on a background daemon thread (submit stays non-blocking)."""
        with self._lock:
            if self._thread is not None:
                return self
            self._stop = False
            self._thread = threading.Thread(target=self._serve_loop,
                                            daemon=True,
                                            name="sample-server")
        self._thread.start()
        return self

    def stop(self):
        with self._lock:
            self._stop = True
            self._cv.notify_all()
            t, self._thread = self._thread, None
        if t is not None:
            t.join()
        return self

    def _serve_loop(self):
        while True:
            with self._lock:
                if self._stop:
                    return
            if not self.pump():
                with self._cv:
                    if self._stop:
                        return
                    self._cv.wait(timeout=0.02)

    # -- internals -------------------------------------------------------------

    @staticmethod
    def _rank(b: Batch):
        return (b.priority, -b.seq)

    def _expired(self, job: Job, now: float) -> bool:
        return (job.spec.deadline_s is not None
                and now - job.submitted_at > job.spec.deadline_s)

    def _expire_queued_deadlines(self, now: float):  # lock_held: _lock
        """Under the lock: fail queued jobs whose wall budget ran out
        while waiting (running jobs are checked between chunks)."""
        for j in [j for j in self._queue if self._expired(j, now)]:
            self._queue.remove(j)
            self._fail_deadline(j)

    def _fail_deadline(self, job: Job):  # lock_held: _lock
        """Under the lock: fail one job with a DeadlineExceeded error."""
        job.error = (f"DeadlineExceeded: {job.spec.deadline_s}s wall "
                     f"budget exhausted at {job.sweeps_done}/"
                     f"{job.total_sweeps} sweeps")
        self._count("deadline_failures")
        self._finalize(job, JobStatus.FAILED)

    def _drop_spooled(self, batch: Batch):
        """Forget the batch's spooled checkpoint (it reached a terminal
        state; the record would otherwise be re-admitted by recover())."""
        if batch.ck_digest is not None and self.spool is not None:
            self.spool.remove(batch.ck_digest)
        batch.ck_digest = None

    def _ck_every(self, batch: Batch) -> Optional[int]:
        """Effective checkpoint interval for a batch: the tightest of the
        tenants' ``spec.checkpoint_every`` (falling back to the server
        default per tenant); None disables checkpointing."""
        vals = [j.spec.checkpoint_every if j.spec.checkpoint_every
                is not None else self.checkpoint_every for j in batch.jobs]
        vals = [v for v in vals if v is not None]
        return min(vals) if vals else None

    def _choose_batch(self) -> Optional[Batch]:  # lock_held: _lock
        """Under the lock: highest-(priority, FIFO) among started batches
        and the would-be batch led by the best *eligible* queued job
        (jobs inside a retry-backoff window are invisible this step)."""
        now = time.perf_counter()
        self._expire_queued_deadlines(now)
        eligible = [j for j in self._queue if j.next_eligible_at <= now]
        best_started = max(self._batches, key=self._rank, default=None)
        lead = max(eligible,
                   key=lambda j: (j.spec.priority, -j.seq), default=None)
        batch = best_started
        if lead is not None and (
                best_started is None or
                (lead.spec.priority, -lead.seq) > self._rank(best_started)):
            batch = self.scheduler.next_batch(eligible)
            for j in batch.jobs:
                self._queue.remove(j)
            self._batches.append(batch)
        if batch is None:
            return None
        if (self._current is not None and self._current is not batch
                and self._current in self._batches
                and batch.priority > self._current.priority):
            self._count("preemptions")  # higher-priority work parked a batch
        self._current = batch
        return batch

    def _engine_key_builder(self, prob: _Problem, spec: JobSpec, r_exec: int):
        # a degrade policy compiles a *different* chunk executable (the
        # checked-exchange path with the health carry), so it is part of
        # the pool identity — a degraded job never reuses (or poisons)
        # the clean executable of its policy-free twin
        key = (prob.fingerprint, spec.engine, spec.precision, r_exec,
               str(spec.degrade_policy), _hashable_kw(prob.engine_kw))

        def builder():
            if self.fault_plan is not None:
                # raised inside the builder so the pool's breaker and
                # failed_builds accounting see injected build faults
                # exactly like real compile failures
                self.fault_plan.apply("build", key=key)
            kw = dict(prob.engine_kw)
            if spec.degrade_policy is not None:
                kw["degrade"] = spec.degrade_policy
            if spec.engine == "lattice":
                return make_engine("lattice", L=prob.L, seed=prob.seed,
                                   replicas=r_exec,
                                   precision=spec.precision, **kw)
            kw.setdefault("coloring", prob.coloring)
            if spec.engine in ("dsim", "dsim_dist"):
                return make_engine(spec.engine, prob.graph, replicas=r_exec,
                                   precision=spec.precision, **kw)
            # gibbs (f32-only, enforced at submit)
            return make_engine(spec.engine, prob.graph, replicas=r_exec,
                               **kw)

        return key, builder

    def _stream_points(self, sweeps: int) -> set:
        """Stream points bound chunk sizes, so polls see fresh data and
        preemption is never more than one stream interval away."""
        every = max(sweeps // self.stream_chunks, 1)
        return set(range(every, sweeps + 1, every)) | {sweeps}

    def _record_points(self, spec_points, sweeps: int) -> List[int]:
        """Union of tenant-requested points and stream points."""
        pts = self._stream_points(sweeps)
        for p in spec_points:
            pts |= set(p if p is not None else (sweeps,))
        return sorted(pts)

    def _start_batch(self, batch: Batch):
        lead = batch.jobs[0].spec
        # registry read under the lock — register_problem can run
        # concurrently with the pump (the rest of batch start-up touches
        # only the batch, which no other thread owns yet)
        with self._lock:
            prob = self._problems[lead.problem]
        key, builder = self._engine_key_builder(prob, lead, batch.r_exec)
        batch.pool_key = key
        handle, hit = self.pool.get(key, builder)
        if handle.supports_packing:
            seeds: List[int] = []
            for j in batch.jobs:
                seeds += spawn_seeds(j.spec.seed, j.spec.replicas)
            pad = batch.r_exec - len(seeds)
            if pad:
                seeds += spawn_seeds(_FILLER_SEED + batch.seq, pad)
            state = handle.init_state_packed(seeds)
        else:
            state = handle.init_state(seed=lead.seed)
        sweeps = batch.jobs[0].total_sweeps
        eng = getattr(handle, "eng", None)
        if lead.degrade_policy is not None \
                and getattr(eng, "health", None) is not None:
            # engine-boundary fault site: compile the plan's
            # exchange_corrupt/exchange_drop rules into one code per
            # checked exchange and arm them on the engine — injection
            # happens on the device-side wire, upstream of the
            # integrity layer, not in the cursor hook
            codes = None if self.fault_plan is None else \
                self.fault_plan.exchange_codes(
                    max(sweeps // int(lead.sync_every), 1))
            eng.set_exchange_faults(codes)
        pts = self._record_points([j.spec.record_points for j in batch.jobs],
                                  sweeps)
        cursor = handle.start_recorded(state, batch.jobs[0].schedule, pts,
                                       sync_every=lead.sync_every)
        # a tenant's trace must not depend on its batch-mates: each job
        # harvests only its own requested points plus the shared stream
        # points, quantized with the quantum the cursor ACTUALLY applied
        # (cursor.S — gibbs has no boundaries and records at S=1 whatever
        # sync_every says)
        stream = self._stream_points(sweeps)
        batch.own_points = {
            j.id: set(quantize_record_points(
                sorted(stream | set(j.spec.record_points or ())), cursor.S,
                limit=sweeps))
            for j in batch.jobs}
        if self.fault_plan is not None:
            # boundary-exchange fault site: the hook fires inside
            # RecordedCursor.advance at the top of every plan chunk, with
            # the raw cursor (state is a plain attribute there, so
            # "corrupt" rules can scramble it in place)
            plan = self.fault_plan
            ids = tuple(j.id for j in batch.jobs) \
                + tuple(j.spec.seed for j in batch.jobs)

            def _exchange_hook(c):
                plan.apply("exchange", cursor=c, index=c._i, jobs=ids,
                           key=key)
            cursor.fault_hook = _exchange_hook
        if self.warm_compile and not hit:
            # cold handle: compiles land before the timed region (a pool
            # hit is already warm — re-warming would re-execute every
            # distinct chunk length for nothing).  warm() is pure, so
            # warming before a checkpoint restore is safe.
            t0 = time.perf_counter()
            cursor.warm()
            batch.warm_s = time.perf_counter() - t0
        self._try_resume(batch, cursor)
        batch.handle, batch.cursor, batch.pool_hit = handle, cursor, hit
        batch.started_at = time.perf_counter()
        with self._lock:
            self._count("engine_calls")
            for j in batch.jobs:
                if j.status.terminal:
                    continue   # recovered batches can carry finished slots
                j.attempts += 1
                j.status = JobStatus.RUNNING
                if j.started_at is None:   # retries keep first-start time
                    j.started_at = batch.started_at
                    self._h_queue_wait.labels(engine=lead.engine).observe(
                        batch.started_at - j.submitted_at)
                j.packed_with = len(batch.jobs) - 1
                j.pool_hit = hit

    def _try_resume(self, batch: Batch, cursor) -> bool:
        """Restore the batch's cursor (and the tenants' partial traces)
        from a checkpoint record when one is attached and its layout —
        job ids, replica slices, executed width — matches this batch
        exactly.  Any mismatch falls back to a from-scratch run (partials
        reset); the per-job seeding then still reproduces the no-fault
        trajectory bitwise."""
        ck = batch.resume_ck
        if ck is None and len(batch.jobs) == 1 \
                and batch.jobs[0].resume_ck is not None:
            ck = batch.jobs[0].resume_ck
            batch.ck_digest = batch.jobs[0].resume_ck_digest
        if ck is None:
            return False
        lay = ck["layout"]
        matches = (list(lay["job_ids"]) == [j.id for j in batch.jobs]
                   and [tuple(s) for s in lay["slices"]]
                   == [tuple(s) for s in batch.slices]
                   and int(lay["r_exec"]) == int(batch.r_exec))
        restored = False
        if matches:
            try:
                cursor.restore_checkpoint(ck["cursor"])
                restored = True
            except ValueError:
                restored = False
        with self._lock:
            for j, part in zip(batch.jobs, ck["jobs"]):
                if j.status.terminal:
                    continue
                j.resume_ck = None
                j.resume_ck_digest = None
                if not restored:
                    j.reset_partials()
                    continue
                p = part["partials"]
                j.times = [int(t) for t in p["times"]]
                j.energy_rows = [np.asarray(r).copy()
                                 for r in p["energy_rows"]]
                j.best_energy = float(p["best_energy"])
                j.best_replica = int(p["best_replica"])
                j.best_spins = None if p["best_spins"] is None \
                    else np.asarray(p["best_spins"]).copy()
                j.flips = int(p["flips"])
                j.sweeps_done = int(p["sweeps_done"])
                j.device_s = float(p["device_s"])
                j.resumed_sweeps += int(p["sweeps_done"])
            if restored:
                batch.ck = ck
                batch.ck_token = tuple(ck["token"])
                batch.points_seen = cursor.points_recorded
                batch.last_ck_sweep = int(cursor.sweeps_done)
                self._count("checkpoints_resumed")
            else:
                batch.ck = None
                if batch.ck_digest is not None and self.spool is not None:
                    self.spool.remove(batch.ck_digest)
                batch.ck_digest = None
        batch.resume_ck = None
        return restored

    def _harvest_degrade(self, batch: Batch):  # lock_held: _lock
        """Under the lock, at batch retirement: copy the mesh health
        monitor's report into every degraded tenant's ``degrade`` result
        field and roll its totals into the server counter families."""
        eng = getattr(getattr(batch, "handle", None), "eng", None)
        health = getattr(eng, "health", None)
        if health is None or batch.degrade_harvested:
            return
        batch.degrade_harvested = True
        rep = health.report()
        for j in batch.jobs:
            if j.spec.degrade_policy is not None:
                j.degrade = dict(rep)
        self._count("exchange_integrity_failures", int(rep["detections"]))
        self._count("stale_exchanges", int(rep["stale_exchanges"]))
        self._count("mesh_resyncs", int(rep["resyncs"]))

    def _advance_batch(self, batch: Batch):
        cur = batch.cursor
        chunk_idx = batch.chunks_done
        lead_engine = batch.jobs[0].spec.engine
        t0 = time.perf_counter()
        with self.tracer.span("pump.chunk", batch=batch.seq,
                              chunk=chunk_idx, engine=lead_engine,
                              jobs=len(batch.jobs)):
            if self.fault_plan is not None:
                # "chunk" fault site; "hang" rules sleep inside the timed
                # window so the stuck-chunk watchdog below sees them
                self.fault_plan.apply(
                    "chunk", cursor=cur, index=chunk_idx,
                    jobs=tuple(j.id for j in batch.jobs)
                    + tuple(j.spec.seed for j in batch.jobs),
                    key=batch.pool_key)
            cur.advance(1)
        dt = time.perf_counter() - t0
        batch.device_s += dt
        batch.chunks_done += 1
        self._h_pump.labels(engine=lead_engine).observe(dt)
        if self.chunk_timeout_s is not None and dt > self.chunk_timeout_s:
            # watchdog: the chunk stalled far past budget — flag this
            # key's executable for operators (sticky in pool.stats())
            self.pool.mark_suspect(
                batch.pool_key,
                f"chunk {chunk_idx} took {dt:.3f}s "
                f"(chunk_timeout_s={self.chunk_timeout_s})")
            with self._lock:
                self._count("stuck_chunks")
        now = time.perf_counter()
        if cur.points_recorded == batch.points_seen and not cur.done:
            # mid-gap chunk (max_chunk split): nothing recorded, so skip
            # the flip-settling host sync and trace restack — just keep
            # progress/cancellation/deadlines current
            with self._lock:
                alive = False
                for j, (a, b) in zip(batch.jobs, batch.slices):
                    if j.status is not JobStatus.RUNNING:
                        continue
                    j.sweeps_done = cur.sweeps_done
                    j.device_s = batch.device_s * (b - a) / \
                        max(batch.r_exec, 1)
                    if j.cancel_requested:
                        self._finalize(j, JobStatus.CANCELLED)
                    elif self._expired(j, now):
                        self._fail_deadline(j)
                    else:
                        alive = True
                if not alive:
                    self._harvest_degrade(batch)
                    if batch in self._batches:
                        self._batches.remove(batch)
                    if self._current is batch:
                        self._current = None
                    self._drop_spooled(batch)
            return
        t0 = time.perf_counter()
        rec = cur.record()
        fpr = cur.flips_per_replica()
        batch.device_s += time.perf_counter() - t0
        energies = np.asarray(rec.energies) if len(rec.times) else None
        new = range(batch.points_seen, len(rec.times))
        if energies is not None and len(rec.times) > batch.points_seen \
                and not np.isfinite(energies[batch.points_seen:]).all():
            # integrity guard: garbage state (a corrupting node, an
            # overflowed kernel) shows up as non-finite energies; fail the
            # chunk as transient so the retry path restores the last
            # pre-corruption checkpoint instead of streaming junk
            with self._lock:
                self._count("corrupted_chunks")
            raise StateCorruption(
                f"non-finite energies recorded at chunk {chunk_idx} "
                f"(pool key {batch.pool_key!r}) — sampler state is "
                "corrupt")
        # spins snapshots are only consistent with a row recorded at the
        # cursor's *current* state (chunks end on record points).  The
        # device sync + (R, N) transfer happens OUTSIDE the server lock —
        # job partials are only ever mutated by the (single) pump holder,
        # so the improvement pre-check is race-free — keeping submit/poll
        # latency independent of problem size.
        spins_fresh = (len(rec.times) > 0
                       and int(rec.times[-1]) == cur.sweeps_done)
        spins = None
        if spins_fresh:
            last = len(rec.times) - 1
            improved = any(
                j.status is JobStatus.RUNNING
                and float(energies[last, a:b].min()) < j.best_energy
                for j, (a, b) in zip(batch.jobs, batch.slices))
            if improved:
                spins = np.asarray(batch.handle.global_spins(cur.state))
        with self._lock:
            for i in new:
                t = int(rec.times[i])
                want_spins = (spins is not None and i == len(rec.times) - 1)
                for j, (a, b) in zip(batch.jobs, batch.slices):
                    if j.status is not JobStatus.RUNNING or \
                            t not in batch.own_points[j.id]:
                        continue
                    j.observe(t, energies[i, a:b],
                              spins[a:b] if want_spins else None)
            for j, (a, b) in zip(batch.jobs, batch.slices):
                if j.status is not JobStatus.RUNNING:
                    continue
                j.flips = int(fpr[a:b].sum())
                j.sweeps_done = cur.sweeps_done
                # device time attributed by executed replica share (tenant
                # shares sum to the batch total); flips_per_s is then the
                # machine-level flip rate observed while this job ran
                j.device_s = batch.device_s * (b - a) / max(batch.r_exec, 1)
                if j.cancel_requested:
                    self._finalize(j, JobStatus.CANCELLED)
                elif not cur.done and self._expired(j, now):
                    # between-chunk deadline enforcement: only this
                    # tenant fails; packmates keep their slices and run on
                    self._fail_deadline(j)
            alive = [j for j in batch.jobs
                     if j.status is JobStatus.RUNNING]
            batch.points_seen = len(rec.times)
            if cur.done or not alive:
                self._harvest_degrade(batch)
                for j in alive:
                    self._finalize(j, JobStatus.DONE)
                if batch in self._batches:
                    self._batches.remove(batch)
                if self._current is batch:
                    self._current = None
                self._drop_spooled(batch)
                return
        # chunk-granular checkpointing: once a tenant's checkpoint
        # interval has elapsed, snapshot the cursor + partial traces so
        # retries and post-crash recovery resume from here, not sweep 0
        ck_every = self._ck_every(batch)
        if ck_every is not None \
                and cur.sweeps_done - batch.last_ck_sweep >= ck_every \
                and any(j.status is JobStatus.RUNNING for j in batch.jobs):
            self._write_checkpoint(batch)

    def _write_checkpoint(self, batch: Batch):
        """Snapshot the batch — cursor (device state pulled to host) plus
        every tenant's partial trace and spec — as one picklable record;
        spool it (content-addressed, superseding the batch's previous
        record) when a spool is configured.  The record alone is enough
        to rebuild the jobs in a fresh process (:meth:`recover`)."""
        cur = batch.cursor
        ck_cursor = cur.checkpoint()     # device sync happens outside lock
        with self._lock:
            jobs_part = []
            for j in batch.jobs:
                jobs_part.append({
                    "id": j.id, "seq": j.seq, "spec": j.spec,
                    "schedule": j.schedule, "schedule_fp": j.schedule_fp,
                    "status": j.status.value,
                    "partials": {
                        "times": list(j.times),
                        "energy_rows": [r.copy() for r in j.energy_rows],
                        "best_energy": j.best_energy,
                        "best_replica": j.best_replica,
                        "best_spins": None if j.best_spins is None
                        else j.best_spins.copy(),
                        "flips": j.flips,
                        "sweeps_done": j.sweeps_done,
                        "device_s": j.device_s,
                        "retries": j.retries,
                        "resumed_sweeps": j.resumed_sweeps,
                        "restarted_sweeps": j.restarted_sweeps,
                    }})
            record = {
                "format": 1,
                "token": ("batch",) + tuple(j.id for j in batch.jobs),
                "sweeps_done": int(cur.sweeps_done),
                "problem": batch.jobs[0].spec.problem,
                "problem_fp": batch.jobs[0].problem_fp,
                "jobs": jobs_part,
                "layout": {"job_ids": [j.id for j in batch.jobs],
                           "slices": [tuple(s) for s in batch.slices],
                           "r_exec": int(batch.r_exec)},
                "cursor": ck_cursor,
            }
            batch.ck = record
            batch.ck_token = record["token"]
            batch.last_ck_sweep = int(cur.sweeps_done)
            self._count("checkpoints_written")
        if self.spool is not None:
            batch.ck_digest = self.spool.put(record,
                                             replaces=batch.ck_digest)

    def _handle_batch_failure(self, batch: Batch, err: Exception):
        """Recovery policy for a batch whose start/advance threw.

        Multi-tenant batches are quarantined and *bisected*: the live
        jobs re-run in two halves (pinned to fresh pack groups so the
        scheduler keeps each cohort together), repeatedly isolating the
        poison job, which alone ends FAILED — bounded by
        ``max_bisect_calls`` extra engine calls.  A solo transient
        failure retries under the job's ``max_retries`` with seeded
        exponential backoff, resuming from the batch's checkpoint when
        its layout still matches; anything else fails the job."""
        kind = classify_error(err)
        now = time.perf_counter()
        with self._lock:
            # a degraded mesh that escalated (fail_fast detection,
            # stale_hold budget blown) still reports: harvest before the
            # retry machinery tears the batch down, so the detections
            # that caused this failure are counted and visible
            self._harvest_degrade(batch)
            if batch in self._batches:
                self._batches.remove(batch)
            if self._current is batch:
                self._current = None
            live = [j for j in batch.jobs if not j.status.terminal]
            if not live:
                self._drop_spooled(batch)
                return
            if len(live) > 1:
                # a multi-tenant failure cannot be attributed, whatever
                # its kind — bisect (budget permitting) until the culprit
                # is alone, THEN apply transient/permanent retry policy
                if self._bisect_left >= 2:
                    self._bisect_left -= 2
                    self._count("quarantined_batches")
                    half = (len(live) + 1) // 2
                    for part in (live[:half], live[half:]):
                        group = ("bisect", self._group_seq)
                        self._group_seq += 1
                        for j in part:
                            j.pack_group = group
                            j.bisect_runs += 1
                            j.reset_partials()
                            j.resume_ck = None
                            j.resume_ck_digest = None
                            j.status = JobStatus.QUEUED
                            j.next_eligible_at = now + compute_backoff(
                                j.bisect_runs - 1,
                                base=self.retry_backoff_s,
                                cap=self.retry_backoff_cap_s,
                                jitter=self.retry_jitter,
                                seed=j.spec.seed)
                            self._queue.append(j)
                    self._count("bisect_requeues", len(live))
                    self._drop_spooled(batch)
                    self._cv.notify_all()
                    return
                self._fail_batch(batch, err)
                return
            j = live[0]
            budget = j.spec.max_retries if j.spec.max_retries is not None \
                else self.max_retries
            if kind == "transient" and j.retries < budget:
                j.retries += 1
                self._count("retries")
                if batch.ck is not None:
                    # resume the retry from the last good checkpoint; pin
                    # the job solo so the next batch's layout matches
                    j.resume_ck = batch.ck
                    j.resume_ck_digest = batch.ck_digest
                    batch.ck_digest = None
                else:
                    j.reset_partials()
                j.pack_group = ("retry", self._group_seq)
                self._group_seq += 1
                j.status = JobStatus.QUEUED
                j.next_eligible_at = now + compute_backoff(
                    j.retries - 1, base=self.retry_backoff_s,
                    cap=self.retry_backoff_cap_s,
                    jitter=self.retry_jitter, seed=j.spec.seed)
                self._queue.append(j)
                self._drop_spooled(batch)
                self._cv.notify_all()
                return
            self._fail_batch(batch, err)

    def _fail_batch(self, batch: Batch, err: Exception):
        with self._lock:
            self._harvest_degrade(batch)
            for j in batch.jobs:
                if not j.status.terminal:
                    j.error = f"{type(err).__name__}: {err}"
                    self._finalize(j, JobStatus.FAILED)
            if batch in self._batches:
                self._batches.remove(batch)
            if self._current is batch:
                self._current = None
            self._drop_spooled(batch)

    def _finalize(self, job: Job, status: JobStatus):  # lock_held: _lock
        job.status = status
        job.finished_at = time.perf_counter()
        if job.resume_ck_digest is not None and self.spool is not None:
            # a queued retry that died before running again (deadline,
            # cancel) still owns a spool record — release it
            self.spool.remove(job.resume_ck_digest)
        job.resume_ck = None
        job.resume_ck_digest = None
        if status is JobStatus.DONE:
            self._count("completed")
            eng = job.spec.engine
            self._h_job_total.labels(engine=eng).observe(
                job.finished_at - job.submitted_at)
            if job.device_s > 0 and job.flips:
                rate = job.flips / job.device_s
                self._h_goodput.labels(engine=eng).observe(rate)
                self._g_flips.labels(
                    engine=eng, precision=job.spec.precision).set(rate)
        elif status is JobStatus.FAILED:
            self._count("failed")
        else:
            self._count("cancelled")
        self._terminal_order.append(job.id)
        while len(self._terminal_order) > self.retain_jobs:
            self._jobs.pop(self._terminal_order.popleft(), None)
        self._cv.notify_all()

    # -- crash recovery --------------------------------------------------------

    def recover(self, spool_dir: Optional[str] = None) -> List[str]:
        """Re-admit the in-flight jobs a crashed process left spooled.

        Reads every readable checkpoint record in the spool (``spool_dir``
        overrides the server's own; a server built without a spool adopts
        it), keeps the newest record per batch lineage (max
        ``sweeps_done``), and rebuilds each batch exactly as checkpointed:
        same job ids/specs/partial traces, same replica layout, cursor
        restored on first pump.  The continuation is bitwise-identical to
        the uninterrupted run.  Requires every referenced problem to be
        re-registered first with a *matching* content fingerprint —
        a missing or mismatched problem raises RuntimeError (resuming a
        checkpoint into different couplings would be silent garbage).

        Returns the ids of the re-admitted (non-terminal) jobs; records
        whose tenants all reached terminal states are dropped.  Safe to
        call more than once (already-known job ids are skipped).
        """
        if spool_dir is not None and self.spool is None:
            self.spool = CheckpointSpool(spool_dir)
        spool = self.spool if spool_dir is None \
            else CheckpointSpool(spool_dir)
        if spool is None:
            raise RuntimeError("recover() needs a spool: pass spool_dir= "
                               "or build the server with one")
        best: Dict[tuple, tuple] = {}
        for digest, rec in spool.records():
            tok = tuple(rec.get("token", ()))
            if not tok:
                continue
            prev = best.get(tok)
            if prev is None or int(rec["sweeps_done"]) > prev[0]:
                best[tok] = (int(rec["sweeps_done"]), digest, rec)
        readmitted: List[str] = []
        now = time.perf_counter()
        with self._lock:
            for tok in sorted(best):
                _, digest, rec = best[tok]
                name = rec["problem"]
                prob = self._problems.get(name)
                if prob is None:
                    raise RuntimeError(
                        f"recover: checkpoint {tok!r} references problem "
                        f"{name!r}, which is not registered — re-register "
                        "it before recovering")
                if prob.fingerprint != rec["problem_fp"]:
                    raise RuntimeError(
                        f"recover: problem {name!r} fingerprint "
                        f"{prob.fingerprint} does not match the "
                        f"checkpoint's {rec['problem_fp']} — refusing to "
                        "resume into a different instance")
                if any(part["id"] in self._jobs for part in rec["jobs"]):
                    continue         # this lineage is already re-admitted
                jobs, live = [], []
                for part in rec["jobs"]:
                    j = Job(part["id"], int(part["seq"]), part["spec"],
                            rec["problem_fp"], part["schedule"],
                            part["schedule_fp"], now)
                    p = part["partials"]
                    j.times = [int(t) for t in p["times"]]
                    j.energy_rows = [np.asarray(r).copy()
                                     for r in p["energy_rows"]]
                    j.best_energy = float(p["best_energy"])
                    j.best_replica = int(p["best_replica"])
                    j.best_spins = None if p["best_spins"] is None \
                        else np.asarray(p["best_spins"]).copy()
                    j.flips = int(p["flips"])
                    j.sweeps_done = int(p["sweeps_done"])
                    j.device_s = float(p["device_s"])
                    j.retries = int(p["retries"])
                    j.resumed_sweeps = int(p["resumed_sweeps"])
                    j.restarted_sweeps = int(p["restarted_sweeps"])
                    st = JobStatus(part["status"])
                    self._jobs[j.id] = j
                    self._next_seq = max(self._next_seq, j.seq + 1)
                    jobs.append(j)
                    if st.terminal:
                        # finished before the crash: keep it queryable,
                        # hold its slice in the layout, don't re-run it
                        j.status = st
                        self._terminal_order.append(j.id)
                    else:
                        live.append(j)
                if not live:
                    for j in jobs:
                        self._jobs.pop(j.id, None)
                    spool.remove(digest)
                    continue
                lay = rec["layout"]
                batch = Batch(jobs=jobs, key=jobs[0].pack_key,
                              r_exec=int(lay["r_exec"]),
                              slices=[tuple(s) for s in lay["slices"]],
                              seq=min(j.seq for j in jobs),
                              priority=max(j.spec.priority for j in jobs))
                batch.resume_ck = rec
                batch.ck_digest = digest if spool is self.spool else None
                batch.ck_token = tok
                batch.last_ck_sweep = int(rec["sweeps_done"])
                self._batches.append(batch)
                self._count("submitted", len(live))
                self._count("recovered_jobs", len(live))
                readmitted += [j.id for j in live]
            self._cv.notify_all()
        return readmitted

    # -- warmup / stats --------------------------------------------------------

    def prewarm(self, problem: str, *, engine: str = "gibbs",
                replicas: int = 1, precision: str = "f32", sweeps: int = 1024,
                sync_every=1, schedule=None,
                wait: bool = False) -> threading.Thread:
        """Build + warm-compile the engine a future submit will need, on a
        daemon thread — the cold compile never touches the serving path.
        ``replicas`` is bucketed exactly like the scheduler would."""
        with self._lock:
            prob = self._problems[problem]
        spec = JobSpec(problem=problem, engine=engine, sweeps=int(sweeps),
                       replicas=int(replicas), precision=precision,
                       sync_every=sync_every, schedule=schedule)
        r_exec = self.scheduler.r_exec_for(engine, replicas, precision)
        key, builder = self._engine_key_builder(prob, spec, r_exec)
        sched = schedule if schedule is not None else ea_schedule(int(sweeps))
        pts = self._record_points([None], int(sched.total_sweeps))

        def warm(handle):
            st = handle.init_state(seed=0)
            handle.start_recorded(st, sched, pts,
                                  sync_every=sync_every).warm()

        t = self.pool.prewarm_async(key, builder, warm)
        if wait:
            t.join()
            if t.error is not None:  # surface what a fire-and-forget hides
                raise t.error
        return t

    def _refresh_gauges(self) -> None:  # lock_held: _lock
        """Under the lock: push instantaneous state into the gauges so a
        snapshot/exposition read is current."""
        self._g_queue.set(len(self._queue))
        self._g_inflight.set(len(self._batches))

    def stats(self) -> dict:
        """Consistent, deep-copied snapshot — counters are the registry's
        view, nested component dicts are taken under each component's own
        lock and copied, so mutating the result can never corrupt server
        state (and the server never mutates the caller's copy)."""
        # component snapshots first (each under its owner's lock; their
        # counters only mutate under self._lock, so ordering is benign)
        pool = self.pool.stats()
        scheduler = self.scheduler.stats()
        spool = None if self.spool is None else self.spool.stats()
        # FaultPlan.fired takes the plan's own lock (no torn reads while
        # a pump thread is appending events)
        fired = 0 if self.fault_plan is None else self.fault_plan.fired
        with self._lock:
            self._refresh_gauges()
            out = {attr: int(fam.value)
                   for attr, fam in self._counter_fams.items()}
            out.update(
                queue_depth=len(self._queue),
                inflight_batches=len(self._batches),
                bisect_calls_left=self._bisect_left,
                faults_injected=fired,
                spool=spool, pool=pool, scheduler=scheduler)
        return copy.deepcopy(out)

    def metrics_snapshot(self) -> dict:
        """JSON-able dump of every metric family (see obs.MetricsRegistry)."""
        with self._lock:
            self._refresh_gauges()
        return self.metrics.snapshot()

    def render_metrics(self) -> str:
        """Prometheus text exposition of the server's registry."""
        with self._lock:
            self._refresh_gauges()
        return self.metrics.render_text()
