"""Async sampling server: job queue, packing scheduler, engine pool,
streaming results.

``SampleServer`` turns the engine layer into a multi-tenant service:

- **submit / poll / result / cancel** — anneal requests become jobs with
  priorities and admission control (a bounded queue rejects overload with
  :class:`QueueFull` instead of buffering unboundedly).
- **replica packing** — compatible concurrent jobs (same problem, engine,
  precision, exchange period, beta staircase) coalesce into one batched
  engine call along the replica axis R; each tenant owns a replica slice,
  and because packed replicas are seeded per-job, a job's trajectory is
  bitwise independent of its batch-mates.
- **engine pool** — compiled handles live in an LRU keyed by problem
  fingerprint (+ engine/precision/packed width), so hot problems never
  recompile; ``prewarm`` moves cold compiles off the serving path entirely.
- **streaming** — jobs advance through the bounded chunks of the shared
  recording driver (``RecordedCursor``); ``poll`` returns the partial
  energy trace, best-so-far spins, and *exact* per-job flip counts
  mid-anneal, and the server can preempt a long batch between chunks when
  higher-priority work arrives.

Driving: ``pump()`` runs one chunk of the best batch (deterministic,
test-friendly); ``start()`` runs the same loop on a background thread.

  srv = SampleServer()
  srv.register_problem("ea8", graph=g, coloring=col)
  jid = srv.submit("ea8", engine="dsim", sweeps=2048, replicas=4)
  srv.poll(jid)["sweeps_done"]      # streams while annealing
  srv.result(jid)["best_energy"]
"""

from __future__ import annotations

import hashlib
import itertools
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.core.annealing import ea_schedule
from repro.engines import make_engine
from repro.engines.base import (LANE_WIDTH, MAX_LANE_WORDS, check_precision,
                                lanes_of, quantize_record_points, spawn_seeds)

from .jobs import Job, JobSpec, JobStatus, problem_fingerprint, \
    schedule_fingerprint
from .pool import EnginePool
from .scheduler import Batch, ReplicaPackingScheduler

__all__ = ["SampleServer", "QueueFull"]

_FILLER_SEED = 1_000_003      # namespace for pad-replica seed spawning


def _hashable_kw(kw: Dict[str, Any]) -> tuple:
    """Engine kwargs as a hashable pool-key component.  Graph-registered
    problems carry arrays (``labels`` partitions, meshes) in their
    ``engine_kw``; a raw ``tuple(sorted(kw.items()))`` made the pool key
    unhashable, so every mesh-engine job died at the cache probe.  Arrays
    key by content digest (same partition -> same executable, regardless
    of array identity); anything else non-primitive keys by ``repr``."""
    items = []
    for k, v in sorted(kw.items()):
        if isinstance(v, np.ndarray) or hasattr(v, "__array__"):
            a = np.asarray(v)
            v = ("ndarray", a.dtype.str, a.shape,
                 hashlib.sha1(a.tobytes()).hexdigest())
        elif not isinstance(v, (int, float, str, bool, bytes, frozenset,
                                tuple, type(None))):
            v = ("repr", repr(v))
        items.append((k, v))
    return tuple(items)


class QueueFull(RuntimeError):
    """Admission control: the bounded job queue rejected a submission."""


class _Problem:
    def __init__(self, name, graph, coloring, L, seed, engine_kw):
        self.name = name
        self.graph = graph
        self.coloring = coloring
        self.L = L
        self.seed = seed
        self.engine_kw = dict(engine_kw)
        self.fingerprint = problem_fingerprint(graph=graph, L=L, seed=seed)


class SampleServer:
    """Multi-tenant annealing server over the unified engine layer."""

    def __init__(self, *, pool_capacity: int = 8, max_queue_depth: int = 128,
                 max_replicas_per_call: int = 64, pack: bool = True,
                 pad_pow2: bool = True, stream_chunks: int = 8,
                 warm_compile: bool = True, retain_jobs: int = 4096):
        self.pool = EnginePool(pool_capacity)
        self.scheduler = ReplicaPackingScheduler(
            max_replicas_per_call=max_replicas_per_call, pack=pack,
            pad_pow2=pad_pow2)
        self.max_queue_depth = int(max_queue_depth)
        self.stream_chunks = max(int(stream_chunks), 1)
        self.warm_compile = bool(warm_compile)
        # terminal results are retained for the most recent `retain_jobs`
        # jobs (bounded memory on a long-lived server); older ids 404
        self.retain_jobs = max(int(retain_jobs), 1)
        self._terminal_order: deque = deque()

        self._lock = threading.RLock()
        self._cv = threading.Condition(self._lock)
        self._pump_lock = threading.Lock()
        self._problems: Dict[str, _Problem] = {}
        self._jobs: Dict[str, Job] = {}
        self._queue: List[Job] = []
        self._batches: List[Batch] = []
        self._current: Optional[Batch] = None
        self._seq = itertools.count()
        self._thread: Optional[threading.Thread] = None
        self._stop = False
        # register-time bit-plane prewarm threads (join to block on warmth)
        self.prewarm_threads: List[threading.Thread] = []
        # counters
        self.submitted = 0
        self.completed = 0
        self.failed = 0
        self.cancelled = 0
        self.rejected = 0
        self.engine_calls = 0        # batched anneal launches (cursors built)
        self.preemptions = 0

    # -- problems --------------------------------------------------------------

    def register_problem(self, name: str, *, graph=None, coloring=None,
                         L: Optional[int] = None, seed: int = 0,
                         prewarm_bitplane: bool = False,
                         prewarm_words: int = 1,
                         **engine_kw) -> str:
        """Register a problem instance under ``name``; returns its content
        fingerprint (the packing/pool identity).

        ``prewarm_bitplane=True`` builds + warm-compiles the bit-plane
        executable of ``prewarm_words`` stacked word planes (the
        W = prewarm_words, R = 32*W bucket) on a daemon thread at register
        time: the scheduler clamps executed widths up to a word multiple,
        so every bit-plane pack composition totalling at most ``32 *
        prewarm_words`` chains buckets to that single key and sees zero
        cold starts (e.g. ``prewarm_words=2`` pre-compiles the W=2
        executable that R=33 and R=64 submissions share).
        Lattice-registered problems prewarm the lattice engine;
        graph-registered problems the mesh engine (which must be buildable
        on this host's device count — pass K/labels in ``engine_kw`` as
        needed).  The prewarm thread is appended to
        :attr:`prewarm_threads` (join it to block on warmth).
        """
        if (graph is None) == (L is None):
            raise ValueError("register exactly one of graph= or L=")
        words = int(prewarm_words)
        if not 1 <= words <= MAX_LANE_WORDS:
            raise ValueError(f"prewarm_words must be in "
                             f"[1, {MAX_LANE_WORDS}], got {prewarm_words}")
        p = _Problem(name, graph, coloring, L, seed, engine_kw)
        with self._lock:
            self._problems[name] = p
        if prewarm_bitplane:
            engine = "lattice" if L is not None else "dsim_dist"
            self.prewarm_threads.append(
                self.prewarm(name, engine=engine,
                             replicas=LANE_WIDTH * words,
                             precision="bitplane"))
        return p.fingerprint

    # -- submission ------------------------------------------------------------

    def submit(self, problem: str, *, engine: str = "gibbs",
               sweeps: int = 1024, replicas: int = 1, seed: int = 0,
               precision: str = "f32", sync_every=1,
               record_points: Optional[Sequence[int]] = None,
               priority: int = 0, schedule=None) -> str:
        """Admit one annealing job; returns its job id (non-blocking)."""
        with self._lock:
            if problem not in self._problems:
                raise ValueError(f"unknown problem {problem!r}")
            prob = self._problems[problem]
        if engine == "lattice" and prob.L is None:
            raise ValueError("lattice engine needs an L=-registered problem")
        if engine != "lattice" and prob.graph is None:
            raise ValueError(f"{engine!r} engine needs a graph-registered "
                             "problem")
        # same guard the registry applies, surfaced at admission so an
        # unsupported (engine, precision) pair is a clear submit error,
        # not a failed job (let alone a downstream shape error)
        check_precision(engine, precision)
        r_cap = self.scheduler.replica_budget(precision)
        if replicas < 1 or replicas > r_cap:
            raise ValueError(
                f"replicas must be in [1, {r_cap}]"
                + (" (bit-plane jobs pack into the 32 lanes of each of up "
                   f"to {MAX_LANE_WORDS} stacked uint32 word planes, "
                   "bounded by the per-call budget)"
                   if lanes_of(precision) > 1 else ""))
        if sync_every not in ("phase", None) and int(sync_every) < 1:
            raise ValueError(f"sync_every must be >= 1, 'phase', or None; "
                             f"got {sync_every!r}")
        sched = schedule if schedule is not None else ea_schedule(int(sweeps))
        sweeps = int(sched.total_sweeps)
        if sync_every not in ("phase", None) and int(sync_every) > sweeps:
            raise ValueError(
                f"sync_every={sync_every} exceeds the {sweeps}-sweep "
                "schedule (no record point is reachable)")
        if record_points is not None:
            record_points = tuple(int(p) for p in record_points)
            if any(p > sweeps for p in record_points):
                raise ValueError("record point beyond the schedule")
        spec = JobSpec(problem=problem, engine=engine, sweeps=sweeps,
                       replicas=int(replicas), seed=int(seed),
                       precision=precision, sync_every=sync_every,
                       record_points=record_points, priority=int(priority),
                       schedule=schedule)
        with self._lock:
            if len(self._queue) >= self.max_queue_depth:
                self.rejected += 1
                raise QueueFull(
                    f"queue depth {len(self._queue)} at limit "
                    f"{self.max_queue_depth}")
            seq = next(self._seq)
            job = Job(f"job-{seq:06d}", seq, spec, prob.fingerprint, sched,
                      schedule_fingerprint(sched), time.perf_counter())
            self._jobs[job.id] = job
            self._queue.append(job)
            self.submitted += 1
            self._cv.notify_all()
        return job.id

    # -- queries ---------------------------------------------------------------

    def _job(self, job_id: str) -> Job:
        try:
            return self._jobs[job_id]
        except KeyError:
            raise KeyError(f"unknown job {job_id!r}") from None

    def poll(self, job_id: str) -> dict:
        """Snapshot of a job (streams partial results while RUNNING)."""
        with self._lock:
            return self._job(job_id).poll_snapshot()

    def result(self, job_id: str, timeout: Optional[float] = None) -> dict:
        """Final payload; drives the server inline when no background
        thread is running, else blocks.  ``timeout`` bounds the wait
        either way (inline pumping checks the deadline between chunks).
        If the serving thread is stopped mid-wait, the caller takes over
        pumping instead of hanging."""
        deadline = None if timeout is None else time.perf_counter() + timeout
        with self._lock:
            job = self._job(job_id)
            threaded = self._thread is not None
        if threaded:
            with self._cv:
                ok = self._cv.wait_for(
                    lambda: job.status.terminal or self._thread is None,
                    timeout=timeout)
            if not ok:
                raise TimeoutError(f"{job_id} not finished in {timeout}s")
        while not job.status.terminal:
            if deadline is not None and time.perf_counter() > deadline:
                raise TimeoutError(f"{job_id} not finished in {timeout}s")
            if not self.pump():
                with self._lock:     # a concurrent pumper may have just
                    if job.status.terminal:      # finished it
                        break
                raise RuntimeError(
                    f"{job_id} is {job.status.value} but the server has "
                    "no runnable work")
        with self._lock:
            return job.result_payload()

    def cancel(self, job_id: str) -> bool:
        """Cancel a job; queued jobs stop immediately, running jobs at the
        next chunk boundary (partial results are kept).  False if the job
        already reached a terminal state."""
        with self._lock:
            job = self._job(job_id)
            if job.status.terminal:
                return False
            job.cancel_requested = True
            if job.status is JobStatus.QUEUED and job in self._queue:
                self._queue.remove(job)
                self._finalize(job, JobStatus.CANCELLED)
            return True

    # -- the serving loop ------------------------------------------------------

    def pump(self) -> bool:
        """One scheduling step: pick the best batch (forming it from the
        queue if the queue outranks every started batch) and advance it by
        one bounded chunk.  Returns False when there is nothing to run."""
        with self._pump_lock:
            with self._lock:
                batch = self._choose_batch()
                if batch is None:
                    return False
            try:
                if not batch.started:
                    self._start_batch(batch)
                self._advance_batch(batch)
            except Exception as e:        # noqa: BLE001 — isolate tenants
                self._fail_batch(batch, e)
            return True

    def drain(self):
        """Run until every admitted job is terminal."""
        while self.pump():
            pass
        return self

    def start(self):
        """Serve on a background daemon thread (submit stays non-blocking)."""
        with self._lock:
            if self._thread is not None:
                return self
            self._stop = False
            self._thread = threading.Thread(target=self._serve_loop,
                                            daemon=True,
                                            name="sample-server")
        self._thread.start()
        return self

    def stop(self):
        with self._lock:
            self._stop = True
            self._cv.notify_all()
            t, self._thread = self._thread, None
        if t is not None:
            t.join()
        return self

    def _serve_loop(self):
        while True:
            with self._lock:
                if self._stop:
                    return
            if not self.pump():
                with self._cv:
                    if self._stop:
                        return
                    self._cv.wait(timeout=0.02)

    # -- internals -------------------------------------------------------------

    @staticmethod
    def _rank(b: Batch):
        return (b.priority, -b.seq)

    def _choose_batch(self) -> Optional[Batch]:
        """Under the lock: highest-(priority, FIFO) among started batches
        and the would-be batch led by the best queued job."""
        best_started = max(self._batches, key=self._rank, default=None)
        lead = max(self._queue,
                   key=lambda j: (j.spec.priority, -j.seq), default=None)
        batch = best_started
        if lead is not None and (
                best_started is None or
                (lead.spec.priority, -lead.seq) > self._rank(best_started)):
            batch = self.scheduler.next_batch(self._queue)
            for j in batch.jobs:
                self._queue.remove(j)
            self._batches.append(batch)
        if batch is None:
            return None
        if (self._current is not None and self._current is not batch
                and self._current in self._batches
                and batch.priority > self._current.priority):
            self.preemptions += 1     # higher-priority work parked a batch
        self._current = batch
        return batch

    def _engine_key_builder(self, prob: _Problem, spec: JobSpec, r_exec: int):
        key = (prob.fingerprint, spec.engine, spec.precision, r_exec,
               _hashable_kw(prob.engine_kw))

        def builder():
            kw = dict(prob.engine_kw)
            if spec.engine == "lattice":
                return make_engine("lattice", L=prob.L, seed=prob.seed,
                                   replicas=r_exec,
                                   precision=spec.precision, **kw)
            kw.setdefault("coloring", prob.coloring)
            if spec.engine in ("dsim", "dsim_dist"):
                return make_engine(spec.engine, prob.graph, replicas=r_exec,
                                   precision=spec.precision, **kw)
            # gibbs (f32-only, enforced at submit)
            return make_engine(spec.engine, prob.graph, replicas=r_exec,
                               **kw)

        return key, builder

    def _stream_points(self, sweeps: int) -> set:
        """Stream points bound chunk sizes, so polls see fresh data and
        preemption is never more than one stream interval away."""
        every = max(sweeps // self.stream_chunks, 1)
        return set(range(every, sweeps + 1, every)) | {sweeps}

    def _record_points(self, spec_points, sweeps: int) -> List[int]:
        """Union of tenant-requested points and stream points."""
        pts = self._stream_points(sweeps)
        for p in spec_points:
            pts |= set(p if p is not None else (sweeps,))
        return sorted(pts)

    def _start_batch(self, batch: Batch):
        lead = batch.jobs[0].spec
        prob = self._problems[lead.problem]
        key, builder = self._engine_key_builder(prob, lead, batch.r_exec)
        handle, hit = self.pool.get(key, builder)
        if handle.supports_packing:
            seeds: List[int] = []
            for j in batch.jobs:
                seeds += spawn_seeds(j.spec.seed, j.spec.replicas)
            pad = batch.r_exec - len(seeds)
            if pad:
                seeds += spawn_seeds(_FILLER_SEED + batch.seq, pad)
            state = handle.init_state_packed(seeds)
        else:
            state = handle.init_state(seed=lead.seed)
        sweeps = batch.jobs[0].total_sweeps
        pts = self._record_points([j.spec.record_points for j in batch.jobs],
                                  sweeps)
        cursor = handle.start_recorded(state, batch.jobs[0].schedule, pts,
                                       sync_every=lead.sync_every)
        # a tenant's trace must not depend on its batch-mates: each job
        # harvests only its own requested points plus the shared stream
        # points, quantized with the quantum the cursor ACTUALLY applied
        # (cursor.S — gibbs has no boundaries and records at S=1 whatever
        # sync_every says)
        stream = self._stream_points(sweeps)
        batch.own_points = {
            j.id: set(quantize_record_points(
                sorted(stream | set(j.spec.record_points or ())), cursor.S,
                limit=sweeps))
            for j in batch.jobs}
        if self.warm_compile and not hit:
            # cold handle: compiles land before the timed region (a pool
            # hit is already warm — re-warming would re-execute every
            # distinct chunk length for nothing)
            t0 = time.perf_counter()
            cursor.warm()
            batch.warm_s = time.perf_counter() - t0
        batch.handle, batch.cursor, batch.pool_hit = handle, cursor, hit
        batch.started_at = time.perf_counter()
        with self._lock:
            self.engine_calls += 1
            for j in batch.jobs:
                j.status = JobStatus.RUNNING
                j.started_at = batch.started_at
                j.packed_with = len(batch.jobs) - 1
                j.pool_hit = hit

    def _advance_batch(self, batch: Batch):
        cur = batch.cursor
        t0 = time.perf_counter()
        cur.advance(1)
        batch.device_s += time.perf_counter() - t0
        if cur.points_recorded == batch.points_seen and not cur.done:
            # mid-gap chunk (max_chunk split): nothing recorded, so skip
            # the flip-settling host sync and trace restack — just keep
            # progress/cancellation current
            with self._lock:
                alive = False
                for j, (a, b) in zip(batch.jobs, batch.slices):
                    if j.status is not JobStatus.RUNNING:
                        continue
                    j.sweeps_done = cur.sweeps_done
                    j.device_s = batch.device_s * (b - a) / \
                        max(batch.r_exec, 1)
                    if j.cancel_requested:
                        self._finalize(j, JobStatus.CANCELLED)
                    else:
                        alive = True
                if not alive:
                    if batch in self._batches:
                        self._batches.remove(batch)
                    if self._current is batch:
                        self._current = None
            return
        t0 = time.perf_counter()
        rec = cur.record()
        fpr = cur.flips_per_replica()
        batch.device_s += time.perf_counter() - t0
        energies = np.asarray(rec.energies) if len(rec.times) else None
        new = range(batch.points_seen, len(rec.times))
        # spins snapshots are only consistent with a row recorded at the
        # cursor's *current* state (chunks end on record points).  The
        # device sync + (R, N) transfer happens OUTSIDE the server lock —
        # job partials are only ever mutated by the (single) pump holder,
        # so the improvement pre-check is race-free — keeping submit/poll
        # latency independent of problem size.
        spins_fresh = (len(rec.times) > 0
                       and int(rec.times[-1]) == cur.sweeps_done)
        spins = None
        if spins_fresh:
            last = len(rec.times) - 1
            improved = any(
                j.status is JobStatus.RUNNING
                and float(energies[last, a:b].min()) < j.best_energy
                for j, (a, b) in zip(batch.jobs, batch.slices))
            if improved:
                spins = np.asarray(batch.handle.global_spins(cur.state))
        with self._lock:
            for i in new:
                t = int(rec.times[i])
                want_spins = (spins is not None and i == len(rec.times) - 1)
                for j, (a, b) in zip(batch.jobs, batch.slices):
                    if j.status is not JobStatus.RUNNING or \
                            t not in batch.own_points[j.id]:
                        continue
                    j.observe(t, energies[i, a:b],
                              spins[a:b] if want_spins else None)
            for j, (a, b) in zip(batch.jobs, batch.slices):
                if j.status is not JobStatus.RUNNING:
                    continue
                j.flips = int(fpr[a:b].sum())
                j.sweeps_done = cur.sweeps_done
                # device time attributed by executed replica share (tenant
                # shares sum to the batch total); flips_per_s is then the
                # machine-level flip rate observed while this job ran
                j.device_s = batch.device_s * (b - a) / max(batch.r_exec, 1)
                if j.cancel_requested:
                    self._finalize(j, JobStatus.CANCELLED)
            alive = [j for j in batch.jobs
                     if j.status is JobStatus.RUNNING]
            batch.points_seen = len(rec.times)
            if cur.done or not alive:
                for j in alive:
                    self._finalize(j, JobStatus.DONE)
                if batch in self._batches:
                    self._batches.remove(batch)
                if self._current is batch:
                    self._current = None

    def _fail_batch(self, batch: Batch, err: Exception):
        with self._lock:
            for j in batch.jobs:
                if not j.status.terminal:
                    j.error = f"{type(err).__name__}: {err}"
                    self._finalize(j, JobStatus.FAILED)
            if batch in self._batches:
                self._batches.remove(batch)
            if self._current is batch:
                self._current = None

    def _finalize(self, job: Job, status: JobStatus):
        job.status = status
        job.finished_at = time.perf_counter()
        if status is JobStatus.DONE:
            self.completed += 1
        elif status is JobStatus.FAILED:
            self.failed += 1
        else:
            self.cancelled += 1
        self._terminal_order.append(job.id)
        while len(self._terminal_order) > self.retain_jobs:
            self._jobs.pop(self._terminal_order.popleft(), None)
        self._cv.notify_all()

    # -- warmup / stats --------------------------------------------------------

    def prewarm(self, problem: str, *, engine: str = "gibbs",
                replicas: int = 1, precision: str = "f32", sweeps: int = 1024,
                sync_every=1, schedule=None,
                wait: bool = False) -> threading.Thread:
        """Build + warm-compile the engine a future submit will need, on a
        daemon thread — the cold compile never touches the serving path.
        ``replicas`` is bucketed exactly like the scheduler would."""
        with self._lock:
            prob = self._problems[problem]
        spec = JobSpec(problem=problem, engine=engine, sweeps=int(sweeps),
                       replicas=int(replicas), precision=precision,
                       sync_every=sync_every, schedule=schedule)
        r_exec = self.scheduler.r_exec_for(engine, replicas, precision)
        key, builder = self._engine_key_builder(prob, spec, r_exec)
        sched = schedule if schedule is not None else ea_schedule(int(sweeps))
        pts = self._record_points([None], int(sched.total_sweeps))

        def warm(handle):
            st = handle.init_state(seed=0)
            handle.start_recorded(st, sched, pts,
                                  sync_every=sync_every).warm()

        t = self.pool.prewarm_async(key, builder, warm)
        if wait:
            t.join()
            if t.error is not None:  # surface what a fire-and-forget hides
                raise t.error
        return t

    def stats(self) -> dict:
        with self._lock:
            return {
                "submitted": self.submitted,
                "completed": self.completed,
                "failed": self.failed,
                "cancelled": self.cancelled,
                "rejected": self.rejected,
                "engine_calls": self.engine_calls,
                "preemptions": self.preemptions,
                "queue_depth": len(self._queue),
                "inflight_batches": len(self._batches),
                "pool": self.pool.stats(),
                "scheduler": self.scheduler.stats(),
            }
