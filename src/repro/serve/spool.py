"""Content-addressed, size-capped checkpoint spool.

Between chunks the server snapshots in-flight batches (cursor checkpoint +
per-job partial results) into this spool; after a process crash,
``SampleServer.recover`` reads the newest record per batch back and
resumes every job from its last checkpoint, bitwise-identically to an
uninterrupted run.

Layout and durability:

- One pickle file per record, named by the sha1 of its bytes
  (``<digest>.ck``) — content addressing makes writes idempotent and
  de-duplicates identical states.
- Writes are atomic (temp file + ``os.replace``), and a new checkpoint is
  durable *before* the one it supersedes is deleted — a kill -9 at any
  instant leaves at least one valid checkpoint per batch on disk.  A
  crash between replace and delete can leave two records for one batch;
  :meth:`records` surfaces all of them and the server keeps the one with
  the highest ``sweeps_done``.
- The spool is size-capped: after each put, oldest-first eviction (by
  mtime, never the record just written) keeps the directory under
  ``max_bytes``.  Truncated or unreadable files (a crash mid-write before
  the atomic rename only leaves ``*.tmp`` litter, which is ignored) are
  skipped, never fatal.
"""

from __future__ import annotations

import os
import pickle
from typing import Any, List, Optional, Tuple

from repro.core.snapshot import snapshot_digest, write_snapshot_file

__all__ = ["CheckpointSpool"]

_SUFFIX = ".ck"


class CheckpointSpool:
    """Directory of pickled checkpoint records; see the module docstring."""

    def __init__(self, root: str, max_bytes: int = 256 * 1024 * 1024):
        if max_bytes < 1:
            raise ValueError("max_bytes must be >= 1")
        self.root = str(root)
        self.max_bytes = int(max_bytes)
        os.makedirs(self.root, exist_ok=True)
        self.puts = 0
        self.evictions = 0
        self.corrupt_checkpoints = 0

    def _path(self, digest: str) -> str:
        return os.path.join(self.root, digest + _SUFFIX)

    def put(self, record: Any, replaces: Optional[str] = None) -> str:
        """Persist ``record``; returns its content digest.

        ``replaces`` names the digest this record supersedes (the batch's
        previous checkpoint): it is deleted only after the new record is
        durably in place."""
        blob = pickle.dumps(record, protocol=pickle.HIGHEST_PROTOCOL)
        digest = snapshot_digest(blob)
        write_snapshot_file(self._path(digest), blob)
        self.puts += 1
        if replaces and replaces != digest:
            self.remove(replaces)
        self._enforce_cap(keep=digest)
        return digest

    def load(self, digest: str) -> Any:
        """Load a record, verifying its bytes still hash to ``digest``.

        A bit-flipped or truncated file (disk rot, torn write) is treated
        as *missing* — counted in ``corrupt_checkpoints`` and removed so
        the next scan doesn't re-verify it — rather than letting a random
        ``UnpicklingError`` (or worse, a silently wrong record) escape
        into the resume/retry path.  Callers already handle missing
        checkpoints with a from-scratch restart."""
        path = self._path(digest)
        try:
            with open(path, "rb") as f:
                blob = f.read()
        except OSError:
            raise FileNotFoundError(path)
        if snapshot_digest(blob) != digest:
            self.corrupt_checkpoints += 1
            try:
                os.remove(path)
            except OSError:
                pass
            raise FileNotFoundError(
                f"checkpoint {digest} failed content-hash verification; "
                "treated as missing")
        return pickle.loads(blob)

    def remove(self, digest: str) -> bool:
        try:
            os.remove(self._path(digest))
            return True
        except OSError:
            return False

    def records(self) -> List[Tuple[str, Any]]:
        """All readable (digest, record) pairs; corrupt files skipped."""
        out = []
        for name in sorted(os.listdir(self.root)):
            if not name.endswith(_SUFFIX):
                continue
            digest = name[:-len(_SUFFIX)]
            try:
                out.append((digest, self.load(digest)))
            except (OSError, pickle.UnpicklingError, EOFError,
                    AttributeError, ImportError, ValueError):
                continue
        return out

    def nbytes(self) -> int:
        total = 0
        for name in os.listdir(self.root):
            if name.endswith(_SUFFIX):
                try:
                    total += os.path.getsize(os.path.join(self.root, name))
                except OSError:
                    pass
        return total

    def __len__(self) -> int:
        return sum(1 for n in os.listdir(self.root) if n.endswith(_SUFFIX))

    def _enforce_cap(self, keep: str):
        """Oldest-first eviction down to ``max_bytes``; the record just
        written is never evicted (the cap must not undo the put)."""
        entries = []
        for name in os.listdir(self.root):
            if not name.endswith(_SUFFIX) or name == keep + _SUFFIX:
                continue
            p = os.path.join(self.root, name)
            try:
                st = os.stat(p)
            except OSError:
                continue
            entries.append((st.st_mtime, st.st_size, p))
        total = self.nbytes()
        for _, size, p in sorted(entries):
            if total <= self.max_bytes:
                break
            try:
                os.remove(p)
                total -= size
                self.evictions += 1
            except OSError:
                pass

    def stats(self) -> dict:
        return {"root": self.root, "records": len(self),
                "nbytes": self.nbytes(), "max_bytes": self.max_bytes,
                "puts": self.puts, "evictions": self.evictions,
                "corrupt_checkpoints": self.corrupt_checkpoints}
