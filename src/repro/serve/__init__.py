"""Serving layer: the async multi-tenant sampling server and the LM
token paths.

- :class:`SampleServer` (server.py) — async job queue with priorities and
  admission control, replica-packing scheduler, LRU engine pool, and
  streaming per-chunk results.  The production sampling front door.
- :class:`SampleService` (sample_service.py) — the synchronous one-call
  facade, kept for scripts and as the packing benchmark baseline.
- serve_step.py — prefill/decode steps for the LM workload family.
"""

from .jobs import Job, JobSpec, JobStatus
from .pool import EnginePool
from .sample_service import SampleService
from .scheduler import Batch, ReplicaPackingScheduler
from .server import QueueFull, SampleServer

__all__ = ["SampleServer", "SampleService", "QueueFull", "EnginePool",
           "ReplicaPackingScheduler", "Batch", "Job", "JobSpec",
           "JobStatus"]
