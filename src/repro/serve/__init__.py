"""Serving layer: the async multi-tenant sampling server and the LM
token paths.

- :class:`SampleServer` (server.py) — async job queue with priorities and
  admission control, replica-packing scheduler, LRU engine pool, and
  streaming per-chunk results.  The production sampling front door.
- :class:`SampleService` (sample_service.py) — the synchronous one-call
  facade, kept for scripts and as the packing benchmark baseline.
- faults.py / spool.py — the deterministic fault-injection harness, the
  serving failure taxonomy, and the checkpoint spool behind
  ``SampleServer.recover`` (see DESIGN.md, "Fault tolerance and
  recovery").
- serve_step.py — prefill/decode steps for the LM workload family.
"""

from .faults import (DeadlineExceeded, FaultPlan, FaultRule,
                     InjectedFault, PermanentFault, StateCorruption,
                     TransientFault, classify_error, compute_backoff)
from .jobs import Job, JobSpec, JobStatus
from .pool import CircuitOpen, EnginePool
from .sample_service import SampleService
from .scheduler import Batch, ReplicaPackingScheduler
from .server import QueueFull, SampleServer
from .spool import CheckpointSpool

__all__ = ["SampleServer", "SampleService", "QueueFull", "EnginePool",
           "ReplicaPackingScheduler", "Batch", "Job", "JobSpec",
           "JobStatus", "FaultPlan", "FaultRule", "InjectedFault",
           "TransientFault", "PermanentFault", "StateCorruption",
           "DeadlineExceeded", "CircuitOpen", "CheckpointSpool",
           "classify_error", "compute_backoff"]
