"""LRU pool of compiled engine handles.

Building an engine is cheap; the expensive part is the jit compile of its
chunk runners on first use — seconds on this host, against millisecond
anneals.  The pool keys handles by (problem fingerprint, engine, precision,
packed replica count, engine-kwargs), so a hot problem never recompiles:
the second request for the same key is a dict hit and runs warm.

Capacity-bounded LRU: the serving layer multiplexes many problems over one
device, and each cached handle pins compiled executables plus problem
constants — eviction drops the coldest key (its compiled runners are
garbage-collected; a later request simply rebuilds).

Builds are per-key single-flight: a second thread asking for a key that is
mid-build waits for the first build instead of compiling twice, and the
pool lock is *not* held during builds, so an async prewarm never blocks
the serving path on a compile.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Any, Callable, Dict, Tuple

__all__ = ["EnginePool"]


class EnginePool:
    """Capacity-bounded LRU cache of engine handles with single-flight
    builds; see the module docstring."""

    def __init__(self, capacity: int = 8):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = int(capacity)
        self._cache: "OrderedDict[tuple, Any]" = OrderedDict()
        self._building: Dict[tuple, threading.Event] = {}
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key: tuple, builder: Callable[[], Any]) -> Tuple[Any, bool]:
        """Return (handle, was_hit); builds via ``builder()`` on miss.

        ``was_hit`` means the handle was already cached *when asked* — a
        caller that waited on another thread's in-flight build gets False,
        because that handle is freshly built and possibly not yet warmed
        (callers use the flag to decide whether to warm-compile).
        """
        waited = False
        while True:
            with self._lock:
                if key in self._cache:
                    self._cache.move_to_end(key)
                    self.hits += 1
                    return self._cache[key], not waited
                ev = self._building.get(key)
                if ev is None:
                    ev = threading.Event()
                    self._building[key] = ev
                    self.misses += 1
                    break            # we build
            waited = True
            ev.wait()                # someone else is building this key
        try:
            handle = builder()
        except BaseException:
            with self._lock:
                del self._building[key]
            ev.set()
            raise
        with self._lock:
            self._cache[key] = handle
            self._cache.move_to_end(key)
            while len(self._cache) > self.capacity:
                self._cache.popitem(last=False)
                self.evictions += 1
            del self._building[key]
        ev.set()
        return handle, False

    def prewarm_async(self, key: tuple, builder: Callable[[], Any],
                      warm: Callable[[Any], None] = None) -> threading.Thread:
        """Build (and optionally warm-compile) a key on a daemon thread —
        cold-start work fully off the serving path.  Returns the thread;
        a build/warm failure is stashed on it as ``thread.error`` (the key
        just stays cold), so a joining caller can surface it."""
        def _work():
            try:
                handle, hit = self.get(key, builder)
                if warm is not None and not hit:
                    warm(handle)
            except Exception as e:   # noqa: BLE001 — reported via .error
                t.error = e

        t = threading.Thread(target=_work, daemon=True,
                             name=f"engine-prewarm-{key[0]}")
        t.error = None
        t.start()
        return t

    def __contains__(self, key: tuple) -> bool:
        with self._lock:
            return key in self._cache

    def __len__(self) -> int:
        with self._lock:
            return len(self._cache)

    def stats(self) -> dict:
        with self._lock:
            return {"capacity": self.capacity, "size": len(self._cache),
                    "hits": self.hits, "misses": self.misses,
                    "evictions": self.evictions}
