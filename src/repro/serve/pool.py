"""LRU pool of compiled engine handles, with a build circuit breaker.

Building an engine is cheap; the expensive part is the jit compile of its
chunk runners on first use — seconds on this host, against millisecond
anneals.  The pool keys handles by (problem fingerprint, engine, precision,
packed replica count, engine-kwargs), so a hot problem never recompiles:
the second request for the same key is a dict hit and runs warm.

Capacity-bounded LRU: the serving layer multiplexes many problems over one
device, and each cached handle pins compiled executables plus problem
constants — eviction drops the coldest key (its compiled runners are
garbage-collected; a later request simply rebuilds).

Builds are per-key single-flight: a second thread asking for a key that is
mid-build waits for the first build instead of compiling twice, and the
pool lock is *not* held during builds, so an async prewarm never blocks
the serving path on a compile.

Failure machinery (a compile that dies must not take the serving path
down with it):

- **Accounting** — every failed build is counted (``failed_builds``) and
  its stringified error kept (``last_error``, also per key), surfaced in
  :meth:`stats`; a fire-and-forget ``prewarm_async`` failure is therefore
  visible even if nobody joins the thread.
- **Circuit breaker** — ``breaker_threshold`` *consecutive* failed builds
  of one key open that key's circuit: further ``get``\\ s fast-fail with
  :class:`CircuitOpen` (no compile attempt, the serving loop is not
  stalled re-dying) until ``breaker_cooldown_s`` has passed, after which
  one caller is let through to probe (half-open); a successful build
  closes the circuit.  The clock is injectable for deterministic tests.
- **Suspect marking** — the serving watchdog calls :meth:`mark_suspect`
  when a chunk ran absurdly long on some key's executable; sticky until
  :meth:`clear_suspect`, surfaced in :meth:`stats` for operators.
"""

from __future__ import annotations

import threading
import time
from collections import OrderedDict
from typing import Any, Callable, Dict, Optional, Tuple

__all__ = ["EnginePool", "CircuitOpen"]


class CircuitOpen(TimeoutError):
    """A key's build circuit is open (too many consecutive build
    failures); the pool fast-fails instead of re-attempting the compile.
    Subclasses TimeoutError so the retry policy classifies it transient —
    the cooldown may clear it."""


class EnginePool:
    """Capacity-bounded LRU cache of engine handles with single-flight
    builds and a per-key build circuit breaker; see the module docstring."""

    def __init__(self, capacity: int = 8, *, breaker_threshold: int = 3,
                 breaker_cooldown_s: float = 30.0,
                 clock: Callable[[], float] = time.monotonic,
                 metrics=None):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        if breaker_threshold < 1:
            raise ValueError("breaker_threshold must be >= 1")
        self.capacity = int(capacity)
        self.breaker_threshold = int(breaker_threshold)
        self.breaker_cooldown_s = float(breaker_cooldown_s)
        self._clock = clock
        self._cache: "OrderedDict[tuple, Any]" = OrderedDict()
        self._building: Dict[tuple, threading.Event] = {}
        # per-key breaker record: consecutive fails, last failure time+error
        self._breaker: Dict[tuple, Dict[str, Any]] = {}
        self._suspect: Dict[tuple, str] = {}
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.failed_builds = 0
        self.fast_fails = 0          # gets rejected by an open circuit
        self.last_error: Optional[str] = None
        # optional obs.MetricsRegistry (the server shares its own): build
        # durations, hit/miss counters, and a live circuit-state gauge
        self._m_hits = self._m_misses = self._m_failed = None
        self._h_build = self._g_open = None
        if metrics is not None:
            self._m_hits = metrics.counter(
                "pool_hits_total", "engine-pool cache hits")
            self._m_misses = metrics.counter(
                "pool_misses_total", "engine-pool cache misses (builds)")
            self._m_failed = metrics.counter(
                "pool_failed_builds_total", "engine builds that raised")
            self._h_build = metrics.histogram(
                "pool_build_seconds", "engine build duration on miss")
            self._g_open = metrics.gauge(
                "pool_open_circuits", "keys with an open build circuit")

    def get(self, key: tuple, builder: Callable[[], Any]) -> Tuple[Any, bool]:
        """Return (handle, was_hit); builds via ``builder()`` on miss.

        ``was_hit`` means the handle was already cached *when asked* — a
        caller that waited on another thread's in-flight build gets False,
        because that handle is freshly built and possibly not yet warmed
        (callers use the flag to decide whether to warm-compile).

        Raises :class:`CircuitOpen` without calling ``builder`` when the
        key has failed ``breaker_threshold`` consecutive builds and the
        cooldown has not elapsed.
        """
        waited = False
        while True:
            with self._lock:
                if key in self._cache:
                    self._cache.move_to_end(key)
                    self.hits += 1
                    if self._m_hits is not None:
                        self._m_hits.inc()
                    return self._cache[key], not waited
                br = self._breaker.get(key)
                if br is not None and br["fails"] >= self.breaker_threshold:
                    remaining = self.breaker_cooldown_s - \
                        (self._clock() - br["at"])
                    if remaining > 0:
                        self.fast_fails += 1
                        raise CircuitOpen(
                            f"build circuit open for {key!r}: "
                            f"{br['fails']} consecutive build failures "
                            f"(last: {br['error']}); retrying in "
                            f"{remaining:.1f}s")
                    # cooldown elapsed: fall through half-open — this
                    # caller probes with one build attempt
                ev = self._building.get(key)
                if ev is None:
                    ev = threading.Event()
                    self._building[key] = ev
                    self.misses += 1
                    if self._m_misses is not None:
                        self._m_misses.inc()
                    break            # we build
            waited = True
            ev.wait()                # someone else is building this key
        t_build = time.perf_counter()
        try:
            handle = builder()
        except BaseException as e:
            with self._lock:
                del self._building[key]
                br = self._breaker.setdefault(
                    key, {"fails": 0, "at": 0.0, "error": None})
                br["fails"] += 1
                br["at"] = self._clock()
                br["error"] = f"{type(e).__name__}: {e}"
                self.failed_builds += 1
                self.last_error = br["error"]
                if self._m_failed is not None:
                    self._m_failed.inc()
                if self._g_open is not None:
                    self._g_open.set(self._open_circuits())
            ev.set()
            raise
        if self._h_build is not None:
            self._h_build.observe(time.perf_counter() - t_build)
        with self._lock:
            self._cache[key] = handle
            self._cache.move_to_end(key)
            while len(self._cache) > self.capacity:
                self._cache.popitem(last=False)
                self.evictions += 1
            del self._building[key]
            self._breaker.pop(key, None)   # success closes the circuit
            if self._g_open is not None:
                self._g_open.set(self._open_circuits())
        ev.set()
        return handle, False

    def prewarm_async(self, key: tuple, builder: Callable[[], Any],
                      warm: Callable[[Any], None] = None) -> threading.Thread:
        """Build (and optionally warm-compile) a key on a daemon thread —
        cold-start work fully off the serving path.  Returns the thread;
        a build/warm failure is stashed on it as ``thread.error`` *and*
        counted in the pool's ``failed_builds``/``last_error`` (a warm
        failure too), so a fire-and-forget caller that never joins still
        sees the failure in :meth:`stats`."""
        def _work():
            try:
                handle, hit = self.get(key, builder)
                if warm is not None and not hit:
                    warm(handle)
            except Exception as e:   # noqa: BLE001 — reported via .error
                t.error = e
                with self._lock:
                    # get() already counted a *build* failure; count a
                    # warm/other failure here so nothing is silent
                    err = f"{type(e).__name__}: {e}"
                    if self.last_error != err:
                        self.failed_builds += 1
                        self.last_error = err

        t = threading.Thread(target=_work, daemon=True,
                             name=f"engine-prewarm-{key[0]}")
        t.error = None
        t.start()
        return t

    # -- health ----------------------------------------------------------------

    def mark_suspect(self, key: tuple, reason: str):
        """Flag a key's executable as suspect (watchdog: a chunk stalled
        past its timeout).  Sticky until :meth:`clear_suspect`."""
        with self._lock:
            self._suspect[key] = str(reason)

    def clear_suspect(self, key: tuple) -> bool:
        with self._lock:
            return self._suspect.pop(key, None) is not None

    def suspects(self) -> Dict[tuple, str]:
        with self._lock:
            return dict(self._suspect)

    def breaker_state(self, key: tuple) -> Optional[dict]:
        """The key's breaker record (consecutive fails, last error) or
        None when the circuit is closed and clean."""
        with self._lock:
            br = self._breaker.get(key)
            return None if br is None else dict(br)

    def __contains__(self, key: tuple) -> bool:
        with self._lock:
            return key in self._cache

    def __len__(self) -> int:
        with self._lock:
            return len(self._cache)

    def _open_circuits(self) -> int:
        """Under the lock: how many keys currently fast-fail."""
        return sum(
            1 for br in self._breaker.values()
            if br["fails"] >= self.breaker_threshold
            and (self._clock() - br["at"]) < self.breaker_cooldown_s)

    def stats(self) -> dict:
        with self._lock:
            open_keys = self._open_circuits()
            if self._g_open is not None:
                self._g_open.set(open_keys)
            return {"capacity": self.capacity, "size": len(self._cache),
                    "hits": self.hits, "misses": self.misses,
                    "evictions": self.evictions,
                    "failed_builds": self.failed_builds,
                    "fast_fails": self.fast_fails,
                    "last_error": self.last_error,
                    "open_circuits": open_keys,
                    "suspect_keys": len(self._suspect)}
