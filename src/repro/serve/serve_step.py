"""Serving steps: prefill and single-token decode over the model zoo's
caches (standard KV, rolling SWA ring, Mamba2 recurrent state)."""

from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["make_prefill_step", "make_decode_step", "greedy_generate",
           "cache_len_for"]


def cache_len_for(cfg, seq_len: int) -> int:
    """Cache extent per attention layer for a serving context of seq_len.

    SWA archs with rolling caches only ever need `window` slots — this is
    what makes long_500k feasible for h2o-danube."""
    if cfg.window is not None and cfg.use_rolling_swa:
        return min(cfg.window, seq_len)
    return seq_len


def make_prefill_step(model, cfg):
    def prefill(params, batch, caches):
        if cfg.encdec:
            enc = model.encode(params, batch["frames"])
            logits, caches = model.decode(params, batch["tokens"], enc,
                                          caches=caches)
            return logits[:, -1:], caches, enc
        logits, caches, _ = model.forward(
            params, batch.get("tokens"), embeds=batch.get("embeds"),
            positions3=batch.get("positions3"), caches=caches)
        return logits[:, -1:], caches, None
    return prefill


def make_decode_step(model, cfg):
    def decode(params, tokens_1, caches, enc=None, positions3=None):
        """tokens_1 (B, 1) -> (logits (B,1,V), new caches)."""
        if cfg.encdec:
            logits, caches = model.decode(params, tokens_1, enc, caches=caches)
            return logits, caches
        logits, caches, _ = model.forward(params, tokens_1, caches=caches,
                                          positions3=positions3)
        return logits, caches
    return decode


def greedy_generate(model, cfg, params, batch, max_new: int,
                    cache_dtype=jnp.float32):
    """Prefill + greedy decode loop (the batched-serving example path)."""
    if cfg.encdec:
        B = batch["tokens"].shape[0]
        s_max = batch["tokens"].shape[1] + max_new
    elif "tokens" in batch:
        B = batch["tokens"].shape[0]
        s_max = cache_len_for(cfg, batch["tokens"].shape[1] + max_new)
    else:
        B = batch["embeds"].shape[0]
        s_max = cache_len_for(cfg, batch["embeds"].shape[1] + max_new)
    caches = model.init_cache(B, s_max, dtype=cache_dtype)
    prefill = jax.jit(make_prefill_step(model, cfg))
    decode = jax.jit(make_decode_step(model, cfg))
    logits, caches, enc = prefill(params, batch, caches)
    outs = []
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    for _ in range(max_new):
        outs.append(tok)
        logits, caches = decode(params, tok, caches, enc=enc)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    return jnp.concatenate(outs, axis=1)
