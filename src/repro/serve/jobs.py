"""Job model for the async sampling server.

A *job* is one annealing request: problem + engine + schedule + R replica
chains + seed.  Jobs move QUEUED -> RUNNING -> {DONE, FAILED, CANCELLED};
while RUNNING they accumulate a streamed partial trace (energies at record
points, best-so-far configuration, exact flips) that ``SampleServer.poll``
exposes mid-anneal.

Two requests are *pack-compatible* — runnable as replica slices of one
batched engine call — iff their :func:`pack_key` matches: same problem
fingerprint, engine, precision, boundary-exchange period, and beta
staircase.  The fingerprints below make that check O(1) at schedule time.
"""

from __future__ import annotations

import dataclasses
import enum
import hashlib
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["JobStatus", "JobSpec", "Job", "pack_key",
           "problem_fingerprint", "schedule_fingerprint"]


class JobStatus(str, enum.Enum):
    QUEUED = "queued"
    RUNNING = "running"
    DONE = "done"
    FAILED = "failed"
    CANCELLED = "cancelled"

    @property
    def terminal(self) -> bool:
        return self in (JobStatus.DONE, JobStatus.FAILED,
                        JobStatus.CANCELLED)


def problem_fingerprint(graph=None, L: Optional[int] = None,
                        seed: int = 0) -> str:
    """Content hash of a problem instance.

    Graphs hash their ELL arrays (topology + couplings + fields), so two
    services holding bitwise-equal instances agree; lattices are generated
    from (L, seed) and hash that recipe.
    """
    h = hashlib.sha1()
    if graph is not None:
        for arr in (graph.idx, graph.w, graph.h):
            a = np.asarray(arr)
            h.update(str(a.shape).encode())
            h.update(str(a.dtype).encode())
            h.update(a.tobytes())
        return "g:" + h.hexdigest()[:16]
    if L is None:
        raise ValueError("problem needs graph= or L=")
    return f"lat:L={int(L)}:seed={int(seed)}"


def schedule_fingerprint(schedule) -> str:
    """Content hash of a beta staircase (dense per-sweep array)."""
    a = np.asarray(schedule.beta_array())
    h = hashlib.sha1()
    h.update(str(a.shape).encode())
    h.update(str(a.dtype).encode())
    h.update(a.tobytes())
    return h.hexdigest()[:16]


@dataclasses.dataclass(frozen=True)
class JobSpec:
    """What the caller asked for (immutable once admitted)."""

    problem: str                     # registered problem name
    engine: str = "gibbs"
    sweeps: int = 1024
    replicas: int = 1
    seed: int = 0
    precision: str = "f32"
    sync_every: Any = 1              # int S | 'phase' | None
    record_points: Optional[Tuple[int, ...]] = None
    priority: int = 0                # higher runs sooner
    schedule: Any = None             # explicit Schedule; None -> ea_schedule
    # fault-tolerance policy (None -> the server's defaults)
    max_retries: Optional[int] = None    # transient-failure retry budget
    deadline_s: Optional[float] = None   # wall budget from submit; enforced
    #                                      between chunks (DeadlineExceeded)
    checkpoint_every: Optional[int] = None  # sweeps between spool snapshots
    # mesh degraded-mode policy: None | "fail_fast" | "stale_hold[:N]" |
    # "freeze_boundary" (core.degrade.DegradePolicy.parse vocabulary);
    # only meaningful for the mesh engines (dsim_dist / lattice)
    degrade_policy: Optional[str] = None


def pack_key(spec: JobSpec, problem_fp: str, schedule_fp: str) -> tuple:
    """Compatibility class for replica packing: jobs with equal keys can
    share one batched engine call (each job owns a replica slice)."""
    return (problem_fp, spec.engine, spec.precision, str(spec.sync_every),
            schedule_fp, str(spec.degrade_policy))


class Job:
    """Runtime record: spec + status + streamed partial results.

    All mutation happens under the server's lock; ``poll_snapshot`` hands
    out copies so callers never alias live buffers.
    """

    def __init__(self, job_id: str, seq: int, spec: JobSpec,
                 problem_fp: str, schedule, schedule_fp: str,
                 submitted_at: float):
        self.id = job_id
        self.seq = seq               # admission order (FIFO tie-break)
        self.spec = spec
        self.problem_fp = problem_fp
        self.schedule = schedule
        self.schedule_fp = schedule_fp
        self.pack_key = pack_key(spec, problem_fp, schedule_fp)
        self.status = JobStatus.QUEUED
        self.cancel_requested = False
        self.error: Optional[str] = None
        # fault-tolerance runtime
        self.attempts: int = 0       # batch starts this job participated in
        self.retries: int = 0        # transient-failure retries consumed
        self.bisect_runs: int = 0    # quarantine re-runs (not retries)
        self.pack_group: Optional[tuple] = None  # bisect/recover pinning:
        #   jobs only pack with equal groups (None packs freely)
        self.next_eligible_at: float = 0.0       # retry backoff gate
        self.resume_ck: Any = None   # checkpoint record to resume from
        self.resume_ck_digest: Optional[str] = None  # its spool address
        self.resumed_sweeps: int = 0     # sweeps recovered via checkpoints
        self.restarted_sweeps: int = 0   # sweeps re-executed from scratch
        # timestamps (time.perf_counter clock)
        self.submitted_at = submitted_at
        self.started_at: Optional[float] = None
        self.finished_at: Optional[float] = None
        # streamed partials
        self.times: List[int] = []
        self.energy_rows: List[np.ndarray] = []   # each (r,) at a point
        self.best_energy: float = float("inf")
        self.best_replica: int = -1
        self.best_spins: Optional[np.ndarray] = None
        self.flips: int = 0
        self.sweeps_done: int = 0
        self.total_sweeps: int = int(spec.sweeps)
        self.device_s: float = 0.0   # replica-share of batch device time
        # batching facts (filled when the batch starts)
        self.packed_with: int = 0
        self.pool_hit: Optional[bool] = None
        # degraded-mode provenance (mesh engines with a degrade policy:
        # the health monitor's report at batch end)
        self.degrade: Optional[Dict[str, Any]] = None

    # -- streaming updates (caller holds the server lock) ----------------------

    def observe(self, t: int, energies_r: np.ndarray,
                spins_r: Optional[np.ndarray]):
        """Fold in one record point: (r,) energies and, when the point is
        the cursor's current state, the (r, N) spins for best-so-far."""
        self.times.append(int(t))
        row = np.asarray(energies_r, np.float64).copy()
        self.energy_rows.append(row)
        i = int(np.argmin(row))
        if float(row[i]) < self.best_energy and spins_r is not None:
            self.best_energy = float(row[i])
            self.best_replica = i
            self.best_spins = np.asarray(spins_r[i]).copy()

    def reset_partials(self):
        """Drop streamed partials for a from-scratch re-run (retry or
        bisect without a usable checkpoint); the discarded progress is
        accounted in ``restarted_sweeps``."""
        self.restarted_sweeps += self.sweeps_done
        self.times = []
        self.energy_rows = []
        self.best_energy = float("inf")
        self.best_replica = -1
        self.best_spins = None
        self.flips = 0
        self.sweeps_done = 0
        self.device_s = 0.0

    # -- views ----------------------------------------------------------------

    def energies(self) -> np.ndarray:
        if not self.energy_rows:
            return np.zeros((0, self.spec.replicas))
        return np.stack(self.energy_rows)

    def poll_snapshot(self) -> Dict[str, Any]:
        out = {
            "job_id": self.id,
            "problem": self.spec.problem,
            "engine": self.spec.engine,
            "precision": self.spec.precision,
            "replicas": self.spec.replicas,
            "priority": self.spec.priority,
            "status": self.status.value,
            "sweeps_done": self.sweeps_done,
            "total_sweeps": self.total_sweeps,
            "times": np.asarray(self.times, np.int64),
            "energies": self.energies(),
            "best_energy": self.best_energy,
            "best_replica": self.best_replica,
            "best_spins": None if self.best_spins is None
            else self.best_spins.copy(),
            "flips": self.flips,
            "packed_with": self.packed_with,
            "pool_hit": self.pool_hit,
            "error": self.error,
            "retries": self.retries,
            "bisect_runs": self.bisect_runs,
            "resumed_sweeps": self.resumed_sweeps,
            "restarted_sweeps": self.restarted_sweeps,
            "degrade": None if self.degrade is None else dict(self.degrade),
        }
        return out

    def result_payload(self) -> Dict[str, Any]:
        """Final payload (terminal jobs); extends the poll snapshot with
        latency accounting in the SampleService key vocabulary."""
        out = self.poll_snapshot()
        queue_s = ((self.started_at or self.finished_at or self.submitted_at)
                   - self.submitted_at)
        wall_s = 0.0
        if self.finished_at is not None and self.started_at is not None:
            wall_s = self.finished_at - self.started_at
        total_s = ((self.finished_at or self.submitted_at)
                   - self.submitted_at)
        out.update({
            "queue_s": queue_s,
            "wall_s": wall_s,            # running wall (excludes queueing)
            # executed-replica share of batch device time (tenant shares
            # sum to the batch total), so flips / device_s reads as the
            # machine-level flip rate observed while this job ran
            "device_s": self.device_s,
            "total_s": total_s,
            "cold_start": (None if self.pool_hit is None
                           else not self.pool_hit),
            "flips_per_s": self.flips / max(self.device_s, 1e-9),
        })
        return out
