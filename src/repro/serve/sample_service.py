"""Annealing-as-a-service over the unified engine layer.

The serving story for the sampling side of the machine: a service owns a
problem instance, builds any registry backend once (compiled chunk runners
are cached inside the engine), and then serves anneal requests — each
request runs R independent replica chains in one batched call and returns
per-replica energies, the best configuration, and the exact flip count.
``serve_lm.py``'s token path and this sampling path are the two workload
families the production deployment multiplexes.

This is the synchronous one-call facade (and the one-job-at-a-time
baseline in benchmarks/serve_load.py); the async multi-tenant front door —
job queue, replica packing, engine pool, streaming — is
:class:`repro.serve.SampleServer` (see examples/serve_sampling.py).

  svc = SampleService(graph=g, coloring=col)
  out = svc.submit(engine="dsim", sweeps=2048, replicas=8, seed=3)
  out["best_energy"], out["energies"], out["flips"]
"""

from __future__ import annotations

import time
from typing import Dict, Optional, Sequence

import numpy as np

from repro.engines import make_engine
from repro.core.annealing import Schedule, ea_schedule

__all__ = ["SampleService"]


class SampleService:
    """One problem instance, every backend, batched replica anneals."""

    def __init__(self, graph=None, coloring=None, L: Optional[int] = None,
                 seed: int = 0, **engine_kw):
        self.graph = graph
        self.coloring = coloring
        self.L = L
        self.seed = seed
        self.engine_kw = engine_kw
        self._handles: Dict[tuple, object] = {}

    def _handle(self, engine: str, replicas: int):
        key = (engine, replicas)
        if key not in self._handles:
            kw = dict(self.engine_kw)
            if engine == "lattice":
                self._handles[key] = make_engine(
                    engine, L=self.L, seed=self.seed, replicas=replicas, **kw)
            else:
                self._handles[key] = make_engine(
                    engine, self.graph, coloring=self.coloring,
                    replicas=replicas, **kw)
        return self._handles[key]

    def submit(self, engine: str = "gibbs", sweeps: int = 1024,
               replicas: int = 1, seed: int = 0,
               schedule: Optional[Schedule] = None,
               record_points: Optional[Sequence[int]] = None,
               sync_every=1) -> dict:
        """Run one annealing job; returns a plain-dict result payload.

        Cold submissions warm-compile the chunk runners *outside* the timed
        region (one throwaway execution per distinct chunk length), so
        ``flips_per_s`` always reports warm throughput — compile time never
        bills into the capacity number.
        """
        cold = (engine, replicas) not in self._handles
        h = self._handle(engine, replicas)
        sch = schedule if schedule is not None else ea_schedule(sweeps)
        pts = list(record_points) if record_points is not None else [sweeps]
        if cold:
            h.start_recorded(h.init_state(seed=seed), sch, pts,
                             sync_every=sync_every).warm()
        t0 = time.perf_counter()
        st = h.init_state(seed=seed)
        st, rec = h.run_recorded(st, sch, pts, sync_every=sync_every)
        wall = time.perf_counter() - t0
        energies = np.asarray(rec.energies)          # (P, R)
        finals = energies[-1]
        best = int(np.argmin(finals))
        spins = np.asarray(h.global_spins(st))
        return {
            "engine": engine,
            "replicas": replicas,
            "times": np.asarray(rec.times),
            "energies": energies,
            "best_energy": float(finals[best]),
            "best_replica": best,
            "best_spins": spins[best],
            "flips": rec.flips,
            "wall_s": wall,
            # compile happens in the pre-timed warm pass, so flips_per_s is
            # warm throughput even when cold_start is True
            "cold_start": cold,
            "flips_per_s": rec.flips / max(wall, 1e-9),
        }
