"""Engine protocol, chunk planning, and the shared recording driver.

Before this layer, every backend hand-rolled the same ``run_recorded`` loop
(quantize record points to exchange boundaries, decompose the gaps into
power-of-two chunks, jit one runner per chunk length, read an observable at
each record point).  The four near-duplicates now all call
:func:`run_recorded_driver`; a backend only supplies its chunk runner and
its observable.

Flip accounting: device-side counters are int32 (TPU-native), which wraps
after ~2.1e9 flips — minutes of runtime at the paper's 1e12 flips/s.  The
driver therefore treats the device counter as a modular odometer: it reads
it once per chunk, takes the delta mod 2**32, and accumulates the exact
total in a host-side Python int (arbitrary precision, so >= int64 by
construction).  ``chunk_plan(max_chunk=...)`` bounds the per-chunk delta
below 2**31 so the modular delta is unambiguous.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional, Protocol, Sequence, Union, runtime_checkable

import jax.numpy as jnp
import numpy as np

__all__ = ["Engine", "RunRecord", "SyncSpec", "chunk_plan",
           "run_recorded_driver", "spawn_seeds", "stack_states",
           "flips_chunk_cap"]

SyncSpec = Union[int, str, None]


@runtime_checkable
class Engine(Protocol):
    """What every sampling backend exposes to callers.

    ``replicas`` (R) is fixed at construction; states carry a leading
    replica axis and all traces are per-replica.
    """

    replicas: int
    n_sites: int

    def init_state(self, seed: int = 0) -> Any:
        """Fresh replicated sampler state (R independent RNG streams)."""

    def run_recorded(self, state, schedule, record_points: Sequence[int],
                     sync_every: SyncSpec = 1):
        """Run to each record point; returns (state, RunRecord)."""

    def energy(self, state) -> jnp.ndarray:
        """(R,) true global energies of the current configurations."""

    def global_spins(self, state) -> jnp.ndarray:
        """(R, N) spins in the original problem's node order."""

    def lower_chunk(self, iters: int = 2, S: int = 4):
        """Lower (not run) one sampling chunk — dry-run/roofline hook."""


@dataclasses.dataclass
class RunRecord:
    """Recorded trajectory: unpacks like the legacy ``(times, energies)``
    pair; ``flips`` rides along as the exact host-side total."""

    times: np.ndarray          # (P,) sweep indices of the record points
    energies: jnp.ndarray      # (P,) or (P, R) energies at those points
    flips: int = 0             # exact accepted-flip total (Python int)

    def __iter__(self):
        return iter((self.times, self.energies))

    def __len__(self):
        return 2

    def __getitem__(self, i):
        return (self.times, self.energies)[i]


def chunk_plan(points: Sequence[int],
               max_chunk: Optional[int] = None) -> List[int]:
    """Decompose gaps between record points into power-of-two chunks.

    Returns a list of chunk lengths whose cumsum passes through every point,
    using only power-of-two lengths so at most log2(max_gap) distinct jit
    signatures are compiled.  ``max_chunk`` (a power of two) additionally
    caps each chunk — used to bound per-chunk flip counts below 2**31.
    """
    if max_chunk is not None:
        if max_chunk < 1 or max_chunk & (max_chunk - 1):
            raise ValueError(f"max_chunk must be a power of two, got {max_chunk}")
    plan: List[int] = []
    prev = 0
    for p in points:
        gap = int(p) - prev
        if gap < 0:
            raise ValueError("record points must be nondecreasing")
        while gap > 0:
            c = 1 << (gap.bit_length() - 1)
            if max_chunk is not None:
                c = min(c, max_chunk)
            plan.append(c)
            gap -= c
        prev = int(p)
    return plan


def flips_chunk_cap(flips_per_sweep: int, sweeps_per_iter: int = 1) -> int:
    """Largest power-of-two iteration chunk whose worst-case flip count
    stays below 2**31 (so int32 deltas are exact)."""
    per_iter = max(int(flips_per_sweep), 1) * max(int(sweeps_per_iter), 1)
    cap = max((1 << 30) // per_iter, 1)
    return 1 << (cap.bit_length() - 1)


def quantize_record_points(record_points: Sequence[int], S: int) -> List[int]:
    """Record points snapped to multiples of the exchange period S."""
    return sorted(set(max(S, int(round(p / S)) * S) for p in record_points))


def _flips_read(value) -> np.ndarray:
    return np.atleast_1d(np.asarray(value)).astype(np.int64) % (1 << 32)


def run_recorded_driver(*, state, schedule, record_points: Sequence[int],
                        chunk_fn: Callable,
                        record_fn: Callable,
                        sync_every: SyncSpec = 1,
                        flips_of: Optional[Callable] = None,
                        flips_per_sweep: Optional[int] = None):
    """The shared recording loop.

    Args:
      state: engine state (any pytree).
      schedule: a ``repro.core.annealing.Schedule``.
      record_points: sweep indices at which to record.
      chunk_fn: ``(state, betas_2d, iters, S) -> state`` runs ``iters``
        iterations of ``S`` sweeps; betas_2d has shape (iters, S).
      record_fn: ``state -> observable`` read at each record point.
      sync_every: int S (exchange every S sweeps), 'phase', or None —
        engines that don't exchange just ignore it in their chunk_fn.
      flips_of: optional ``state -> int32 array`` cumulative device flip
        counter(s); when given, the driver accumulates the exact total.
      flips_per_sweep: worst-case flips per sweep (usually N sites times
        replicas); bounds chunk sizes so int32 deltas never alias.

    Returns (state, RunRecord).
    """
    S = 1 if sync_every in ("phase", None) else int(sync_every)
    pts = quantize_record_points(record_points, S)
    betas = schedule.beta_array()
    if len(betas) < pts[-1]:
        raise ValueError("schedule shorter than last record point")
    max_chunk = None
    if flips_per_sweep is not None:
        max_chunk = flips_chunk_cap(flips_per_sweep, S)
    plan = chunk_plan([p // S for p in pts], max_chunk=max_chunk)
    targets = set(pts)

    # The device counter is read lazily: at record points (which synchronize
    # anyway for the observable) and just before the worst-case flips since
    # the last read could reach 2**31 (keeping the modular delta
    # unambiguous).  Chunks never end with a gratuitous host sync.
    flips_total = 0
    prev = _flips_read(flips_of(state)) if flips_of is not None else None
    pending = 0                      # worst-case flips since `prev` was read
    LIMIT = 1 << 31

    def read_flips():
        nonlocal flips_total, prev, pending
        cur = _flips_read(flips_of(state))
        flips_total += int(((cur - prev) % (1 << 32)).sum())
        prev = cur
        pending = 0

    out, times, pos = [], [], 0
    betas = np.asarray(betas)
    for c in plan:
        nsw = c * S
        worst = nsw * (flips_per_sweep or 0)
        if flips_of is not None and flips_per_sweep and \
                pending + worst >= LIMIT:
            read_flips()
        # trailing dims (e.g. a per-replica axis) ride along untouched
        bchunk = jnp.asarray(betas[pos:pos + nsw]).reshape(
            (c, S) + betas.shape[1:])
        state = chunk_fn(state, bchunk, c, S)
        pos += nsw
        pending += worst
        if flips_of is not None and flips_per_sweep is None:
            read_flips()             # unknown bound: stay exact per chunk
        if pos in targets:
            out.append(record_fn(state))
            times.append(pos)
            if flips_of is not None:
                read_flips()
    if flips_of is not None and pending:
        read_flips()
    return state, RunRecord(np.asarray(times), jnp.stack(out), flips_total)


# ---------------------------------------------------------------------------
# replica helpers
# ---------------------------------------------------------------------------

def spawn_seeds(seed: int, replicas: int) -> List[int]:
    """R independent 31-bit seeds derived from one master seed.

    Uses numpy's SeedSequence spawning, so replica streams are statistically
    independent and replica r of (seed, R) equals replica r of (seed, R')
    for r < min(R, R') — growing the replica batch never reshuffles the
    existing chains.
    """
    ss = np.random.SeedSequence(seed)
    return [int(child.generate_state(1)[0] & 0x7FFFFFFF)
            for child in ss.spawn(replicas)]


def stack_states(states: Sequence[Any]):
    """Stack per-replica state pytrees along a new leading replica axis."""
    import jax
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *states)
