"""Engine protocol, chunk planning, and the shared recording driver.

Before this layer, every backend hand-rolled the same ``run_recorded`` loop
(quantize record points to exchange boundaries, decompose the gaps into
power-of-two chunks, jit one runner per chunk length, read an observable at
each record point).  The four near-duplicates now all call
:func:`run_recorded_driver`; a backend only supplies its chunk runner and
its observable.

Flip accounting: device-side counters are int32 (TPU-native), which wraps
after ~2.1e9 flips — minutes of runtime at the paper's 1e12 flips/s.  The
driver therefore treats the device counter as a modular odometer: it reads
it once per chunk, takes the delta mod 2**32, and accumulates the exact
total in a host-side Python int (arbitrary precision, so >= int64 by
construction).  ``chunk_plan(max_chunk=...)`` bounds the per-chunk delta
below 2**31 so the modular delta is unambiguous.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, List, Optional, Protocol, Sequence, Union, runtime_checkable

import jax.numpy as jnp
import numpy as np

__all__ = ["Engine", "RunRecord", "SyncSpec", "chunk_plan",
           "run_recorded_driver", "RecordedCursor", "spawn_seeds",
           "stack_states", "flips_chunk_cap", "PRECISIONS",
           "ENGINE_PRECISIONS", "LANE_WIDTH", "MAX_LANE_WORDS", "lane_words",
           "lanes_of", "check_precision", "check_lanes"]

SyncSpec = Union[int, str, None]

# ---------------------------------------------------------------------------
# precision pipelines
# ---------------------------------------------------------------------------
#
# "f32"      — floating reference (tanh + float compare, Philox or LFSR).
# "int8"     — the hardware's fixed-point pipeline: int8 on-chip couplings,
#              integer field accumulation, LUT-threshold accepts.
# "bitplane" — multi-spin coding over the int8 substrate: spins as uint32
#              bit-planes, 32 replica lanes per word, stacked into W word
#              planes (lane l = word l//32, bit l%32), word-wide field math
#              with per-lane RNG/accept.  Lattice engine (halo planes) and
#              mesh engine (native-word boundary all-gather); replicas are
#              lanes, so R <= MAX_LANE_WORDS * LANE_WIDTH.
#
# One shared table so the registry, the serving layer, and the engines all
# reject an unsupported (engine, precision) pair with the same clear error
# — a scheduler-level shape error is never the first symptom.

PRECISIONS = ("f32", "int8", "bitplane")
ENGINE_PRECISIONS = {
    "gibbs": ("f32",),
    "dsim": ("f32", "int8"),
    "dsim_dist": ("f32", "int8", "bitplane"),
    "lattice": ("f32", "int8", "bitplane"),
}
# canonical word-format constants live next to the packing routines
from repro.core.packing import LANE_WIDTH, MAX_LANE_WORDS  # noqa: E402


def lanes_of(precision: str) -> int:
    """Replica lanes one engine call packs per word (1 off the bitplane
    path) — the quantum the serving scheduler clamps batch widths to."""
    return LANE_WIDTH if precision == "bitplane" else 1


def lane_words(n_lanes: int) -> int:
    """Word planes needed for ``n_lanes`` packed lanes: W = ceil(L/32)."""
    return (int(n_lanes) + LANE_WIDTH - 1) // LANE_WIDTH


def check_lanes(precision: str, replicas: int,
                max_words: int = MAX_LANE_WORDS,
                what: str = "replicas") -> int:
    """The one lane-cap guard every packed path shares.

    Validates ``replicas`` (>= 1 on any precision; <= ``max_words * 32``
    on the bitplane path, where they become bit lanes of stacked uint32
    word planes) and returns the word count W the packed state will carry
    — 1 for unpacked precisions.  ``what`` names the quantity in the error
    (the packed tempering ladder passes "chains*temperatures")."""
    r = int(replicas)
    if r < 1:
        raise ValueError(f"{what} must be >= 1, got {r}")
    if precision != "bitplane":
        return 1
    cap = int(max_words) * LANE_WIDTH
    if r > cap:
        raise ValueError(
            f"precision='bitplane' packs {what} into the bit lanes of up "
            f"to {int(max_words)} stacked uint32 word planes; {what} must "
            f"be in [1, {cap}], got {r}")
    return lane_words(r)


def check_precision(engine: str, precision: str):
    """Registry-level guard: raise a clear ValueError for an unknown
    precision or an (engine, precision) pair no backend implements."""
    if precision not in PRECISIONS:
        raise ValueError(f"unknown precision {precision!r}; choose from "
                         f"{PRECISIONS}")
    ok = ENGINE_PRECISIONS.get(engine, ("f32",))
    if precision not in ok:
        raise ValueError(
            f"precision={precision!r} is not supported on engine "
            f"{engine!r} (supported: {', '.join(ok)})"
            + ("; bit-plane multi-spin coding is a lattice/dsim_dist path"
               if precision == "bitplane" else ""))


@runtime_checkable
class Engine(Protocol):
    """What every sampling backend exposes to callers.

    ``replicas`` (R) is fixed at construction; states carry a leading
    replica axis and all traces are per-replica.
    """

    replicas: int
    n_sites: int

    def init_state(self, seed: int = 0) -> Any:
        """Fresh replicated sampler state (R independent RNG streams)."""

    def run_recorded(self, state, schedule, record_points: Sequence[int],
                     sync_every: SyncSpec = 1):
        """Run to each record point; returns (state, RunRecord)."""

    def energy(self, state) -> jnp.ndarray:
        """(R,) true global energies of the current configurations."""

    def global_spins(self, state) -> jnp.ndarray:
        """(R, N) spins in the original problem's node order."""

    def lower_chunk(self, iters: int = 2, S: int = 4):
        """Lower (not run) one sampling chunk — dry-run/roofline hook."""


@dataclasses.dataclass
class RunRecord:
    """Recorded trajectory: unpacks like the legacy ``(times, energies)``
    pair; ``flips`` rides along as the exact host-side total."""

    times: np.ndarray          # (P,) sweep indices of the record points
    energies: jnp.ndarray      # (P,) or (P, R) energies at those points
    flips: int = 0             # exact accepted-flip total (Python int)

    def __iter__(self):
        return iter((self.times, self.energies))

    def __len__(self):
        return 2

    def __getitem__(self, i):
        return (self.times, self.energies)[i]


def chunk_plan(points: Sequence[int],
               max_chunk: Optional[int] = None) -> List[int]:
    """Decompose gaps between record points into power-of-two chunks.

    Returns a list of chunk lengths whose cumsum passes through every point,
    using only power-of-two lengths so at most log2(max_gap) distinct jit
    signatures are compiled.  ``max_chunk`` (a power of two) additionally
    caps each chunk — used to bound per-chunk flip counts below 2**31.
    """
    if max_chunk is not None:
        if max_chunk < 1 or max_chunk & (max_chunk - 1):
            raise ValueError(f"max_chunk must be a power of two, got {max_chunk}")
    plan: List[int] = []
    prev = 0
    for p in points:
        gap = int(p) - prev
        if gap < 0:
            raise ValueError("record points must be nondecreasing")
        while gap > 0:
            c = 1 << (gap.bit_length() - 1)
            if max_chunk is not None:
                c = min(c, max_chunk)
            plan.append(c)
            gap -= c
        prev = int(p)
    return plan


def flips_chunk_cap(flips_per_sweep: int, sweeps_per_iter: int = 1) -> int:
    """Largest power-of-two iteration chunk whose worst-case flip count
    stays below 2**31 (so int32 deltas are exact)."""
    per_iter = max(int(flips_per_sweep), 1) * max(int(sweeps_per_iter), 1)
    cap = max((1 << 30) // per_iter, 1)
    return 1 << (cap.bit_length() - 1)


def quantize_record_points(record_points: Sequence[int], S: int,
                           limit: Optional[int] = None) -> List[int]:
    """Record points snapped to multiples of the exchange period S.

    ``limit`` (the schedule length): round-to-nearest can push a valid
    point past the end of the schedule (e.g. 1000 with S=7 -> 1001), so
    when given, quantized points clamp down to the last reachable
    boundary ``(limit // S) * S``.
    """
    pts = set(max(S, int(round(p / S)) * S) for p in record_points)
    if limit is not None:
        last = (int(limit) // S) * S
        if last >= S:
            pts = set(min(p, last) for p in pts)
    return sorted(pts)


def _flips_read(value) -> np.ndarray:
    return np.atleast_1d(np.asarray(value)).astype(np.int64) % (1 << 32)


class RecordedCursor:
    """The shared recording loop in resumable form.

    Same chunk plan, record-point quantization, and exact modular flip
    accounting as :func:`run_recorded_driver` — but advanced one bounded
    chunk at a time (:meth:`advance`), so a scheduler can interleave several
    runs on one device, stream partial traces to callers mid-anneal, and
    preempt a long job between chunks.  Driving a cursor to completion is
    bitwise identical to the one-shot driver; ``run_recorded_driver`` *is*
    a cursor driven to completion.

    Args are those of :func:`run_recorded_driver`.  Mid-run, :meth:`record`
    returns an exact snapshot (times/observables recorded so far, exact
    flips so far); :attr:`flips_vec` additionally keeps the per-counter
    (e.g. per-replica) totals so a multi-tenant caller can attribute flips
    to the replica slices it packed into one batched run.
    """

    def __init__(self, *, state, schedule, record_points: Sequence[int],
                 chunk_fn: Callable, record_fn: Callable,
                 sync_every: SyncSpec = 1,
                 flips_of: Optional[Callable] = None,
                 flips_per_sweep: Optional[int] = None):
        if len(record_points) == 0:
            raise ValueError("record_points must be non-empty")
        S = 1 if sync_every in ("phase", None) else int(sync_every)
        if S < 1:
            raise ValueError(f"sync_every must be >= 1, got {sync_every!r}")
        betas = np.asarray(schedule.beta_array())
        if max(int(p) for p in record_points) > len(betas):
            raise ValueError("schedule shorter than last record point")
        pts = quantize_record_points(record_points, S, limit=len(betas))
        if len(betas) < pts[-1]:
            raise ValueError("schedule shorter than last record point")
        max_chunk = None
        if flips_per_sweep is not None:
            max_chunk = flips_chunk_cap(flips_per_sweep, S)
        self.state = state
        self.S = S
        self.total_sweeps = pts[-1]
        self._betas = betas
        self._chunk_fn = chunk_fn
        self._record_fn = record_fn
        self._flips_of = flips_of
        self._flips_per_sweep = flips_per_sweep
        self._plan = chunk_plan([p // S for p in pts], max_chunk=max_chunk)
        self._targets = set(pts)
        self._i = 0                  # next chunk index into the plan
        self._pos = 0                # sweeps completed
        self._out: List[Any] = []
        self._times: List[int] = []
        # optional per-chunk boundary hook (fault injection: the serving
        # layer's FaultPlan raises/hangs/corrupts here, at exactly the
        # points where the hardware would drop a boundary exchange)
        self.fault_hook: Optional[Callable] = None
        # optional per-chunk timer `(sweeps, seconds) -> None` (telemetry:
        # obs.EtaMeter attaches here).  When set, each chunk is bracketed
        # by block_until_ready so device-async work is attributed to the
        # chunk that launched it; when None (default) no sync is added
        # and the lazy-flip-read fast path is untouched.
        self.chunk_timer: Optional[Callable] = None
        # The device counter is read lazily: at record points (which
        # synchronize anyway for the observable) and just before the
        # worst-case flips since the last read could reach 2**31 (keeping
        # the modular delta unambiguous).  Chunks never end with a
        # gratuitous host sync.
        self._prev = _flips_read(flips_of(state)) if flips_of is not None \
            else None
        self._pending = 0            # worst-case flips since `_prev` was read
        self.flips_vec = None if self._prev is None else \
            np.zeros(self._prev.shape, np.int64)
        self._flips_total = 0        # exact host total (Python int)

    _LIMIT = 1 << 31

    @property
    def done(self) -> bool:
        return self._i >= len(self._plan)

    @property
    def sweeps_done(self) -> int:
        return self._pos

    @property
    def points_recorded(self) -> int:
        """How many record points have been hit so far (no device sync) —
        lets a caller skip :meth:`record` after a mid-gap chunk."""
        return len(self._times)

    @property
    def flips(self) -> int:
        """Exact flips up to the last counter read (no device sync)."""
        return self._flips_total

    def _read_flips(self):
        cur = _flips_read(self._flips_of(self.state))
        delta = (cur - self._prev) % (1 << 32)
        self.flips_vec += delta
        self._flips_total += int(delta.sum())
        self._prev = cur
        self._pending = 0

    def advance(self, max_chunks: int = 1) -> int:
        """Run up to ``max_chunks`` plan chunks; returns how many ran."""
        ran = 0
        while ran < max_chunks and not self.done:
            if self.fault_hook is not None:
                self.fault_hook(self)
            c = self._plan[self._i]
            nsw = c * self.S
            worst = nsw * (self._flips_per_sweep or 0)
            if self._flips_of is not None and self._flips_per_sweep and \
                    self._pending + worst >= self._LIMIT:
                self._read_flips()
            # trailing dims (e.g. a per-replica axis) ride along untouched
            bchunk = jnp.asarray(
                self._betas[self._pos:self._pos + nsw]).reshape(
                    (c, self.S) + self._betas.shape[1:])
            if self.chunk_timer is not None:
                import jax
                jax.block_until_ready(self.state)
                t0 = time.perf_counter()
                self.state = self._chunk_fn(self.state, bchunk, c, self.S)
                jax.block_until_ready(self.state)
                self.chunk_timer(nsw, time.perf_counter() - t0)
            else:
                self.state = self._chunk_fn(self.state, bchunk, c, self.S)
            self._i += 1
            self._pos += nsw
            self._pending += worst
            ran += 1
            if self._flips_of is not None and self._flips_per_sweep is None:
                self._read_flips()   # unknown bound: stay exact per chunk
            if self._pos in self._targets:
                self._out.append(self._record_fn(self.state))
                self._times.append(self._pos)
                if self._flips_of is not None:
                    self._read_flips()
        return ran

    def run_to_completion(self):
        self.advance(max_chunks=len(self._plan))
        if self._flips_of is not None and self._pending:
            self._read_flips()
        return self

    def record(self) -> RunRecord:
        """Exact snapshot of the trajectory recorded so far.

        Mid-run this settles the pending flip window (one host sync — the
        caller is asking for an exact partial result); after
        :meth:`run_to_completion` it is free.  With no record points hit
        yet, ``energies`` is an empty (0,) array.
        """
        if self._flips_of is not None and self._pending:
            self._read_flips()
        obs = jnp.stack(self._out) if self._out else jnp.zeros((0,))
        return RunRecord(np.asarray(self._times, np.int64), obs,
                         self._flips_total)

    def warm(self):
        """Execute each distinct chunk length once, discarding the result.

        Chunk runners jit-compile per (length, S) signature; running every
        distinct length in the plan on the *initial* state populates those
        caches without advancing the cursor (chunk_fn is pure), so a serving
        layer can absorb cold-start compiles off the request's timed path.
        The record observable is warmed too (it may be jitted, e.g. the
        partitioned engines' energy readout).
        """
        import jax
        seen = set()
        for c in self._plan[self._i:]:
            if c in seen:
                continue
            seen.add(c)
            nsw = c * self.S
            bchunk = jnp.asarray(self._betas[:nsw]).reshape(
                (c, self.S) + self._betas.shape[1:])
            jax.block_until_ready(self._chunk_fn(self.state, bchunk, c,
                                                 self.S))
        if not self.done:
            jax.block_until_ready(self._record_fn(self.state))
        return self

    # -- checkpoint / resume ---------------------------------------------------

    _CK_FORMAT = 1

    def checkpoint(self, snapshot_fn: Optional[Callable] = None) -> dict:
        """Picklable host-side checkpoint of the cursor mid-run.

        Captures everything :meth:`restore_checkpoint` needs to continue
        the run bitwise-identically on a *fresh* cursor built from the
        same (schedule, record points, sync_every): plan position,
        recorded times/observables so far, the exact modular flip
        accounting (``_prev``/``_pending``/totals), and the engine state
        — via ``snapshot_fn`` (normally the handle's ``snapshot``, which
        pulls device arrays to owned numpy copies) or raw.  Settles the
        pending flip window first, so the checkpoint's counters are exact
        at this boundary.
        """
        if self._flips_of is not None and self._pending:
            self._read_flips()
        snap = self.state if snapshot_fn is None else snapshot_fn(self.state)
        return {
            "format": self._CK_FORMAT,
            "S": self.S,
            "total_sweeps": self.total_sweeps,
            "plan_len": len(self._plan),
            "i": self._i,
            "pos": self._pos,
            "times": list(self._times),
            "out": [np.asarray(o) for o in self._out],
            "prev": None if self._prev is None else self._prev.copy(),
            "pending": self._pending,
            "flips_vec": None if self.flips_vec is None
            else self.flips_vec.copy(),
            "flips_total": self._flips_total,
            "state": snap,
        }

    def restore_checkpoint(self, ck: dict,
                           restore_fn: Optional[Callable] = None):
        """Resume a fresh cursor from :meth:`checkpoint` output.

        The cursor must have been constructed with the same schedule,
        record points, and sync period — validated against the
        checkpoint's (S, total_sweeps, plan length) triple; a mismatch
        raises ValueError (the caller restarts from sweep 0 instead of
        silently resuming into a different trajectory).  With a matching
        plan the continuation is bitwise-identical to the uninterrupted
        run.  ``restore_fn`` (normally the handle's ``restore``) pushes
        the state snapshot back to device, re-sharded where the engine
        shards.
        """
        if ck.get("format") != self._CK_FORMAT:
            raise ValueError(f"unknown checkpoint format "
                             f"{ck.get('format')!r}")
        have = (ck["S"], ck["total_sweeps"], ck["plan_len"])
        want = (self.S, self.total_sweeps, len(self._plan))
        if have != want:
            raise ValueError(
                f"checkpoint plan mismatch: checkpoint has (S, sweeps, "
                f"chunks)={have}, cursor has {want}")
        self.state = ck["state"] if restore_fn is None \
            else restore_fn(ck["state"])
        self._i = int(ck["i"])
        self._pos = int(ck["pos"])
        self._times = [int(t) for t in ck["times"]]
        self._out = [jnp.asarray(o) for o in ck["out"]]
        self._prev = None if ck["prev"] is None \
            else np.asarray(ck["prev"]).copy()
        self._pending = int(ck["pending"])
        self.flips_vec = None if ck["flips_vec"] is None \
            else np.asarray(ck["flips_vec"]).copy()
        self._flips_total = int(ck["flips_total"])
        return self


def run_recorded_driver(*, state, schedule, record_points: Sequence[int],
                        chunk_fn: Callable,
                        record_fn: Callable,
                        sync_every: SyncSpec = 1,
                        flips_of: Optional[Callable] = None,
                        flips_per_sweep: Optional[int] = None):
    """The shared recording loop (a :class:`RecordedCursor` driven to
    completion).

    Args:
      state: engine state (any pytree).
      schedule: a ``repro.core.annealing.Schedule``.
      record_points: sweep indices at which to record (non-empty).
      chunk_fn: ``(state, betas_2d, iters, S) -> state`` runs ``iters``
        iterations of ``S`` sweeps; betas_2d has shape (iters, S).
      record_fn: ``state -> observable`` read at each record point.
      sync_every: int S (exchange every S sweeps), 'phase', or None —
        engines that don't exchange just ignore it in their chunk_fn.
      flips_of: optional ``state -> int32 array`` cumulative device flip
        counter(s); when given, the driver accumulates the exact total.
      flips_per_sweep: worst-case flips per sweep (usually N sites times
        replicas); bounds chunk sizes so int32 deltas never alias.

    Returns (state, RunRecord).
    """
    cur = RecordedCursor(
        state=state, schedule=schedule, record_points=record_points,
        chunk_fn=chunk_fn, record_fn=record_fn, sync_every=sync_every,
        flips_of=flips_of, flips_per_sweep=flips_per_sweep)
    cur.run_to_completion()
    return cur.state, cur.record()


# ---------------------------------------------------------------------------
# replica helpers
# ---------------------------------------------------------------------------

def spawn_seeds(seed: int, replicas: int) -> List[int]:
    """R independent 31-bit seeds derived from one master seed.

    Uses numpy's SeedSequence spawning, so replica streams are statistically
    independent and replica r of (seed, R) equals replica r of (seed, R')
    for r < min(R, R') — growing the replica batch never reshuffles the
    existing chains.
    """
    ss = np.random.SeedSequence(seed)
    return [int(child.generate_state(1)[0] & 0x7FFFFFFF)
            for child in ss.spawn(replicas)]


def stack_states(states: Sequence[Any]):
    """Stack per-replica state pytrees along a new leading replica axis."""
    import jax
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *states)
