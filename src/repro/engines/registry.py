"""String-keyed engine factory and the uniform adapters behind it.

``make_engine(name, ...)`` builds any of the four sampling backends from a
problem description and returns a handle satisfying the :class:`Engine`
protocol: replicated ``init_state``, driver-backed ``run_recorded`` with
(P, R) per-replica energy traces and exact flip totals, ``energy``,
``global_spins``, and ``lower_chunk``.

At replicas=1 every handle is bitwise identical to its legacy class driven
directly (same seeds, same RNG streams) — the adapters only normalize
shapes, never dynamics.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.graph import IsingGraph
from repro.core.coloring import Coloring, greedy_coloring
from repro.core.gibbs import GibbsEngine
from repro.core.dsim import PartitionedProblem, build_partitioned, DSIMEngine
from repro.core.dsim_dist import DistDSIMEngine
from repro.core.lattice import LatticeProblem, build_ea3d_lattice
from repro.core.lattice_dsim import LatticeDSIM
from repro.compat import make_mesh, auto_axes
from repro.core.snapshot import restore_state, snapshot_state
from .base import RunRecord, SyncSpec, check_lanes, check_precision

__all__ = ["ENGINE_NAMES", "make_engine", "HandleCursor"]

ENGINE_NAMES = ("gibbs", "dsim", "dsim_dist", "lattice")


def _as_2d(energies: jnp.ndarray) -> jnp.ndarray:
    """(P,) single-replica trace -> (P, 1); (P, R) passes through."""
    return energies[:, None] if energies.ndim == 1 else energies


def _as_1d(x) -> jnp.ndarray:
    return jnp.atleast_1d(jnp.asarray(x))


class HandleCursor:
    """Registry-normalized view of a :class:`RecordedCursor`.

    Same incremental surface (``advance``/``done``/``record``), but partial
    records come back in handle shape — energies always (P, R) — and the
    per-counter flip totals are reduced to one exact total per replica, so
    a packing scheduler can attribute flips to the replica slices of the
    jobs it coalesced into this one batched run.
    """

    def __init__(self, cursor, replicas: int, handle=None):
        self._c = cursor
        self.replicas = int(replicas)
        self._handle = handle

    @property
    def state(self):
        return self._c.state

    @state.setter
    def state(self, st):
        # fault injection ("corrupt" rules) swaps the live state in place
        self._c.state = st

    @property
    def fault_hook(self):
        """Per-chunk boundary hook on the underlying cursor (fault
        injection fires here, at the boundary-exchange points)."""
        return self._c.fault_hook

    @fault_hook.setter
    def fault_hook(self, fn):
        self._c.fault_hook = fn

    @property
    def chunk_timer(self):
        """Per-chunk `(sweeps, seconds)` timer on the underlying cursor
        (telemetry: obs.EtaMeter / server pump-latency attach here)."""
        return self._c.chunk_timer

    @chunk_timer.setter
    def chunk_timer(self, fn):
        self._c.chunk_timer = fn

    @property
    def done(self) -> bool:
        return self._c.done

    @property
    def sweeps_done(self) -> int:
        return self._c.sweeps_done

    @property
    def total_sweeps(self) -> int:
        return self._c.total_sweeps

    @property
    def S(self) -> int:
        """The record-point quantum the cursor actually applied (1 for
        engines without boundaries, whatever sync_every resolved to
        otherwise) — callers mirroring the quantization must use this,
        not the sync_every they passed in."""
        return self._c.S

    @property
    def points_recorded(self) -> int:
        return self._c.points_recorded

    @property
    def flips(self) -> int:
        return self._c.flips

    def advance(self, max_chunks: int = 1) -> int:
        n = self._c.advance(max_chunks)
        if self._c.done:
            self._c.run_to_completion()    # settles the pending flip window
        return n

    def record(self) -> RunRecord:
        rec = self._c.record()
        e = rec.energies
        if len(rec.times) > 0:
            e = _as_2d(e)
        return RunRecord(rec.times, e, rec.flips)

    def flips_per_replica(self) -> np.ndarray:
        """(R,) exact per-replica flip totals up to the last counter read."""
        vec = self._c.flips_vec
        if vec is None:
            return np.zeros((self.replicas,), np.int64)
        if vec.shape[0] == self.replicas:
            return vec.reshape(self.replicas, -1).sum(axis=1)
        if self.replicas == 1:
            return np.asarray([vec.sum()], np.int64)
        raise ValueError(
            f"flip counters {vec.shape} don't lead with R={self.replicas}")

    def warm(self):
        self._c.warm()
        return self

    def checkpoint(self) -> dict:
        """Picklable mid-run checkpoint (state pulled to host via the
        handle's ``snapshot`` when the cursor was built by one)."""
        fn = self._handle.snapshot if self._handle is not None else None
        return self._c.checkpoint(snapshot_fn=fn)

    def restore_checkpoint(self, ck: dict):
        """Resume from :meth:`checkpoint` output, bitwise-identically;
        the state is pushed back to device (re-sharded) via the handle's
        ``restore``.  Raises ValueError on a plan mismatch."""
        fn = self._handle.restore if self._handle is not None else None
        self._c.restore_checkpoint(ck, restore_fn=fn)
        return self


class _Handle:
    """Shared adapter plumbing over a legacy engine instance.

    The default methods cover the engines whose replicas are fixed at
    construction (dist, lattice); the batched-state engines (gibbs, dsim)
    override ``init_state`` to thread the replica count, and gibbs alone
    overrides ``_recorded`` (it has no boundaries, so no sync_every)."""

    name: str = ""
    supports_packing: bool = True     # init_state_packed(seeds) available

    def __init__(self, eng, replicas: int, n_sites: int):
        self.eng = eng
        self.replicas = int(replicas)
        self.n_sites = int(n_sites)

    @property
    def precision(self) -> str:
        """Numeric pipeline of the update rule ("f32" or "int8")."""
        return getattr(self.eng, "precision", "f32")

    @property
    def kernel_path(self):
        """Which lattice dispatch actually runs ("fused"/"per_phase");
        None for engines without the fused/per-phase split."""
        return getattr(self.eng, "kernel_path", None)

    def init_state(self, seed: int = 0):
        return self.eng.init_state(seed)

    def init_state_packed(self, seeds: Sequence[int]):
        """Batched state whose replica r is seeded by seeds[r] alone —
        the replica-packing path: R == len(seeds) must match the handle,
        and each chain's trajectory is independent of its batch-mates."""
        seeds = [int(s) for s in seeds]
        if len(seeds) != self.replicas:
            raise ValueError(
                f"need exactly R={self.replicas} seeds, got {len(seeds)}")
        return self.eng.init_state(seeds=seeds)

    def _recorded(self, state, schedule, record_points, sync_every, cursor):
        return self.eng.run_recorded_full(state, schedule, record_points,
                                          sync_every=sync_every,
                                          cursor=cursor)

    def run_recorded(self, state, schedule, record_points: Sequence[int],
                     sync_every: SyncSpec = 1):
        state, rec = self._recorded(state, schedule, record_points,
                                    sync_every, cursor=False)
        return state, RunRecord(rec.times, _as_2d(rec.energies), rec.flips)

    def start_recorded(self, state, schedule, record_points: Sequence[int],
                       sync_every: SyncSpec = 1) -> HandleCursor:
        """Begin (not run) a recorded anneal; returns a resumable
        :class:`HandleCursor` advanced chunk by chunk by the caller."""
        cur = self._recorded(state, schedule, record_points, sync_every,
                             cursor=True)
        return HandleCursor(cur, self.replicas, handle=self)

    def snapshot(self, state):
        """Host-side owned copy of an engine state (see core.snapshot)."""
        return snapshot_state(state)

    def restore(self, snap):
        """Snapshot -> live device state, re-sharded where the engine
        shards (lattice, dist)."""
        st = restore_state(snap)
        if hasattr(self.eng, "shard_state"):
            st = self.eng.shard_state(st)
        return st

    def energy(self, state) -> jnp.ndarray:
        return _as_1d(self.eng.energy(state))

    def global_spins(self, state) -> jnp.ndarray:
        return jnp.atleast_2d(self.eng.global_spins(state))

    def lower_chunk(self, iters: int = 2, S: int = 4):
        return self.eng.lower_chunk(iters=iters, S=S)

    def trace_chunk(self, iters: int = 2, S: int = 4, **kw):
        """Traced (not lowered) chunk for the static contract auditor:
        returns the jitted runner's Traced object.  Mesh engines accept
        ``sync=``/``degrade=``/``freeze=``/``has_codes=`` passthroughs and
        trace over ``AbstractMesh`` without any device backing."""
        return self.eng.trace_chunk(iters=iters, S=S, **kw)

    def __repr__(self):
        return (f"<engine {self.name!r} n={self.n_sites} "
                f"R={self.replicas}>")


class _BatchedStateHandle(_Handle):
    """gibbs/dsim: the replica axis lives on the state, not the engine."""

    def init_state(self, seed: int = 0):
        # R=1 keeps the legacy unbatched state (bitwise-stable trajectories)
        return self.eng.init_state(
            seed, replicas=None if self.replicas == 1 else self.replicas)


class _GibbsHandle(_BatchedStateHandle):
    name = "gibbs"

    def _recorded(self, state, schedule, record_points, sync_every, cursor):
        # monolithic: no boundaries, so no sync_every
        return self.eng.run_recorded_full(state, schedule, record_points,
                                          cursor=cursor)

    def energy(self, state) -> jnp.ndarray:
        return _as_1d(self.eng.direct_energy(state))

    def global_spins(self, state) -> jnp.ndarray:
        return jnp.atleast_2d(state.m)

    def _chunk_fn_args(self, iters: int, S: int):
        st = self.init_state(seed=0)
        batched = self.eng.is_batched(st)
        betas = jnp.zeros((iters * S,), jnp.float32)
        return self.eng._run_chunk(iters * S, batched), (st, betas)

    def lower_chunk(self, iters: int = 2, S: int = 4):
        run, args = self._chunk_fn_args(iters, S)
        return run.lower(*args)

    def trace_chunk(self, iters: int = 2, S: int = 4, **kw):
        run, args = self._chunk_fn_args(iters, S)
        return run.trace(*args)


class _DSIMHandle(_BatchedStateHandle):
    name = "dsim"

    def _chunk_fn_args(self, iters: int, S: int, sync: SyncSpec = None):
        st = self.init_state(seed=0)
        batched = self.eng.is_batched(st)
        sync = S if sync is None else sync
        if self.eng.precision == "int8":
            from repro.core.annealing import beta_table
            table = beta_table(np.ones((iters * S,), np.float32))
            lut = self.eng._lut_for(table)
            rows = jnp.zeros((iters, S), jnp.int32)
            return self.eng._run_chunk(iters, S, sync, batched), \
                (st, rows, lut)
        betas = jnp.zeros((iters, S), jnp.float32)
        return self.eng._run_chunk(iters, S, sync, batched), (st, betas)

    def lower_chunk(self, iters: int = 2, S: int = 4):
        run, args = self._chunk_fn_args(iters, S, S)
        return run.lower(*args)

    def trace_chunk(self, iters: int = 2, S: int = 4, sync: SyncSpec = None,
                    **kw):
        run, args = self._chunk_fn_args(iters, S, sync)
        return run.trace(*args)


class _DistHandle(_Handle):
    name = "dsim_dist"
    # the mesh engine's f32 path derives all replica RNG streams jointly
    # from one seed; the int8/bitplane paths spawn per-replica streams
    # (prefix-stable lanes) but the handle still runs one tenant per call —
    # the serving scheduler never packs dist jobs, so per-job seed lists
    # are not exposed here
    supports_packing = False

    def init_state_packed(self, seeds: Sequence[int]):
        raise NotImplementedError(
            "dsim_dist runs one tenant per batched call (no replica "
            "packing); submit with replicas=R and a single seed instead")


class _LatticeHandle(_Handle):
    name = "lattice"


def _default_coloring(g: IsingGraph, coloring: Optional[Coloring]) -> Coloring:
    if coloring is not None:
        return coloring
    return greedy_coloring(np.asarray(g.idx), np.asarray(g.w))


def _default_partitioned(graph, coloring, K, labels) -> PartitionedProblem:
    if isinstance(graph, PartitionedProblem):
        return graph
    g = graph
    col = _default_coloring(g, coloring)
    K = 4 if K is None else int(K)
    if labels is None:
        from repro.core.partition import greedy_partition
        labels = greedy_partition(np.asarray(g.idx), np.asarray(g.w), K,
                                  seed=0)
    return build_partitioned(g, col, np.asarray(labels, np.int32), K)


def make_engine(name: str, graph=None, *, coloring: Optional[Coloring] = None,
                replicas: int = 1, rng: str = "philox", fmt=None,
                K: Optional[int] = None, labels=None, mode: str = "dsim",
                mesh=None, axis: str = "data", dim_axes=None,
                lattice: Optional[LatticeProblem] = None,
                L: Optional[int] = None, seed: int = 0,
                impl: str = "auto", bitpack: bool = True,
                fused: bool = True, kernel_bx: Optional[int] = None,
                bitpack_halos: bool = True, precision: str = "f32",
                vmem_budget_bytes: Optional[int] = None,
                degrade=None):
    """Build a sampling engine by name.

      "gibbs"     — monolithic chromatic Gibbs; needs ``graph`` (+coloring).
      "dsim"      — partitioned, stacked on one device; ``graph`` (or a
                    prebuilt PartitionedProblem) + K/labels.
      "dsim_dist" — the same semantics across a device mesh; K must equal
                    the mesh axis size (defaults to a mesh over all local
                    devices).
      "lattice"   — brick-partitioned structured EA3D lattice (the fused-
                    kernel production path); pass ``lattice=`` a
                    LatticeProblem or ``L=`` to build one from ``seed``.

    ``replicas=R`` makes every handle run R independent chains per call.

    ``precision="int8"`` selects the fixed-point update pipeline (int8
    on-chip couplings, integer field accumulation, LUT-threshold accepts)
    on the dsim, dsim_dist, and lattice engines; ``precision="bitplane"``
    (lattice and dsim_dist) multi-spin-codes that pipeline — spins stored
    as uint32 bit-planes with up to 32 replica lanes per word, word-wide
    field math, per-lane RNG; lane r is bit-identical to int8 replica r.
    On dsim_dist the boundary all-gather ships the native words (4 B per
    boundary site for all 32 chains, zero pack/unpack on the collective
    path).  ``"f32"`` (default) is the floating reference the integer
    paths are statistically compared against.

    ``degrade=`` (mesh engines only) turns on the boundary-integrity
    layer with a ``core.degrade.DegradePolicy`` — None, a policy object,
    or "fail_fast" | "stale_hold[:N]" | "freeze_boundary".
    """
    if name not in ENGINE_NAMES:
        raise ValueError(f"unknown engine {name!r}; choose from {ENGINE_NAMES}")
    if replicas < 1:
        raise ValueError("replicas must be >= 1")
    check_precision(name, precision)
    check_lanes(precision, replicas)
    if degrade is not None and name not in ("dsim_dist", "lattice"):
        raise ValueError(
            f"degrade policies apply to the mesh engines "
            f"(dsim_dist, lattice), not {name!r}")

    if name == "gibbs":
        if not isinstance(graph, IsingGraph):
            raise ValueError("gibbs engine needs an IsingGraph")
        eng = GibbsEngine(graph, _default_coloring(graph, coloring),
                          rng=rng, fmt=fmt)
        return _GibbsHandle(eng, replicas, graph.n)

    if name == "dsim":
        prob = _default_partitioned(graph, coloring, K, labels)
        eng = DSIMEngine(prob, rng=rng, fmt=fmt, mode=mode,
                         precision=precision)
        return _DSIMHandle(eng, replicas, prob.n)

    if name == "dsim_dist":
        prob = _default_partitioned(graph, coloring, K, labels)
        if mesh is None:
            import jax
            ndev = len(jax.devices())
            if ndev != prob.K:
                raise ValueError(
                    f"dsim_dist needs a mesh with K={prob.K} devices along "
                    f"{axis!r} (have {ndev}); pass mesh= explicitly")
            mesh = make_mesh((prob.K,), (axis,), axis_types=auto_axes(1))
        eng = DistDSIMEngine(prob, mesh, axis=axis, rng=rng, fmt=fmt,
                             mode=mode, bitpack=bitpack, replicas=replicas,
                             precision=precision, degrade=degrade)
        return _DistHandle(eng, replicas, prob.n)

    # name == "lattice"
    prob = lattice
    if prob is None:
        if L is None:
            raise ValueError("lattice engine needs lattice= or L=")
        prob = build_ea3d_lattice(int(L), seed=seed)
    if mesh is None:
        mesh = make_mesh((1,), (axis,), axis_types=auto_axes(1))
        dim_axes = (axis, None, None) if dim_axes is None else dim_axes
    elif dim_axes is None:
        raise ValueError("pass dim_axes when passing a mesh")
    extra = {} if vmem_budget_bytes is None else \
        {"vmem_budget_bytes": vmem_budget_bytes}
    eng = LatticeDSIM(prob, mesh, dim_axes=dim_axes, fmt=fmt, impl=impl,
                      kernel_bx=kernel_bx, bitpack_halos=bitpack_halos,
                      fused=fused, replicas=replicas, precision=precision,
                      degrade=degrade, **extra)
    return _LatticeHandle(eng, replicas, prob.n_active)
