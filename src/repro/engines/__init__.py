"""Unified engine layer.

Every sampling backend — monolithic Gibbs, partitioned DSIM (stacked and
device-mesh), structured-lattice DSIM — is reachable through one protocol
(:class:`Engine`) and one string-keyed factory (:func:`make_engine`), runs
R independent replicas per call, and records trajectories through one shared
chunk-planning driver (:func:`run_recorded_driver`).

  from repro.engines import make_engine
  eng = make_engine("lattice", graph=None, L=8, seed=0, replicas=4)
  st = eng.init_state(seed=0)
  st, rec = eng.run_recorded(st, ea_schedule(512), [64, 512], sync_every=4)
  rec.energies      # (points, R) per-replica traces
  rec.flips         # exact total flips (host int, no int32 wraparound)
"""

from .base import (Engine, RecordedCursor, RunRecord, chunk_plan,
                   run_recorded_driver, spawn_seeds, stack_states)

__all__ = ["Engine", "RecordedCursor", "RunRecord", "chunk_plan",
           "run_recorded_driver", "spawn_seeds", "stack_states",
           "ENGINE_NAMES", "make_engine"]


def make_engine(name, *args, **kwargs):
    # lazy: registry imports the core engines, which import engines.base —
    # resolving at call time keeps the package import acyclic
    from .registry import make_engine as _make
    return _make(name, *args, **kwargs)


def __getattr__(name):
    if name == "ENGINE_NAMES":        # canonical copy lives in the registry
        from .registry import ENGINE_NAMES
        return ENGINE_NAMES
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
