"""Measured η = f_comm / f_pbit — the paper's timing ratio, live.

``core/commcost.py`` *predicts* the clocking bound (Eq. 2: the machine
behaves as an unpartitioned one when f_comm/f_pbit >= 2 * N_color *
C_max).  The :class:`EtaMeter` *measures* the same ratio on a running
engine from two ingredients:

* **per-chunk wall time** from the recorded-cursor chunk hook
  (``cursor.chunk_timer`` — the same per-chunk boundary where
  ``faults.py`` injects): each recorded chunk contributes ``sweeps``
  p-bit sweeps *plus* its share of boundary exchanges (``sweeps / S``
  for iteration-synced runs, ``sweeps * n_color`` for per-phase sync);
* **exchange-only time** from the mesh engines'
  ``boundary_exchange_fn()`` — a jitted closure over exactly the
  ``_exchange_block*`` collective (all-gather / halo ppermute) with the
  p-bit update elided, timed on live state via
  :meth:`EtaMeter.measure_exchange`.

From those: ``t_ex`` (s/exchange) gives ``f_comm = 1/t_ex``; the pure
update time ``t_pbit = (chunk_time - exchanges * t_ex) / sweeps`` gives
``f_pbit = 1/t_pbit`` (per-p-bit attempt frequency — every site
attempts once per sweep); measured η is their ratio, and the margin is
η divided by ``commcost.eta_threshold(n_color, c_max)`` for the active
partition — margin >= 1 means the realized exchange cadence clears the
paper's bound.

The clock is injectable for tests; all accumulation is lock-guarded so
a dashboard thread can read :meth:`report` while the pump records.
"""

from __future__ import annotations

import threading
import time
from typing import Callable, Optional, Union

from ..core import commcost

__all__ = ["EtaMeter", "exchanges_per_sweep", "dist_eta_meter"]

SyncSpec = Union[int, str, None]


def exchanges_per_sweep(sync_every: SyncSpec, n_color: int) -> float:
    """Boundary exchanges per sweep implied by the sync policy:
    one per S-sweep iteration block, or one per color phase."""
    if sync_every == "phase":
        return float(n_color)
    if sync_every is None:
        return 1.0
    S = int(sync_every)
    if S < 1:
        raise ValueError(f"sync_every must be >= 1, got {sync_every!r}")
    return 1.0 / S


class EtaMeter:
    """Accumulates p-bit-update vs boundary-exchange time per chunk."""

    def __init__(self, *, n_color: int, c_max: Optional[float] = None,
                 sync_every: SyncSpec = 1,
                 clock: Callable[[], float] = time.perf_counter):
        if n_color < 1:
            raise ValueError("n_color must be >= 1")
        self.n_color = int(n_color)
        self.c_max = None if c_max is None else float(c_max)
        self.sync_every = sync_every
        self.clock = clock
        self._x_per_sweep = exchanges_per_sweep(sync_every, n_color)
        self._lock = threading.Lock()
        self._chunk_s = 0.0
        self._sweeps = 0
        self._exchanges = 0.0
        self._chunks = 0
        self._ex_s = 0.0
        self._ex_n = 0
        self._stale = 0
        self._stale_total = 0
        self._max_staleness = 0

    # -- recording ------------------------------------------------------------------

    def record_chunk(self, sweeps: int, seconds: float,
                     exchanges: Optional[float] = None) -> None:
        """One recorded chunk: `sweeps` p-bit sweeps took `seconds` wall
        time *including* its boundary exchanges (derived from the sync
        policy unless given explicitly)."""
        if exchanges is None:
            exchanges = sweeps * self._x_per_sweep
        with self._lock:
            self._chunk_s += float(seconds)
            self._sweeps += int(sweeps)
            self._exchanges += float(exchanges)
            self._chunks += 1

    def on_chunk(self, sweeps: int, seconds: float) -> None:
        """Cursor ``chunk_timer`` signature; see RecordedCursor.advance."""
        self.record_chunk(sweeps, seconds)

    def attach(self, cursor) -> "EtaMeter":
        """Install this meter as the cursor's chunk timer (same hook
        surface the fault plan uses; enables the blocking timestamps)."""
        cursor.chunk_timer = self.on_chunk
        return self

    def record_exchange(self, seconds: float, count: int = 1) -> None:
        """Exchange-only timing: `count` boundary exchanges took
        `seconds` total (from ``measure_exchange`` or an external probe)."""
        with self._lock:
            self._ex_s += float(seconds)
            self._ex_n += int(count)

    def measure_exchange(self, fn: Callable[[], object], *,
                         reps: int = 32, warmup: int = 4) -> float:
        """Time a jitted exchange-only closure (an engine's
        ``boundary_exchange_fn()`` output bound to live state), blocking
        on the result so device time is fully attributed; records the
        measurement and returns mean seconds per exchange."""
        import jax
        for _ in range(max(warmup, 1)):
            jax.block_until_ready(fn())
        t0 = self.clock()
        for _ in range(reps):
            out = fn()
        jax.block_until_ready(out)
        dt = self.clock() - t0
        self.record_exchange(dt, reps)
        return dt / reps

    def note_stale(self, held: int, total: int,
                   max_staleness: int = 0) -> None:
        """Degraded-mode accounting from a mesh engine's health monitor:
        ``held`` of ``total`` attempted exchanges were held at last-known-
        good ghosts (cumulative; feed per-run totals once, or deltas)."""
        with self._lock:
            self._stale += int(held)
            self._stale_total += int(total)
            self._max_staleness = max(self._max_staleness,
                                      int(max_staleness))

    # -- derived quantities ----------------------------------------------------------

    @property
    def stale_exchanges(self) -> int:
        with self._lock:
            return self._stale

    @property
    def max_staleness_seen(self) -> int:
        with self._lock:
            return self._max_staleness

    @property
    def delivered_fraction(self) -> float:
        """Fraction of attempted exchanges actually ingested (1.0 until
        degraded-mode accounting reports otherwise)."""
        with self._lock:
            if not self._stale_total:
                return 1.0
            return max(0.0, 1.0 - self._stale / self._stale_total)

    @property
    def effective_eta(self) -> float:
        """Measured η scaled by the delivered-exchange fraction: held
        exchanges don't refresh the boundary, so the *effective* comm
        frequency — the quantity the paper's threshold bounds — drops in
        proportion.  Equal to ``eta`` on a healthy mesh."""
        return self.eta * self.delivered_fraction

    @property
    def t_exchange_s(self) -> float:
        """Mean seconds per boundary exchange (NaN until measured)."""
        with self._lock:
            return self._ex_s / self._ex_n if self._ex_n else float("nan")

    @property
    def t_pbit_sweep_s(self) -> float:
        """Pure p-bit update seconds per sweep: chunk time minus the
        exchange share, floored at a tenth of the raw per-sweep time so
        a mismeasured t_ex can never produce a negative rate."""
        with self._lock:
            if self._sweeps == 0:
                return float("nan")
            chunk_s, sweeps, exchanges = \
                self._chunk_s, self._sweeps, self._exchanges
            ex_s = self._ex_s / self._ex_n if self._ex_n else 0.0
        raw = chunk_s / sweeps
        t = (chunk_s - exchanges * ex_s) / sweeps
        return max(t, 0.1 * raw)

    @property
    def f_comm_hz(self) -> float:
        t = self.t_exchange_s
        return 1.0 / t if t > 0 else float("nan")

    @property
    def f_pbit_hz(self) -> float:
        t = self.t_pbit_sweep_s
        return 1.0 / t if t > 0 else float("nan")

    @property
    def eta(self) -> float:
        """Measured η = f_comm / f_pbit = t_pbit_sweep / t_exchange."""
        return self.t_pbit_sweep_s / self.t_exchange_s

    @property
    def eta_threshold(self) -> float:
        if self.c_max is None:
            return float("nan")
        return commcost.eta_threshold(self.n_color, self.c_max)

    def report(self) -> dict:
        """JSON-able summary; NaNs where a side hasn't been measured."""
        with self._lock:
            chunks, sweeps = self._chunks, self._sweeps
            chunk_s, exchanges = self._chunk_s, self._exchanges
            ex_n = self._ex_n
        eta = self.eta
        thr = self.eta_threshold
        margin = eta / thr if thr and thr == thr else float("nan")
        eff = self.effective_eta
        eff_margin = eff / thr if thr and thr == thr else float("nan")
        return {
            "measured_eta": eta,
            "eta_threshold": thr,
            "margin": margin,
            "behaves_unpartitioned": bool(margin >= 1.0)
            if margin == margin else None,
            "effective_eta": eff,
            "delivered_fraction": self.delivered_fraction,
            "stale_exchanges": self.stale_exchanges,
            "max_staleness_seen": self.max_staleness_seen,
            # degradation crossed the paper's topology threshold: the held
            # exchanges alone pushed an above-threshold mesh below Eq. 2
            "degraded_below_threshold": bool(margin >= 1.0 > eff_margin)
            if margin == margin and eff_margin == eff_margin else None,
            "f_comm_hz": self.f_comm_hz,
            "f_pbit_hz": self.f_pbit_hz,
            "t_exchange_s": self.t_exchange_s,
            "t_pbit_sweep_s": self.t_pbit_sweep_s,
            "n_color": self.n_color,
            "c_max": self.c_max,
            "sync_every": self.sync_every,
            "chunks_recorded": chunks,
            "sweeps_recorded": sweeps,
            "chunk_seconds": chunk_s,
            "exchanges_attributed": exchanges,
            "exchanges_timed": ex_n,
        }


def dist_eta_meter(engine, *, sync_every: SyncSpec = 1, topo=None,
                   clock: Callable[[], float] = time.perf_counter
                   ) -> EtaMeter:
    """EtaMeter pre-loaded with the commcost threshold of a partitioned
    mesh engine: n_color from the coloring, C_max from the engine's own
    boundary matrix on ``topo`` (default: unit-pin ring over its K
    partitions, the conservative all-links-equal reading of Eq. S.3)."""
    p = engine.p
    import numpy as np
    b = commcost.boundary_matrix(np.asarray(p.graph.idx),
                                 np.asarray(p.graph.w), p.labels, p.K)
    if topo is None:
        topo = commcost.RingTopology(k=max(p.K, 2), pins_per_link=1)
    c_max = commcost.comm_cost(b, topo).c_max
    return EtaMeter(n_color=len(p.color_slots), c_max=c_max,
                    sync_every=sync_every, clock=clock)
