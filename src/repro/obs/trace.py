"""Lightweight span tracing with an explicit device-sync boundary.

``with tracer.span("pump.chunk", job=jid) as sp: ...`` times a named
region on an injectable monotonic clock and appends the finished span to
a bounded in-memory ring (oldest evicted first).  Spans nest per thread
— the parent id is whatever span is open on the current thread — so a
wave's ``serve_wave.drain`` span owns its per-job children without any
global context plumbing.

JAX dispatch is asynchronous: a chunk launch returns before the device
finishes, so a naive ``perf_counter`` pair around ``chunk_fn`` would
attribute device time to whichever *later* span happens to block.  A
span therefore carries an explicit sync boundary: ``sp.sync(value)``
stashes a pytree (e.g. the returned state) and the tracer calls
``jax.block_until_ready`` on it *before* taking the end timestamp, so
device work lands in the span that launched it.  The blocker is lazy
and injectable — nothing here imports jax unless a span actually syncs,
keeping the module dependency-free for pure-host users and tests.
"""

from __future__ import annotations

import contextlib
import itertools
import json
import threading
import time
from collections import deque
from typing import Any, Callable, List, Optional

__all__ = ["Span", "Tracer"]


def _default_block(value: Any) -> None:
    import jax
    jax.block_until_ready(value)


class Span:
    """One timed region; exposed to the ``with`` body for attrs/sync."""

    __slots__ = ("name", "attrs", "span_id", "parent_id", "thread",
                 "t0", "t1", "_sync")

    def __init__(self, name: str, span_id: int, parent_id: Optional[int],
                 thread: str, t0: float, attrs: dict):
        self.name = name
        self.span_id = span_id
        self.parent_id = parent_id
        self.thread = thread
        self.t0 = t0
        self.t1: Optional[float] = None
        self.attrs = attrs
        self._sync: Any = None

    def set(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    def sync(self, value: Any) -> Any:
        """Register a pytree to block on before the end timestamp."""
        self._sync = value
        return value

    @property
    def duration_s(self) -> Optional[float]:
        return None if self.t1 is None else self.t1 - self.t0

    def to_dict(self) -> dict:
        return {"name": self.name, "span_id": self.span_id,
                "parent_id": self.parent_id, "thread": self.thread,
                "t0": self.t0, "t1": self.t1,
                "duration_s": self.duration_s, "attrs": dict(self.attrs)}


class Tracer:
    """Bounded span recorder with per-thread nesting.

    ``clock`` must be monotonic (default ``time.perf_counter``);
    ``block`` is called with a span's sync payload before the end stamp
    (default: lazy ``jax.block_until_ready``).
    """

    def __init__(self, clock: Callable[[], float] = time.perf_counter,
                 capacity: int = 4096,
                 block: Callable[[Any], None] = _default_block):
        self._clock = clock
        self._block = block
        self._ring: deque = deque(maxlen=int(capacity))
        self._ids = itertools.count(1)
        self._lock = threading.Lock()
        self._tls = threading.local()

    def _stack(self) -> List[Span]:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    @contextlib.contextmanager
    def span(self, name: str, sync: Any = None, **attrs):
        stack = self._stack()
        parent = stack[-1].span_id if stack else None
        with self._lock:
            sid = next(self._ids)
        sp = Span(name, sid, parent, threading.current_thread().name,
                  self._clock(), attrs)
        if sync is not None:
            sp._sync = sync
        stack.append(sp)
        try:
            yield sp
        finally:
            if sp._sync is not None:
                self._block(sp._sync)
            sp.t1 = self._clock()
            if stack and stack[-1] is sp:
                stack.pop()
            with self._lock:
                self._ring.append(sp)

    # -- readers --------------------------------------------------------------------

    def spans(self, name: Optional[str] = None) -> List[dict]:
        with self._lock:
            out = [s.to_dict() for s in self._ring]
        if name is not None:
            out = [s for s in out if s["name"] == name]
        return out

    def durations(self, name: str) -> List[float]:
        return [s["duration_s"] for s in self.spans(name)
                if s["duration_s"] is not None]

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()

    def export_jsonl(self, path: str) -> int:
        """Append every finished span as one JSON line; returns count."""
        rows = self.spans()
        with open(path, "a") as f:
            for r in rows:
                f.write(json.dumps(r, default=str) + "\n")
        return len(rows)
