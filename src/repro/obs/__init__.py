"""Runtime telemetry fabric: metrics, tracing, and measured-η timing.

Dependency-free (stdlib + the repo's own commcost model; jax is only
imported lazily at explicit sync boundaries).  Three layers:

* :mod:`.metrics` — thread-safe :class:`MetricsRegistry` of counters,
  gauges, and fixed-bucket histograms with labeled children, JSON
  snapshots, and Prometheus text exposition;
* :mod:`.trace` — bounded-ring span :class:`Tracer` with an explicit
  ``block_until_ready`` boundary for device-async attribution;
* :mod:`.timing` — :class:`EtaMeter`, which turns per-chunk wall time
  plus exchange-only collective time into measured η = f_comm/f_pbit
  and its margin against ``commcost.eta_threshold``.
"""

from .metrics import (DEFAULT_TIME_BUCKETS, Counter, Gauge, Histogram,
                      MetricsRegistry)
from .timing import EtaMeter, dist_eta_meter, exchanges_per_sweep
from .trace import Span, Tracer

__all__ = [
    "MetricsRegistry", "Counter", "Gauge", "Histogram",
    "DEFAULT_TIME_BUCKETS",
    "Tracer", "Span",
    "EtaMeter", "dist_eta_meter", "exchanges_per_sweep",
]
