"""Dependency-free metrics registry: counters, gauges, bucket histograms.

One :class:`MetricsRegistry` per process (or per server) holds metric
*families*; a family with no labels acts directly as the metric, and
``family.labels(engine="dsim")`` returns (creating on first use) the
labeled child for that label set — the per-engine / per-precision /
per-pool-key breakdown the serving layer wants.

Histograms are fixed-bucket: they store cumulative counts per upper
bound plus a running sum, never individual samples, so p50/p90/p99 are
estimated by linear interpolation inside the owning bucket — O(buckets)
memory regardless of traffic, and every observation is O(log buckets).

Everything is guarded by one registry-level lock (a counter bump is a
single ``dict``-free float add under the lock), so concurrent writers
never lose increments and a reader's :meth:`MetricsRegistry.snapshot` /
:meth:`MetricsRegistry.render_text` is a consistent cut.

Two export surfaces, both pure stdlib:

* :meth:`MetricsRegistry.snapshot` — a JSON-able dict (benchmarks embed
  it into BENCH_*.json records);
* :meth:`MetricsRegistry.render_text` — Prometheus text exposition
  (``# HELP`` / ``# TYPE`` / ``name{label="v"} value`` and the
  ``_bucket``/``_sum``/``_count`` triplet for histograms).
"""

from __future__ import annotations

import bisect
import math
import threading
from typing import Dict, Optional, Sequence, Tuple

__all__ = ["MetricsRegistry", "Counter", "Gauge", "Histogram",
           "DEFAULT_TIME_BUCKETS"]

# Latency-flavored default bounds (seconds): 10 us .. 60 s, roughly
# geometric with a 1-2.5-5 mantissa so percentile interpolation stays
# tight across six decades of chunk/queue/build times.
DEFAULT_TIME_BUCKETS: Tuple[float, ...] = tuple(
    m * 10.0 ** e
    for e in range(-5, 2)
    for m in (1.0, 2.5, 5.0)
) + (60.0,)


def _label_key(labels: Dict[str, str]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _fmt_labels(items: Tuple[Tuple[str, str], ...], extra: str = "") -> str:
    parts = [f'{k}="{_escape(v)}"' for k, v in items]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


class Counter:
    """Monotone float counter (one labeled child of a family)."""

    kind = "counter"

    def __init__(self, lock: threading.RLock):
        self._lock = lock
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError("counters only go up")
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def _render(self):
        return self.value

    def _snap(self) -> dict:
        return {"value": self.value}


class Gauge:
    """Settable instantaneous value."""

    kind = "gauge"

    def __init__(self, lock: threading.RLock):
        self._lock = lock
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    def dec(self, n: float = 1.0) -> None:
        self.inc(-n)

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def _render(self):
        return self.value

    def _snap(self) -> dict:
        return {"value": self.value}


class Histogram:
    """Fixed-bucket histogram: cumulative-count exposition, interpolated
    percentiles, no sample storage."""

    kind = "histogram"

    def __init__(self, lock: threading.RLock,
                 buckets: Sequence[float] = DEFAULT_TIME_BUCKETS):
        bs = tuple(float(b) for b in buckets)
        if not bs or any(b2 <= b1 for b1, b2 in zip(bs, bs[1:])):
            raise ValueError("buckets must be a non-empty increasing sequence")
        self._lock = lock
        self._bounds = bs                      # finite upper bounds
        self._counts = [0] * (len(bs) + 1)     # +1 for the +Inf bucket
        self._sum = 0.0
        self._count = 0

    def observe(self, v: float) -> None:
        v = float(v)
        i = bisect.bisect_left(self._bounds, v)
        with self._lock:
            self._counts[i] += 1
            self._sum += v
            self._count += 1

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    def quantile(self, q: float) -> float:
        """Interpolated quantile from bucket counts; NaN when empty.
        Observations beyond the last finite bound clamp to that bound."""
        if not 0.0 <= q <= 1.0:
            raise ValueError("q must be in [0, 1]")
        with self._lock:
            total = self._count
            counts = list(self._counts)
        if total == 0:
            return float("nan")
        rank = q * total
        cum = 0.0
        for i, c in enumerate(counts):
            prev_cum = cum
            cum += c
            if cum >= rank and c > 0:
                if i >= len(self._bounds):       # +Inf bucket
                    return self._bounds[-1]
                lo = self._bounds[i - 1] if i > 0 else 0.0
                hi = self._bounds[i]
                frac = (rank - prev_cum) / c
                return lo + (hi - lo) * min(max(frac, 0.0), 1.0)
        return self._bounds[-1]

    def _cumulative(self):
        with self._lock:
            counts = list(self._counts)
            s, n = self._sum, self._count
        cum, out = 0, []
        for bound, c in zip(self._bounds, counts):
            cum += c
            out.append((bound, cum))
        return out, n, s

    def _snap(self) -> dict:
        cum, n, s = self._cumulative()
        d = {"count": n, "sum": s,
             "buckets": [[b, c] for b, c in cum] + [["+Inf", n]]}
        if n:
            d.update(p50=self.quantile(0.50), p90=self.quantile(0.90),
                     p99=self.quantile(0.99))
        return d


class _Family:
    """A named metric family: the no-label child plus labeled children."""

    def __init__(self, name: str, help: str, ctor, lock: threading.RLock):
        self.name = name
        self.help = help
        self._ctor = ctor
        self._lock = lock
        self._children: Dict[Tuple[Tuple[str, str], ...], object] = {}
        self.kind = ctor(lock).kind  # probe; cheap

    def labels(self, **labels) -> object:
        key = _label_key(labels)
        with self._lock:
            child = self._children.get(key)
            if child is None:
                child = self._ctor(self._lock)
                self._children[key] = child
            return child

    # the family doubles as its own no-label child
    def _default(self):
        return self.labels()

    def inc(self, n: float = 1.0) -> None:
        self._default().inc(n)

    def dec(self, n: float = 1.0) -> None:
        self._default().dec(n)

    def set(self, v: float) -> None:
        self._default().set(v)

    def observe(self, v: float) -> None:
        self._default().observe(v)

    @property
    def value(self) -> float:
        return self._default().value

    @property
    def count(self) -> int:
        return self._default().count

    def quantile(self, q: float) -> float:
        return self._default().quantile(q)

    def series(self):
        with self._lock:
            return list(self._children.items())


class MetricsRegistry:
    """Thread-safe named registry of counter/gauge/histogram families."""

    def __init__(self):
        self._lock = threading.RLock()
        self._families: Dict[str, _Family] = {}

    def _family(self, name: str, help: str, ctor) -> _Family:
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = _Family(name, help, ctor, self._lock)
                self._families[name] = fam
            elif fam._ctor is not ctor and fam.kind != ctor(self._lock).kind:
                raise ValueError(
                    f"metric {name!r} already registered as {fam.kind}")
            return fam

    def counter(self, name: str, help: str = "") -> _Family:
        return self._family(name, help, Counter)

    def gauge(self, name: str, help: str = "") -> _Family:
        return self._family(name, help, Gauge)

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = DEFAULT_TIME_BUCKETS) -> _Family:
        bs = tuple(buckets)
        return self._family(name, help,
                            lambda lock: Histogram(lock, buckets=bs))

    def get(self, name: str) -> Optional[_Family]:
        with self._lock:
            return self._families.get(name)

    # -- export ---------------------------------------------------------------------

    def snapshot(self) -> dict:
        """Consistent JSON-able dump: {name: {type, help, series: [...]}}."""
        with self._lock:
            fams = list(self._families.values())
        out = {}
        for fam in fams:
            series = []
            for key, child in fam.series():
                entry = {"labels": dict(key)}
                entry.update(child._snap())
                series.append(entry)
            out[fam.name] = {"type": fam.kind, "help": fam.help,
                             "series": series}
        return out

    def render_text(self) -> str:
        """Prometheus text exposition format (version 0.0.4)."""
        with self._lock:
            fams = list(self._families.values())
        lines = []
        for fam in fams:
            if fam.help:
                lines.append(f"# HELP {fam.name} {_escape(fam.help)}")
            lines.append(f"# TYPE {fam.name} {fam.kind}")
            for key, child in fam.series():
                if fam.kind == "histogram":
                    cum, n, s = child._cumulative()
                    for bound, c in cum:
                        le = _fmt_labels(key, f'le="{bound:g}"')
                        lines.append(f"{fam.name}_bucket{le} {c}")
                    inf = _fmt_labels(key, 'le="+Inf"')
                    lines.append(f"{fam.name}_bucket{inf} {n}")
                    lines.append(f"{fam.name}_sum{_fmt_labels(key)} {s:g}")
                    lines.append(f"{fam.name}_count{_fmt_labels(key)} {n}")
                else:
                    v = child._render()
                    v_s = f"{v:g}" if math.isfinite(v) else str(v)
                    lines.append(f"{fam.name}{_fmt_labels(key)} {v_s}")
        return "\n".join(lines) + "\n"
