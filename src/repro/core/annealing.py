"""Inverse-temperature (beta) schedules.

Paper Methods: EA results use simulated annealing with beta = 0.5, 1.0, ..., 5.0;
Pegasus/Zephyr/3SAT use beta = 0.5, 0.625, ..., 10.  Schedules are staircases in
sweep index, applied identically across engines.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

__all__ = ["Schedule", "ArraySchedule", "ea_schedule", "sat_schedule",
           "geometric_schedule", "constant_schedule", "replica_beta_arrays",
           "beta_table", "beta_row_indices"]


class Schedule:
    """Sweep-indexed staircase of inverse temperatures."""

    def __init__(self, betas: np.ndarray, total_sweeps: int):
        betas = np.asarray(betas, dtype=np.float32)
        if len(betas) < 1:
            raise ValueError("need at least one beta")
        self.betas = betas
        self.total_sweeps = int(total_sweeps)
        # stage s covers sweeps [bounds[s], bounds[s+1])
        self.bounds = np.linspace(0, total_sweeps, len(betas) + 1).astype(np.int64)

    def beta_at(self, sweep) -> jnp.ndarray:
        """beta for a (traced) sweep index."""
        b = jnp.asarray(self.bounds[1:-1])
        stage = jnp.searchsorted(b, sweep, side="right")
        return jnp.asarray(self.betas)[stage]

    def beta_array(self) -> np.ndarray:
        """Dense (total_sweeps,) beta staircase — for scanned runners."""
        out = np.empty(self.total_sweeps, dtype=np.float32)
        for s, beta in enumerate(self.betas):
            out[self.bounds[s]:self.bounds[s + 1]] = beta
        return out

    def rescale(self, total_sweeps: int) -> "Schedule":
        return Schedule(self.betas, total_sweeps)


class ArraySchedule:
    """Adapter presenting a precomputed dense per-sweep array as a Schedule
    to the recording driver.

    Accepts (T,) staircases, (T, R) per-replica staircases, or (T, ...) any
    trailing layout — trailing dims ride through the driver's chunking
    untouched.  Dtype is preserved, so LUT *row-index* staircases (int32)
    flow through the same machinery as f32 betas.
    """

    def __init__(self, values):
        self.values = np.asarray(values)
        if self.values.ndim < 1 or len(self.values) < 1:
            raise ValueError("need at least one scheduled sweep")
        self.total_sweeps = int(self.values.shape[0])

    def beta_array(self) -> np.ndarray:
        return self.values


def beta_table(betas) -> np.ndarray:
    """Sorted unique beta values of a staircase (any shape) — the rows of a
    threshold LUT (:func:`repro.core.pbit.threshold_lut`)."""
    return np.unique(np.asarray(betas, np.float32))


def beta_row_indices(betas, table: np.ndarray) -> np.ndarray:
    """Map a beta staircase (any shape, e.g. the (T, R) per-replica fans of
    :func:`replica_beta_arrays`) to int32 row indices into ``table``.

    Every value must appear in ``table`` exactly — the LUT folds beta in, so
    an unlisted beta has no row to select.
    """
    betas = np.asarray(betas, np.float32)
    table = np.asarray(table, np.float32)
    rows = np.searchsorted(table, betas)
    rows = np.clip(rows, 0, len(table) - 1)
    if not (table[rows] == betas).all():
        missing = np.unique(betas[table[rows] != betas])
        raise ValueError(f"betas {missing[:5]} not in the LUT beta table")
    return rows.astype(np.int32)


def replica_beta_arrays(schedule: Schedule, replicas: int,
                        spread: float = 0.0) -> np.ndarray:
    """Per-replica beta staircases, shape (total_sweeps, R).

    ``spread=0`` replicates the schedule verbatim — R independent chains on
    identical trajectories (restart averaging).  ``spread>0`` scales replica
    r's betas by a geometric factor in [1-spread, 1+spread], so one batched
    call covers a fan of annealing rates (APT-style temperature diversity
    without exchange moves).  Feed the result to the engines' per-replica
    beta path (e.g. ``GibbsEngine.run_recorded_full(betas_R=...)``).
    """
    if replicas < 1:
        raise ValueError("replicas must be >= 1")
    if not 0.0 <= spread < 1.0:
        raise ValueError("spread must be in [0, 1)")
    base = schedule.beta_array()
    if spread == 0.0:
        return np.tile(base[:, None], (1, replicas)).astype(np.float32)
    factors = np.geomspace(1.0 - spread, 1.0 + spread, replicas)
    return (base[:, None] * factors[None, :]).astype(np.float32)


def ea_schedule(total_sweeps: int) -> Schedule:
    return Schedule(np.arange(0.5, 5.0 + 1e-6, 0.5), total_sweeps)


def sat_schedule(total_sweeps: int) -> Schedule:
    return Schedule(np.arange(0.5, 10.0 + 1e-6, 0.125), total_sweeps)


def geometric_schedule(beta0: float, beta1: float, stages: int,
                       total_sweeps: int) -> Schedule:
    return Schedule(np.geomspace(beta0, beta1, stages), total_sweeps)


def constant_schedule(beta: float, total_sweeps: int) -> Schedule:
    return Schedule(np.array([beta]), total_sweeps)
