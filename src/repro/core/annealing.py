"""Inverse-temperature (beta) schedules.

Paper Methods: EA results use simulated annealing with beta = 0.5, 1.0, ..., 5.0;
Pegasus/Zephyr/3SAT use beta = 0.5, 0.625, ..., 10.  Schedules are staircases in
sweep index, applied identically across engines.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

__all__ = ["Schedule", "ea_schedule", "sat_schedule", "geometric_schedule",
           "constant_schedule"]


class Schedule:
    """Sweep-indexed staircase of inverse temperatures."""

    def __init__(self, betas: np.ndarray, total_sweeps: int):
        betas = np.asarray(betas, dtype=np.float32)
        if len(betas) < 1:
            raise ValueError("need at least one beta")
        self.betas = betas
        self.total_sweeps = int(total_sweeps)
        # stage s covers sweeps [bounds[s], bounds[s+1])
        self.bounds = np.linspace(0, total_sweeps, len(betas) + 1).astype(np.int64)

    def beta_at(self, sweep) -> jnp.ndarray:
        """beta for a (traced) sweep index."""
        b = jnp.asarray(self.bounds[1:-1])
        stage = jnp.searchsorted(b, sweep, side="right")
        return jnp.asarray(self.betas)[stage]

    def beta_array(self) -> np.ndarray:
        """Dense (total_sweeps,) beta staircase — for scanned runners."""
        out = np.empty(self.total_sweeps, dtype=np.float32)
        for s, beta in enumerate(self.betas):
            out[self.bounds[s]:self.bounds[s + 1]] = beta
        return out

    def rescale(self, total_sweeps: int) -> "Schedule":
        return Schedule(self.betas, total_sweeps)


def ea_schedule(total_sweeps: int) -> Schedule:
    return Schedule(np.arange(0.5, 5.0 + 1e-6, 0.5), total_sweeps)


def sat_schedule(total_sweeps: int) -> Schedule:
    return Schedule(np.arange(0.5, 10.0 + 1e-6, 0.125), total_sweeps)


def geometric_schedule(beta0: float, beta1: float, stages: int,
                       total_sweeps: int) -> Schedule:
    return Schedule(np.geomspace(beta0, beta1, stages), total_sweeps)


def constant_schedule(beta: float, total_sweeps: int) -> Schedule:
    return Schedule(np.array([beta]), total_sweeps)
