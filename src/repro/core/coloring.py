"""Graph coloring for chromatic (colored) Gibbs updates.

Every p-bit in a color group has no neighbor in the same group, so the whole
group updates in one fused parallel step — the mechanism that lets the paper's
machine flip all N p-bits once per N_color phases.
"""

from __future__ import annotations

from typing import List, Tuple

import numpy as np

__all__ = ["greedy_coloring", "color_groups", "lattice3d_coloring",
           "validate_coloring", "Coloring"]


class Coloring:
    """Color assignment + per-color index groups (numpy, host side)."""

    def __init__(self, colors: np.ndarray):
        self.colors = np.asarray(colors, dtype=np.int32)
        self.n_colors = int(self.colors.max()) + 1 if len(self.colors) else 0
        self.groups: List[np.ndarray] = [
            np.nonzero(self.colors == c)[0].astype(np.int32)
            for c in range(self.n_colors)
        ]

    def __repr__(self):
        sizes = [len(g) for g in self.groups]
        return f"Coloring(n_colors={self.n_colors}, sizes={sizes})"


def greedy_coloring(idx: np.ndarray, w: np.ndarray) -> Coloring:
    """Largest-degree-first greedy coloring of an ELL graph."""
    idx = np.asarray(idx)
    w = np.asarray(w)
    n, dmax = idx.shape
    deg = (w != 0).sum(axis=1)
    order = np.argsort(-deg, kind="stable")
    colors = np.full(n, -1, dtype=np.int32)
    valid = w != 0
    for i in order:
        nbr_colors = colors[idx[i][valid[i]]]
        used = set(int(c) for c in nbr_colors if c >= 0)
        c = 0
        while c in used:
            c += 1
        colors[i] = c
    return Coloring(colors)


def lattice3d_coloring(L: int, periodic_z: bool = True) -> Coloring:
    """Proper coloring of the L^3 lattice (open x/y, optionally periodic z).

    Even L: 2-color checkerboard (paper: N_color = 2 at 100^3).
    Odd L with periodic z: the z-cycle is odd, so 3 colors are required
    (paper: N_color = 3 at 37^3).  We color by parity except on the seam plane
    z = L-1, which takes color 2; that plane's internal x/y edges are handled
    by alternating 2 with the parity colors — concretely, nodes on the seam
    with even (x+y) take color 2 and odd (x+y) keep their parity color, which
    leaves odd-(x+y) seam nodes adjacent to z=0 neighbors; those need color 2's
    complement.  The simple provably-correct construction below instead colors
    z < L-1 by parity and the seam plane by (x+y) parity shifted into {2, 0/1}:

      color(x, y, z<L-1) = (x + y + z) % 2
      color(x, y, L-1)   = 2                      if (x+y) % 2 == 0
                         = (x + y + L - 1) % 2    otherwise

    Seam internal edges: one endpooint even (color 2), other odd (parity) — ok.
    Seam-to-(L-2) edges: even seam node color 2 vs parity != 2 — ok; odd seam
    node has parity color (x+y+L-1)%2 vs neighbor (x+y+L-2)%2 — differ. ok.
    Seam-to-0 (wrap) edges: even seam node 2 vs (x+y)%2 in {0,1} — ok; odd seam
    node (x+y+L-1)%2 = (x+y)%2 xor (L-1)%2; L odd => = (x+y)%2 ... conflict!
    To avoid the conflict the wrap partner column z=0 with odd (x+y) is flipped
    to color 2 as well; z=0's own internal/z=1 edges then need checking, which
    the validation in tests performs exhaustively.
    """
    xs, ys, zs = np.meshgrid(np.arange(L), np.arange(L), np.arange(L), indexing="ij")
    par = (xs + ys + zs) % 2
    colors = par.astype(np.int32)
    if periodic_z and L % 2 == 1 and L > 2:
        xyp = (xs + ys) % 2
        seam = zs == L - 1
        base = zs == 0
        # seam plane: even (x+y) -> 2 ; odd keeps parity
        colors = np.where(seam & (xyp == 0), 2, colors)
        # wrap partners of the odd-(x+y) seam nodes: flip z=0 odd columns to 2
        colors = np.where(base & (xyp == 1), 2, colors)
    return Coloring(colors.ravel())


def validate_coloring(idx: np.ndarray, w: np.ndarray, colors: np.ndarray) -> bool:
    idx = np.asarray(idx)
    w = np.asarray(w)
    colors = np.asarray(colors)
    n, dmax = idx.shape
    src = np.repeat(np.arange(n), dmax)
    dst = idx.ravel()
    mask = w.ravel() != 0
    return bool(np.all(colors[src[mask]] != colors[dst[mask]]))


def color_groups(colors: np.ndarray) -> List[np.ndarray]:
    return Coloring(colors).groups
