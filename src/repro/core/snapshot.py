"""Host-side state snapshotting for preemptible / resumable anneals.

Engine states are jax pytrees of device arrays.  A *snapshot* is the same
pytree with every array leaf pulled to host memory as an owned numpy copy —
cheap insurance a serving layer can take between chunks: a preempted or
cancelled job's exact sampler state survives engine-pool eviction and can
be handed back to a (re)built engine later.  ``restore_state`` pushes the
leaves back to device; engines that shard their states (lattice, dist)
re-establish placement via their own ``shard_state`` — the registry handle's
``restore`` does this automatically.

Snapshots are plain numpy pytrees, so they also pickle — a durable-queue
backend can persist in-flight jobs across process restarts.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["snapshot_state", "restore_state", "snapshot_nbytes"]


def _is_array(x) -> bool:
    return isinstance(x, (jax.Array, np.ndarray, np.generic))


def snapshot_state(state):
    """Device pytree -> structurally identical host pytree (owned copies)."""
    return jax.tree.map(
        lambda x: np.array(x) if _is_array(x) else x, state)


def restore_state(snapshot):
    """Host snapshot -> device pytree (dtypes and structure preserved).

    Placement is the default device; sharded engines re-place via their
    ``shard_state`` (the registry handle's ``restore`` calls it for you).
    """
    return jax.tree.map(
        lambda x: jnp.asarray(x) if _is_array(x) else x, snapshot)


def snapshot_nbytes(snapshot) -> int:
    """Total host bytes held by a snapshot (pool / queue accounting)."""
    return sum(x.nbytes for x in jax.tree.leaves(snapshot) if _is_array(x))
