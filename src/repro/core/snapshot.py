"""Host-side state snapshotting for preemptible / resumable anneals.

Engine states are jax pytrees of device arrays.  A *snapshot* is the same
pytree with every array leaf pulled to host memory as an owned numpy copy —
cheap insurance a serving layer can take between chunks: a preempted or
cancelled job's exact sampler state survives engine-pool eviction and can
be handed back to a (re)built engine later.  ``restore_state`` pushes the
leaves back to device; engines that shard their states (lattice, dist)
re-establish placement via their own ``shard_state`` — the registry handle's
``restore`` does this automatically.

Snapshots are plain numpy pytrees, so they also pickle — the serving
layer's checkpoint spool (``repro.serve.spool``) persists in-flight jobs
across process restarts through the durable-write helpers below:
``write_snapshot_file`` is atomic (temp + ``os.replace``, fsynced), so a
kill -9 at any instant leaves either the old bytes or the new bytes on
disk, never a torn file, and ``snapshot_digest`` gives the sha1 content
address those files are named by.
"""

from __future__ import annotations

import hashlib
import os
import pickle
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["snapshot_state", "restore_state", "snapshot_nbytes",
           "snapshot_digest", "write_snapshot_file", "load_snapshot_file"]


def _is_array(x) -> bool:
    return isinstance(x, (jax.Array, np.ndarray, np.generic))


def snapshot_state(state):
    """Device pytree -> structurally identical host pytree (owned copies)."""
    return jax.tree.map(
        lambda x: np.array(x) if _is_array(x) else x, state)


def restore_state(snapshot):
    """Host snapshot -> device pytree (dtypes and structure preserved).

    Placement is the default device; sharded engines re-place via their
    ``shard_state`` (the registry handle's ``restore`` calls it for you).
    """
    return jax.tree.map(
        lambda x: jnp.asarray(x) if _is_array(x) else x, snapshot)


def snapshot_nbytes(snapshot) -> int:
    """Total host bytes held by a snapshot (pool / queue accounting)."""
    return sum(x.nbytes for x in jax.tree.leaves(snapshot) if _is_array(x))


def snapshot_digest(obj) -> str:
    """sha1 content address of a snapshot/record (bytes are hashed as-is;
    anything else is pickled first)."""
    blob = obj if isinstance(obj, bytes) else \
        pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    return hashlib.sha1(blob).hexdigest()


def write_snapshot_file(path: str, obj) -> str:
    """Durably write a snapshot/record to ``path`` (atomic, fsynced).

    The bytes land in a temp file in the same directory, are fsynced, and
    replace ``path`` in one ``os.replace`` — a crash mid-write can never
    leave a torn file at ``path``.  Returns the content digest.
    """
    blob = obj if isinstance(obj, bytes) else \
        pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    d = os.path.dirname(os.path.abspath(path)) or "."
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(blob)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            os.remove(tmp)
        except OSError:
            pass
        raise
    return snapshot_digest(blob)


def load_snapshot_file(path: str):
    """Read back a record written by :func:`write_snapshot_file`."""
    with open(path, "rb") as f:
        return pickle.load(f)
