"""1-bit packing of p-bit states.

The paper's architecture keeps every spin as literally one bit — p-bit
states on chip, 1 bit per boundary p-bit on the wire.  Two packings live
here:

* **site packing** (``pack_pm1``/``unpack_pm1``): the spins of one chain
  packed 8-per-uint8 along the site axis — the distributed backend's wire
  format for boundary all-gathers (the roofline collective term counts the
  packed N/8 bytes, faithful to the paper's traffic accounting).
* **lane packing** (``pack_lanes``/``unpack_lanes``): independent
  *replicas* of one site packed into the bit lanes of stacked uint32 word
  planes — multi-spin coding, the substrate of the bit-plane engine
  (``precision="bitplane"``).  A lane count L occupies
  ``W = ceil(L / 32)`` word planes: lane ``l`` lives at word ``l // 32``,
  bit ``l % 32`` (1 = +1, 0 = -1), and dead lanes are confined to the tail
  of the LAST word, so growing the lane count never reinterprets existing
  words.  A word-plane slice IS the packed halo payload (4 B/site *per
  word plane*), so the bit-plane path ships boundaries with zero
  pack/unpack compute at any W.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

__all__ = ["pad_to_multiple", "pack_pm1", "unpack_pm1",
           "LANE_WIDTH", "MAX_LANE_WORDS", "lane_words", "lane_shifts",
           "lane_coords", "pack_lanes", "unpack_lanes",
           "lane_permute", "lane_swap"]

# numpy constant: creating a jnp array at import time leaks a tracer if the
# first import happens inside an active trace (e.g. lazy import under jit)
_POW2 = np.asarray([1, 2, 4, 8, 16, 32, 64, 128], dtype=np.uint8)


def pad_to_multiple(n: int, k: int = 8) -> int:
    return ((n + k - 1) // k) * k


def pack_pm1(x: jnp.ndarray) -> jnp.ndarray:
    """Pack +-1 int8 spins (last dim, multiple of 8) into uint8 bitmaps."""
    *lead, n = x.shape
    if n % 8 != 0:
        raise ValueError("last dim must be a multiple of 8")
    bits = (x > 0).astype(jnp.uint8).reshape(*lead, n // 8, 8)
    return (bits * _POW2).sum(axis=-1).astype(jnp.uint8)


def unpack_pm1(p: jnp.ndarray, n: int) -> jnp.ndarray:
    """Inverse of :func:`pack_pm1`; returns +-1 int8 of last-dim size n."""
    *lead, nb = p.shape
    bits = (p[..., :, None] & _POW2) > 0
    out = jnp.where(bits, 1, -1).astype(jnp.int8).reshape(*lead, nb * 8)
    return out[..., :n]


# ---------------------------------------------------------------------------
# lane packing: 32 replicas per uint32 word plane, W stacked planes
# (multi-spin coding across words)
# ---------------------------------------------------------------------------

LANE_WIDTH = 32       # replica lanes per word — the uint32 word width
MAX_LANE_WORDS = 8    # stacked word planes the packed paths accept (W cap)


def lane_words(n_lanes: int) -> int:
    """Word planes needed for ``n_lanes`` lanes: W = ceil(L / 32)."""
    n = int(n_lanes)
    if not 1 <= n <= MAX_LANE_WORDS * LANE_WIDTH:
        raise ValueError(
            f"n_lanes must be in [1, {MAX_LANE_WORDS * LANE_WIDTH}] "
            f"({MAX_LANE_WORDS} stacked uint32 word planes), got {n}")
    return (n + LANE_WIDTH - 1) // LANE_WIDTH


def lane_shifts(n_lanes: int, ndim: int) -> jnp.ndarray:
    """(n_lanes, 1, ..., 1) uint32 shift amounts broadcasting against an
    ``ndim``-dimensional word array — the within-word lane-axis constant
    (<= 32 lanes; multi-word extraction pairs it with :func:`lane_coords`)."""
    if not 1 <= n_lanes <= LANE_WIDTH:
        raise ValueError(f"n_lanes must be in [1, {LANE_WIDTH}], "
                         f"got {n_lanes}")
    return jnp.arange(n_lanes, dtype=jnp.uint32).reshape(
        (n_lanes,) + (1,) * ndim)


def lane_coords(n_lanes: int, ndim: int):
    """Per-lane (word index, bit shift) for extraction from stacked planes.

    Returns ``(word_idx, bit_shift)``: ``word_idx`` is (L,) int32 and
    ``bit_shift`` is (L, 1, ..., 1) uint32 broadcasting against the
    ``ndim`` trailing dims of a (W, ...) word array, so lane l's bit of
    every site is ``(w[word_idx[l]] >> bit_shift[l]) & 1`` — vectorized as
    ``(w[word_idx] >> bit_shift) & 1``, shape (L, ...)."""
    L = int(n_lanes)
    lane_words(L)      # validates the range
    ids = np.arange(L)
    word_idx = jnp.asarray((ids // LANE_WIDTH).astype(np.int32))
    bit_shift = jnp.asarray((ids % LANE_WIDTH).astype(np.uint32)).reshape(
        (L,) + (1,) * ndim)
    return word_idx, bit_shift


def _scatter_bits(bits: jnp.ndarray, n_words: int) -> jnp.ndarray:
    """(L, ...) uint32 0/1 bit values -> (W, ...) packed words, dead lanes
    of the last word zero."""
    L = int(bits.shape[0])
    npad = n_words * LANE_WIDTH - L
    if npad:
        bits = jnp.concatenate(
            [bits, jnp.zeros((npad,) + bits.shape[1:], jnp.uint32)], axis=0)
    bits = bits.reshape((n_words, LANE_WIDTH) + bits.shape[1:])
    sh = jnp.arange(LANE_WIDTH, dtype=jnp.uint32).reshape(
        (1, LANE_WIDTH) + (1,) * (bits.ndim - 2))
    # lane bits are disjoint, so the sum is a bitwise OR
    return (bits << sh).sum(axis=1).astype(jnp.uint32)


def pack_lanes(x: jnp.ndarray) -> jnp.ndarray:
    """Pack +-1 spins (leading lane axis) into stacked uint32 word planes.

    ``x`` is (R, ...) with values in {-1, +1}; returns (W, ...) uint32 with
    W = ceil(R / 32), where bit b of word plane w is lane ``w*32 + b``'s
    spin (1 = +1).  Lanes >= R (the tail of the last word) are zero.
    """
    R = int(x.shape[0])
    W = lane_words(R)
    return _scatter_bits((x > 0).astype(jnp.uint32), W)


def unpack_lanes(w: jnp.ndarray, n_lanes: int) -> jnp.ndarray:
    """Inverse of :func:`pack_lanes`: (W, ...) uint32 word planes ->
    (n_lanes, ...) +-1 int8 spins."""
    L = int(n_lanes)
    W = lane_words(L)
    if int(w.shape[0]) != W:
        raise ValueError(f"{L} lanes need {W} word planes, got "
                         f"leading axis {int(w.shape[0])}")
    word_idx, sh = lane_coords(L, w.ndim - 1)
    bits = (w[word_idx] >> sh) & jnp.uint32(1)
    return jnp.where(bits != 0, 1, -1).astype(jnp.int8)


def lane_permute(w: jnp.ndarray, perm) -> jnp.ndarray:
    """Permute the replica lanes of stacked word planes: out lane i = in
    lane perm[i].

    ``w`` is (W, ...); ``perm`` is an (L,) integer array (static or
    traced), L <= W*32 — the bit gather/scatter a replica-exchange swap
    move compiles to: a swap of temperatures t and t+1 is the transposition
    perm = id[..t+1, t..], and a whole accepted-swap set is ONE permutation
    applied to every site's words.  Cross-word moves are the same gather —
    source bits are read per lane across all planes and re-scattered, so a
    permutation never costs more than L bit extracts per site regardless of
    how many word boundaries it crosses.  Lanes >= L of the output are
    cleared (the packed convention: unused lanes hold zero)."""
    perm = jnp.asarray(perm, jnp.int32)
    L = int(perm.shape[0])
    W = int(w.shape[0])
    if not 1 <= L <= W * LANE_WIDTH:
        raise ValueError(f"perm must have 1..{W * LANE_WIDTH} lanes for "
                         f"{W} word plane(s), got {L}")
    src_w = perm // LANE_WIDTH
    src_b = (perm % LANE_WIDTH).astype(jnp.uint32).reshape(
        (L,) + (1,) * (w.ndim - 1))
    bits = (w[src_w] >> src_b) & jnp.uint32(1)       # (L, ...)
    return _scatter_bits(bits, W)


def lane_swap(w: jnp.ndarray, i: int, j: int, accept=None) -> jnp.ndarray:
    """Exchange bit lanes i and j of every site (in place of a gather of
    the two configurations): d = bit_i XOR bit_j, XORed back into both
    lanes — a no-op exactly where the lanes already agree.  Works across
    word planes (lane l = word l//32, bit l%32).  ``accept`` (bool,
    broadcastable against one word plane) gates the swap; the common case
    is a scalar Metropolis verdict applied to all sites of a replica
    pair."""
    wi, bi = divmod(int(i), LANE_WIDTH)
    wj, bj = divmod(int(j), LANE_WIDTH)
    si, sj = jnp.uint32(bi), jnp.uint32(bj)
    d = ((w[wi] >> si) ^ (w[wj] >> sj)) & jnp.uint32(1)
    if accept is not None:
        d = jnp.where(accept, d, jnp.uint32(0))
    if wi == wj:
        return w.at[wi].set(w[wi] ^ ((d << si) | (d << sj)))
    w = w.at[wi].set(w[wi] ^ (d << si))
    return w.at[wj].set(w[wj] ^ (d << sj))
