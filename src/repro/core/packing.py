"""1-bit packing of p-bit states.

The paper's architecture keeps every spin as literally one bit — p-bit
states on chip, 1 bit per boundary p-bit on the wire.  Two packings live
here:

* **site packing** (``pack_pm1``/``unpack_pm1``): the spins of one chain
  packed 8-per-uint8 along the site axis — the distributed backend's wire
  format for boundary all-gathers (the roofline collective term counts the
  packed N/8 bytes, faithful to the paper's traffic accounting).
* **lane packing** (``pack_lanes``/``unpack_lanes``): 32 independent
  *replicas* of one site packed into the bit lanes of a single uint32 word
  — multi-spin coding, the substrate of the bit-plane engine
  (``precision="bitplane"``).  Bit r of a word is replica r's spin
  (1 = +1, 0 = -1); a word-plane slice IS the packed halo payload, so the
  bit-plane path ships boundaries with zero pack/unpack compute.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

__all__ = ["pad_to_multiple", "pack_pm1", "unpack_pm1",
           "LANE_WIDTH", "lane_shifts", "pack_lanes", "unpack_lanes",
           "lane_permute", "lane_swap"]

# numpy constant: creating a jnp array at import time leaks a tracer if the
# first import happens inside an active trace (e.g. lazy import under jit)
_POW2 = np.asarray([1, 2, 4, 8, 16, 32, 64, 128], dtype=np.uint8)


def pad_to_multiple(n: int, k: int = 8) -> int:
    return ((n + k - 1) // k) * k


def pack_pm1(x: jnp.ndarray) -> jnp.ndarray:
    """Pack +-1 int8 spins (last dim, multiple of 8) into uint8 bitmaps."""
    *lead, n = x.shape
    if n % 8 != 0:
        raise ValueError("last dim must be a multiple of 8")
    bits = (x > 0).astype(jnp.uint8).reshape(*lead, n // 8, 8)
    return (bits * _POW2).sum(axis=-1).astype(jnp.uint8)


def unpack_pm1(p: jnp.ndarray, n: int) -> jnp.ndarray:
    """Inverse of :func:`pack_pm1`; returns +-1 int8 of last-dim size n."""
    *lead, nb = p.shape
    bits = (p[..., :, None] & _POW2) > 0
    out = jnp.where(bits, 1, -1).astype(jnp.int8).reshape(*lead, nb * 8)
    return out[..., :n]


# ---------------------------------------------------------------------------
# lane packing: 32 replicas per uint32 word (multi-spin coding)
# ---------------------------------------------------------------------------

LANE_WIDTH = 32      # replica lanes per word — the uint32 word width


def lane_shifts(n_lanes: int, ndim: int) -> jnp.ndarray:
    """(n_lanes, 1, ..., 1) uint32 shift amounts broadcasting against an
    ``ndim``-dimensional word array — the one lane-axis constant every
    pack/unpack/per-lane-extract shares."""
    if not 1 <= n_lanes <= LANE_WIDTH:
        raise ValueError(f"n_lanes must be in [1, {LANE_WIDTH}], "
                         f"got {n_lanes}")
    return jnp.arange(n_lanes, dtype=jnp.uint32).reshape(
        (n_lanes,) + (1,) * ndim)


def pack_lanes(x: jnp.ndarray) -> jnp.ndarray:
    """Pack +-1 spins (leading lane axis, <= 32 lanes) into uint32 words.

    ``x`` is (R, ...) with values in {-1, +1}; returns (...) uint32 where
    bit r of each word is lane r's spin (1 = +1).  Lanes >= R are zero.
    """
    R = int(x.shape[0])
    sh = lane_shifts(R, x.ndim - 1)
    bits = (x > 0).astype(jnp.uint32)
    # lane bits are disjoint, so the sum is a bitwise OR
    return (bits << sh).sum(axis=0).astype(jnp.uint32)


def unpack_lanes(w: jnp.ndarray, n_lanes: int) -> jnp.ndarray:
    """Inverse of :func:`pack_lanes`: (...) uint32 words -> (n_lanes, ...)
    +-1 int8 spins."""
    sh = lane_shifts(n_lanes, w.ndim)
    bits = (w[None] >> sh) & jnp.uint32(1)
    return jnp.where(bits != 0, 1, -1).astype(jnp.int8)


def lane_permute(w: jnp.ndarray, perm) -> jnp.ndarray:
    """Permute the replica lanes of packed words: out bit i = in bit perm[i].

    ``perm`` is an (L,) integer array (static or traced), L <= 32 — the
    bit-gather/scatter a replica-exchange swap move compiles to: a swap of
    temperatures t and t+1 is the transposition perm = id[..t+1, t..], and a
    whole accepted-swap set is ONE permutation applied to every word.  Lanes
    >= L of the output are cleared (the packed convention: unused lanes hold
    zero)."""
    perm = jnp.asarray(perm, jnp.uint32)
    L = int(perm.shape[0])
    if not 1 <= L <= LANE_WIDTH:
        raise ValueError(f"perm must have 1..{LANE_WIDTH} lanes, got {L}")
    src = perm.reshape((L,) + (1,) * w.ndim)
    bits = (w[None] >> src) & jnp.uint32(1)
    return (bits << lane_shifts(L, w.ndim)).sum(axis=0).astype(jnp.uint32)


def lane_swap(w: jnp.ndarray, i: int, j: int, accept=None) -> jnp.ndarray:
    """Exchange bit lanes i and j of every word (in place of a gather of
    the two configurations): d = bit_i XOR bit_j, XORed back into both
    lanes — a no-op exactly where the lanes already agree.  ``accept``
    (bool, broadcastable against ``w``) gates the swap; the common case is
    a scalar Metropolis verdict applied to all sites of a replica pair."""
    si, sj = jnp.uint32(i), jnp.uint32(j)
    d = ((w >> si) ^ (w >> sj)) & jnp.uint32(1)
    if accept is not None:
        d = jnp.where(accept, d, jnp.uint32(0))
    return w ^ ((d << si) | (d << sj))
