"""1-bit packing of boundary p-bit states.

The paper's architecture ships exactly 1 bit per boundary p-bit.  TPU ICI
moves bytes, so the distributed backend packs +-1 spins into uint8 lanes
before the boundary all-gather; the roofline collective term then counts the
packed size (N/8 bytes), faithful to the paper's traffic accounting.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

__all__ = ["pad_to_multiple", "pack_pm1", "unpack_pm1"]

# numpy constant: creating a jnp array at import time leaks a tracer if the
# first import happens inside an active trace (e.g. lazy import under jit)
_POW2 = np.asarray([1, 2, 4, 8, 16, 32, 64, 128], dtype=np.uint8)


def pad_to_multiple(n: int, k: int = 8) -> int:
    return ((n + k - 1) // k) * k


def pack_pm1(x: jnp.ndarray) -> jnp.ndarray:
    """Pack +-1 int8 spins (last dim, multiple of 8) into uint8 bitmaps."""
    *lead, n = x.shape
    if n % 8 != 0:
        raise ValueError("last dim must be a multiple of 8")
    bits = (x > 0).astype(jnp.uint8).reshape(*lead, n // 8, 8)
    return (bits * _POW2).sum(axis=-1).astype(jnp.uint8)


def unpack_pm1(p: jnp.ndarray, n: int) -> jnp.ndarray:
    """Inverse of :func:`pack_pm1`; returns +-1 int8 of last-dim size n."""
    *lead, nb = p.shape
    bits = (p[..., :, None] & _POW2) > 0
    out = jnp.where(bits, 1, -1).astype(jnp.int8).reshape(*lead, nb * 8)
    return out[..., :n]
