"""Residual-energy analysis: power-law exponents, bootstrap CIs, collapse.

The paper fits rho_E(t) ~ t^(-kappa_f) in log-log over the decaying window
and reports 95% bootstrap confidence intervals over 10 instances x 10 runs,
identically across all platforms and timing settings (Methods).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

__all__ = ["fit_kappa", "bootstrap_ci", "bootstrap_kappa", "time_to_target",
           "eta_from_sync", "KappaFit"]


@dataclasses.dataclass(frozen=True)
class KappaFit:
    kappa: float          # decay exponent (positive = decaying)
    intercept: float      # log10 rho at t=1
    r2: float
    window: Tuple[int, int]


def fit_kappa(t: np.ndarray, rho: np.ndarray,
              window: Optional[Tuple[float, float]] = None,
              floor: float = 1e-12) -> KappaFit:
    """Least-squares log-log fit of rho ~ t^-kappa.

    ``window`` restricts to t in [lo, hi]; points with rho <= floor are
    dropped (residual energy can hit exactly zero on small instances).
    """
    t = np.asarray(t, dtype=np.float64)
    rho = np.asarray(rho, dtype=np.float64)
    m = (t > 0) & (rho > floor)
    if window is not None:
        m &= (t >= window[0]) & (t <= window[1])
    if m.sum() < 2:
        return KappaFit(kappa=np.nan, intercept=np.nan, r2=np.nan,
                        window=(0, 0))
    x, y = np.log10(t[m]), np.log10(rho[m])
    A = np.stack([x, np.ones_like(x)], axis=1)
    coef, res, *_ = np.linalg.lstsq(A, y, rcond=None)
    slope, icpt = coef
    ss_tot = ((y - y.mean()) ** 2).sum()
    ss_res = ((y - A @ coef) ** 2).sum()
    r2 = 1.0 - ss_res / ss_tot if ss_tot > 0 else 1.0
    return KappaFit(kappa=-float(slope), intercept=float(icpt), r2=float(r2),
                    window=(int(t[m].min()), int(t[m].max())))


def bootstrap_ci(samples: np.ndarray, stat=np.mean, n_boot: int = 1000,
                 alpha: float = 0.05, seed: int = 0) -> Tuple[float, float, float]:
    """(point, lo, hi) percentile bootstrap CI over the leading axis."""
    samples = np.asarray(samples)
    rng = np.random.default_rng(seed)
    point = float(stat(samples, axis=0).mean()) if samples.ndim > 1 \
        else float(stat(samples))
    n = samples.shape[0]
    stats = np.empty(n_boot)
    for b in range(n_boot):
        pick = rng.integers(0, n, size=n)
        s = stat(samples[pick], axis=0)
        stats[b] = np.mean(s)
    lo, hi = np.percentile(stats, [100 * alpha / 2, 100 * (1 - alpha / 2)])
    return point, float(lo), float(hi)


def bootstrap_kappa(t: np.ndarray, rho_runs: np.ndarray,
                    window: Optional[Tuple[float, float]] = None,
                    n_boot: int = 500, alpha: float = 0.05,
                    seed: int = 0) -> Tuple[float, float, float]:
    """Bootstrap kappa_f over runs: rho_runs (runs, T) resampled with
    replacement; kappa fit on the resampled mean trace (paper protocol:
    instances x runs pooled on the leading axis)."""
    rho_runs = np.asarray(rho_runs, dtype=np.float64)
    rng = np.random.default_rng(seed)
    point = fit_kappa(t, rho_runs.mean(axis=0), window).kappa
    n = rho_runs.shape[0]
    ks = np.empty(n_boot)
    for b in range(n_boot):
        pick = rng.integers(0, n, size=n)
        ks[b] = fit_kappa(t, rho_runs[pick].mean(axis=0), window).kappa
    lo, hi = np.percentile(ks, [100 * alpha / 2, 100 * (1 - alpha / 2)])
    return float(point), float(lo), float(hi)


def time_to_target(t: np.ndarray, rho: np.ndarray, target: float) -> float:
    """First sweep count at which the mean trace reaches rho <= target
    (log-linear interpolation; inf if never)."""
    t = np.asarray(t, dtype=np.float64)
    rho = np.asarray(rho, dtype=np.float64)
    below = np.nonzero(rho <= target)[0]
    if len(below) == 0:
        return float("inf")
    i = below[0]
    if i == 0:
        return float(t[0])
    # interpolate in log-log
    x0, x1 = np.log(t[i - 1]), np.log(t[i])
    y0, y1 = np.log(max(rho[i - 1], 1e-300)), np.log(max(rho[i], 1e-300))
    if y1 == y0:
        return float(t[i])
    f = (np.log(target) - y0) / (y1 - y0)
    return float(np.exp(x0 + f * (x1 - x0)))


def eta_from_sync(sync_every, n_color: int, c_max: float) -> float:
    """Map the simulator's staleness control to the paper's eta axis.

    One boundary exchange per S sweeps corresponds to
    f_comm/f_p-bit = 2*N_color*C_max / S evaluated at the Eq.-2 equality:
    sync_every = 1 sits exactly at the threshold eta = 2*N_color*C_max, and
    'phase' sync (refresh every color phase) sits N_color x above it.
    """
    thr = 2.0 * n_color * c_max
    if sync_every == "phase":
        return thr * n_color
    if sync_every is None:
        return 0.0
    return thr / float(sync_every)
