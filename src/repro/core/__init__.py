"""The paper's primary contribution as a composable JAX library.

All four engines are constructible by name through the unified layer in
:mod:`repro.engines` (``make_engine("gibbs" | "dsim" | "dsim_dist" |
"lattice", ...)``), run R independent replicas per call, and record through
one shared chunk-planning driver — see DESIGN.md.

Engines (all share the p-bit update rule and the chromatic schedule):
  gibbs.GibbsEngine        — monolithic reference (the paper's GPU role)
  dsim.DSIMEngine          — partitioned, shadow weights, stale 1-bit
                             boundary exchange (sync_every = the eta dial);
                             mode='cmft' gives the mean-field twin
  dsim_dist.DistDSIMEngine — the same semantics on a device mesh
                             (shard_map + bit-packed boundary all-gather)
  lattice_dsim.LatticeDSIM — brick-per-device structured lattice with the
                             fused Pallas update and 1-bit halo ppermute
                             (the 1M-p-bit production path)
  apt_icm.APTICM           — adaptive parallel tempering + isoenergetic
                             cluster moves (the G81 algorithm)

Design tools: partition / potts_partition (topology-aware), commcost
(C_max, Eq. 2 threshold), analysis (kappa fits, bootstrap CIs, eta maps).
"""
