"""Topology-aware Potts partitioning (paper Supplementary S5).

Minimizes  H_Potts(s) = sum_edges |J_ij| * kappa(|s_i - s_j|)
                        + lam * sum_q (n_q - N/K)^2          (Eq. S.7)

with the distance kernel kappa(0)=0, kappa(1)=delta_near, kappa(>=2)=delta_far
(Eq. S.8).  Minimization is batched Metropolis annealing in numpy (the
objective only runs at setup time).  Because the kernel penalizes cluster-index
distance, the resulting partition is naturally ordered along a chain: the
canonical ordering (or its reverse) already minimizes the comm cost (Fig. S3b).
"""

from __future__ import annotations

from typing import Optional

import numpy as np

__all__ = ["potts_partition", "potts_energy"]


def _kappa_table(K: int, delta_near: float, delta_far: float) -> np.ndarray:
    d = np.arange(K)
    return np.where(d == 0, 0.0, np.where(d == 1, delta_near, delta_far))


def potts_energy(idx, w, labels, K, delta_near=1.0, delta_far=8.0,
                 lam: float = 0.0) -> float:
    kap = _kappa_table(K, delta_near, delta_far)
    n, dmax = idx.shape
    nbr_l = labels[idx]                       # (N, D)
    dist = np.abs(labels[:, None] - nbr_l)
    e = 0.5 * (np.abs(w) * kap[dist]).sum()   # halve the double count
    sizes = np.bincount(labels, minlength=K).astype(np.float64)
    e += lam * ((sizes - n / K) ** 2).sum()
    return float(e)


def potts_partition(idx: np.ndarray, w: np.ndarray, K: int,
                    delta_near: float = 1.0, delta_far: float = 8.0,
                    lam: Optional[float] = None,
                    steps: int = 60, frac: float = 0.15,
                    beta0: float = 0.2, beta1: float = 4.0,
                    seed: int = 0,
                    init: Optional[np.ndarray] = None) -> np.ndarray:
    """Anneal the Potts objective; returns labels in [0, K).

    ``frac`` of nodes propose a move per step (batched Metropolis; cluster
    sizes refresh after each batch, a standard approximation).  Proposals are
    chain-local (l +- 1) half the time and uniform otherwise.
    """
    n, dmax = idx.shape
    rng = np.random.default_rng(seed)
    kap = _kappa_table(K, delta_near, delta_far)
    absw = np.abs(w)
    if lam is None:
        # a 5% imbalance of one cluster should cost about one cut edge per node
        lam = delta_near * dmax / (2.0 * (0.05 * n / K) ** 2 + 1e-9)

    labels = (np.arange(n) * K // n).astype(np.int64) if init is None \
        else np.asarray(init, dtype=np.int64).copy()
    target = n / K

    betas = np.geomspace(beta0, beta1, steps)
    quench = np.full(max(steps // 3, 10), np.inf)           # greedy finish
    for beta in np.concatenate([betas, quench]):
        sel = rng.random(n) < frac
        ids = np.nonzero(sel)[0]
        if len(ids) == 0:
            continue
        cur = labels[ids]
        step_dir = rng.integers(0, 2, size=len(ids)) * 2 - 1
        local = np.clip(cur + step_dir, 0, K - 1)
        uniform = rng.integers(0, K, size=len(ids))
        prop = np.where(rng.random(len(ids)) < 0.5, local, uniform)

        nbr_l = labels[idx[ids]]                            # (B, D)
        e_cur = (absw[ids] * kap[np.abs(cur[:, None] - nbr_l)]).sum(axis=1)
        e_prop = (absw[ids] * kap[np.abs(prop[:, None] - nbr_l)]).sum(axis=1)
        sizes = np.bincount(labels, minlength=K).astype(np.float64)
        d_bal = lam * (2.0 * (sizes[prop] - sizes[cur]) + 2.0)
        d_bal = np.where(prop == cur, 0.0, d_bal)
        dE = (e_prop - e_cur) + d_bal
        if np.isinf(beta):
            acc = dE < 0
        else:
            acc = rng.random(len(ids)) < np.exp(-beta * np.clip(dE, -50, 50))
        labels[ids[acc]] = prop[acc]
    return labels.astype(np.int32)
