"""Brick-partitioned lattice DSIM on a device mesh (the production engine).

The global lattice arrays are sharded directly over mesh axes — one brick
per device.  Inside ``shard_map`` each device runs the fused Pallas color
update on its brick; the ONLY collectives during sampling are the halo
``ppermute``s of 1-byte boundary spin planes, every ``sync_every`` sweeps
(x/y open chains, z a periodic ring — exactly the paper's boundary traffic,
with ppermute as the source-synchronous link).

This is the path the 1M-p-bit production config (`ea3d_1m`) lowers through
in the multi-pod dry-run.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .lattice import LatticeProblem
from .packing import pack_pm1, unpack_pm1, pad_to_multiple
from .pbit import FixedPoint, lfsr_init
from .gibbs import chunk_plan
from repro.kernels.ops import pbit_update_op, brick_energy_op

__all__ = ["LatticeDSIM", "LatticeState"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class LatticeState:
    m: jnp.ndarray        # (X, Y, Z) int8
    s: jnp.ndarray        # (X, Y, Z) uint32 LFSR states
    halos: tuple          # 6 halo-plane arrays (see _halo_shapes)
    sweep: jnp.ndarray
    flips: jnp.ndarray


class LatticeDSIM:
    """dim_axes: mesh axis name (or None) for each lattice dim (x, y, z).

    ``bitpack_halos``: ship halo planes as 1-bit bitmaps over the ppermute
    links (8x less wire than int8 — the paper's exact 1-bit-per-boundary-
    p-bit traffic; §Perf H8)."""

    def __init__(self, prob: LatticeProblem, mesh: Mesh,
                 dim_axes: Tuple[Optional[str], Optional[str], Optional[str]],
                 fmt: Optional[FixedPoint] = None, impl: str = "auto",
                 kernel_bx: Optional[int] = None, bitpack_halos: bool = True):
        self.p = prob
        self.mesh = mesh
        self.dim_axes = dim_axes
        self.fmt = fmt
        self.impl = impl
        self.kernel_bx = kernel_bx
        self.bitpack_halos = bitpack_halos
        X, Y, Z = prob.dims
        self.nb = tuple(1 if a is None else mesh.shape[a] for a in dim_axes)
        for d, (ext, k) in enumerate(zip(prob.dims, self.nb)):
            if ext % k != 0:
                raise ValueError(f"dim {d} extent {ext} not divisible by mesh factor {k}")
        self.brick = tuple(e // k for e, k in zip(prob.dims, self.nb))
        ax, ay, az = dim_axes
        self.spec_m = P(ax, ay, az)
        self.spec_masks = P(None, ax, ay, az)
        # halo plane specs: (nbx, Y, Z), (nbx, Y, Z), (X, nby, Z), ... each
        # sharded so every device holds exactly its (1-plane) halo slice
        self.halo_specs = (P(ax, ay, az), P(ax, ay, az),
                           P(ax, ay, az), P(ax, ay, az),
                           P(ax, ay, az), P(ax, ay, az))
        self._shard = lambda spec: NamedSharding(mesh, spec)
        self._chunk_cache = {}
        self._energy_fn = None

    # -- halo plumbing -------------------------------------------------------------

    def _halo_shapes(self):
        (X, Y, Z), (kx, ky, kz) = self.p.dims, self.nb
        return [(kx, Y, Z), (kx, Y, Z), (X, ky, Z), (X, ky, Z), (X, Y, kz), (X, Y, kz)]

    def _exchange_block(self, m):
        """Refresh the six halo planes of this brick via neighbor ppermute.

        Halo planes cross links 1-bit packed (pack -> permute -> unpack),
        exactly the paper's boundary traffic; padding spins in the packed
        tail are inert (their couplings are zero)."""
        ax, ay, az = self.dim_axes
        kx, ky, kz = self.nb

        def shift(plane, axis_name, k, up: bool, periodic: bool):
            # up=True: receive the plane of my -1 neighbor (their high face)
            if axis_name is None or k == 1:
                if periodic:
                    return plane  # my own opposite face wraps to me
                return jnp.zeros_like(plane)
            if up:
                perm = [(i, (i + 1) % k) for i in range(k)] if periodic \
                    else [(i, i + 1) for i in range(k - 1)]
            else:
                perm = [(i, (i - 1) % k) for i in range(k)] if periodic \
                    else [(i, i - 1) for i in range(1, k)]
            if not self.bitpack_halos:
                return jax.lax.ppermute(plane, axis_name, perm)
            shape = plane.shape
            n = int(np.prod(shape))
            npad = pad_to_multiple(n, 8)
            flat = jnp.pad(plane.reshape(-1), (0, npad - n),
                           constant_values=1)
            packed = pack_pm1(flat)
            packed = jax.lax.ppermute(packed, axis_name, perm)
            return unpack_pm1(packed, n).reshape(shape)

        xlo = shift(m[-1:, :, :], ax, kx, True, False)[0]
        xhi = shift(m[:1, :, :], ax, kx, False, False)[0]
        ylo = shift(m[:, -1:, :], ay, ky, True, False)[:, 0, :]
        yhi = shift(m[:, :1, :], ay, ky, False, False)[:, 0, :]
        zlo = shift(m[:, :, -1:], az, kz, True, True)[:, :, 0]
        zhi = shift(m[:, :, :1], az, kz, False, True)[:, :, 0]
        return (xlo, xhi, ylo, yhi, zlo, zhi)

    # -- block step -------------------------------------------------------------------

    def _sweep_block(self, m, s, halos, beta, masks, h, w6):
        flips = jnp.zeros((), jnp.int32)
        for c in range(self.p.n_colors):
            m2, s = pbit_update_op(m, s, beta, masks[c], h, w6, halos,
                                   fmt=self.fmt, bx=self.kernel_bx,
                                   impl=self.impl)
            flips = flips + (m2 != m).sum().astype(jnp.int32)
            m = m2
        return m, s, flips

    def _iteration_block(self, m, s, halos, betas_S, masks, h, w6):
        def body(carry, beta):
            m, s, fl = carry
            m, s, f = self._sweep_block(m, s, halos, beta, masks, h, w6)
            return (m, s, fl + f), None
        (m, s, fl), _ = jax.lax.scan(body, (m, s, jnp.zeros((), jnp.int32)),
                                     betas_S)
        halos = self._exchange_block(m)
        return m, s, halos, fl

    # -- runners ------------------------------------------------------------------------

    def _axes_all(self):
        return tuple(a for a in self.dim_axes if a is not None)

    def _run_chunk(self, iters: int, S: int):
        key = (iters, S)
        if key in self._chunk_cache:
            return self._chunk_cache[key]
        spec_m, spec_masks = self.spec_m, self.spec_masks
        hspecs = self.halo_specs
        axes_all = self._axes_all()

        def block(m, s, halos, betas, masks, h, w6):
            # halos arrive as (k?, ...) plane stacks; squeeze the brick dims
            xlo, xhi, ylo, yhi, zlo, zhi = halos
            halos = (xlo[0], xhi[0], ylo[:, 0, :], yhi[:, 0, :],
                     zlo[:, :, 0], zhi[:, :, 0])
            local = jnp.zeros((), jnp.int32)

            def it(carry, b):
                m, s, halos, fl = carry
                m, s, halos, f = self._iteration_block(m, s, halos, b,
                                                       masks, h, w6)
                return (m, s, halos, fl + f), None
            (m, s, halos, local), _ = jax.lax.scan(
                it, (m, s, halos, local), betas)
            flips = jax.lax.psum(local, axes_all) if axes_all else local
            xlo, xhi, ylo, yhi, zlo, zhi = halos
            halos = (xlo[None], xhi[None], ylo[:, None, :], yhi[:, None, :],
                     zlo[:, :, None], zhi[:, :, None])
            return m, s, halos, flips

        smapped = jax.shard_map(
            block, mesh=self.mesh,
            in_specs=(spec_m, spec_m, hspecs, P(), spec_masks, spec_m,
                      tuple(spec_m for _ in range(6))),
            out_specs=(spec_m, spec_m, hspecs, P()),
            check_vma=False,
        )

        @jax.jit
        def run(state: LatticeState, betas, masks, h, w6):
            m, s, halos, fl = smapped(state.m, state.s, state.halos, betas,
                                      masks, h, w6)
            return LatticeState(
                m=m, s=s, halos=halos,
                sweep=state.sweep + betas.shape[0] * betas.shape[1],
                flips=state.flips + fl)

        self._chunk_cache[key] = run
        return run

    def init_state(self, seed: int = 0) -> LatticeState:
        p = self.p
        X, Y, Z = p.dims
        rng = np.random.default_rng(seed)
        m = jnp.asarray(rng.choice(np.array([-1, 1], np.int8), size=(X, Y, Z)))
        s = lfsr_init(X * Y * Z, seed).reshape(X, Y, Z)
        halos = tuple(jnp.zeros(sh, jnp.int8) for sh in self._halo_shapes())
        st = LatticeState(m=m, s=s, halos=halos,
                          sweep=jnp.zeros((), jnp.int32),
                          flips=jnp.zeros((), jnp.int32))
        st = self.shard_state(st)
        # one synchronizing exchange so the first sweeps see real halos
        return self._refresh_halos(st)

    def shard_state(self, st: LatticeState) -> LatticeState:
        put = jax.device_put
        return LatticeState(
            m=put(st.m, self._shard(self.spec_m)),
            s=put(st.s, self._shard(self.spec_m)),
            halos=tuple(put(hh, self._shard(sp))
                        for hh, sp in zip(st.halos, self.halo_specs)),
            sweep=put(st.sweep, self._shard(P())),
            flips=put(st.flips, self._shard(P())))

    def _refresh_halos(self, st: LatticeState) -> LatticeState:
        def block(m):
            xlo, xhi, ylo, yhi, zlo, zhi = self._exchange_block(m)
            return (xlo[None], xhi[None], ylo[:, None, :], yhi[:, None, :],
                    zlo[:, :, None], zhi[:, :, None])
        halos = jax.jit(jax.shard_map(
            block, mesh=self.mesh, in_specs=(self.spec_m,),
            out_specs=self.halo_specs, check_vma=False))(st.m)
        return dataclasses.replace(st, halos=halos)

    def run_recorded(self, state: LatticeState, schedule,
                     record_points: Sequence[int], sync_every: int = 1):
        S = int(sync_every)
        pts = sorted(set(max(S, int(round(pp / S)) * S) for pp in record_points))
        betas = schedule.beta_array()
        if len(betas) < pts[-1]:
            raise ValueError("schedule shorter than last record point")
        out, times, pos = [], [], 0
        for c in chunk_plan([pp // S for pp in pts]):
            nsw = c * S
            bchunk = jnp.asarray(betas[pos:pos + nsw]).reshape(c, S)
            state = self._run_chunk(c, S)(state, bchunk, self.p.masks,
                                          self.p.h, self.p.w6)
            pos += nsw
            if pos in set(pts):
                out.append(self.energy(state))
                times.append(pos)
        return state, (np.asarray(times), jnp.stack(out))

    # -- observables -----------------------------------------------------------------------

    def energy(self, state: LatticeState) -> jnp.ndarray:
        """True global energy (halos refreshed for the readout)."""
        if self._energy_fn is None:
            axes_all = self._axes_all()

            def block(m, active, h, w6):
                halos = self._exchange_block(m)
                e = brick_energy_op(m, active, h, w6, halos,
                                    bx=self.kernel_bx, impl=self.impl)
                return jax.lax.psum(e, axes_all) if axes_all else e

            self._energy_fn = jax.jit(jax.shard_map(
                block, mesh=self.mesh,
                in_specs=(self.spec_m, self.spec_m, self.spec_m,
                          tuple(self.spec_m for _ in range(6))),
                out_specs=P(), check_vma=False))
        return self._energy_fn(state.m, self.p.active, self.p.h, self.p.w6)

    # -- dry-run hook -----------------------------------------------------------------------

    def lower_chunk(self, iters: int = 2, S: int = 4):
        run = self._run_chunk(iters, S)

        def sds(x, spec):
            return jax.ShapeDtypeStruct(x.shape, x.dtype,
                                        sharding=self._shard(spec))
        p = self.p
        X, Y, Z = p.dims
        st = LatticeState(
            m=jax.ShapeDtypeStruct((X, Y, Z), jnp.int8,
                                   sharding=self._shard(self.spec_m)),
            s=jax.ShapeDtypeStruct((X, Y, Z), jnp.uint32,
                                   sharding=self._shard(self.spec_m)),
            halos=tuple(jax.ShapeDtypeStruct(tuple(sh), jnp.int8,
                                             sharding=self._shard(sp))
                        for sh, sp in zip(self._halo_shapes(), self.halo_specs)),
            sweep=jax.ShapeDtypeStruct((), jnp.int32, sharding=self._shard(P())),
            flips=jax.ShapeDtypeStruct((), jnp.int32, sharding=self._shard(P())),
        )
        betas = jax.ShapeDtypeStruct((iters, S), jnp.float32,
                                     sharding=self._shard(P()))
        masks = sds(p.masks, self.spec_masks)
        h = sds(p.h, self.spec_m)
        w6 = tuple(sds(w, self.spec_m) for w in p.w6)
        return run.lower(st, betas, masks, h, w6)
