"""Brick-partitioned lattice DSIM on a device mesh (the production engine).

The global lattice arrays are sharded directly over mesh axes — one brick
per device.  Inside ``shard_map`` each device runs the fused multi-phase
Pallas sweep on its brick: one kernel launch executes the full color cycle
for up to ``sync_every`` sweeps (the per-phase kernel is kept as the
reference path, selected with ``fused=False``).  The ONLY collectives
during sampling are the halo ``ppermute``s of 1-byte boundary spin planes,
every ``sync_every`` sweeps (x/y open chains, z a periodic ring — exactly
the paper's boundary traffic, with ppermute as the source-synchronous link).

Replicas: states always carry a leading replica axis R (default 1).  The
R chains share the brick layout — the replica axis is a plain leading data
dim on every sharded array, so halo ppermutes ship all R planes in one
collective and the update kernel runs per replica (vmapped for the jnp
reference path, an in-block loop for the Pallas paths).

This is the path the 1M-p-bit production config (`ea3d_1m`) lowers through
in the multi-pod dry-run.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .lattice import LatticeProblem
from .packing import pack_pm1, unpack_pm1, pad_to_multiple
from .pbit import FixedPoint, lfsr_init
from repro.compat import shard_map
from repro.engines.base import run_recorded_driver, spawn_seeds
from repro.kernels.ops import pbit_update_op, pbit_sweep_op, brick_energy_op

__all__ = ["LatticeDSIM", "LatticeState"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class LatticeState:
    m: jnp.ndarray        # (R, X, Y, Z) int8
    s: jnp.ndarray        # (R, X, Y, Z) uint32 LFSR states
    halos: tuple          # 6 halo-plane arrays, each (R, ...) (see _halo_shapes)
    sweep: jnp.ndarray    # scalar int32
    flips: jnp.ndarray    # (R,) int32 modular odometers (exact totals are
                          # accumulated host-side by the recording driver)

    @property
    def replicas(self) -> int:
        return int(self.m.shape[0])


class LatticeDSIM:
    """dim_axes: mesh axis name (or None) for each lattice dim (x, y, z).

    ``bitpack_halos``: ship halo planes as 1-bit bitmaps over the ppermute
    links (8x less wire than int8 — the paper's exact 1-bit-per-boundary-
    p-bit traffic; §Perf H8).

    ``fused``: run the multi-phase fused sweep kernel (one launch per
    ``sync_every`` sweeps); ``fused=False`` keeps the per-phase reference
    dispatch (one launch per color phase), bitwise identical."""

    def __init__(self, prob: LatticeProblem, mesh: Mesh,
                 dim_axes: Tuple[Optional[str], Optional[str], Optional[str]],
                 fmt: Optional[FixedPoint] = None, impl: str = "auto",
                 kernel_bx: Optional[int] = None, bitpack_halos: bool = True,
                 fused: bool = True, replicas: int = 1):
        self.p = prob
        self.mesh = mesh
        self.dim_axes = dim_axes
        self.fmt = fmt
        self.impl = impl
        self.kernel_bx = kernel_bx
        self.bitpack_halos = bitpack_halos
        self.fused = fused and kernel_bx is None  # x-tiling forces per-phase
        self.replicas = int(replicas)
        if self.replicas < 1:
            raise ValueError("replicas must be >= 1")
        self.n_sites = prob.n_active
        X, Y, Z = prob.dims
        self.nb = tuple(1 if a is None else mesh.shape[a] for a in dim_axes)
        for d, (ext, k) in enumerate(zip(prob.dims, self.nb)):
            if ext % k != 0:
                raise ValueError(f"dim {d} extent {ext} not divisible by mesh factor {k}")
        self.brick = tuple(e // k for e, k in zip(prob.dims, self.nb))
        ax, ay, az = dim_axes
        self.spec_m = P(None, ax, ay, az)        # leading replica axis
        self.spec_flat = P(ax, ay, az)           # problem constants (no R)
        self.spec_masks = P(None, ax, ay, az)
        # halo plane specs: (R, nbx, Y, Z), ... each sharded so every device
        # holds exactly its (1-plane) halo slice for all replicas
        self.halo_specs = tuple(P(None, ax, ay, az) for _ in range(6))
        self._shard = lambda spec: NamedSharding(mesh, spec)
        self._chunk_cache = {}
        self._energy_fn = None

    # -- halo plumbing -------------------------------------------------------------

    def _halo_shapes(self):
        (X, Y, Z), (kx, ky, kz) = self.p.dims, self.nb
        R = self.replicas
        return [(R, kx, Y, Z), (R, kx, Y, Z), (R, X, ky, Z), (R, X, ky, Z),
                (R, X, Y, kz), (R, X, Y, kz)]

    def _exchange_block(self, m):
        """Refresh the six halo planes of this brick via neighbor ppermute.

        ``m`` is (R, bx, by, bz); all R planes of one face cross the link in
        one (1-bit packed) ppermute.  Padding spins in the packed tail are
        inert (their couplings are zero)."""
        ax, ay, az = self.dim_axes
        kx, ky, kz = self.nb

        def shift(plane, axis_name, k, up: bool, periodic: bool):
            # up=True: receive the plane of my -1 neighbor (their high face)
            if axis_name is None or k == 1:
                if periodic:
                    return plane  # my own opposite face wraps to me
                return jnp.zeros_like(plane)
            if up:
                perm = [(i, (i + 1) % k) for i in range(k)] if periodic \
                    else [(i, i + 1) for i in range(k - 1)]
            else:
                perm = [(i, (i - 1) % k) for i in range(k)] if periodic \
                    else [(i, i - 1) for i in range(1, k)]
            if not self.bitpack_halos:
                return jax.lax.ppermute(plane, axis_name, perm)
            shape = plane.shape
            n = int(np.prod(shape))
            npad = pad_to_multiple(n, 8)
            flat = jnp.pad(plane.reshape(-1), (0, npad - n),
                           constant_values=1)
            packed = pack_pm1(flat)
            packed = jax.lax.ppermute(packed, axis_name, perm)
            return unpack_pm1(packed, n).reshape(shape)

        xlo = shift(m[:, -1:, :, :], ax, kx, True, False)[:, 0]
        xhi = shift(m[:, :1, :, :], ax, kx, False, False)[:, 0]
        ylo = shift(m[:, :, -1:, :], ay, ky, True, False)[:, :, 0, :]
        yhi = shift(m[:, :, :1, :], ay, ky, False, False)[:, :, 0, :]
        zlo = shift(m[:, :, :, -1:], az, kz, True, True)[:, :, :, 0]
        zhi = shift(m[:, :, :, :1], az, kz, False, True)[:, :, :, 0]
        return (xlo, xhi, ylo, yhi, zlo, zhi)

    # -- block step -------------------------------------------------------------------

    def _sweep_phases_block(self, m, s, halos, betas_S, masks, h, w6):
        """S sweeps of one replica's brick via per-phase dispatch (the
        reference path).  m/s (bx, by, bz)."""
        def body(carry, beta):
            m, s, fl = carry
            for c in range(self.p.n_colors):
                m2, s = pbit_update_op(m, s, beta, masks[c], h, w6, halos,
                                       fmt=self.fmt, bx=self.kernel_bx,
                                       impl=self.impl)
                fl = fl + (m2 != m).sum().astype(jnp.int32)
                m = m2
            return (m, s, fl), None
        (m, s, fl), _ = jax.lax.scan(
            body, (m, s, jnp.zeros((), jnp.int32)), betas_S)
        return m, s, fl

    def _sweep_fused_block(self, m, s, halos, betas_S, masks, h, w6):
        """S sweeps of one replica's brick in ONE fused kernel launch."""
        return pbit_sweep_op(m, s, betas_S, masks, h, w6, halos,
                             fmt=self.fmt, impl=self.impl)

    def _iteration_block(self, m, s, halos, betas_S, masks, h, w6):
        """S sweeps for all R replicas, then one halo exchange.

        m/s (R, bx, by, bz); halos 6 x (R, plane)."""
        one = self._sweep_fused_block if self.fused else \
            self._sweep_phases_block
        from repro.kernels.ops import default_impl
        resolved = self.impl if self.impl != "auto" else default_impl()
        if resolved == "ref":
            # pure-jnp path: replicas vmap cleanly
            m, s, fl = jax.vmap(
                lambda mr, sr, hr: one(mr, sr, hr, betas_S, masks, h, w6),
                in_axes=(0, 0, 0))(m, s, halos)
        else:
            # pallas paths: unrolled replica loop (no pallas_call batching)
            outs = [one(m[r], s[r], jax.tree.map(lambda x: x[r], halos),
                        betas_S, masks, h, w6)
                    for r in range(m.shape[0])]
            m = jnp.stack([o[0] for o in outs])
            s = jnp.stack([o[1] for o in outs])
            fl = jnp.stack([o[2] for o in outs])
        halos = self._exchange_block(m)
        return m, s, halos, fl

    # -- runners ------------------------------------------------------------------------

    def _axes_all(self):
        return tuple(a for a in self.dim_axes if a is not None)

    def _run_chunk(self, iters: int, S: int):
        key = (iters, S)
        if key in self._chunk_cache:
            return self._chunk_cache[key]
        spec_m, spec_masks = self.spec_m, self.spec_masks
        spec_flat = self.spec_flat
        hspecs = self.halo_specs
        axes_all = self._axes_all()
        R = self.replicas

        def block(m, s, halos, betas, masks, h, w6):
            # halos arrive as (R, k?, ...) plane stacks; squeeze the brick dims
            xlo, xhi, ylo, yhi, zlo, zhi = halos
            halos = (xlo[:, 0], xhi[:, 0], ylo[:, :, 0, :], yhi[:, :, 0, :],
                     zlo[:, :, :, 0], zhi[:, :, :, 0])
            local = jnp.zeros((R,), jnp.int32)

            def it(carry, b):
                m, s, halos, fl = carry
                m, s, halos, f = self._iteration_block(m, s, halos, b,
                                                       masks, h, w6)
                return (m, s, halos, fl + f), None
            (m, s, halos, local), _ = jax.lax.scan(
                it, (m, s, halos, local), betas)
            flips = jax.lax.psum(local, axes_all) if axes_all else local
            xlo, xhi, ylo, yhi, zlo, zhi = halos
            halos = (xlo[:, None], xhi[:, None],
                     ylo[:, :, None, :], yhi[:, :, None, :],
                     zlo[:, :, :, None], zhi[:, :, :, None])
            return m, s, halos, flips

        smapped = shard_map(
            block, mesh=self.mesh,
            in_specs=(spec_m, spec_m, hspecs, P(), spec_masks, spec_flat,
                      tuple(spec_flat for _ in range(6))),
            out_specs=(spec_m, spec_m, hspecs, P()),
            check_vma=False,
        )

        @jax.jit
        def run(state: LatticeState, betas, masks, h, w6):
            m, s, halos, fl = smapped(state.m, state.s, state.halos, betas,
                                      masks, h, w6)
            return LatticeState(
                m=m, s=s, halos=halos,
                sweep=state.sweep + betas.shape[0] * betas.shape[1],
                flips=state.flips + fl)

        self._chunk_cache[key] = run
        return run

    def init_state(self, seed: int = 0) -> LatticeState:
        p = self.p
        X, Y, Z = p.dims
        R = self.replicas
        seeds = [seed] if R == 1 else spawn_seeds(seed, R)
        ms, ss = [], []
        for sd in seeds:
            rng = np.random.default_rng(sd)
            ms.append(rng.choice(np.array([-1, 1], np.int8), size=(X, Y, Z)))
            ss.append(np.asarray(lfsr_init(X * Y * Z, sd)).reshape(X, Y, Z))
        m = jnp.asarray(np.stack(ms))
        s = jnp.asarray(np.stack(ss))
        halos = tuple(jnp.zeros(sh, jnp.int8) for sh in self._halo_shapes())
        st = LatticeState(m=m, s=s, halos=halos,
                          sweep=jnp.zeros((), jnp.int32),
                          flips=jnp.zeros((R,), jnp.int32))
        st = self.shard_state(st)
        # one synchronizing exchange so the first sweeps see real halos
        return self._refresh_halos(st)

    def shard_state(self, st: LatticeState) -> LatticeState:
        put = jax.device_put
        return LatticeState(
            m=put(st.m, self._shard(self.spec_m)),
            s=put(st.s, self._shard(self.spec_m)),
            halos=tuple(put(hh, self._shard(sp))
                        for hh, sp in zip(st.halos, self.halo_specs)),
            sweep=put(st.sweep, self._shard(P())),
            flips=put(st.flips, self._shard(P())))

    def _refresh_halos(self, st: LatticeState) -> LatticeState:
        def block(m):
            xlo, xhi, ylo, yhi, zlo, zhi = self._exchange_block(m)
            return (xlo[:, None], xhi[:, None],
                    ylo[:, :, None, :], yhi[:, :, None, :],
                    zlo[:, :, :, None], zhi[:, :, :, None])
        halos = jax.jit(shard_map(
            block, mesh=self.mesh, in_specs=(self.spec_m,),
            out_specs=self.halo_specs, check_vma=False))(st.m)
        return dataclasses.replace(st, halos=halos)

    def run_recorded_full(self, state: LatticeState, schedule,
                          record_points: Sequence[int], sync_every: int = 1):
        """Shared-driver runner; returns (state, RunRecord)."""
        def chunk(st, betas2d, iters, S):
            return self._run_chunk(iters, S)(st, betas2d, self.p.masks,
                                             self.p.h, self.p.w6)

        return run_recorded_driver(
            state=state, schedule=schedule, record_points=record_points,
            chunk_fn=chunk, record_fn=self.energy, sync_every=int(sync_every),
            flips_of=lambda st: st.flips,
            flips_per_sweep=self.n_sites * self.replicas)

    def run_recorded(self, state: LatticeState, schedule,
                     record_points: Sequence[int], sync_every: int = 1):
        """Run to each record point; returns (state, (times, energies))."""
        return self.run_recorded_full(state, schedule, record_points,
                                      sync_every=sync_every)

    # -- observables -----------------------------------------------------------------------

    def energy(self, state: LatticeState) -> jnp.ndarray:
        """True global energies, one per replica (halos refreshed for the
        readout).  Returns (R,) — or a scalar when replicas == 1, keeping
        the legacy contract."""
        if self._energy_fn is None:
            axes_all = self._axes_all()

            def block(m, active, h, w6):
                halos = self._exchange_block(m)
                e = jax.vmap(
                    lambda mr, hr: brick_energy_op(mr, active, h, w6, hr,
                                                   bx=self.kernel_bx,
                                                   impl=self.impl),
                    in_axes=(0, 0))(m, halos)
                return jax.lax.psum(e, axes_all) if axes_all else e

            self._energy_fn = jax.jit(shard_map(
                block, mesh=self.mesh,
                in_specs=(self.spec_m, self.spec_flat, self.spec_flat,
                          tuple(self.spec_flat for _ in range(6))),
                out_specs=P(), check_vma=False))
        e = self._energy_fn(state.m, self.p.active, self.p.h, self.p.w6)
        return e[0] if self.replicas == 1 else e

    def global_spins(self, state: LatticeState) -> jnp.ndarray:
        """(R, L^3) active-site spins in ea3d node order ((L,L,L) row-major);
        squeezed to (L^3,) when replicas == 1."""
        L = self.p.L
        spins = state.m[:, :L, :L, :L].reshape(self.replicas, L ** 3)
        return spins[0] if self.replicas == 1 else spins

    # -- dry-run hook -----------------------------------------------------------------------

    def lower_chunk(self, iters: int = 2, S: int = 4):
        run = self._run_chunk(iters, S)

        def sds(x, spec):
            return jax.ShapeDtypeStruct(x.shape, x.dtype,
                                        sharding=self._shard(spec))
        p = self.p
        X, Y, Z = p.dims
        R = self.replicas
        st = LatticeState(
            m=jax.ShapeDtypeStruct((R, X, Y, Z), jnp.int8,
                                   sharding=self._shard(self.spec_m)),
            s=jax.ShapeDtypeStruct((R, X, Y, Z), jnp.uint32,
                                   sharding=self._shard(self.spec_m)),
            halos=tuple(jax.ShapeDtypeStruct(tuple(sh), jnp.int8,
                                             sharding=self._shard(sp))
                        for sh, sp in zip(self._halo_shapes(), self.halo_specs)),
            sweep=jax.ShapeDtypeStruct((), jnp.int32, sharding=self._shard(P())),
            flips=jax.ShapeDtypeStruct((R,), jnp.int32,
                                       sharding=self._shard(P())),
        )
        betas = jax.ShapeDtypeStruct((iters, S), jnp.float32,
                                     sharding=self._shard(P()))
        masks = sds(p.masks, self.spec_masks)
        h = sds(p.h, self.spec_flat)
        w6 = tuple(sds(w, self.spec_flat) for w in p.w6)
        return run.lower(st, betas, masks, h, w6)
