"""Brick-partitioned lattice DSIM on a device mesh (the production engine).

The global lattice arrays are sharded directly over mesh axes — one brick
per device.  Inside ``shard_map`` each device runs the fused multi-phase
Pallas sweep on its brick: one kernel launch executes the full color cycle
for up to ``sync_every`` sweeps (the per-phase kernel is kept as the
reference path, selected with ``fused=False``).  The ONLY collectives
during sampling are the halo ``ppermute``s of 1-byte boundary spin planes,
every ``sync_every`` sweeps (x/y open chains, z a periodic ring — exactly
the paper's boundary traffic, with ppermute as the source-synchronous link).

Replicas: states always carry a leading replica axis R (default 1).  The
R chains share the brick layout — the replica axis is a plain leading data
dim on every sharded array, so halo ppermutes ship all R planes in one
collective and the update kernel runs per replica (vmapped for the jnp
reference path, an in-block loop for the Pallas paths).

This is the path the 1M-p-bit production config (`ea3d_1m`) lowers through
in the multi-pod dry-run.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .annealing import ArraySchedule, beta_row_indices, beta_table
from .degrade import (DegradePolicy, MeshHealthMonitor, health_init,
                      wire_checksum)
from .lattice import LatticeProblem
from .packing import (LANE_WIDTH, pack_lanes, pack_pm1, unpack_lanes,
                      unpack_pm1, pad_to_multiple)
from .pbit import (FixedPoint, LUT_SELECT_MAX_WIDTH, bitplane_planes,
                   field_bound, flips_publish, lfsr_init,
                   quantize_couplings, threshold_lut_cached)
from repro.compat import shard_map
from repro.engines.base import (RecordedCursor, check_lanes,
                                run_recorded_driver, spawn_seeds)
from repro.kernels.ops import (pbit_update_op, pbit_sweep_op,
                               pbit_update_int_op, pbit_sweep_int_op,
                               pbit_bitplane_sweep_op, brick_energy_op)

__all__ = ["LatticeDSIM", "LatticeState", "BitplaneLatticeState",
           "fused_working_set_bytes", "fused_brick_ceiling"]

# Per-site VMEM bytes of the single-block fused kernel (DESIGN.md
# "VMEM working-set math"): f32 path = 7 f32 coupling arrays + in/out spins
# (int8) + in/out LFSR (u32) + n_colors parity masks; int8 path = the same
# with the couplings at 1 B/site.  The bitplane path packs 32 replica lanes
# per uint32 word: in/out spin words (8 B/site for ALL lanes), in/out
# per-lane LFSR columns (8 B/site/lane), 12 sign/nonzero planes + base
# (52 B/site) and uint32 color masks (4 B/site each) — per *lane* it is the
# densest layout of the three.  Halo planes and the threshold LUT are
# O(B^(2/3)) / O(1) and added separately.
_PER_SITE_BYTES = {"f32": 38, "int8": 17}
_LUT_ROWS_NOMINAL = 32          # staircase entries assumed for init-time sizing
DEFAULT_VMEM_BUDGET = 16 << 20  # 16 MiB/core, the TPU VMEM working budget


def _per_site_bytes(precision: str, n_colors: int,
                    lanes: int = LANE_WIDTH) -> int:
    if precision == "bitplane":
        # W stacked word planes: in/out spin words and color masks scale
        # with W, the 12+1 sign/nonzero/base planes are shared by every
        # word, LFSR columns are per lane.  W=1 reduces to the PR 4 value
        # 60 + 4 n_c + 8 lanes.
        words = max(1, (int(lanes) + LANE_WIDTH - 1) // LANE_WIDTH)
        return 52 + 8 * words + 4 * n_colors * words + 8 * lanes
    return _PER_SITE_BYTES[precision] + n_colors


def fused_working_set_bytes(brick: Tuple[int, int, int], n_colors: int,
                            precision: str = "f32",
                            lut_width: Optional[int] = None,
                            lanes: int = LANE_WIDTH) -> int:
    """VMEM bytes the single-block fused sweep kernel needs for one brick.

    ``lanes`` only matters on the bitplane path (per-lane LFSR columns)."""
    bx, by, bz = brick
    sites = bx * by * bz
    per_site = _per_site_bytes(precision, n_colors, lanes)
    halo_unit = 4 if precision == "bitplane" else 1   # word vs int8 planes
    halo = 2 * halo_unit * (by * bz + bx * bz + bx * by)
    lut = 0
    if precision in ("int8", "bitplane"):
        lut = 4 * _LUT_ROWS_NOMINAL * (lut_width if lut_width else 1)
    return per_site * sites + halo + lut


def fused_brick_ceiling(n_colors: int, precision: str = "f32",
                        budget: int = DEFAULT_VMEM_BUDGET,
                        lanes: int = LANE_WIDTH) -> int:
    """Largest cubic brick extent whose fused working set fits ``budget``."""
    per_site = _per_site_bytes(precision, n_colors, lanes)
    side = int(round((budget / per_site) ** (1.0 / 3.0)))
    while fused_working_set_bytes((side, side, side), n_colors,
                                  precision, lanes=lanes) > budget:
        side -= 1
    return side


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class LatticeState:
    m: jnp.ndarray        # (R, X, Y, Z) int8
    s: jnp.ndarray        # (R, X, Y, Z) uint32 LFSR states
    halos: tuple          # 6 halo-plane arrays, each (R, ...) (see _halo_shapes)
    sweep: jnp.ndarray    # scalar int32
    flips: jnp.ndarray    # (R,) int32 modular odometers (exact totals are
                          # accumulated host-side by the recording driver)

    @property
    def replicas(self) -> int:
        return int(self.m.shape[0])


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class BitplaneLatticeState:
    """Multi-spin-coded state: replicas live in the bit lanes of ``m``.

    ``m`` stacks W = ceil(R/32) word planes — bit b of plane w is replica
    lane ``w*32 + b``'s spin (1 = +1); only the LFSR columns and flip
    odometers keep an explicit replica axis — each lane owns its own RNG
    stream (the lane-independence contract)."""

    m: jnp.ndarray        # (W, X, Y, Z) uint32 stacked spin word planes
    s: jnp.ndarray        # (R, X, Y, Z) uint32 per-lane LFSR states
    halos: tuple          # 6 packed word halo planes, leading W axis
    sweep: jnp.ndarray    # scalar int32
    flips: jnp.ndarray    # (R,) int32 per-lane modular odometers

    @property
    def replicas(self) -> int:
        return int(self.s.shape[0])


class LatticeDSIM:
    """dim_axes: mesh axis name (or None) for each lattice dim (x, y, z).

    ``bitpack_halos``: ship halo planes as 1-bit bitmaps over the ppermute
    links (8x less wire than int8 — the paper's exact 1-bit-per-boundary-
    p-bit traffic; §Perf H8).

    ``fused``: run the multi-phase fused sweep kernel (one launch per
    ``sync_every`` sweeps); ``fused=False`` keeps the per-phase reference
    dispatch (one launch per color phase), bitwise identical.  A fused
    request whose brick working set exceeds ``vmem_budget_bytes`` falls
    back to the per-phase path with a one-time warning; the decision is
    exposed as ``kernel_path`` / ``fallback_reason``.

    ``precision``: "f32" (reference) or "int8" — the hardware's fixed-point
    pipeline: couplings quantized to int8 at init with one per-problem
    scale, int32 field accumulation, and tanh + float compare replaced by a
    uint32 compare against a per-(beta, field) threshold LUT; annealing
    staircases become LUT row indices.  ``fmt`` folds into the LUT."""

    def __init__(self, prob: LatticeProblem, mesh: Mesh,
                 dim_axes: Tuple[Optional[str], Optional[str], Optional[str]],
                 fmt: Optional[FixedPoint] = None, impl: str = "auto",
                 kernel_bx: Optional[int] = None, bitpack_halos: bool = True,
                 fused: bool = True, replicas: int = 1,
                 precision: str = "f32",
                 vmem_budget_bytes: int = DEFAULT_VMEM_BUDGET,
                 degrade: Union[None, str, DegradePolicy] = None):
        if precision not in ("f32", "int8", "bitplane"):
            raise ValueError(f"unknown precision {precision!r}")
        self.p = prob
        self.mesh = mesh
        self.dim_axes = dim_axes
        self.fmt = fmt
        self.impl = impl
        self.kernel_bx = kernel_bx
        self.bitpack_halos = bitpack_halos
        self.precision = precision
        self.vmem_budget_bytes = int(vmem_budget_bytes)
        self.replicas = int(replicas)
        # the shared lane-cap guard; W word planes for the packed path
        self.words = check_lanes(precision, self.replicas)
        if precision == "bitplane" and kernel_bx is not None:
            raise ValueError("kernel_bx (per-phase x-tiling) is not "
                             "available on the bitplane path")
        self.n_sites = prob.n_active
        X, Y, Z = prob.dims
        if precision in ("int8", "bitplane"):
            self.h_q, self.w6_q, self.q_scale = quantize_couplings(prob.h,
                                                                   prob.w6)
            self.f_max = field_bound(self.h_q, self.w6_q)
            # Mosaic cannot gather per element from VMEM: the Pallas int
            # kernels rely on lut_accept's rank-count form, which caps the
            # row width.  The bitplane path uses the rank count on EVERY
            # impl (the word math has no per-lane gather form at all).
            # Fail at init with a clear message, not at first lowering.
            from repro.kernels.ops import default_impl
            resolved = impl if impl != "auto" else default_impl()
            if (resolved == "pallas" or precision == "bitplane") and \
                    2 * self.f_max + 1 > LUT_SELECT_MAX_WIDTH:
                raise ValueError(
                    f"precision={precision!r} needs a threshold LUT row of "
                    f"<= {LUT_SELECT_MAX_WIDTH} entries (gather-free "
                    f"rank-count accept); this problem quantizes to "
                    f"f_max={self.f_max} (width {2 * self.f_max + 1}).  "
                    f"Use impl='ref' with precision='int8' or coarser "
                    f"couplings.")
        else:
            self.h_q = self.w6_q = None
            self.q_scale, self.f_max = 1.0, 0
        if precision == "bitplane":
            # sign-plane quantization (validates couplings land on +-1/0)
            # + lane-masked uint32 color masks: lanes >= R never update.
            # Dead lanes live only in the LAST word plane, so every other
            # plane carries the full 32-lane mask.
            self.signs6_w, self.nz6_w, self.base_w, _ = bitplane_planes(
                self.h_q, self.w6_q)
            W = self.words
            last = self.replicas - (W - 1) * LANE_WIDTH
            lane_masks = np.full((W,), 0xFFFFFFFF, np.uint64)
            lane_masks[-1] = (np.uint64(1) << np.uint64(last)) - \
                np.uint64(1) if last < LANE_WIDTH else np.uint64(0xFFFFFFFF)
            self.lane_masks = lane_masks.astype(np.uint32)
            mk = np.asarray(prob.masks)          # (n_colors, X, Y, Z)
            self.masks_w = jnp.asarray(
                np.where(mk[:, None] != 0,
                         self.lane_masks[None, :, None, None, None],
                         0).astype(np.uint32))   # (n_colors, W, X, Y, Z)
        self._lut_cache = {}
        self.nb = tuple(1 if a is None else mesh.shape[a] for a in dim_axes)
        for d, (ext, k) in enumerate(zip(prob.dims, self.nb)):
            if ext % k != 0:
                raise ValueError(f"dim {d} extent {ext} not divisible by mesh factor {k}")
        self.brick = tuple(e // k for e, k in zip(prob.dims, self.nb))
        # fused-vs-per-phase decision (DESIGN.md "VMEM working-set math"):
        # x-tiling forces per-phase; so does a brick working set beyond the
        # VMEM budget — the fallback is no longer silent.  The bitplane
        # path has exactly one dispatch (the single-block word kernel), so
        # an over-budget brick warns but cannot fall back.
        self.fused_requested = bool(fused)
        # bitplane launches are per WORD PLANE, so the kernel working set
        # is bounded by one full word (<= 32 lanes) regardless of W
        launch_lanes = min(self.replicas, LANE_WIDTH) \
            if precision == "bitplane" else self.replicas
        self.fused_working_set = fused_working_set_bytes(
            self.brick, prob.n_colors, precision,
            lut_width=2 * self.f_max + 1, lanes=launch_lanes)
        self.fallback_reason = None
        fused = bool(fused)
        if precision == "bitplane":
            if self.fused_working_set > self.vmem_budget_bytes:
                ceiling = fused_brick_ceiling(prob.n_colors, precision,
                                              self.vmem_budget_bytes,
                                              lanes=launch_lanes)
                warnings.warn(
                    f"bitplane sweep kernel needs "
                    f"{self.fused_working_set:,} B of VMEM for brick "
                    f"{self.brick} ({launch_lanes} lanes per word-plane "
                    f"launch, {prob.n_colors} colors) — over the "
                    f"{self.vmem_budget_bytes:,} B budget and the word "
                    f"kernel has no per-phase fallback; shard to bricks of "
                    f"~{ceiling}^3 or fewer sites for TPU.",
                    RuntimeWarning, stacklevel=2)
            self.fused = True
        else:
            if fused and kernel_bx is not None:
                fused, self.fallback_reason = False, "kernel_bx"
            if fused and self.fused_working_set > self.vmem_budget_bytes:
                ceiling = fused_brick_ceiling(prob.n_colors, precision,
                                              self.vmem_budget_bytes)
                fused, self.fallback_reason = False, "vmem"
                warnings.warn(
                    f"lattice fused sweep kernel needs "
                    f"{self.fused_working_set:,} B of VMEM for brick "
                    f"{self.brick} ({precision}, {prob.n_colors} colors) — "
                    f"over the {self.vmem_budget_bytes:,} B budget; falling "
                    f"back to the per-phase x-tiled dispatch.  Fused "
                    f"single-block ceiling at this budget is ~{ceiling}^3 "
                    f"per brick.",
                    RuntimeWarning, stacklevel=2)
            self.fused = fused
        ax, ay, az = dim_axes
        self.spec_m = P(None, ax, ay, az)        # leading replica axis
        self.spec_flat = P(ax, ay, az)           # problem constants (no R)
        self.spec_masks = P(None, ax, ay, az)
        # bitplane color masks carry (n_colors, W, X, Y, Z) — two
        # replicated leading axes ahead of the lattice dims
        self.spec_masks_w = P(None, None, ax, ay, az)
        # halo plane specs: (R, nbx, Y, Z), ... each sharded so every device
        # holds exactly its (1-plane) halo slice for all replicas.  On the
        # bitplane path the replica axis lives inside the words and the
        # leading axis is the W stacked word planes.
        self.halo_specs = tuple(P(None, ax, ay, az) for _ in range(6))
        self._shard = lambda spec: NamedSharding(mesh, spec)
        self._chunk_cache = {}
        self._energy_fn = None
        self._exchange_only_fn = None
        # degraded-mode fabric: the six faces are the boundary sources
        self.degrade = DegradePolicy.parse(degrade)
        self.health = MeshHealthMonitor(self.degrade, 6, kind="faces") \
            if self.degrade is not None else None
        self._fault_codes = None

    @property
    def kernel_path(self) -> str:
        """Which update dispatch actually runs: "fused", "per_phase", or
        "bitplane" (the multi-spin-coded word kernel)."""
        if self.precision == "bitplane":
            return "bitplane"
        return "fused" if self.fused else "per_phase"

    def _lut_for(self, table: np.ndarray) -> jnp.ndarray:
        """Threshold LUT for a beta table (cached; fmt folded in)."""
        return threshold_lut_cached(self._lut_cache, table, self.q_scale,
                                    self.f_max, fmt=self.fmt)

    # -- halo plumbing -------------------------------------------------------------

    def _halo_shapes(self):
        (X, Y, Z), (kx, ky, kz) = self.p.dims, self.nb
        if self.precision == "bitplane":
            # word planes: 32 replica lanes ride inside each uint32, and
            # the W stacked planes lead (one face payload per word plane)
            W = self.words
            return [(W, kx, Y, Z), (W, kx, Y, Z), (W, X, ky, Z),
                    (W, X, ky, Z), (W, X, Y, kz), (W, X, Y, kz)]
        R = self.replicas
        return [(R, kx, Y, Z), (R, kx, Y, Z), (R, X, ky, Z), (R, X, ky, Z),
                (R, X, Y, kz), (R, X, Y, kz)]

    def _halo_shift(self, plane, axis_name, k, up: bool, periodic: bool,
                    bitpack_pm1: bool):
        """Ship one face plane to the neighbor along a mesh axis.

        up=True: receive the plane of my -1 neighbor (their high face).
        The ONE place the neighbor permutation tables and the k==1
        wrap/zero boundary rule live — both the unpacked (optionally
        pm1-bitpacked) and the bitplane word exchanges route through it.
        """
        if axis_name is None or k == 1:
            if periodic:
                return plane  # my own opposite face wraps to me
            return jnp.zeros_like(plane)
        if up:
            perm = [(i, (i + 1) % k) for i in range(k)] if periodic \
                else [(i, i + 1) for i in range(k - 1)]
        else:
            perm = [(i, (i - 1) % k) for i in range(k)] if periodic \
                else [(i, i - 1) for i in range(1, k)]
        if not bitpack_pm1:
            return jax.lax.ppermute(plane, axis_name, perm)
        shape = plane.shape
        n = int(np.prod(shape))
        npad = pad_to_multiple(n, 8)
        flat = jnp.pad(plane.reshape(-1), (0, npad - n),
                       constant_values=1)
        packed = pack_pm1(flat)
        packed = jax.lax.ppermute(packed, axis_name, perm)
        return unpack_pm1(packed, n).reshape(shape)

    def _exchange_block(self, m):
        """Refresh the six halo planes of this brick via neighbor ppermute.

        ``m`` is (R, bx, by, bz); all R planes of one face cross the link in
        one (1-bit packed) ppermute.  Padding spins in the packed tail are
        inert (their couplings are zero)."""
        ax, ay, az = self.dim_axes
        kx, ky, kz = self.nb

        def shift(plane, axis_name, k, up, periodic):
            return self._halo_shift(plane, axis_name, k, up, periodic,
                                    bitpack_pm1=self.bitpack_halos)

        xlo = shift(m[:, -1:, :, :], ax, kx, True, False)[:, 0]
        xhi = shift(m[:, :1, :, :], ax, kx, False, False)[:, 0]
        ylo = shift(m[:, :, -1:, :], ay, ky, True, False)[:, :, 0, :]
        yhi = shift(m[:, :, :1, :], ay, ky, False, False)[:, :, 0, :]
        zlo = shift(m[:, :, :, -1:], az, kz, True, True)[:, :, :, 0]
        zhi = shift(m[:, :, :, :1], az, kz, False, True)[:, :, :, 0]
        return (xlo, xhi, ylo, yhi, zlo, zhi)

    def _exchange_block_w(self, mw):
        """Bitplane halo exchange: the face slices of the word brick ARE
        the packed wire format — 1 bit per boundary p-bit per lane, exactly
        the paper's traffic, with zero pack/unpack compute.  ``mw`` is
        (W, bx, by, bz): one ppermute ships all W word planes of a face
        (4 B/site *per word plane*); at R=32 the payload is 8x smaller
        than the int8 path's unpacked planes.  Boundary words of
        zero-coupling directions are inert (the nonzero masks zero them)."""
        ax, ay, az = self.dim_axes
        kx, ky, kz = self.nb

        def shift(plane, axis_name, k, up, periodic):
            return self._halo_shift(plane, axis_name, k, up, periodic,
                                    bitpack_pm1=False)

        xlo = shift(mw[:, -1:, :, :], ax, kx, True, False)[:, 0]
        xhi = shift(mw[:, :1, :, :], ax, kx, False, False)[:, 0]
        ylo = shift(mw[:, :, -1:, :], ay, ky, True, False)[:, :, 0, :]
        yhi = shift(mw[:, :, :1, :], ay, ky, False, False)[:, :, 0, :]
        zlo = shift(mw[:, :, :, -1:], az, kz, True, True)[:, :, :, 0]
        zhi = shift(mw[:, :, :, :1], az, kz, False, True)[:, :, :, 0]
        return (xlo, xhi, ylo, yhi, zlo, zhi)

    def boundary_exchange_fn(self):
        """Jitted exchange-ONLY closure: the six-face halo ppermute of
        ``_exchange_block`` / ``_exchange_block_w`` with the sweep elided.
        ``fn(state) -> halos`` on live state — the measured-η probe
        (``obs.EtaMeter.measure_exchange`` times it to get t_exchange)."""
        cached = getattr(self, "_exchange_only_fn", None)
        if cached is not None:
            return cached
        word = self.precision == "bitplane"

        def block(m):
            xlo, xhi, ylo, yhi, zlo, zhi = (
                self._exchange_block_w(m) if word
                else self._exchange_block(m))
            return (xlo[:, None], xhi[:, None],
                    ylo[:, :, None, :], yhi[:, :, None, :],
                    zlo[:, :, :, None], zhi[:, :, :, None])

        smapped = shard_map(block, mesh=self.mesh,
                            in_specs=(self.spec_m,),
                            out_specs=self.halo_specs, check_vma=False)
        run = jax.jit(lambda m: smapped(m))
        fn = lambda state: run(state.m)  # noqa: E731
        self._exchange_only_fn = fn
        return fn

    # -- block step -------------------------------------------------------------------

    def _sweep_phases_block(self, m, s, halos, betas_S, masks, h, w6):
        """S sweeps of one replica's brick via per-phase dispatch (the
        reference path).  m/s (bx, by, bz)."""
        def body(carry, beta):
            m, s, fl = carry
            for c in range(self.p.n_colors):
                m2, s = pbit_update_op(m, s, beta, masks[c], h, w6, halos,
                                       fmt=self.fmt, bx=self.kernel_bx,
                                       impl=self.impl)
                fl = fl + (m2 != m).sum().astype(jnp.int32)
                m = m2
            return (m, s, fl), None
        (m, s, fl), _ = jax.lax.scan(
            body, (m, s, jnp.zeros((), jnp.int32)), betas_S)
        return m, s, fl

    def _sweep_phases_int_block(self, m, s, halos, rows_S, masks, h_q, w6_q,
                                lut):
        """Integer-path per-phase dispatch: LUT row indices replace betas."""
        def body(carry, row):
            m, s, fl = carry
            for c in range(self.p.n_colors):
                m2, s = pbit_update_int_op(m, s, row, masks[c], h_q, w6_q,
                                           halos, lut, bx=self.kernel_bx,
                                           impl=self.impl)
                fl = fl + (m2 != m).sum().astype(jnp.int32)
                m = m2
            return (m, s, fl), None
        (m, s, fl), _ = jax.lax.scan(
            body, (m, s, jnp.zeros((), jnp.int32)), rows_S)
        return m, s, fl

    def _one_replica_sweeps(self, masks, h, w6, lut):
        """(m, s, halos, sched_S) -> (m, s, flips) for one replica's brick:
        fused or per-phase, float betas or integer LUT rows."""
        if self.precision == "int8":
            if self.fused:
                return lambda mr, sr, hr, ps: pbit_sweep_int_op(
                    mr, sr, ps, masks, h, w6, hr, lut, impl=self.impl)
            return lambda mr, sr, hr, ps: self._sweep_phases_int_block(
                mr, sr, hr, ps, masks, h, w6, lut)
        if self.fused:
            return lambda mr, sr, hr, ps: pbit_sweep_op(
                mr, sr, ps, masks, h, w6, hr, fmt=self.fmt, impl=self.impl)
        return lambda mr, sr, hr, ps: self._sweep_phases_block(
            mr, sr, hr, ps, masks, h, w6)

    def _sweep_block(self, m, s, halos, sched_S, masks, h, w6, lut=None):
        """S sweeps for all R replicas against fixed halos (no exchange).

        m/s (R, bx, by, bz); halos 6 x (R, plane).  ``sched_S`` is the
        per-sweep schedule — (S,) shared or (S, R) per-replica; f32 betas on
        the float path, int32 LUT row indices on the integer path."""
        one = self._one_replica_sweeps(masks, h, w6, lut)
        per_rep = sched_S.ndim == 2
        from repro.kernels.ops import default_impl
        resolved = self.impl if self.impl != "auto" else default_impl()
        if resolved == "ref":
            # pure-jnp path: replicas vmap cleanly
            m, s, fl = jax.vmap(one, in_axes=(0, 0, 0, 1 if per_rep else
                                              None))(m, s, halos, sched_S)
        else:
            # pallas paths: unrolled replica loop (no pallas_call batching)
            outs = [one(m[r], s[r], jax.tree.map(lambda x: x[r], halos),
                        sched_S[:, r] if per_rep else sched_S)
                    for r in range(m.shape[0])]
            m = jnp.stack([o[0] for o in outs])
            s = jnp.stack([o[1] for o in outs])
            fl = jnp.stack([o[2] for o in outs])
        return m, s, fl

    def _iteration_block(self, m, s, halos, sched_S, masks, h, w6, lut=None):
        """S sweeps for all R replicas, then one halo exchange."""
        m, s, fl = self._sweep_block(m, s, halos, sched_S, masks, h, w6, lut)
        halos = self._exchange_block(m)
        return m, s, halos, fl

    # -- degraded-mode exchange (integrity header + stale hold) ----------------------

    def _exchange_block_checked(self, m, halos_prev, health, codes,
                                freeze: bool):
        """The six-face halo exchange with the integrity layer on.

        Every wired face ships a ``[seq, checksum]`` uint32 header over the
        same ppermute link as its payload; the receiver re-checksums what
        actually arrived and compares.  A face that fails (or a ``codes``
        fault injected at this — the engine — boundary) is *held* at its
        last-known-good plane from ``halos_prev``; its staleness counter
        advances.  Open-chain edge devices have no inbound neighbor on
        their outer faces: those planes are legitimate zeros, not wire
        traffic, and are always accepted (``has_src`` mask).  Unwired axes
        (k == 1) never touch a link and are always accepted.  With zero
        faults the selected halos are bitwise the unchecked exchange's.

        ``halos_prev`` and the returned halos are the *squeezed* planes (as
        carried inside the chunk scan).  Health carries per-face staleness;
        per-device divergence (edges) is pmax-reduced at chunk end.
        """
        seq, stale, frozen, det, held, maxst = health
        ax, ay, az = self.dim_axes
        kx, ky, kz = self.nb
        word = self.precision == "bitplane"
        bitpack = (not word) and self.bitpack_halos

        faces = [
            (m[:, -1:, :, :], ax, kx, True, False),    # xlo <- -x neighbor
            (m[:, :1, :, :], ax, kx, False, False),    # xhi <- +x neighbor
            (m[:, :, -1:, :], ay, ky, True, False),
            (m[:, :, :1, :], ay, ky, False, False),
            (m[:, :, :, -1:], az, kz, True, True),     # z is a periodic ring
            (m[:, :, :, :1], az, kz, False, True),
        ]
        squeeze = (lambda p: p[:, 0], lambda p: p[:, 0],
                   lambda p: p[:, :, 0, :], lambda p: p[:, :, 0, :],
                   lambda p: p[:, :, :, 0], lambda p: p[:, :, :, 0])

        corrupt = drop = None
        if codes is not None:
            total = jnp.uint32(codes.shape[0])
            code = jnp.where(
                seq < total,
                codes[jnp.clip(seq, 0, total - 1).astype(jnp.int32)], 0)
            corrupt, drop = code == 2, code == 1

        new_faces, oks = [], []
        for i, (plane, axis_name, k, up, periodic) in enumerate(faces):
            wired = axis_name is not None and k > 1
            if not wired:
                # no link: periodic k==1 wraps my own face, open k==1 is a
                # fixed zero boundary — nothing to verify
                rx = self._halo_shift(plane, axis_name, k, up, periodic,
                                      bitpack_pm1=False)
                new_faces.append(squeeze[i](rx))
                oks.append(jnp.bool_(True))
                continue
            rx = self._halo_shift(plane, axis_name, k, up, periodic,
                                  bitpack_pm1=bitpack)
            hdr = jnp.stack([seq, wire_checksum(plane)])
            hdr_rx = self._halo_shift(hdr, axis_name, k, up, periodic,
                                      bitpack_pm1=False)
            idx = jax.lax.axis_index(axis_name)
            has_src = jnp.bool_(True) if periodic else \
                (idx > 0 if up else idx < k - 1)
            if corrupt is not None:
                hit, dr = corrupt & has_src, drop & has_src
                flip = jnp.uint32(1) if word else jnp.int8(2)
                rx = jnp.where(hit, rx ^ flip, rx)
                rx = jnp.where(dr, jnp.zeros_like(rx), rx)
                hdr_rx = jnp.where(dr, jnp.full_like(hdr_rx, 0xFFFFFFFF),
                                   hdr_rx)
            ok = (wire_checksum(rx) == hdr_rx[1]) & (hdr_rx[0] == seq)
            oks.append(ok | ~has_src)
            new_faces.append(squeeze[i](rx))

        ok6 = jnp.stack(oks)
        if freeze:
            frozen = jnp.maximum(frozen, (~ok6).any().astype(jnp.int32))
            bad6 = (~ok6) | (frozen > 0)
        else:
            bad6 = ~ok6
        det = det + (~ok6).any().astype(jnp.int32)
        held = held + bad6.any().astype(jnp.int32)
        stale = jnp.where(bad6, stale + 1, 0)
        maxst = jnp.maximum(maxst, stale.max())
        seq = seq + jnp.uint32(1)
        halos = tuple(jnp.where(bad6[i], halos_prev[i], new_faces[i])
                      for i in range(6))
        return halos, (seq, stale, frozen, det, held, maxst)

    @staticmethod
    def _health_pmax(health, axes_all):
        """Replicate the health carry: per-device staleness diverges at
        open-chain edges (outer faces carry no wire), so keep the mesh-wide
        worst case.  seq advances identically everywhere."""
        if not axes_all:
            return health
        seq, stale, frozen, det, held, maxst = health
        pm = lambda x: jax.lax.pmax(x, axes_all)  # noqa: E731
        return (seq, pm(stale), pm(frozen), pm(det), pm(held), pm(maxst))

    # -- runners ------------------------------------------------------------------------

    def _axes_all(self):
        return tuple(a for a in self.dim_axes if a is not None)

    def _run_chunk(self, iters: int, S: int, per_rep: bool = False):
        key = (iters, S, per_rep)
        if key in self._chunk_cache:
            return self._chunk_cache[key]
        spec_m, spec_masks = self.spec_m, self.spec_masks
        spec_flat = self.spec_flat
        hspecs = self.halo_specs
        axes_all = self._axes_all()
        R = self.replicas
        int8 = self.precision == "int8"

        def block(m, s, halos, sched, masks, h, w6, lut):
            # halos arrive as (R, k?, ...) plane stacks; squeeze the brick dims
            xlo, xhi, ylo, yhi, zlo, zhi = halos
            halos = (xlo[:, 0], xhi[:, 0], ylo[:, :, 0, :], yhi[:, :, 0, :],
                     zlo[:, :, :, 0], zhi[:, :, :, 0])
            local = jnp.zeros((R,), jnp.uint32)

            def it(carry, b):
                m, s, halos, fl = carry
                m, s, halos, f = self._iteration_block(m, s, halos, b,
                                                       masks, h, w6, lut)
                return (m, s, halos, fl + f.astype(jnp.uint32)), None
            (m, s, halos, local), _ = jax.lax.scan(
                it, (m, s, halos, local), sched)
            flips = jax.lax.psum(local, axes_all) if axes_all else local
            xlo, xhi, ylo, yhi, zlo, zhi = halos
            halos = (xlo[:, None], xhi[:, None],
                     ylo[:, :, None, :], yhi[:, :, None, :],
                     zlo[:, :, :, None], zhi[:, :, :, None])
            return m, s, halos, flips

        # identical construction for both precisions — the integer path just
        # appends the (replicated) threshold LUT as a trailing operand
        fn = block if int8 else (
            lambda m, s, halos, sched, masks, h, w6:
                block(m, s, halos, sched, masks, h, w6, None))
        lut_spec = ((P(),) if int8 else ())
        smapped = shard_map(
            fn, mesh=self.mesh,
            in_specs=(spec_m, spec_m, hspecs, P(), spec_masks, spec_flat,
                      tuple(spec_flat for _ in range(6))) + lut_spec,
            out_specs=(spec_m, spec_m, hspecs, P()),
            check_vma=False,
        )

        @jax.jit
        def run(state: LatticeState, sched, masks, h, w6, *lut_opt):
            m, s, halos, fl = smapped(state.m, state.s, state.halos,
                                      sched, masks, h, w6, *lut_opt)
            return LatticeState(
                m=m, s=s, halos=halos,
                sweep=state.sweep + sched.shape[0] * sched.shape[1],
                flips=flips_publish(state.flips, fl))

        self._chunk_cache[key] = run
        return run

    def _run_chunk_bp(self, iters: int, S: int):
        """Bitplane chunk runner: words sweep via the multi-spin-coded op;
        halos are native word planes (the 1-bit wire format).  Shared-vs-
        per-lane schedules need no flag here: the sweep op dispatches on
        the trailing dims of the rows operand (jit retraces per shape)."""
        key = ("bp", iters, S)
        if key in self._chunk_cache:
            return self._chunk_cache[key]
        spec_w, spec_m = self.spec_m, self.spec_m
        spec_masks, spec_flat = self.spec_masks_w, self.spec_flat
        hspecs = self.halo_specs
        axes_all = self._axes_all()
        R = self.replicas

        def block(mw, s, halos, sched, masks_w, signs, nz, base, lut):
            # halos arrive as (W, k?, ...) plane stacks; squeeze brick dims
            xlo, xhi, ylo, yhi, zlo, zhi = halos
            halos = (xlo[:, 0], xhi[:, 0], ylo[:, :, 0, :], yhi[:, :, 0, :],
                     zlo[:, :, :, 0], zhi[:, :, :, 0])
            local = jnp.zeros((R,), jnp.uint32)

            def it(carry, b):
                mw, s, halos, fl = carry
                mw, s, f = pbit_bitplane_sweep_op(
                    mw, s, b, masks_w, signs, nz, base, halos, lut,
                    impl=self.impl)
                halos = self._exchange_block_w(mw)
                return (mw, s, halos, fl + f.astype(jnp.uint32)), None
            (mw, s, halos, local), _ = jax.lax.scan(
                it, (mw, s, halos, local), sched)
            flips = jax.lax.psum(local, axes_all) if axes_all else local
            xlo, xhi, ylo, yhi, zlo, zhi = halos
            halos = (xlo[:, None], xhi[:, None],
                     ylo[:, :, None, :], yhi[:, :, None, :],
                     zlo[:, :, :, None], zhi[:, :, :, None])
            return mw, s, halos, flips

        smapped = shard_map(
            block, mesh=self.mesh,
            in_specs=(spec_w, spec_m, hspecs, P(), spec_masks,
                      tuple(spec_flat for _ in range(6)),
                      tuple(spec_flat for _ in range(6)), spec_flat, P()),
            out_specs=(spec_w, spec_m, hspecs, P()),
            check_vma=False,
        )

        @jax.jit
        def run(state: BitplaneLatticeState, sched, masks_w, signs, nz,
                base, lut):
            mw, s, halos, fl = smapped(state.m, state.s, state.halos,
                                       sched, masks_w, signs, nz, base, lut)
            return BitplaneLatticeState(
                m=mw, s=s, halos=halos,
                sweep=state.sweep + sched.shape[0] * sched.shape[1],
                flips=flips_publish(state.flips, fl))

        self._chunk_cache[key] = run
        return run

    def _run_chunk_deg(self, iters: int, S: int, per_rep: bool,
                       freeze: bool, has_codes: bool):
        """int8/f32 chunk runner with the integrity layer on: threads the
        health carry through the scan and runs the checked exchange."""
        key = ("deg", iters, S, per_rep, freeze, has_codes)
        if key in self._chunk_cache:
            return self._chunk_cache[key]
        spec_m, spec_masks = self.spec_m, self.spec_masks
        spec_flat = self.spec_flat
        hspecs = self.halo_specs
        axes_all = self._axes_all()
        R = self.replicas
        int8 = self.precision == "int8"
        hlspec = tuple(P() for _ in range(6))

        def block(m, s, halos, sched, masks, h, w6, health, *rest):
            codes = rest[0] if has_codes else None
            lut = rest[-1] if int8 else None
            xlo, xhi, ylo, yhi, zlo, zhi = halos
            halos = (xlo[:, 0], xhi[:, 0], ylo[:, :, 0, :], yhi[:, :, 0, :],
                     zlo[:, :, :, 0], zhi[:, :, :, 0])
            local = jnp.zeros((R,), jnp.uint32)

            def it(carry, b):
                m, s, halos, fl, health = carry
                m, s, f = self._sweep_block(m, s, halos, b, masks, h, w6,
                                            lut)
                halos, health = self._exchange_block_checked(
                    m, halos, health, codes, freeze)
                return (m, s, halos, fl + f.astype(jnp.uint32), health), None
            (m, s, halos, local, health), _ = jax.lax.scan(
                it, (m, s, halos, local, health), sched)
            flips = jax.lax.psum(local, axes_all) if axes_all else local
            health = self._health_pmax(health, axes_all)
            xlo, xhi, ylo, yhi, zlo, zhi = halos
            halos = (xlo[:, None], xhi[:, None],
                     ylo[:, :, None, :], yhi[:, :, None, :],
                     zlo[:, :, :, None], zhi[:, :, :, None])
            return m, s, halos, flips, health

        in_specs = (spec_m, spec_m, hspecs, P(), spec_masks, spec_flat,
                    tuple(spec_flat for _ in range(6)), hlspec)
        if has_codes:
            in_specs = in_specs + (P(),)
        if int8:
            in_specs = in_specs + (P(),)
        smapped = shard_map(
            block, mesh=self.mesh, in_specs=in_specs,
            out_specs=(spec_m, spec_m, hspecs, P(), hlspec),
            check_vma=False,
        )

        @jax.jit
        def run(state: LatticeState, sched, masks, h, w6, health, *rest):
            m, s, halos, fl, health = smapped(
                state.m, state.s, state.halos, sched, masks, h, w6,
                health, *rest)
            st = LatticeState(
                m=m, s=s, halos=halos,
                sweep=state.sweep + sched.shape[0] * sched.shape[1],
                flips=flips_publish(state.flips, fl))
            return st, health

        self._chunk_cache[key] = run
        return run

    def _run_chunk_bp_deg(self, iters: int, S: int, freeze: bool,
                          has_codes: bool):
        """Bitplane chunk runner with the integrity layer on."""
        key = ("bp-deg", iters, S, freeze, has_codes)
        if key in self._chunk_cache:
            return self._chunk_cache[key]
        spec_w, spec_m = self.spec_m, self.spec_m
        spec_masks, spec_flat = self.spec_masks_w, self.spec_flat
        hspecs = self.halo_specs
        axes_all = self._axes_all()
        R = self.replicas
        hlspec = tuple(P() for _ in range(6))

        def block(mw, s, halos, sched, masks_w, signs, nz, base, lut,
                  health, *rest):
            codes = rest[0] if has_codes else None
            xlo, xhi, ylo, yhi, zlo, zhi = halos
            halos = (xlo[:, 0], xhi[:, 0], ylo[:, :, 0, :], yhi[:, :, 0, :],
                     zlo[:, :, :, 0], zhi[:, :, :, 0])
            local = jnp.zeros((R,), jnp.uint32)

            def it(carry, b):
                mw, s, halos, fl, health = carry
                mw, s, f = pbit_bitplane_sweep_op(
                    mw, s, b, masks_w, signs, nz, base, halos, lut,
                    impl=self.impl)
                halos, health = self._exchange_block_checked(
                    mw, halos, health, codes, freeze)
                return (mw, s, halos, fl + f.astype(jnp.uint32), health), None
            (mw, s, halos, local, health), _ = jax.lax.scan(
                it, (mw, s, halos, local, health), sched)
            flips = jax.lax.psum(local, axes_all) if axes_all else local
            health = self._health_pmax(health, axes_all)
            xlo, xhi, ylo, yhi, zlo, zhi = halos
            halos = (xlo[:, None], xhi[:, None],
                     ylo[:, :, None, :], yhi[:, :, None, :],
                     zlo[:, :, :, None], zhi[:, :, :, None])
            return mw, s, halos, flips, health

        in_specs = (spec_w, spec_m, hspecs, P(), spec_masks,
                    tuple(spec_flat for _ in range(6)),
                    tuple(spec_flat for _ in range(6)), spec_flat, P(),
                    hlspec)
        if has_codes:
            in_specs = in_specs + (P(),)
        smapped = shard_map(
            block, mesh=self.mesh, in_specs=in_specs,
            out_specs=(spec_w, spec_m, hspecs, P(), hlspec),
            check_vma=False,
        )

        @jax.jit
        def run(state: BitplaneLatticeState, sched, masks_w, signs, nz,
                base, lut, health, *rest):
            mw, s, halos, fl, health = smapped(
                state.m, state.s, state.halos, sched, masks_w, signs, nz,
                base, lut, health, *rest)
            st = BitplaneLatticeState(
                m=mw, s=s, halos=halos,
                sweep=state.sweep + sched.shape[0] * sched.shape[1],
                flips=flips_publish(state.flips, fl))
            return st, health

        self._chunk_cache[key] = run
        return run

    def set_exchange_faults(self, codes):
        """Schedule engine-boundary exchange faults: ``codes[seq]`` in
        {0 ok, 1 drop, 2 corrupt} applied to the *received* halo planes at
        global exchange ``seq`` (see ``serve.faults.FaultPlan``).  ``None``
        clears.  Requires a degrade policy — an unchecked engine would
        silently ingest the damage."""
        if codes is None:
            self._fault_codes = None
            return
        if self.degrade is None:
            raise ValueError("set_exchange_faults needs a degrade policy "
                             "(unchecked engines must not ingest damage)")
        self._fault_codes = jnp.asarray(np.asarray(codes), jnp.int32)

    def resync(self, state):
        """Quarantine exit: instantaneous full-boundary refresh.

        Re-derives every halo plane from the *current* spins — exactly the
        exchange a no-fault run would have performed here, so the returned
        halos are bitwise the no-fault trajectory's (verified in tests).
        Clears staleness/freeze on the health monitor."""
        st = self._refresh_halos(state)
        if self.health is not None:
            self.health.on_resync()
        return st

    def init_state(self, seed: int = 0,
                   seeds: Optional[Sequence[int]] = None) -> LatticeState:
        """Fresh replicated state.  ``seeds=[...]`` (length R) gives every
        replica its own explicit seed — the packed-batch path, where
        replica r's trajectory depends only on seeds[r]."""
        p = self.p
        X, Y, Z = p.dims
        R = self.replicas
        if seeds is not None:
            seeds = [int(s) for s in seeds]
            if len(seeds) != R:
                raise ValueError(f"need exactly R={R} seeds, got {len(seeds)}")
        else:
            seeds = [seed] if R == 1 else spawn_seeds(seed, R)
        ms, ss = [], []
        for sd in seeds:
            rng = np.random.default_rng(sd)
            ms.append(rng.choice(np.array([-1, 1], np.int8), size=(X, Y, Z)))
            ss.append(np.asarray(lfsr_init(X * Y * Z, sd)).reshape(X, Y, Z))
        s = jnp.asarray(np.stack(ss))
        if self.precision == "bitplane":
            # lane r's spins and LFSR column come from seeds[r] exactly as
            # replica r of the unpacked engines would — lane r of a packed
            # run is bit-identical to int8 replica r at matched schedules
            mw = pack_lanes(jnp.asarray(np.stack(ms)))
            halos = tuple(jnp.zeros(sh, jnp.uint32)
                          for sh in self._halo_shapes())
            st = BitplaneLatticeState(m=mw, s=s, halos=halos,
                                      sweep=jnp.zeros((), jnp.int32),
                                      flips=jnp.zeros((R,), jnp.int32))
        else:
            m = jnp.asarray(np.stack(ms))
            halos = tuple(jnp.zeros(sh, jnp.int8)
                          for sh in self._halo_shapes())
            st = LatticeState(m=m, s=s, halos=halos,
                              sweep=jnp.zeros((), jnp.int32),
                              flips=jnp.zeros((R,), jnp.int32))
        st = self.shard_state(st)
        # one synchronizing exchange so the first sweeps see real halos
        return self._refresh_halos(st)

    def shard_state(self, st):
        # drop the cached exchange-only closure: it closed over the old
        # sharding, and a restore()/re-shard must not probe stale layouts
        self._exchange_only_fn = None
        put = jax.device_put
        cls = type(st)
        # bitplane words lead with the W stacked planes, unpacked spins
        # with R — either way one replicated leading axis
        return cls(
            m=put(st.m, self._shard(self.spec_m)),
            s=put(st.s, self._shard(self.spec_m)),
            halos=tuple(put(hh, self._shard(sp))
                        for hh, sp in zip(st.halos, self.halo_specs)),
            sweep=put(st.sweep, self._shard(P())),
            flips=put(st.flips, self._shard(P())))

    def _refresh_halos(self, st):
        if self.precision == "bitplane":
            def block(mw):
                xlo, xhi, ylo, yhi, zlo, zhi = self._exchange_block_w(mw)
                return (xlo[:, None], xhi[:, None],
                        ylo[:, :, None, :], yhi[:, :, None, :],
                        zlo[:, :, :, None], zhi[:, :, :, None])
            halos = jax.jit(shard_map(
                block, mesh=self.mesh, in_specs=(self.spec_m,),
                out_specs=self.halo_specs, check_vma=False))(st.m)
            return dataclasses.replace(st, halos=halos)

        def block(m):
            xlo, xhi, ylo, yhi, zlo, zhi = self._exchange_block(m)
            return (xlo[:, None], xhi[:, None],
                    ylo[:, :, None, :], yhi[:, :, None, :],
                    zlo[:, :, :, None], zhi[:, :, :, None])
        halos = jax.jit(shard_map(
            block, mesh=self.mesh, in_specs=(self.spec_m,),
            out_specs=self.halo_specs, check_vma=False))(st.m)
        return dataclasses.replace(st, halos=halos)

    def run_recorded_full(self, state: LatticeState, schedule,
                          record_points: Sequence[int], sync_every: int = 1,
                          betas_R: Optional[np.ndarray] = None,
                          cursor: bool = False):
        """Shared-driver runner; returns (state, RunRecord).

        ``betas_R`` (total_sweeps, R) optionally gives each replica its own
        beta staircase (:func:`repro.core.annealing.replica_beta_arrays`);
        on the integer path each staircase becomes a fan of LUT row
        indices, so the replica axis rides the fixed-point kernels
        unchanged."""
        if betas_R is not None:
            betas_R = np.asarray(betas_R, np.float32)
            if betas_R.ndim != 2 or betas_R.shape[1] != self.replicas:
                raise ValueError(
                    f"betas_R must be (total_sweeps, R={self.replicas})")
            schedule = ArraySchedule(betas_R)
        beta_arr = np.asarray(schedule.beta_array(), np.float32)
        per_rep = beta_arr.ndim == 2

        deg = self.degrade is not None
        if deg:
            self.health.reset()
            codes = self._fault_codes
            freeze = self.degrade.mode == "freeze_boundary"
            has_codes = codes is not None
            code_args = (codes,) if has_codes else ()

        if self.precision == "bitplane":
            table = beta_table(beta_arr)
            lut = self._lut_for(table)
            sched = ArraySchedule(beta_row_indices(beta_arr, table))

            if deg:
                def chunk(st, rows2d, iters, S):
                    st, carry = self._run_chunk_bp_deg(
                        iters, S, freeze, has_codes)(
                            st, rows2d, self.masks_w, self.signs6_w,
                            self.nz6_w, self.base_w, lut,
                            self.health.carry, *code_args)
                    self.health.update(carry, exchanges=iters)
                    return st
            else:
                def chunk(st, rows2d, iters, S):
                    return self._run_chunk_bp(iters, S)(
                        st, rows2d, self.masks_w, self.signs6_w,
                        self.nz6_w, self.base_w, lut)
        elif self.precision == "int8":
            table = beta_table(beta_arr)
            lut = self._lut_for(table)
            sched = ArraySchedule(beta_row_indices(beta_arr, table))

            if deg:
                def chunk(st, rows2d, iters, S):
                    st, carry = self._run_chunk_deg(
                        iters, S, per_rep, freeze, has_codes)(
                            st, rows2d, self.p.masks, self.h_q, self.w6_q,
                            self.health.carry, *(code_args + (lut,)))
                    self.health.update(carry, exchanges=iters)
                    return st
            else:
                def chunk(st, rows2d, iters, S):
                    return self._run_chunk(iters, S, per_rep)(
                        st, rows2d, self.p.masks, self.h_q, self.w6_q, lut)
        else:
            sched = ArraySchedule(beta_arr) if per_rep else schedule

            if deg:
                def chunk(st, betas2d, iters, S):
                    st, carry = self._run_chunk_deg(
                        iters, S, per_rep, freeze, has_codes)(
                            st, betas2d, self.p.masks, self.p.h, self.p.w6,
                            self.health.carry, *code_args)
                    self.health.update(carry, exchanges=iters)
                    return st
            else:
                def chunk(st, betas2d, iters, S):
                    return self._run_chunk(iters, S, per_rep)(
                        st, betas2d, self.p.masks, self.p.h, self.p.w6)

        kw = dict(
            state=state, schedule=sched, record_points=record_points,
            chunk_fn=chunk, record_fn=self.energy, sync_every=int(sync_every),
            flips_of=lambda st: st.flips,
            flips_per_sweep=self.n_sites * self.replicas)
        if cursor:
            return RecordedCursor(**kw)
        return run_recorded_driver(**kw)

    def run_recorded(self, state: LatticeState, schedule,
                     record_points: Sequence[int], sync_every: int = 1):
        """Run to each record point; returns (state, (times, energies))."""
        return self.run_recorded_full(state, schedule, record_points,
                                      sync_every=sync_every)

    # -- observables -----------------------------------------------------------------------

    def energy(self, state) -> jnp.ndarray:
        """True global energies, one per replica (halos refreshed for the
        readout).  Returns (R,) — or a scalar when replicas == 1, keeping
        the legacy contract."""
        if self._energy_fn is None:
            axes_all = self._axes_all()
            R = self.replicas
            bitplane = self.precision == "bitplane"

            def block(m, active, h, w6):
                if bitplane:
                    # unpack lanes + word halos, then the shared per-replica
                    # energy readout — identical float ops to the unpacked
                    # engines, so equal spins give equal energies
                    halos = tuple(unpack_lanes(hw, R)
                                  for hw in self._exchange_block_w(m))
                    m = unpack_lanes(m, R)
                else:
                    halos = self._exchange_block(m)
                e = jax.vmap(
                    lambda mr, hr: brick_energy_op(mr, active, h, w6, hr,
                                                   bx=self.kernel_bx,
                                                   impl=self.impl),
                    in_axes=(0, 0))(m, halos)
                return jax.lax.psum(e, axes_all) if axes_all else e

            self._energy_fn = jax.jit(shard_map(
                block, mesh=self.mesh,
                in_specs=(self.spec_m, self.spec_flat, self.spec_flat,
                          tuple(self.spec_flat for _ in range(6))),
                out_specs=P(), check_vma=False))
        e = self._energy_fn(state.m, self.p.active, self.p.h, self.p.w6)
        return e[0] if self.replicas == 1 else e

    def global_spins(self, state) -> jnp.ndarray:
        """(R, L^3) active-site spins in ea3d node order ((L,L,L) row-major);
        squeezed to (L^3,) when replicas == 1."""
        L = self.p.L
        if self.precision == "bitplane":
            spins = unpack_lanes(state.m[:, :L, :L, :L], self.replicas) \
                .reshape(self.replicas, L ** 3)
        else:
            spins = state.m[:, :L, :L, :L].reshape(self.replicas, L ** 3)
        return spins[0] if self.replicas == 1 else spins

    # -- dry-run hook -----------------------------------------------------------------------

    def _chunk_args(self, iters: int, S: int, lut_rows: int,
                    degrade: bool = False, freeze: bool = False,
                    has_codes: bool = False):
        """(runner, abstract args) for one sampling chunk — shared by the
        lowering dry-run and the static contract auditor's tracer.  With
        ``degrade`` the checked-exchange runner (per-face health carry,
        optional fault-code operand) is selected instead of the plain one."""
        def sds(x, spec):
            return jax.ShapeDtypeStruct(x.shape, x.dtype,
                                        sharding=self._shard(spec))
        p = self.p
        X, Y, Z = p.dims
        R = self.replicas
        health = tuple(
            jax.ShapeDtypeStruct(np.shape(h), np.asarray(h).dtype,
                                 sharding=self._shard(P()))
            for h in health_init(6)) if degrade else None
        codes_opt = (jax.ShapeDtypeStruct((8,), jnp.uint32,
                                          sharding=self._shard(P())),) \
            if has_codes else ()
        if self.precision == "bitplane":
            st = BitplaneLatticeState(
                m=jax.ShapeDtypeStruct((self.words, X, Y, Z), jnp.uint32,
                                       sharding=self._shard(self.spec_m)),
                s=jax.ShapeDtypeStruct((R, X, Y, Z), jnp.uint32,
                                       sharding=self._shard(self.spec_m)),
                halos=tuple(jax.ShapeDtypeStruct(tuple(sh), jnp.uint32,
                                                 sharding=self._shard(sp))
                            for sh, sp in zip(self._halo_shapes(),
                                              self.halo_specs)),
                sweep=jax.ShapeDtypeStruct((), jnp.int32,
                                           sharding=self._shard(P())),
                flips=jax.ShapeDtypeStruct((R,), jnp.int32,
                                           sharding=self._shard(P())),
            )
            rows = jax.ShapeDtypeStruct((iters, S), jnp.int32,
                                        sharding=self._shard(P()))
            masks_w = sds(self.masks_w, self.spec_masks_w)
            signs = tuple(sds(w, self.spec_flat) for w in self.signs6_w)
            nz = tuple(sds(w, self.spec_flat) for w in self.nz6_w)
            base = sds(self.base_w, self.spec_flat)
            lut = jax.ShapeDtypeStruct((lut_rows, 2 * self.f_max + 1),
                                       jnp.uint32, sharding=self._shard(P()))
            if degrade:
                run = self._run_chunk_bp_deg(iters, S, freeze, has_codes)
                return run, (st, rows, masks_w, signs, nz, base, lut,
                             health) + codes_opt
            return self._run_chunk_bp(iters, S), \
                (st, rows, masks_w, signs, nz, base, lut)
        st = LatticeState(
            m=jax.ShapeDtypeStruct((R, X, Y, Z), jnp.int8,
                                   sharding=self._shard(self.spec_m)),
            s=jax.ShapeDtypeStruct((R, X, Y, Z), jnp.uint32,
                                   sharding=self._shard(self.spec_m)),
            halos=tuple(jax.ShapeDtypeStruct(tuple(sh), jnp.int8,
                                             sharding=self._shard(sp))
                        for sh, sp in zip(self._halo_shapes(), self.halo_specs)),
            sweep=jax.ShapeDtypeStruct((), jnp.int32, sharding=self._shard(P())),
            flips=jax.ShapeDtypeStruct((R,), jnp.int32,
                                       sharding=self._shard(P())),
        )
        masks = sds(p.masks, self.spec_masks)
        if self.precision == "int8":
            sched = jax.ShapeDtypeStruct((iters, S), jnp.int32,
                                         sharding=self._shard(P()))
            hh = sds(self.h_q, self.spec_flat)
            ww = tuple(sds(w, self.spec_flat) for w in self.w6_q)
            lut_opt = (jax.ShapeDtypeStruct((lut_rows, 2 * self.f_max + 1),
                                            jnp.uint32,
                                            sharding=self._shard(P())),)
        else:
            sched = jax.ShapeDtypeStruct((iters, S), jnp.float32,
                                         sharding=self._shard(P()))
            hh = sds(p.h, self.spec_flat)
            ww = tuple(sds(w, self.spec_flat) for w in p.w6)
            lut_opt = ()
        if degrade:
            run = self._run_chunk_deg(iters, S, False, freeze, has_codes)
            return run, (st, sched, masks, hh, ww, health) \
                + codes_opt + lut_opt
        return self._run_chunk(iters, S), \
            (st, sched, masks, hh, ww) + lut_opt

    def lower_chunk(self, iters: int = 2, S: int = 4, lut_rows: int = 10):
        """Lower (not run) one sampling chunk — used by the launch dry-run."""
        run, args = self._chunk_args(iters, S, lut_rows)
        return run.lower(*args)

    def trace_chunk(self, iters: int = 2, S: int = 4, lut_rows: int = 10,
                    degrade: bool = False, freeze: bool = False,
                    has_codes: bool = False):
        """Trace (not lower) one sampling chunk and return the jitted
        runner's Traced object, whose ``.jaxpr`` the static contract
        auditor walks.  Unlike :meth:`lower_chunk` this works over an
        ``AbstractMesh`` — halo dtype/count contracts are auditable on a
        single-device host, no multi-device subprocess needed."""
        run, args = self._chunk_args(iters, S, lut_rows, degrade=degrade,
                                     freeze=freeze, has_codes=has_codes)
        return run.trace(*args)
