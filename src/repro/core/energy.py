"""Ising energy, residual energy, and local fields."""

from __future__ import annotations

import jax.numpy as jnp

from .graph import IsingGraph

__all__ = ["local_fields", "energy", "residual_energy", "cut_value"]


def local_fields(g: IsingGraph, m: jnp.ndarray) -> jnp.ndarray:
    """h_i + sum_j J_ij m_j for all nodes (pre-beta).  m: (N,) int8 spins."""
    nbr = jnp.take(m, g.idx, axis=0).astype(g.w.dtype)  # (N, D)
    return g.h + (g.w * nbr).sum(axis=-1)


def energy(g: IsingGraph, m: jnp.ndarray) -> jnp.ndarray:
    """E(m) = -sum_{i<j} J_ij m_i m_j - sum_i h_i m_i  (exact for +-1 weights)."""
    mf = m.astype(g.w.dtype)
    nbr = jnp.take(m, g.idx, axis=0).astype(g.w.dtype)
    pair = (mf[:, None] * g.w * nbr).sum()
    return -0.5 * pair - (g.h * mf).sum()


def residual_energy(E, E_ground, n: int):
    """rho_E = (E - E_ground) / N  (paper Eq. S.1)."""
    return (E - E_ground) / n


def cut_value(g: IsingGraph, m: jnp.ndarray) -> jnp.ndarray:
    """Max-Cut value of the bipartition encoded by spins m.

    For Max-Cut the Ising mapping uses J_ij = -w_ij (antiferromagnetic for
    positive graph weights); here we evaluate the cut directly on the graph's
    stored weights:  cut = sum_{(i,j): m_i != m_j} w_ij.
    """
    mf = m.astype(g.w.dtype)
    nbr = jnp.take(m, g.idx, axis=0).astype(g.w.dtype)
    # (1 - m_i m_j)/2 is 1 across the cut, 0 inside a side; halve double count
    disagree = (1.0 - mf[:, None] * nbr) * 0.5
    return 0.5 * (g.w * disagree).sum()
