"""Sparse Ising graph representation and instance builders.

The canonical on-device format is ELL (padded neighbor lists): fixed-shape,
gather-friendly, TPU-native.  ``idx[i, d]`` is the d-th neighbor of node i and
``w[i, d]`` the coupling weight; padding entries point at node 0 with weight 0,
so a gather + masked-by-weight sum is always valid.

Energies follow the Ising convention  E(m) = -sum_{i<j} J_ij m_i m_j - sum_i h_i m_i
with m_i in {-1, +1}.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np
import jax.numpy as jnp

__all__ = [
    "IsingGraph",
    "from_edges",
    "ea3d",
    "ea3d_edges",
    "toroidal_grid",
    "random_regular",
    "edges_from_ell",
]


@dataclasses.dataclass(frozen=True)
class IsingGraph:
    """Padded-neighbor-list (ELL) sparse Ising graph."""

    idx: jnp.ndarray  # (N, D) int32 neighbor indices (padded with 0)
    w: jnp.ndarray    # (N, D) float32 coupling weights (padded with 0.0)
    h: jnp.ndarray    # (N,)  float32 biases
    meta: dict = dataclasses.field(default_factory=dict, hash=False, compare=False)

    @property
    def n(self) -> int:
        return int(self.idx.shape[0])

    @property
    def max_degree(self) -> int:
        return int(self.idx.shape[1])

    @property
    def num_edges(self) -> int:
        # each undirected edge appears twice in the ELL rows
        return int((np.asarray(self.w) != 0).sum() // 2)

    def to_numpy(self) -> "IsingGraph":
        return IsingGraph(
            idx=np.asarray(self.idx),
            w=np.asarray(self.w),
            h=np.asarray(self.h),
            meta=self.meta,
        )


def from_edges(
    n: int,
    ei: np.ndarray,
    ej: np.ndarray,
    ew: np.ndarray,
    h: Optional[np.ndarray] = None,
    meta: Optional[dict] = None,
) -> IsingGraph:
    """Build an ELL graph from an undirected edge list (each edge listed once)."""
    ei = np.asarray(ei, dtype=np.int64)
    ej = np.asarray(ej, dtype=np.int64)
    ew = np.asarray(ew, dtype=np.float32)
    if not (len(ei) == len(ej) == len(ew)):
        raise ValueError("edge arrays must have equal length")
    if len(ei) and (ei.max() >= n or ej.max() >= n or ei.min() < 0 or ej.min() < 0):
        raise ValueError("edge endpoint out of range")
    if np.any(ei == ej):
        raise ValueError("self loops are not allowed in an Ising graph")

    # symmetric incidence
    src = np.concatenate([ei, ej])
    dst = np.concatenate([ej, ei])
    wgt = np.concatenate([ew, ew])

    deg = np.bincount(src, minlength=n)
    dmax = int(deg.max()) if len(src) else 1
    dmax = max(dmax, 1)

    order = np.argsort(src, kind="stable")
    src, dst, wgt = src[order], dst[order], wgt[order]
    # slot position of each incidence within its row
    starts = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(deg, out=starts[1:])
    slot = np.arange(len(src)) - starts[src]

    idx = np.zeros((n, dmax), dtype=np.int32)
    w = np.zeros((n, dmax), dtype=np.float32)
    idx[src, slot] = dst
    w[src, slot] = wgt

    hh = np.zeros(n, dtype=np.float32) if h is None else np.asarray(h, dtype=np.float32)
    if hh.shape != (n,):
        raise ValueError("bias vector has wrong shape")
    return IsingGraph(idx=jnp.asarray(idx), w=jnp.asarray(w), h=jnp.asarray(hh),
                      meta=dict(meta or {}))


# ---------------------------------------------------------------------------
# 3D Edwards-Anderson spin glasses
# ---------------------------------------------------------------------------

def _lattice_id(x, y, z, L):
    return (x * L + y) * L + z


def ea3d_edges(L: int, seed: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Edge list of the 3D EA spin glass per the paper's Methods:

    J_ij in {+-1} i.i.d. uniform on nearest-neighbor edges of an L^3 cubic
    lattice, periodic boundary in z, open boundaries in x and y.
    """
    rng = np.random.default_rng(seed)
    xs, ys, zs = np.meshgrid(np.arange(L), np.arange(L), np.arange(L), indexing="ij")
    xs, ys, zs = xs.ravel(), ys.ravel(), zs.ravel()

    ei, ej = [], []
    # +x (open)
    m = xs < L - 1
    ei.append(_lattice_id(xs[m], ys[m], zs[m], L))
    ej.append(_lattice_id(xs[m] + 1, ys[m], zs[m], L))
    # +y (open)
    m = ys < L - 1
    ei.append(_lattice_id(xs[m], ys[m], zs[m], L))
    ej.append(_lattice_id(xs[m], ys[m] + 1, zs[m], L))
    # +z (periodic); for L == 2 the wrap edge duplicates the open edge - skip wrap then
    if L > 2:
        ei.append(_lattice_id(xs, ys, zs, L))
        ej.append(_lattice_id(xs, ys, (zs + 1) % L, L))
    else:
        m = zs < L - 1
        ei.append(_lattice_id(xs[m], ys[m], zs[m], L))
        ej.append(_lattice_id(xs[m], ys[m], zs[m] + 1, L))

    ei = np.concatenate(ei)
    ej = np.concatenate(ej)
    ew = rng.choice(np.array([-1.0, 1.0], dtype=np.float32), size=len(ei))
    return ei, ej, ew


def ea3d(L: int, seed: int = 0) -> IsingGraph:
    """3D Edwards-Anderson spin glass instance (see :func:`ea3d_edges`)."""
    ei, ej, ew = ea3d_edges(L, seed)
    g = from_edges(L ** 3, ei, ej, ew, meta={"kind": "ea3d", "L": L, "seed": seed})
    return g


# ---------------------------------------------------------------------------
# Other instance families
# ---------------------------------------------------------------------------

def toroidal_grid(rows: int, cols: int, seed: int = 0,
                  weights: str = "pm1") -> IsingGraph:
    """Toroidal 2D grid with random +-1 weights (the Gset G81 family shape)."""
    rng = np.random.default_rng(seed)
    xs, ys = np.meshgrid(np.arange(rows), np.arange(cols), indexing="ij")
    xs, ys = xs.ravel(), ys.ravel()
    nid = xs * cols + ys
    ei = np.concatenate([nid, nid])
    ej = np.concatenate([((xs + 1) % rows) * cols + ys, xs * cols + (ys + 1) % cols])
    if weights == "pm1":
        ew = rng.choice(np.array([-1.0, 1.0], dtype=np.float32), size=len(ei))
    else:
        ew = np.ones(len(ei), dtype=np.float32)
    return from_edges(rows * cols, ei, ej, ew,
                      meta={"kind": "toroidal", "rows": rows, "cols": cols, "seed": seed})


def random_regular(n: int, d: int, seed: int = 0) -> IsingGraph:
    """Random d-regular graph, +-1 weights.

    Configuration model + edge-swap repair: full rejection has vanishing
    acceptance for d >= 5, so self-loops/multi-edges are fixed by random
    2-swaps instead (standard construction)."""
    if (n * d) % 2 != 0:
        raise ValueError("n*d must be even")
    rng = np.random.default_rng(seed)
    for _ in range(50):
        stubs = np.repeat(np.arange(n), d)
        rng.shuffle(stubs)
        ei, ej = stubs[0::2].copy(), stubs[1::2].copy()
        ok = True
        for _ in range(50 * n):
            bad = np.nonzero(ei == ej)[0]
            if len(bad) == 0:
                key = np.minimum(ei, ej).astype(np.int64) * n + \
                    np.maximum(ei, ej)
                order = np.argsort(key)
                dup = np.nonzero(np.diff(key[order]) == 0)[0]
                if len(dup) == 0:
                    break
                bad = order[dup]
            # 2-swap each offending edge with a random partner edge
            partners = rng.integers(0, len(ei), size=len(bad))
            ej[bad], ej[partners] = ej[partners].copy(), ej[bad].copy()
        else:
            ok = False
        if not ok:
            continue
        key = np.minimum(ei, ej).astype(np.int64) * n + np.maximum(ei, ej)
        if np.any(ei == ej) or len(np.unique(key)) != len(key):
            continue
        ew = rng.choice(np.array([-1.0, 1.0], dtype=np.float32), size=len(ei))
        return from_edges(n, ei, ej, ew,
                          meta={"kind": "random_regular", "d": d, "seed": seed})
    raise RuntimeError("failed to sample a simple random regular graph")


def edges_from_ell(g: IsingGraph) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Recover the unique undirected edge list (i < j) from an ELL graph."""
    idx = np.asarray(g.idx)
    w = np.asarray(g.w)
    n, d = idx.shape
    src = np.repeat(np.arange(n), d)
    dst = idx.ravel()
    wgt = w.ravel()
    m = (wgt != 0) & (src < dst)
    return src[m], dst[m], wgt[m]
