"""The p-bit update rule and its numeric-format options.

Paper Sec. II:  m_i = sgn[tanh(I_i) + r],  r ~ U(-1, 1),
I_i = beta * (h_i + sum_j J_ij m_j).

Numeric formats (paper Methods): the GPU baseline uses floating point +
Philox; the hardware uses fixed point s{a}{b} + on-chip LFSRs.  Both are
first-class here: ``rng='philox'`` uses jax.random, ``rng='lfsr'`` uses a
vectorized xorshift32 (one 32-bit LFSR state per p-bit, mirroring the
hardware's per-p-bit LFSR fabric).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["FixedPoint", "quantize", "pbit_update", "lfsr_init", "lfsr_next",
           "lfsr_uniform", "S41", "S43", "S46",
           "LFSR_UNIFORM_BITS", "quantize_couplings", "field_bound",
           "threshold_lut", "threshold_lut_cached", "lut_accept",
           "bitplane_planes", "flips_publish"]


def flips_publish(flips_i32: jnp.ndarray, delta_u32: jnp.ndarray):
    """Fold a uint32-modular flip delta into the int32 odometer view.

    Flip odometers are carried across chunk boundaries as int32 (pytree/
    snapshot dtype contract) but their arithmetic must be mod-2^32 in the
    unsigned domain: accumulate in uint32, add into the bitcast view, and
    bitcast back.  In-range totals are bit-identical to a plain int32 add;
    past 2^31 the unsigned view keeps the exact modular count the recording
    driver folds host-side.  The static contract auditor (rule IR-E)
    requires every published counter to end in this u32 -> i32 bitcast.
    """
    u = jax.lax.bitcast_convert_type(flips_i32, jnp.uint32)
    return jax.lax.bitcast_convert_type(u + delta_u32, jnp.int32)


@dataclasses.dataclass(frozen=True)
class FixedPoint:
    """Signed fixed point s{int_bits}{frac_bits}: step 2^-frac, saturating."""

    int_bits: int
    frac_bits: int

    @property
    def step(self) -> float:
        return 2.0 ** (-self.frac_bits)

    @property
    def lo(self) -> float:
        return -(2.0 ** self.int_bits)

    @property
    def hi(self) -> float:
        return 2.0 ** self.int_bits - self.step


S41 = FixedPoint(4, 1)  # EA benchmarks
S43 = FixedPoint(4, 3)  # Pegasus / Zephyr / 3SAT
S46 = FixedPoint(4, 6)  # G81 adaptive parallel tempering


def quantize(x: jnp.ndarray, fmt: Optional[FixedPoint]) -> jnp.ndarray:
    """Round-to-nearest + saturate to the fixed-point grid (no-op if fmt None)."""
    if fmt is None:
        return x
    q = jnp.round(x / fmt.step) * fmt.step
    return jnp.clip(q, fmt.lo, fmt.hi)


def pbit_update(field: jnp.ndarray, beta, rand_u: jnp.ndarray,
                fmt: Optional[FixedPoint] = None) -> jnp.ndarray:
    """One synchronous p-bit update for an independent (same-color) set.

    ``field`` is h + sum_j J_ij m_j (pre-beta); ``rand_u`` uniform in (-1, 1).
    Returns int8 spins in {-1, +1}.
    """
    act = quantize(beta * field, fmt)
    val = jnp.tanh(act) + rand_u
    # sgn with the (measure-zero) tie broken toward +1
    return jnp.where(val >= 0, 1, -1).astype(jnp.int8)


# ---------------------------------------------------------------------------
# LFSR (xorshift32) — the hardware RNG, vectorized one state per p-bit
# ---------------------------------------------------------------------------

def lfsr_init(n: int, seed: int) -> jnp.ndarray:
    """Nonzero uint32 states, seeded reproducibly (host-side splitmix64)."""
    rng = np.random.default_rng(np.uint64(seed) ^ np.uint64(0x9E3779B97F4A7C15))
    s = rng.integers(1, 2 ** 32, size=n, dtype=np.uint32)
    return jnp.asarray(s)


def lfsr_next(state: jnp.ndarray) -> jnp.ndarray:
    """xorshift32 step (Marsaglia); acts elementwise on uint32 states."""
    s = state
    s = s ^ (s << jnp.uint32(13))
    s = s ^ (s >> jnp.uint32(17))
    s = s ^ (s << jnp.uint32(5))
    return s


def lfsr_uniform(state: jnp.ndarray) -> jnp.ndarray:
    """Map uint32 state -> uniform float32 in (-1, 1)."""
    # keep 24 mantissa-safe bits
    bits = (state >> jnp.uint32(8)).astype(jnp.float32)
    return bits * jnp.float32(2.0 / 16777216.0) - jnp.float32(1.0)


# ---------------------------------------------------------------------------
# fixed-point coupling quantization + threshold LUTs (the hardware pipeline)
# ---------------------------------------------------------------------------
#
# The machine never evaluates tanh at runtime: couplings live on chip as
# small signed integers, the local field is an integer accumulate, and the
# Boltzmann acceptance is a single unsigned compare of the raw LFSR draw
# against a pre-tabulated threshold
#
#   accept(+1)  <=>  tanh(beta*field) + r >= 0,   r = u / 2^23 - 1
#               <=>  u >= ceil((1 - tanh(beta * scale * f)) * 2^23) = T[beta, f]
#
# with u the 24-bit LFSR draw (state >> 8) and f the *integer* field.  T is
# one small uint32 row per beta-staircase entry, computed host-side in f64;
# annealing staircases become row indices into the table.

LFSR_UNIFORM_BITS = 24  # the draw u = state >> 8 is uniform on [0, 2^24)
_HALF = 1 << (LFSR_UNIFORM_BITS - 1)   # 2^23: u/2^23 - 1 is the (-1,1) map


def quantize_couplings(h, w6, bits: int = 8):
    """Quantize biases + the six directional couplings to signed ``bits``.

    One per-problem scale (symmetric, max-abs / qmax) covers h and all six
    weight planes, so the integer field  f = h_q + sum_d w_q[d] * m_d  obeys
    scale * f ~= h + sum_d w[d] * m_d.  For the paper's +-J EA instances the
    quantization is *exact*.  A common integer factor of the quantized
    values is divided out (and folded into the scale): +-J couplings land
    on +-1 — the hardware's actual small-integer weights — which keeps the
    integer field range, and with it the threshold-LUT width, minimal.

    Returns ``(h_q, w6_q, scale)`` with int8 arrays and a float scale.
    """
    qmax = float(2 ** (bits - 1) - 1)
    h = np.asarray(h, np.float64)
    ws = [np.asarray(w, np.float64) for w in w6]
    amax = max([np.abs(h).max()] + [np.abs(w).max() for w in ws])
    scale = (amax / qmax) if amax > 0 else 1.0
    to_int = lambda a: np.clip(np.rint(a / scale), -qmax, qmax).astype(np.int64)
    qs = [to_int(h)] + [to_int(w) for w in ws]
    g = int(np.gcd.reduce([np.gcd.reduce(np.abs(q), axis=None) for q in qs]))
    if g > 1:
        qs = [q // g for q in qs]
        scale *= g
    qs = [q.astype(np.int8) for q in qs]
    return jnp.asarray(qs[0]), tuple(jnp.asarray(q) for q in qs[1:]), \
        float(scale)


def field_bound(h_q, w6_q) -> int:
    """Tight per-site bound on |h_q + sum_d w_q[d] * m_d| over m in {-1,+1}."""
    b = np.abs(np.asarray(h_q, np.int64))
    for w in w6_q:
        b = b + np.abs(np.asarray(w, np.int64))
    return int(b.max())


# Widest LUT row evaluated by the unrolled rank-count accept (below).
# Per-element gather is a scalar loop on XLA:CPU and unsupported from VMEM
# on Mosaic; the rank count is Lw scalar compares that fuse into ONE
# elementwise pass — and GCD-reduced +-J problems need only 2*6+1 = 13.
LUT_SELECT_MAX_WIDTH = 64


def lut_accept(thr: jnp.ndarray, field: jnp.ndarray, f_off: int,
               u: jnp.ndarray) -> jnp.ndarray:
    """The LUT accept test ``u >= thr[field + f_off]`` (thr is one LUT row).

    Narrow rows exploit the row's monotonicity (thr is nonincreasing in the
    field index, guaranteed by :func:`threshold_lut`): the number of
    entries already satisfied by ``u`` is ``count = #{k : u >= thr[k]}``,
    and those entries are exactly the top ``count`` field indices, so

        u >= thr[idx]   <=>   idx + count >= len(thr)

    — an unrolled chain of compares against scalars, pure vector-unit work
    with no gather and no select traffic.  Wide rows fall back to a gather.
    """
    lw = int(thr.shape[0])
    idx = jnp.clip(field + f_off, 0, lw - 1)
    if lw <= LUT_SELECT_MAX_WIDTH:
        count = jnp.zeros(u.shape, jnp.int32)
        for k in range(lw):
            count = count + (u >= thr[k]).astype(jnp.int32)
        return idx + count >= lw
    return u >= jnp.take(thr, idx, mode="clip")


def bitplane_planes(h_q, w6_q):
    """Sign-plane quantization: the bit-plane engine's per-site constants.

    With couplings quantized to {-1, 0, +1} (:func:`quantize_couplings` on
    +-J problems), the product w_d * m_d collapses to one XOR per neighbor
    bit: encoding spin +1 as bit 1, the contribution of a nonzero coupling
    is +1 exactly when ``m_bit XOR (w_d < 0)`` is 1.  The integer field of
    lane r is then

        f = h_q + 2*c - nnz,    c = #{nonzero d : contribution +1}

    so the threshold-LUT column index ``f + f_max`` equals ``base + 2*c``
    with the lane-independent ``base = h_q - nnz + f_max`` precomputed per
    site.  Returns ``(signs6, nz6, base, f_max)``:

      signs6: 6 uint32 planes, all-ones words where w_d < 0 (XOR operand,
        broadcast across the 32 lanes of a word);
      nz6: 6 uint32 planes, all-ones words where w_d != 0 (AND mask);
      base: int32 plane, h_q - nnz + f_max (in [0, 2*f_max] by the field
        bound);
      f_max: the :func:`field_bound` of the quantized problem.

    Raises ValueError when any |w_q| > 1 — multi-bit couplings have no
    single sign plane; such problems stay on the int8 path.
    """
    ones = np.uint32(0xFFFFFFFF)
    h_q = np.asarray(h_q, np.int64)
    ws = [np.asarray(w, np.int64) for w in w6_q]
    bad = max(int(np.abs(w).max()) for w in ws)
    if bad > 1:
        raise ValueError(
            f"bitplane needs couplings quantized to {{-1, 0, +1}} (one sign "
            f"bit per neighbor); this problem quantizes to |w_q| up to "
            f"{bad}.  Use precision='int8' instead.")
    f_max = field_bound(h_q, ws)
    signs6 = tuple(jnp.asarray(np.where(w < 0, ones, 0).astype(np.uint32))
                   for w in ws)
    nz6 = tuple(jnp.asarray(np.where(w != 0, ones, 0).astype(np.uint32))
                for w in ws)
    nnz = sum((w != 0).astype(np.int64) for w in ws)
    base = jnp.asarray((h_q - nnz + f_max).astype(np.int32))
    return signs6, nz6, base, f_max


def threshold_lut(betas, scale: float, f_max: int,
                  fmt: Optional[FixedPoint] = None) -> np.ndarray:
    """(len(betas), 2*f_max+1) uint32 acceptance thresholds.

    Row b, column f + f_max holds T such that the p-bit update at inverse
    temperature betas[b] and integer field f accepts +1 iff the raw 24-bit
    LFSR draw u satisfies u >= T.  ``fmt`` (the s{a}{b} activation format of
    the f32 path) folds into the table for free: the activation is rounded
    and saturated *before* tanh, exactly as the float kernel would.

    Monotone in beta by construction: for f > 0 rows are non-increasing
    down the staircase, for f < 0 non-decreasing, and T(f=0) == 2^23.
    Each *row* is monotone non-increasing in f (beta >= 0 and tanh is
    monotone) — the invariant :func:`lut_accept`'s rank count relies on.
    """
    betas = np.asarray(betas, np.float64).reshape(-1)
    if (betas < 0).any():
        raise ValueError("threshold LUTs need beta >= 0 (rows must be "
                         "monotone in the field for the rank-count accept)")
    f = np.arange(-int(f_max), int(f_max) + 1, dtype=np.float64)
    act = betas[:, None] * (float(scale) * f)[None, :]
    if fmt is not None:
        act = np.clip(np.round(act / fmt.step) * fmt.step, fmt.lo, fmt.hi)
    t = np.ceil((1.0 - np.tanh(act)) * _HALF)
    return np.clip(t, 0, 1 << LFSR_UNIFORM_BITS).astype(np.uint32)


def threshold_lut_cached(cache: dict, table: np.ndarray, scale: float,
                         f_max: int,
                         fmt: Optional[FixedPoint] = None) -> jnp.ndarray:
    """Device-resident :func:`threshold_lut`, memoized in the caller-owned
    ``cache`` — the one LUT-construction path shared by every engine.  The
    key covers everything that determines the table, so one cache dict may
    be shared across problems."""
    key = (table.tobytes(), float(scale), int(f_max), fmt)
    if key not in cache:
        cache[key] = jnp.asarray(threshold_lut(table, scale, f_max, fmt=fmt))
    return cache[key]
