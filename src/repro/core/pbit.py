"""The p-bit update rule and its numeric-format options.

Paper Sec. II:  m_i = sgn[tanh(I_i) + r],  r ~ U(-1, 1),
I_i = beta * (h_i + sum_j J_ij m_j).

Numeric formats (paper Methods): the GPU baseline uses floating point +
Philox; the hardware uses fixed point s{a}{b} + on-chip LFSRs.  Both are
first-class here: ``rng='philox'`` uses jax.random, ``rng='lfsr'`` uses a
vectorized xorshift32 (one 32-bit LFSR state per p-bit, mirroring the
hardware's per-p-bit LFSR fabric).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["FixedPoint", "quantize", "pbit_update", "lfsr_init", "lfsr_next",
           "lfsr_uniform", "S41", "S43", "S46"]


@dataclasses.dataclass(frozen=True)
class FixedPoint:
    """Signed fixed point s{int_bits}{frac_bits}: step 2^-frac, saturating."""

    int_bits: int
    frac_bits: int

    @property
    def step(self) -> float:
        return 2.0 ** (-self.frac_bits)

    @property
    def lo(self) -> float:
        return -(2.0 ** self.int_bits)

    @property
    def hi(self) -> float:
        return 2.0 ** self.int_bits - self.step


S41 = FixedPoint(4, 1)  # EA benchmarks
S43 = FixedPoint(4, 3)  # Pegasus / Zephyr / 3SAT
S46 = FixedPoint(4, 6)  # G81 adaptive parallel tempering


def quantize(x: jnp.ndarray, fmt: Optional[FixedPoint]) -> jnp.ndarray:
    """Round-to-nearest + saturate to the fixed-point grid (no-op if fmt None)."""
    if fmt is None:
        return x
    q = jnp.round(x / fmt.step) * fmt.step
    return jnp.clip(q, fmt.lo, fmt.hi)


def pbit_update(field: jnp.ndarray, beta, rand_u: jnp.ndarray,
                fmt: Optional[FixedPoint] = None) -> jnp.ndarray:
    """One synchronous p-bit update for an independent (same-color) set.

    ``field`` is h + sum_j J_ij m_j (pre-beta); ``rand_u`` uniform in (-1, 1).
    Returns int8 spins in {-1, +1}.
    """
    act = quantize(beta * field, fmt)
    val = jnp.tanh(act) + rand_u
    # sgn with the (measure-zero) tie broken toward +1
    return jnp.where(val >= 0, 1, -1).astype(jnp.int8)


# ---------------------------------------------------------------------------
# LFSR (xorshift32) — the hardware RNG, vectorized one state per p-bit
# ---------------------------------------------------------------------------

def lfsr_init(n: int, seed: int) -> jnp.ndarray:
    """Nonzero uint32 states, seeded reproducibly (host-side splitmix64)."""
    rng = np.random.default_rng(np.uint64(seed) ^ np.uint64(0x9E3779B97F4A7C15))
    s = rng.integers(1, 2 ** 32, size=n, dtype=np.uint32)
    return jnp.asarray(s)


def lfsr_next(state: jnp.ndarray) -> jnp.ndarray:
    """xorshift32 step (Marsaglia); acts elementwise on uint32 states."""
    s = state
    s = s ^ (s << jnp.uint32(13))
    s = s ^ (s >> jnp.uint32(17))
    s = s ^ (s << jnp.uint32(5))
    return s


def lfsr_uniform(state: jnp.ndarray) -> jnp.ndarray:
    """Map uint32 state -> uniform float32 in (-1, 1)."""
    # keep 24 mantissa-safe bits
    bits = (state >> jnp.uint32(8)).astype(jnp.float32)
    return bits * jnp.float32(2.0 / 16777216.0) - jnp.float32(1.0)
