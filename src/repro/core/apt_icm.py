"""Adaptive parallel tempering with isoenergetic cluster moves (APT+ICM).

The algorithm of the paper's G81 result (Sec. S9, after Ref. [23]):
P independent chains each hold a full ladder of T inverse temperatures;
every sweep, neighboring-temperature replicas attempt a Metropolis exchange
(acceptance min(1, exp((b2-b1)(E2-E1)))); every ``icm_every`` sweeps, chain
pairs at the same temperature perform a Houdayer isoenergetic cluster move —
a connected cluster of disagreeing spins is flipped in both replicas,
preserving E1+E2 while hopping valleys.

The temperature ladder is placed adaptively (``adapt_ladder``): pilot runs
estimate the energy fluctuation sigma_E(beta) and betas are spaced so that
d_beta * sigma_E is roughly constant — the constant-acceptance rule used by
the APT preprocessing of Ref. [72].
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .graph import IsingGraph
from .coloring import Coloring
from .gibbs import color_fields
from .pbit import FixedPoint, quantize
from .energy import energy as direct_energy

__all__ = ["APTICM", "APTState", "adapt_ladder"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class APTState:
    m: jnp.ndarray       # (P, T, N) int8
    E: jnp.ndarray       # (P, T) f32
    key: jnp.ndarray
    sweep: jnp.ndarray
    swaps: jnp.ndarray   # accepted exchange count
    icms: jnp.ndarray    # performed cluster moves


class APTICM:
    def __init__(self, g: IsingGraph, coloring: Coloring, betas: np.ndarray,
                 chains: int = 2, fmt: Optional[FixedPoint] = None):
        if chains % 2 != 0:
            raise ValueError("chains must be even (ICM pairs)")
        self.g = g
        self.betas = jnp.asarray(betas, jnp.float32)   # (T,)
        self.T = len(betas)
        self.P = chains
        self.fmt = fmt
        self.n = g.n
        self._nodes = [jnp.asarray(grp) for grp in coloring.groups]
        self._idx = [jnp.take(g.idx, grp, axis=0) for grp in self._nodes]
        self._w = [jnp.take(g.w, grp, axis=0) for grp in self._nodes]
        self._h = [jnp.take(g.h, grp) for grp in self._nodes]
        self._step = jax.jit(self._step_impl, static_argnames=("do_icm",))

    # -- init ------------------------------------------------------------------

    def init_state(self, seed: int = 0) -> APTState:
        key = jax.random.PRNGKey(seed)
        key, sub = jax.random.split(key)
        m = jnp.where(jax.random.bernoulli(sub, 0.5, (self.P, self.T, self.n)),
                      1, -1).astype(jnp.int8)
        E = jax.vmap(jax.vmap(lambda mm: direct_energy(self.g, mm)))(m)
        zero = jnp.zeros((), jnp.int32)
        return APTState(m=m, E=E, key=key, sweep=zero, swaps=zero, icms=zero)

    # -- one replica-sweep over all (P, T) -----------------------------------------
    # The (P, T) chain/temperature grid IS a replica axis: every color phase
    # rides the same shared gather path as the engine layer's batched chains
    # (repro.core.gibbs.color_fields), with a per-replica beta.

    def _gibbs_sweep(self, m, E, key):
        beta = self.betas[None, :, None]                     # (1, T, 1)
        for c in range(len(self._nodes)):
            nodes, idx, w, h = (self._nodes[c], self._idx[c],
                                self._w[c], self._h[c])
            field = color_fields(m, idx, w, h)               # (P, T, nc)
            key, sub = jax.random.split(key)
            r = jax.random.uniform(sub, field.shape, minval=-1.0, maxval=1.0)
            act = quantize(beta * field, self.fmt)
            old = m[:, :, nodes]
            new = jnp.where(jnp.tanh(act) + r >= 0, 1, -1).astype(jnp.int8)
            E = E - ((new - old).astype(jnp.float32) * field).sum(axis=-1)
            m = m.at[:, :, nodes].set(new)
        return m, E, key

    # -- replica exchange ---------------------------------------------------------

    def _exchange(self, m, E, key, swaps):
        for offset in (0, 1):
            t0 = jnp.arange(offset, self.T - 1, 2)
            b0, b1 = self.betas[t0], self.betas[t0 + 1]
            E0, E1 = E[:, t0], E[:, t0 + 1]                  # (P, |pairs|)
            key, sub = jax.random.split(key)
            u = jax.random.uniform(sub, E0.shape)
            acc = u < jnp.exp(jnp.clip((b1 - b0) * (E1 - E0), -50.0, 50.0))
            swaps = swaps + acc.sum().astype(jnp.int32)
            accm = acc[:, :, None]
            m0, m1 = m[:, t0], m[:, t0 + 1]
            m = m.at[:, t0].set(jnp.where(accm, m1, m0))
            m = m.at[:, t0 + 1].set(jnp.where(accm, m0, m1))
            e0 = jnp.where(acc, E1, E0)
            e1 = jnp.where(acc, E0, E1)
            E = E.at[:, t0].set(e0).at[:, t0 + 1].set(e1)
        return m, E, key, swaps

    # -- isoenergetic cluster move ---------------------------------------------------

    def _icm(self, m, E, key, icms):
        """Houdayer move between chain pairs (2p, 2p+1) at every temperature."""
        g = self.g
        m1, m2 = m[0::2], m[1::2]                            # (P/2, T, N)
        q = (m1 * m2).astype(jnp.int8)
        disagree = q < 0                                     # (P/2, T, N)
        key, sub = jax.random.split(key)
        # random seed site among disagreements (fallback 0 if none)
        scores = jax.random.uniform(sub, disagree.shape) * disagree
        seed_site = jnp.argmax(scores.reshape(*disagree.shape[:2], -1), axis=-1)
        any_dis = disagree.any(axis=-1)

        cluster0 = jax.nn.one_hot(seed_site, self.n, dtype=jnp.bool_) \
            & disagree

        def grow(state):
            cl, _ = state
            # neighbor expansion through nonzero couplings
            nbr_any = jnp.zeros_like(cl)
            src = cl[:, :, g.idx]                            # (P/2, T, N, D)
            reach = (src & (g.w != 0)[None, None]).any(axis=-1)
            new = cl | (reach & disagree)
            return new, (new != cl).any()

        def cond(state):
            return state[1]

        cluster, _ = jax.lax.while_loop(cond, grow, (cluster0, jnp.bool_(True)))
        flip = cluster & any_dis[:, :, None]
        fl = jnp.where(flip, -1, 1).astype(jnp.int8)
        m1n, m2n = m1 * fl, m2 * fl
        mn = m.at[0::2].set(m1n).at[1::2].set(m2n)
        En = jax.vmap(jax.vmap(lambda mm: direct_energy(self.g, mm)))(
            mn.reshape(-1, self.n).reshape(self.P, self.T, self.n))
        icms = icms + any_dis.sum().astype(jnp.int32)
        return mn, En, key, icms

    # -- scan step --------------------------------------------------------------------

    def _step_impl(self, state: APTState, do_icm: bool) -> APTState:
        m, E, key = state.m, state.E, state.key
        m, E, key = self._gibbs_sweep(m, E, key)
        m, E, key, swaps = self._exchange(m, E, key, state.swaps)
        icms = state.icms
        if do_icm:
            m, E, key, icms = self._icm(m, E, key, icms)
        return APTState(m=m, E=E, key=key, sweep=state.sweep + 1,
                        swaps=swaps, icms=icms)

    def run(self, state: APTState, sweeps: int, icm_every: int = 10,
            record_every: int = 10):
        """Returns (state, (sweep_idx, best-energy trace))."""
        best, ts = [], []
        for t in range(1, sweeps + 1):
            state = self._step(state, do_icm=(icm_every > 0 and t % icm_every == 0))
            if t % record_every == 0 or t == sweeps:
                best.append(float(state.E.min()))
                ts.append(t)
        return state, (np.asarray(ts), np.asarray(best))

    def best_config(self, state: APTState) -> Tuple[np.ndarray, float]:
        E = np.asarray(state.E)
        p, t = np.unravel_index(np.argmin(E), E.shape)
        return np.asarray(state.m[p, t]), float(E[p, t])


def adapt_ladder(g: IsingGraph, coloring: Coloring, beta_min: float,
                 beta_max: float, n_temps: int, pilot_sweeps: int = 100,
                 seed: int = 0) -> np.ndarray:
    """Place betas so d_beta * sigma_E(beta) is ~constant (APT preprocessing)."""
    from .gibbs import GibbsEngine
    from .annealing import constant_schedule

    probe = np.geomspace(beta_min, beta_max, 8)
    sig = []
    eng = GibbsEngine(g, coloring)
    for b in probe:
        st = eng.init_state(seed=seed)
        st, (Etr, _) = eng.run_dense(
            st, constant_schedule(float(b), pilot_sweeps).beta_array())
        tail = np.asarray(Etr)[pilot_sweeps // 2:]
        sig.append(max(float(tail.std()), 1e-6))
    sig = np.asarray(sig)
    # integrate d_beta proportional to 1/sigma between probes
    dens = 1.0 / np.interp(np.linspace(beta_min, beta_max, 512), probe, sig)
    cum = np.concatenate([[0.0], np.cumsum(dens)])
    cum /= cum[-1]
    grid = np.linspace(beta_min, beta_max, 513)
    targets = np.linspace(0, 1, n_temps)
    return np.interp(targets, cum, grid)
