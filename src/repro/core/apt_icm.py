"""Adaptive parallel tempering with isoenergetic cluster moves (APT+ICM).

The algorithm of the paper's G81 result (Sec. S9, after Ref. [23]):
P independent chains each hold a full ladder of T inverse temperatures;
every sweep, neighboring-temperature replicas attempt a Metropolis exchange
(acceptance min(1, exp((b2-b1)(E2-E1)))); every ``icm_every`` sweeps, chain
pairs at the same temperature perform a Houdayer isoenergetic cluster move —
a connected cluster of disagreeing spins is flipped in both replicas,
preserving E1+E2 while hopping valleys.

The temperature ladder is placed adaptively (``adapt_ladder``): pilot runs
estimate the energy fluctuation sigma_E(beta) and betas are spaced so that
d_beta * sigma_E is roughly constant — the constant-acceptance rule used by
the APT preprocessing of Ref. [72].

Three execution modes share the algorithm:

* ``rng="philox"`` (default) — the floating reference: f32 fields,
  tanh + uniform compare.
* ``rng="lfsr"`` — the fixed-point pipeline: int8 quantized couplings,
  int32 field accumulation, one per-(p-bit, chain, temperature) xorshift32
  LFSR, and the accept as a LUT-threshold compare of the raw 24-bit draw
  (one LUT row per ladder temperature).
* ``packed=True`` (requires ``rng="lfsr"``) — the whole (chains x
  temperatures) grid rides the bit lanes of stacked uint32 word planes:
  lane ``l = p*T + t`` is chain p at temperature t, living at word plane
  ``l // 32``, bit ``l % 32`` — so a ladder of up to
  ``MAX_LANE_WORDS * 32`` lanes (G81-class T = 64 ladders included) packs
  into W = ceil(P*T/32) planes.  The sweep runs the XOR / carry-save-adder
  word field with a per-lane LUT-row fan, replica-exchange swap moves
  become *lane permutations* — cross-word transpositions are the same one
  bit gather/scatter applied to every site
  (:func:`repro.core.packing.lane_permute`) — and the ICM disagreement set
  is a per-pair bit extraction across the planes.  Packed trajectories are
  bit-identical to the unpacked ``rng="lfsr"`` run at matched seeds.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .graph import IsingGraph
from .coloring import Coloring
from .gibbs import color_fields
from .pbit import (FixedPoint, LUT_SELECT_MAX_WIDTH, bitplane_planes,
                   field_bound, lfsr_init, lfsr_next, quantize,
                   quantize_couplings, threshold_lut)
from .packing import LANE_WIDTH, lane_coords, lane_permute, pack_lanes, \
    unpack_lanes
from .energy import energy as direct_energy
from repro.engines.base import check_lanes
from repro.kernels.ops import bitplane_gather_count_op

__all__ = ["APTICM", "APTState", "adapt_ladder"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class APTState:
    m: jnp.ndarray       # (P, T, N) int8 — or (W, N) uint32 word planes when packed
    E: jnp.ndarray       # (P, T) f32
    key: jnp.ndarray     # philox stream (exchange/ICM draws in every mode)
    sweep: jnp.ndarray
    swaps: jnp.ndarray   # accepted exchange count
    icms: jnp.ndarray    # performed cluster moves
    lfsr: Optional[jnp.ndarray] = None   # (P, T, N) | (L, N) uint32 states


class APTICM:
    def __init__(self, g: IsingGraph, coloring: Coloring, betas: np.ndarray,
                 chains: int = 2, fmt: Optional[FixedPoint] = None,
                 rng: str = "philox", packed: bool = False):
        if chains % 2 != 0:
            raise ValueError("chains must be even (ICM pairs)")
        if rng not in ("philox", "lfsr"):
            raise ValueError(f"unknown rng {rng!r}")
        if packed and rng != "lfsr":
            raise ValueError("packed=True runs the fixed-point word "
                             "pipeline; it needs rng='lfsr'")
        self.g = g
        self.betas = jnp.asarray(betas, jnp.float32)   # (T,)
        self.T = len(betas)
        self.P = chains
        self.L = self.P * self.T          # word lanes of the packed grid
        self.fmt = fmt
        self.rng_kind = rng
        self.packed = bool(packed)
        # packed grids stack word planes: lane l -> (word l//32, bit l%32)
        self.words = check_lanes("bitplane", self.L,
                                 what="chains*temperatures") if packed else 1
        self.n = g.n
        self._nodes = [jnp.asarray(grp) for grp in coloring.groups]
        self._idx = [jnp.take(g.idx, grp, axis=0) for grp in self._nodes]
        self._w = [jnp.take(g.w, grp, axis=0) for grp in self._nodes]
        self._h = [jnp.take(g.h, grp) for grp in self._nodes]
        if rng == "lfsr":
            h_q, (w_q,), self.q_scale = quantize_couplings(g.h, (g.w,))
            wq = np.asarray(w_q)
            dirs = tuple(wq[:, d] for d in range(wq.shape[-1]))
            self.f_max = field_bound(h_q, dirs)
            lut = threshold_lut(np.asarray(betas), self.q_scale, self.f_max,
                                fmt=fmt)
            self._lut = jnp.asarray(lut)               # (T, 2*f_max+1)
            self._w_q = [jnp.take(w_q, grp, axis=0) for grp in self._nodes]
            self._h_q = [jnp.take(h_q, grp) for grp in self._nodes]
            # unpacked per-temperature threshold rows, broadcast-ready
            # against (P, T, nc) fields
            self._thr_T = self._lut[None, :, None, :]
        if packed:
            signs, nz, base, _ = bitplane_planes(h_q, dirs)
            signs_nd = jnp.stack(signs, axis=-1)       # (N, D) uint32
            nz_nd = jnp.stack(nz, axis=-1)
            self._signs = [jnp.take(signs_nd, grp, axis=0)
                           for grp in self._nodes]
            self._nz = [jnp.take(nz_nd, grp, axis=0) for grp in self._nodes]
            self._base = [jnp.take(base, grp) for grp in self._nodes]
            # per-lane LUT-row fan: lane l = p*T + t reads row t
            lane_rows = np.tile(np.arange(self.T), self.P)
            self._thr_lanes = self._lut[jnp.asarray(lane_rows)][:, None, :]
            # per-lane (word, bit) coordinates for the gather/scatter fans
            self._lane_w, self._lane_b = lane_coords(self.L, 1)
            # even-chain lane ids (the ICM pair anchors): lane(2p, t); the
            # paired chain sits T lanes up — lane(2p+1, t) = lane(2p, t) + T.
            # Pairs may straddle word boundaries, so each side carries its
            # own (word, bit) coordinates.
            even = np.asarray([[2 * p * self.T + t for t in range(self.T)]
                               for p in range(self.P // 2)], np.int64)
            odd = even + self.T
            self._ev_w = jnp.asarray((even // LANE_WIDTH).astype(np.int32))
            self._ev_b = jnp.asarray(
                (even % LANE_WIDTH).astype(np.uint32))[:, :, None]
            self._od_w = jnp.asarray((odd // LANE_WIDTH).astype(np.int32))
            self._od_b = jnp.asarray(
                (odd % LANE_WIDTH).astype(np.uint32))[:, :, None]
        self._step = jax.jit(self._step_impl, static_argnames=("do_icm",))

    # -- init ------------------------------------------------------------------

    def init_state(self, seed: int = 0) -> APTState:
        """Fresh state; the initial spins (and hence energies) are derived
        identically in every mode, so packed and unpacked-lfsr runs start
        from the same configurations at the same seed."""
        key = jax.random.PRNGKey(seed)
        key, sub = jax.random.split(key)
        m = jnp.where(jax.random.bernoulli(sub, 0.5, (self.P, self.T, self.n)),
                      1, -1).astype(jnp.int8)
        E = jax.vmap(jax.vmap(lambda mm: direct_energy(self.g, mm)))(m)
        zero = jnp.zeros((), jnp.int32)
        lfsr = None
        if self.rng_kind == "lfsr":
            lfsr = lfsr_init(self.L * self.n, seed)
            lfsr = lfsr.reshape(self.L, self.n) if self.packed else \
                lfsr.reshape(self.P, self.T, self.n)
        if self.packed:
            m = pack_lanes(m.reshape(self.L, self.n))      # (W, N) words
        return APTState(m=m, E=E, key=key, sweep=zero, swaps=zero,
                        icms=zero, lfsr=lfsr)

    # -- one replica-sweep over all (P, T) -----------------------------------------
    # The (P, T) chain/temperature grid IS a replica axis: every color phase
    # rides the same shared gather path as the engine layer's batched chains
    # (repro.core.gibbs.color_fields), with a per-replica beta — and in
    # packed mode the whole grid is 32 bit lanes of one word per site.

    def _gibbs_sweep(self, m, E, key):
        beta = self.betas[None, :, None]                     # (1, T, 1)
        for c in range(len(self._nodes)):
            nodes, idx, w, h = (self._nodes[c], self._idx[c],
                                self._w[c], self._h[c])
            field = color_fields(m, idx, w, h)               # (P, T, nc)
            key, sub = jax.random.split(key)
            r = jax.random.uniform(sub, field.shape, minval=-1.0, maxval=1.0)
            act = quantize(beta * field, self.fmt)
            old = m[:, :, nodes]
            new = jnp.where(jnp.tanh(act) + r >= 0, 1, -1).astype(jnp.int8)
            E = E - ((new - old).astype(jnp.float32) * field).sum(axis=-1)
            m = m.at[:, :, nodes].set(new)
        return m, E, key

    def _accept_rows(self, thr, field, u):
        """LUT accept with broadcast per-row thresholds (the per-lane /
        per-temperature fan form of :func:`repro.core.pbit.lut_accept`):
        rank-count against each row's entries, valid because rows are
        monotone nonincreasing in the field index.  Wide rows (non-+-J
        couplings blow f_max up to int8 magnitudes) fall back to a gather,
        mirroring ``lut_accept``'s cap, so the unroll never exceeds
        ``LUT_SELECT_MAX_WIDTH`` compares per phase."""
        lw = int(thr.shape[-1])
        idx = jnp.clip(field + self.f_max, 0, lw - 1)
        if lw <= LUT_SELECT_MAX_WIDTH:
            count = jnp.zeros(u.shape, jnp.int32)
            for k in range(lw):
                count = count + (u >= thr[..., k]).astype(jnp.int32)
            return idx + count >= lw
        return u >= jnp.take_along_axis(
            jnp.broadcast_to(thr, u.shape + (lw,)), idx[..., None],
            axis=-1)[..., 0]

    def _gibbs_sweep_int(self, m, E, lfsr):
        """Unpacked fixed-point sweep: integer fields, per-(p,t,site) LFSR
        draws, per-temperature LUT rows.  The reference the packed word
        sweep is bit-identical to."""
        scale = jnp.float32(self.q_scale)
        i32 = jnp.int32
        for c in range(len(self._nodes)):
            nodes, idx = self._nodes[c], self._idx[c]
            nbr = m[:, :, idx].astype(i32)                   # (P, T, nc, D)
            field = self._h_q[c].astype(i32) + \
                (self._w_q[c].astype(i32) * nbr).sum(axis=-1)
            s = lfsr[:, :, nodes]
            s = lfsr_next(s)
            lfsr = lfsr.at[:, :, nodes].set(s)
            u = s >> jnp.uint32(8)
            accept = self._accept_rows(self._thr_T, field, u)
            old = m[:, :, nodes]
            new = jnp.where(accept, 1, -1).astype(jnp.int8)
            E = E - ((new - old).astype(jnp.float32)
                     * field.astype(jnp.float32)).sum(axis=-1) * scale
            m = m.at[:, :, nodes].set(new)
        return m, E, lfsr

    def _gibbs_sweep_packed(self, mw, E, lfsr):
        """Word sweep: XOR sign application + carry-save adder tree for the
        per-lane field, per-lane LFSR columns, per-lane LUT-row fan.  Lane
        l reads bit ``l % 32`` of word plane ``l // 32`` (``mw`` is (W, N));
        lane scatters land on disjoint bits, so ``.add`` composes them."""
        scale = jnp.float32(self.q_scale)
        wl, bl = self._lane_w, self._lane_b                  # (L,), (L, 1)
        one = jnp.uint32(1)
        i32 = jnp.int32
        Ef = E.reshape(-1)                                   # (L,)
        for c in range(len(self._nodes)):
            nodes = self._nodes[c]
            counts = bitplane_gather_count_op(
                mw, self._idx[c], self._signs[c], self._nz[c])
            s = lfsr[:, nodes]
            s = lfsr_next(s)
            lfsr = lfsr.at[:, nodes].set(s)
            u = s >> jnp.uint32(8)                           # (L, nc)
            cnt = jnp.zeros(u.shape, i32)
            for i, b in enumerate(counts):                   # b: (W, nc)
                cnt = cnt + (((b[wl] >> bl) & one)
                             << jnp.uint32(i)).astype(i32)
            field = self._base[c][None, :] - self.f_max + 2 * cnt
            accept = self._accept_rows(self._thr_lanes, field, u)
            mwn = mw[:, nodes]                               # (W, nc)
            oldb = (mwn[wl] >> bl) & one
            old = jnp.where(oldb != 0, 1, -1)
            new = jnp.where(accept, 1, -1)
            Ef = Ef - ((new - old).astype(jnp.float32)
                       * field.astype(jnp.float32)).sum(axis=-1) * scale
            upd = jnp.zeros(mwn.shape, jnp.uint32) \
                .at[wl].add(accept.astype(jnp.uint32) << bl)
            mw = mw.at[:, nodes].set(upd)
        return mw, Ef.reshape(self.P, self.T), lfsr

    # -- replica exchange ---------------------------------------------------------

    def _exchange(self, m, E, key, swaps):
        for offset in (0, 1):
            t0 = jnp.arange(offset, self.T - 1, 2)
            b0, b1 = self.betas[t0], self.betas[t0 + 1]
            E0, E1 = E[:, t0], E[:, t0 + 1]                  # (P, |pairs|)
            key, sub = jax.random.split(key)
            u = jax.random.uniform(sub, E0.shape)
            acc = u < jnp.exp(jnp.clip((b1 - b0) * (E1 - E0), -50.0, 50.0))
            swaps = swaps + acc.sum().astype(jnp.int32)
            accm = acc[:, :, None]
            m0, m1 = m[:, t0], m[:, t0 + 1]
            m = m.at[:, t0].set(jnp.where(accm, m1, m0))
            m = m.at[:, t0 + 1].set(jnp.where(accm, m0, m1))
            e0 = jnp.where(acc, E1, E0)
            e1 = jnp.where(acc, E0, E1)
            E = E.at[:, t0].set(e0).at[:, t0 + 1].set(e1)
        return m, E, key, swaps

    def _exchange_packed(self, mw, E, key, swaps):
        """Replica exchange as a lane permutation: the accepted swap set of
        one offset pass is ONE permutation of the word lanes (a bit
        gather/scatter applied to every site's word) plus the matching
        permutation of the per-lane energies — the per-lane LUT rows stay
        pinned to their lane's temperature, so no state re-labeling is
        needed.  Acceptance draws consume the philox key exactly like the
        unpacked pass (same shapes, same order), keeping the two modes
        bit-identical."""
        for offset in (0, 1):
            t0 = jnp.arange(offset, self.T - 1, 2)
            b0, b1 = self.betas[t0], self.betas[t0 + 1]
            E0, E1 = E[:, t0], E[:, t0 + 1]                  # (P, |pairs|)
            key, sub = jax.random.split(key)
            u = jax.random.uniform(sub, E0.shape)
            acc = u < jnp.exp(jnp.clip((b1 - b0) * (E1 - E0), -50.0, 50.0))
            swaps = swaps + acc.sum().astype(jnp.int32)
            l0 = (jnp.arange(self.P, dtype=jnp.int32)[:, None] * self.T
                  + t0[None, :].astype(jnp.int32)).reshape(-1)
            accf = acc.reshape(-1)
            perm = jnp.arange(self.L, dtype=jnp.int32)
            perm = perm.at[l0].set(jnp.where(accf, l0 + 1, l0))
            perm = perm.at[l0 + 1].set(jnp.where(accf, l0, l0 + 1))
            mw = lane_permute(mw, perm)
            E = E.reshape(-1)[perm].reshape(self.P, self.T)
        return mw, E, key, swaps

    # -- isoenergetic cluster move ---------------------------------------------------

    def _grow_cluster(self, cluster0, disagree):
        """Expand a seed cluster through nonzero couplings, confined to the
        disagreement set, to a fixed point."""
        g = self.g

        def grow(state):
            cl, _ = state
            src = cl[:, :, g.idx]                            # (P/2, T, N, D)
            reach = (src & (g.w != 0)[None, None]).any(axis=-1)
            new = cl | (reach & disagree)
            return new, (new != cl).any()

        return jax.lax.while_loop(lambda s: s[1], grow,
                                  (cluster0, jnp.bool_(True)))[0]

    def _icm(self, m, E, key, icms):
        """Houdayer move between chain pairs (2p, 2p+1) at every temperature."""
        m1, m2 = m[0::2], m[1::2]                            # (P/2, T, N)
        disagree = (m1 * m2) < 0                             # (P/2, T, N)
        key, sub = jax.random.split(key)
        # random seed site among disagreements (fallback 0 if none)
        scores = jax.random.uniform(sub, disagree.shape) * disagree
        seed_site = jnp.argmax(scores.reshape(*disagree.shape[:2], -1), axis=-1)
        any_dis = disagree.any(axis=-1)

        cluster0 = jax.nn.one_hot(seed_site, self.n, dtype=jnp.bool_) \
            & disagree
        cluster = self._grow_cluster(cluster0, disagree)
        flip = cluster & any_dis[:, :, None]
        fl = jnp.where(flip, -1, 1).astype(jnp.int8)
        m1n, m2n = m1 * fl, m2 * fl
        mn = m.at[0::2].set(m1n).at[1::2].set(m2n)
        En = jax.vmap(jax.vmap(lambda mm: direct_energy(self.g, mm)))(mn)
        icms = icms + any_dis.sum().astype(jnp.int32)
        return mn, En, key, icms

    def _icm_packed(self, mw, E, key, icms):
        """Houdayer move on XOR'd disagreement bits: chain pair (2p, 2p+1)
        at temperature t disagrees exactly where the pair's two lane bits —
        extracted at each lane's own (word, bit) coordinate, since pairs
        may straddle word planes — differ.  The cluster flip is one XOR
        plane scattered back onto both lanes of each pair (disjoint lane
        bits, so the two scatter-adds compose)."""
        one = jnp.uint32(1)
        disagree = (((mw[self._ev_w] >> self._ev_b)
                     ^ (mw[self._od_w] >> self._od_b)) & one) \
            .astype(bool)                                    # (P/2, T, N)
        key, sub = jax.random.split(key)
        scores = jax.random.uniform(sub, disagree.shape) * disagree
        seed_site = jnp.argmax(scores.reshape(*disagree.shape[:2], -1),
                               axis=-1)
        any_dis = disagree.any(axis=-1)
        cluster0 = jax.nn.one_hot(seed_site, self.n, dtype=jnp.bool_) \
            & disagree
        cluster = self._grow_cluster(cluster0, disagree)
        flip = cluster & any_dis[:, :, None]
        fl = flip.astype(jnp.uint32)
        fw = jnp.zeros_like(mw) \
            .at[self._ev_w].add(fl << self._ev_b) \
            .at[self._od_w].add(fl << self._od_b)
        mw = mw ^ fw                                         # flip both lanes
        spins = unpack_lanes(mw, self.L).reshape(self.P, self.T, self.n)
        En = jax.vmap(jax.vmap(lambda mm: direct_energy(self.g, mm)))(spins)
        icms = icms + any_dis.sum().astype(jnp.int32)
        return mw, En, key, icms

    # -- scan step --------------------------------------------------------------------

    def _step_impl(self, state: APTState, do_icm: bool) -> APTState:
        m, E, key, lfsr = state.m, state.E, state.key, state.lfsr
        if self.packed:
            m, E, lfsr = self._gibbs_sweep_packed(m, E, lfsr)
            m, E, key, swaps = self._exchange_packed(m, E, key, state.swaps)
            icms = state.icms
            if do_icm:
                m, E, key, icms = self._icm_packed(m, E, key, icms)
        else:
            if self.rng_kind == "lfsr":
                m, E, lfsr = self._gibbs_sweep_int(m, E, lfsr)
            else:
                m, E, key = self._gibbs_sweep(m, E, key)
            m, E, key, swaps = self._exchange(m, E, key, state.swaps)
            icms = state.icms
            if do_icm:
                m, E, key, icms = self._icm(m, E, key, icms)
        return APTState(m=m, E=E, key=key, sweep=state.sweep + 1,
                        swaps=swaps, icms=icms, lfsr=lfsr)

    def run(self, state: APTState, sweeps: int, icm_every: int = 10,
            record_every: int = 10):
        """Returns (state, (sweep_idx, best-energy trace))."""
        best, ts = [], []
        for t in range(1, sweeps + 1):
            state = self._step(state, do_icm=(icm_every > 0 and t % icm_every == 0))
            if t % record_every == 0 or t == sweeps:
                best.append(float(state.E.min()))
                ts.append(t)
        return state, (np.asarray(ts), np.asarray(best))

    def spins(self, state: APTState) -> jnp.ndarray:
        """(P, T, N) int8 spins in every mode (packed states unpack)."""
        if self.packed:
            return unpack_lanes(state.m, self.L).reshape(
                self.P, self.T, self.n)
        return state.m

    def best_config(self, state: APTState) -> Tuple[np.ndarray, float]:
        E = np.asarray(state.E)
        p, t = np.unravel_index(np.argmin(E), E.shape)
        return np.asarray(self.spins(state)[p, t]), float(E[p, t])


def adapt_ladder(g: IsingGraph, coloring: Coloring, beta_min: float,
                 beta_max: float, n_temps: int, pilot_sweeps: int = 100,
                 seed: int = 0) -> np.ndarray:
    """Place betas so d_beta * sigma_E(beta) is ~constant (APT preprocessing)."""
    from .gibbs import GibbsEngine
    from .annealing import constant_schedule

    probe = np.geomspace(beta_min, beta_max, 8)
    sig = []
    eng = GibbsEngine(g, coloring)
    for b in probe:
        st = eng.init_state(seed=seed)
        st, (Etr, _) = eng.run_dense(
            st, constant_schedule(float(b), pilot_sweeps).beta_array())
        tail = np.asarray(Etr)[pilot_sweeps // 2:]
        sig.append(max(float(tail.std()), 1e-6))
    sig = np.asarray(sig)
    # integrate d_beta proportional to 1/sigma between probes
    dens = 1.0 / np.interp(np.linspace(beta_min, beta_max, 512), probe, sig)
    cum = np.concatenate([[0.0], np.cumsum(dens)])
    cum /= cum[-1]
    grid = np.linspace(beta_min, beta_max, 513)
    targets = np.linspace(0, 1, n_temps)
    return np.interp(targets, cum, grid)
