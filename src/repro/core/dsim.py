"""Distributed Sparse Ising Machine — partitioned Gibbs sampling with
shadow weights and tunably-stale 1-bit boundary exchange (the paper's core).

Construction (host side, numpy): the graph is partitioned into K clusters;
cut-edge weights are duplicated on both sides (*shadow weights*), so each
cluster evaluates every local field from cluster-local memory.  The only
cross-cluster quantity is the boundary p-bit *state*, refreshed every
``sync_every = S`` sweeps:

  mode='dsim' : ghosts get the instantaneous boundary states (hardware).
  mode='cmft' : ghosts get the mean over the last S sweeps (parallel cluster
                mean-field theory, Supplementary S3).

``sync_every``:
  'phase'  : refresh before every color phase -> EXACTLY the monolithic
             chromatic dynamics (the proper coloring guarantees a neighbor in
             another cluster is never updated in the same phase), i.e. the
             eta -> infinity limit of Fig. 3.
  S >= 1   : refresh every S sweeps (eta ~ 1/S); the stale regime.
  None     : never refresh (the paper's disconnected-links control, S7).

Two numerically identical backends share this layout:
  * stacked   — all K partitions batched on the leading axis of one device
                (used for experiments and tests on CPU);
  * shard_map — the leading axis laid across a mesh axis; the exchange
                becomes an all-gather of the packed boundary states
                (``repro.core.dsim_dist``).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from .graph import IsingGraph
from .coloring import Coloring
from .annealing import ArraySchedule, beta_row_indices, beta_table
from .pbit import (FixedPoint, field_bound, quantize, quantize_couplings,
                   threshold_lut_cached, lut_accept, lfsr_init, lfsr_next,
                   lfsr_uniform)
from .energy import energy as direct_energy
from repro.engines.base import (RecordedCursor, run_recorded_driver, spawn_seeds,
                                stack_states)

__all__ = ["PartitionedProblem", "build_partitioned", "DSIMEngine", "DSIMState"]


@dataclasses.dataclass(frozen=True)
class PartitionedProblem:
    """Device-ready partitioned graph with shadow weights and ghost slots."""

    K: int
    n: int                      # global number of p-bits
    n_max: int                  # local slots per partition (padded)
    g_max: int                  # ghost slots per partition (padded)
    local_idx: jnp.ndarray      # (K, n_max, D) int32 into [0, n_max + g_max)
    local_w: jnp.ndarray        # (K, n_max, D) f32 (shadow weights included)
    local_h: jnp.ndarray        # (K, n_max) f32
    valid: jnp.ndarray          # (K, n_max) bool
    ghost_src: jnp.ndarray      # (K, g_max) int32 flat into K * n_max
    global_ids: jnp.ndarray     # (K, n_max) int32, padding -> n (dump slot)
    color_slots: tuple          # per color: (K, nc_max) int32 local slots
    color_mask: tuple           # per color: (K, nc_max) bool
    # boundary packing (for the distributed backend): slots each partition
    # must publish, and ghost_src re-indexed into the packed boundary pool
    bnd_slots: jnp.ndarray      # (K, b_max) int32 local slots (pad 0)
    bnd_mask: jnp.ndarray       # (K, b_max) bool
    ghost_src_packed: jnp.ndarray  # (K, g_max) int32 flat into K * b_max
    labels: np.ndarray = dataclasses.field(compare=False)  # (N,) original labels
    graph: IsingGraph = dataclasses.field(compare=False)

    @property
    def b_max(self) -> int:
        return int(self.bnd_slots.shape[1])


def build_partitioned(g: IsingGraph, coloring: Coloring,
                      labels: np.ndarray, K: int) -> PartitionedProblem:
    idx = np.asarray(g.idx)
    w = np.asarray(g.w)
    h = np.asarray(g.h)
    colors = coloring.colors
    n, dmax = idx.shape
    labels = np.asarray(labels, dtype=np.int32)
    if labels.shape != (n,):
        raise ValueError("labels shape mismatch")

    locals_ = [np.nonzero(labels == k)[0] for k in range(K)]
    n_max = max(max(len(l) for l in locals_), 1)
    slot_of = np.zeros(n, dtype=np.int64)
    for k in range(K):
        slot_of[locals_[k]] = np.arange(len(locals_[k]))

    ghosts, g_sizes = [], []
    for k in range(K):
        rows = idx[locals_[k]]
        msk = w[locals_[k]] != 0
        nb = rows[msk]
        ext = np.unique(nb[labels[nb] != k])
        ghosts.append(ext)
        g_sizes.append(len(ext))
    g_max = max(max(g_sizes), 1)

    local_idx = np.zeros((K, n_max, dmax), dtype=np.int32)
    local_w = np.zeros((K, n_max, dmax), dtype=np.float32)
    local_h = np.zeros((K, n_max), dtype=np.float32)
    valid = np.zeros((K, n_max), dtype=bool)
    ghost_src = np.zeros((K, g_max), dtype=np.int32)
    global_ids = np.full((K, n_max), n, dtype=np.int32)

    for k in range(K):
        loc = locals_[k]
        nk = len(loc)
        valid[k, :nk] = True
        global_ids[k, :nk] = loc
        local_h[k, :nk] = h[loc]
        rows = idx[loc]                       # (nk, D)
        ww = w[loc]
        local_w[k, :nk] = ww
        ext = ghosts[k]
        # map neighbor ids: local -> slot, external -> n_max + ghost position
        is_ext = (labels[rows] != k) & (ww != 0)
        mapped = np.where(ww != 0, slot_of[rows], 0)
        if len(ext):
            gpos = np.searchsorted(ext, rows)
            gpos = np.clip(gpos, 0, len(ext) - 1)
            mapped = np.where(is_ext, n_max + gpos, mapped)
        local_idx[k, :nk] = mapped
        if len(ext):
            ghost_src[k, :len(ext)] = labels[ext] * n_max + slot_of[ext]

    # per-color slot lists
    color_slots, color_mask = [], []
    for c in range(coloring.n_colors):
        sizes = [int((colors[locals_[k]] == c).sum()) for k in range(K)]
        nc_max = max(max(sizes), 1)
        cs = np.zeros((K, nc_max), dtype=np.int32)
        cm = np.zeros((K, nc_max), dtype=bool)
        for k in range(K):
            sel = np.nonzero(colors[locals_[k]] == c)[0]
            cs[k, :len(sel)] = sel
            cm[k, :len(sel)] = True
        color_slots.append(jnp.asarray(cs))
        color_mask.append(jnp.asarray(cm))

    # boundary publication lists: slots of k referenced by any other partition
    bnd = []
    referenced = np.zeros((K, n_max), dtype=bool)
    for k in range(K):
        ext = ghosts[k]
        referenced[labels[ext], slot_of[ext]] = True
    b_sizes = [int(referenced[k].sum()) for k in range(K)]
    b_max = max(max(b_sizes), 1)
    bnd_slots = np.zeros((K, b_max), dtype=np.int32)
    bnd_mask = np.zeros((K, b_max), dtype=bool)
    packed_pos = np.full((K, n_max), -1, dtype=np.int64)  # slot -> packed col
    for k in range(K):
        sl = np.nonzero(referenced[k])[0]
        bnd_slots[k, :len(sl)] = sl
        bnd_mask[k, :len(sl)] = True
        packed_pos[k, sl] = np.arange(len(sl))
    gk = ghost_src // n_max
    gs = ghost_src % n_max
    ghost_src_packed = (gk * b_max + packed_pos[gk, gs]).astype(np.int32)
    ghost_src_packed = np.where(ghost_src_packed < 0, 0, ghost_src_packed)

    return PartitionedProblem(
        K=K, n=n, n_max=n_max, g_max=g_max,
        local_idx=jnp.asarray(local_idx), local_w=jnp.asarray(local_w),
        local_h=jnp.asarray(local_h), valid=jnp.asarray(valid),
        ghost_src=jnp.asarray(ghost_src), global_ids=jnp.asarray(global_ids),
        color_slots=tuple(color_slots), color_mask=tuple(color_mask),
        bnd_slots=jnp.asarray(bnd_slots), bnd_mask=jnp.asarray(bnd_mask),
        ghost_src_packed=jnp.asarray(ghost_src_packed),
        labels=labels, graph=g,
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DSIMState:
    m: jnp.ndarray        # (K, n_max) int8 local spins — (R, K, n_max) batched
    ghosts: jnp.ndarray   # (K, g_max) f32 (instantaneous +-1 or CMFT means)
    macc: jnp.ndarray     # (K, n_max) f32 window accumulator (CMFT)
    rng: jnp.ndarray      # philox key | (K, n_max) uint32 LFSR states
    sweep: jnp.ndarray    # scalar int32
    flips: jnp.ndarray    # scalar int32 modular odometer (exact total is
                          # accumulated host-side by the recording driver)


SyncSpec = Union[int, str, None]


class DSIMEngine:
    """Partitioned chromatic Gibbs sampler (stacked single-device backend).

    ``precision="int8"`` runs the hardware's fixed-point pipeline: local
    couplings/biases quantized to int8 at init (one per-problem scale),
    int32 field accumulation, and the tanh + float compare replaced by one
    unsigned compare of the raw 24-bit LFSR draw against a per-(beta, field)
    threshold LUT; annealing staircases become LUT row indices.  Requires
    ``rng='lfsr'`` and ``mode='dsim'``; ``fmt`` folds into the LUT."""

    def __init__(self, prob: PartitionedProblem, rng: str = "philox",
                 fmt: Optional[FixedPoint] = None, mode: str = "dsim",
                 precision: str = "f32"):
        if mode not in ("dsim", "cmft"):
            raise ValueError(f"unknown mode {mode!r}")
        if rng not in ("philox", "lfsr"):
            raise ValueError(f"unknown rng {rng!r}")
        if precision not in ("f32", "int8"):
            raise ValueError(f"unknown precision {precision!r}")
        if precision == "int8" and (rng != "lfsr" or mode != "dsim"):
            # the fixed-point path is the hardware pipeline: per-p-bit LFSRs
            # (the LUT thresholds the raw 24-bit draw) and instantaneous +-1
            # ghosts (cmft's fractional window-means don't fit integer fields)
            raise ValueError("precision='int8' needs rng='lfsr', mode='dsim'")
        self.p = prob
        self.rng_kind = rng
        self.fmt = fmt
        self.mode = mode
        self.precision = precision
        if precision == "int8":
            self.local_h_q, (self.local_w_q,), self.q_scale = \
                quantize_couplings(prob.local_h, (prob.local_w,))
            wq = np.asarray(self.local_w_q)
            self.f_max = field_bound(
                self.local_h_q, tuple(wq[..., d] for d in range(wq.shape[-1])))
            self._lut_cache = {}
        self._rows = jnp.arange(prob.K)[:, None]
        self._chunk_cache = {}
        self._energy = jax.jit(self._energy_impl)

    def _lut_for(self, table: np.ndarray) -> jnp.ndarray:
        return threshold_lut_cached(self._lut_cache, table, self.q_scale,
                                    self.f_max, fmt=self.fmt)

    # -- state -----------------------------------------------------------------

    def init_state(self, seed: int = 0, m0: Optional[np.ndarray] = None,
                   replicas: Optional[int] = None,
                   seeds: Optional[Sequence[int]] = None) -> DSIMState:
        """Fresh state; ``replicas=R`` stacks R independent chains along a
        new leading axis (independent RNG streams from spawned seeds).
        ``seeds=[...]`` gives every chain its own explicit seed — the
        packed-batch path, where replica r's trajectory depends only on
        seeds[r] (co-packed tenants never perturb each other)."""
        if seeds is not None:
            return stack_states([self.init_state(int(s), m0=m0)
                                 for s in seeds])
        if replicas is not None:
            return stack_states([self.init_state(s, m0=m0)
                                 for s in spawn_seeds(seed, replicas)])
        p = self.p
        key = jax.random.PRNGKey(seed)
        if m0 is None:
            key, sub = jax.random.split(key)
            m = jnp.where(jax.random.bernoulli(sub, 0.5, (p.K, p.n_max)), 1, -1)
            m = m.astype(jnp.int8)
        else:
            mg = np.asarray(m0, dtype=np.int8)
            m = np.ones((p.K, p.n_max), dtype=np.int8)
            gid = np.asarray(p.global_ids)
            ok = gid < p.n
            m[ok] = mg[gid[ok]]
            m = jnp.asarray(m)
        rng = key if self.rng_kind == "philox" else \
            lfsr_init(p.K * p.n_max, seed).reshape(p.K, p.n_max)
        ghosts = self._exchange_inst(m)
        zero = jnp.zeros((), dtype=jnp.int32)
        return DSIMState(m=m, ghosts=ghosts,
                         macc=jnp.zeros((p.K, p.n_max), jnp.float32),
                         rng=rng, sweep=zero, flips=zero)

    # -- exchange ---------------------------------------------------------------

    def _exchange_inst(self, m) -> jnp.ndarray:
        """Instantaneous 1-bit boundary states -> ghost slots (DSIM)."""
        flat = m.reshape(-1).astype(jnp.float32)
        return flat[self.p.ghost_src]

    def _exchange_mean(self, macc, S) -> jnp.ndarray:
        """Window-mean boundary values -> ghost slots (CMFT)."""
        flat = (macc / jnp.float32(S)).reshape(-1)
        return flat[self.p.ghost_src]

    # -- one color phase ----------------------------------------------------------

    def _phase(self, c: int, m, ghosts, rng, beta, lut=None):
        """``beta`` is the f32 inverse temperature — or, on the int8 path,
        the int32 LUT row index the staircase resolved to."""
        p = self.p
        int8 = lut is not None
        slots, mask = p.color_slots[c], p.color_mask[c]       # (K, nc)
        # (K, nc, D) neighbor slot ids -> per-partition-row gather (vmapped,
        # no (K, nc, n_max+g_max) broadcast is ever materialized)
        idx_c = jnp.take_along_axis(p.local_idx, slots[:, :, None], axis=1)
        # one gather/accumulate sequence for both precisions — only the
        # coupling source and accumulation dtype differ.  On the integer
        # pipeline ghosts are instantaneous +-1 states in dsim mode, so
        # the f32 state array casts losslessly to int32.
        acc = jnp.int32 if int8 else jnp.float32
        h_src, w_src = (self.local_h_q, self.local_w_q) if int8 else \
            (p.local_h, p.local_w)
        mext = jnp.concatenate([m.astype(acc), ghosts.astype(acc)], axis=1)
        w_c = jnp.take_along_axis(w_src, slots[:, :, None],
                                  axis=1).astype(acc)
        h_c = jnp.take_along_axis(h_src, slots, axis=1).astype(acc)
        nbr = jax.vmap(lambda row, ii: row[ii])(mext, idx_c)
        field = h_c + (w_c * nbr).sum(axis=-1)
        if self.rng_kind == "philox":
            rng, sub = jax.random.split(rng)
            r = jax.random.uniform(sub, field.shape, minval=-1.0, maxval=1.0)
        else:
            s = jnp.take_along_axis(rng, slots, axis=1)
            s = lfsr_next(s)
            # the int8 accept draws raw bits from s; materializing the f32
            # uniform too would put dead float math in the integer body
            # (contract rule IR-A)
            r = None if int8 else lfsr_uniform(s)
            rng = rng.at[self._rows, slots].set(s)
        old = jnp.take_along_axis(m, slots, axis=1)
        if int8:
            # pure-integer accept: raw 24-bit draw vs tabulated threshold
            u = s >> jnp.uint32(8)
            thr = jax.lax.dynamic_index_in_dim(lut,
                                               jnp.asarray(beta, jnp.int32),
                                               axis=0, keepdims=False)
            new = jnp.where(lut_accept(thr, field, self.f_max, u),
                            1, -1).astype(jnp.int8)
        else:
            act = quantize(beta * field, self.fmt)
            new = jnp.where(jnp.tanh(act) + r >= 0, 1, -1).astype(jnp.int8)
        new = jnp.where(mask, new, old)
        flips = (new != old).sum().astype(jnp.int32)
        m = m.at[self._rows, slots].set(new)
        return m, rng, flips

    def _sweep(self, m, ghosts, rng, beta, sync_phase: bool, lut=None):
        flips = jnp.zeros((), jnp.int32)
        for c in range(len(self.p.color_slots)):
            if sync_phase:
                ghosts = self._exchange_inst(m)
            m, rng, f = self._phase(c, m, ghosts, rng, beta, lut)
            flips = flips + f
        return m, ghosts, rng, flips

    # -- runners -----------------------------------------------------------------

    def _iteration(self, state: DSIMState, betas_S: jnp.ndarray,
                   sync: SyncSpec, lut=None) -> DSIMState:
        """S sweeps then one boundary exchange (or per-phase / none)."""
        m, ghosts, macc, rng = state.m, state.ghosts, state.macc, state.rng
        # flip odometer arithmetic is uint32-modular (contract rule IR-E);
        # the int32 state field is just the pytree/snapshot dtype view
        fl_u = jax.lax.bitcast_convert_type(state.flips, jnp.uint32)
        S = betas_S.shape[0]

        def body(carry, beta):
            m, ghosts, macc, rng, fl_u = carry
            m, ghosts, rng, f = self._sweep(m, ghosts, rng, beta,
                                            sync_phase=(sync == "phase"),
                                            lut=lut)
            if self.mode == "cmft":
                # dsim mode never reads the window accumulator — keeping
                # the add here would put dead f32 arithmetic in the int8
                # chunk body (contract rule IR-A)
                macc = macc + m.astype(jnp.float32)
            return (m, ghosts, macc, rng, fl_u + f.astype(jnp.uint32)), None

        (m, ghosts, macc, rng, fl_u), _ = jax.lax.scan(
            body, (m, ghosts, macc, rng, fl_u), betas_S)
        flips = jax.lax.bitcast_convert_type(fl_u, jnp.int32)
        if sync == "phase" or sync is None:
            pass  # ghosts already handled / never refreshed
        elif self.mode == "cmft":
            ghosts = self._exchange_mean(macc, S)
        else:
            ghosts = self._exchange_inst(m)
        macc = jnp.zeros_like(macc)
        return DSIMState(m=m, ghosts=ghosts, macc=macc, rng=rng,
                         sweep=state.sweep + S, flips=flips)

    @staticmethod
    def is_batched(state: DSIMState) -> bool:
        return state.m.ndim == 3

    def _run_chunk(self, iters: int, S: int, sync: SyncSpec,
                   batched: bool = False):
        key = (iters, S, sync, batched)
        if key not in self._chunk_cache:
            def one(st, b, lut):
                return self._iteration(st, b, sync, lut)
            it = one if not batched else \
                jax.vmap(one, in_axes=(0, None, None))

            @jax.jit
            def f(state, sched, *lut_opt):
                # sched (iters, S): f32 betas, or int32 LUT rows with the
                # threshold LUT as the trailing operand
                lut = lut_opt[0] if lut_opt else None

                def body(st, b):
                    return it(st, b, lut), None
                st, _ = jax.lax.scan(body, state, sched)
                return st
            self._chunk_cache[key] = f
        return self._chunk_cache[key]

    def run_recorded_full(self, state: DSIMState, schedule,
                          record_points: Sequence[int],
                          sync_every: SyncSpec = 1, cursor: bool = False):
        """Shared-driver runner; returns (state, RunRecord) — or, with
        ``cursor=True``, the resumable RecordedCursor."""
        sync = sync_every if sync_every in ("phase", None) else int(sync_every)
        batched = self.is_batched(state)
        R = state.m.shape[0] if batched else 1

        if self.precision == "int8":
            # the staircase becomes LUT row indices (beta is in the table)
            beta_arr = np.asarray(schedule.beta_array(), np.float32)
            table = beta_table(beta_arr)
            lut = self._lut_for(table)
            sched = ArraySchedule(beta_row_indices(beta_arr, table))

            def chunk(st, rows2d, iters, S):
                return self._run_chunk(iters, S, sync, batched)(st, rows2d,
                                                                lut)
        else:
            sched = schedule

            def chunk(st, betas2d, iters, S):
                return self._run_chunk(iters, S, sync, batched)(st, betas2d)

        kw = dict(
            state=state, schedule=sched, record_points=record_points,
            chunk_fn=chunk, record_fn=self.energy, sync_every=sync_every,
            flips_of=lambda st: st.flips, flips_per_sweep=self.p.n * R)
        if cursor:
            return RecordedCursor(**kw)
        return run_recorded_driver(**kw)

    def run_recorded(self, state: DSIMState, schedule,
                     record_points: Sequence[int],
                     sync_every: SyncSpec = 1):
        """Run to each record point; returns (state, (times, energies)).

        ``sync_every``: int S (exchange every S sweeps), 'phase', or None.
        Record points are quantized to multiples of S.
        """
        return self.run_recorded_full(state, schedule, record_points,
                                      sync_every=sync_every)

    # -- observables ----------------------------------------------------------------

    def global_spins(self, state: DSIMState) -> jnp.ndarray:
        if self.is_batched(state):
            return jax.vmap(self._global_spins_impl)(state.m)
        return self._global_spins_impl(state.m)

    def _global_spins_impl(self, m: jnp.ndarray) -> jnp.ndarray:
        p = self.p
        buf = jnp.ones((p.n + 1,), dtype=jnp.int8)
        buf = buf.at[p.global_ids.reshape(-1)].set(m.reshape(-1))
        return buf[: p.n]

    def _energy_impl(self, state: DSIMState) -> jnp.ndarray:
        spins = self.global_spins(state)
        if self.is_batched(state):
            return jax.vmap(lambda m: direct_energy(self.p.graph, m))(spins)
        return direct_energy(self.p.graph, spins)

    def energy(self, state: DSIMState) -> jnp.ndarray:
        """True global energy of the current configuration."""
        return self._energy(state)

    def local_fields_check(self, state: DSIMState) -> jnp.ndarray:
        """Global-layout local fields as the partitions see them (tests)."""
        p = self.p
        mext = jnp.concatenate([state.m.astype(jnp.float32), state.ghosts], axis=1)
        nbr = jax.vmap(lambda row, ii: row[ii])(mext, p.local_idx)
        f = p.local_h + (p.local_w * nbr).sum(axis=-1)       # (K, n_max)
        buf = jnp.zeros((p.n + 1,), dtype=jnp.float32)
        buf = buf.at[p.global_ids.reshape(-1)].set(f.reshape(-1))
        return buf[: p.n]
