"""Structured-lattice problem builder for the brick-partitioned DSIM.

The 3D EA lattice is stored as six directional weight arrays (one per
neighbor direction) so the update kernel needs no index traffic at all —
the TPU-native equivalent of the FPGA's hardwired neighbor fabric.  The
weights are generated from the *same* edge list as :func:`repro.core.graph.ea3d`
(same seed -> identical couplings), so structured and ELL engines are
cross-checkable.

x and y (open boundaries) may be zero-padded up to mesh-divisible extents;
z (periodic) must divide its mesh factor exactly, because the wrap edge is
carried by the ring ppermute.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np
import jax.numpy as jnp

from .graph import ea3d_edges
from .coloring import lattice3d_coloring

__all__ = ["LatticeProblem", "build_ea3d_lattice"]


@dataclasses.dataclass(frozen=True)
class LatticeProblem:
    L: int                      # active cubic extent
    dims: Tuple[int, int, int]  # padded global dims (X, Y, Z); Z == L
    seed: int
    n_colors: int
    h: jnp.ndarray              # (X, Y, Z) f32
    w6: tuple                   # 6 x (X, Y, Z) f32: to -x, +x, -y, +y, -z, +z
    masks: jnp.ndarray          # (n_colors, X, Y, Z) int8 update masks
    active: jnp.ndarray         # (X, Y, Z) int8

    @property
    def n_active(self) -> int:
        return self.L ** 3


def build_ea3d_lattice(L: int, seed: int = 0,
                       pad_xy: Optional[Tuple[int, int]] = None
                       ) -> LatticeProblem:
    ei, ej, ew = ea3d_edges(L, seed)
    X, Y = (L, L) if pad_xy is None else pad_xy
    if X < L or Y < L:
        raise ValueError("padding must not shrink the lattice")
    Z = L
    shape = (X, Y, Z)

    def coords(n):
        x, r = np.divmod(n, L * L)
        y, z = np.divmod(r, L)
        return x, y, z

    xi, yi, zi = coords(ei)
    xj, yj, zj = coords(ej)
    w6 = [np.zeros(shape, dtype=np.float32) for _ in range(6)]
    WXM, WXP, WYM, WYP, WZM, WZP = range(6)

    dx, dy = xj - xi, yj - yi
    dz = zj - zi
    # +x edges (i -> j at x+1)
    m = dx == 1
    w6[WXP][xi[m], yi[m], zi[m]] = ew[m]
    w6[WXM][xj[m], yj[m], zj[m]] = ew[m]
    # +y edges
    m = dy == 1
    w6[WYP][xi[m], yi[m], zi[m]] = ew[m]
    w6[WYM][xj[m], yj[m], zj[m]] = ew[m]
    # +z edges including the periodic wrap (dz == -(L-1) means zi == L-1 -> 0)
    m = (dz == 1) | (dz == -(L - 1))
    w6[WZP][xi[m], yi[m], zi[m]] = ew[m]
    w6[WZM][xj[m], yj[m], zj[m]] = ew[m]

    active = np.zeros(shape, dtype=np.int8)
    active[:L, :L, :L] = 1

    col = lattice3d_coloring(L)
    colors = col.colors.reshape(L, L, L)
    masks = np.zeros((col.n_colors,) + shape, dtype=np.int8)
    for c in range(col.n_colors):
        masks[c, :L, :L, :L] = (colors == c).astype(np.int8)

    return LatticeProblem(
        L=L, dims=shape, seed=seed, n_colors=col.n_colors,
        h=jnp.zeros(shape, jnp.float32),
        w6=tuple(jnp.asarray(w) for w in w6),
        masks=jnp.asarray(masks), active=jnp.asarray(active),
    )
